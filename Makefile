# Build-time entry points. Python runs ONLY here (AOT lowering); the Rust
# side consumes the resulting artifacts/ directory at run time.

PY ?= python3

.PHONY: artifacts artifacts-paper ci doc train-smoke sync-smoke plan-smoke exec-smoke shm-smoke \
        net-smoke cfd-smoke trace-smoke audit loom miri tsan asan

# Standard artifact set: training/demo variant + the second-Reynolds
# scenario, plus the B=8 batched-serving executable.
artifacts:
	cd python && $(PY) -m compile.aot --out ../artifacts --variants small,re200

# Paper-fidelity grid (slow: long base-flow development).
artifacts-paper:
	cd python && $(PY) -m compile.aot --out ../artifacts --variants paper

# Tier-1 gate (fmt, clippy, release build, docs, tests, smokes).
ci:
	./ci.sh

# Repo-invariant audit (ARCHITECTURE.md §9): SAFETY comments on every
# unsafe, determinism bans (hash collections / wall clock / f32 sums) in
# the bitwise-pinned modules, wire-tag coverage. Exceptions live in
# rust/audit.allow. Runs unconditionally in ci.sh too.
audit:
	cargo run --release --quiet -- audit

# Loom model checking of the seqlock ring protocol (exhaustive
# interleavings of publish/consume, wraparound, torn writes, the
# drain-before-Died handshake). Needs the loom dev-dependency.
loom:
	RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
	    cargo test --release --test loom_shm

# Miri over the safe codec layers (wire frames, exchange interfaces,
# trajectory buffer). Needs a nightly toolchain with miri installed.
miri:
	MIRIFLAGS="-Zmiri-strict-provenance" cargo +nightly miri test --lib \
	    exec::wire io_interface drl::buffer

# Sanitizers over the concurrent exec/transport tests (real mmap ring,
# OS threads/processes). Need nightly + rust-src for -Zbuild-std.
tsan:
	RUSTFLAGS="-Zsanitizer=thread" \
	    cargo +nightly test -Zbuild-std \
	    --target "$$(rustc -vV | sed -n 's/^host: //p')" \
	    --test exec_backend --test exec_transport_conformance

asan:
	RUSTFLAGS="-Zsanitizer=address" \
	    cargo +nightly test -Zbuild-std \
	    --target "$$(rustc -vV | sed -n 's/^host: //p')" \
	    --test exec_backend --test exec_transport_conformance

# Rustdoc gate: warning-free docs + runnable doctests (same as ci.sh).
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	cargo test --doc

# Artifact-free end-to-end training smoke: surrogate scenario + native
# policy/update backends; runs in seconds without `make artifacts`.
train-smoke:
	cargo run --release -- train \
	    --scenario surrogate --backend native --update-backend native \
	    --artifacts out/train-smoke/no-artifacts \
	    --out out/train-smoke --work-dir out/train-smoke/work \
	    --envs 2 --horizon 10 --iterations 3

# Planner smoke: rank a small core budget, then let --layout auto pick
# and train the winning (envs, sync, io) layout artifact-free.
plan-smoke:
	cargo run --release -- plan --cores 12 --episodes 240 --out out/plan-smoke
	cargo run --release -- train \
	    --scenario surrogate --backend native --update-backend native \
	    --layout auto --cores 4 \
	    --artifacts out/plan-smoke/no-artifacts \
	    --out out/plan-smoke/auto --work-dir out/plan-smoke/auto/work \
	    --horizon 5 --iterations 2

# Multi-process executor smoke: the artifact-free loop on real
# `drlfoam worker` OS processes, then once more with a chaos-injected
# worker crash (respawn + episode re-queue must keep training green).
exec-smoke:
	cargo run --release -- train \
	    --scenario analytic --backend native --update-backend native \
	    --executor multi-process \
	    --artifacts out/exec-smoke/no-artifacts \
	    --out out/exec-smoke --work-dir out/exec-smoke/work \
	    --envs 2 --horizon 10 --iterations 3
	cargo run --release -- train \
	    --scenario analytic --backend native --update-backend native \
	    --executor multi-process --chaos 0:1 \
	    --artifacts out/exec-smoke/no-artifacts \
	    --out out/exec-smoke/chaos --work-dir out/exec-smoke/chaos/work \
	    --envs 2 --horizon 10 --iterations 3

# Shared-memory transport smoke: the artifact-free multi-process loop
# once per transport, bitwise-diffed (learning columns of train_log.csv
# + policy_final.bin), then the exec_transport bench's throughput gate
# (shm lockstep steps/s must not fall below pipe).
shm-smoke:
	for t in pipe shm; do \
	    cargo run --release --quiet -- train \
	        --scenario surrogate --backend native --update-backend native \
	        --executor multi-process --transport $$t \
	        --artifacts out/shm-smoke/no-artifacts \
	        --out out/shm-smoke/$$t --work-dir out/shm-smoke/$$t/work \
	        --envs 2 --horizon 5 --iterations 2 --quiet || exit 1; \
	done
	cut -d, -f1-9 out/shm-smoke/pipe/train_log.csv > out/shm-smoke/pipe-learning.csv
	cut -d, -f1-9 out/shm-smoke/shm/train_log.csv > out/shm-smoke/shm-learning.csv
	cmp out/shm-smoke/pipe-learning.csv out/shm-smoke/shm-learning.csv
	cmp out/shm-smoke/pipe/policy_final.bin out/shm-smoke/shm/policy_final.bin
	cargo bench --bench exec_transport -- --gate

# Socket transport smoke: train --transport tcp with both workers behind
# a real `drlfoam agent` on localhost, bitwise-diffed against the pipe
# transport (learning columns + policy_final.bin), then the
# exec_transport bench's throughput gate (shm and uds lockstep steps/s
# must not fall below pipe).
net-smoke:
	rm -rf out/net-smoke
	mkdir -p out/net-smoke
	cargo build --release
	cargo run --release --quiet -- train \
	    --scenario surrogate --backend native --update-backend native \
	    --executor multi-process --transport pipe \
	    --artifacts out/net-smoke/no-artifacts \
	    --out out/net-smoke/pipe --work-dir out/net-smoke/pipe/work \
	    --envs 2 --horizon 5 --iterations 2 --quiet
	@# the agent must outlive the training run, so it runs from the built
	@# binary (killing a wrapping `cargo run` would orphan the listener)
	target/release/drlfoam agent --bind 127.0.0.1:7912 \
	    > out/net-smoke/agent.log 2>&1 & \
	AGENT_PID=$$!; \
	for _ in $$(seq 1 100); do \
	    grep -q "agent listening on" out/net-smoke/agent.log 2>/dev/null && break; \
	    sleep 0.1; \
	done; \
	cargo run --release --quiet -- train \
	    --scenario surrogate --backend native --update-backend native \
	    --executor multi-process --transport tcp --hosts 127.0.0.1:7912:2 \
	    --artifacts out/net-smoke/no-artifacts \
	    --out out/net-smoke/tcp --work-dir out/net-smoke/tcp/work \
	    --envs 2 --horizon 5 --iterations 2 --quiet; \
	STATUS=$$?; kill $$AGENT_PID 2>/dev/null || true; exit $$STATUS
	cut -d, -f1-9 out/net-smoke/pipe/train_log.csv > out/net-smoke/pipe-learning.csv
	cut -d, -f1-9 out/net-smoke/tcp/train_log.csv > out/net-smoke/tcp-learning.csv
	cmp out/net-smoke/pipe-learning.csv out/net-smoke/tcp-learning.csv
	cmp out/net-smoke/pipe/policy_final.bin out/net-smoke/tcp/policy_final.bin
	cargo bench --bench exec_transport -- --gate

# Native CFD engine smoke: cylinder training with zero artifacts on the
# pure-Rust engine (--cfd-backend native), bitwise-diffed across a
# re-run and a forced-scalar single-thread run, then the cfd_scaling
# bench's SIMD-vs-scalar throughput gate.
cfd-smoke:
	for v in a b; do \
	    DRLFOAM_CFD_THREADS=2 cargo run --release --quiet -- train \
	        --scenario cylinder --variant tiny --cfd-backend native \
	        --backend native --update-backend native \
	        --artifacts out/cfd-smoke/no-artifacts \
	        --out out/cfd-smoke/$$v --work-dir out/cfd-smoke/$$v/work \
	        --envs 2 --horizon 3 --iterations 2 --quiet || exit 1; \
	done
	DRLFOAM_CFD_THREADS=1 DRLFOAM_FORCE_SCALAR=1 cargo run --release --quiet -- train \
	    --scenario cylinder --variant tiny --cfd-backend native \
	    --backend native --update-backend native \
	    --artifacts out/cfd-smoke/no-artifacts \
	    --out out/cfd-smoke/scalar --work-dir out/cfd-smoke/scalar/work \
	    --envs 2 --horizon 3 --iterations 2 --quiet
	cut -d, -f1-9 out/cfd-smoke/a/train_log.csv > out/cfd-smoke/a-learning.csv
	cut -d, -f1-9 out/cfd-smoke/b/train_log.csv > out/cfd-smoke/b-learning.csv
	cut -d, -f1-9 out/cfd-smoke/scalar/train_log.csv > out/cfd-smoke/scalar-learning.csv
	cmp out/cfd-smoke/a-learning.csv out/cfd-smoke/b-learning.csv
	cmp out/cfd-smoke/a-learning.csv out/cfd-smoke/scalar-learning.csv
	cmp out/cfd-smoke/a/policy_final.bin out/cfd-smoke/b/policy_final.bin
	cmp out/cfd-smoke/a/policy_final.bin out/cfd-smoke/scalar/policy_final.bin
	cargo bench --bench cfd_scaling -- --gate

# Tracing smoke: a traced in-process run re-parsed by `drlfoam trace`,
# then the acceptance topology — two localhost `drlfoam agent` processes,
# one merged trace with a lane per host, drift.csv populated, and the
# traced run bitwise-identical to its untraced twin — then the
# episode_breakdown bench's overhead gate (tracing <=2% lockstep
# steps/s).
trace-smoke:
	rm -rf out/trace-smoke
	mkdir -p out/trace-smoke
	cargo build --release
	cargo run --release --quiet -- train \
	    --scenario surrogate --backend native --update-backend native \
	    --artifacts out/trace-smoke/no-artifacts \
	    --out out/trace-smoke/ip --work-dir out/trace-smoke/ip/work \
	    --trace out/trace-smoke/ip/trace.json \
	    --envs 2 --horizon 5 --iterations 2 --quiet
	cargo run --release --quiet -- trace out/trace-smoke/ip/trace.json
	@# agents must outlive the training runs, so they run from the built
	@# binary (killing a wrapping `cargo run` would orphan the listeners)
	target/release/drlfoam agent --bind 127.0.0.1:7915 \
	    > out/trace-smoke/agent-a.log 2>&1 & \
	AGENT_A=$$!; \
	target/release/drlfoam agent --bind 127.0.0.1:7916 \
	    > out/trace-smoke/agent-b.log 2>&1 & \
	AGENT_B=$$!; \
	for log in out/trace-smoke/agent-a.log out/trace-smoke/agent-b.log; do \
	    for _ in $$(seq 1 100); do \
	        grep -q "agent listening on" $$log 2>/dev/null && break; \
	        sleep 0.1; \
	    done; \
	done; \
	cargo run --release --quiet -- train \
	    --scenario surrogate --backend native --update-backend native \
	    --executor multi-process --transport tcp \
	    --hosts 127.0.0.1:7915:1,127.0.0.1:7916:1 \
	    --artifacts out/trace-smoke/no-artifacts \
	    --out out/trace-smoke/plain --work-dir out/trace-smoke/plain/work \
	    --envs 2 --horizon 5 --iterations 2 --quiet && \
	cargo run --release --quiet -- train \
	    --scenario surrogate --backend native --update-backend native \
	    --executor multi-process --transport tcp \
	    --hosts 127.0.0.1:7915:1,127.0.0.1:7916:1 \
	    --artifacts out/trace-smoke/no-artifacts \
	    --out out/trace-smoke/traced --work-dir out/trace-smoke/traced/work \
	    --trace out/trace-smoke/traced/trace.json \
	    --envs 2 --horizon 5 --iterations 2 --quiet; \
	STATUS=$$?; kill $$AGENT_A $$AGENT_B 2>/dev/null || true; exit $$STATUS
	grep -q "127.0.0.1:7915" out/trace-smoke/traced/trace.json
	grep -q "127.0.0.1:7916" out/trace-smoke/traced/trace.json
	cut -d, -f1-9 out/trace-smoke/plain/train_log.csv > out/trace-smoke/plain-learning.csv
	cut -d, -f1-9 out/trace-smoke/traced/train_log.csv > out/trace-smoke/traced-learning.csv
	cmp out/trace-smoke/plain-learning.csv out/trace-smoke/traced-learning.csv
	cmp out/trace-smoke/plain/policy_final.bin out/trace-smoke/traced/policy_final.bin
	cargo bench --bench episode_breakdown -- --gate

# Rollout-scheduler smoke: the same artifact-free loop once per sync
# policy (full episode barrier, partial barrier, async).
sync-smoke:
	for s in full partial:2 async; do \
	    cargo run --release --quiet -- train \
	        --scenario surrogate --backend native --update-backend native \
	        --sync $$s \
	        --artifacts out/sync-smoke/no-artifacts \
	        --out out/sync-smoke/$$s --work-dir out/sync-smoke/$$s/work \
	        --envs 3 --horizon 5 --iterations 2 --quiet || exit 1; \
	done
