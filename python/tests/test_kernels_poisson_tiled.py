"""Row-panel-tiled SOR kernel (the TPU schedule) vs the oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import poisson, poisson_tiled, ref
from tests.test_kernels_poisson import masks, rand_field


@settings(max_examples=12, deadline=None)
@given(
    blocks=st.integers(2, 6),
    block_rows=st.sampled_from([4, 8]),
    nx=st.integers(6, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_panel_interiors_match_reference(blocks, block_rows, nx, seed):
    """Away from panel boundaries the tiled sweep must equal the
    sequential red-black sweep exactly."""
    ny = blocks * block_rows
    p = rand_field(seed, ny, nx)
    rhs = rand_field(seed + 1, ny, nx)
    red, black, _ = masks(ny, nx)
    h, omega = 0.1, 1.6
    got = np.asarray(poisson_tiled.rb_sor_sweep_tiled(
        p, rhs, red, black, omega=omega, h=h, block_rows=block_rows))
    want = np.asarray(ref.rb_sor_sweep(p, rhs, red, black, omega, h))
    # rows adjacent to a panel boundary may differ (block-async relaxation)
    for b in range(blocks):
        r0, r1 = b * block_rows, (b + 1) * block_rows
        inner = slice(r0 + 1, r1 - 1)
        np.testing.assert_allclose(got[inner], want[inner],
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"panel {b}")


def test_boundary_rows_pass_through():
    ny, nx = 24, 20
    p = rand_field(3, ny, nx)
    rhs = rand_field(4, ny, nx)
    red, black, _ = masks(ny, nx)
    out = np.asarray(poisson_tiled.rb_sor_sweep_tiled(
        p, rhs, red, black, omega=1.7, h=0.1, block_rows=8))
    np.testing.assert_array_equal(out[0, :], p[0, :])
    np.testing.assert_array_equal(out[-1, :], p[-1, :])
    np.testing.assert_array_equal(out[:, 0], p[:, 0])
    np.testing.assert_array_equal(out[:, -1], p[:, -1])


@pytest.mark.parametrize("block_rows", [4, 8, 16])
def test_global_residual_contracts(block_rows):
    """Block-asynchronous relaxation must still solve the system."""
    ny, nx, h = 32, 32, 0.1
    rhs = rand_field(7, ny, nx)
    red, black, interior = masks(ny, nx)
    rhs = rhs * interior
    p = jnp.zeros((ny, nx), jnp.float32)
    r0 = float(ref.poisson_residual(p, rhs, h, interior))
    for _ in range(200):
        p = poisson_tiled.rb_sor_sweep_tiled(
            p, rhs, red, black, omega=1.6, h=h, block_rows=block_rows)
    r1 = float(ref.poisson_residual(p, rhs, h, interior))
    assert r1 < 0.05 * r0, (r0, r1)


def test_single_panel_equals_untiled():
    """block_rows == ny reduces to the production whole-array kernel."""
    ny, nx = 16, 24
    p = rand_field(0, ny, nx)
    rhs = rand_field(1, ny, nx)
    red, black, _ = masks(ny, nx)
    a = poisson_tiled.rb_sor_sweep_tiled(p, rhs, red, black,
                                         omega=1.7, h=0.1, block_rows=ny)
    b = poisson.rb_sor_sweep(p, rhs, red, black, omega=1.7, h=0.1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-7)


def test_vmem_budget_paper_grid():
    # paper grid nx=515, B=32: comfortably under VMEM with double buffering
    assert poisson_tiled.vmem_per_instance(32, 515) < 2 * 2**20
