"""Pallas red-black SOR kernel vs the pure-jnp oracle (ref.py)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import poisson, ref


def masks(ny, nx):
    jj, ii = np.meshgrid(np.arange(ny), np.arange(nx), indexing="ij")
    interior = (jj > 0) & (jj < ny - 1) & (ii > 0) & (ii < nx - 1)
    red = (((jj + ii) % 2 == 0) & interior).astype(np.float32)
    black = (((jj + ii) % 2 == 1) & interior).astype(np.float32)
    return red, black, interior.astype(np.float32)


def rand_field(seed, ny, nx, scale=1.0):
    return (np.random.default_rng(seed).standard_normal((ny, nx)) * scale
            ).astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(
    ny=st.integers(4, 40),
    nx=st.integers(4, 64),
    seed=st.integers(0, 2**31 - 1),
    omega=st.floats(0.5, 1.95),
)
def test_matches_reference(ny, nx, seed, omega):
    p = rand_field(seed, ny, nx)
    rhs = rand_field(seed + 1, ny, nx)
    red, black, _ = masks(ny, nx)
    h = 0.1
    got = poisson.rb_sor_sweep(p, rhs, red, black, omega=omega, h=h)
    want = ref.rb_sor_sweep(p, rhs, red, black, omega, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_boundary_cells_untouched():
    ny, nx = 16, 24
    p = rand_field(0, ny, nx)
    rhs = rand_field(1, ny, nx)
    red, black, _ = masks(ny, nx)
    out = np.asarray(poisson.rb_sor_sweep(p, rhs, red, black, omega=1.7, h=0.1))
    np.testing.assert_array_equal(out[0, :], p[0, :])
    np.testing.assert_array_equal(out[-1, :], p[-1, :])
    np.testing.assert_array_equal(out[:, 0], p[:, 0])
    np.testing.assert_array_equal(out[:, -1], p[:, -1])


@pytest.mark.parametrize("omega", [1.0, 1.5, 1.7])
def test_residual_contracts(omega):
    """Sweeping must monotonically (on average) reduce the Poisson residual
    for a zero-Dirichlet problem."""
    ny, nx, h = 32, 32, 0.1
    rhs = rand_field(7, ny, nx, scale=1.0)
    red, black, interior = masks(ny, nx)
    p = jnp.zeros((ny, nx), jnp.float32)
    r0 = float(ref.poisson_residual(p, rhs * interior, h, interior))
    for _ in range(200):
        p = poisson.rb_sor_sweep(p, rhs * interior, red, black, omega=omega, h=h)
    r1 = float(ref.poisson_residual(p, rhs * interior, h, interior))
    assert r1 < 0.05 * r0, (r0, r1)


def test_sor_faster_than_jacobi_like():
    """omega=1.7 must converge faster than omega=1.0 (Gauss-Seidel)."""
    ny, nx, h = 32, 32, 0.1
    rhs = rand_field(3, ny, nx)
    red, black, interior = masks(ny, nx)
    rhs = rhs * interior

    def run(omega, n):
        p = jnp.zeros((ny, nx), jnp.float32)
        for _ in range(n):
            p = poisson.rb_sor_sweep(p, rhs, red, black, omega=omega, h=h)
        return float(ref.poisson_residual(p, rhs, h, interior))

    assert run(1.7, 60) < run(1.0, 60)


def test_vmem_estimate():
    # paper grid: (96 rows, 515 cols) panels of 32 rows -> well under 16 MiB
    assert poisson.vmem_bytes(32, 515) < 16 * 2**20


def test_dtype_support_f64():
    """The shipped artifacts are f32; numerics-debug runs use f64 — the
    kernel must agree with the oracle there too."""
    import jax
    ny, nx, h = 12, 16, 0.1
    rng = np.random.default_rng(0)
    with jax.experimental.enable_x64():
        p = rng.standard_normal((ny, nx))
        rhs = rng.standard_normal((ny, nx))
        red, black, _ = masks(ny, nx)
        got = poisson.rb_sor_sweep(p, rhs, red.astype(np.float64),
                                   black.astype(np.float64), omega=1.5, h=h)
        want = ref.rb_sor_sweep(p, rhs, red.astype(np.float64),
                                black.astype(np.float64), 1.5, h)
        assert np.asarray(got).dtype == np.float64
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-12)


def test_sweep_is_idempotent_on_converged_solution():
    """If p already solves the system exactly, a sweep must not move it
    (fixed point of the SOR iteration)."""
    ny, nx, h = 16, 16, 0.2
    # build p first, then define rhs = lap(p): p is then an exact solution
    p = rand_field(11, ny, nx)
    rhs = np.asarray(ref.laplacian(p, h))
    red, black, interior = masks(ny, nx)
    out = np.asarray(poisson.rb_sor_sweep(p, rhs, red, black, omega=1.7, h=h))
    np.testing.assert_allclose(out[1:-1, 1:-1], p[1:-1, 1:-1],
                               rtol=1e-4, atol=1e-5)
