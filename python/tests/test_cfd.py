"""CFD solver invariants: geometry, BCs, projection, forces, probes."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import cfd
from compile.configs import TINY, SMALL, VARIANTS
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny_geom():
    return cfd.build_geometry(TINY)


@pytest.fixture(scope="module")
def small_geom():
    return cfd.build_geometry(SMALL)


class TestGeometry:
    def test_solid_is_cylinder(self, small_geom):
        g, cfg = small_geom, SMALL
        area = g.solid.sum() * cfg.h * cfg.h
        assert abs(area - np.pi * cfg.radius**2) / (np.pi * cfg.radius**2) < 0.15

    def test_jets_have_cells_and_balance(self, small_geom):
        g = small_geom
        jet_cells = (np.abs(g.jet_u) + np.abs(g.jet_v)) > 0
        assert jet_cells.sum() >= 4, "each jet needs >=2 cells on this grid"
        # jets are inside the solid shell
        assert np.all(g.solid[jet_cells] == 1.0)
        # V_G1 = -V_G2: net mass flux of the unit-action jet field ~ 0
        # (top jet blows radially out, bottom sucks radially in)
        net = g.jet_v.sum()
        gross = np.abs(g.jet_v).sum()
        assert gross > 0
        # both jets point +y for positive action: v-components add up
        assert net > 0.9 * gross

    def test_inlet_profile(self, small_geom):
        g, cfg = small_geom, SMALL
        # parabola peaks at channel centre with Um = 1.5 Ubar
        assert abs(g.u_in.max() - cfg.u_max) < 0.01
        assert g.u_in[0] >= 0 and g.u_in[-1] >= 0
        # mean over the channel ~ Ubar (Eq. 5)
        assert abs(g.u_in.mean() - cfg.u_mean) < 0.05

    def test_checkerboard_partition(self, small_geom):
        g = small_geom
        assert np.all(g.red * g.black == 0)
        inter = g.interior
        np.testing.assert_array_equal(g.red + g.black, inter)

    def test_probes_inside_domain(self, small_geom):
        g, cfg = small_geom, SMALL
        assert g.probe_xy.shape == (149, 2)
        assert np.all(g.probe_xy[:, 0] > -cfg.x_up)
        assert np.all(g.probe_xy[:, 0] < cfg.x_down)
        assert np.all(g.probe_xy[:, 1] > cfg.y_lo)
        assert np.all(g.probe_xy[:, 1] < cfg.y_hi)
        # no probe inside the cylinder
        r = np.hypot(g.probe_xy[:, 0], g.probe_xy[:, 1])
        assert np.all(r > cfg.radius)

    def test_probe_weights_partition_of_unity(self, small_geom):
        np.testing.assert_allclose(small_geom.probe_w.sum(axis=1), 1.0,
                                   rtol=1e-5)


class TestBCs:
    def test_velocity_bcs(self, tiny_geom):
        g, cfg = tiny_geom, TINY
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((cfg.ny, cfg.nx)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((cfg.ny, cfg.nx)), jnp.float32)
        u, v = cfd.apply_vel_bcs(u, v, jnp.asarray(g.u_in))
        u, v = np.asarray(u), np.asarray(v)
        np.testing.assert_allclose(u[1:-1, 0], g.u_in[1:-1], rtol=1e-6)
        np.testing.assert_array_equal(v[1:-1, 0], 0.0)
        np.testing.assert_array_equal(u[:, -1], u[:, -2])
        np.testing.assert_array_equal(u[0, :], 0.0)
        np.testing.assert_array_equal(u[-1, :], 0.0)
        np.testing.assert_array_equal(v[0, :], 0.0)

    def test_pressure_bcs(self):
        rng = np.random.default_rng(1)
        p = jnp.asarray(rng.standard_normal((12, 20)), jnp.float32)
        p = np.asarray(cfd.apply_pressure_bcs(p))
        np.testing.assert_array_equal(p[:, -1], 0.0)
        np.testing.assert_array_equal(p[1:-1, 0], p[1:-1, 1])
        np.testing.assert_array_equal(p[0, :], p[1, :])


class TestSolver:
    def test_probe_sampling_exact_for_linear_field(self, small_geom):
        g, cfg = small_geom, SMALL
        X, Y = np.meshgrid(g.xc, g.yc)
        p = (0.3 * X - 0.7 * Y + 1.0).astype(np.float32)
        got = np.asarray(cfd.sample_probes(jnp.asarray(p), g))
        want = 0.3 * g.probe_xy[:, 0] - 0.7 * g.probe_xy[:, 1] + 1.0
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_substep_reduces_divergence(self, tiny_geom):
        """The projection must make div(u) small relative to pre-projection."""
        g, cfg = tiny_geom, TINY
        substep = jax.jit(cfd.make_substep_fn(cfg, g))
        u, v, p = cfd.quiescent_state(cfg, g)
        state = (u, v, p)
        for _ in range(10):
            state, _ = substep(state, jnp.float32(0.0))
        u, v, p = state
        # exclude the IBM shell: direct re-forcing after projection leaves
        # O(1) divergence in the 1-2 cells hugging the body (expected for
        # this class of IBM); the bulk fluid must be far cleaner.
        X, Y = np.meshgrid(g.xc, g.yc)
        away = (np.hypot(X, Y) > cfg.radius + 2.5 * cfg.h).astype(np.float32)
        inter = np.asarray(g.interior) * away
        div = np.abs(np.asarray(ref.divergence(u, v, cfg.h)) * inter)
        # scale: u_max/h would be O(30); projected flow must be far below
        assert div.max() < 0.5, div.max()

    def test_uncontrolled_drag_positive_and_plausible(self, tiny_geom):
        g, cfg = tiny_geom, TINY
        u, v, p, cds, cls = cfd.develop_base_flow(cfg, g, time_units=3.0)
        assert cds[-1] > 1.0, "drag must be positive and O(1)"
        assert cds[-1] < 10.0
        assert np.all(np.isfinite(cds)) and np.all(np.isfinite(cls))

    def test_jet_changes_flow_and_lift(self, tiny_geom):
        """Blowing must alter the force history vs the uncontrolled run."""
        g, cfg = tiny_geom, TINY
        period = jax.jit(cfd.make_period_fn(cfg, g))
        u, v, p, _, _ = cfd.develop_base_flow(cfg, g, time_units=2.0)
        _, _, _, _, cd0, cl0 = period(u, v, p, jnp.float32(0.0))
        _, _, _, _, cd1, cl1 = period(u, v, p, jnp.float32(1.0))
        assert float(jnp.mean(jnp.abs(cl1 - cl0))) > 1e-3

    def test_pallas_and_ref_paths_agree(self, tiny_geom):
        g, cfg = tiny_geom, TINY
        sp = jax.jit(cfd.make_substep_fn(cfg, g, use_pallas=True))
        sr = jax.jit(cfd.make_substep_fn(cfg, g, use_pallas=False))
        state = cfd.quiescent_state(cfg, g)
        s1, (cd1, cl1) = sp(state, jnp.float32(0.3))
        s2, (cd2, cl2) = sr(state, jnp.float32(0.3))
        for a, b in zip(s1, s2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        assert abs(float(cd1) - float(cd2)) < 1e-3

    def test_period_fn_shapes(self, tiny_geom):
        g, cfg = tiny_geom, TINY
        period = jax.jit(cfd.make_period_fn(cfg, g))
        u, v, p = cfd.quiescent_state(cfg, g)
        u2, v2, p2, probes, cd_h, cl_h = period(u, v, p, jnp.float32(0.0))
        assert u2.shape == (cfg.ny, cfg.nx)
        assert probes.shape == (149,)
        assert cd_h.shape == (cfg.substeps,)
        assert cl_h.shape == (cfg.substeps,)


class TestStability:
    def test_all_variants_stable_configs(self):
        for cfg in VARIANTS.values():
            cfg.check_stability()

    def test_long_run_bounded(self, tiny_geom):
        g, cfg = tiny_geom, TINY
        u, v, p, cds, _ = cfd.develop_base_flow(cfg, g, time_units=5.0)
        assert float(jnp.max(jnp.abs(u))) < 10.0
        assert np.all(np.isfinite(np.asarray(u)))
