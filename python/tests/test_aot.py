"""AOT pipeline: HLO text well-formedness, manifest schema, bin layouts,
and a full python-side round-trip through the XLA client (the same parser
the Rust runtime uses)."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, cfd, model
from compile.configs import TINY, DRL

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def lower_tiny_period():
    geom = cfd.build_geometry(TINY)
    return aot.lower_cfd_period(TINY, geom), geom


class TestLowering:
    def test_hlo_text_structure(self):
        text, _ = lower_tiny_period()
        assert "ENTRY" in text
        assert "HloModule" in text

    def test_hlo_text_reparses(self):
        """Round-trip through the HLO-text parser. (jaxlib 0.8 dropped the
        python-side proto-compile API, so *numeric* round-trip equivalence
        is asserted on the Rust side in rust/tests/runtime_load.rs, which
        uses the same text parser via xla_extension.)"""
        text, geom = lower_tiny_period()
        cfg = TINY
        module = xc._xla.hlo_module_from_text(text)
        again = module.to_string()
        assert "ENTRY" in again
        # parameters survive with shapes intact
        assert f"f32[{cfg.ny},{cfg.nx}]" in again
        # output tuple: 3 fields + probes + 2 histories
        assert f"f32[{cfg.substeps}]" in again

    def test_policy_apply_lowering(self):
        text = aot.lower_policy_apply(1)
        assert "ENTRY" in text
        # parameter count: flat + obs
        assert text.count("parameter(") >= 2

    def test_ppo_update_lowering(self):
        text = aot.lower_ppo_update()
        assert "ENTRY" in text
        assert text.count("parameter(") >= 9


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="run `make artifacts` first")
class TestShippedArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_schema(self, manifest):
        assert manifest["format_version"] == 1
        d = manifest["drl"]
        assert d["n_obs"] == 149
        assert d["n_params"] == DRL.n_params
        layout = d["param_layout"]
        assert layout[0]["name"] == "w1"
        off = 0
        for s in layout:
            assert s["offset"] == off
            off += int(np.prod(s["shape"]))
        assert off == d["n_params"]

    def test_variant_entries(self, manifest):
        for name, v in manifest["variants"].items():
            assert os.path.exists(os.path.join(ARTIFACTS, v["cfd_period"]))
            assert os.path.exists(os.path.join(ARTIFACTS, v["state0"]))
            assert len(v["probe_mean"]) == 149
            assert len(v["probe_std"]) == 149
            assert all(s > 0 for s in v["probe_std"])
            assert 1.0 < v["cd0"] < 10.0

    def test_state0_size_matches_grid(self, manifest):
        for name, v in manifest["variants"].items():
            path = os.path.join(ARTIFACTS, v["state0"])
            n = os.path.getsize(path)
            assert n == 3 * v["ny"] * v["nx"] * 4

    def test_params_init_size(self, manifest):
        n = os.path.getsize(os.path.join(ARTIFACTS, "params_init.bin"))
        assert n == manifest["drl"]["n_params"] * 4

    def test_no_elided_constants(self, manifest):
        """Regression: as_hlo_text must be called with
        print_large_constants=True, otherwise the baked geometry masks are
        elided as '{...}' and the Rust-side text parser reads garbage."""
        for name in os.listdir(ARTIFACTS):
            if name.endswith(".hlo.txt"):
                text = open(os.path.join(ARTIFACTS, name)).read()
                assert "{...}" not in text, f"{name} has elided constants"

    def test_params_init_matches_seed0(self, manifest):
        got = np.fromfile(os.path.join(ARTIFACTS, "params_init.bin"),
                          dtype="<f4")
        want = model.init_params(DRL, seed=0)
        np.testing.assert_array_equal(got, want)
