"""PPO model: layout, forward equivalence, loss/update math, GAE oracle."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.configs import DRL


@pytest.fixture(scope="module")
def flat0():
    return jnp.asarray(model.init_params(DRL, seed=0))


class TestLayout:
    def test_layout_covers_vector(self):
        slots, n = model.param_layout(DRL)
        assert n == DRL.n_params
        # contiguity: each slot starts where the previous ended
        off = 0
        for s in slots:
            assert s.offset == off
            off += int(np.prod(s.shape))
        assert off == n

    def test_unflatten_roundtrip(self, flat0):
        p = model.unflatten(flat0, DRL)
        assert p["w1"].shape == (DRL.n_obs, DRL.hidden)
        assert p["logstd"].shape == (DRL.n_act,)
        # re-flatten manually and compare
        slots, n = model.param_layout(DRL)
        re = np.concatenate([np.asarray(p[s.name]).ravel() for s in slots])
        np.testing.assert_array_equal(re, np.asarray(flat0))

    def test_init_params_deterministic(self):
        a = model.init_params(DRL, seed=3)
        b = model.init_params(DRL, seed=3)
        c = model.init_params(DRL, seed=4)
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_mu_head_small_at_init(self, flat0):
        obs = jnp.asarray(np.random.default_rng(0).standard_normal((8, DRL.n_obs)),
                          jnp.float32)
        mu, logstd, v = model.forward(flat0, obs, DRL, use_pallas=False)
        assert float(jnp.max(jnp.abs(mu))) < 0.5
        np.testing.assert_allclose(np.asarray(logstd), DRL.init_logstd)


class TestForward:
    def test_pallas_matches_ref(self, flat0):
        obs = jnp.asarray(np.random.default_rng(1).standard_normal((4, DRL.n_obs)),
                          jnp.float32)
        m1 = model.forward(flat0, obs, DRL, use_pallas=True)
        m2 = model.forward(flat0, obs, DRL, use_pallas=False)
        for a, b in zip(m1, m2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_gaussian_logp(self, seed):
        rng = np.random.default_rng(seed)
        mu = rng.standard_normal((6, 1)).astype(np.float32)
        logstd = rng.standard_normal(1).astype(np.float32) * 0.3
        act = rng.standard_normal((6, 1)).astype(np.float32)
        got = np.asarray(model.gaussian_logp(
            jnp.asarray(act), jnp.asarray(mu), jnp.asarray(logstd)))
        std = np.exp(logstd)
        want = (-0.5 * ((act - mu) / std) ** 2 - np.log(std)
                - 0.5 * math.log(2 * math.pi)).sum(-1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestUpdate:
    def _batch(self, flat, seed=0):
        rng = np.random.default_rng(seed)
        b = DRL.minibatch
        obs = jnp.asarray(rng.standard_normal((b, DRL.n_obs)), jnp.float32)
        mu, logstd, _ = model.forward(flat, obs, DRL, use_pallas=False)
        act = mu + jnp.exp(logstd) * jnp.asarray(
            rng.standard_normal((b, DRL.n_act)), jnp.float32)
        logp = model.gaussian_logp(act, mu, logstd)
        adv = jnp.asarray(rng.standard_normal(b), jnp.float32)
        ret = jnp.asarray(rng.standard_normal(b), jnp.float32)
        return obs, act, logp, adv, ret

    def test_first_epoch_ratio_is_one(self, flat0):
        """With unchanged params, ratio == 1 -> pg loss == -mean(adv)."""
        obs, act, logp, adv, ret = self._batch(flat0)
        total, stats = model.ppo_loss(flat0, obs, act, logp, adv, ret, DRL)
        pg = float(stats[0])
        assert abs(pg - float(-jnp.mean(adv))) < 1e-4
        assert abs(float(stats[3])) < 1e-5          # approx KL ~ 0
        assert float(stats[4]) == 0.0               # clipfrac == 0

    def test_update_moves_params_against_gradient(self, flat0):
        obs, act, logp, adv, ret = self._batch(flat0)
        upd = jax.jit(model.make_ppo_update(DRL))
        m = jnp.zeros_like(flat0)
        v = jnp.zeros_like(flat0)
        f1, m1, v1, stats = upd(flat0, m, v, jnp.float32(1.0),
                                obs, act, logp, adv, ret)
        assert float(jnp.linalg.norm(f1 - flat0)) > 0
        # Adam first step: |delta| <= lr per coordinate (up to eps)
        assert float(jnp.max(jnp.abs(f1 - flat0))) <= DRL.lr * 1.01

    def test_repeated_updates_reduce_value_loss(self, flat0):
        """On a fixed regression batch the value head must fit."""
        rng = np.random.default_rng(2)
        b = DRL.minibatch
        obs = jnp.asarray(rng.standard_normal((b, DRL.n_obs)), jnp.float32)
        act = jnp.zeros((b, DRL.n_act), jnp.float32)
        mu, logstd, _ = model.forward(flat0, obs, DRL, use_pallas=False)
        logp = model.gaussian_logp(act, mu, logstd)
        adv = jnp.zeros(b, jnp.float32)             # isolate the value loss
        ret = jnp.asarray(rng.standard_normal(b), jnp.float32)
        upd = jax.jit(model.make_ppo_update(DRL))
        flat, m, v = flat0, jnp.zeros_like(flat0), jnp.zeros_like(flat0)
        losses = []
        for t in range(1, 60):
            flat, m, v, stats = upd(flat, m, v, jnp.float32(t),
                                    obs, act, logp, adv, ret)
            losses.append(float(stats[1]))
        assert losses[-1] < 0.5 * losses[0], (losses[0], losses[-1])

    def test_adam_matches_numpy_reference(self, flat0):
        """One full Adam step cross-checked against a numpy implementation."""
        obs, act, logp, adv, ret = self._batch(flat0, seed=5)
        g, _ = jax.grad(model.ppo_loss, has_aux=True)(
            flat0, obs, act, logp, adv, ret, DRL)
        g = np.asarray(g, np.float64)
        f = np.asarray(flat0, np.float64)
        m = DRL.adam_b1 * 0 + (1 - DRL.adam_b1) * g
        v = (1 - DRL.adam_b2) * g * g
        mh = m / (1 - DRL.adam_b1)
        vh = v / (1 - DRL.adam_b2)
        want = f - DRL.lr * mh / (np.sqrt(vh) + DRL.adam_eps)
        upd = jax.jit(model.make_ppo_update(DRL))
        got, _, _, _ = upd(flat0, jnp.zeros_like(flat0), jnp.zeros_like(flat0),
                           jnp.float32(1.0), obs, act, logp, adv, ret)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-5)


class TestGAE:
    def test_constant_reward_closed_form(self):
        """r=1, V=0 everywhere: adv_t = sum_k (gamma*lam)^k over remaining."""
        n, gamma, lam = 10, 0.9, 0.8
        rew = np.ones(n, np.float32)
        val = np.zeros(n, np.float32)
        adv, ret = model.gae(rew, val, 0.0, gamma, lam)
        gl = gamma * lam
        want = np.array([(1 - gl ** (n - t)) / (1 - gl) for t in range(n)])
        np.testing.assert_allclose(adv, want, rtol=1e-5)
        np.testing.assert_allclose(ret, adv, rtol=1e-6)   # V=0 -> ret == adv

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 50))
    def test_lambda_one_is_discounted_return(self, seed, n):
        rng = np.random.default_rng(seed)
        rew = rng.standard_normal(n).astype(np.float32)
        val = rng.standard_normal(n).astype(np.float32)
        last = float(rng.standard_normal())
        gamma = 0.95
        adv, ret = model.gae(rew, val, last, gamma, 1.0)
        # with lam=1: ret_t = sum gamma^k r_{t+k} + gamma^{n-t} last
        want = np.zeros(n)
        acc = last
        for t in reversed(range(n)):
            acc = rew[t] + gamma * acc
            want[t] = acc
        np.testing.assert_allclose(ret, want, rtol=2e-4, atol=2e-4)
