"""Pallas dense kernel vs oracle, and the AD constraint it imposes."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp, ref


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 64), i=st.integers(1, 160), o=st.integers(1, 128),
       seed=st.integers(0, 2**31 - 1),
       act=st.sampled_from(["tanh", "none"]))
def test_matches_reference(b, i, o, seed, act):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, i)).astype(np.float32)
    w = (rng.standard_normal((i, o)) * 0.1).astype(np.float32)
    bias = rng.standard_normal(o).astype(np.float32)
    got = mlp.dense(x, w, bias, act)
    want = ref.dense(x, w, bias, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_interpret_pallas_has_no_reverse_ad():
    """Documents the constraint that forces ppo_update onto the ref forward:
    reverse-mode AD through interpret-mode pallas_call raises. If this ever
    starts passing, model.forward can switch the grad path to Pallas."""
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 8), jnp.float32) * 0.1
    b = jnp.zeros(8, jnp.float32)
    with pytest.raises(Exception):
        jax.grad(lambda w_: mlp.dense(x, w_, b).sum())(w)


def test_ref_grad_matches_finite_difference():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 6)).astype(np.float32)
    w = (rng.standard_normal((6, 3)) * 0.2).astype(np.float32)
    b = rng.standard_normal(3).astype(np.float32)

    f = lambda w_: ref.dense(jnp.asarray(x), w_, jnp.asarray(b)).sum()
    g = np.asarray(jax.grad(f)(jnp.asarray(w)))
    eps = 1e-3
    for idx in [(0, 0), (3, 2), (5, 1)]:
        wp = w.copy(); wp[idx] += eps
        wm = w.copy(); wm[idx] -= eps
        fd = (float(f(jnp.asarray(wp))) - float(f(jnp.asarray(wm)))) / (2 * eps)
        assert abs(fd - g[idx]) < 5e-2, (idx, fd, g[idx])


def test_mxu_tiles():
    n, pad = mlp.mxu_tiles(64, 512, 512)
    assert n == 1 * 4 * 4
    assert 0.0 <= pad < 1.0
    # the 149-input layer pads badly, as documented
    _, pad1 = mlp.mxu_tiles(64, 149, 512)
    assert pad1 > 0.2
