"""Pallas advection-diffusion kernel vs oracle + analytic fields."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import stencil, ref


def rand(seed, ny, nx):
    return (np.random.default_rng(seed).standard_normal((ny, nx))
            ).astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(ny=st.integers(4, 48), nx=st.integers(4, 64),
       seed=st.integers(0, 2**31 - 1),
       nu=st.floats(1e-3, 1.0))
def test_matches_reference(ny, nx, seed, nu):
    u, v = rand(seed, ny, nx), rand(seed + 1, ny, nx)
    h = 0.05
    ru, rv = stencil.adv_diff_rhs(u, v, h=h, nu=float(nu))
    ru2, rv2 = ref.adv_diff_rhs(u, v, h, float(nu))
    np.testing.assert_allclose(np.asarray(ru), np.asarray(ru2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(rv), np.asarray(rv2), rtol=2e-4, atol=2e-4)


def test_linear_field_zero_laplacian():
    """For u = a + bx + cy, lap(u)=0 and the advection term is exact, so the
    interior RHS equals -(u b + v c) for both our kernel and the oracle."""
    ny, nx, h = 24, 32, 0.1
    y, x = np.meshgrid(np.arange(ny) * h, np.arange(nx) * h, indexing="ij")
    u = (1.0 + 2.0 * x + 3.0 * y).astype(np.float32)
    v = np.full((ny, nx), 0.5, np.float32)
    ru, rv = stencil.adv_diff_rhs(u, v, h=h, nu=0.01)
    ru = np.asarray(ru)[2:-2, 2:-2]
    expect = -(u * 2.0 + v * 3.0)[2:-2, 2:-2]
    np.testing.assert_allclose(ru, expect, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(rv)[2:-2, 2:-2], 0.0, atol=1e-3)


def test_quadratic_laplacian():
    """u = x^2 + y^2 has lap(u) = 4 exactly under the 5-point stencil."""
    ny, nx, h = 16, 20, 0.25
    y, x = np.meshgrid(np.arange(ny) * h, np.arange(nx) * h, indexing="ij")
    u = (x * x + y * y).astype(np.float32)
    v = np.zeros((ny, nx), np.float32)
    nu = 1.0
    ru, _ = stencil.adv_diff_rhs(u, v * 0, h=h, nu=nu)
    # advection term: -u du/dx = -u * 2x
    expect = (-u * 2 * x + nu * 4.0)[2:-2, 2:-2]
    np.testing.assert_allclose(np.asarray(ru)[2:-2, 2:-2], expect,
                               rtol=1e-2, atol=1e-2)


def test_divergence_grad_adjointness():
    """The pseudo-staggered pairing: div(grad p) == 5-point laplacian."""
    ny, nx, h = 20, 28, 0.1
    p = rand(5, ny, nx)
    gx, gy = ref.grad_p(p, h)
    got = np.asarray(ref.divergence(gx, gy, h))[1:-1, 1:-1]
    want = np.asarray(ref.laplacian(p, h))[1:-1, 1:-1]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
