"""Pallas kernel: dense layer for the policy/value MLP (the DRL hot spot).

The policy is the Rabault 2x512 tanh MLP. On TPU this is an MXU problem:
the kernel tiles (B, I) x (I, O) into 128x128 panels (bf16-friendly shapes;
we keep f32 on this CPU target), accumulating in f32 scratch. For the
149->512->512 policy the whole weight set (1.3 MiB) fits in VMEM, so the
serving path is a single fused kernel invocation per layer with ~93% MXU
occupancy on the 512x512 layer (512 = 4x128 exactly; the 149-column input
panel pads to 256, costing ~27% of layer-1 flops — see EXPERIMENTS.md
section Perf).

Built with ``interpret=True`` (CPU PJRT; see poisson.py). Differentiable:
interpret-mode pallas_call supports jax.grad, asserted in
python/tests/test_mlp.py, so ppo_update lowers through the same kernel the
serving path uses.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    y = x_ref[...] @ w_ref[...] + b_ref[...][None, :]
    if activation == "tanh":
        y = jnp.tanh(y)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("activation",))
def dense(x, w, b, activation="tanh"):
    """Pallas dense layer; twin of ref.dense. x:(B,I) w:(I,O) b:(O,)."""
    bsz, _ = x.shape
    out = w.shape[1]
    kernel = functools.partial(_dense_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, out), x.dtype),
        interpret=True,
    )(x, w, b)


def mxu_tiles(bsz, inner, out, tile=128):
    """Number of 128x128 MXU tiles a (B,I)x(I,O) matmul occupies, and the
    padding overhead fraction — the perf-model input for DESIGN.md."""
    import math

    tb = math.ceil(bsz / tile)
    ti = math.ceil(inner / tile)
    to = math.ceil(out / tile)
    used = bsz * inner * out
    padded = tb * ti * to * tile**3
    return tb * ti * to, 1.0 - used / padded
