"""Pure-jnp reference implementations (the correctness oracle).

Every Pallas kernel in this package has an exact functional twin here.
``pytest python/tests`` asserts allclose between the two over shape/seed
sweeps (hypothesis), and the CFD/PPO modules can be built against either
implementation (``use_pallas`` flag) so any numeric drift is attributable.

Array convention: fields are ``(ny, nx)`` float32, row j = y index,
column i = x index. Row/column 0 and -1 are boundary cells owned by the
BC routines in ``cfd.py``; kernels only update the interior.
"""

import jax.numpy as jnp


def shift_n(a):
    """Value of the north neighbour (j+1) at each cell; wrap rows are only
    ever read at boundary cells, which the callers never update."""
    return jnp.roll(a, -1, axis=0)


def shift_s(a):
    return jnp.roll(a, 1, axis=0)


def shift_e(a):
    return jnp.roll(a, -1, axis=1)


def shift_w(a):
    return jnp.roll(a, 1, axis=1)


def laplacian(a, h):
    """Standard 5-point Laplacian (interior values only are meaningful)."""
    return (shift_e(a) + shift_w(a) + shift_n(a) + shift_s(a) - 4.0 * a) / (h * h)


def adv_diff_rhs(u, v, h, nu):
    """RHS of the momentum predictor: -(u.grad)u + nu lap(u), central
    differences, collocated. Returns (ru, rv)."""
    dudx = (shift_e(u) - shift_w(u)) / (2.0 * h)
    dudy = (shift_n(u) - shift_s(u)) / (2.0 * h)
    dvdx = (shift_e(v) - shift_w(v)) / (2.0 * h)
    dvdy = (shift_n(v) - shift_s(v)) / (2.0 * h)
    ru = -u * dudx - v * dudy + nu * laplacian(u, h)
    rv = -u * dvdx - v * dvdy + nu * laplacian(v, h)
    return ru, rv


def divergence(u, v, h):
    """Backward-difference divergence (pseudo-staggered pairing with the
    forward-difference pressure gradient below; the composition is the
    compact 5-point Laplacian, which kills collocated checkerboarding)."""
    return (u - shift_w(u)) / h + (v - shift_s(v)) / h


def grad_p(p, h):
    """Forward-difference pressure gradient (adjoint of `divergence`)."""
    return (shift_e(p) - p) / h, (shift_n(p) - p) / h


def sor_color_sweep(p, rhs, color_mask, omega, h):
    """One coloured Gauss-Seidel/SOR half-sweep of the 5-point Poisson
    problem lap(p) = rhs on cells where color_mask == 1."""
    gs = 0.25 * (shift_e(p) + shift_w(p) + shift_n(p) + shift_s(p) - h * h * rhs)
    return jnp.where(color_mask > 0, (1.0 - omega) * p + omega * gs, p)


def rb_sor_sweep(p, rhs, red_mask, black_mask, omega, h):
    """One full red-black SOR sweep (red half-sweep, then black using the
    freshly-updated red values). Masks are interior-only."""
    p = sor_color_sweep(p, rhs, red_mask, omega, h)
    p = sor_color_sweep(p, rhs, black_mask, omega, h)
    return p


def poisson_residual(p, rhs, h, interior_mask):
    """L2 norm of lap(p) - rhs over the interior (diagnostic for tests)."""
    r = (laplacian(p, h) - rhs) * interior_mask
    return jnp.sqrt(jnp.sum(r * r) / jnp.sum(interior_mask))


def dense(x, w, b, activation="tanh"):
    """Reference dense layer: activation(x @ w + b)."""
    y = x @ w + b
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "none":
        return y
    raise ValueError(activation)
