"""Pallas kernel: advection-diffusion RHS of the momentum predictor.

Computes, for both velocity components in one fused kernel,

    r = -(u du/dx + v du/dy) + (1/Re) lap(u)

with second-order central differences on the collocated grid. Fusing both
components amortises the neighbour loads: u,v are each read once per cell
and contribute to 10 stencil taps (arithmetic intensity ~1.9 flop/byte on
f32, firmly memory-bound on TPU HBM -> the panel schedule from
kernels/poisson.py applies unchanged).

Built with ``interpret=True`` for CPU-PJRT execution (see poisson.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adv_diff_kernel(u_ref, v_ref, ru_ref, rv_ref, *, h, nu):
    u = u_ref[...]
    v = v_ref[...]

    def sh(a, d, ax):
        return jnp.roll(a, d, axis=ax)

    inv2h = 1.0 / (2.0 * h)
    invh2 = 1.0 / (h * h)

    u_e, u_w = sh(u, -1, 1), sh(u, 1, 1)
    u_n, u_s = sh(u, -1, 0), sh(u, 1, 0)
    v_e, v_w = sh(v, -1, 1), sh(v, 1, 1)
    v_n, v_s = sh(v, -1, 0), sh(v, 1, 0)

    dudx = (u_e - u_w) * inv2h
    dudy = (u_n - u_s) * inv2h
    dvdx = (v_e - v_w) * inv2h
    dvdy = (v_n - v_s) * inv2h
    lap_u = (u_e + u_w + u_n + u_s - 4.0 * u) * invh2
    lap_v = (v_e + v_w + v_n + v_s - 4.0 * v) * invh2

    ru_ref[...] = -u * dudx - v * dudy + nu * lap_u
    rv_ref[...] = -u * dvdx - v * dvdy + nu * lap_v


@functools.partial(jax.jit, static_argnames=("h", "nu"))
def adv_diff_rhs(u, v, *, h, nu):
    """Pallas advection-diffusion RHS; twin of ref.adv_diff_rhs."""
    ny, nx = u.shape
    kernel = functools.partial(_adv_diff_kernel, h=h, nu=nu)
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((ny, nx), u.dtype),
            jax.ShapeDtypeStruct((ny, nx), u.dtype),
        ],
        interpret=True,
    )(u, v)
