"""Pallas kernel: row-panel-tiled red-black SOR sweep (the TPU schedule).

`poisson.rb_sor_sweep` uses one whole-array block because the CPU PJRT
plugin executes Pallas in interpret mode. On a real TPU the field must be
streamed HBM->VMEM in panels; this module implements that schedule
explicitly so it is tested *now* (against the oracle, in interpret mode)
and ready for a Mosaic build:

  grid = (ny // block_rows,)
  each program instance updates rows [i*B, (i+1)*B) and reads one halo row
  on each side; halos are expressed by passing the full field and slicing
  with pl.dynamic_slice inside the kernel (interpret-friendly stand-in for
  overlapping BlockSpecs).

VMEM budget per instance: (B+2) x nx x 4 bytes x 4 operands; for the
`paper` grid (nx=515) and B=32 that is ~280 KB — far under the 16 MiB
VMEM, leaving room for double buffering (DESIGN.md section 3).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile_kernel(p_ref, rhs_ref, red_ref, black_ref, out_ref, *, omega, h,
                 block_rows, ny):
    """One program instance: rows [i*B, (i+1)*B), halo-aware."""
    i = pl.program_id(0)
    row0 = i * block_rows

    # load the panel plus one halo row each side; clamp the window into
    # [0, ny - panel_rows] so the load never runs past the array (the
    # interior masks are zero on the physical boundary rows, so reading a
    # shifted window at the edges is safe as long as the store offset
    # below uses the same clamped origin).
    panel_rows = min(block_rows + 2, ny)  # degenerate: one panel = whole field
    lo = jnp.clip(row0 - 1, 0, ny - panel_rows)
    p = pl.load(p_ref, (pl.dslice(lo, panel_rows), slice(None)))
    rhs = pl.load(rhs_ref, (pl.dslice(lo, panel_rows), slice(None)))
    red = pl.load(red_ref, (pl.dslice(lo, panel_rows), slice(None)))
    black = pl.load(black_ref, (pl.dslice(lo, panel_rows), slice(None)))

    # Halo rows must NOT be relaxed locally: their true north/south
    # neighbours live in the adjacent panel (the axis-0 roll would wrap in
    # garbage from the far side of this panel). Zeroing the update on the
    # two edge rows leaves them at their input values — the "lagged halo"
    # of block-asynchronous relaxation. The physical boundary rows are
    # mask-zero anyway, so this is exact there.
    edge = jnp.zeros((panel_rows, 1), p.dtype).at[1:-1].set(1.0)

    def color(pc, mask):
        gs = 0.25 * (
            jnp.roll(pc, -1, axis=1) + jnp.roll(pc, 1, axis=1)
            + jnp.roll(pc, -1, axis=0) + jnp.roll(pc, 1, axis=0)
            - h * h * rhs
        )
        return jnp.where(mask * edge > 0, (1.0 - omega) * pc + omega * gs, pc)

    p = color(p, red)
    p = color(p, black)

    # write back the interior of the panel (drop the halo rows). The first
    # panel starts at row0=0 where lo==row0, so the offset differs.
    off = row0 - lo
    pl.store(
        out_ref,
        (pl.dslice(row0, block_rows), slice(None)),
        jax.lax.dynamic_slice_in_dim(p, off, block_rows, axis=0),
    )


@functools.partial(jax.jit, static_argnames=("omega", "h", "block_rows"))
def rb_sor_sweep_tiled(p, rhs, red_mask, black_mask, *, omega, h,
                       block_rows=8):
    """Row-panel-tiled red-black SOR sweep; twin of ref.rb_sor_sweep.

    NOTE on red-black semantics across panels: the black half-sweep reads
    red values from the halo rows, which are *pre-sweep* values for
    neighbouring panels. This is the standard block-asynchronous relaxation
    trade-off; convergence degrades by O(1/B) and the result differs from
    the sequential sweep only on rows adjacent to panel boundaries. Tests
    assert exact agreement in the panel interiors and contraction of the
    global residual.
    """
    ny, nx = p.shape
    assert ny % block_rows == 0, (ny, block_rows)
    grid = (ny // block_rows,)
    kernel = functools.partial(
        _tile_kernel, omega=omega, h=h, block_rows=block_rows, ny=ny)
    return pl.pallas_call(
        kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((ny, nx), p.dtype),
        interpret=True,
    )(p, rhs, red_mask, black_mask)


def vmem_per_instance(block_rows, nx, operands=4, dtype_bytes=4):
    """VMEM bytes per program instance (halo included)."""
    return (block_rows + 2) * nx * dtype_bytes * operands
