"""Pallas kernel: red-black SOR sweep for the pressure-Poisson projection.

This is the CFD hot spot: each projection runs ``n_sweeps`` of this kernel,
and each actuation period runs ``substeps`` projections, so >80% of the
flops of an episode land here (see EXPERIMENTS.md section Perf).

TPU mapping (DESIGN.md section Hardware-Adaptation): the paper's substrate is a
CPU MPI solver; re-thought for TPU, the red-black sweep is a VPU stencil.
The kernel is written block-generically: with ``block_rows`` < ny it tiles
the field into (block_rows, nx) row panels held in VMEM (a (256, 512) f32
panel = 512 KiB; five operand panels fit comfortably in 16 MiB VMEM with
double buffering), streaming panels HBM->VMEM along y. On this box the CPU
PJRT plugin cannot execute Mosaic custom-calls, so artifacts are built with
``interpret=True`` and a single whole-array block; correctness of the
tiled path is asserted against ref.py in python/tests/test_poisson.py.

Halo note: a row-panel needs its north/south neighbour rows. We express
this by passing the *whole* field per block via the index map and slicing
inside the kernel (interpret mode); a production Mosaic build would use
overlapping BlockSpecs instead.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _rb_sor_kernel(p_ref, rhs_ref, red_ref, black_ref, out_ref, *, omega, h):
    """One full red+black SOR sweep over the block.

    The black half-sweep reads the freshly updated red cells, giving true
    Gauss-Seidel ordering (twice the asymptotic convergence rate of Jacobi).
    """
    p = p_ref[...]
    rhs = rhs_ref[...]
    red = red_ref[...]
    black = black_ref[...]

    def color(pc, mask):
        gs = 0.25 * (
            jnp.roll(pc, -1, axis=1) + jnp.roll(pc, 1, axis=1)
            + jnp.roll(pc, -1, axis=0) + jnp.roll(pc, 1, axis=0)
            - h * h * rhs
        )
        return jnp.where(mask > 0, (1.0 - omega) * pc + omega * gs, pc)

    p = color(p, red)
    p = color(p, black)
    out_ref[...] = p


@functools.partial(jax.jit, static_argnames=("omega", "h"))
def rb_sor_sweep(p, rhs, red_mask, black_mask, *, omega, h):
    """Pallas red-black SOR sweep; twin of ref.rb_sor_sweep.

    Masks are interior-only (boundary rows/cols zero), so boundary cells —
    owned by cfd.apply_pressure_bcs — are passed through untouched.
    """
    ny, nx = p.shape
    kernel = functools.partial(_rb_sor_kernel, omega=omega, h=h)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((ny, nx), p.dtype),
        interpret=True,
    )(p, rhs, red_mask, black_mask)


def vmem_bytes(block_rows, nx, dtype_bytes=4, operands=5):
    """VMEM footprint estimate for a (block_rows, nx) panel schedule —
    recorded in DESIGN.md section Perf for the paper grid."""
    return block_rows * nx * dtype_bytes * operands
