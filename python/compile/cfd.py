"""L2: 2D incompressible Navier-Stokes solver for the confined cylinder.

From-scratch substitute for the paper's OpenFOAM ``pimpleFoam`` substrate
(DESIGN.md section 2): Chorin projection on a uniform collocated grid with
pseudo-staggered div/grad pairing, RK2 central advection-diffusion
predictor, red-black SOR pressure projection (Pallas kernel), and a
direct-forcing immersed-boundary cylinder carrying the two synthetic jets
(theta = 90/270 deg, width 10 deg, parabolic lip profile, zero net mass
flux: V_G1 = -V_G2 = action).

Everything here runs at *build time only*: ``aot.py`` lowers
``make_period_fn`` once to HLO text and the Rust runtime executes it.
"""

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from .configs import GridConfig
from .kernels import poisson as k_poisson
from .kernels import stencil as k_stencil
from .kernels import ref as k_ref


# --------------------------------------------------------------------------
# Geometry: masks, jets, probes (all static numpy, baked into the HLO)
# --------------------------------------------------------------------------

@dataclass
class Geometry:
    """Static fields derived from a GridConfig (numpy, f32)."""

    cfg: GridConfig
    xc: np.ndarray          # (nx,) cell-centre x coordinates
    yc: np.ndarray          # (ny,)
    solid: np.ndarray       # (ny,nx) 1 inside the cylinder
    jet_u: np.ndarray       # (ny,nx) unit-action jet velocity, x component
    jet_v: np.ndarray       # (ny,nx)
    red: np.ndarray         # (ny,nx) interior red checkerboard
    black: np.ndarray       # (ny,nx)
    interior: np.ndarray    # (ny,nx) non-boundary cells
    u_in: np.ndarray        # (ny,) parabolic inlet profile
    probe_xy: np.ndarray    # (n_probes, 2)
    probe_idx: np.ndarray   # (n_probes, 4, 2) bilinear corner (j,i)
    probe_w: np.ndarray     # (n_probes, 4) bilinear weights


def probe_positions(n_probes: int = 149) -> np.ndarray:
    """149 pressure probes: two rings around the cylinder, a near-jet ring,
    and a wake grid — the Wang et al. layout is not published, so we follow
    its description (around the cylinder + wake region, sparse)."""
    pts = []
    for r, n in ((0.75, 24), (1.0, 24)):
        th = np.linspace(0.0, 2.0 * np.pi, n, endpoint=False)
        pts.append(np.stack([r * np.cos(th), r * np.sin(th)], axis=1))
    # near-jet probes just off the two lips
    th_j = np.concatenate([
        np.deg2rad(np.linspace(75, 105, 5)),
        np.deg2rad(np.linspace(255, 285, 5)),
    ])
    pts.append(np.stack([0.6 * np.cos(th_j), 0.6 * np.sin(th_j)], axis=1))
    # wake grid 13 x 7
    gx, gy = np.meshgrid(np.linspace(1.0, 8.0, 13), np.linspace(-1.5, 1.5, 7))
    pts.append(np.stack([gx.ravel(), gy.ravel()], axis=1))
    out = np.concatenate(pts, axis=0)
    assert out.shape[0] == n_probes, out.shape
    return out.astype(np.float32)


def build_geometry(cfg: GridConfig) -> Geometry:
    ny, nx, h = cfg.ny, cfg.nx, cfg.h
    xc = (-cfg.x_up + (np.arange(nx) + 0.5) * h).astype(np.float32)
    yc = (cfg.y_lo + (np.arange(ny) + 0.5) * h).astype(np.float32)
    X, Y = np.meshgrid(xc, yc)                      # (ny, nx)
    r = np.sqrt(X * X + Y * Y)
    solid = (r < cfg.radius).astype(np.float32)

    # Jet cells: outermost solid ring (solid with >=1 fluid neighbour)
    fluid = 1.0 - solid
    nb_fluid = (np.roll(fluid, 1, 0) + np.roll(fluid, -1, 0)
                + np.roll(fluid, 1, 1) + np.roll(fluid, -1, 1))
    shell = (solid > 0) & (nb_fluid > 0)
    theta = np.arctan2(Y, X)                        # [-pi, pi]
    half_w = np.deg2rad(cfg.jet_width_deg) / 2.0

    jet_u = np.zeros((ny, nx), np.float32)
    jet_v = np.zeros((ny, nx), np.float32)
    for theta0, sign in ((np.pi / 2, 1.0), (-np.pi / 2, -1.0)):
        d = np.arctan2(np.sin(theta - theta0), np.cos(theta - theta0))
        in_arc = shell & (np.abs(d) < half_w)
        w = 1.0 - (d / half_w) ** 2                 # parabolic lip profile
        jet_u += np.where(in_arc, sign * w * np.cos(theta), 0.0)
        jet_v += np.where(in_arc, sign * w * np.sin(theta), 0.0)

    jj, ii = np.meshgrid(np.arange(ny), np.arange(nx), indexing="ij")
    interior = ((jj > 0) & (jj < ny - 1) & (ii > 0) & (ii < nx - 1))
    red = (((jj + ii) % 2 == 0) & interior).astype(np.float32)
    black = (((jj + ii) % 2 == 1) & interior).astype(np.float32)

    u_in = (cfg.u_max
            * (1.0 - ((yc - cfg.y_center) / (cfg.height / 2.0)) ** 2)
            ).astype(np.float32)

    pxy = probe_positions()
    # bilinear gather: cell-centre based; clamp to interior
    fx = (pxy[:, 0] + cfg.x_up) / h - 0.5
    fy = (pxy[:, 1] - cfg.y_lo) / h - 0.5
    i0 = np.clip(np.floor(fx).astype(np.int32), 0, nx - 2)
    j0 = np.clip(np.floor(fy).astype(np.int32), 0, ny - 2)
    tx = (fx - i0).astype(np.float32)
    ty = (fy - j0).astype(np.float32)
    idx = np.stack([
        np.stack([j0, i0], 1), np.stack([j0, i0 + 1], 1),
        np.stack([j0 + 1, i0], 1), np.stack([j0 + 1, i0 + 1], 1),
    ], axis=1)                                      # (P,4,2)
    w = np.stack([(1 - tx) * (1 - ty), tx * (1 - ty),
                  (1 - tx) * ty, tx * ty], axis=1).astype(np.float32)

    return Geometry(cfg=cfg, xc=xc, yc=yc, solid=solid, jet_u=jet_u,
                    jet_v=jet_v, red=red, black=black,
                    interior=interior.astype(np.float32), u_in=u_in,
                    probe_xy=pxy, probe_idx=idx, probe_w=w)


# --------------------------------------------------------------------------
# Boundary conditions
# --------------------------------------------------------------------------

def apply_vel_bcs(u, v, u_in):
    """Inlet Dirichlet (parabolic), outlet zero-gradient, no-slip walls."""
    u = u.at[:, 0].set(u_in)
    v = v.at[:, 0].set(0.0)
    u = u.at[:, -1].set(u[:, -2])
    v = v.at[:, -1].set(v[:, -2])
    u = u.at[0, :].set(0.0).at[-1, :].set(0.0)
    v = v.at[0, :].set(0.0).at[-1, :].set(0.0)
    return u, v


def apply_pressure_bcs(p):
    """Neumann at inlet/walls, Dirichlet p=0 at the outlet."""
    p = p.at[:, 0].set(p[:, 1])
    p = p.at[0, :].set(p[1, :])
    p = p.at[-1, :].set(p[-2, :])
    p = p.at[:, -1].set(0.0)
    return p


# --------------------------------------------------------------------------
# One projection substep
# --------------------------------------------------------------------------

def make_substep_fn(cfg: GridConfig, geom: Geometry, use_pallas: bool = True):
    """Returns substep((u,v,p), jet_a) -> ((u,v,p), (cd, cl)).

    jet_a is the smoothed jet amplitude V_G1 (V_G2 = -V_G1 by construction
    of geom.jet_{u,v}); cd/cl from immersed-boundary momentum exchange.
    """
    h, dt, nu = cfg.h, cfg.dt, 1.0 / cfg.re
    solid = jnp.asarray(geom.solid)
    jet_u = jnp.asarray(geom.jet_u)
    jet_v = jnp.asarray(geom.jet_v)
    red = jnp.asarray(geom.red)
    black = jnp.asarray(geom.black)
    u_in = jnp.asarray(geom.u_in)
    qref = 0.5 * cfg.u_mean ** 2 * (2.0 * cfg.radius)   # 0.5 rho Ubar^2 D

    if use_pallas:
        adv_diff = functools.partial(k_stencil.adv_diff_rhs, h=h, nu=nu)
        sweep = functools.partial(k_poisson.rb_sor_sweep,
                                  omega=cfg.sor_omega, h=h)
    else:
        adv_diff = lambda u, v: k_ref.adv_diff_rhs(u, v, h, nu)
        sweep = lambda p, rhs, r, b: k_ref.rb_sor_sweep(
            p, rhs, r, b, cfg.sor_omega, h)

    def poisson_solve(p, rhs):
        def body(_, p):
            p = apply_pressure_bcs(p)
            return sweep(p, rhs, red, black)
        p = jax.lax.fori_loop(0, cfg.n_sweeps, body, p)
        return apply_pressure_bcs(p)

    def substep(state, jet_a):
        u, v, p = state
        u, v = apply_vel_bcs(u, v, u_in)

        # RK2 (midpoint) predictor, central advection + diffusion
        ru, rv = adv_diff(u, v)
        uh = u + 0.5 * dt * ru
        vh = v + 0.5 * dt * rv
        uh, vh = apply_vel_bcs(uh, vh, u_in)
        ru, rv = adv_diff(uh, vh)
        us = u + dt * ru
        vs = v + dt * rv
        us, vs = apply_vel_bcs(us, vs, u_in)

        # Immersed boundary: direct forcing + momentum-exchange force.
        # The force on the body is the negative of ALL momentum the forcing
        # injects during the step: the predictor correction (viscous/
        # convective part) plus the post-projection correction, which by
        # the divergence theorem carries the pressure drag
        # (sum_solid grad p * h^2 ~ surface integral of p n dS).
        ut = jet_a * jet_u
        vt = jet_a * jet_v
        fx1 = -(h * h / dt) * jnp.sum(solid * (ut - us))
        fy1 = -(h * h / dt) * jnp.sum(solid * (vt - vs))
        us = us * (1.0 - solid) + ut
        vs = vs * (1.0 - solid) + vt

        # Projection (pseudo-staggered pairing, see kernels/ref.py)
        rhs = k_ref.divergence(us, vs, h) / dt
        p = poisson_solve(p, rhs)
        gpx, gpy = k_ref.grad_p(p, h)
        u = us - dt * gpx
        v = vs - dt * gpy
        u, v = apply_vel_bcs(u, v, u_in)
        fx2 = -(h * h / dt) * jnp.sum(solid * (ut - u))
        fy2 = -(h * h / dt) * jnp.sum(solid * (vt - v))
        u = u * (1.0 - solid) + ut
        v = v * (1.0 - solid) + vt

        fx = fx1 + fx2
        fy = fy1 + fy2
        return (u, v, p), (fx / qref, fy / qref)

    return substep


# --------------------------------------------------------------------------
# One actuation period (the unit the Rust coordinator executes)
# --------------------------------------------------------------------------

def sample_probes(p, geom: Geometry):
    idx = jnp.asarray(geom.probe_idx)      # (P,4,2)
    w = jnp.asarray(geom.probe_w)          # (P,4)
    vals = p[idx[..., 0], idx[..., 1]]     # (P,4)
    return jnp.sum(vals * w, axis=1)


def make_period_fn(cfg: GridConfig, geom: Geometry, use_pallas: bool = True):
    """Returns period(u, v, p, jet_a) ->
    (u', v', p', probes[P], cd_hist[S], cl_hist[S]).

    One actuation period = cfg.substeps projection steps at constant jet
    amplitude (the agent's zero-order hold). The Rust env averages the
    cd/cl histories for the reward (Eq. 12) and feeds probes (normalised)
    to the policy as the next state.
    """
    substep = make_substep_fn(cfg, geom, use_pallas)

    def period(u, v, p, jet_a):
        def body(state, _):
            state, (cd, cl) = substep(state, jet_a)
            return state, (cd, cl)
        (u, v, p), (cd_h, cl_h) = jax.lax.scan(
            body, (u, v, p), None, length=cfg.substeps)
        return u, v, p, sample_probes(p, geom), cd_h, cl_h

    return period


def quiescent_state(cfg: GridConfig, geom: Geometry):
    """Initial condition: inlet profile everywhere (impulsive start)."""
    u = np.broadcast_to(geom.u_in[:, None], (cfg.ny, cfg.nx)).astype(np.float32)
    u = u * (1.0 - geom.solid)
    v = np.zeros((cfg.ny, cfg.nx), np.float32)
    p = np.zeros((cfg.ny, cfg.nx), np.float32)
    return jnp.asarray(u), jnp.asarray(v), jnp.asarray(p)


def develop_base_flow(cfg: GridConfig, geom: Geometry, use_pallas: bool = True,
                      time_units: float | None = None, report_every: int = 0):
    """Run the uncontrolled flow from an impulsive start until vortex
    shedding is developed. Returns (u, v, p, cd_hist, cl_hist) where the
    histories are per-period means over the run (used for C_D0 and for the
    probe-normalisation statistics)."""
    t_total = cfg.base_flow_time if time_units is None else time_units
    n_periods = int(round(t_total / cfg.period))
    period = jax.jit(make_period_fn(cfg, geom, use_pallas))
    u, v, p = quiescent_state(cfg, geom)
    cds, cls = [], []
    for k in range(n_periods):
        u, v, p, _, cd_h, cl_h = period(u, v, p, jnp.float32(0.0))
        cds.append(float(jnp.mean(cd_h)))
        cls.append(float(jnp.mean(cl_h)))
        if report_every and (k + 1) % report_every == 0:
            print(f"  base flow t={(k + 1) * cfg.period:7.2f} "
                  f"cd={cds[-1]:7.3f} cl={cls[-1]:7.3f}", flush=True)
    return u, v, p, np.array(cds), np.array(cls)
