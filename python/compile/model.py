"""L2: PPO policy/value model and update step (TensorForce substitute).

The paper trains a Rabault-style agent: 2x512 tanh MLP Gaussian policy,
clipped-surrogate PPO. We express the whole algorithm in JAX and lower two
executables (see aot.py):

  policy_apply(flat, obs)                    -- serving path, B=1
  ppo_update(flat, m, v, t, obs, act, logp_old, adv, ret)
                                             -- one Adam minibatch step

Parameters travel as ONE flat f32 vector so the Rust trainer holds three
opaque buffers (params, adam_m, adam_v) and never needs the layout; the
layout table still goes into the manifest for checkpoint tooling.

The serving forward runs the Pallas MXU kernel (kernels/mlp.py); the
differentiated forward inside ppo_update uses the pure-jnp twin because
interpret-mode pallas_call does not support reverse-mode AD (asserted in
python/tests/test_mlp.py). Both are allclose-tested against each other, so
the first-epoch ratio is 1 up to f32 rounding.
"""

import math
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from .configs import DrlConfig
from .kernels import mlp as k_mlp
from .kernels import ref as k_ref

LOG_2PI = math.log(2.0 * math.pi)


# --------------------------------------------------------------------------
# Flat parameter vector layout
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Slot:
    name: str
    offset: int
    shape: tuple


def param_layout(cfg: DrlConfig):
    """Ordered (name, shape) table; offsets are cumulative."""
    o, h, a = cfg.n_obs, cfg.hidden, cfg.n_act
    shapes = [
        ("w1", (o, h)), ("b1", (h,)),
        ("w2", (h, h)), ("b2", (h,)),
        ("wmu", (h, a)), ("bmu", (a,)),
        ("logstd", (a,)),
        ("wv", (h, 1)), ("bv", (1,)),
    ]
    slots, off = [], 0
    for name, shp in shapes:
        n = int(np.prod(shp))
        slots.append(Slot(name, off, shp))
        off += n
    assert off == cfg.n_params, (off, cfg.n_params)
    return slots, off


def unflatten(flat, cfg: DrlConfig):
    slots, _ = param_layout(cfg)
    out = {}
    for s in slots:
        n = int(np.prod(s.shape))
        out[s.name] = jax.lax.dynamic_slice(flat, (s.offset,), (n,)).reshape(s.shape)
    return out


def init_params(cfg: DrlConfig, seed: int = 0) -> np.ndarray:
    """Glorot-scaled init; tiny mu head so initial actions are near zero
    (the paper's agent starts with small actions, Fig 5b episode 1)."""
    rng = np.random.default_rng(seed)
    slots, n = param_layout(cfg)
    flat = np.zeros(n, np.float32)
    for s in slots:
        size = int(np.prod(s.shape))
        if s.name == "logstd":
            vals = np.full(size, cfg.init_logstd, np.float32)
        elif len(s.shape) == 1:
            vals = np.zeros(size, np.float32)
        else:
            fan_in, fan_out = s.shape[0], s.shape[1]
            scale = 0.01 if s.name in ("wmu",) else np.sqrt(2.0 / (fan_in + fan_out))
            vals = (rng.standard_normal(size) * scale).astype(np.float32)
        flat[s.offset:s.offset + size] = vals
    return flat


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def forward(flat, obs, cfg: DrlConfig, use_pallas: bool):
    """obs (B, n_obs) -> (mu (B,a), logstd (a,), v (B,))."""
    p = unflatten(flat, cfg)
    dense = k_mlp.dense if use_pallas else k_ref.dense
    h1 = dense(obs, p["w1"], p["b1"], "tanh")
    h2 = dense(h1, p["w2"], p["b2"], "tanh")
    mu = h2 @ p["wmu"] + p["bmu"]
    v = (h2 @ p["wv"] + p["bv"])[:, 0]
    return mu, p["logstd"], v


def make_policy_apply(cfg: DrlConfig, batch: int, use_pallas: bool = True):
    """Serving-path function to lower: (flat, obs) -> (mu, logstd, v)."""

    def policy_apply(flat, obs):
        return forward(flat, obs, cfg, use_pallas)

    return policy_apply


def gaussian_logp(act, mu, logstd):
    """Diagonal-Gaussian log density, summed over the action dim."""
    std = jnp.exp(logstd)
    z = (act - mu) / std
    return jnp.sum(-0.5 * z * z - logstd - 0.5 * LOG_2PI, axis=-1)


# --------------------------------------------------------------------------
# PPO clipped-surrogate update (Eq. 10) + Adam
# --------------------------------------------------------------------------

def ppo_loss(flat, obs, act, logp_old, adv, ret, cfg: DrlConfig):
    mu, logstd, vpred = forward(flat, obs, cfg, use_pallas=False)
    logp = gaussian_logp(act, mu, logstd)
    ratio = jnp.exp(logp - logp_old)
    clipped = jnp.clip(ratio, 1.0 - cfg.clip_eps, 1.0 + cfg.clip_eps)
    pg_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
    v_loss = jnp.mean((vpred - ret) ** 2)
    entropy = jnp.sum(logstd + 0.5 * (LOG_2PI + 1.0))
    total = pg_loss + cfg.vf_coef * v_loss - cfg.ent_coef * entropy
    stats = jnp.stack([
        pg_loss, v_loss, entropy,
        jnp.mean(logp_old - logp),                         # approx KL
        jnp.mean((jnp.abs(ratio - 1.0) > cfg.clip_eps).astype(jnp.float32)),
        jnp.float32(0.0),                                  # grad norm, below
    ])
    return total, stats


def make_ppo_update(cfg: DrlConfig):
    """One Adam minibatch step to lower:
    (flat, m, v, t, obs, act, logp_old, adv, ret)
        -> (flat', m', v', stats[6])."""

    def ppo_update(flat, m, v, t, obs, act, logp_old, adv, ret):
        grad_fn = jax.grad(ppo_loss, has_aux=True)
        g, stats = grad_fn(flat, obs, act, logp_old, adv, ret, cfg)
        gnorm = jnp.sqrt(jnp.sum(g * g))
        stats = stats.at[5].set(gnorm)

        m = cfg.adam_b1 * m + (1.0 - cfg.adam_b1) * g
        v = cfg.adam_b2 * v + (1.0 - cfg.adam_b2) * g * g
        mhat = m / (1.0 - cfg.adam_b1 ** t)
        vhat = v / (1.0 - cfg.adam_b2 ** t)
        flat = flat - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.adam_eps)
        return flat, m, v, stats

    return ppo_update


# --------------------------------------------------------------------------
# Reference rollout utilities (used by python tests; Rust re-implements)
# --------------------------------------------------------------------------

def gae(rewards, values, last_value, gamma, lam):
    """Generalised advantage estimation, numpy reference for the Rust twin
    (rust/src/drl/gae.rs is tested against vectors generated from this)."""
    n = len(rewards)
    adv = np.zeros(n, np.float32)
    last = 0.0
    for t in reversed(range(n)):
        nxt = last_value if t == n - 1 else values[t + 1]
        delta = rewards[t] + gamma * nxt - values[t]
        last = delta + gamma * lam * last
        adv[t] = last
    ret = adv + np.asarray(values, np.float32)
    return adv, ret
