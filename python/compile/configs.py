"""Grid / solver / DRL configuration presets for the AFC reproduction.

The paper (Jia & Xu 2024) uses the Schaefer confined-cylinder benchmark:
domain 22D x 4.1D, cylinder of diameter D=1 centred at the origin, channel
walls at y=-2.0 and y=+2.1 (0.05D vertical offset triggers shedding),
parabolic inlet with mean velocity Ubar=1 (Um=1.5), Re=100, two synthetic
jets of width 10 deg at theta=90/270 deg.

Two variants are AOT-compiled:
  - ``small``: coarse grid used for end-to-end training demos and CI on this
    single-core machine.
  - ``paper``: the fidelity target (dx ~ 1/24, dt matched to explicit
    stability); built on demand via ``make artifacts-paper``.
"""

from dataclasses import dataclass, field
import math


@dataclass(frozen=True)
class GridConfig:
    """Geometry + numerics for one CFD variant (all lengths in units of D)."""

    name: str
    ny: int                      # cells across the channel (y)
    x_up: float = 2.0            # inlet distance upstream of cylinder centre
    x_down: float = 20.0         # outlet distance downstream
    y_lo: float = -2.0           # bottom wall
    y_hi: float = 2.1            # top wall
    re: float = 100.0
    u_mean: float = 1.0          # bulk velocity Ubar
    dt: float = 0.005
    substeps: int = 10           # CFD substeps per actuation period
    n_sweeps: int = 50           # red-black SOR sweeps per projection
    sor_omega: float = 1.7
    jet_width_deg: float = 10.0
    jet_max: float = 1.5         # |V_jet| cap  (paper: <= Um)
    radius: float = 0.5
    base_flow_time: float = 60.0  # uncontrolled development time for state0

    @property
    def height(self) -> float:
        return self.y_hi - self.y_lo

    @property
    def h(self) -> float:
        """Uniform grid spacing (set by ny)."""
        return self.height / self.ny

    @property
    def nx(self) -> int:
        return int(round((self.x_up + self.x_down) / self.h))

    @property
    def u_max(self) -> float:
        """Peak of the parabolic inlet profile: Ubar = 2/3 Um."""
        return 1.5 * self.u_mean

    @property
    def y_center(self) -> float:
        """Channel mid-height (cylinder centre sits at y=0, offset 0.05D)."""
        return 0.5 * (self.y_lo + self.y_hi)

    @property
    def period(self) -> float:
        return self.dt * self.substeps

    def check_stability(self) -> None:
        """Explicit-stability sanity: CFL and diffusion limits."""
        nu = 1.0 / self.re
        cfl_dt = self.h / (1.5 * self.u_max)
        diff_dt = self.h * self.h / (4.0 * nu)
        assert self.dt <= cfl_dt, f"{self.name}: dt {self.dt} > CFL {cfl_dt:.4g}"
        assert self.dt <= diff_dt, f"{self.name}: dt {self.dt} > diff {diff_dt:.4g}"


@dataclass(frozen=True)
class DrlConfig:
    """PPO hyper-parameters (Rabault-style 2x512 Gaussian policy)."""

    n_obs: int = 149             # pressure probes
    n_act: int = 1               # single jet pair, V_G1 = -V_G2
    hidden: int = 512
    minibatch: int = 64          # static minibatch size baked into ppo_update
    lr: float = 3e-4
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    init_logstd: float = -0.5
    gamma: float = 0.99          # used by the Rust GAE path (recorded in manifest)
    gae_lambda: float = 0.95
    action_smoothing_beta: float = 0.4   # Eq. (11)
    reward_lift_penalty: float = 0.1     # omega in Eq. (12)

    @property
    def n_params(self) -> int:
        o, h, a = self.n_obs, self.hidden, self.n_act
        return (o * h + h) + (h * h + h) + (h * a + a) + a + (h + 1)
        # W1,b1        W2,b2        Wmu,bmu      logstd  Wv(+bv)


# Training/demo variant: ~2.4e4 cells, explicit-stable at dt=5e-3.
# n_sweeps=30: the perf pass (EXPERIMENTS.md section Perf) showed the
# warm-started projection converges identically at 30 vs 40 sweeps
# (cd delta < 0.01%, max|div| unchanged) for 23% less compute.
SMALL = GridConfig(name="small", ny=48, dt=0.005, substeps=10,
                   n_sweeps=30, base_flow_time=60.0, jet_width_deg=34.0)

# Paper-fidelity variant (~5e4 cells; OpenFOAM used 16.2k unstructured cells
# with an implicit solver at dt=5e-4; our explicit solver needs dt<=2.3e-3
# at this resolution, so substeps=20 keeps the actuation period close to the
# shedding-relative value used in training demos).
PAPER = GridConfig(name="paper", ny=96, dt=0.002, substeps=20,
                   n_sweeps=60, base_flow_time=80.0, jet_width_deg=18.0)

# Tiny variant for fast unit tests only (never shipped as an artifact).
TINY = GridConfig(name="tiny", ny=24, dt=0.008, substeps=4,
                  n_sweeps=30, base_flow_time=2.0, jet_width_deg=45.0)

# Second-Reynolds-number scenario (`cylinder-re200` in the Rust scenario
# registry): same geometry and grid as ``small`` but Re=200 — stronger,
# less regular shedding, a harder control target. Halved viscosity only
# *relaxes* the diffusion limit, so dt=5e-3 remains explicit-stable; the
# wake needs a little longer to develop.
RE200 = GridConfig(name="re200", ny=48, re=200.0, dt=0.005, substeps=10,
                   n_sweeps=30, base_flow_time=80.0, jet_width_deg=34.0)

VARIANTS = {c.name: c for c in (SMALL, PAPER, TINY, RE200)}

DRL = DrlConfig()

for _c in (SMALL, PAPER, TINY, RE200):
    _c.check_stability()
