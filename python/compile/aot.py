"""AOT pipeline: lower the L2 functions to HLO text + build-time data.

Run once by ``make artifacts`` (never on the request path):

  artifacts/
    manifest.json              -- shapes, layouts, physics + DRL constants
    cfd_period_<variant>.hlo.txt
    policy_apply_b1.hlo.txt
    ppo_update_b<M>.hlo.txt
    params_init.bin            -- flat f32 policy params (LE)
    state0_<variant>.bin       -- developed base flow (u|v|p, f32 LE)

Interchange is HLO *text*: the xla crate's xla_extension 0.5.1 rejects
jax>=0.5 serialized HloModuleProtos (64-bit instruction ids); the text
parser reassigns ids (see /opt/xla-example/README.md).

The base-flow development run also measures C_D0 (the uncontrolled mean
drag used in the reward, Eq. 12; paper: 3.205) and per-probe
normalisation statistics, both recorded in the manifest.
"""

import argparse
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import cfd
from . import model
from .configs import VARIANTS, DRL


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default elides
    big literals as ``{...}``, which the text parser on the Rust side
    silently reads back as garbage — the baked geometry masks (solid,
    jets, checkerboards, probe gather tables) must survive the trip.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_cfd_period(cfg, geom, use_pallas=True):
    fn = cfd.make_period_fn(cfg, geom, use_pallas)
    grid = jax.ShapeDtypeStruct((cfg.ny, cfg.nx), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(grid, grid, grid, scalar))


def lower_policy_apply(batch, use_pallas=True):
    fn = model.make_policy_apply(DRL, batch, use_pallas)
    flat = jax.ShapeDtypeStruct((DRL.n_params,), jnp.float32)
    obs = jax.ShapeDtypeStruct((batch, DRL.n_obs), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(flat, obs))


def lower_ppo_update():
    fn = model.make_ppo_update(DRL)
    b = DRL.minibatch
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((DRL.n_params,), f32),   # flat
        jax.ShapeDtypeStruct((DRL.n_params,), f32),   # adam m
        jax.ShapeDtypeStruct((DRL.n_params,), f32),   # adam v
        jax.ShapeDtypeStruct((), f32),                # t (1-based step)
        jax.ShapeDtypeStruct((b, DRL.n_obs), f32),    # obs
        jax.ShapeDtypeStruct((b, DRL.n_act), f32),    # act
        jax.ShapeDtypeStruct((b,), f32),              # logp_old
        jax.ShapeDtypeStruct((b,), f32),              # adv
        jax.ShapeDtypeStruct((b,), f32),              # ret
    ]
    return to_hlo_text(jax.jit(fn).lower(*args))


def develop_and_measure(cfg, geom, use_pallas=True, report=True):
    """Run the uncontrolled base flow; returns (state0, cd0, probe stats)."""
    period = jax.jit(cfd.make_period_fn(cfg, geom, use_pallas))
    u, v, p = cfd.quiescent_state(cfg, geom)
    n_periods = int(round(cfg.base_flow_time / cfg.period))
    cds, cls, probes = [], [], []
    t0 = time.time()
    for k in range(n_periods):
        u, v, p, pr, cd_h, cl_h = period(u, v, p, jnp.float32(0.0))
        cds.append(float(jnp.mean(cd_h)))
        cls.append(float(jnp.mean(cl_h)))
        probes.append(np.asarray(pr))
        if report and (k + 1) % max(1, n_periods // 6) == 0:
            print(f"  [{cfg.name}] base flow t={(k + 1) * cfg.period:6.1f}"
                  f"/{cfg.base_flow_time:.0f}  cd={cds[-1]:6.3f}"
                  f"  cl={cls[-1]:+6.3f}  ({time.time() - t0:5.1f}s)",
                  flush=True)
    tail = slice(max(1, n_periods // 2), None)       # developed half
    cd0 = float(np.mean(cds[tail]))
    pr_tail = np.stack(probes[tail.start:], axis=0)
    probe_mean = pr_tail.mean(axis=0)
    probe_std = np.maximum(pr_tail.std(axis=0), 1e-3)
    return (np.asarray(u), np.asarray(v), np.asarray(p)), cd0, \
        (probe_mean, probe_std), (np.array(cds), np.array(cls))


def write_bin(path, *arrays):
    with open(path, "wb") as f:
        for a in arrays:
            f.write(np.ascontiguousarray(a, np.float32).tobytes())


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variants", default="small",
                    help="comma-separated subset of: small,paper,tiny,re200")
    ap.add_argument("--policy-batch", type=int, default=8,
                    help="static batch of the batched-serving artifact "
                         "(coordinator central inference); 1 disables it")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--base-flow-time", type=float, default=None,
                    help="override development time (t.u.) for all variants")
    ap.add_argument("--no-pallas", action="store_true",
                    help="build artifacts from the pure-jnp reference path")
    args = ap.parse_args(argv)
    use_pallas = not args.no_pallas

    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    variants = [v.strip() for v in args.variants.split(",") if v.strip()]

    slots, n_params = model.param_layout(DRL)
    manifest = {
        "format_version": 1,
        "kernel_impl": "pallas" if use_pallas else "ref",
        "drl": {
            "n_obs": DRL.n_obs, "n_act": DRL.n_act, "hidden": DRL.hidden,
            "n_params": n_params, "minibatch": DRL.minibatch,
            "lr": DRL.lr, "clip_eps": DRL.clip_eps,
            "vf_coef": DRL.vf_coef, "ent_coef": DRL.ent_coef,
            "gamma": DRL.gamma, "gae_lambda": DRL.gae_lambda,
            "action_smoothing_beta": DRL.action_smoothing_beta,
            "reward_lift_penalty": DRL.reward_lift_penalty,
            "init_logstd": DRL.init_logstd,
            "param_layout": [
                {"name": s.name, "offset": s.offset, "shape": list(s.shape)}
                for s in slots
            ],
        },
        "artifacts": {
            "policy_apply": {"file": "policy_apply_b1.hlo.txt", "batch": 1},
            "ppo_update": {"file": f"ppo_update_b{DRL.minibatch}.hlo.txt",
                           "batch": DRL.minibatch},
        },
        "variants": {},
    }

    print("== lowering DRL executables ==", flush=True)
    with open(os.path.join(out, "policy_apply_b1.hlo.txt"), "w") as f:
        f.write(lower_policy_apply(1, use_pallas))
    if args.policy_batch > 1:
        # static-batch serving artifact for the coordinator's central
        # batched-inference mode (rust/src/coordinator/policy_server.rs)
        bfile = f"policy_apply_b{args.policy_batch}.hlo.txt"
        manifest["artifacts"]["policy_apply_batch"] = {
            "file": bfile, "batch": args.policy_batch,
        }
        with open(os.path.join(out, bfile), "w") as f:
            f.write(lower_policy_apply(args.policy_batch, use_pallas))
    with open(os.path.join(out, manifest["artifacts"]["ppo_update"]["file"]),
              "w") as f:
        f.write(lower_ppo_update())

    params0 = model.init_params(DRL, seed=args.seed)
    write_bin(os.path.join(out, "params_init.bin"), params0)
    print(f"   params_init.bin  ({n_params} f32)", flush=True)

    for name in variants:
        cfg = VARIANTS[name]
        if args.base_flow_time is not None:
            from dataclasses import replace
            cfg = replace(cfg, base_flow_time=args.base_flow_time)
        geom = cfd.build_geometry(cfg)
        print(f"== variant {name}: grid {cfg.ny}x{cfg.nx} "
              f"h={cfg.h:.4f} dt={cfg.dt} ==", flush=True)

        hlo = lower_cfd_period(cfg, geom, use_pallas)
        fn = f"cfd_period_{name}.hlo.txt"
        with open(os.path.join(out, fn), "w") as f:
            f.write(hlo)
        print(f"   {fn}  ({len(hlo)} chars)", flush=True)

        state0, cd0, (pmean, pstd), (cds, cls) = develop_and_measure(
            cfg, geom, use_pallas)
        write_bin(os.path.join(out, f"state0_{name}.bin"), *state0)
        cl_tail = cls[len(cls) // 2:]
        manifest["variants"][name] = {
            "cfd_period": fn,
            "state0": f"state0_{name}.bin",
            "ny": cfg.ny, "nx": cfg.nx, "h": cfg.h, "dt": cfg.dt,
            "substeps": cfg.substeps, "period": cfg.period,
            "re": cfg.re, "n_sweeps": cfg.n_sweeps,
            "jet_max": cfg.jet_max, "jet_width_deg": cfg.jet_width_deg,
            "cd0": cd0,
            "cl0_amplitude": float(np.std(cl_tail)),
            "base_flow_time": cfg.base_flow_time,
            "probe_mean": [float(x) for x in pmean],
            "probe_std": [float(x) for x in pstd],
            "probe_xy": [[float(a), float(b)] for a, b in geom.probe_xy],
        }
        print(f"   cd0={cd0:.3f}  cl'={np.std(cl_tail):.3f}", flush=True)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out}/manifest.json", flush=True)


if __name__ == "__main__":
    main()
