//! I/O-strategy comparison at REAL machine scale (the paper's section III D
//! experiment, shrunk to this box): run the same short training through
//! the three exchange interfaces and compare measured wall time, bytes
//! moved, and result equivalence.
//!
//!     cargo run --release --example io_comparison

use anyhow::Result;
use drlfoam::coordinator::{train, TrainConfig};
use drlfoam::io_interface::IoMode;

fn main() -> Result<()> {
    println!("same 2-env x 6-iteration training through each exchange interface:\n");
    println!(
        "{:<12} {:>9} {:>14} {:>14} {:>12}",
        "mode", "wall (s)", "KB/episode", "final reward", "cfd time (s)"
    );
    let mut rewards = Vec::new();
    for mode in [IoMode::InMemory, IoMode::Optimized, IoMode::Baseline] {
        let root = std::path::PathBuf::from(format!("out/io-comparison/{}", mode.name()));
        let cfg = TrainConfig {
            artifact_dir: "artifacts".into(),
            work_dir: root.join("work"),
            out_dir: root,
            variant: "small".into(),
            n_envs: 2,
            io_mode: mode,
            horizon: 10,
            iterations: 6,
            epochs: 2,
            seed: 3,
            log_every: 1,
            quiet: true,
            ..TrainConfig::default()
        };
        let s = train(&cfg)?;
        let last = s.log.last().unwrap();
        let cfd_total: f64 = s.log.iter().map(|r| r.cfd_s).sum();
        println!(
            "{:<12} {:>9.2} {:>14.1} {:>14.4} {:>12.2}",
            mode.name(),
            s.total_s,
            s.io_bytes_per_episode / 1024.0,
            last.mean_reward,
            cfd_total
        );
        rewards.push(last.mean_reward);
    }
    println!(
        "\nbinary (optimized) exchange is bit-exact: reward delta vs in-memory = {:.2e}",
        (rewards[0] - rewards[1]).abs()
    );
    println!(
        "ascii (baseline) parses through regex: reward delta = {:.2e} (parse precision)",
        (rewards[0] - rewards[2]).abs()
    );
    println!("\nAt 60 envs the byte volumes above are what saturate the shared disk —");
    println!("run `drlfoam reproduce table2` to see the projected cluster effect.");
    Ok(())
}
