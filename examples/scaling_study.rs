//! Scaling study driver: regenerates EVERY table and figure of the
//! paper's evaluation section in one run (DESIGN.md section 6) using the
//! calibrated cluster DES, and prints the headline comparison.
//!
//!     cargo run --release --example scaling_study
//!     cargo run --release --example scaling_study -- --calib out/calib.json
//!
//! Output: out/{fig7,table1,fig8,fig9,fig10,table2_fig11_fig12,sync_sweep,plan,summary}.csv

use anyhow::Result;
use drlfoam::cluster::planner::{self, PlannerConfig};
use drlfoam::cluster::Calibration;
use drlfoam::reproduce;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let calib = match args.iter().position(|a| a == "--calib") {
        Some(i) => Calibration::load(std::path::Path::new(&args[i + 1]))?,
        None => Calibration::paper_scale(),
    };
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out)?;

    println!("{}", reproduce::fig7(&calib, out)?);
    println!("{}", reproduce::table1(&calib, out)?);
    println!("{}", reproduce::fig8(&calib, out)?);
    println!("{}", reproduce::fig9(&calib, out)?);
    println!("{}", reproduce::fig10(&calib, out)?);
    println!("{}", reproduce::table2(&calib, out)?);
    println!("{}", reproduce::sync_sweep(&calib, out)?);
    // the planner's 60-core sweep at a REDUCED episode budget (the
    // paper-scale 3000-episode search is `drlfoam reproduce plan`,
    // deliberately kept out of this every-figure driver for cost)
    let mut pc = PlannerConfig::new(60);
    pc.episodes_total = 300;
    let plan_set = planner::search(&calib, &pc)?;
    plan_set.write_csv(out.join("plan.csv"))?;
    println!("{}", plan_set.render(10));
    println!("{}", reproduce::summary(&calib, out)?);
    println!("all series written under out/*.csv");
    Ok(())
}
