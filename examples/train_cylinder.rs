//! End-to-end training driver (the repository's validation experiment,
//! DESIGN.md section 6 "Fig 5"): train the PPO agent to reduce drag on the
//! confined cylinder with synthetic-jet control, multi-environment, and
//! log the full learning curve.
//!
//!     cargo run --release --example train_cylinder              # ~20 min
//!     cargo run --release --example train_cylinder -- --fast    # ~4 min
//!
//! Writes out/fig5/train_log.csv (reward, Cd, |Cl|, losses, timings per
//! iteration) and out/fig5/policy_final.bin. The headline check is the
//! paper's: mean drag falls below the uncontrolled Cd0 — the agent learns
//! blowing/suction that weakens shedding. EXPERIMENTS.md records a full
//! run.

use anyhow::Result;
use drlfoam::coordinator::{train, TrainConfig};
use drlfoam::io_interface::IoMode;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let cfg = TrainConfig {
        artifact_dir: "artifacts".into(),
        work_dir: "out/fig5/work".into(),
        out_dir: "out/fig5".into(),
        variant: "small".into(),
        n_envs: 4,
        io_mode: IoMode::InMemory,
        horizon: if fast { 20 } else { 40 },
        iterations: if fast { 30 } else { 120 },
        epochs: 4,
        seed: 0,
        log_every: 5,
        quiet: false,
        ..TrainConfig::default()
    };
    println!(
        "training {} iterations x {} envs x {} periods (fast={fast})\n",
        cfg.iterations, cfg.n_envs, cfg.horizon
    );
    let s = train(&cfg)?;

    // learning-curve summary: compare first and last quintile
    let k = (s.log.len() / 5).max(1);
    let head: f64 = s.log[..k].iter().map(|r| r.mean_reward).sum::<f64>() / k as f64;
    let tail: f64 = s.log[s.log.len() - k..]
        .iter()
        .map(|r| r.mean_reward)
        .sum::<f64>()
        / k as f64;
    let cd_head: f64 = s.log[..k].iter().map(|r| r.mean_cd).sum::<f64>() / k as f64;
    let cd_tail: f64 = s.log[s.log.len() - k..]
        .iter()
        .map(|r| r.mean_cd)
        .sum::<f64>()
        / k as f64;
    let m = drlfoam::runtime::Manifest::load("artifacts")?;
    let cd0 = m.variant("small")?.cd0;

    println!("\n=== training summary ({:.1} s wall) ===", s.total_s);
    println!("reward: {head:+.4} -> {tail:+.4}   (first vs last quintile mean)");
    println!("Cd:     {cd_head:.4} -> {cd_tail:.4}   (uncontrolled Cd0 = {cd0:.4})");
    println!(
        "drag reduction vs uncontrolled: {:+.2}%  (paper achieved ~8% at full scale)",
        100.0 * (cd0 - cd_tail) / cd0
    );
    if tail > head {
        println!("learning curve improved ✓");
    } else {
        println!("warning: no improvement — try more iterations (drop --fast)");
    }
    println!("curve: out/fig5/train_log.csv   policy: out/fig5/policy_final.bin");
    Ok(())
}
