//! Quickstart: load the AOT artifacts, run one controlled actuation
//! period, and print what the agent sees.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end slice of the stack: Pallas kernels
//! (L1) inside the JAX-lowered CFD executable (L2), driven by the Rust
//! runtime and environment (L3). Python is not involved at run time.

use anyhow::Result;
use drlfoam::drl::Policy;
use drlfoam::env::CfdEnv;
use drlfoam::io_interface::{make_interface, IoMode};
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::util::rng::Rng;

fn main() -> Result<()> {
    // 1. manifest + runtime: compile the HLO-text artifacts on the PJRT
    //    CPU client (once; executables are cached)
    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::new("artifacts")?;
    let variant = manifest.variant("small")?.clone();
    rt.load(&variant.cfd_period_file)?;
    rt.load(&manifest.drl.policy_apply_file)?;
    println!(
        "loaded variant `{}`: {}x{} grid, {} SOR sweeps, Cd0 = {:.3}",
        variant.name, variant.ny, variant.nx, variant.n_sweeps, variant.cd0
    );

    // 2. environment: developed base flow + in-memory exchange interface
    let work = std::env::temp_dir().join("drlfoam-quickstart");
    std::fs::create_dir_all(&work)?;
    let mut env = CfdEnv::new(
        variant.clone(),
        manifest.load_state0("small")?,
        manifest.drl.action_smoothing_beta,
        manifest.drl.reward_lift_penalty,
        make_interface(IoMode::InMemory, &work, 0)?,
    );

    // 3. policy: initial (untrained) parameters
    let params = manifest.load_params_init()?;
    let policy = Policy::new(manifest.drl.n_obs);
    let mut rng = Rng::new(0);

    let cfd = rt.get(&variant.cfd_period_file)?;
    let pol = rt.get(&manifest.drl.policy_apply_file)?;
    let mut obs = env.reset(cfd)?;
    println!("\n step    jet      Cd       Cl      reward");
    for step in 0..10 {
        let pout = policy.apply(pol, &params, &obs)?;
        let (action, _logp) = policy.sample(&pout, &mut rng);
        let sr = env.step(cfd, action)?;
        println!(
            "{step:>5} {:>7.3} {:>8.3} {:>8.3} {:>9.4}",
            sr.jet, sr.cd_mean, sr.cl_mean, sr.reward
        );
        obs = sr.obs;
    }
    println!("\nOK — the three-layer stack is wired. Next: examples/train_cylinder.rs");
    Ok(())
}
