#!/usr/bin/env bash
# Tier-1 CI gate for drlfoam-rs (run from the repo root).
#
# Mirrors ROADMAP.md's verify line plus the hygiene checks this project
# holds PRs to:
#   1. formatting            cargo fmt --check
#   2. lints                 cargo clippy (changed modules; -D warnings)
#   3. release build         cargo build --release
#   4. tests                 cargo test -q
#
# Integration tests that execute AOT artifacts skip themselves gracefully
# when `make artifacts` has not been run; the scenario-registry and
# batched-inference tests (rust/tests/scenario_registry.rs) run on the
# artifact-free surrogate path and must always pass.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "CI OK"
