#!/usr/bin/env bash
# Tier-1 CI gate for drlfoam-rs (run from the repo root).
#
# Mirrors ROADMAP.md's verify line plus the hygiene checks this project
# holds PRs to:
#   1. formatting            cargo fmt --check
#   2. lints                 cargo clippy (changed modules; -D warnings)
#   3. release build         cargo build --release
#   4. docs                  cargo doc --no-deps with rustdoc -D warnings,
#                            plus the runnable doctests (cargo test --doc)
#   5. tests                 cargo test -q
#   6. artifact-free smoke   drlfoam train on the surrogate scenario with
#                            the native update backend (no artifacts)
#   7. sync-policy smoke     the same loop once per rollout scheduler
#                            policy (--sync full|partial:2|async)
#   8. planner smoke         drlfoam plan sweep + train --layout auto,
#                            both artifact-free
#   9. multi-process smoke   the same artifact-free loop on real
#                            `drlfoam worker` OS processes, plus a
#                            chaos run (worker SIGKILL'd mid-training
#                            -> respawn + episode re-queue)
#  10. shm transport smoke   --transport shm train bitwise-diffed against
#                            --transport pipe, then the exec_transport
#                            bench's --gate (shm steps/s >= pipe, and
#                            uds steps/s >= pipe)
#  10b. socket smoke         --transport tcp trained through a localhost
#                            `drlfoam agent` process, bitwise-diffed
#                            against --transport pipe (learning columns
#                            + policy_final.bin)
#  11. native CFD smoke      --cfd-backend native cylinder training with
#                            zero artifacts, bitwise-diffed across a
#                            re-run, a thread-count change, and
#                            DRLFOAM_FORCE_SCALAR=1; then the cfd_scaling
#                            bench's --gate (SIMD period >= scalar)
#  12. repo-invariant audit  drlfoam audit (SAFETY comments, determinism
#                            bans, wire-tag coverage; ARCHITECTURE.md §9)
#  13. tracing smoke         train --trace (in-process): the Perfetto
#                            JSON + obs_summary.csv + drift.csv validated
#                            through `drlfoam trace` (util/json.rs parse
#                            + metrics::parse_csv); then a two-agent tcp
#                            traced run merged into one trace with a lane
#                            per host, bitwise-diffed against its
#                            untraced twin; then the episode_breakdown
#                            bench's --gate (tracing costs <=2% lockstep
#                            steps/s)
#
# Deeper verification stages run on demand behind env gates (set any to 1;
# they need toolchain components tier-1 does not assume):
#   DRLFOAM_CI_LOOM=1   loom model checking of the seqlock ring protocol
#                       (rust/tests/loom_shm.rs under RUSTFLAGS="--cfg loom")
#   DRLFOAM_CI_MIRI=1   cargo +nightly miri test over the safe codec layers
#                       (exec::wire, io_interface, drl::buffer)
#   DRLFOAM_CI_TSAN=1   ThreadSanitizer over the exec/transport test suite
#   DRLFOAM_CI_ASAN=1   AddressSanitizer over the same suite
#
# Integration tests that execute AOT artifacts skip themselves gracefully
# when `make artifacts` has not been run; the scenario-registry and
# batched-inference tests (rust/tests/scenario_registry.rs) run on the
# artifact-free surrogate path and must always pass.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets --all-features -- -D warnings

echo "== cargo build --release"
cargo build --release

# 11 runs right after the build: the audit is pure static analysis over
# rust/src (plus the fuzz corpus), so a rules violation fails the gate
# before any smoke spends time training.
echo "== repo-invariant audit (drlfoam audit)"
cargo run --release --quiet -- audit

echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo test --doc"
cargo test --doc -q

echo "== cargo test -q"
cargo test -q

# 5. artifact-free training smoke: the full loop (surrogate scenario,
#    native policy serving + native PPO update) must run end-to-end in a
#    checkout with nothing compiled. --artifacts points at a directory
#    that cannot exist so this exercises the zero-artifact path even when
#    `make artifacts` has been run.
echo "== artifact-free training smoke (surrogate scenario, native update)"
SMOKE_OUT=out/ci-train-smoke
rm -rf "$SMOKE_OUT"
cargo run --release --quiet -- train \
    --scenario surrogate --backend native --update-backend native \
    --artifacts "$SMOKE_OUT/no-artifacts" \
    --out "$SMOKE_OUT" --work-dir "$SMOKE_OUT/work" \
    --envs 2 --horizon 5 --iterations 2 --quiet
test -f "$SMOKE_OUT/train_log.csv"
test -f "$SMOKE_OUT/policy_final.bin"
test -f "$SMOKE_OUT/trainer_ckpt.bin"

# 6. rollout-scheduler smoke: every sync policy must complete the same
#    artifact-free run end to end (--sync partial:k is the new axis; the
#    staleness histogram must be written for the non-full policies).
echo "== sync-policy smoke (full / partial:2 / async)"
SYNC_OUT=out/ci-sync-smoke
rm -rf "$SYNC_OUT"
for s in full partial:2 async; do
    cargo run --release --quiet -- train \
        --scenario surrogate --backend native --update-backend native \
        --sync "$s" \
        --artifacts "$SYNC_OUT/no-artifacts" \
        --out "$SYNC_OUT/$s" --work-dir "$SYNC_OUT/$s/work" \
        --envs 3 --horizon 5 --iterations 2 --quiet
    test -f "$SYNC_OUT/$s/train_log.csv"
    test -f "$SYNC_OUT/$s/staleness.csv"
done

# 8a. planner smoke: the exhaustive layout sweep must rank a small budget
#     and write the full plan.csv (reduced episode budget keeps it fast).
echo "== planner smoke (drlfoam plan)"
PLAN_OUT=out/ci-plan-smoke
rm -rf "$PLAN_OUT"
cargo run --release --quiet -- plan --cores 12 --episodes 240 --out "$PLAN_OUT"
test -f "$PLAN_OUT/plan.csv"

# 8b. layout-auto smoke: measured-small calibration -> planner -> the
#     chosen (envs, sync, io) drives a real artifact-free training run.
echo "== train --layout auto smoke (artifact-free)"
AUTO_OUT=out/ci-auto-smoke
rm -rf "$AUTO_OUT"
cargo run --release --quiet -- train \
    --scenario surrogate --backend native --update-backend native \
    --layout auto --cores 4 \
    --artifacts "$AUTO_OUT/no-artifacts" \
    --out "$AUTO_OUT" --work-dir "$AUTO_OUT/work" \
    --horizon 5 --iterations 2 --quiet
test -f "$AUTO_OUT/plan.csv"
test -f "$AUTO_OUT/train_log.csv"
test -f "$AUTO_OUT/policy_final.bin"

# 9a. multi-process executor smoke: the same artifact-free loop, but every
#     environment is a real `drlfoam worker` OS process behind the wire
#     protocol (2 envs, tiny budget).
echo "== multi-process executor smoke (real worker processes)"
EXEC_OUT=out/ci-exec-smoke
rm -rf "$EXEC_OUT"
cargo run --release --quiet -- train \
    --scenario analytic --backend native --update-backend native \
    --executor multi-process \
    --artifacts "$EXEC_OUT/no-artifacts" \
    --out "$EXEC_OUT" --work-dir "$EXEC_OUT/work" \
    --envs 2 --horizon 5 --iterations 2 --quiet
test -f "$EXEC_OUT/train_log.csv"
test -f "$EXEC_OUT/workers.csv"
test -f "$EXEC_OUT/policy_final.bin"

# 9b. fault-handling smoke: --chaos kills env 0's worker on its second
#     episode; training must still complete (respawn + re-queue) and the
#     restart must be visible in workers.csv.
echo "== multi-process fault-recovery smoke (--chaos 0:1)"
CHAOS_OUT=out/ci-exec-chaos
rm -rf "$CHAOS_OUT"
cargo run --release --quiet -- train \
    --scenario analytic --backend native --update-backend native \
    --executor multi-process --chaos 0:1 \
    --artifacts "$CHAOS_OUT/no-artifacts" \
    --out "$CHAOS_OUT" --work-dir "$CHAOS_OUT/work" \
    --envs 2 --horizon 5 --iterations 3 --quiet
test -f "$CHAOS_OUT/train_log.csv"
grep -q "^0,3,1," "$CHAOS_OUT/workers.csv"   # env 0: 3 episodes, 1 restart

# 9c. layout auto through the process executor: calibration measured on
#     real worker processes, the chosen layout trains live.
echo "== train --layout auto --executor multi-process smoke"
EXAUTO_OUT=out/ci-exec-auto
rm -rf "$EXAUTO_OUT"
cargo run --release --quiet -- train \
    --scenario analytic --backend native --update-backend native \
    --executor multi-process --layout auto --cores 4 \
    --artifacts "$EXAUTO_OUT/no-artifacts" \
    --out "$EXAUTO_OUT" --work-dir "$EXAUTO_OUT/work" \
    --horizon 5 --iterations 2 --quiet
test -f "$EXAUTO_OUT/plan.csv"
test -f "$EXAUTO_OUT/train_log.csv"

# 9d. shm transport smoke: the same artifact-free loop over the
#     shared-memory seqlock rings, then bitwise-diffed against the pipe
#     transport — the learning-curve columns of train_log.csv (wall-clock
#     columns 10-14 legitimately differ) and the final parameter vector
#     must be identical. This is the CI-sized slice of the transport
#     conformance matrix (rust/tests/exec_transport_conformance.rs).
echo "== shm transport smoke (--transport shm, bitwise vs pipe)"
SHM_OUT=out/ci-shm-smoke
rm -rf "$SHM_OUT"
for t in pipe shm; do
    cargo run --release --quiet -- train \
        --scenario surrogate --backend native --update-backend native \
        --executor multi-process --transport "$t" \
        --artifacts "$SHM_OUT/no-artifacts" \
        --out "$SHM_OUT/$t" --work-dir "$SHM_OUT/$t/work" \
        --envs 2 --horizon 5 --iterations 2 --quiet
    test -f "$SHM_OUT/$t/train_log.csv"
    test -f "$SHM_OUT/$t/policy_final.bin"
done
cut -d, -f1-9 "$SHM_OUT/pipe/train_log.csv" > "$SHM_OUT/pipe-learning.csv"
cut -d, -f1-9 "$SHM_OUT/shm/train_log.csv" > "$SHM_OUT/shm-learning.csv"
cmp "$SHM_OUT/pipe-learning.csv" "$SHM_OUT/shm-learning.csv"
cmp "$SHM_OUT/pipe/policy_final.bin" "$SHM_OUT/shm/policy_final.bin"
# ring files must not outlive the run
if ls "$SHM_OUT"/shm/work/*.ring >/dev/null 2>&1; then
    echo "shm smoke FAILED: ring files left behind" >&2
    exit 1
fi

# 9e. transport throughput gate: the shm data plane must not be slower
#     than the pipe it replaces on the lockstep (data-plane-heavy) path,
#     and neither may the uds socket lane (the multi-node plane's
#     single-host floor).
echo "== transport throughput gate (cargo bench exec_transport -- --gate)"
cargo bench --bench exec_transport -- --gate

# 9e2. socket transport smoke: --transport tcp with the workers behind a
#      real `drlfoam agent` on localhost, bitwise-diffed against the
#      pipe transport exactly like 9d — the CI-sized slice of the
#      multi-node acceptance bar (agents relay frames, never touch them).
echo "== socket transport smoke (--transport tcp via a localhost agent, bitwise vs pipe)"
NET_OUT=out/ci-net-smoke
NET_PORT=7911
rm -rf "$NET_OUT"
mkdir -p "$NET_OUT"
cargo run --release --quiet -- train \
    --scenario surrogate --backend native --update-backend native \
    --executor multi-process --transport pipe \
    --artifacts "$NET_OUT/no-artifacts" \
    --out "$NET_OUT/pipe" --work-dir "$NET_OUT/pipe/work" \
    --envs 2 --horizon 5 --iterations 2 --quiet
# the agent must outlive the training run; use the built binary directly
# (killing a wrapping `cargo run` would orphan the listener)
"${CARGO_TARGET_DIR:-target}/release/drlfoam" agent --bind 127.0.0.1:$NET_PORT \
    > "$NET_OUT/agent.log" 2>&1 &
AGENT_PID=$!
trap 'kill $AGENT_PID 2>/dev/null || true' EXIT
for _ in $(seq 1 100); do
    grep -q "agent listening on" "$NET_OUT/agent.log" 2>/dev/null && break
    sleep 0.1
done
grep -q "agent listening on" "$NET_OUT/agent.log"
cargo run --release --quiet -- train \
    --scenario surrogate --backend native --update-backend native \
    --executor multi-process --transport tcp --hosts 127.0.0.1:$NET_PORT:2 \
    --artifacts "$NET_OUT/no-artifacts" \
    --out "$NET_OUT/tcp" --work-dir "$NET_OUT/tcp/work" \
    --envs 2 --horizon 5 --iterations 2 --quiet
kill $AGENT_PID 2>/dev/null || true
wait $AGENT_PID 2>/dev/null || true
trap - EXIT
cut -d, -f1-9 "$NET_OUT/pipe/train_log.csv" > "$NET_OUT/pipe-learning.csv"
cut -d, -f1-9 "$NET_OUT/tcp/train_log.csv" > "$NET_OUT/tcp-learning.csv"
cmp "$NET_OUT/pipe-learning.csv" "$NET_OUT/tcp-learning.csv"
cmp "$NET_OUT/pipe/policy_final.bin" "$NET_OUT/tcp/policy_final.bin"

# 9f. native-CFD smoke: a real cylinder training run with zero artifacts
#     (--cfd-backend native; the base flow develops in-process). Run three
#     ways — baseline (2 threads), an identical re-run, and a 1-thread
#     forced-scalar run — all three must agree bitwise on the learning
#     columns and on policy_final.bin. This is the engine's
#     scalar==SIMD==threaded contract observed end to end through
#     training, not just at the kernel level (rust/tests/cfd_native.rs).
echo "== native CFD smoke (--cfd-backend native, bitwise across paths)"
CFD_OUT=out/ci-cfd-smoke
rm -rf "$CFD_OUT"
run_native_cfd() {
    cargo run --release --quiet -- train \
        --scenario cylinder --variant tiny --cfd-backend native \
        --backend native --update-backend native \
        --artifacts "$CFD_OUT/no-artifacts" \
        --out "$CFD_OUT/$1" --work-dir "$CFD_OUT/$1/work" \
        --envs 2 --horizon 3 --iterations 2 --quiet
    test -f "$CFD_OUT/$1/train_log.csv"
    test -f "$CFD_OUT/$1/policy_final.bin"
    cut -d, -f1-9 "$CFD_OUT/$1/train_log.csv" > "$CFD_OUT/$1-learning.csv"
}
DRLFOAM_CFD_THREADS=2 run_native_cfd a
DRLFOAM_CFD_THREADS=2 run_native_cfd b
DRLFOAM_CFD_THREADS=1 DRLFOAM_FORCE_SCALAR=1 run_native_cfd scalar
cmp "$CFD_OUT/a-learning.csv" "$CFD_OUT/b-learning.csv"
cmp "$CFD_OUT/a-learning.csv" "$CFD_OUT/scalar-learning.csv"
cmp "$CFD_OUT/a/policy_final.bin" "$CFD_OUT/b/policy_final.bin"
cmp "$CFD_OUT/a/policy_final.bin" "$CFD_OUT/scalar/policy_final.bin"

# 9g. native CFD SIMD gate: the vectorized row kernels must not be slower
#     than the scalar twins on this machine (trivially passes where AVX2
#     is unavailable — the paths are then identical code).
echo "== native CFD SIMD gate (cargo bench cfd_scaling -- --gate)"
cargo bench --bench cfd_scaling -- --gate

# 13a. tracing smoke, in-process: a traced artifact-free run must leave
#      all three exporter outputs, and `drlfoam trace` must re-parse them
#      (the trace JSON through the util/json.rs parser, the CSVs through
#      metrics::parse_csv) into the component-breakdown table.
echo "== tracing smoke (train --trace, in-process)"
TRACE_OUT=out/ci-trace-smoke
rm -rf "$TRACE_OUT"
cargo run --release --quiet -- train \
    --scenario surrogate --backend native --update-backend native \
    --artifacts "$TRACE_OUT/no-artifacts" \
    --out "$TRACE_OUT" --work-dir "$TRACE_OUT/work" \
    --trace "$TRACE_OUT/trace.json" \
    --envs 2 --horizon 5 --iterations 2 --quiet
test -f "$TRACE_OUT/trace.json"
test -f "$TRACE_OUT/obs_summary.csv"
test -f "$TRACE_OUT/drift.csv"
cargo run --release --quiet -- trace "$TRACE_OUT/trace.json" > "$TRACE_OUT/summary.txt"
grep -q "per-phase percentiles" "$TRACE_OUT/summary.txt"
grep -q "plan-vs-actual drift" "$TRACE_OUT/summary.txt"
grep -q "cfd" "$TRACE_OUT/summary.txt"

# 13b. tracing smoke, two localhost agents: the acceptance topology — a
#      tcp training across two `drlfoam agent` processes must merge every
#      worker's spans into ONE trace with a distinct lane per host (the
#      agent endpoints appear as Perfetto process labels), populate
#      drift.csv, and stay bitwise identical to its untraced twin.
echo "== tracing smoke (two localhost agents, merged trace, bitwise vs untraced)"
TRACE2_OUT=out/ci-trace-agents
TRACE_PORT_A=7913
TRACE_PORT_B=7914
rm -rf "$TRACE2_OUT"
mkdir -p "$TRACE2_OUT"
"${CARGO_TARGET_DIR:-target}/release/drlfoam" agent --bind 127.0.0.1:$TRACE_PORT_A \
    > "$TRACE2_OUT/agent-a.log" 2>&1 &
AGENT_A_PID=$!
"${CARGO_TARGET_DIR:-target}/release/drlfoam" agent --bind 127.0.0.1:$TRACE_PORT_B \
    > "$TRACE2_OUT/agent-b.log" 2>&1 &
AGENT_B_PID=$!
trap 'kill $AGENT_A_PID $AGENT_B_PID 2>/dev/null || true' EXIT
for log in "$TRACE2_OUT/agent-a.log" "$TRACE2_OUT/agent-b.log"; do
    for _ in $(seq 1 100); do
        grep -q "agent listening on" "$log" 2>/dev/null && break
        sleep 0.1
    done
    grep -q "agent listening on" "$log"
done
run_traced_agents() {    # $1 = subdir, $2.. = extra flags
    local sub=$1; shift
    cargo run --release --quiet -- train \
        --scenario surrogate --backend native --update-backend native \
        --executor multi-process --transport tcp \
        --hosts 127.0.0.1:$TRACE_PORT_A:1,127.0.0.1:$TRACE_PORT_B:1 \
        --artifacts "$TRACE2_OUT/no-artifacts" \
        --out "$TRACE2_OUT/$sub" --work-dir "$TRACE2_OUT/$sub/work" \
        --envs 2 --horizon 5 --iterations 2 --quiet "$@"
}
run_traced_agents plain
run_traced_agents traced --trace "$TRACE2_OUT/traced/trace.json"
kill $AGENT_A_PID $AGENT_B_PID 2>/dev/null || true
wait $AGENT_A_PID $AGENT_B_PID 2>/dev/null || true
trap - EXIT
# one merged trace, a lane per agent host, populated drift report
grep -q "127.0.0.1:$TRACE_PORT_A" "$TRACE2_OUT/traced/trace.json"
grep -q "127.0.0.1:$TRACE_PORT_B" "$TRACE2_OUT/traced/trace.json"
test "$(wc -l < "$TRACE2_OUT/traced/drift.csv")" -gt 1
cargo run --release --quiet -- trace "$TRACE2_OUT/traced/trace.json" > /dev/null
# tracing must be bitwise-invisible: learning columns + final parameters
cut -d, -f1-9 "$TRACE2_OUT/plain/train_log.csv" > "$TRACE2_OUT/plain-learning.csv"
cut -d, -f1-9 "$TRACE2_OUT/traced/train_log.csv" > "$TRACE2_OUT/traced-learning.csv"
cmp "$TRACE2_OUT/plain-learning.csv" "$TRACE2_OUT/traced-learning.csv"
cmp "$TRACE2_OUT/plain/policy_final.bin" "$TRACE2_OUT/traced/policy_final.bin"

# 13c. tracing overhead gate: enabling span recording must cost no more
#      than 2% lockstep steps/s (best-of-3 each way).
echo "== tracing overhead gate (cargo bench episode_breakdown -- --gate)"
cargo bench --bench episode_breakdown -- --gate

# ---------------------------------------------------------------------------
# Deeper verification, opt-in (each stage needs a toolchain component the
# tier-1 environment does not assume: the loom dev-dependency graph, a
# nightly toolchain with miri, or sanitizer runtimes + rust-src).
# ---------------------------------------------------------------------------

# Loom model checking: exhaustively explores the interleavings of the
# seqlock ring protocol (publish/consume ordering, wraparound, torn
# writes, the drain-before-Died handshake). cfg(loom) swaps the std
# atomics for loom's via util::sync; the mmap ring itself is stubbed out
# and the protocol runs on the heap-backed ModelRing.
if [[ "${DRLFOAM_CI_LOOM:-0}" == "1" ]]; then
    echo "== loom model checking (rust/tests/loom_shm.rs)"
    RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
        cargo test --release --test loom_shm
fi

# Miri: interprets the safe codec layers (wire frame encode/decode, the
# three CFD<->DRL exchange interfaces, the trajectory buffer) checking
# for UB that tests can't observe. The mmap/process layers are excluded
# — miri has no OS to mmap from.
if [[ "${DRLFOAM_CI_MIRI:-0}" == "1" ]]; then
    echo "== cargo miri test (wire codec, io_interface, drl::buffer)"
    MIRIFLAGS="-Zmiri-strict-provenance" cargo +nightly miri test --lib \
        exec::wire io_interface drl::buffer
fi

# ThreadSanitizer over the concurrent exec/transport suite: catches data
# races the seqlock discipline is supposed to make impossible, on the
# real mmap ring rather than the loom model. Needs nightly + rust-src
# (-Zbuild-std so std itself is instrumented).
if [[ "${DRLFOAM_CI_TSAN:-0}" == "1" ]]; then
    echo "== ThreadSanitizer (exec_backend + exec_transport_conformance)"
    RUSTFLAGS="-Zsanitizer=thread" \
        cargo +nightly test -Zbuild-std \
        --target "$(rustc -vV | sed -n 's/^host: //p')" \
        --test exec_backend --test exec_transport_conformance
fi

# AddressSanitizer over the same suite: bounds/use-after-free coverage
# for the unsafe mmap slot arithmetic.
if [[ "${DRLFOAM_CI_ASAN:-0}" == "1" ]]; then
    echo "== AddressSanitizer (exec_backend + exec_transport_conformance)"
    RUSTFLAGS="-Zsanitizer=address" \
        cargo +nightly test -Zbuild-std \
        --target "$(rustc -vV | sed -n 's/^host: //p')" \
        --test exec_backend --test exec_transport_conformance
fi

echo "CI OK"
