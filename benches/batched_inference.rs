//! Bench: per-env vs central batched policy inference across environment
//! counts — the hybrid-parallelization axis this repo's batched mode
//! implements (paper section III).
//!
//! The scaling sweep runs on the `surrogate` scenario with the native
//! policy twin, so it needs NO artifacts and isolates coordination cost
//! (channel ping-pong + per-env dispatch vs one batched forward pass per
//! actuation period). When AOT artifacts are present, a second section
//! times the real XLA serving paths on the cylinder scenario.
//!
//! Run: `cargo bench --bench batched_inference`

use std::sync::Arc;

use drlfoam::coordinator::{EnvPool, PolicyServer, PoolConfig};
use drlfoam::drl::{NativePolicy, PolicyBackendKind};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::io_interface::IoMode;
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::util::bench;

fn surrogate_cfg(tag: &str, n_envs: usize) -> PoolConfig {
    let work = std::env::temp_dir().join(format!("drlfoam-binf-{tag}{n_envs}"));
    std::fs::create_dir_all(&work).unwrap();
    PoolConfig {
        artifact_dir: "artifacts".into(),
        work_dir: work,
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        n_envs,
        io_mode: IoMode::InMemory,
        seed: 0,
        ..PoolConfig::default()
    }
}

fn main() {
    let horizon = 50;
    let mut results = Vec::new();

    println!("== surrogate scenario, native policy (no artifacts) ==");
    println!("{:<12} {:>5} {:>12} {:>12} {:>8}", "mode", "envs", "wall ms", "ms/period", "speedup");
    for envs in [1usize, 2, 4, 8] {
        let params =
            Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(3));

        let mut pool = EnvPool::standalone(&surrogate_cfg("pe", envs)).unwrap();
        let r_per = bench::bench(
            &format!("surrogate per-env inference x{envs}"),
            1,
            5,
            || {
                pool.rollout(&params, horizon, 0).unwrap();
            },
        );

        let mut pool_b = EnvPool::standalone(&surrogate_cfg("ba", envs)).unwrap();
        let mut server = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
        let r_bat = bench::bench(
            &format!("surrogate batched inference x{envs}"),
            1,
            5,
            || {
                pool_b
                    .rollout_batched(None, &mut server, &params, horizon, 0)
                    .unwrap();
            },
        );

        for (name, r) in [("per-env", &r_per), ("batched", &r_bat)] {
            println!(
                "{:<12} {:>5} {:>12.3} {:>12.4} {:>8}",
                name,
                envs,
                r.mean_s * 1e3,
                r.mean_s * 1e3 / horizon as f64,
                if name == "batched" {
                    format!("{:.2}x", r_per.mean_s / r_bat.mean_s)
                } else {
                    String::new()
                }
            );
        }
        results.push(r_per);
        results.push(r_bat);
    }

    // --- real XLA serving paths, if artifacts are available
    match Manifest::load("artifacts") {
        Err(_) => println!("\n(no artifacts — skipping the XLA cylinder section)"),
        Ok(m) => {
            println!("\n== cylinder scenario, XLA policy serving ==");
            let m = Arc::new(m);
            let params = Arc::new(m.load_params_init().unwrap());
            let envs = 4;
            let horizon = 5;

            let mut cfg = surrogate_cfg("xla-pe", envs);
            cfg.scenario = "cylinder".into();
            cfg.backend = PolicyBackendKind::Xla;
            let mut pool = EnvPool::new(&cfg, &m).unwrap();
            let r_per = bench::bench(
                &format!("cylinder per-env XLA x{envs}"),
                1,
                3,
                || {
                    pool.rollout(&params, horizon, 0).unwrap();
                },
            );

            let mut cfg_b = surrogate_cfg("xla-ba", envs);
            cfg_b.scenario = "cylinder".into();
            cfg_b.backend = PolicyBackendKind::Native; // workers don't serve
            let mut pool_b = EnvPool::new(&cfg_b, &m).unwrap();
            let mut rt = Runtime::new("artifacts").unwrap();
            let mut server = PolicyServer::xla(&m.drl);
            server.load_into(&mut rt).unwrap();
            println!("server: {}", server.describe());
            let r_bat = bench::bench(
                &format!("cylinder batched XLA x{envs}"),
                1,
                3,
                || {
                    pool_b
                        .rollout_batched(Some(&rt), &mut server, &params, horizon, 0)
                        .unwrap();
                },
            );
            println!(
                "per-env {:.1} ms vs batched {:.1} ms per episode-set ({:.2}x)",
                r_per.mean_s * 1e3,
                r_bat.mean_s * 1e3,
                r_per.mean_s / r_bat.mean_s
            );
            results.push(r_per);
            results.push(r_bat);
        }
    }

    bench::save("batched_inference", &results);
}
