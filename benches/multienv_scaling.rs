//! Bench: Table I + Figs 8/9 — multi-environment scaling across the
//! hybrid (N_envs x N_ranks) grid via the cluster DES, plus real
//! multi-threaded pool rollouts at machine scale (1/2/4 envs) as the
//! shadow that validates the DES ordering.
//!
//! Run: `cargo bench --bench multienv_scaling`

use std::sync::Arc;

use drlfoam::cluster::Calibration;
use drlfoam::coordinator::pool::{EnvPool, PoolConfig};
use drlfoam::io_interface::IoMode;
use drlfoam::reproduce;
use drlfoam::runtime::Manifest;
use drlfoam::util::bench;

fn main() {
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out).unwrap();
    let calib = Calibration::paper_scale();
    println!("{}", reproduce::table1(&calib, out).unwrap());
    println!("{}", reproduce::fig8(&calib, out).unwrap());
    println!("{}", reproduce::fig9(&calib, out).unwrap());

    // --- real shadow: thread-pool rollout wall time at machine scale.
    // On a 1-core box threads interleave, so wall time grows ~linearly
    // with TOTAL episodes; the point is exercising the real coordinator.
    let manifest = Arc::new(Manifest::load("artifacts").expect("make artifacts"));
    let params = Arc::new(manifest.load_params_init().unwrap());
    let mut results = Vec::new();
    for envs in [1usize, 2, 4] {
        let work = std::env::temp_dir().join(format!("drlfoam-bench-pool{envs}"));
        std::fs::create_dir_all(&work).unwrap();
        let mut pool = EnvPool::new(
            &PoolConfig {
                artifact_dir: "artifacts".into(),
                work_dir: work,
                variant: "small".into(),
                scenario: "cylinder".into(),
                backend: drlfoam::drl::PolicyBackendKind::Xla,
                n_envs: envs,
                io_mode: IoMode::InMemory,
                seed: 0,
                ..PoolConfig::default()
            },
            &manifest,
        )
        .unwrap();
        let r = bench::bench(
            &format!("pool rollout x{envs} envs (horizon 5, real)"),
            1,
            5,
            || {
                pool.rollout(&params, 5, 0).unwrap();
            },
        );
        results.push(r);
    }
    bench::save("multienv_scaling", &results);
}
