//! Bench: execution-backend transport costs — wire-protocol frame
//! round-trip latency (encode + decode through a byte buffer) and live
//! step/episode throughput per (executor, transport) lane: in-process
//! threads, worker processes over pipes, over the shared-memory seqlock
//! rings, and over the loopback socket transports (tcp, uds). Surrogate
//! scenario, zero artifacts.
//!
//! This is the price tag of closing the sim-to-real gap: how much the
//! process boundary costs relative to the in-process channel path the
//! DES was calibrated on — and how much of that price the shm data
//! plane buys back. The lockstep (batched-inference) section is the
//! data-plane-heavy path: every actuation period crosses the transport
//! twice (Step out, StepOut back), so it is where pipe and shm actually
//! separate.
//!
//! Run: `cargo bench --bench exec_transport`
//!
//! CI gate: `cargo bench --bench exec_transport -- --gate` runs only a
//! quick best-of-N lockstep comparison and exits non-zero if shm step
//! throughput falls below pipe (the sanity bar for the shm ring) or uds
//! falls below pipe (the sanity bar for the socket data plane).

use std::io::Cursor;
use std::sync::Arc;

use drlfoam::coordinator::{EnvPool, PolicyServer, PoolConfig};
use drlfoam::drl::{NativePolicy, PolicyBackendKind};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::exec::wire::{read_frame, write_frame, Frame};
use drlfoam::exec::{ExecutorKind, TransportKind};
use drlfoam::io_interface::IoMode;
use drlfoam::util::bench;

fn pool_cfg(
    tag: &str,
    executor: ExecutorKind,
    transport: TransportKind,
    n_envs: usize,
) -> PoolConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-exectb-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(root.join("work")).unwrap();
    PoolConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        n_envs,
        io_mode: IoMode::InMemory,
        seed: 1,
        executor,
        transport,
        worker_bin: option_env!("CARGO_BIN_EXE_drlfoam").map(Into::into),
        ..PoolConfig::default()
    }
}

/// The five lanes of the conformance matrix's transport axis.
const LANES: [(&str, ExecutorKind, TransportKind); 5] = [
    ("in-process", ExecutorKind::InProcess, TransportKind::Pipe),
    ("mp/pipe", ExecutorKind::MultiProcess, TransportKind::Pipe),
    ("mp/shm", ExecutorKind::MultiProcess, TransportKind::Shm),
    ("mp/tcp", ExecutorKind::MultiProcess, TransportKind::Tcp),
    ("mp/uds", ExecutorKind::MultiProcess, TransportKind::Uds),
];

fn frame_roundtrip_bench(results: &mut Vec<bench::BenchResult>) {
    println!("== wire frames: encode + decode round trip ==");
    let frames: Vec<(&str, Frame)> = vec![
        ("Step", Frame::Step { action: 0.25 }),
        (
            "Obs[32]",
            Frame::Obs {
                obs: vec![0.5; SURROGATE_N_OBS],
            },
        ),
        (
            "SetParams[~2k]",
            Frame::SetParams {
                params: NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(7),
            },
        ),
    ];
    for (name, frame) in &frames {
        let mut encoded = Vec::new();
        write_frame(&mut encoded, frame).unwrap();
        let r = bench::bench(&format!("frame {name} ({} B)", encoded.len()), 1000, 20000, || {
            let mut buf = Vec::with_capacity(encoded.len());
            write_frame(&mut buf, frame).unwrap();
            let got = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert!(got.is_some());
        });
        results.push(r);
    }
}

fn throughput_bench(results: &mut Vec<bench::BenchResult>) {
    let horizon = 50usize;
    println!("\n== episode throughput per transport lane (surrogate, per-env inference) ==");
    println!(
        "{:<16} {:>5} {:>12} {:>14} {:>12}",
        "lane", "envs", "wall ms", "steps/s", "vs threads"
    );
    for envs in [2usize, 4] {
        let mut t_inproc = 0.0f64;
        for (name, kind, transport) in LANES {
            if kind == ExecutorKind::MultiProcess
                && option_env!("CARGO_BIN_EXE_drlfoam").is_none()
            {
                println!("{:<16} {:>5} (skipped: no worker binary)", name, envs);
                continue;
            }
            let cfg = pool_cfg(&format!("{}{envs}", name.replace('/', "-")), kind, transport, envs);
            let mut pool = EnvPool::standalone(&cfg).unwrap();
            let params =
                Arc::new(NativePolicy::new(pool.n_obs(), pool.hidden()).init_params(3));
            let mut iter = 0u64;
            let r = bench::bench(
                &format!("rollout {name} x{envs} (horizon {horizon})"),
                1,
                5,
                || {
                    pool.rollout(&params, horizon, iter).unwrap();
                    iter += 1;
                },
            );
            if kind == ExecutorKind::InProcess {
                t_inproc = r.mean_s;
            }
            let steps_per_s = (envs * horizon) as f64 / r.mean_s;
            println!(
                "{:<16} {:>5} {:>12.2} {:>14.0} {:>11.2}x",
                name,
                envs,
                r.mean_s * 1e3,
                steps_per_s,
                t_inproc / r.mean_s
            );
            results.push(r);
        }
    }
}

/// Best-of-N lockstep wall time for one lane: `reps` batched rollouts,
/// minimum taken (min is the robust statistic for a throughput gate —
/// scheduling noise only ever adds time).
fn lockstep_best_s(
    name: &str,
    kind: ExecutorKind,
    transport: TransportKind,
    envs: usize,
    horizon: usize,
    reps: usize,
) -> f64 {
    let cfg = pool_cfg(&format!("lk-{}", name.replace('/', "-")), kind, transport, envs);
    let mut pool = EnvPool::standalone(&cfg).unwrap();
    let params = Arc::new(NativePolicy::new(pool.n_obs(), pool.hidden()).init_params(3));
    let mut server = PolicyServer::native(pool.n_obs(), pool.hidden());
    // warmup spins the workers (and, for shm, maps the rings)
    pool.rollout_batched(None, &mut server, &params, horizon, 0).unwrap();
    let mut best = f64::INFINITY;
    for rep in 0..reps {
        let t0 = std::time::Instant::now();
        pool.rollout_batched(None, &mut server, &params, horizon, 1 + rep as u64)
            .unwrap();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn lockstep_bench(results: &mut Vec<bench::BenchResult>) {
    let (envs, horizon) = (2usize, 50usize);
    println!("\n== lockstep step throughput (batched inference; 2 transport hops/step) ==");
    if option_env!("CARGO_BIN_EXE_drlfoam").is_none() {
        println!("(skipped: no worker binary)");
        return;
    }
    for (name, kind, transport) in LANES {
        let cfg = pool_cfg(&format!("lkb-{}", name.replace('/', "-")), kind, transport, envs);
        let mut pool = EnvPool::standalone(&cfg).unwrap();
        let params = Arc::new(NativePolicy::new(pool.n_obs(), pool.hidden()).init_params(3));
        let mut server = PolicyServer::native(pool.n_obs(), pool.hidden());
        let mut iter = 0u64;
        let r = bench::bench(&format!("lockstep {name} x{envs} (horizon {horizon})"), 1, 5, || {
            pool.rollout_batched(None, &mut server, &params, horizon, iter).unwrap();
            iter += 1;
        });
        let steps_per_s = (envs * horizon) as f64 / r.mean_s;
        println!("    -> {steps_per_s:.0} steps/s");
        results.push(r);
    }
}

/// `--gate`: the CI sanity bar. Quick best-of-N lockstep comparisons;
/// exits 1 if the shm data plane delivers fewer steps/s than the pipe it
/// is supposed to beat, or if the uds socket lane (frames over a
/// loopback Unix socket, no relay hop) falls below the pipe — a socket
/// transport slower than stdio would make the multi-node plane a
/// regression even on one host.
fn gate() -> ! {
    if option_env!("CARGO_BIN_EXE_drlfoam").is_none() {
        println!("gate skipped: no worker binary");
        std::process::exit(0);
    }
    let (envs, horizon, reps) = (2usize, 50usize, 7usize);
    let pipe_s = lockstep_best_s("gate-pipe", ExecutorKind::MultiProcess, TransportKind::Pipe, envs, horizon, reps);
    let shm_s = lockstep_best_s("gate-shm", ExecutorKind::MultiProcess, TransportKind::Shm, envs, horizon, reps);
    let uds_s = lockstep_best_s("gate-uds", ExecutorKind::MultiProcess, TransportKind::Uds, envs, horizon, reps);
    let steps = (envs * horizon) as f64;
    println!(
        "gate: pipe {:.0} steps/s (best {:.2} ms), shm {:.0} steps/s (best {:.2} ms), \
         uds {:.0} steps/s (best {:.2} ms)",
        steps / pipe_s,
        pipe_s * 1e3,
        steps / shm_s,
        shm_s * 1e3,
        steps / uds_s,
        uds_s * 1e3
    );
    let mut failed = false;
    if shm_s > pipe_s {
        eprintln!("GATE FAILED: shm lockstep throughput below pipe");
        failed = true;
    }
    if uds_s > pipe_s {
        eprintln!("GATE FAILED: uds lockstep throughput below pipe");
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!("gate OK: shm >= pipe, uds >= pipe");
    std::process::exit(0);
}

fn main() {
    if std::env::args().any(|a| a == "--gate") {
        gate();
    }
    let mut results = Vec::new();
    frame_roundtrip_bench(&mut results);
    throughput_bench(&mut results);
    lockstep_bench(&mut results);
    bench::save("exec_transport", &results);
}
