//! Bench: execution-backend transport costs — wire-protocol frame
//! round-trip latency (encode + decode through a byte buffer) and live
//! step/episode throughput per backend (in-process threads vs real
//! `drlfoam worker` processes), surrogate scenario, zero artifacts.
//!
//! This is the price tag of closing the sim-to-real gap: how much the
//! process boundary (pipe hops, frame packing, context switches) costs
//! relative to the in-process channel path the DES was calibrated on.
//!
//! Run: `cargo bench --bench exec_transport`

use std::io::Cursor;
use std::sync::Arc;

use drlfoam::coordinator::{EnvPool, PoolConfig};
use drlfoam::drl::{NativePolicy, PolicyBackendKind};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::exec::wire::{read_frame, write_frame, Frame};
use drlfoam::exec::ExecutorKind;
use drlfoam::io_interface::IoMode;
use drlfoam::util::bench;

fn pool_cfg(tag: &str, executor: ExecutorKind, n_envs: usize) -> PoolConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-exectb-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(root.join("work")).unwrap();
    PoolConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        n_envs,
        io_mode: IoMode::InMemory,
        seed: 1,
        executor,
        worker_bin: option_env!("CARGO_BIN_EXE_drlfoam").map(Into::into),
        ..PoolConfig::default()
    }
}

fn frame_roundtrip_bench(results: &mut Vec<bench::BenchResult>) {
    println!("== wire frames: encode + decode round trip ==");
    let frames: Vec<(&str, Frame)> = vec![
        ("Step", Frame::Step { action: 0.25 }),
        (
            "Obs[32]",
            Frame::Obs {
                obs: vec![0.5; SURROGATE_N_OBS],
            },
        ),
        (
            "SetParams[~2k]",
            Frame::SetParams {
                params: NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(7),
            },
        ),
    ];
    for (name, frame) in &frames {
        let mut encoded = Vec::new();
        write_frame(&mut encoded, frame).unwrap();
        let r = bench::bench(&format!("frame {name} ({} B)", encoded.len()), 1000, 20000, || {
            let mut buf = Vec::with_capacity(encoded.len());
            write_frame(&mut buf, frame).unwrap();
            let got = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert!(got.is_some());
        });
        results.push(r);
    }
}

fn throughput_bench(results: &mut Vec<bench::BenchResult>) {
    let horizon = 50usize;
    println!("\n== step throughput per backend (surrogate, per-env inference) ==");
    println!(
        "{:<16} {:>5} {:>12} {:>14} {:>12}",
        "executor", "envs", "wall ms", "steps/s", "vs threads"
    );
    for envs in [2usize, 4] {
        let mut t_inproc = 0.0f64;
        for kind in [ExecutorKind::InProcess, ExecutorKind::MultiProcess] {
            if kind == ExecutorKind::MultiProcess
                && option_env!("CARGO_BIN_EXE_drlfoam").is_none()
            {
                println!("{:<16} {:>5} (skipped: no worker binary)", kind.name(), envs);
                continue;
            }
            let cfg = pool_cfg(&format!("{}{envs}", kind.name()), kind, envs);
            let mut pool = EnvPool::standalone(&cfg).unwrap();
            let params =
                Arc::new(NativePolicy::new(pool.n_obs(), pool.hidden()).init_params(3));
            let mut iter = 0u64;
            let r = bench::bench(
                &format!("rollout {} x{envs} (horizon {horizon})", kind.name()),
                1,
                5,
                || {
                    pool.rollout(&params, horizon, iter).unwrap();
                    iter += 1;
                },
            );
            if kind == ExecutorKind::InProcess {
                t_inproc = r.mean_s;
            }
            let steps_per_s = (envs * horizon) as f64 / r.mean_s;
            println!(
                "{:<16} {:>5} {:>12.2} {:>14.0} {:>11.2}x",
                kind.name(),
                envs,
                r.mean_s * 1e3,
                steps_per_s,
                t_inproc / r.mean_s
            );
            results.push(r);
        }
    }
}

fn main() {
    let mut results = Vec::new();
    frame_roundtrip_bench(&mut results);
    throughput_bench(&mut results);
    bench::save("exec_transport", &results);
}
