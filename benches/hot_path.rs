//! Bench: request-path micro-benchmarks (the perf-pass instrument for
//! EXPERIMENTS.md section Perf). Not tied to a paper figure: this is the L3
//! latency budget — policy serving, CFD period execution, PPO minibatch,
//! and the literal-conversion overhead around each.
//!
//! Run: `cargo bench --bench hot_path`

use drlfoam::drl::{Batch, Policy, PpoTrainer, TrainerBackend, Trajectory, Transition};
use drlfoam::runtime::{literal_f32, Manifest, Runtime};
use drlfoam::util::bench;
use drlfoam::util::rng::Rng;

fn main() {
    let m = Manifest::load("artifacts").expect("run `make artifacts`");
    let mut rt = Runtime::new("artifacts").unwrap();
    let vm = m.variant("small").unwrap().clone();
    rt.load(&vm.cfd_period_file).unwrap();
    rt.load(&m.drl.policy_apply_file).unwrap();
    rt.load(&m.drl.ppo_update_file).unwrap();
    let params = m.load_params_init().unwrap();
    let mut results = Vec::new();

    // --- policy serving (B=1)
    let pol = rt.get(&m.drl.policy_apply_file).unwrap();
    let policy = Policy::new(m.drl.n_obs);
    let obs = vec![0.2f32; m.drl.n_obs];
    results.push(bench::bench("policy_apply B=1", 10, 100, || {
        policy.apply(pol, &params, &obs).unwrap();
    }));

    // --- device-resident params session (perf fast path)
    let session = drlfoam::drl::policy::PolicySession::new(&rt, &params, m.drl.n_obs).unwrap();
    results.push(bench::bench("policy_apply B=1 (session/buffers)", 10, 100, || {
        session.apply(&rt, pol, &obs).unwrap();
    }));

    // --- literal upload overhead for the params vector (340k f32)
    results.push(bench::bench("literal_f32 340k params", 10, 100, || {
        literal_f32(&params, &[params.len() as i64]).unwrap();
    }));

    // --- CFD period (the dominant cost; includes state up/download)
    let (u, v, p) = m.load_state0("small").unwrap();
    let dims = [vm.ny as i64, vm.nx as i64];
    let cfd = rt.get(&vm.cfd_period_file).unwrap();
    results.push(bench::bench("cfd_period small (incl. transfers)", 3, 30, || {
        let args = [
            literal_f32(&u, &dims).unwrap(),
            literal_f32(&v, &dims).unwrap(),
            literal_f32(&p, &dims).unwrap(),
            drlfoam::runtime::scalar_f32(0.1),
        ];
        cfd.run(&args).unwrap();
    }));

    // --- PPO minibatch update
    let mut rng = Rng::new(1);
    let traj = Trajectory {
        transitions: (0..m.drl.minibatch)
            .map(|_| Transition {
                obs: (0..m.drl.n_obs).map(|_| rng.normal() as f32).collect(),
                action: rng.normal() * 0.1,
                logp: -1.0,
                reward: rng.normal() * 0.1,
                value: 0.0,
            })
            .collect(),
        last_value: 0.0,
        env_id: 0,
    };
    let batch = Batch::assemble(&[traj], m.drl.n_obs, 0.99, 0.95);
    let mut trainer = PpoTrainer::new(&m.drl, params.clone(), 1);
    let upd = rt.get(&m.drl.ppo_update_file).unwrap();
    results.push(bench::bench("ppo_update 1 minibatch (64)", 3, 30, || {
        trainer.update(TrainerBackend::Xla(upd), &batch, &mut rng).unwrap();
    }));

    // --- GAE + batch assembly (pure rust part of the loop)
    let trajs: Vec<Trajectory> = (0..8)
        .map(|e| Trajectory {
            transitions: (0..100)
                .map(|_| Transition {
                    obs: vec![0.1; m.drl.n_obs],
                    action: 0.0,
                    logp: -1.0,
                    reward: 0.05,
                    value: 0.01,
                })
                .collect(),
            last_value: 0.0,
            env_id: e,
        })
        .collect();
    results.push(bench::bench("batch assemble 8x100 samples", 5, 50, || {
        Batch::assemble(&trajs, m.drl.n_obs, 0.99, 0.95);
    }));

    bench::save("hot_path", &results);
}
