//! Bench: request-path micro-benchmarks (the perf-pass instrument for
//! EXPERIMENTS.md section Perf). Not tied to a paper figure: this is the L3
//! latency budget — policy serving, CFD period execution, PPO minibatch,
//! and the literal-conversion overhead around each.
//!
//! The artifact-free lanes (native policy, native CFD period, native PPO
//! minibatch, batch assembly) always run; each XLA lane prints
//! `skipped: no artifacts` when `make artifacts` has not been run.
//!
//! Run: `cargo bench --bench hot_path`

use drlfoam::cfd::{self, NativeEngine, NATIVE_HIDDEN, N_PROBES};
use drlfoam::drl::{
    Batch, NativePolicy, NativeUpdater, Policy, PpoHyperParams, PpoTrainer, TrainerBackend,
    Trajectory, Transition,
};
use drlfoam::runtime::{literal_f32, Manifest, Runtime};
use drlfoam::util::bench;
use drlfoam::util::rng::Rng;

fn synth_traj(n_obs: usize, n: usize, rng: &mut Rng) -> Trajectory {
    Trajectory {
        transitions: (0..n)
            .map(|_| Transition {
                obs: (0..n_obs).map(|_| rng.normal() as f32).collect(),
                action: rng.normal() * 0.1,
                logp: -1.0,
                reward: rng.normal() * 0.1,
                value: 0.0,
            })
            .collect(),
        last_value: 0.0,
        env_id: 0,
    }
}

/// The artifact-free lanes: the exact hot path of a `--cfd-backend
/// native` training run (native serving, native CFD period, native PPO
/// minibatch, GAE/batch assembly).
fn native_lanes(results: &mut Vec<bench::BenchResult>) {
    let mut rng = Rng::new(5);

    // --- native policy serving at the native-cylinder dims
    let net = NativePolicy::new(N_PROBES, NATIVE_HIDDEN);
    let params = net.init_params(0);
    let obs = vec![0.2f32; N_PROBES];
    results.push(bench::bench("native policy_apply B=1", 10, 100, || {
        net.apply(&params, &obs).unwrap();
    }));

    // --- native CFD period (tiny grid; quiescent start, no artifacts)
    let spec = cfd::variant("tiny").unwrap();
    let mut engine = NativeEngine::from_env(spec);
    let (mut u, mut v, mut p) = engine.quiescent();
    results.push(bench::bench("native cfd_period tiny", 5, 30, || {
        engine.period(&mut u, &mut v, &mut p, 0.1);
    }));

    // --- native PPO minibatch update
    let updater = NativeUpdater::new(N_PROBES, NATIVE_HIDDEN, PpoHyperParams::default());
    let traj = synth_traj(N_PROBES, 64, &mut rng);
    let batch = Batch::assemble(&[traj], N_PROBES, 0.99, 0.95);
    let mut trainer = PpoTrainer::with_minibatch(params, 64, 1);
    results.push(bench::bench("native ppo_update 1 minibatch (64)", 3, 30, || {
        trainer
            .update(TrainerBackend::Native(&updater), &batch, &mut rng)
            .unwrap();
    }));

    // --- GAE + batch assembly (pure rust part of the loop)
    let trajs: Vec<Trajectory> = (0..8)
        .map(|e| Trajectory {
            transitions: (0..100)
                .map(|_| Transition {
                    obs: vec![0.1; N_PROBES],
                    action: 0.0,
                    logp: -1.0,
                    reward: 0.05,
                    value: 0.01,
                })
                .collect(),
            last_value: 0.0,
            env_id: e,
        })
        .collect();
    results.push(bench::bench("batch assemble 8x100 samples", 5, 50, || {
        Batch::assemble(&trajs, N_PROBES, 0.99, 0.95);
    }));
}

/// The XLA lanes, only runnable over real artifacts.
fn xla_lanes(m: &Manifest, results: &mut Vec<bench::BenchResult>) {
    let mut rt = Runtime::new("artifacts").unwrap();
    let vm = m.variant("small").unwrap().clone();
    rt.load(&vm.cfd_period_file).unwrap();
    rt.load(&m.drl.policy_apply_file).unwrap();
    rt.load(&m.drl.ppo_update_file).unwrap();
    let params = m.load_params_init().unwrap();

    // --- policy serving (B=1)
    let pol = rt.get(&m.drl.policy_apply_file).unwrap();
    let policy = Policy::new(m.drl.n_obs);
    let obs = vec![0.2f32; m.drl.n_obs];
    results.push(bench::bench("policy_apply B=1", 10, 100, || {
        policy.apply(pol, &params, &obs).unwrap();
    }));

    // --- device-resident params session (perf fast path)
    let session = drlfoam::drl::policy::PolicySession::new(&rt, &params, m.drl.n_obs).unwrap();
    results.push(bench::bench("policy_apply B=1 (session/buffers)", 10, 100, || {
        session.apply(&rt, pol, &obs).unwrap();
    }));

    // --- literal upload overhead for the params vector (340k f32)
    results.push(bench::bench("literal_f32 340k params", 10, 100, || {
        literal_f32(&params, &[params.len() as i64]).unwrap();
    }));

    // --- CFD period (the dominant cost; includes state up/download)
    let (u, v, p) = m.load_state0("small").unwrap();
    let dims = [vm.ny as i64, vm.nx as i64];
    let cfd = rt.get(&vm.cfd_period_file).unwrap();
    results.push(bench::bench("cfd_period small (incl. transfers)", 3, 30, || {
        let args = [
            literal_f32(&u, &dims).unwrap(),
            literal_f32(&v, &dims).unwrap(),
            literal_f32(&p, &dims).unwrap(),
            drlfoam::runtime::scalar_f32(0.1),
        ];
        cfd.run(&args).unwrap();
    }));

    // --- PPO minibatch update
    let mut rng = Rng::new(1);
    let traj = synth_traj(m.drl.n_obs, m.drl.minibatch, &mut rng);
    let batch = Batch::assemble(&[traj], m.drl.n_obs, 0.99, 0.95);
    let mut trainer = PpoTrainer::new(&m.drl, params.clone(), 1);
    let upd = rt.get(&m.drl.ppo_update_file).unwrap();
    results.push(bench::bench("ppo_update 1 minibatch (64)", 3, 30, || {
        trainer.update(TrainerBackend::Xla(upd), &batch, &mut rng).unwrap();
    }));
}

fn main() {
    let mut results = Vec::new();
    native_lanes(&mut results);
    match Manifest::load_optional("artifacts").unwrap() {
        Some(m) => xla_lanes(&m, &mut results),
        None => {
            for lane in [
                "policy_apply B=1",
                "policy_apply B=1 (session/buffers)",
                "literal_f32 340k params",
                "cfd_period small (incl. transfers)",
                "ppo_update 1 minibatch (64)",
            ] {
                println!("{lane}: skipped: no artifacts");
            }
        }
    }
    bench::save("hot_path", &results);
}
