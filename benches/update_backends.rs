//! Bench: the PPO update component in isolation — native pure-Rust step
//! vs the AOT `ppo_update` artifact. The paper's §III deconstruction
//! makes the update an independently measurable component; this prints
//! its per-minibatch cost per backend.
//!
//! The first section runs with zero artifacts (surrogate-sized 32x32
//! net); when `make artifacts` has been run, a second section times both
//! backends on the real manifest-sized network (149 obs, 2x512 hidden).
//!
//! Run: `cargo bench --bench update_backends`

use drlfoam::drl::{
    Batch, NativePolicy, NativeUpdater, PpoHyperParams, PpoTrainer, TrainerBackend, Trajectory,
    Transition,
};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::util::bench;
use drlfoam::util::rng::Rng;

fn synth_batch(n_obs: usize, n: usize) -> Batch {
    let mut rng = Rng::new(3);
    let traj = Trajectory {
        transitions: (0..n)
            .map(|_| Transition {
                obs: (0..n_obs).map(|_| rng.normal() as f32).collect(),
                action: rng.normal() * 0.1,
                logp: -0.6,
                reward: rng.normal() * 0.1,
                value: 0.0,
            })
            .collect(),
        last_value: 0.0,
        env_id: 0,
    };
    Batch::assemble(&[traj], n_obs, 0.99, 0.95)
}

fn main() {
    let mut results = Vec::new();
    let mut rng = Rng::new(1);

    println!("== native update backend, surrogate-sized net (no artifacts) ==");
    let (o, h) = (SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let minibatch = 64;
    let nu = NativeUpdater::new(o, h, PpoHyperParams::default());
    let batch = synth_batch(o, minibatch);
    let mut trainer =
        PpoTrainer::with_minibatch(NativePolicy::new(o, h).init_params(3), minibatch, 1);
    results.push(bench::bench(
        &format!("native update {o}x{h} mb{minibatch}"),
        5,
        50,
        || {
            trainer
                .update(TrainerBackend::Native(&nu), &batch, &mut rng)
                .unwrap();
        },
    ));

    match Manifest::load("artifacts") {
        Err(_) => println!("(no artifacts — skipping the manifest-sized native-vs-XLA section)"),
        Ok(m) => {
            println!("\n== manifest-sized net ({}x{}): native vs XLA ==", m.drl.n_obs, m.drl.hidden);
            let params = m.load_params_init().unwrap();
            let batch = synth_batch(m.drl.n_obs, m.drl.minibatch);
            let nu = NativeUpdater::from_manifest(&m.drl);
            let mut tn = PpoTrainer::new(&m.drl, params.clone(), 1);
            let r_nat = bench::bench(
                &format!("native update {}x{} mb{}", m.drl.n_obs, m.drl.hidden, m.drl.minibatch),
                2,
                20,
                || {
                    tn.update(TrainerBackend::Native(&nu), &batch, &mut rng)
                        .unwrap();
                },
            );

            let mut rt = Runtime::new("artifacts").unwrap();
            rt.load(&m.drl.ppo_update_file).unwrap();
            let exe = rt.get(&m.drl.ppo_update_file).unwrap();
            let mut tx = PpoTrainer::new(&m.drl, params, 1);
            let r_xla = bench::bench(
                &format!("xla ppo_update mb{}", m.drl.minibatch),
                2,
                20,
                || {
                    tx.update(TrainerBackend::Xla(exe), &batch, &mut rng).unwrap();
                },
            );
            println!(
                "native {:.2} ms vs xla {:.2} ms per minibatch epoch ({:.2}x)",
                r_nat.mean_s * 1e3,
                r_xla.mean_s * 1e3,
                r_nat.mean_s / r_xla.mean_s
            );
            results.push(r_nat);
            results.push(r_xla);
        }
    }

    bench::save("update_backends", &results);
}
