//! Bench: Fig 10 — per-episode time breakdown (CFD vs I/O vs DRL) as the
//! environment count grows, via the DES at paper scale; plus the real
//! measured breakdown of one episode on this machine (XLA engine when
//! artifacts exist, skipped per-lane otherwise).
//!
//! Run: `cargo bench --bench episode_breakdown`

use drlfoam::cluster::Calibration;
use drlfoam::drl::Policy;
use drlfoam::env::{CfdEngineRef, CfdEnv};
use drlfoam::io_interface::{make_interface, IoMode};
use drlfoam::reproduce;
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::util::rng::Rng;

fn main() {
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out).unwrap();
    let calib = Calibration::paper_scale();
    println!("{}", reproduce::fig10(&calib, out).unwrap());

    // --- real measured breakdown, one 20-period episode per I/O mode
    let m = match Manifest::load_optional("artifacts").unwrap() {
        Some(m) => m,
        None => {
            println!("real breakdown (xla): skipped: no artifacts");
            return;
        }
    };
    let mut rt = Runtime::new("artifacts").unwrap();
    let vm = m.variant("small").unwrap().clone();
    rt.load(&vm.cfd_period_file).unwrap();
    rt.load(&m.drl.policy_apply_file).unwrap();
    let params = m.load_params_init().unwrap();
    let policy = Policy::new(m.drl.n_obs);

    println!("real breakdown on this machine (20 periods, `small` grid):");
    println!(
        "{:<12} {:>10} {:>10} {:>12}",
        "mode", "cfd (ms)", "io (ms)", "policy (ms)"
    );
    for mode in [IoMode::InMemory, IoMode::Optimized, IoMode::Baseline] {
        let work = std::env::temp_dir().join(format!("drlfoam-bench-bd-{}", mode.name()));
        std::fs::create_dir_all(&work).unwrap();
        let mut env = CfdEnv::new(
            vm.clone(),
            m.load_state0("small").unwrap(),
            m.drl.action_smoothing_beta,
            m.drl.reward_lift_penalty,
            make_interface(mode, &work, 0).unwrap(),
        );
        let cfd = rt.get(&vm.cfd_period_file).unwrap();
        let pol = rt.get(&m.drl.policy_apply_file).unwrap();
        let mut rng = Rng::new(0);
        let mut obs = env.reset(CfdEngineRef::Xla(cfd)).unwrap();
        let (mut t_cfd, mut t_io, mut t_pol) = (0.0, 0.0, 0.0);
        for _ in 0..20 {
            let t0 = std::time::Instant::now();
            let pout = policy.apply(pol, &params, &obs).unwrap();
            t_pol += t0.elapsed().as_secs_f64();
            let (a, _) = policy.sample(&pout, &mut rng);
            let sr = env.step(CfdEngineRef::Xla(cfd), a).unwrap();
            t_cfd += sr.timings.cfd_s;
            t_io += sr.timings.io_s;
            obs = sr.obs;
        }
        println!(
            "{:<12} {:>10.1} {:>10.1} {:>12.1}",
            mode.name(),
            t_cfd * 1e3,
            t_io * 1e3,
            t_pol * 1e3
        );
    }
}
