//! Bench: Fig 10 — per-episode time breakdown (CFD vs I/O vs DRL) as the
//! environment count grows, via the DES at paper scale; plus the real
//! measured breakdown on this machine, read from the unified tracing
//! plane (`drlfoam::obs`, ARCHITECTURE.md §12) instead of ad-hoc timers:
//! each lane runs a short artifact-free surrogate training with span
//! recording enabled and aggregates the drained spans per phase — the
//! same numbers `--trace` exports to `out/obs_summary.csv`.
//!
//! Run: `cargo bench --bench episode_breakdown`
//! CI gate: `cargo bench --bench episode_breakdown -- --gate` runs a
//! lockstep (central batched inference) training twice — tracing off,
//! tracing on — best-of-3 each, and exits 1 if enabling span recording
//! costs more than 2% lockstep steps/s. Export cost is excluded by
//! design: it is a one-shot end-of-run write, not a per-step tax.

use drlfoam::cluster::Calibration;
use drlfoam::coordinator::{train, InferenceMode, TrainConfig};
use drlfoam::drl::{PolicyBackendKind, UpdateBackendKind};
use drlfoam::io_interface::IoMode;
use drlfoam::obs;
use drlfoam::reproduce;

fn bench_cfg(tag: &str, io_mode: IoMode) -> TrainConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-bench-bd-{tag}-{}", std::process::id()));
    TrainConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        n_envs: 4,
        io_mode,
        horizon: 20,
        iterations: 2,
        epochs: 2,
        seed: 11,
        log_every: 1,
        quiet: true,
        ..TrainConfig::default()
    }
}

/// Run one traced lane and return `(phase -> (count, total_s), counters)`
/// from the drained span plane. Draining resets the plane, so lanes are
/// isolated from each other.
fn traced_lane(
    cfg: &TrainConfig,
) -> (
    std::collections::BTreeMap<&'static str, (usize, f64)>,
    std::collections::BTreeMap<String, u64>,
) {
    obs::enable();
    train(cfg).unwrap();
    let drained = obs::drain_all();
    obs::disable();
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
    let mut by_phase = std::collections::BTreeMap::new();
    for s in &drained.spans {
        if let Some(p) = obs::Phase::from_u8(s.phase) {
            let e = by_phase.entry(p.name()).or_insert((0usize, 0.0f64));
            e.0 += 1;
            e.1 += s.dur_us as f64 / 1e6;
        }
    }
    (by_phase, drained.counters)
}

/// `--gate`: span recording must cost <= 2% lockstep steps/s. Both twins
/// run the identical central-batched training; the traced twin records
/// spans into the plane (drained and discarded afterwards). Best-of-3
/// wall time is the robust statistic.
fn gate() -> ! {
    let run = |tag: &str, traced: bool| -> f64 {
        let mut cfg = bench_cfg(tag, IoMode::InMemory);
        cfg.inference = InferenceMode::Batched;
        cfg.n_envs = 4;
        cfg.horizon = 64;
        cfg.iterations = 6;
        // warmup run, then best-of-3
        let mut best = f64::INFINITY;
        for i in 0..4 {
            if traced {
                obs::enable();
            } else {
                obs::disable();
            }
            let t0 = std::time::Instant::now();
            train(&cfg).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            let _ = obs::drain_all();
            obs::disable();
            if i > 0 {
                best = best.min(wall);
            }
        }
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
        let steps = (cfg.iterations * cfg.n_envs * cfg.horizon) as f64;
        steps / best
    };
    let off = run("gate-off", false);
    let on = run("gate-on", true);
    println!(
        "gate: lockstep steps/s untraced {off:.0}, traced {on:.0} ({:.3}x)",
        on / off
    );
    if on < off * 0.98 {
        eprintln!("GATE FAILED: enabling tracing costs >2% lockstep steps/s");
        std::process::exit(1);
    }
    println!("gate OK: tracing overhead within 2%");
    std::process::exit(0);
}

fn main() {
    if std::env::args().any(|a| a == "--gate") {
        gate();
    }
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out).unwrap();
    let calib = Calibration::paper_scale();
    println!("{}", reproduce::fig10(&calib, out).unwrap());

    // --- real measured breakdown from the span plane, one short
    // artifact-free training per I/O mode (4 envs x 20 steps x 2 iters)
    println!("measured breakdown on this machine (surrogate, per obs span plane):");
    println!(
        "{:<12} {:>10} {:>10} {:>12} {:>10} {:>12}",
        "mode", "cfd (ms)", "io (ms)", "policy (ms)", "update (ms)", "idle (ms)"
    );
    for mode in [IoMode::InMemory, IoMode::Optimized, IoMode::Baseline] {
        let cfg = bench_cfg(&format!("lane-{}", mode.name()), mode);
        let (by_phase, _counters) = traced_lane(&cfg);
        let ms = |k: &str| by_phase.get(k).map(|e| e.1 * 1e3).unwrap_or(0.0);
        println!(
            "{:<12} {:>10.1} {:>10.1} {:>12.1} {:>10.1} {:>12.1}",
            mode.name(),
            ms("cfd"),
            ms("io"),
            ms("policy") + ms("policy_batch"),
            ms("update"),
            ms("barrier_idle"),
        );
    }
    println!(
        "\n(same aggregation `--trace` writes to out/obs_summary.csv; load the\n trace JSON in ui.perfetto.dev for the per-env timeline)"
    );
}
