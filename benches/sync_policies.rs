//! Bench: the rollout-scheduler axis — full vs partial-barrier vs async
//! sync policies on the live coordinator (surrogate scenario, native
//! backends, zero artifacts). Prints per-policy wall time, measured
//! barrier-idle seconds, and mean parameter staleness across env counts
//! {2, 4, 8}; the DES twin of this sweep is `drlfoam reproduce sync`.
//!
//! Run: `cargo bench --bench sync_policies`

use drlfoam::coordinator::{train, SyncPolicy, TrainConfig};
use drlfoam::drl::{PolicyBackendKind, UpdateBackendKind};
use drlfoam::io_interface::IoMode;
use drlfoam::util::bench;

fn cfg(tag: &str, n_envs: usize, sync: SyncPolicy) -> TrainConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-syncb-{tag}-{}", std::process::id()));
    TrainConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root,
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        sync,
        n_envs,
        io_mode: IoMode::InMemory,
        horizon: 20,
        iterations: 4,
        epochs: 2,
        seed: 3,
        log_every: 10_000,
        quiet: true,
        ..TrainConfig::default()
    }
}

fn main() {
    let mut results = Vec::new();
    println!("== sync policies, surrogate scenario (no artifacts) ==");
    println!(
        "{:<12} {:>5} {:>8} {:>12} {:>14} {:>10} {:>10}",
        "sync", "envs", "updates", "wall ms", "idle ms/round", "staleness", "vs full"
    );
    for envs in [2usize, 4, 8] {
        let policies = [
            SyncPolicy::Full,
            SyncPolicy::Partial { k: (envs / 2).max(1) },
            SyncPolicy::Async,
        ];
        let mut t_full = 0.0f64;
        for sync in policies {
            let c = cfg(&sync.name().replace(':', "-"), envs, sync);
            // one warmup + 3 timed runs; idle/staleness come from the last
            // run's own accounting (they are properties of the schedule,
            // not of the harness timer)
            let mut last = None;
            let r = bench::bench(
                &format!("train sync={} x{envs}", sync.name()),
                1,
                3,
                || {
                    last = Some(train(&c).expect("training failed"));
                },
            );
            let s = last.expect("bench ran");
            if sync == SyncPolicy::Full {
                t_full = r.mean_s;
            }
            // per-round idle, the unit the DES's SimBreakdown reports
            let idle_per_round = s.barrier_idle_s / s.log.len().max(1) as f64;
            println!(
                "{:<12} {:>5} {:>8} {:>12.1} {:>14.3} {:>10.3} {:>9.2}x",
                sync.name(),
                envs,
                s.log.len(),
                r.mean_s * 1e3,
                idle_per_round * 1e3,
                s.mean_staleness,
                t_full / r.mean_s
            );
            std::fs::remove_dir_all(&c.out_dir).ok();
            results.push(r);
        }
    }
    bench::save("sync_policies", &results);
}
