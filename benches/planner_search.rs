//! Bench: the allocation planner's exhaustive DES-backed sweep — cost of
//! `drlfoam plan` per core budget, and how the layout count grows. The
//! planner is an offline tool, but `train --layout auto` runs it before
//! every auto-planned job, so its latency budget matters.
//!
//! Run: `cargo bench --bench planner_search`

use drlfoam::cluster::planner::{search, PlannerConfig};
use drlfoam::cluster::Calibration;
use drlfoam::util::bench;

fn main() {
    let calib = Calibration::paper_scale();
    let mut results = Vec::new();
    println!("== allocation planner sweep (DES-scored, paper calibration) ==");
    println!("{:<10} {:>9} {:>12} {:>14}", "cores", "layouts", "episodes", "sweep ms");
    for cores in [8usize, 20, 60] {
        let mut pc = PlannerConfig::new(cores);
        // reduced budget: bench the search machinery, not 3000-episode DES
        pc.episodes_total = 120;
        let mut layouts = 0usize;
        let r = bench::bench(&format!("plan cores={cores}"), 1, 3, || {
            let set = search(&calib, &pc).expect("planner failed");
            layouts = set.plans.len();
        });
        println!(
            "{:<10} {:>9} {:>12} {:>14.1}",
            cores,
            layouts,
            pc.episodes_total,
            r.mean_s * 1e3
        );
        results.push(r);
    }
    bench::save("planner_search", &results);
}
