//! Bench: Table II + Figs 11/12 — the three I/O strategies (Baseline /
//! I/O-Disabled / Optimized) across env counts via the DES, plus the real
//! per-exchange cost of each interface on this machine's filesystem
//! (bytes written, serialize+parse wall time).
//!
//! Run: `cargo bench --bench io_strategies`

use drlfoam::cluster::Calibration;
use drlfoam::io_interface::{make_interface, CfdOutput, FlowSnapshot, IoMode};
use drlfoam::reproduce;
use drlfoam::util::bench;
use drlfoam::util::rng::Rng;

fn main() {
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out).unwrap();
    let calib = Calibration::paper_scale();
    println!("{}", reproduce::table2(&calib, out).unwrap());
    println!("{}", reproduce::summary(&calib, out).unwrap());

    // --- real per-exchange costs on this machine (the `small` grid)
    let (ny, nx, substeps) = (48usize, 258usize, 10usize);
    let mut rng = Rng::new(5);
    let u: Vec<f32> = (0..ny * nx).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..ny * nx).map(|_| rng.normal() as f32).collect();
    let p: Vec<f32> = (0..ny * nx).map(|_| rng.normal() as f32).collect();
    let payload = CfdOutput {
        probes: (0..149).map(|_| rng.normal() as f32).collect(),
        cd_hist: vec![3.1; substeps],
        cl_hist: vec![-0.7; substeps],
    };
    let work = std::env::temp_dir().join("drlfoam-bench-io");
    std::fs::create_dir_all(&work).unwrap();

    let mut results = Vec::new();
    for mode in [IoMode::Baseline, IoMode::Optimized, IoMode::InMemory] {
        let mut iface = make_interface(mode, &work, 0).unwrap();
        let flow = FlowSnapshot { u: &u, v: &v, p: &p, ny, nx };
        let (_, st) = iface.exchange(0, &payload, &flow).unwrap();
        let mut k = 1usize;
        let r = bench::bench(&format!("exchange {} (real fs)", mode.name()), 2, 15, || {
            let flow = FlowSnapshot { u: &u, v: &v, p: &p, ny, nx };
            iface.exchange(k, &payload, &flow).unwrap();
            iface.inject_action(k, 0.4).unwrap();
            k += 1;
        });
        println!(
            "    -> {} bytes/exchange ({} files)",
            st.bytes_written, st.files
        );
        results.push(r);
    }
    bench::save("io_strategies", &results);
}
