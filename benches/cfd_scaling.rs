//! Bench: Fig 7 — CFD strong scaling (speedup + parallel efficiency vs
//! N_ranks, T_1 and T_100 series), plus the real single-rank CFD period
//! cost on this machine that anchors the DES calibration.
//!
//! Run: `cargo bench --bench cfd_scaling`

use drlfoam::cluster::Calibration;
use drlfoam::env::CfdEnv;
use drlfoam::io_interface::{make_interface, IoMode};
use drlfoam::reproduce;
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::util::bench;

fn main() {
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out).unwrap();
    let calib = Calibration::paper_scale();
    println!("{}", reproduce::fig7(&calib, out).unwrap());

    // --- real anchor: single-rank CFD actuation period on this machine
    let m = Manifest::load("artifacts").expect("run `make artifacts`");
    let mut rt = Runtime::new("artifacts").unwrap();
    let vm = m.variant("small").unwrap().clone();
    rt.load(&vm.cfd_period_file).unwrap();
    let work = std::env::temp_dir().join("drlfoam-bench-cfd");
    std::fs::create_dir_all(&work).unwrap();
    let mut env = CfdEnv::new(
        vm.clone(),
        m.load_state0("small").unwrap(),
        m.drl.action_smoothing_beta,
        m.drl.reward_lift_penalty,
        make_interface(IoMode::InMemory, &work, 0).unwrap(),
    );
    let cfd = rt.get(&vm.cfd_period_file).unwrap();
    env.reset(cfd).unwrap();
    let r = bench::bench("cfd_period small (1 rank, real)", 3, 20, || {
        env.step(cfd, 0.1).unwrap();
    });
    println!(
        "\n(real {:.1} ms/period on this machine vs paper-scale {:.2} s; the DES\n uses the paper scale for absolute hours, `--calib out/calib.json`\n for machine scale)",
        r.mean_s * 1e3,
        calib.t_period_1rank
    );
    bench::save("cfd_scaling", &[r]);
}
