//! Bench: Fig 7 — CFD strong scaling (speedup + parallel efficiency vs
//! N_ranks, T_1 and T_100 series), plus the real CFD period cost on this
//! machine from BOTH engines:
//!
//! * native lanes (always, artifact-free): the pure-Rust SIMD+threaded
//!   engine across scalar/SIMD × thread counts on the `tiny` and `small`
//!   grids — the race the `--cfd-backend native` tentpole claims;
//! * XLA anchor (when `make artifacts` has run): the AOT `cfd_period`
//!   through CfdEnv, the series the DES calibration is scaled against.
//!
//! Run: `cargo bench --bench cfd_scaling`
//! CI gate: `cargo bench --bench cfd_scaling -- --gate` asserts the SIMD
//! path is not slower than scalar on this machine (trivially passes where
//! AVX2 is unavailable) — exits 1 on regression.

use drlfoam::cfd::{self, NativeEngine};
use drlfoam::cluster::Calibration;
use drlfoam::env::{CfdEngineRef, CfdEnv};
use drlfoam::io_interface::{make_interface, IoMode};
use drlfoam::reproduce;
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::util::bench;

/// One native-engine lane: actuation periods on a developing flow from a
/// quiescent start (no base-flow develop, so the lane costs milliseconds
/// and needs no artifacts). Returns the bench result for `save`.
fn native_lane(
    variant: &str,
    threads: usize,
    force_scalar: bool,
    warmup: usize,
    iters: usize,
) -> bench::BenchResult {
    let spec = cfd::variant(variant).unwrap();
    let mut engine = NativeEngine::new(spec, threads, force_scalar);
    let (mut u, mut v, mut p) = engine.quiescent();
    let label = format!(
        "native {variant} {}T {}",
        engine.threads(),
        if engine.simd_active() { "simd" } else { "scalar" }
    );
    bench::bench(&label, warmup, iters, || {
        engine.period(&mut u, &mut v, &mut p, 0.1);
    })
}

/// `--gate`: SIMD must not be slower than scalar (5% measurement slack;
/// best-of-N period time is the robust statistic). Where AVX2 is absent
/// the two lanes run identical code, so the gate passes trivially.
fn gate() -> ! {
    if !drlfoam::cfd::simd::avx2_available() {
        println!("gate skipped: AVX2 unavailable (scalar == simd path)");
        std::process::exit(0);
    }
    let best = |force_scalar: bool| -> f64 {
        let spec = cfd::variant("small").unwrap();
        let mut engine = NativeEngine::new(spec, 1, force_scalar);
        let (mut u, mut v, mut p) = engine.quiescent();
        for _ in 0..3 {
            engine.period(&mut u, &mut v, &mut p, 0.1);
        }
        (0..10)
            .map(|_| {
                let t0 = std::time::Instant::now();
                engine.period(&mut u, &mut v, &mut p, 0.1);
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min)
    };
    let scalar_s = best(true);
    let simd_s = best(false);
    println!(
        "gate: scalar best {:.3} ms/period, simd best {:.3} ms/period ({:.2}x)",
        scalar_s * 1e3,
        simd_s * 1e3,
        scalar_s / simd_s
    );
    if simd_s > scalar_s * 1.05 {
        eprintln!("GATE FAILED: native SIMD cfd period slower than scalar");
        std::process::exit(1);
    }
    println!("gate OK: simd >= scalar throughput");
    std::process::exit(0);
}

fn main() {
    if std::env::args().any(|a| a == "--gate") {
        gate();
    }
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out).unwrap();
    let calib = Calibration::paper_scale();
    println!("{}", reproduce::fig7(&calib, out).unwrap());

    // --- native engine lanes: scalar vs SIMD vs SIMD+threads, always on
    println!(
        "\n== native CFD engine (artifact-free; avx2 {}) ==",
        if drlfoam::cfd::simd::avx2_available() { "on" } else { "off" }
    );
    let mut results = Vec::new();
    for variant in ["tiny", "small"] {
        let (warmup, iters) = if variant == "tiny" { (5, 30) } else { (3, 15) };
        results.push(native_lane(variant, 1, true, warmup, iters));
        results.push(native_lane(variant, 1, false, warmup, iters));
        for threads in [2usize, 4] {
            results.push(native_lane(variant, threads, false, warmup, iters));
        }
    }

    // --- XLA anchor: single-rank AOT CFD actuation period on this machine
    match Manifest::load_optional("artifacts").unwrap() {
        Some(m) => {
            let mut rt = Runtime::new("artifacts").unwrap();
            let vm = m.variant("small").unwrap().clone();
            rt.load(&vm.cfd_period_file).unwrap();
            let work = std::env::temp_dir().join("drlfoam-bench-cfd");
            std::fs::create_dir_all(&work).unwrap();
            let mut env = CfdEnv::new(
                vm.clone(),
                m.load_state0("small").unwrap(),
                m.drl.action_smoothing_beta,
                m.drl.reward_lift_penalty,
                make_interface(IoMode::InMemory, &work, 0).unwrap(),
            );
            let cfd = rt.get(&vm.cfd_period_file).unwrap();
            env.reset(CfdEngineRef::Xla(cfd)).unwrap();
            let r = bench::bench("cfd_period small (xla, 1 rank)", 3, 20, || {
                env.step(CfdEngineRef::Xla(cfd), 0.1).unwrap();
            });
            println!(
                "\n(real {:.1} ms/period on this machine vs paper-scale {:.2} s; the DES\n uses the paper scale for absolute hours, `--calib out/calib.json`\n for machine scale)",
                r.mean_s * 1e3,
                calib.t_period_1rank
            );
            results.push(r);
        }
        None => println!("cfd_period small (xla): skipped: no artifacts"),
    }
    bench::save("cfd_scaling", &results);
}
