//! Scheduler equivalence: the unified rollout scheduler must not change
//! the learning dynamics of the synchronous baseline.
//!
//! * `--sync full` reproduces the PRE-REFACTOR synchronous loop bitwise:
//!   the old loop body (broadcast -> `EnvPool::rollout` episode barrier ->
//!   GAE -> minibatch update) is reimplemented here verbatim over public
//!   APIs, and its learning-curve rows must equal the scheduler's
//!   `train_log.csv` exactly (timing columns excluded — wall clock is not
//!   reproducible).
//! * `--sync partial:n_envs` is a full barrier and must match `--sync
//!   full` bitwise, final parameters included.
//!
//! Everything runs artifact-free (surrogate scenario, native backends).

use std::sync::Arc;

use drlfoam::coordinator::{train, EnvPool, PoolConfig, SyncPolicy, TrainConfig};
use drlfoam::drl::{
    Batch, NativePolicy, NativeUpdater, PolicyBackendKind, PpoHyperParams, PpoTrainer,
    TrainerBackend, UpdateBackendKind, DEFAULT_GAE_LAMBDA, DEFAULT_GAMMA,
};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::io_interface::IoMode;
use drlfoam::util::rng::Rng;

fn base_cfg(tag: &str) -> TrainConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-sched-{tag}-{}", std::process::id()));
    TrainConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        n_envs: 3,
        io_mode: IoMode::InMemory,
        horizon: 5,
        iterations: 3,
        epochs: 2,
        seed: 7,
        log_every: 1,
        quiet: true,
        ..TrainConfig::default()
    }
}

/// The learning-curve columns of train_log.csv: everything before the
/// wall-clock fields (iteration..approx_kl, the first 9 of 14).
fn learning_rows(out_dir: &std::path::Path) -> Vec<String> {
    let csv = std::fs::read_to_string(out_dir.join("train_log.csv")).unwrap();
    csv.lines()
        .skip(1)
        .map(|l| l.splitn(15, ',').take(9).collect::<Vec<_>>().join(","))
        .collect()
}

/// The pre-refactor synchronous training loop (the PR-2
/// `coordinator::train` body on the artifact-free path), reimplemented
/// over public APIs: same pool, same episode seeds, same trainer RNG
/// stream (`seed ^ 0xDA7A`), same 64-wide standalone minibatch, same row
/// formatting. This is the golden reference `--sync full` must match.
fn reference_sync_rows(cfg: &TrainConfig) -> (Vec<String>, Vec<f32>) {
    let pool_cfg = PoolConfig {
        artifact_dir: cfg.artifact_dir.clone(),
        work_dir: cfg.work_dir.clone(),
        variant: cfg.variant.clone(),
        scenario: cfg.scenario.clone(),
        backend: PolicyBackendKind::Native,
        n_envs: cfg.n_envs,
        io_mode: cfg.io_mode,
        seed: cfg.seed,
        ..PoolConfig::default()
    };
    std::fs::create_dir_all(&cfg.work_dir).unwrap();
    let mut pool = EnvPool::standalone(&pool_cfg).unwrap();
    let (n_obs, hidden) = (SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let params0 = NativePolicy::new(n_obs, hidden).init_params(cfg.seed);
    // 64 = the artifact-free standalone minibatch width
    let mut trainer = PpoTrainer::with_minibatch(params0, 64, cfg.epochs);
    let nu = NativeUpdater::new(n_obs, hidden, PpoHyperParams::default());
    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);

    let mut rows = Vec::new();
    let mut episodes_done = 0usize;
    for it in 0..cfg.iterations {
        let params = Arc::new(trainer.params.clone());
        let outs = pool.rollout(&params, cfg.horizon, it as u64).unwrap();
        episodes_done += outs.len();
        let n = outs.len() as f64;
        let mean_reward = outs.iter().map(|o| o.stats.reward_sum).sum::<f64>() / n;
        let mean_cd = outs.iter().map(|o| o.stats.cd_mean).sum::<f64>() / n;
        let mean_cl = outs.iter().map(|o| o.stats.cl_abs_mean).sum::<f64>() / n;
        let jet_final = outs.last().map(|o| o.stats.jet_final).unwrap_or(0.0);
        let trajs: Vec<_> = outs.into_iter().map(|o| o.traj).collect();
        let batch = Batch::assemble(&trajs, n_obs, DEFAULT_GAMMA, DEFAULT_GAE_LAMBDA);
        let upd = trainer
            .update(TrainerBackend::Native(&nu), &batch, &mut rng)
            .unwrap();
        rows.push(format!(
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6}",
            it, episodes_done, mean_reward, mean_cd, mean_cl, jet_final, upd.pi_loss,
            upd.v_loss, upd.approx_kl
        ));
    }
    (rows, trainer.params.clone())
}

#[test]
fn sync_full_matches_pre_refactor_loop_bitwise() {
    let cfg_ref = base_cfg("ref");
    let (want_rows, want_params) = reference_sync_rows(&cfg_ref);
    std::fs::remove_dir_all(&cfg_ref.out_dir).ok();

    let cfg = base_cfg("full");
    assert_eq!(cfg.sync, SyncPolicy::Full, "full is the default");
    let s = train(&cfg).expect("training failed");
    let got_rows = learning_rows(&cfg.out_dir);
    std::fs::remove_dir_all(&cfg.out_dir).ok();

    assert_eq!(got_rows, want_rows, "learning-curve CSV diverged");
    assert_eq!(s.final_params, want_params, "final parameters diverged");
    assert_eq!(s.mean_staleness, 0.0);
}

#[test]
fn sync_partial_n_envs_equals_full() {
    let cfg_full = base_cfg("pf-full");
    let a = train(&cfg_full).unwrap();
    let rows_full = learning_rows(&cfg_full.out_dir);
    std::fs::remove_dir_all(&cfg_full.out_dir).ok();

    let mut cfg_part = base_cfg("pf-part");
    cfg_part.sync = SyncPolicy::Partial { k: cfg_part.n_envs };
    let b = train(&cfg_part).unwrap();
    let rows_part = learning_rows(&cfg_part.out_dir);
    std::fs::remove_dir_all(&cfg_part.out_dir).ok();

    assert_eq!(rows_full, rows_part, "partial:n_envs must be a full barrier");
    assert_eq!(a.final_params, b.final_params);
    assert_eq!(b.mean_staleness, 0.0, "a full barrier is on-policy");
}

#[test]
fn sync_partial_k_above_pool_clamps_to_full() {
    let cfg_full = base_cfg("cl-full");
    let a = train(&cfg_full).unwrap();
    std::fs::remove_dir_all(&cfg_full.out_dir).ok();

    let mut cfg_big = base_cfg("cl-big");
    cfg_big.sync = SyncPolicy::Partial { k: 99 };
    let b = train(&cfg_big).unwrap();
    std::fs::remove_dir_all(&cfg_big.out_dir).ok();

    assert_eq!(a.final_params, b.final_params);
    assert_eq!(a.log.len(), b.log.len());
}

#[test]
fn sync_full_batched_inference_still_matches_per_env() {
    // the refactor routes batched serving through the subset rollout;
    // the per-env vs batched bitwise equivalence must survive it
    let cfg_pe = base_cfg("bi-pe");
    let a = train(&cfg_pe).unwrap();
    std::fs::remove_dir_all(&cfg_pe.out_dir).ok();

    let mut cfg_ba = base_cfg("bi-ba");
    cfg_ba.inference = drlfoam::coordinator::InferenceMode::Batched;
    let b = train(&cfg_ba).unwrap();
    std::fs::remove_dir_all(&cfg_ba.out_dir).ok();

    assert_eq!(a.final_params, b.final_params);
    assert_eq!(a.log[0].mean_reward, b.log[0].mean_reward);
}

#[test]
fn partial_with_batched_inference_composes() {
    // the policy server batches whatever observation set is at the
    // barrier (the re-dispatched subset), not all n — the run must
    // complete the full episode budget with bounded staleness
    let mut cfg = base_cfg("bi-part");
    cfg.inference = drlfoam::coordinator::InferenceMode::Batched;
    cfg.sync = SyncPolicy::Partial { k: 2 };
    let s = train(&cfg).expect("partial + batched failed");
    std::fs::remove_dir_all(&cfg.out_dir).ok();
    // 9 episodes at k=2 -> 5 updates (last one short)
    assert_eq!(s.log.len(), 5);
    assert_eq!(s.log.last().unwrap().episodes_done, 9);
    assert_eq!(s.staleness_hist.iter().sum::<usize>(), 9);
    assert!(s.log.iter().all(|r| r.mean_reward.is_finite()));
}
