//! Loom model checks for the shm data plane's seqlock protocol.
//!
//! This file is compiled ONLY under `RUSTFLAGS="--cfg loom"` (`make
//! loom`, or `DRLFOAM_CI_LOOM=1 ./ci.sh`); a regular `cargo test` sees
//! an empty test binary. Under loom, every test body runs once per
//! *possible interleaving* of its threads (bounded by
//! `LOOM_MAX_PREEMPTIONS`), with loom's tracked atomics and cells
//! standing in for std's via the `util::sync` facade — so these are
//! exhaustive memory-model proofs of the protocol in
//! `exec::seqlock`, not stress tests.
//!
//! The mmap ring itself (`exec::shm`) cannot exist under loom (loom
//! atomics are heap objects, not views over mapped bytes), so the checks
//! run on `seqlock::ModelRing`, which drives its slots through the SAME
//! five protocol functions (`slot_init` / `producer_owns` / `publish` /
//! `consumer_owns` / `release`) the mmap ring uses — the orderings being
//! proved here are, by construction, the orderings shipping in shm.rs.
//!
//! What is covered, mapped to the claims in ARCHITECTURE.md §9:
//!
//! * publish/consume ordering — frames arrive complete, in order;
//! * wraparound at `n_slots` — the lap arithmetic (`seq = pos + n_slots`
//!   on release) keeps ownership correct across ring laps;
//! * torn-write-never-published — a producer that crashes mid-write is
//!   invisible to the consumer on EVERY interleaving;
//! * drain-before-Died — the `peer_gone` handshake from
//!   `exec/process.rs::ring_reader_loop` (load the death flag with
//!   Acquire BEFORE each empty poll) can never report a death while a
//!   published frame is still in the ring;
//! * and one deliberately-broken ordering (`push_with_relaxed_publish`,
//!   Release weakened to Relaxed) that loom must CATCH — proving the
//!   model genuinely explores the interleavings rather than vacuously
//!   passing.
#![cfg(loom)]

use drlfoam::exec::seqlock::ModelRing;
use drlfoam::util::sync::{Arc, AtomicBool, Ordering};

use loom::thread;

/// Frames arrive complete and in publication order: the consumer either
/// sees nothing yet or the exact bytes the producer published, never a
/// prefix, never reordered.
#[test]
fn published_frames_arrive_complete_and_in_order() {
    loom::model(|| {
        let (mut tx, mut rx) = ModelRing::pair(2);
        let producer = thread::spawn(move || {
            assert!(tx.try_push(&[1, 2, 3]));
            assert!(tx.try_push(&[4, 5]));
        });
        let mut got: Vec<Vec<u8>> = Vec::new();
        while got.len() < 2 {
            match rx.try_pop() {
                Some(frame) => got.push(frame),
                None => thread::yield_now(),
            }
        }
        assert_eq!(got, vec![vec![1, 2, 3], vec![4, 5]]);
        producer.join().unwrap();
    });
}

/// A producer that dies between writing payload bytes and publishing
/// leaves `seq == pos`, so on EVERY interleaving the consumer treats the
/// slot as empty — it must not even *read* the cell (loom tracks the
/// access; a protocol bug that peeks at an unpublished slot while the
/// producer writes it is a detected data race, not silent corruption).
#[test]
fn torn_write_is_never_observable() {
    loom::model(|| {
        let (mut tx, mut rx) = ModelRing::pair(2);
        let producer = thread::spawn(move || {
            assert!(tx.try_push(&[7]));
            tx.write_torn(&[0xDE, 0xAD, 0xBE, 0xEF]); // crash mid-write
        });
        // The only frame that can ever come out is the published one.
        let mut got: Vec<Vec<u8>> = Vec::new();
        for _ in 0..4 {
            if let Some(frame) = rx.try_pop() {
                got.push(frame);
            } else {
                thread::yield_now();
            }
        }
        producer.join().unwrap();
        // after join: the published frame is visible, the torn one never is
        if let Some(frame) = rx.try_pop() {
            got.push(frame);
        }
        assert_eq!(got, vec![vec![7]]);
        assert!(rx.try_pop().is_none());
    });
}

/// Wraparound: with `n_slots = 2`, four frames force every slot through
/// a full lap (`seq` advancing `i → i+1 → i+n_slots → ...`). Ownership
/// hand-off must stay correct across laps on every interleaving — the
/// producer can never overwrite an unconsumed slot, the consumer can
/// never re-read a stale one.
#[test]
fn wraparound_keeps_ownership_across_laps() {
    loom::model(|| {
        let (mut tx, mut rx) = ModelRing::pair(2);
        const N: u8 = 4; // 2 full laps of a 2-slot ring
        let producer = thread::spawn(move || {
            for i in 0..N {
                while !tx.try_push(&[i]) {
                    thread::yield_now();
                }
            }
        });
        let mut next = 0u8;
        while next < N {
            match rx.try_pop() {
                Some(frame) => {
                    assert_eq!(frame, vec![next]);
                    next += 1;
                }
                None => thread::yield_now(),
            }
        }
        assert!(rx.try_pop().is_none());
        producer.join().unwrap();
    });
}

/// The drain-before-Died handshake of `exec/process.rs`, modelled
/// exactly: the worker publishes its last frame *then* dies (the pipe
/// reader observes EOF and stores `peer_gone` with Release); the ring
/// reader loads `peer_gone` with Acquire BEFORE each empty poll and
/// reports Died only on (gone && ring empty). The ordering — flag first,
/// then poll — is what makes "gone, ring empty" conclusive: seeing
/// `gone == true` acquires everything the worker published before
/// dying, so an empty poll afterwards proves the ring is truly drained.
/// Polling first and checking the flag second would race (frame lands
/// between the two) and drop the worker's final episode.
#[test]
fn death_is_reported_only_after_the_ring_is_drained() {
    loom::model(|| {
        let (mut tx, mut rx) = ModelRing::pair(2);
        let peer_gone = Arc::new(AtomicBool::new(false));
        let worker_gone = Arc::clone(&peer_gone);
        let worker = thread::spawn(move || {
            assert!(tx.try_push(&[42])); // final episode frame
            worker_gone.store(true, Ordering::Release); // then EOF
        });
        // ring_reader_loop, verbatim shape:
        let mut drained: Vec<Vec<u8>> = Vec::new();
        let died = loop {
            let gone = peer_gone.load(Ordering::Acquire); // BEFORE the poll
            match rx.try_pop() {
                Some(frame) => drained.push(frame),
                None if gone => break true, // Died: gone AND drained
                None => thread::yield_now(),
            }
        };
        assert!(died);
        // On every interleaving the final frame was drained before Died.
        assert_eq!(drained, vec![vec![42]]);
        worker.join().unwrap();
    });
}

/// Negative control: weaken the producer's publish from Release to
/// Relaxed and loom MUST object — the consumer can then acquire the new
/// sequence value without the payload write having happened-before its
/// read, which loom reports as a causality violation on the slot cell.
/// This is the proof that the suite genuinely explores interleavings:
/// if loom ever stops catching this, the green runs above mean nothing.
#[test]
#[should_panic]
fn relaxed_publish_is_caught_by_loom() {
    loom::model(|| {
        let (mut tx, mut rx) = ModelRing::pair(2);
        let producer = thread::spawn(move || {
            assert!(tx.push_with_relaxed_publish(&[9, 9, 9]));
        });
        loop {
            if let Some(frame) = rx.try_pop() {
                // reached only on interleavings where the racy publish
                // was observed; loom flags the unordered cell access
                assert_eq!(frame, vec![9, 9, 9]);
                break;
            }
            thread::yield_now();
        }
        producer.join().unwrap();
    });
}
