//! Integration: the full coordinator training loop (multi-env pool, GAE,
//! PPO updates) runs end-to-end and produces sane outputs — entirely
//! artifact-free: surrogate scenario + native policy/update backends, so
//! this suite is green without `make artifacts`. The last test
//! cross-checks the native update against the XLA `ppo_update` artifact
//! and skips itself when no artifacts are present.

use drlfoam::coordinator::{train, InferenceMode, SyncPolicy, TrainConfig};
use drlfoam::drl::{
    Batch, NativePolicy, NativeUpdater, PolicyBackendKind, PpoTrainer, TrainerBackend,
    Trajectory, Transition, UpdateBackendKind,
};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::io_interface::IoMode;
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::util::rng::Rng;

fn base_cfg(tag: &str) -> TrainConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-train-{tag}-{}", std::process::id()));
    TrainConfig {
        // points into the temp root, so the artifact-free path runs even
        // in checkouts where `make artifacts` has been executed
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        n_envs: 2,
        io_mode: IoMode::InMemory,
        horizon: 5,
        iterations: 3,
        epochs: 2,
        seed: 1,
        log_every: 1,
        quiet: true,
        ..TrainConfig::default()
    }
}

#[test]
fn train_loop_runs_and_logs() {
    let cfg = base_cfg("basic");
    let s = train(&cfg).expect("training failed");
    assert_eq!(s.log.len(), 3);
    assert_eq!(s.log.last().unwrap().episodes_done, 6);
    for row in &s.log {
        assert!(row.mean_reward.is_finite());
        assert!(row.mean_cd > 1.0 && row.mean_cd < 10.0, "cd {}", row.mean_cd);
        assert!(row.approx_kl.is_finite());
    }
    // the full barrier is on-policy: no staleness anywhere
    assert_eq!(s.mean_staleness, 0.0);
    assert_eq!(s.staleness_hist.iter().sum::<usize>(), 6);
    // outputs written
    assert!(cfg.out_dir.join("train_log.csv").exists());
    assert!(cfg.out_dir.join("policy_final.bin").exists());
    let csv = std::fs::read_to_string(cfg.out_dir.join("train_log.csv")).unwrap();
    assert_eq!(csv.lines().count(), 4); // header + 3 iterations
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn training_is_seed_reproducible() {
    let mut cfg = base_cfg("seedA");
    cfg.iterations = 2;
    let a = train(&cfg).unwrap();
    std::fs::remove_dir_all(&cfg.out_dir).ok();
    let mut cfg2 = base_cfg("seedB");
    cfg2.iterations = 2;
    let b = train(&cfg2).unwrap();
    std::fs::remove_dir_all(&cfg2.out_dir).ok();
    assert_eq!(a.log[0].mean_reward, b.log[0].mean_reward);
    assert_eq!(a.final_params, b.final_params);
}

#[test]
fn params_change_over_training() {
    let cfg = base_cfg("delta");
    // the artifact-free path initialises from the native Glorot init
    // seeded with cfg.seed
    let p0 = NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(cfg.seed);
    let s = train(&cfg).unwrap();
    assert_eq!(p0.len(), s.final_params.len());
    let delta: f32 = p0
        .iter()
        .zip(&s.final_params)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(delta > 0.0, "no learning happened");
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn io_mode_affects_bytes_not_results() {
    let mut cfg_m = base_cfg("iomemX");
    cfg_m.n_envs = 1;
    let a = train(&cfg_m).unwrap();
    std::fs::remove_dir_all(&cfg_m.out_dir).ok();

    let mut cfg_b = base_cfg("iobinX");
    cfg_b.n_envs = 1;
    cfg_b.io_mode = IoMode::Optimized;
    let b = train(&cfg_b).unwrap();
    std::fs::remove_dir_all(&cfg_b.out_dir).ok();

    assert_eq!(a.io_bytes_per_episode, 0.0);
    assert!(b.io_bytes_per_episode > 0.0);
    // the binary exchange is bit-exact, so learning curves must match
    assert_eq!(a.log[0].mean_reward, b.log[0].mean_reward);
    assert_eq!(a.final_params, b.final_params);
}

#[test]
fn batched_inference_trains_identically() {
    // per-env and central batched serving share seed derivation and (on
    // the native backend) bitwise-identical forward math, so the whole
    // training run must be bit-reproducible across the two modes
    let mut cfg_pe = base_cfg("inf-pe");
    cfg_pe.n_envs = 3;
    let a = train(&cfg_pe).unwrap();
    std::fs::remove_dir_all(&cfg_pe.out_dir).ok();

    let mut cfg_ba = base_cfg("inf-ba");
    cfg_ba.n_envs = 3;
    cfg_ba.inference = InferenceMode::Batched;
    let b = train(&cfg_ba).unwrap();
    std::fs::remove_dir_all(&cfg_ba.out_dir).ok();

    assert_eq!(a.log[0].mean_reward, b.log[0].mean_reward);
    assert_eq!(a.final_params, b.final_params);
}

#[test]
fn async_training_runs_and_learns_shape() {
    let mut cfg = base_cfg("async");
    cfg.n_envs = 2;
    cfg.iterations = 2; // 4 episodes total
    cfg.sync = SyncPolicy::Async;
    let s = train(&cfg).expect("async training failed");
    assert_eq!(s.log.len(), 4, "async = one update per episode");
    assert_eq!(s.log.last().unwrap().episodes_done, 4);
    for row in &s.log {
        assert!(row.mean_reward.is_finite());
        assert!(row.mean_cd > 1.0 && row.mean_cd < 10.0);
    }
    // the staleness accounting covers every consumed episode, and the
    // A3C-style bound holds loosely on this tiny run
    assert_eq!(s.staleness_hist.iter().sum::<usize>(), 4);
    assert!(s.mean_staleness <= 4.0, "mean staleness {}", s.mean_staleness);
    assert!(s.barrier_idle_s >= 0.0);
    assert!(cfg.out_dir.join("train_log.csv").exists());
    assert!(cfg.out_dir.join("staleness.csv").exists());
    assert!(cfg.out_dir.join("policy_final.bin").exists());
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn partial_sync_runs_and_bounds_staleness() {
    let mut cfg = base_cfg("partial");
    cfg.n_envs = 3;
    cfg.iterations = 2; // 6 episodes total, k=2 -> 3 updates
    cfg.sync = SyncPolicy::Partial { k: 2 };
    let s = train(&cfg).expect("partial training failed");
    assert_eq!(s.log.len(), 3, "ceil(6 / 2) updates");
    assert_eq!(s.log.last().unwrap().episodes_done, 6);
    assert_eq!(s.staleness_hist.iter().sum::<usize>(), 6);
    // an episode can at most miss the updates fired while it ran
    assert!(s.mean_staleness < 3.0, "mean staleness {}", s.mean_staleness);
    for row in &s.log {
        assert!(row.mean_reward.is_finite());
        assert!(row.approx_kl.is_finite());
    }
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn checkpoint_resume_reproduces_training() {
    // train a few iterations; restore the checkpoint into a fresh trainer
    // and confirm parameters AND the Adam step counter round-trip through
    // the on-disk format
    let cfg = base_cfg("ckpt");
    let s = train(&cfg).unwrap();
    let ck = drlfoam::runtime::read_f32_bin(cfg.out_dir.join("trainer_ckpt.bin")).unwrap();
    let n = NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).n_params();
    assert_eq!(ck.len(), 4 + 3 * n, "v1 checkpoint = header + (params|m|v)");
    let mut t = PpoTrainer::with_minibatch(vec![0.0; n], 64, 1);
    t.restore(&ck).unwrap();
    assert_eq!(t.params, s.final_params);
    // 3 iterations x 2 epochs x 1 minibatch (2 envs x 5 periods = 10
    // samples, padded into one 64-wide minibatch) = 6 Adam steps
    assert_eq!(t.adam_step(), 6, "Adam step counter lost in checkpoint");
    // and the counter survives a second checkpoint->restore hop
    let ck2 = t.checkpoint();
    let mut t2 = PpoTrainer::with_minibatch(vec![0.0; n], 64, 1);
    t2.restore(&ck2).unwrap();
    assert_eq!(t2.adam_step(), 6);
    assert_eq!(t2.params, s.final_params);
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn native_vs_xla_update_equivalence() {
    // gradient-level cross-check of the two update backends over the real
    // manifest-sized network; skips gracefully in artifact-free checkouts
    let m = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(_) => {
            eprintln!(
                "skipping native_vs_xla_update_equivalence: no artifacts (run `make artifacts`)"
            );
            return;
        }
    };
    let mut rt = Runtime::new("artifacts").unwrap();
    rt.load(&m.drl.ppo_update_file).unwrap();
    let params = m.load_params_init().unwrap();

    let mut rng = Rng::new(11);
    let traj = Trajectory {
        transitions: (0..m.drl.minibatch)
            .map(|_| Transition {
                obs: (0..m.drl.n_obs).map(|_| rng.normal() as f32).collect(),
                action: rng.normal() * 0.1,
                logp: -0.6,
                reward: rng.normal() * 0.1,
                value: 0.1 * rng.normal(),
            })
            .collect(),
        last_value: 0.0,
        env_id: 0,
    };
    let batch = Batch::assemble(&[traj], m.drl.n_obs, m.drl.gamma, m.drl.gae_lambda);

    let mut tx = PpoTrainer::new(&m.drl, params.clone(), 1);
    let mut tn = PpoTrainer::new(&m.drl, params.clone(), 1);
    let nu = NativeUpdater::from_manifest(&m.drl);
    // identical RNG seeds -> identical minibatch partitions on both paths
    let sx = tx
        .update(
            TrainerBackend::Xla(rt.get(&m.drl.ppo_update_file).unwrap()),
            &batch,
            &mut Rng::new(5),
        )
        .unwrap();
    let sn = tn
        .update(TrainerBackend::Native(&nu), &batch, &mut Rng::new(5))
        .unwrap();

    // the two backends sum in different orders, so f32 rounding differs:
    // tolerances, not bitwise equality
    assert!((sx.pi_loss - sn.pi_loss).abs() < 1e-4, "pi {} vs {}", sx.pi_loss, sn.pi_loss);
    assert!((sx.v_loss - sn.v_loss).abs() < 1e-3, "v {} vs {}", sx.v_loss, sn.v_loss);
    assert!((sx.entropy - sn.entropy).abs() < 1e-4, "ent {} vs {}", sx.entropy, sn.entropy);
    assert!((sx.approx_kl - sn.approx_kl).abs() < 1e-4, "kl {} vs {}", sx.approx_kl, sn.approx_kl);
    assert!(
        (sx.grad_norm - sn.grad_norm).abs() < 1e-2 * sx.grad_norm.abs().max(1.0),
        "gnorm {} vs {}",
        sx.grad_norm,
        sn.grad_norm
    );
    // one Adam step from identical state: every parameter moves by ~lr at
    // most, so mean drift far below lr means the per-parameter gradient
    // signs/magnitudes agree (rare near-zero-gradient sign flips aside)
    let (mut max_d, mut sum_d) = (0.0f64, 0.0f64);
    for (a, b) in tx.params.iter().zip(&tn.params) {
        let d = (*a as f64 - *b as f64).abs();
        max_d = max_d.max(d);
        sum_d += d;
    }
    let mean_d = sum_d / tx.params.len() as f64;
    assert!(max_d < 2.5 * m.drl.lr, "max param delta {max_d}");
    assert!(mean_d < 0.1 * m.drl.lr, "mean param delta {mean_d}");
}
