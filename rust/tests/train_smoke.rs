//! Integration: the full coordinator training loop (multi-env pool, GAE,
//! PPO updates) runs end-to-end and produces sane outputs.

use drlfoam::coordinator::{train, TrainConfig};
use drlfoam::io_interface::IoMode;

fn base_cfg(tag: &str) -> TrainConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-train-{tag}-{}", std::process::id()));
    TrainConfig {
        artifact_dir: "artifacts".into(),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        n_envs: 2,
        io_mode: IoMode::InMemory,
        horizon: 5,
        iterations: 3,
        epochs: 2,
        seed: 1,
        log_every: 1,
        quiet: true,
        ..TrainConfig::default()
    }
}

#[test]
fn train_loop_runs_and_logs() {
    let cfg = base_cfg("basic");
    let s = train(&cfg).expect("training failed");
    assert_eq!(s.log.len(), 3);
    assert_eq!(s.log.last().unwrap().episodes_done, 6);
    for row in &s.log {
        assert!(row.mean_reward.is_finite());
        assert!(row.mean_cd > 1.0 && row.mean_cd < 10.0, "cd {}", row.mean_cd);
        assert!(row.approx_kl.is_finite());
    }
    // outputs written
    assert!(cfg.out_dir.join("train_log.csv").exists());
    assert!(cfg.out_dir.join("policy_final.bin").exists());
    let csv = std::fs::read_to_string(cfg.out_dir.join("train_log.csv")).unwrap();
    assert_eq!(csv.lines().count(), 4); // header + 3 iterations
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn training_is_seed_reproducible() {
    let mut cfg = base_cfg("seedA");
    cfg.iterations = 2;
    let a = train(&cfg).unwrap();
    std::fs::remove_dir_all(&cfg.out_dir).ok();
    let mut cfg2 = base_cfg("seedB");
    cfg2.iterations = 2;
    let b = train(&cfg2).unwrap();
    std::fs::remove_dir_all(&cfg2.out_dir).ok();
    assert_eq!(a.log[0].mean_reward, b.log[0].mean_reward);
    assert_eq!(a.final_params, b.final_params);
}

#[test]
fn params_change_over_training() {
    let cfg = base_cfg("delta");
    let m = drlfoam::runtime::Manifest::load("artifacts").unwrap();
    let p0 = m.load_params_init().unwrap();
    let s = train(&cfg).unwrap();
    let delta: f32 = p0
        .iter()
        .zip(&s.final_params)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(delta > 0.0, "no learning happened");
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn io_mode_affects_bytes_not_results() {
    let mut cfg_m = base_cfg("iomemX");
    cfg_m.n_envs = 1;
    let a = train(&cfg_m).unwrap();
    std::fs::remove_dir_all(&cfg_m.out_dir).ok();

    let mut cfg_b = base_cfg("iobinX");
    cfg_b.n_envs = 1;
    cfg_b.io_mode = IoMode::Optimized;
    let b = train(&cfg_b).unwrap();
    std::fs::remove_dir_all(&cfg_b.out_dir).ok();

    assert_eq!(a.io_bytes_per_episode, 0.0);
    assert!(b.io_bytes_per_episode > 0.0);
    // the binary exchange is bit-exact, so learning curves must match
    assert_eq!(a.log[0].mean_reward, b.log[0].mean_reward);
    assert_eq!(a.final_params, b.final_params);
}

#[test]
fn async_training_runs_and_learns_shape() {
    let mut cfg = base_cfg("async");
    cfg.n_envs = 2;
    cfg.iterations = 2; // 4 episodes total
    let s = drlfoam::coordinator::train_async(&cfg).expect("async training failed");
    assert_eq!(s.log.len(), 4);
    for row in &s.log {
        assert!(row.reward.is_finite());
        assert!(row.cd_mean > 1.0 && row.cd_mean < 10.0);
        // bounded staleness: at most n_envs - 1 updates behind... plus the
        // updates that happened while this episode was in flight
        assert!(row.staleness <= 4, "staleness {}", row.staleness);
    }
    assert!(cfg.out_dir.join("train_async_log.csv").exists());
    assert!(cfg.out_dir.join("policy_final_async.bin").exists());
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn checkpoint_resume_reproduces_training() {
    // train 2 iterations; restore the checkpoint into a fresh trainer and
    // confirm the parameters round-trip through the on-disk format
    let cfg = base_cfg("ckpt");
    let s = train(&cfg).unwrap();
    let ck = drlfoam::runtime::read_f32_bin(cfg.out_dir.join("trainer_ckpt.bin")).unwrap();
    let m = drlfoam::runtime::Manifest::load("artifacts").unwrap();
    assert_eq!(ck.len(), 3 * m.drl.n_params);
    let mut t = drlfoam::drl::PpoTrainer::new(&m.drl, vec![0.0; m.drl.n_params], 1);
    t.restore(&ck).unwrap();
    assert_eq!(t.params, s.final_params);
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}
