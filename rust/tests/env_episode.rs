//! Integration: CfdEnv episode lifecycle over the real artifacts.

use drlfoam::drl::Policy;
use drlfoam::env::{CfdEngineRef, CfdEnv};
use drlfoam::io_interface::{make_interface, IoMode};
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::util::rng::Rng;

fn mk_env(mode: IoMode, tag: &str) -> (Manifest, Runtime, CfdEnv) {
    let m = Manifest::load("artifacts").expect("run `make artifacts`");
    let mut rt = Runtime::new("artifacts").unwrap();
    let vm = m.variant("small").unwrap().clone();
    rt.load(&vm.cfd_period_file).unwrap();
    rt.load(&m.drl.policy_apply_file).unwrap();
    let work = std::env::temp_dir().join(format!("drlfoam-env-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&work).unwrap();
    let env = CfdEnv::new(
        vm,
        m.load_state0("small").unwrap(),
        m.drl.action_smoothing_beta,
        m.drl.reward_lift_penalty,
        make_interface(mode, &work, 0).unwrap(),
    );
    (m, rt, env)
}

#[test]
fn reset_gives_normalised_observation() {
    let (m, rt, mut env) = mk_env(IoMode::InMemory, "reset");
    let cfd = rt.get(&env.variant.cfd_period_file).unwrap();
    let obs = env.reset(CfdEngineRef::Xla(cfd)).unwrap();
    assert_eq!(obs.len(), m.drl.n_obs);
    assert!(obs.iter().all(|x| x.is_finite()));
    // base-flow probes are normalised by base-flow statistics: z-scores
    // should be O(1), not O(100)
    let max = obs.iter().cloned().fold(0f32, |a, b| a.max(b.abs()));
    assert!(max < 25.0, "obs z-scores too large: {max}");
}

#[test]
fn uncontrolled_reward_near_zero() {
    // r = cd0 - <cd> - 0.1 |<cl>|; with jet ~ 0 the drag term vanishes and
    // the remaining bias is the base-flow lift asymmetry (documented).
    let (_m, rt, mut env) = mk_env(IoMode::InMemory, "r0");
    let cfd = rt.get(&env.variant.cfd_period_file).unwrap();
    env.reset(CfdEngineRef::Xla(cfd)).unwrap();
    let sr = env.step(CfdEngineRef::Xla(cfd), 0.0).unwrap();
    let lift_bias = 0.1 * sr.cl_mean.abs();
    assert!(
        (sr.reward + lift_bias).abs() < 0.15,
        "reward {} lift bias {lift_bias}",
        sr.reward
    );
}

#[test]
fn action_smoothing_follows_eq11() {
    let (_m, rt, mut env) = mk_env(IoMode::InMemory, "smooth");
    let cfd = rt.get(&env.variant.cfd_period_file).unwrap();
    env.reset(CfdEngineRef::Xla(cfd)).unwrap();
    let beta = 0.4;
    let a = 1.0;
    let s1 = env.step(CfdEngineRef::Xla(cfd), a).unwrap();
    assert!((s1.jet - beta * a).abs() < 1e-9, "jet {}", s1.jet);
    let s2 = env.step(CfdEngineRef::Xla(cfd), a).unwrap();
    let want = s1.jet + beta * (a - s1.jet);
    assert!((s2.jet - want).abs() < 1e-9);
}

#[test]
fn jet_cap_enforced() {
    let (_m, rt, mut env) = mk_env(IoMode::InMemory, "cap");
    let cfd = rt.get(&env.variant.cfd_period_file).unwrap();
    env.reset(CfdEngineRef::Xla(cfd)).unwrap();
    let cap = env.variant.jet_max;
    for _ in 0..30 {
        let sr = env.step(CfdEngineRef::Xla(cfd), 100.0).unwrap();
        assert!(sr.jet <= cap + 1e-9, "jet {} cap {cap}", sr.jet);
    }
}

#[test]
fn episode_through_all_io_modes_agrees() {
    // the exchange interface must be value-preserving: same episode, same
    // rewards (ASCII mode to parse precision).
    let mut rewards = Vec::new();
    for (mode, tag) in [
        (IoMode::InMemory, "m1"),
        (IoMode::Optimized, "m2"),
        (IoMode::Baseline, "m3"),
    ] {
        let (m, rt, mut env) = mk_env(mode, tag);
        let cfd = rt.get(&env.variant.cfd_period_file).unwrap();
        let pol = rt.get(&m.drl.policy_apply_file).unwrap();
        let params = m.load_params_init().unwrap();
        let policy = Policy::new(m.drl.n_obs);
        let mut rng = Rng::new(77);
        let mut obs = env.reset(CfdEngineRef::Xla(cfd)).unwrap();
        let mut total = 0.0;
        for _ in 0..3 {
            let pout = policy.apply(pol, &params, &obs).unwrap();
            let (a, _) = policy.sample(&pout, &mut rng);
            let sr = env.step(CfdEngineRef::Xla(cfd), a).unwrap();
            total += sr.reward;
            obs = sr.obs;
        }
        rewards.push(total);
    }
    assert!(
        (rewards[0] - rewards[1]).abs() < 1e-9,
        "in-memory vs binary: {rewards:?}"
    );
    assert!(
        (rewards[0] - rewards[2]).abs() < 1e-3,
        "in-memory vs ascii: {rewards:?}"
    );
}

#[test]
fn reset_is_reproducible() {
    let (_m, rt, mut env) = mk_env(IoMode::InMemory, "repro");
    let cfd = rt.get(&env.variant.cfd_period_file).unwrap();
    let o1 = env.reset(CfdEngineRef::Xla(cfd)).unwrap();
    let s1 = env.step(CfdEngineRef::Xla(cfd), 0.5).unwrap();
    let o2 = env.reset(CfdEngineRef::Xla(cfd)).unwrap();
    let s2 = env.step(CfdEngineRef::Xla(cfd), 0.5).unwrap();
    assert_eq!(o1, o2);
    assert_eq!(s1.obs, s2.obs);
    assert_eq!(s1.reward, s2.reward);
}
