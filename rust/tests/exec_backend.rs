//! Execution-backend integration: the multi-process executor (real
//! `drlfoam worker` OS processes over the wire protocol) must be
//! *indistinguishable* from the in-process golden reference — bitwise —
//! and must survive losing workers: a SIGKILL'd worker is respawned and
//! its episode re-queued with the identical seed, so even a faulted run
//! reproduces the fault-free learning curve.
//!
//! Everything runs artifact-free (surrogate scenario, native backends).
//! The worker binary is resolved via `CARGO_BIN_EXE_drlfoam` (the test
//! executable itself has no `worker` subcommand); when Cargo does not
//! provide it, the suite skips gracefully.

use std::sync::Arc;

use drlfoam::coordinator::{train, EnvPool, PolicyServer, PoolConfig, SyncPolicy, TrainConfig};
use drlfoam::drl::{NativePolicy, PolicyBackendKind, UpdateBackendKind};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::exec::ExecutorKind;
use drlfoam::io_interface::IoMode;
use drlfoam::metrics::parse_csv;

fn worker_bin() -> Option<std::path::PathBuf> {
    option_env!("CARGO_BIN_EXE_drlfoam").map(Into::into)
}

macro_rules! require_worker_bin {
    () => {
        match worker_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: CARGO_BIN_EXE_drlfoam not provided by cargo");
                return;
            }
        }
    };
}

fn scratch(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("drlfoam-exec-{tag}-{}", std::process::id()))
}

fn train_cfg(tag: &str, executor: ExecutorKind) -> TrainConfig {
    let root = scratch(tag);
    TrainConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        executor,
        worker_bin: worker_bin(),
        n_envs: 2,
        io_mode: IoMode::InMemory,
        horizon: 5,
        iterations: 3,
        epochs: 2,
        seed: 11,
        log_every: 1,
        quiet: true,
        ..TrainConfig::default()
    }
}

fn pool_cfg(tag: &str, executor: ExecutorKind, n_envs: usize) -> PoolConfig {
    let root = scratch(tag);
    std::fs::create_dir_all(root.join("work")).unwrap();
    PoolConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        n_envs,
        io_mode: IoMode::InMemory,
        seed: 5,
        executor,
        worker_bin: worker_bin(),
        ..PoolConfig::default()
    }
}

/// The learning-curve columns of train_log.csv: everything before the
/// wall-clock fields (the first 9 of 14).
fn learning_rows(out_dir: &std::path::Path) -> Vec<String> {
    let csv = std::fs::read_to_string(out_dir.join("train_log.csv")).unwrap();
    csv.lines()
        .skip(1)
        .map(|l| l.splitn(15, ',').take(9).collect::<Vec<_>>().join(","))
        .collect()
}

#[test]
fn multi_process_spawns_real_worker_processes() {
    let _ = require_worker_bin!();
    let mut pool = EnvPool::standalone(&pool_cfg("spawn", ExecutorKind::MultiProcess, 2)).unwrap();
    assert_eq!(pool.executor(), ExecutorKind::MultiProcess);
    let pids = pool.worker_pids();
    assert_eq!(pids.len(), 2, "one OS process per environment");
    assert!(
        pids.iter().all(|&p| p != std::process::id()),
        "workers must be real child processes, not this test"
    );
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(5));
    let outs = pool.rollout(&params, 4, 0).unwrap();
    assert_eq!(outs.len(), 2);
    assert!(outs.iter().all(|o| o.traj.transitions.len() == 4));
    // per-worker telemetry accumulates across the pipe
    assert!(pool.telemetry().iter().all(|t| t.episodes == 1));
    assert_eq!(pool.restarts(), 0);
}

#[test]
fn rank_groups_spawn_a_process_per_rank() {
    let _ = require_worker_bin!();
    let mut cfg = pool_cfg("ranks", ExecutorKind::MultiProcess, 2);
    cfg.ranks_per_env = 2;
    let mut pool = EnvPool::standalone(&cfg).unwrap();
    // 2 envs x 2 ranks: rank 0 works, rank 1 holds its placement core
    assert_eq!(pool.worker_pids().len(), 4);
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(5));
    let outs = pool.rollout(&params, 3, 0).unwrap();
    assert_eq!(outs.len(), 2);
}

#[test]
fn in_process_rejects_rank_groups() {
    let mut cfg = pool_cfg("ranks-ip", ExecutorKind::InProcess, 1);
    cfg.ranks_per_env = 2;
    let err = EnvPool::standalone(&cfg).unwrap_err().to_string();
    assert!(err.contains("multi-process"), "{err}");
}

#[test]
fn multi_process_episodes_match_in_process_bitwise() {
    let _ = require_worker_bin!();
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(21));
    let mut ip = EnvPool::standalone(&pool_cfg("bit-ip", ExecutorKind::InProcess, 3)).unwrap();
    let a = ip.rollout(&params, 6, 2).unwrap();
    let mut mp = EnvPool::standalone(&pool_cfg("bit-mp", ExecutorKind::MultiProcess, 3)).unwrap();
    let b = mp.rollout(&params, 6, 2).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.env_id, y.env_id);
        // Trajectory: PartialEq over every action/logp/reward/value/obs
        // f64/f32 — the wire protocol must be bit-transparent
        assert_eq!(x.traj, y.traj, "env {}", x.env_id);
        assert_eq!(x.stats.reward_sum, y.stats.reward_sum);
    }
}

#[test]
fn multi_process_lockstep_batched_matches_in_process() {
    let _ = require_worker_bin!();
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(8));
    let mut server_a = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let mut ip = EnvPool::standalone(&pool_cfg("lk-ip", ExecutorKind::InProcess, 2)).unwrap();
    let a = ip.rollout_batched(None, &mut server_a, &params, 5, 1).unwrap();
    let mut server_b = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let mut mp = EnvPool::standalone(&pool_cfg("lk-mp", ExecutorKind::MultiProcess, 2)).unwrap();
    let b = mp.rollout_batched(None, &mut server_b, &params, 5, 1).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.env_id, y.env_id);
        assert_eq!(x.traj, y.traj, "env {}", x.env_id);
    }
}

#[test]
fn training_runs_are_bitwise_identical_across_backends() {
    // the acceptance criterion: identical learning CSV under --sync full
    let _ = require_worker_bin!();
    let cfg_ip = train_cfg("train-ip", ExecutorKind::InProcess);
    assert_eq!(cfg_ip.sync, SyncPolicy::Full);
    let a = train(&cfg_ip).expect("in-process training failed");
    let rows_ip = learning_rows(&cfg_ip.out_dir);
    std::fs::remove_dir_all(&cfg_ip.out_dir).ok();

    let cfg_mp = train_cfg("train-mp", ExecutorKind::MultiProcess);
    let b = train(&cfg_mp).expect("multi-process training failed");
    let rows_mp = learning_rows(&cfg_mp.out_dir);
    assert!(cfg_mp.out_dir.join("workers.csv").exists());
    std::fs::remove_dir_all(&cfg_mp.out_dir).ok();

    assert_eq!(rows_ip, rows_mp, "learning-curve CSV diverged across executors");
    assert_eq!(a.final_params, b.final_params, "final parameters diverged");
    assert_eq!(b.worker_restarts, 0);
}

#[test]
fn sigkilled_worker_is_respawned_and_episode_requeued() {
    let _ = require_worker_bin!();
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(3));

    // fault-free twin for the bitwise comparison
    let mut twin = EnvPool::standalone(&pool_cfg("kill-twin", ExecutorKind::MultiProcess, 2)).unwrap();
    let want = twin.rollout(&params, 5, 0).unwrap();

    let mut pool = EnvPool::standalone(&pool_cfg("kill", ExecutorKind::MultiProcess, 2)).unwrap();
    let pids_before = pool.worker_pids();
    // SIGKILL env 0's worker, then dispatch into the carnage: whether the
    // dispatch hits the broken pipe or the death notice races in later,
    // the pool must respawn the worker and replay the episode
    pool.kill_worker(0).unwrap();
    let got = pool.rollout(&params, 5, 0).unwrap();

    assert_eq!(got.len(), 2);
    assert_eq!(pool.restarts(), 1, "exactly one worker restart");
    assert_eq!(pool.restarts_by_env(), vec![1, 0]);
    let pids_after = pool.worker_pids();
    assert_ne!(pids_before[0], pids_after[0], "env 0 worker was respawned");
    assert_eq!(pids_before[1], pids_after[1], "env 1 worker untouched");
    // the re-queued episode replays the identical seed: bitwise equal to
    // the fault-free twin, so recovery cannot perturb learning
    for (x, y) in want.iter().zip(&got) {
        assert_eq!(x.env_id, y.env_id);
        assert_eq!(x.traj, y.traj, "env {}", x.env_id);
    }
}

#[test]
fn chaos_crash_mid_training_recovers_and_reproduces_the_run() {
    // full scheduler loop: worker 0 aborts on receiving its 2nd episode
    // (--chaos 0:1); training must complete with one recorded restart
    // and a learning curve identical to the fault-free run
    let _ = require_worker_bin!();
    let clean_cfg = train_cfg("chaos-clean", ExecutorKind::MultiProcess);
    let clean = train(&clean_cfg).expect("fault-free training failed");
    let rows_clean = learning_rows(&clean_cfg.out_dir);
    std::fs::remove_dir_all(&clean_cfg.out_dir).ok();

    let mut cfg = train_cfg("chaos", ExecutorKind::MultiProcess);
    cfg.fault_injection = Some("0:1".into());
    let s = train(&cfg).expect("training with injected crash failed");
    let rows = learning_rows(&cfg.out_dir);

    assert_eq!(s.worker_restarts, 1, "summary must record the restart");
    assert_eq!(rows, rows_clean, "recovery must not perturb the learning curve");
    assert_eq!(clean.final_params, s.final_params);

    // workers.csv records the per-env restart + telemetry schema
    let text = std::fs::read_to_string(cfg.out_dir.join("workers.csv")).unwrap();
    let (header, rows) = parse_csv(&text).unwrap();
    assert_eq!(
        header,
        vec!["env_id", "episodes", "restarts", "wall_s", "cfd_s", "io_s", "policy_s"]
    );
    assert_eq!(rows.len(), cfg.n_envs);
    assert_eq!(rows[0][2], "1", "env 0 restarted once");
    assert_eq!(rows[1][2], "0");
    let episodes: usize = rows.iter().map(|r| r[1].parse::<usize>().unwrap()).sum();
    assert_eq!(episodes, cfg.n_envs * cfg.iterations);
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn worker_error_is_contextual_not_a_hang() {
    // a worker whose setup fails (cylinder scenario, no artifacts) must
    // surface the root cause through the process boundary
    let _ = require_worker_bin!();
    let mut cfg = pool_cfg("seterr", ExecutorKind::MultiProcess, 1);
    cfg.scenario = "cylinder".into();
    cfg.backend = PolicyBackendKind::Native;
    let mut pool = EnvPool::standalone(&cfg).unwrap();
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(1));
    let err = pool
        .rollout(&params, 3, 0)
        .expect_err("setup failure must propagate")
        .to_string();
    assert!(err.contains("artifacts"), "{err}");
}

// --- wire-protocol fuzzing --------------------------------------------------
//
// `exec/wire.rs` is the trust boundary between the coordinator and
// arbitrary worker processes: whatever bytes arrive, the decoder must
// yield `Ok(Some(frame))`, `Ok(None)` (clean EOF before a header), or a
// typed `Err` — never panic, and never silently misparse. A proptest
// dependency is off the table, so this is a hand-rolled deterministic
// fuzz loop over the repo's own xorshift RNG: same seed, same corpus,
// every run.

mod wire_fuzz {
    use std::io::Cursor;

    use drlfoam::coordinator::EpisodeStats;
    use drlfoam::drl::{Trajectory, Transition};
    use drlfoam::env::{StepResult, StepTimings};
    use drlfoam::exec::wire::{read_frame, write_frame, Frame};
    use drlfoam::io_interface::IoStats;
    use drlfoam::util::rng::Rng;

    /// One random frame, sized by the RNG: payloads span empty to a few
    /// KiB so header/payload boundaries land everywhere. Every
    /// `wire::Tag` variant has an arm here — the `drlfoam audit` rule
    /// `wire-tag-coverage` checks this corpus, so a frame added to the
    /// protocol without a fuzz case fails the audit.
    fn random_frame(rng: &mut Rng) -> Frame {
        match rng.below(13) {
            0 => Frame::Hello {
                env_id: rng.next_u64() as u32,
                rank: rng.below(8) as u32,
                pid: rng.next_u64() as u32,
                n_obs: rng.below(512) as u32,
                version: rng.next_u64() as u32,
                shm: rng.below(2) as u32,
            },
            1 => Frame::SetParams {
                params: (0..rng.below(1024)).map(|_| rng.range(-2.0, 2.0) as f32).collect(),
            },
            2 => Frame::Reset,
            3 => Frame::Step { action: rng.normal() },
            4 => Frame::Rollout {
                horizon: rng.below(4096) as u32,
                episode: rng.next_u64(),
                episode_seed: rng.next_u64(),
            },
            5 => Frame::Heartbeat,
            9 => Frame::Shutdown,
            6 => Frame::Obs {
                obs: (0..rng.below(512)).map(|_| rng.normal() as f32).collect(),
            },
            7 => Frame::StepOut {
                result: StepResult {
                    obs: (0..rng.below(64)).map(|_| rng.normal() as f32).collect(),
                    reward: rng.normal(),
                    cd_mean: rng.normal(),
                    cl_mean: rng.normal(),
                    jet: rng.normal(),
                    timings: StepTimings { cfd_s: rng.uniform(), io_s: rng.uniform() },
                    io: IoStats::default(),
                },
            },
            8 => Frame::Episode {
                env_id: rng.below(64) as u32,
                stats: EpisodeStats {
                    reward_sum: rng.normal(),
                    cd_mean: rng.normal(),
                    cl_abs_mean: rng.normal().abs(),
                    jet_final: rng.normal(),
                    cfd_s: rng.uniform(),
                    io_s: rng.uniform(),
                    policy_s: rng.uniform(),
                    wall_s: rng.uniform(),
                    io: IoStats::default(),
                },
                traj: Trajectory {
                    env_id: rng.below(64),
                    last_value: rng.normal(),
                    transitions: (0..rng.below(20))
                        .map(|_| Transition {
                            obs: (0..rng.below(16)).map(|_| rng.normal() as f32).collect(),
                            action: rng.normal(),
                            logp: rng.normal(),
                            reward: rng.normal(),
                            value: rng.normal(),
                        })
                        .collect(),
                },
            },
            10 => {
                let s = |rng: &mut Rng, n: usize| -> String {
                    String::from_utf8_lossy(
                        &(0..rng.below(n)).map(|_| rng.below(256) as u8).collect::<Vec<_>>(),
                    )
                    .into_owned()
                };
                Frame::Spawn {
                    env_id: rng.below(64) as u32,
                    rank: rng.below(8) as u32,
                    seed: rng.next_u64(),
                    heartbeat_ms: rng.below(1000) as u64,
                    scenario: s(rng, 32),
                    variant: s(rng, 16),
                    artifact_dir: s(rng, 128),
                    work_dir: s(rng, 128),
                    io_mode: s(rng, 16),
                    backend: s(rng, 16),
                    cfd_backend: s(rng, 16),
                    fault_injection: s(rng, 24),
                    trace: rng.below(256) as u8,
                }
            }
            12 => Frame::Telemetry {
                env_id: rng.below(64) as u32,
                rank: rng.below(8) as u32,
                // raw u8, not just the live kinds {0,1,2}: unknown kinds
                // must round-trip bit-exactly like every other frame
                kind: rng.below(256) as u8,
                clock_us: rng.next_u64(),
                echo_us: rng.next_u64(),
                spans: (0..rng.below(32))
                    .map(|_| drlfoam::obs::SpanRec {
                        phase: rng.below(256) as u8,
                        start_us: rng.next_u64(),
                        dur_us: rng.next_u64(),
                        env_id: rng.below(64) as u32,
                        episode: rng.next_u64(),
                    })
                    .collect(),
            },
            _ => Frame::Error {
                msg: String::from_utf8_lossy(
                    &(0..rng.below(256)).map(|_| rng.below(256) as u8).collect::<Vec<_>>(),
                )
                .into_owned(),
            },
        }
    }

    fn encode(frame: &Frame) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        buf
    }

    #[test]
    fn truncated_frames_are_typed_errors_never_frames() {
        // cutting a well-formed frame anywhere must yield Ok(None) at
        // offset 0 (clean EOF) and Err everywhere else — never a frame
        let mut rng = Rng::new(0xF0CC_5EED);
        for _ in 0..64 {
            let buf = encode(&random_frame(&mut rng));
            let cuts = [0, 1, 2, 3, buf.len() / 2, buf.len().saturating_sub(1)];
            for &cut in cuts.iter().filter(|&&c| c < buf.len()) {
                match read_frame(&mut Cursor::new(&buf[..cut])) {
                    Ok(None) => assert_eq!(cut, 0, "EOF mid-frame must be an error"),
                    Ok(Some(f)) => panic!("truncated at {cut}/{}: misparsed {f:?}", buf.len()),
                    Err(_) => assert!(cut > 0, "clean EOF must be Ok(None)"),
                }
            }
        }
    }

    #[test]
    fn bit_flipped_frames_never_panic_or_destabilise_reencoding() {
        // a single flipped bit may still decode (flips inside an f32
        // payload are just different numbers) — but whatever decodes
        // must re-encode to the *same bytes it was decoded from*, i.e.
        // a corrupt frame can never alias two byte representations
        let mut rng = Rng::new(0xB17F11B5);
        for _ in 0..128 {
            let clean = encode(&random_frame(&mut rng));
            let mut buf = clean.clone();
            let bit = rng.below(buf.len() * 8);
            buf[bit / 8] ^= 1u8 << (bit % 8);
            match read_frame(&mut Cursor::new(&buf)) {
                Err(_) | Ok(None) => {}
                Ok(Some(frame)) => {
                    let round1 = encode(&frame);
                    let reread = read_frame(&mut Cursor::new(&round1))
                        .expect("re-reading own encoding failed")
                        .expect("own encoding read as EOF");
                    assert_eq!(round1, encode(&reread), "re-encoding is not a fixed point");
                }
            }
        }
    }

    #[test]
    fn mutated_length_prefixes_are_rejected_not_trusted() {
        let mut rng = Rng::new(0x1E46);
        for _ in 0..64 {
            let clean = encode(&random_frame(&mut rng));
            let mut buf = clean.clone();
            // lie about the length: longer than the bytes that follow,
            // absurdly huge (must trip the MAX_FRAME guard before any
            // allocation), or zero
            for lie in [buf.len() as u32 * 2 + 7, u32::MAX, 0] {
                buf[..4].copy_from_slice(&lie.to_le_bytes());
                match read_frame(&mut Cursor::new(&buf)) {
                    Ok(Some(f)) => panic!("length {lie} accepted: {f:?}"),
                    Ok(None) | Err(_) => {}
                }
            }
        }
    }

    #[test]
    fn unknown_tags_are_typed_errors() {
        // 13 is the first tag value past Spawn (= 12, the newest frame)
        for bad_tag in [0u8, 13, 99, 200, 255] {
            let mut buf = encode(&Frame::Heartbeat);
            buf[4] = bad_tag; // first payload byte is the tag
            let err = read_frame(&mut Cursor::new(&buf))
                .expect_err("unknown tag must be rejected")
                .to_string();
            assert!(err.contains("tag"), "error should name the tag: {err}");
        }
    }

    /// A reader that returns at most `chunk` bytes per `read` call —
    /// the socket-transport reality where a frame header can arrive
    /// split at any byte boundary.
    struct Chunked<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }

    impl std::io::Read for Chunked<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(buf.len()).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn partial_reads_split_anywhere_in_the_length_prefix_still_decode() {
        // chunk = 1 delivers each of the 4 length-prefix bytes in its
        // own read() call; larger chunks move the split points across
        // every header/payload boundary
        let mut rng = Rng::new(0x5917);
        let frames: Vec<Frame> = (0..24).map(|_| random_frame(&mut rng)).collect();
        let mut stream = Vec::new();
        for f in &frames {
            write_frame(&mut stream, f).unwrap();
        }
        for chunk in 1..=7 {
            let mut r = Chunked { data: &stream, pos: 0, chunk };
            for (i, want) in frames.iter().enumerate() {
                let got = read_frame(&mut r)
                    .unwrap_or_else(|e| panic!("chunk={chunk} frame {i}: {e}"))
                    .unwrap_or_else(|| panic!("chunk={chunk} frame {i}: premature EOF"));
                assert_eq!(&got, want, "chunk={chunk} frame {i}");
            }
            assert!(read_frame(&mut r).unwrap().is_none(), "chunk={chunk}: trailing bytes");
        }
    }

    #[test]
    fn interleaved_heartbeats_never_corrupt_neighbouring_frames() {
        // agents relay keepalives between data frames; every data frame
        // must survive byte-exactly no matter how many heartbeats land
        // around it
        let mut rng = Rng::new(0xBEA7);
        let data: Vec<Frame> = (0..16).map(|_| random_frame(&mut rng)).collect();
        let mut stream = Vec::new();
        for f in &data {
            for _ in 0..rng.below(4) {
                write_frame(&mut stream, &Frame::Heartbeat).unwrap();
            }
            write_frame(&mut stream, f).unwrap();
        }
        let mut r = Chunked { data: &stream, pos: 0, chunk: 3 };
        let mut got = Vec::new();
        while let Some(f) = read_frame(&mut r).unwrap() {
            if f != Frame::Heartbeat {
                got.push(f);
            }
        }
        assert_eq!(got, data);
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_any_allocation() {
        // MAX_FRAME is 256 MiB; every length above it must be refused
        // from the 4 header bytes alone — the payload is never read, so
        // the test would OOM/hang if the guard trusted the prefix
        const MAX_FRAME: u32 = 256 << 20;
        for lie in [MAX_FRAME + 1, MAX_FRAME * 2, u32::MAX] {
            let mut buf = lie.to_le_bytes().to_vec();
            buf.push(5); // a plausible tag byte, but no payload follows
            let err = read_frame(&mut Cursor::new(&buf))
                .expect_err("oversized length must be rejected")
                .to_string();
            assert!(err.contains("length"), "error should name the length: {err}");
        }
        // the boundary itself is within protocol (the frame is merely
        // truncated here, which is a different typed error)
        let mut buf = MAX_FRAME.to_le_bytes().to_vec();
        buf.push(5);
        let err = read_frame(&mut Cursor::new(&buf)).expect_err("truncated").to_string();
        assert!(err.contains("payload"), "{err}");
    }

    #[test]
    fn random_garbage_never_panics() {
        let mut rng = Rng::new(0x6A4BA6E);
        for _ in 0..256 {
            let n = rng.below(512);
            let garbage: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            // any outcome is fine except a panic or a hang
            let _ = read_frame(&mut Cursor::new(&garbage));
        }
    }
}
