//! Integration: artifact loading + numeric round-trip through the PJRT
//! runtime (the Rust half of the AOT contract; the Python half is
//! python/tests/test_aot.py).

use drlfoam::runtime::{literal_f32, scalar_f32, to_vec_f32, Manifest, Runtime};

fn setup() -> (Manifest, Runtime) {
    let m = Manifest::load("artifacts").expect("run `make artifacts`");
    let rt = Runtime::new("artifacts").unwrap();
    (m, rt)
}

#[test]
fn cfd_period_runs_and_produces_physical_values() {
    let (m, mut rt) = setup();
    let v = m.variant("small").unwrap().clone();
    rt.load(&v.cfd_period_file).unwrap();
    let (u, vv, p) = m.load_state0("small").unwrap();
    let dims = [v.ny as i64, v.nx as i64];
    let args = [
        literal_f32(&u, &dims).unwrap(),
        literal_f32(&vv, &dims).unwrap(),
        literal_f32(&p, &dims).unwrap(),
        scalar_f32(0.0),
    ];
    let outs = rt.get(&v.cfd_period_file).unwrap().run(&args).unwrap();
    assert_eq!(outs.len(), 6);
    let u2 = to_vec_f32(&outs[0]).unwrap();
    let probes = to_vec_f32(&outs[3]).unwrap();
    let cd = to_vec_f32(&outs[4]).unwrap();
    let cl = to_vec_f32(&outs[5]).unwrap();
    assert_eq!(u2.len(), v.ny * v.nx);
    assert_eq!(probes.len(), 149);
    assert_eq!(cd.len(), v.substeps);
    // every value finite
    assert!(u2.iter().all(|x| x.is_finite()), "u has non-finite values");
    assert!(probes.iter().all(|x| x.is_finite()));
    assert!(cd.iter().all(|x| x.is_finite()), "cd {cd:?}");
    assert!(cl.iter().all(|x| x.is_finite()));
    // uncontrolled drag continues the manifest's base-flow value
    let cd_mean = cd.iter().sum::<f32>() as f64 / cd.len() as f64;
    assert!(
        (cd_mean - v.cd0).abs() < 0.5,
        "cd {cd_mean} vs cd0 {}",
        v.cd0
    );
}

#[test]
fn cfd_period_is_deterministic() {
    let (m, mut rt) = setup();
    let v = m.variant("small").unwrap().clone();
    rt.load(&v.cfd_period_file).unwrap();
    let (u, vv, p) = m.load_state0("small").unwrap();
    let dims = [v.ny as i64, v.nx as i64];
    let mk = || {
        [
            literal_f32(&u, &dims).unwrap(),
            literal_f32(&vv, &dims).unwrap(),
            literal_f32(&p, &dims).unwrap(),
            scalar_f32(0.7),
        ]
    };
    let exe = rt.get(&v.cfd_period_file).unwrap();
    let a = to_vec_f32(&exe.run(&mk()).unwrap()[0]).unwrap();
    let b = to_vec_f32(&exe.run(&mk()).unwrap()[0]).unwrap();
    assert_eq!(a, b);
}

#[test]
fn jet_action_changes_the_flow() {
    let (m, mut rt) = setup();
    let v = m.variant("small").unwrap().clone();
    rt.load(&v.cfd_period_file).unwrap();
    let (u, vv, p) = m.load_state0("small").unwrap();
    let dims = [v.ny as i64, v.nx as i64];
    let run = |jet: f32| {
        let args = [
            literal_f32(&u, &dims).unwrap(),
            literal_f32(&vv, &dims).unwrap(),
            literal_f32(&p, &dims).unwrap(),
            scalar_f32(jet),
        ];
        let outs = rt.get(&v.cfd_period_file).unwrap().run(&args).unwrap();
        to_vec_f32(&outs[5]).unwrap() // cl history
    };
    let cl0 = run(0.0);
    let cl1 = run(1.0);
    let diff: f32 = cl0
        .iter()
        .zip(&cl1)
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>();
    assert!(diff > 1e-3, "jet had no effect on lift: {diff}");
}

#[test]
fn policy_apply_matches_manifest_shapes() {
    let (m, mut rt) = setup();
    rt.load(&m.drl.policy_apply_file).unwrap();
    let params = m.load_params_init().unwrap();
    let obs = vec![0.25f32; m.drl.n_obs];
    let args = [
        literal_f32(&params, &[params.len() as i64]).unwrap(),
        literal_f32(&obs, &[1, m.drl.n_obs as i64]).unwrap(),
    ];
    let outs = rt.get(&m.drl.policy_apply_file).unwrap().run(&args).unwrap();
    assert_eq!(outs.len(), 3);
    let mu = to_vec_f32(&outs[0]).unwrap();
    let logstd = to_vec_f32(&outs[1]).unwrap();
    let value = to_vec_f32(&outs[2]).unwrap();
    assert_eq!(mu.len(), 1);
    assert_eq!(logstd.len(), 1);
    assert_eq!(value.len(), 1);
    // init: tiny mu head, logstd as configured
    assert!(mu[0].abs() < 0.5, "mu {mu:?}");
    assert!((logstd[0] as f64 - m.drl.init_logstd).abs() < 1e-5);
}

#[test]
fn ppo_update_changes_params_within_adam_bound() {
    let (m, mut rt) = setup();
    rt.load(&m.drl.ppo_update_file).unwrap();
    let n = m.drl.n_params;
    let b = m.drl.minibatch;
    let params = m.load_params_init().unwrap();
    let zeros = vec![0f32; n];
    let obs = vec![0.1f32; b * m.drl.n_obs];
    let act = vec![0.05f32; b];
    let logp = vec![-1.0f32; b];
    let adv: Vec<f32> = (0..b).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let ret = vec![0.5f32; b];
    let args = [
        literal_f32(&params, &[n as i64]).unwrap(),
        literal_f32(&zeros, &[n as i64]).unwrap(),
        literal_f32(&zeros, &[n as i64]).unwrap(),
        scalar_f32(1.0),
        literal_f32(&obs, &[b as i64, m.drl.n_obs as i64]).unwrap(),
        literal_f32(&act, &[b as i64, 1]).unwrap(),
        literal_f32(&logp, &[b as i64]).unwrap(),
        literal_f32(&adv, &[b as i64]).unwrap(),
        literal_f32(&ret, &[b as i64]).unwrap(),
    ];
    let outs = rt.get(&m.drl.ppo_update_file).unwrap().run(&args).unwrap();
    assert_eq!(outs.len(), 4);
    let new_params = to_vec_f32(&outs[0]).unwrap();
    let stats = to_vec_f32(&outs[3]).unwrap();
    assert_eq!(new_params.len(), n);
    assert_eq!(stats.len(), 6);
    assert!(stats.iter().all(|x| x.is_finite()), "stats {stats:?}");
    let max_delta = params
        .iter()
        .zip(&new_params)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_delta > 0.0, "update was a no-op");
    // first Adam step is bounded by lr
    assert!(
        (max_delta as f64) <= m.drl.lr * 1.01,
        "delta {max_delta} > lr {}",
        m.drl.lr
    );
}
