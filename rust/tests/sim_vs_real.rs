//! DES-vs-real shadow validation (DESIGN.md section 6, last row): every DES
//! claim that CAN be checked at this machine's scale is checked against
//! real execution. The 60-core absolute numbers are simulation; these
//! tests pin the simulator to reality where reality is available.
//!
//! All tests here time real execution, so they serialise on a global
//! mutex (the default test harness runs tests on parallel threads, which
//! would contaminate wall-clock measurements on this 1-core box).

use std::sync::Mutex;

use drlfoam::cluster::{simulate_training, Calibration, SimConfig};
use drlfoam::coordinator::{train, SyncPolicy, TrainConfig};
use drlfoam::io_interface::IoMode;

static SERIAL: Mutex<()> = Mutex::new(());

struct RealRun {
    total_s: f64,
    cfd_s: f64,
    io_s: f64,
    policy_s: f64,
    io_bytes: f64,
}

fn real_train(mode: IoMode, tag: &str, horizon: usize, iterations: usize) -> RealRun {
    let root = std::env::temp_dir().join(format!("drlfoam-svr-{tag}-{}", std::process::id()));
    let cfg = TrainConfig {
        artifact_dir: "artifacts".into(),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        n_envs: 1,
        io_mode: mode,
        horizon,
        iterations,
        epochs: 1,
        seed: 5,
        log_every: 10_000,
        quiet: true,
        ..TrainConfig::default()
    };
    let s = train(&cfg).unwrap();
    let run = RealRun {
        total_s: s.total_s,
        cfd_s: s.log.iter().map(|r| r.cfd_s).sum(),
        io_s: s.log.iter().map(|r| r.io_s).sum(),
        policy_s: s.log.iter().map(|r| r.policy_s).sum(),
        io_bytes: s.io_bytes_per_episode,
    };
    std::fs::remove_dir_all(&root).ok();
    run
}

#[test]
fn real_io_cost_ordering_matches_des_premise() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // The DES premise: io volume baseline > optimized > in-memory(=0).
    // Bytes are profile-independent; wall-time ordering only holds in
    // optimized builds (debug-build serialization is dominated by rustc
    // overhead, not the filesystem).
    let mem = real_train(IoMode::InMemory, "mem", 8, 2);
    let opt = real_train(IoMode::Optimized, "opt", 8, 2);
    let base = real_train(IoMode::Baseline, "base", 8, 2);
    assert!(mem.io_s < 1e-3, "in-memory io {}", mem.io_s);
    assert!(opt.io_s > 0.0);
    assert_eq!(mem.io_bytes, 0.0);
    assert!(
        base.io_bytes > 2.0 * opt.io_bytes,
        "ascii bytes {:.0} not >> binary bytes {:.0}",
        base.io_bytes,
        opt.io_bytes
    );
    if !cfg!(debug_assertions) {
        assert!(
            base.io_s > opt.io_s,
            "ascii io {:.4}s not > binary io {:.4}s",
            base.io_s,
            opt.io_s
        );
    }
}

#[test]
fn real_cfd_dominates_the_compute_components() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // paper section III A: CFD dominates the episode. Compare against the
    // other *measured components* (policy serving + exchange), which is
    // robust to harness/runtime overhead outside the step loop.
    let r = real_train(IoMode::InMemory, "dom", 8, 2);
    let frac = r.cfd_s / (r.cfd_s + r.policy_s + r.io_s);
    assert!(frac > 0.5, "cfd fraction {frac:.2} suspiciously low");
}

#[test]
fn des_with_measured_calibration_predicts_real_components() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // Calibrate the DES from a real run, then close the loop on the
    // components the DES models (CFD + policy + update), not on harness
    // overheads it deliberately excludes.
    let horizon = 8usize;
    let iterations = 2usize;
    let r = real_train(IoMode::InMemory, "loop", horizon, iterations);
    // +1 period per episode: env.reset runs one uncontrolled period
    let periods = (iterations * (horizon + 1)) as f64;
    let t_period_real = r.cfd_s / periods;
    let t_policy_real = r.policy_s / (iterations * (horizon + 1)) as f64;
    let calib = Calibration::from_measured(
        t_period_real,
        t_policy_real,
        2e-3,
        3.2e5,
        1.6e5,
        2e-3,
        5e-4,
        horizon,
    );
    let sim = simulate_training(
        &calib,
        &SimConfig {
            n_envs: 1,
            n_ranks: 1,
            episodes_total: iterations,
            io_mode: IoMode::InMemory,
            sync: SyncPolicy::Full,
            remote_envs: 0,
            seed: 3,
        },
    );
    // DES models horizon periods/episode (no reset period) + update time;
    // compare against the measured modelled components.
    let real_modelled = (r.cfd_s + r.policy_s) * horizon as f64 / (horizon + 1) as f64;
    let rel = (sim.total_s - real_modelled).abs() / real_modelled;
    assert!(
        rel < 0.40,
        "DES {:.2}s vs real modelled components {:.2}s (rel {:.2})",
        sim.total_s,
        real_modelled,
        rel
    );
    // and the DES must not be wildly off the true wall time either
    assert!(sim.total_s < r.total_s * 1.5);
}

#[test]
fn real_io_fraction_modest_at_single_env() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // At 1 env even ASCII exchange must be a minority cost (the paper's
    // I/O wall appears only at many envs, via disk contention).
    let base = real_train(IoMode::Baseline, "fbase", 8, 2);
    let frac = base.io_s / (base.cfd_s + base.io_s + base.policy_s);
    assert!(frac < 0.5, "I/O fraction at 1 env = {frac:.2}");
}
