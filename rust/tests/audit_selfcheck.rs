//! The audit audits itself: the real repo must come up clean (every
//! exception justified in `rust/audit.allow`), and a deliberately-bad
//! fixture tree must trip every rule — so a future refactor can neither
//! rot the codebase past the audit nor quietly lobotomize the audit.

use drlfoam::audit::{run, AuditConfig};

/// The repo root, found by walking up from the build manifest dir — the
/// same discovery `drlfoam audit` uses from an arbitrary cwd.
fn repo_cfg() -> AuditConfig {
    AuditConfig::discover(std::path::Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap()
}

#[test]
fn the_repo_itself_passes_the_audit() {
    let report = run(&repo_cfg()).unwrap();
    assert!(report.ok(), "repo audit FAILED:\n{}", report.to_text());
    assert!(
        report.files_checked > 20,
        "only {} files walked — audit is not seeing the tree",
        report.files_checked
    );
    // the telemetry_now() allowlist entries must be doing real work (a
    // stale entry is itself a finding, so ok() already bounds the other
    // direction)
    assert!(
        report.suppressed >= 2,
        "expected the det-wall-clock allowlist entries to suppress \
         findings, suppressed={}",
        report.suppressed
    );
}

/// A minimal repo tree seeded with one violation of every det rule plus
/// an unjustified `unsafe`, and one clean file proving the rules don't
/// over-fire outside their scope.
fn write_bad_fixture(tag: &str) -> std::path::PathBuf {
    let root = std::env::temp_dir().join(format!(
        "drlfoam-audit-fixture-{tag}-{}",
        std::process::id()
    ));
    let src = root.join("rust").join("src");
    std::fs::create_dir_all(src.join("cluster")).unwrap();
    std::fs::create_dir_all(src.join("util")).unwrap();
    // det-critical file: hash collections, two wall-clock reads, f32 and
    // untyped sums, and a bare unsafe
    std::fs::write(
        src.join("cluster").join("des.rs"),
        r#"use std::collections::HashMap;
pub fn score(xs: &[f32]) -> f32 {
    let t0 = Instant::now();
    let t1 = Instant::now();
    let m: HashMap<u32, f32> = HashMap::new();
    let a = xs.iter().copied().sum::<f32>();
    let b: f32 = xs.iter().copied().sum();
    let p = xs.as_ptr();
    let c = unsafe { *p };
    a + b + c + m.len() as f32 + (t1 - t0).as_secs_f32()
}
"#,
    )
    .unwrap();
    // non-critical file: same hash/clock/sum patterns are fine here, and
    // a SAFETY-commented unsafe satisfies the unsafe rule
    std::fs::write(
        src.join("util").join("ok.rs"),
        r#"use std::collections::HashMap;
pub fn helper(xs: &[f32]) -> f32 {
    let _t = Instant::now();
    let _m: HashMap<u32, u32> = HashMap::new();
    let s: f32 = xs.iter().copied().sum();
    // SAFETY: xs is non-empty by the caller's contract.
    let first = unsafe { *xs.as_ptr() };
    s + first
}
"#,
    )
    .unwrap();
    root
}

#[test]
fn a_deliberately_bad_fixture_trips_every_rule() {
    let root = write_bad_fixture("trip");
    let report = run(&AuditConfig::for_root(&root)).unwrap();
    let rules: Vec<&str> = report.findings.iter().map(|f| f.rule).collect();
    for rule in [
        "unsafe-safety-comment",
        "det-hash-collections",
        "det-wall-clock",
        "f32-sum-in-scored-path",
    ] {
        assert!(
            rules.contains(&rule),
            "rule {rule} did not fire on the bad fixture:\n{}",
            report.to_text()
        );
    }
    // every finding points into the det-critical file — the clean file's
    // identical patterns are out of scope, and its SAFETY comment holds
    assert!(
        report
            .findings
            .iter()
            .all(|f| f.file == "rust/src/cluster/des.rs"),
        "findings leaked outside the det-critical fixture:\n{}",
        report.to_text()
    );
    // both Instant::now reads are reported, with real line numbers
    let clocks: Vec<usize> = report
        .findings
        .iter()
        .filter(|f| f.rule == "det-wall-clock")
        .map(|f| f.line)
        .collect();
    assert_eq!(clocks, vec![3, 4], "wall-clock lines: {clocks:?}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn allowlist_suppresses_caps_and_reports_stale_entries() {
    let root = write_bad_fixture("allow");
    let allow_path = root.join("rust").join("audit.allow");
    std::fs::write(
        &allow_path,
        "# fixture allowlist\n\
         det-wall-clock | rust/src/cluster/des.rs | 1 | capped below the real count on purpose\n\
         det-hash-collections | rust/src/cluster/des.rs | 9 | generous cap, suppresses all\n\
         f32-sum-in-scored-path | rust/src/util/ok.rs | 1 | never fires here: stale\n",
    )
    .unwrap();
    // for_root picks the allowlist up from its conventional location
    let report = run(&AuditConfig::for_root(&root)).unwrap();
    assert!(!report.ok());

    // over-cap: 2 wall-clock findings against max-count 1 -> ALL reported,
    // annotated with the cap so the reviewer sees which entry is too small
    let clocks: Vec<&str> = report
        .findings
        .iter()
        .filter(|f| f.rule == "det-wall-clock")
        .map(|f| f.message.as_str())
        .collect();
    assert_eq!(clocks.len(), 2, "{}", report.to_text());
    assert!(
        clocks.iter().all(|m| m.contains("allowlist caps")),
        "{clocks:?}"
    );

    // within-cap: the HashMap findings are suppressed and counted
    assert!(
        !report.findings.iter().any(|f| f.rule == "det-hash-collections"),
        "{}",
        report.to_text()
    );
    assert!(report.suppressed >= 2, "suppressed={}", report.suppressed);

    // stale entry -> its own finding, pointing at the allowlist line
    let stale: Vec<_> = report
        .findings
        .iter()
        .filter(|f| f.rule == "allowlist-stale")
        .collect();
    assert_eq!(stale.len(), 1, "{}", report.to_text());
    assert!(stale[0].message.contains("f32-sum-in-scored-path"), "{}", stale[0].message);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn json_report_is_machine_readable() {
    let root = write_bad_fixture("json");
    let report = run(&AuditConfig::for_root(&root)).unwrap();
    let js = report.to_json();
    assert!(js.contains("\"ok\":false"), "{js}");
    assert!(js.contains("\"findings\":["), "{js}");
    assert!(js.contains("\"rule\":\"unsafe-safety-comment\""), "{js}");
    assert!(js.contains("\"file\":\"rust/src/cluster/des.rs\""), "{js}");
    assert!(js.contains("\"suppressed\":0"), "{js}");
    let _ = std::fs::remove_dir_all(&root);
}
