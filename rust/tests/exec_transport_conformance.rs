//! Transport conformance: the bar any executor data plane must clear.
//!
//! The multi-process executor can move frames over stdin/stdout pipes
//! (`--transport pipe`), over shared-memory seqlock rings (`--transport
//! shm`, the pipe staying control channel + fallback), or over sockets
//! (`--transport tcp|uds`, one stream per worker — the multi-node data
//! plane). Whatever the transport, training must be *indistinguishable*
//! — the learning-curve CSV and the final parameter vector must match
//! the in-process golden reference bitwise. This suite runs that
//! equivalence matrix across
//!
//! ```text
//! {in-process, pipe, shm, tcp, uds} × {full, partial:k, async} × {per-env, batched}
//! ```
//!
//! restricted to the cells where the schedule itself is deterministic:
//! `partial:k` with `k < n` and per-env `async` with `n > 1` consume
//! episodes in racy arrival order by design, so only their learning
//! *distribution* is defined, not a bitwise curve. `partial:n` (the
//! drained-then-sorted batch), per-env `async` with one env, and batched
//! `async` (deterministic slot order) pin the same code paths without
//! the race.
//!
//! On top of the matrix: chaos tests around the seqlock's core guarantee
//! — a crash mid-write (torn ring slot + truncated pipe frame) must
//! never surface a corrupt frame, and respawn + re-queue recovery must
//! reproduce the fault-free run bitwise, with the injected kill counted
//! exactly once in `TrainSummary::worker_restarts` and `workers.csv`.
//!
//! Everything runs artifact-free (surrogate scenario, native backends);
//! the suite skips gracefully when Cargo does not provide the worker
//! binary.

use std::sync::Arc;

use drlfoam::coordinator::{
    train, EnvPool, PolicyServer, PoolConfig, SyncPolicy, TrainConfig,
};
use drlfoam::drl::{NativePolicy, PolicyBackendKind, UpdateBackendKind};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::exec::{ExecutorKind, TransportKind};
use drlfoam::io_interface::IoMode;
use drlfoam::metrics::parse_csv;

fn worker_bin() -> Option<std::path::PathBuf> {
    option_env!("CARGO_BIN_EXE_drlfoam").map(Into::into)
}

macro_rules! require_worker_bin {
    () => {
        match worker_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: CARGO_BIN_EXE_drlfoam not provided by cargo");
                return;
            }
        }
    };
}

fn scratch(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("drlfoam-conf-{tag}-{}", std::process::id()))
}

/// One lane of the transport axis: where workers live and how frames
/// move. In-process workers serialise nothing, so they only pair with
/// the (irrelevant) pipe default.
#[derive(Clone, Copy)]
struct Lane {
    name: &'static str,
    executor: ExecutorKind,
    transport: TransportKind,
}

const LANES: [Lane; 5] = [
    Lane {
        name: "in-process",
        executor: ExecutorKind::InProcess,
        transport: TransportKind::Pipe,
    },
    Lane {
        name: "pipe",
        executor: ExecutorKind::MultiProcess,
        transport: TransportKind::Pipe,
    },
    Lane {
        name: "shm",
        executor: ExecutorKind::MultiProcess,
        transport: TransportKind::Shm,
    },
    Lane {
        name: "tcp",
        executor: ExecutorKind::MultiProcess,
        transport: TransportKind::Tcp,
    },
    Lane {
        name: "uds",
        executor: ExecutorKind::MultiProcess,
        transport: TransportKind::Uds,
    },
];

fn train_cfg(tag: &str, lane: Lane, n_envs: usize) -> TrainConfig {
    let root = scratch(tag);
    TrainConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        executor: lane.executor,
        transport: lane.transport,
        worker_bin: worker_bin(),
        n_envs,
        io_mode: IoMode::InMemory,
        horizon: 5,
        iterations: 3,
        epochs: 2,
        seed: 11,
        log_every: 1,
        quiet: true,
        ..TrainConfig::default()
    }
}

fn pool_cfg(tag: &str, lane: Lane, n_envs: usize) -> PoolConfig {
    let root = scratch(tag);
    std::fs::create_dir_all(root.join("work")).unwrap();
    PoolConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        n_envs,
        io_mode: IoMode::InMemory,
        seed: 5,
        executor: lane.executor,
        transport: lane.transport,
        worker_bin: worker_bin(),
        ..PoolConfig::default()
    }
}

/// The learning-curve columns of train_log.csv: everything before the
/// wall-clock fields (the first 9 of 14).
fn learning_rows(out_dir: &std::path::Path) -> Vec<String> {
    let csv = std::fs::read_to_string(out_dir.join("train_log.csv")).unwrap();
    csv.lines()
        .skip(1)
        .map(|l| l.splitn(15, ',').take(9).collect::<Vec<_>>().join(","))
        .collect()
}

/// Run one matrix cell on every transport lane and assert the learning
/// CSV and final parameters are bitwise identical across all five.
fn assert_cell_bitwise(
    cell: &str,
    n_envs: usize,
    sync: SyncPolicy,
    batched: bool,
) {
    use drlfoam::coordinator::InferenceMode;
    let mut reference: Option<(Vec<String>, Vec<f32>, &'static str)> = None;
    for lane in LANES {
        let tag = format!("{cell}-{}", lane.name);
        let mut cfg = train_cfg(&tag, lane, n_envs);
        cfg.sync = sync;
        cfg.inference = if batched {
            InferenceMode::Batched
        } else {
            InferenceMode::PerEnv
        };
        let summary = train(&cfg)
            .unwrap_or_else(|e| panic!("cell {cell}, lane {}: training failed: {e:#}", lane.name));
        let rows = learning_rows(&cfg.out_dir);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
        match &reference {
            None => reference = Some((rows, summary.final_params, lane.name)),
            Some((want_rows, want_params, ref_name)) => {
                assert_eq!(
                    want_rows, &rows,
                    "cell {cell}: learning CSV diverged ({ref_name} vs {})",
                    lane.name
                );
                assert_eq!(
                    want_params, &summary.final_params,
                    "cell {cell}: final params diverged ({ref_name} vs {})",
                    lane.name
                );
            }
        }
    }
}

// --- the equivalence matrix -------------------------------------------------

#[test]
fn matrix_full_per_env() {
    let _ = require_worker_bin!();
    assert_cell_bitwise("full-pe", 2, SyncPolicy::Full, false);
}

#[test]
fn matrix_full_batched() {
    let _ = require_worker_bin!();
    assert_cell_bitwise("full-ba", 2, SyncPolicy::Full, true);
}

#[test]
fn matrix_partial_k_per_env() {
    let _ = require_worker_bin!();
    // k == n: the partial-barrier code path (drain + sort by env) with a
    // deterministic batch composition
    assert_cell_bitwise("part-pe", 2, SyncPolicy::Partial { k: 2 }, false);
}

#[test]
fn matrix_partial_k_batched() {
    let _ = require_worker_bin!();
    assert_cell_bitwise("part-ba", 2, SyncPolicy::Partial { k: 2 }, true);
}

#[test]
fn matrix_async_per_env() {
    let _ = require_worker_bin!();
    // one env: the async (k = 1) loop without the multi-env arrival race
    assert_cell_bitwise("async-pe", 1, SyncPolicy::Async, false);
}

#[test]
fn matrix_async_batched() {
    let _ = require_worker_bin!();
    // batched lockstep returns episodes in slot order: deterministic
    // even under async with several envs
    assert_cell_bitwise("async-ba", 2, SyncPolicy::Async, true);
}

// --- shm data plane, pool level ---------------------------------------------

#[test]
fn shm_episodes_match_in_process_bitwise() {
    let _ = require_worker_bin!();
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(21));
    let mut ip = EnvPool::standalone(&pool_cfg("bit-ip", LANES[0], 3)).unwrap();
    let a = ip.rollout(&params, 6, 2).unwrap();
    let mut shm = EnvPool::standalone(&pool_cfg("bit-shm", LANES[2], 3)).unwrap();
    let b = shm.rollout(&params, 6, 2).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.env_id, y.env_id);
        assert_eq!(x.traj, y.traj, "env {}", x.env_id);
        assert_eq!(x.stats.reward_sum, y.stats.reward_sum);
    }
}

#[test]
fn shm_lockstep_batched_matches_in_process() {
    // the lockstep path is where the ring actually carries the traffic:
    // every actuation period moves Step out and StepOut back
    let _ = require_worker_bin!();
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(8));
    let mut server_a = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let mut ip = EnvPool::standalone(&pool_cfg("lk-ip", LANES[0], 2)).unwrap();
    let a = ip.rollout_batched(None, &mut server_a, &params, 5, 1).unwrap();
    let mut server_b = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let mut shm = EnvPool::standalone(&pool_cfg("lk-shm", LANES[2], 2)).unwrap();
    let b = shm.rollout_batched(None, &mut server_b, &params, 5, 1).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.env_id, y.env_id);
        assert_eq!(x.traj, y.traj, "env {}", x.env_id);
    }
}

// --- chaos: crashes, torn writes, recovery ----------------------------------

#[test]
fn shm_sigkilled_worker_is_respawned_and_episode_requeued() {
    let _ = require_worker_bin!();
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(3));

    // fault-free twin for the bitwise comparison
    let mut twin = EnvPool::standalone(&pool_cfg("kill-twin", LANES[2], 2)).unwrap();
    let want = twin.rollout(&params, 5, 0).unwrap();

    let mut pool = EnvPool::standalone(&pool_cfg("kill", LANES[2], 2)).unwrap();
    let pids_before = pool.worker_pids();
    pool.kill_worker(0).unwrap();
    let got = pool.rollout(&params, 5, 0).unwrap();

    assert_eq!(got.len(), 2);
    assert_eq!(pool.restarts(), 1, "exactly one worker restart");
    assert_eq!(pool.restarts_by_env(), vec![1, 0]);
    let pids_after = pool.worker_pids();
    assert_ne!(pids_before[0], pids_after[0], "env 0 worker was respawned");
    assert_eq!(pids_before[1], pids_after[1], "env 1 worker untouched");
    // respawn gets fresh generation-keyed rings, so the replay cannot
    // read stale ring state: bitwise equal to the fault-free twin
    for (x, y) in want.iter().zip(&got) {
        assert_eq!(x.env_id, y.env_id);
        assert_eq!(x.traj, y.traj, "env {}", x.env_id);
    }
}

#[test]
fn torn_frame_crash_never_corrupts_and_recovery_is_bitwise() {
    // the centre of the chaos story: worker 0 dies *between* heartbeats
    // on receiving its 2nd episode, after writing a torn (unpublished)
    // ring slot AND a truncated pipe frame. The seqlock must make the
    // torn slot invisible, the pipe reader must treat the truncated
    // frame as death (not data), and respawn + re-queue must reproduce
    // the fault-free learning curve bitwise.
    let _ = require_worker_bin!();
    let clean_cfg = train_cfg("torn-clean", LANES[2], 2);
    let clean = train(&clean_cfg).expect("fault-free shm training failed");
    let rows_clean = learning_rows(&clean_cfg.out_dir);
    std::fs::remove_dir_all(&clean_cfg.out_dir).ok();

    let mut cfg = train_cfg("torn", LANES[2], 2);
    cfg.fault_injection = Some("0:1:midframe".into());
    let s = train(&cfg).expect("training with mid-frame crash failed");
    let rows = learning_rows(&cfg.out_dir);

    // the injected kill is counted exactly once — not zero (the crash
    // fired: its tombstone exists), not more (no corrupt-frame fallout)
    assert!(
        cfg.work_dir.join("chaos-env0-ep1.tombstone").exists(),
        "chaos hook must actually have fired"
    );
    assert_eq!(s.worker_restarts, 1, "exactly the injected kill");
    assert_eq!(rows, rows_clean, "recovery must not perturb the learning curve");
    assert_eq!(clean.final_params, s.final_params, "final params diverged");

    // workers.csv agrees with the summary, per env
    let text = std::fs::read_to_string(cfg.out_dir.join("workers.csv")).unwrap();
    let (header, wrows) = parse_csv(&text).unwrap();
    assert_eq!(
        header,
        vec!["env_id", "episodes", "restarts", "wall_s", "cfd_s", "io_s", "policy_s"]
    );
    assert_eq!(wrows[0][2], "1", "env 0 restarted once");
    assert_eq!(wrows[1][2], "0", "env 1 untouched");
    let episodes: usize = wrows.iter().map(|r| r[1].parse::<usize>().unwrap()).sum();
    assert_eq!(episodes, cfg.n_envs * cfg.iterations);
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn pipe_midframe_crash_also_recovers_bitwise() {
    // same chaos shape on the pipe transport: the truncated pipe frame
    // must read as death, never as data
    let _ = require_worker_bin!();
    let clean_cfg = train_cfg("ptorn-clean", LANES[1], 2);
    let clean = train(&clean_cfg).expect("fault-free pipe training failed");
    let rows_clean = learning_rows(&clean_cfg.out_dir);
    std::fs::remove_dir_all(&clean_cfg.out_dir).ok();

    let mut cfg = train_cfg("ptorn", LANES[1], 2);
    cfg.fault_injection = Some("0:1:midframe".into());
    let s = train(&cfg).expect("pipe training with mid-frame crash failed");
    let rows = learning_rows(&cfg.out_dir);
    assert_eq!(s.worker_restarts, 1);
    assert_eq!(rows, rows_clean);
    assert_eq!(clean.final_params, s.final_params);
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn socket_sigkilled_worker_is_respawned_and_episode_requeued() {
    // connection-kill recovery on both socket transports: SIGKILL leaves
    // the coordinator's socket reader at EOF mid-episode; respawn dials a
    // fresh listener and the identical (episode, seed) replay keeps the
    // run bitwise
    let _ = require_worker_bin!();
    for lane in [LANES[3], LANES[4]] {
        let params =
            Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(3));
        let mut twin =
            EnvPool::standalone(&pool_cfg(&format!("skill-twin-{}", lane.name), lane, 2)).unwrap();
        let want = twin.rollout(&params, 5, 0).unwrap();

        let mut pool =
            EnvPool::standalone(&pool_cfg(&format!("skill-{}", lane.name), lane, 2)).unwrap();
        let pids_before = pool.worker_pids();
        pool.kill_worker(0).unwrap();
        let got = pool.rollout(&params, 5, 0).unwrap();

        assert_eq!(got.len(), 2, "lane {}", lane.name);
        assert_eq!(pool.restarts(), 1, "lane {}: exactly one restart", lane.name);
        assert_eq!(pool.restarts_by_env(), vec![1, 0], "lane {}", lane.name);
        let pids_after = pool.worker_pids();
        assert_ne!(pids_before[0], pids_after[0], "lane {}: env 0 respawned", lane.name);
        assert_eq!(pids_before[1], pids_after[1], "lane {}: env 1 untouched", lane.name);
        for (x, y) in want.iter().zip(&got) {
            assert_eq!(x.env_id, y.env_id);
            assert_eq!(x.traj, y.traj, "lane {} env {}", lane.name, x.env_id);
        }
    }
}

#[test]
fn socket_midframe_crash_recovers_bitwise_with_exact_accounting() {
    // the pipe chaos shape on tcp and uds: a worker dying after writing a
    // truncated socket frame must read as death (never as data), and the
    // respawn + re-queue must reproduce the fault-free run bitwise with
    // the kill counted exactly once in workers.csv
    let _ = require_worker_bin!();
    for lane in [LANES[3], LANES[4]] {
        let clean_cfg = train_cfg(&format!("storn-clean-{}", lane.name), lane, 2);
        let clean = train(&clean_cfg)
            .unwrap_or_else(|e| panic!("fault-free {} training failed: {e:#}", lane.name));
        let rows_clean = learning_rows(&clean_cfg.out_dir);
        std::fs::remove_dir_all(&clean_cfg.out_dir).ok();

        let mut cfg = train_cfg(&format!("storn-{}", lane.name), lane, 2);
        cfg.fault_injection = Some("0:1:midframe".into());
        let s = train(&cfg)
            .unwrap_or_else(|e| panic!("{} training with mid-frame crash failed: {e:#}", lane.name));
        let rows = learning_rows(&cfg.out_dir);
        assert_eq!(s.worker_restarts, 1, "lane {}: exactly the injected kill", lane.name);
        assert_eq!(rows, rows_clean, "lane {}: learning curve perturbed", lane.name);
        assert_eq!(clean.final_params, s.final_params, "lane {}: params diverged", lane.name);

        let text = std::fs::read_to_string(cfg.out_dir.join("workers.csv")).unwrap();
        let (header, wrows) = parse_csv(&text).unwrap();
        assert_eq!(
            header,
            vec!["env_id", "episodes", "restarts", "wall_s", "cfd_s", "io_s", "policy_s"]
        );
        assert_eq!(wrows[0][2], "1", "lane {}: env 0 restarted once", lane.name);
        assert_eq!(wrows[1][2], "0", "lane {}: env 1 untouched", lane.name);
        let episodes: usize = wrows.iter().map(|r| r[1].parse::<usize>().unwrap()).sum();
        assert_eq!(episodes, cfg.n_envs * cfg.iterations);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}

// --- multi-node: training through real drlfoam agent processes --------------

/// A spawned `drlfoam agent` process, killed on drop so a failing test
/// never leaks a listener.
struct AgentProc {
    child: std::process::Child,
}

impl Drop for AgentProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Start `drlfoam agent --bind <sock>` and wait for its readiness line.
fn spawn_agent(bin: &std::path::Path, sock: &std::path::Path) -> AgentProc {
    use std::io::BufRead;
    let mut child = std::process::Command::new(bin)
        .arg("agent")
        .arg("--bind")
        .arg(sock)
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::inherit())
        .spawn()
        .expect("spawning drlfoam agent");
    let stdout = child.stdout.take().expect("piped agent stdout");
    let mut line = String::new();
    std::io::BufReader::new(stdout)
        .read_line(&mut line)
        .expect("reading the agent readiness line");
    assert!(
        line.contains("agent listening on"),
        "unexpected agent banner: {line:?}"
    );
    AgentProc { child }
}

#[test]
fn training_through_two_agents_matches_in_process_bitwise() {
    // the acceptance bar of the socket transport: a layout spanning two
    // per-host supervisors (both on localhost here) must reproduce the
    // in-process learning curve bitwise — agents relay frames, they never
    // touch them
    let _ = require_worker_bin!();
    let bin = worker_bin().unwrap();

    let reference_cfg = train_cfg("agents-ref", LANES[0], 2);
    let reference = train(&reference_cfg).expect("in-process reference training failed");
    let rows_ref = learning_rows(&reference_cfg.out_dir);
    std::fs::remove_dir_all(&reference_cfg.out_dir).ok();

    let root = scratch("agents");
    std::fs::create_dir_all(&root).unwrap();
    let sock_a = root.join("agent-a.sock");
    let sock_b = root.join("agent-b.sock");
    let _agent_a = spawn_agent(&bin, &sock_a);
    let _agent_b = spawn_agent(&bin, &sock_b);

    let mut cfg = train_cfg("agents-run", LANES[4], 2);
    // one core per agent: first-fit packing sends env 0 to A, env 1 to B
    cfg.hosts = drlfoam::exec::net::HostSpec::parse_list(&format!(
        "{}:1,{}:1",
        sock_a.display(),
        sock_b.display()
    ))
    .unwrap();
    let s = train(&cfg).expect("training through two agents failed");
    let rows = learning_rows(&cfg.out_dir);
    assert_eq!(rows, rows_ref, "learning curve diverged through the agents");
    assert_eq!(reference.final_params, s.final_params, "final params diverged");
    assert_eq!(s.worker_restarts, 0, "no restarts expected in a fault-free run");
    std::fs::remove_dir_all(&cfg.out_dir).ok();
    std::fs::remove_dir_all(&root).ok();
}

// --- guard rails ------------------------------------------------------------

#[test]
fn shm_with_in_process_executor_is_rejected() {
    let mut cfg = pool_cfg("shm-ip", LANES[0], 1);
    cfg.transport = TransportKind::Shm;
    let err = EnvPool::standalone(&cfg).unwrap_err().to_string();
    assert!(err.contains("multi-process"), "{err}");
}

#[test]
fn shm_ring_files_are_cleaned_up_on_drop() {
    let _ = require_worker_bin!();
    let cfg = pool_cfg("cleanup", LANES[2], 2);
    let work = cfg.work_dir.clone();
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(5));
    {
        let mut pool = EnvPool::standalone(&cfg).unwrap();
        let outs = pool.rollout(&params, 3, 0).unwrap();
        assert_eq!(outs.len(), 2);
    } // pool dropped: executor tears the rings down
    let leftover: Vec<_> = std::fs::read_dir(&work)
        .unwrap()
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".ring"))
        .collect();
    assert!(leftover.is_empty(), "ring files left behind: {leftover:?}");
}
