//! Determinism + correctness pins for the native CFD engine
//! (`rust/src/cfd/`, the `--cfd-backend native` tentpole).
//!
//! Three layers:
//!
//! * seeded property sweeps over randomized grids (ny, omega, Re,
//!   substeps, sweeps) pinning the engine's bitwise contract — scalar ==
//!   SIMD and 1 thread == N threads, down to the last bit of every field
//!   and every extracted force/probe;
//! * physical sanity of the `tiny` developed base flow (the Schaefer
//!   drag-coefficient band, a finite shedding amplitude) plus bitwise
//!   reproducibility of the development itself;
//! * a tolerance race against the XLA `cfd_period` artifact on the
//!   `small` grid — the two engines implement the same discretization,
//!   so one actuation period from the same state must agree to within
//!   f32 accumulation noise. Skips cleanly when `make artifacts` has not
//!   been run.

use drlfoam::cfd::{self, GridSpec, NativeEngine};
use drlfoam::runtime::{literal_f32, scalar_f32, to_vec_f32, Manifest, Runtime};
use drlfoam::util::prop;
use drlfoam::util::rng::Rng;

/// A randomized variant derived from the `tiny` preset: small enough for
/// property sweeps, varied enough to exercise odd panel splits, SIMD
/// remainder columns, and both SOR relaxation regimes.
fn random_spec(rng: &mut Rng) -> GridSpec {
    let mut s = cfd::variant("tiny").unwrap();
    s.ny = 20 + 2 * rng.below(11); // 20..=40: panel counts 3..5, nx 107..215
    s.sor_omega = rng.range(1.3, 1.9);
    s.re = rng.range(80.0, 250.0);
    s.substeps = 2 + rng.below(3); // 2..=4
    s.n_sweeps = 10 + rng.below(15); // 10..=24
    s
}

fn eq_bits(a: &[f32], b: &[f32], what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{what}[{i}]: {x:?} != {y:?}"));
        }
    }
    Ok(())
}

/// Run `n` actuation periods from a quiescent start; return every output
/// stream plus the final fields, so a comparison sees the whole state.
fn run_periods(
    spec: &GridSpec,
    threads: usize,
    force_scalar: bool,
    n: usize,
    jet: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut engine = NativeEngine::new(spec.clone(), threads, force_scalar);
    let (mut u, mut v, mut p) = engine.quiescent();
    let (mut probes, mut cds, mut cls) = (Vec::new(), Vec::new(), Vec::new());
    for _ in 0..n {
        let out = engine.period(&mut u, &mut v, &mut p, jet);
        probes.extend(out.probes);
        cds.extend(out.cd_hist);
        cls.extend(out.cl_hist);
    }
    (u, v, p, probes, cds, cls)
}

fn compare_runs(
    spec: &GridSpec,
    (ta, sa): (usize, bool),
    (tb, sb): (usize, bool),
    jet: f32,
) -> Result<(), String> {
    let a = run_periods(spec, ta, sa, 2, jet);
    let b = run_periods(spec, tb, sb, 2, jet);
    let tag = format!(
        "ny={} omega={:.3} re={:.1} sub={} sweeps={} [{}T/{}] vs [{}T/{}]",
        spec.ny,
        spec.sor_omega,
        spec.re,
        spec.substeps,
        spec.n_sweeps,
        ta,
        if sa { "scalar" } else { "simd" },
        tb,
        if sb { "scalar" } else { "simd" },
    );
    eq_bits(&a.0, &b.0, &format!("{tag} u"))?;
    eq_bits(&a.1, &b.1, &format!("{tag} v"))?;
    eq_bits(&a.2, &b.2, &format!("{tag} p"))?;
    eq_bits(&a.3, &b.3, &format!("{tag} probes"))?;
    eq_bits(&a.4, &b.4, &format!("{tag} cd_hist"))?;
    eq_bits(&a.5, &b.5, &format!("{tag} cl_hist"))?;
    Ok(())
}

#[test]
fn scalar_and_simd_paths_agree_bitwise() {
    // Where AVX2 is unavailable both runs take the scalar path and the
    // property is trivially true; on AVX2 machines this is the real pin.
    prop::check("scalar == simd bitwise", 5, |rng| {
        let spec = random_spec(rng);
        let jet = rng.range(-0.4, 0.4) as f32;
        compare_runs(&spec, (1, true), (1, false), jet)
    });
}

#[test]
fn thread_count_does_not_change_a_single_bit() {
    prop::check("1 thread == N threads bitwise", 5, |rng| {
        let spec = random_spec(rng);
        let jet = rng.range(-0.4, 0.4) as f32;
        let threads = 2 + rng.below(3); // 2..=4
        compare_runs(&spec, (1, false), (threads, false), jet)?;
        // and the combined claim: threaded SIMD == single-thread scalar
        compare_runs(&spec, (1, true), (threads, false), jet)
    });
}

#[test]
fn tiny_base_flow_is_sane_and_reproducible() {
    let develop = || {
        let mut engine = NativeEngine::from_env(cfd::variant("tiny").unwrap());
        engine.develop_base_flow()
    };
    let a = develop();
    // Schaefer-benchmark band for the blockage-corrected coarse grid:
    // the tiny oracle run gives cd0 = 3.99, cl amplitude 0.43.
    assert!(
        (3.0..5.5).contains(&a.cd0),
        "tiny base-flow cd0 {} outside the sane band",
        a.cd0
    );
    assert!(
        (0.1..1.5).contains(&a.cl0_amplitude),
        "tiny base-flow cl amplitude {} outside the sane band",
        a.cl0_amplitude
    );
    assert!(
        a.probe_std.iter().all(|s| *s > 0.0 && s.is_finite()),
        "probe normalisation stds must be positive and finite"
    );
    assert!(a.u.iter().all(|x| x.is_finite()), "base-flow u has NaN/inf");

    // A second, independent development must be bitwise identical — the
    // process-wide cache in `cached_base_flow` relies on this.
    let b = develop();
    assert_eq!(a.cd0.to_bits(), b.cd0.to_bits(), "cd0 diverged");
    eq_bits(&a.u, &b.u, "base u").unwrap();
    eq_bits(&a.v, &b.v, "base v").unwrap();
    eq_bits(&a.p, &b.p, "base p").unwrap();
    eq_bits(&a.probe_mean, &b.probe_mean, "probe_mean").unwrap();
    eq_bits(&a.probe_std, &b.probe_std, "probe_std").unwrap();
}

/// |a - b| <= atol elementwise.
fn close(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(
        worst <= atol,
        "{what}: max |native - xla| = {worst:e} > atol {atol:e}"
    );
}

/// One actuation period from the developed `small` state: the native
/// engine vs the AOT XLA artifact. Same discretization, different
/// accumulation order, so tolerance (not bitwise) — the oracle margins
/// are probes 3.7e-5 and cd 1.2e-5, pinned here with ~100x headroom.
#[test]
fn native_period_tracks_xla_on_small() {
    let m = match Manifest::load_optional("artifacts").unwrap() {
        Some(m) => m,
        None => {
            eprintln!("native_period_tracks_xla_on_small: skipped: no artifacts");
            return;
        }
    };
    let vm = m.variant("small").unwrap().clone();
    let mut rt = Runtime::new("artifacts").unwrap();
    rt.load(&vm.cfd_period_file).unwrap();
    let cfd = rt.get(&vm.cfd_period_file).unwrap();
    let (u0, v0, p0) = m.load_state0("small").unwrap();
    let jet = 0.1f32;

    let dims = [vm.ny as i64, vm.nx as i64];
    let args = [
        literal_f32(&u0, &dims).unwrap(),
        literal_f32(&v0, &dims).unwrap(),
        literal_f32(&p0, &dims).unwrap(),
        scalar_f32(jet),
    ];
    let outs = cfd.run(&args).unwrap();
    assert_eq!(outs.len(), 6, "cfd_period output arity");
    let probes_x = to_vec_f32(&outs[3]).unwrap();
    let cd_x = to_vec_f32(&outs[4]).unwrap();
    let cl_x = to_vec_f32(&outs[5]).unwrap();

    let mut engine = NativeEngine::from_env(cfd::variant("small").unwrap());
    let (mut u, mut v, mut p) = (u0, v0, p0);
    let out = engine.period(&mut u, &mut v, &mut p, jet);

    close(&out.probes, &probes_x, 5e-3, "probes");
    close(&out.cd_hist, &cd_x, 2e-3, "cd_hist");
    close(&out.cl_hist, &cl_x, 2e-3, "cl_hist");
}
