//! Failure injection: broken artifacts and malformed inputs must produce
//! clean, contextual errors — never panics or silent garbage.

use drlfoam::runtime::{read_f32_bin, write_f32_bin, Manifest, Runtime};

fn scratch(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("drlfoam-fail-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_contextual_error() {
    let d = scratch("nomanifest");
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("manifest.json"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupt_manifest_json_rejected() {
    let d = scratch("badjson");
    std::fs::write(d.join("manifest.json"), "{ not json !!!").unwrap();
    assert!(Manifest::load(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn manifest_missing_keys_rejected() {
    let d = scratch("missingkeys");
    std::fs::write(d.join("manifest.json"), r#"{"format_version": 1}"#).unwrap();
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("drl"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn truncated_state0_rejected() {
    // copy the real manifest but truncate the state file
    let m = Manifest::load("artifacts").expect("make artifacts");
    let d = scratch("truncstate");
    std::fs::copy("artifacts/manifest.json", d.join("manifest.json")).unwrap();
    let v = m.variant("small").unwrap();
    write_f32_bin(d.join(&v.state0_file), &vec![0f32; 7]).unwrap();
    std::fs::copy(
        std::path::Path::new("artifacts").join("params_init.bin"),
        d.join("params_init.bin"),
    )
    .unwrap();
    let m2 = Manifest::load(&d).unwrap();
    let err = m2.load_state0("small").unwrap_err().to_string();
    assert!(err.contains("state0"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn wrong_sized_params_rejected() {
    let d = scratch("badparams");
    std::fs::copy("artifacts/manifest.json", d.join("manifest.json")).unwrap();
    write_f32_bin(d.join("params_init.bin"), &[1.0, 2.0]).unwrap();
    let m = Manifest::load(&d).unwrap();
    let err = m.load_params_init().unwrap_err().to_string();
    assert!(err.contains("params_init"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn non_multiple_of_four_bin_rejected() {
    let d = scratch("oddbin");
    std::fs::write(d.join("x.bin"), [1u8, 2, 3]).unwrap();
    assert!(read_f32_bin(d.join("x.bin")).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn garbage_hlo_text_fails_at_load() {
    let d = scratch("badhlo");
    std::fs::write(d.join("bad.hlo.txt"), "this is not hlo").unwrap();
    let mut rt = Runtime::new(&d).unwrap();
    let msg = match rt.load("bad.hlo.txt") {
        Ok(_) => panic!("garbage HLO text compiled?!"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("bad.hlo.txt"), "{msg}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn missing_artifact_file_contextual() {
    let mut rt = Runtime::new("artifacts").unwrap();
    let err = match rt.load("nope.hlo.txt") {
        Ok(_) => panic!("missing artifact loaded?!"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("nope.hlo.txt"), "{err}");
}

#[test]
fn executable_wrong_arity_is_error_not_crash() {
    let m = Manifest::load("artifacts").unwrap();
    let mut rt = Runtime::new("artifacts").unwrap();
    rt.load(&m.drl.policy_apply_file).unwrap();
    let exe = rt.get(&m.drl.policy_apply_file).unwrap();
    // policy_apply wants (params, obs); give it one arg
    let one = drlfoam::runtime::literal_f32(&[0.0f32; 4], &[4]).unwrap();
    assert!(exe.run(&[one]).is_err());
}

#[test]
fn unknown_io_mode_rejected() {
    assert!(drlfoam::io_interface::IoMode::parse("carrier-pigeon").is_err());
}
