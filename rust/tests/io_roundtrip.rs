//! Integration: the three exchange interfaces are lossless (to parsing
//! precision) and their cost ordering matches the paper's premise
//! (baseline writes several times more bytes than optimized).

use drlfoam::io_interface::{make_interface, CfdOutput, FlowSnapshot, IoMode};
use drlfoam::util::prop;
use drlfoam::util::rng::Rng;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("drlfoam-io-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn random_payload(rng: &mut Rng, n_probes: usize, substeps: usize, cells: usize) -> (CfdOutput, Vec<f32>, Vec<f32>, Vec<f32>) {
    let out = CfdOutput {
        probes: (0..n_probes).map(|_| rng.normal() as f32).collect(),
        cd_hist: (0..substeps).map(|_| 3.0 + 0.2 * rng.normal() as f32).collect(),
        cl_hist: (0..substeps).map(|_| rng.normal() as f32).collect(),
    };
    let u: Vec<f32> = (0..cells).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..cells).map(|_| rng.normal() as f32).collect();
    let p: Vec<f32> = (0..cells).map(|_| rng.normal() as f32).collect();
    (out, u, v, p)
}

fn roundtrip(mode: IoMode, tol: f32) {
    let dir = tmp_dir(mode.name());
    prop::check(&format!("{} roundtrip", mode.name()), 10, |rng| {
        let (ny, nx) = (8, 12);
        let (out, u, v, p) = random_payload(rng, 16, 5, ny * nx);
        let mut iface = make_interface(mode, &dir, 0).unwrap();
        let flow = FlowSnapshot { u: &u, v: &v, p: &p, ny, nx };
        let (parsed, stats) = iface.exchange(0, &out, &flow).map_err(|e| e.to_string())?;
        for (a, b) in out.probes.iter().zip(&parsed.probes) {
            if (a - b).abs() > tol {
                return Err(format!("probe {a} vs {b}"));
            }
        }
        if parsed.cd_hist.len() != out.cd_hist.len() {
            return Err("cd history length changed".into());
        }
        for (a, b) in out.cd_hist.iter().zip(&parsed.cd_hist) {
            if (a - b).abs() > tol {
                return Err(format!("cd {a} vs {b}"));
            }
        }
        if mode != IoMode::InMemory && stats.bytes_written == 0 {
            return Err("no bytes written".into());
        }
        // action round-trip
        let a0 = rng.normal();
        let (a1, _) = iface.inject_action(0, a0).map_err(|e| e.to_string())?;
        if (a0 - a1).abs() > 1e-8 {
            return Err(format!("action {a0} vs {a1}"));
        }
        Ok(())
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ascii_roundtrip_lossless() {
    roundtrip(IoMode::Baseline, 1e-5);
}

#[test]
fn binary_roundtrip_exact() {
    roundtrip(IoMode::Optimized, 0.0);
}

#[test]
fn memory_roundtrip_exact() {
    roundtrip(IoMode::InMemory, 0.0);
}

#[test]
fn byte_volumes_ordered_like_the_paper() {
    // baseline (ASCII, full flow) must cost several times the optimized
    // (binary, restart-only) volume; in-memory costs nothing. Paper ratio:
    // 5.0 MB / 1.2 MB ~ 4.2x.
    let dir = tmp_dir("volumes");
    let mut rng = Rng::new(9);
    let (ny, nx) = (48, 258); // the `small` grid
    let (out, u, v, p) = random_payload(&mut rng, 149, 10, ny * nx);
    let flow = FlowSnapshot { u: &u, v: &v, p: &p, ny, nx };

    let mut bytes = std::collections::BTreeMap::new();
    for mode in [IoMode::Baseline, IoMode::Optimized, IoMode::InMemory] {
        let mut iface = make_interface(mode, &dir, 1).unwrap();
        let (_, st) = iface.exchange(0, &out, &flow).unwrap();
        bytes.insert(mode.name(), st.bytes_written);
    }
    assert_eq!(bytes["in-memory"], 0);
    assert!(bytes["optimized"] > 0);
    let ratio = bytes["baseline"] as f64 / bytes["optimized"] as f64;
    assert!(
        ratio > 2.0,
        "baseline/optimized byte ratio {ratio:.2} too small (paper ~4.2)"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ascii_files_are_openfoam_flavoured() {
    let dir = tmp_dir("foamcheck");
    let mut rng = Rng::new(1);
    let (out, u, v, p) = random_payload(&mut rng, 8, 3, 24);
    let mut iface = make_interface(IoMode::Baseline, &dir, 2).unwrap();
    let flow = FlowSnapshot { u: &u, v: &v, p: &p, ny: 4, nx: 6 };
    iface.exchange(0, &out, &flow).unwrap();
    let udir = dir.join("env002").join("0.U");
    let text = std::fs::read_to_string(udir).unwrap();
    assert!(text.contains("FoamFile"));
    assert!(text.contains("internalField"));
    std::fs::remove_dir_all(&dir).ok();
}
