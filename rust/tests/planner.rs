//! Allocation-planner integration: the joint (n_envs x ranks x sync x io)
//! sweep must rediscover the paper's optimal 60-core layout (Table I/II:
//! 60 single-rank envs, optimized exchange, ~47x / ~78%), fail clearly on
//! impossible core budgets, respect the staleness weight, emit a CSV that
//! round-trips through the shared parser, and drive an end-to-end
//! artifact-free `--layout auto` training run.

use drlfoam::cluster::planner::{search, Objective, Plan, PlannerConfig, PLAN_CSV_HEADER};
use drlfoam::cluster::Calibration;
use drlfoam::coordinator::{train, SyncPolicy, TrainConfig};
use drlfoam::drl::{PolicyBackendKind, UpdateBackendKind};
use drlfoam::io_interface::IoMode;
use drlfoam::metrics::parse_csv;

fn paper_cfg(cores: usize, episodes: usize) -> PlannerConfig {
    let mut c = PlannerConfig::new(cores);
    // a reduced episode budget keeps the sweep fast; speedup/efficiency
    // are ratios of structurally identical runs, so the optimum is the
    // same as at the paper's 3000 (reproduce::plan runs the full budget)
    c.episodes_total = episodes;
    c
}

#[test]
fn planner_at_60_cores_recovers_the_paper_optimum() {
    let calib = Calibration::paper_scale();
    let set = search(&calib, &paper_cfg(60, 300)).unwrap();
    let best = set.best().unwrap();
    assert_eq!(
        (best.n_envs, best.n_ranks),
        (60, 1),
        "layout {} x {} is not the paper's 60 x 1 optimum",
        best.n_envs,
        best.n_ranks
    );
    assert_eq!(best.io_mode, IoMode::Optimized, "io {}", best.io_mode.name());
    assert_eq!(best.sync, SyncPolicy::Full, "sync {}", best.sync.name());
    assert_eq!(best.mean_staleness, 0.0);
    // paper: ~47x speedup at ~78% parallel efficiency on 60 cores
    assert!(
        best.speedup > 36.0 && best.speedup < 58.0,
        "speedup {:.1} outside the Table-I tolerance band",
        best.speedup
    );
    assert!(
        best.efficiency_pct > 64.0 && best.efficiency_pct < 92.0,
        "efficiency {:.1}% outside the Table-I tolerance band",
        best.efficiency_pct
    );
    // the winner is Pareto-optimal, and the front also carries an
    // off-policy layout trading staleness for wall time
    assert!(best.pareto);
    assert!(
        set.pareto_front().iter().any(|p| p.mean_staleness > 0.0),
        "no staleness/wall-time trade on the Pareto front"
    );
}

#[test]
fn impossible_core_budget_is_a_clear_error() {
    let calib = Calibration::paper_scale();
    let mut c = paper_cfg(1, 60);
    c.ranks_options = vec![2, 5];
    let err = search(&calib, &c).unwrap_err().to_string();
    assert!(err.contains("core budget"), "unhelpful error: {err}");
    assert!(err.contains('2'), "error does not name the rank minimum: {err}");
}

#[test]
fn staleness_weight_dominance_prefers_full_sync() {
    let calib = Calibration::paper_scale();
    let mut c = paper_cfg(16, 160);
    c.ranks_options = vec![1];
    c.staleness_weight = 100.0;
    let conservative = search(&calib, &c).unwrap();
    let best = conservative.best().unwrap().clone();
    assert_eq!(best.sync, SyncPolicy::Full, "weight 100 still picked {}", best.sync.name());
    assert_eq!(best.mean_staleness, 0.0);
    // weight 0 is the pure wall-clock argmin
    c.staleness_weight = 0.0;
    let fastest = search(&calib, &c).unwrap();
    let t_min = fastest
        .plans
        .iter()
        .map(|p| p.duration_h)
        .fold(f64::INFINITY, f64::min);
    assert_eq!(fastest.best().unwrap().duration_h, t_min);
    assert!(fastest.best().unwrap().duration_h <= best.duration_h + 1e-12);
}

#[test]
fn plan_csv_round_trips_through_the_shared_parser() {
    let calib = Calibration::paper_scale();
    let set = search(&calib, &paper_cfg(8, 80)).unwrap();
    let dir = std::env::temp_dir().join(format!("drlfoam-plan-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.csv");
    set.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let (header, rows) = parse_csv(&text).unwrap();
    assert_eq!(header.join(","), PLAN_CSV_HEADER);
    assert_eq!(rows.len(), set.plans.len());
    for (row, p) in rows.iter().zip(&set.plans) {
        let q = Plan::from_csv(row).unwrap();
        assert_eq!((q.n_envs, q.n_ranks, q.total_cpus), (p.n_envs, p.n_ranks, p.total_cpus));
        assert_eq!(q.sync, p.sync);
        assert_eq!(q.io_mode, p.io_mode);
        assert_eq!(q.pareto, p.pareto);
        assert!((q.duration_h - p.duration_h).abs() <= 1e-3 * p.duration_h.max(1.0));
        assert!((q.mean_staleness - p.mean_staleness).abs() < 5e-3);
        assert!((q.efficiency_pct - p.efficiency_pct).abs() < 5e-2);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The `--layout auto` pipeline, artifact-free: a measured-small
/// calibration feeds the search, the winner is applied to the real
/// scheduler loop via `TrainConfig::apply_plan`, and training runs end
/// to end on the surrogate scenario.
#[test]
fn layout_auto_pipeline_trains_artifact_free() {
    // stand-in for `drlfoam calibrate` / the CLI's quick measurement:
    // per-component costs of roughly surrogate magnitude
    let calib = Calibration::from_measured(2e-4, 5e-6, 2e-5, 6.0e5, 1.5e5, 3e-4, 5e-5, 4);
    let mut pc = PlannerConfig::new(3);
    pc.episodes_total = 6;
    pc.ranks_options = vec![1];
    // the in-process loop can skip the filesystem for real
    pc.io_options = vec![IoMode::Baseline, IoMode::Optimized, IoMode::InMemory];
    let set = search(&calib, &pc).unwrap();
    let best = set.best().unwrap();
    assert!(best.n_envs >= 1 && best.n_envs <= 3);

    let root = std::env::temp_dir().join(format!("drlfoam-auto-{}", std::process::id()));
    let mut cfg = TrainConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        horizon: 4,
        iterations: 2,
        epochs: 1,
        seed: 5,
        quiet: true,
        ..TrainConfig::default()
    };
    cfg.apply_plan(best);
    assert_eq!(cfg.n_envs, best.n_envs);
    assert_eq!(cfg.sync, best.sync);
    assert_eq!(cfg.io_mode, best.io_mode);
    let summary = train(&cfg).unwrap();
    assert!(!summary.log.is_empty());
    assert_eq!(
        summary.log.last().unwrap().episodes_done,
        cfg.iterations * cfg.n_envs
    );
    assert!(root.join("train_log.csv").exists());
    assert!(root.join("policy_final.bin").exists());
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn objective_efficiency_and_pareto_rankings_are_coherent() {
    let calib = Calibration::paper_scale();
    let mut c = paper_cfg(6, 60);
    c.objective = Objective::Efficiency;
    let by_eff = search(&calib, &c).unwrap();
    // the efficiency objective maximizes penalized speedup*efficiency —
    // the knee of the scaling curve, never the trivial 1-core corner
    let best_eff = by_eff.best().unwrap();
    let knee = |p: &drlfoam::cluster::planner::Plan| {
        p.speedup * p.efficiency_pct / (1.0 + c.staleness_weight * p.mean_staleness)
    };
    let max_knee = by_eff.plans.iter().map(knee).fold(f64::NEG_INFINITY, f64::max);
    assert!(knee(best_eff) + 1e-9 >= max_knee);
    assert!(best_eff.total_cpus > 1, "efficiency objective picked the 1-core corner");
    c.objective = Objective::Pareto;
    let by_pareto = search(&calib, &c).unwrap();
    assert!(by_pareto.best().unwrap().pareto, "pareto objective ranked a dominated layout first");
    // every front member ranks ahead of every dominated layout
    let first_dominated = by_pareto.plans.iter().position(|p| !p.pareto);
    if let Some(i) = first_dominated {
        assert!(by_pareto.plans[..i].iter().all(|p| p.pareto));
        assert!(by_pareto.plans[i..].iter().all(|p| !p.pareto));
    }
}
