//! Integration: the cluster DES reproduces the *shape* of the paper's
//! Tables I/II and Figs 7-12 (who wins, where the cliffs fall, rough
//! factors). Tolerances are generous on absolute numbers, tight on
//! orderings and trends.

use drlfoam::cluster::{simulate_training, Calibration, MpiScaling, SimConfig};
use drlfoam::coordinator::SyncPolicy;
use drlfoam::io_interface::IoMode;

fn hours(c: &Calibration, envs: usize, ranks: usize, mode: IoMode) -> f64 {
    simulate_training(
        c,
        &SimConfig {
            n_envs: envs,
            n_ranks: ranks,
            episodes_total: 3000,
            io_mode: mode,
            sync: SyncPolicy::Full,
            remote_envs: 0,
            seed: 1,
        },
    )
    .total_s
        / 3600.0
}

#[test]
fn table1_absolute_durations_close_to_paper() {
    let c = Calibration::paper_scale();
    // paper column: (envs, ranks, hours)
    let rows = [
        (1, 1, 225.2),
        (10, 1, 26.3),
        (30, 1, 9.6),
        (60, 1, 7.6),
        (1, 2, 289.6),
        (10, 2, 33.2),
        (30, 2, 12.4),
        (1, 5, 305.8),
        (12, 5, 32.4),
    ];
    for (envs, ranks, want) in rows {
        let got = hours(&c, envs, ranks, IoMode::Baseline);
        let rel = (got - want).abs() / want;
        assert!(
            rel < 0.25,
            "envs={envs} ranks={ranks}: {got:.1} h vs paper {want} (rel {rel:.2})"
        );
    }
}

#[test]
fn single_core_multi_env_is_the_best_hybrid() {
    // the paper's core finding: for fixed total CPUs, ranks=1 wins
    let c = Calibration::paper_scale();
    for cpus in [10usize, 20, 60] {
        let t1 = hours(&c, cpus, 1, IoMode::Baseline);
        let t2 = hours(&c, cpus / 2, 2, IoMode::Baseline);
        let t5 = hours(&c, cpus / 5, 5, IoMode::Baseline);
        assert!(t1 < t2, "cpus={cpus}: ranks1 {t1:.1} !< ranks2 {t2:.1}");
        assert!(t2 < t5, "cpus={cpus}: ranks2 {t2:.1} !< ranks5 {t5:.1}");
    }
}

#[test]
fn efficiency_cliff_past_30_envs_baseline_only() {
    let c = Calibration::paper_scale();
    let eff = |envs: usize, mode| {
        let t1 = hours(&c, 1, 1, mode);
        let t = hours(&c, envs, 1, mode);
        100.0 * t1 / t / envs as f64
    };
    // paper Table I: 30 envs 78.4%, 60 envs 49.3%
    let e30 = eff(30, IoMode::Baseline);
    let e60 = eff(60, IoMode::Baseline);
    assert!(e30 > 65.0 && e30 < 90.0, "eff(30) = {e30:.1}");
    assert!(e60 > 40.0 && e60 < 62.0, "eff(60) = {e60:.1}");
    assert!(e30 - e60 > 15.0, "no cliff: {e30:.1} -> {e60:.1}");
    // optimized I/O removes the cliff (paper: ~78% at 60)
    let o60 = eff(60, IoMode::Optimized);
    assert!(o60 > 68.0, "optimized eff(60) = {o60:.1}");
}

#[test]
fn table2_io_speedup_grows_with_envs() {
    let c = Calibration::paper_scale();
    // paper: disabling I/O buys 14% at 1 env, 37% at 60 envs
    let gain = |envs: usize| {
        let tb = hours(&c, envs, 1, IoMode::Baseline);
        let td = hours(&c, envs, 1, IoMode::InMemory);
        100.0 * (tb - td) / tb
    };
    let g1 = gain(1);
    let g60 = gain(60);
    assert!(g1 > 2.0 && g1 < 25.0, "gain(1) = {g1:.1}%");
    assert!(g60 > 25.0 && g60 < 50.0, "gain(60) = {g60:.1}%");
    assert!(g60 > g1 + 10.0, "gain must grow: {g1:.1} -> {g60:.1}");
}

#[test]
fn optimized_tracks_io_disabled() {
    // paper: T_optimized ~ T_io-disabled across the sweep
    let c = Calibration::paper_scale();
    for envs in [1usize, 10, 30, 60] {
        let td = hours(&c, envs, 1, IoMode::InMemory);
        let to = hours(&c, envs, 1, IoMode::Optimized);
        assert!(
            (to - td) / td < 0.12,
            "envs={envs}: optimized {to:.1} vs disabled {td:.1}"
        );
    }
}

#[test]
fn fig7_cfd_scaling_shape() {
    let m = MpiScaling::default();
    assert!(m.efficiency(2) > 0.85, "eff(2) = {}", m.efficiency(2));
    assert!(m.efficiency(16) < 0.2, "eff(16) = {}", m.efficiency(16));
    // monotone decreasing efficiency
    let mut prev = f64::INFINITY;
    for n in [1, 2, 4, 8, 16] {
        let e = m.efficiency(n);
        assert!(e <= prev + 1e-12, "eff not monotone at {n}");
        prev = e;
    }
}

#[test]
fn headline_speedups() {
    let c = Calibration::paper_scale();
    let t11 = hours(&c, 1, 1, IoMode::Baseline);
    let s_base = t11 / hours(&c, 60, 1, IoMode::Baseline);
    let s_opt = t11 / hours(&c, 60, 1, IoMode::Optimized);
    // paper: ~30x baseline, ~47x optimized on 60 cores
    assert!(s_base > 24.0 && s_base < 38.0, "baseline speedup {s_base:.1}");
    assert!(s_opt > 38.0 && s_opt < 56.0, "optimized speedup {s_opt:.1}");
    assert!(s_opt > s_base * 1.25);
}

#[test]
fn des_scales_to_any_env_count_deterministically() {
    let c = Calibration::paper_scale();
    for envs in [3usize, 7, 24, 48] {
        let a = hours(&c, envs, 1, IoMode::Baseline);
        let b = hours(&c, envs, 1, IoMode::Baseline);
        assert_eq!(a, b);
        assert!(a.is_finite() && a > 0.0);
    }
}
