//! `drlfoam agent` lifecycle: the per-host supervisor must fail loudly,
//! never leak, and never hang.
//!
//! Three properties, each the distributed analogue of something the
//! process executor already guarantees locally:
//!
//! * a second agent on an occupied endpoint is refused at startup with
//!   an error naming the bind (silent port-stealing would split a
//!   topology across two supervisors);
//! * a coordinator that vanishes mid-run must not leave orphaned rank
//!   groups holding cores — connection EOF makes the agent kill and
//!   reap its worker;
//! * a SIGKILL'd agent surfaces as a training error (failed respawn →
//!   counted restart path), not a hang: the coordinator's reconnect hits
//!   connection-refused immediately, well inside the worker liveness
//!   timeout.
//!
//! Everything runs artifact-free on the surrogate scenario and skips
//! gracefully when Cargo does not provide the binary.

use std::io::BufRead;
use std::sync::Arc;
use std::time::{Duration, Instant};

use drlfoam::coordinator::{EnvPool, PoolConfig};
use drlfoam::drl::{NativePolicy, PolicyBackendKind};
use drlfoam::env::scenario::{SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::exec::net::HostSpec;
use drlfoam::exec::wire::{self, Frame};
use drlfoam::exec::{ExecutorKind, TransportKind};
use drlfoam::io_interface::IoMode;

fn worker_bin() -> Option<std::path::PathBuf> {
    option_env!("CARGO_BIN_EXE_drlfoam").map(Into::into)
}

macro_rules! require_worker_bin {
    () => {
        match worker_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: CARGO_BIN_EXE_drlfoam not provided by cargo");
                return;
            }
        }
    };
}

fn scratch(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("drlfoam-agent-{tag}-{}", std::process::id()))
}

/// A spawned `drlfoam agent`, killed + reaped on drop.
struct AgentProc {
    child: std::process::Child,
}

impl AgentProc {
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for AgentProc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Start an agent on `sock` and block until its readiness line.
fn spawn_agent(bin: &std::path::Path, sock: &std::path::Path) -> AgentProc {
    let mut child = std::process::Command::new(bin)
        .arg("agent")
        .arg("--bind")
        .arg(sock)
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::inherit())
        .spawn()
        .expect("spawning drlfoam agent");
    let stdout = child.stdout.take().expect("piped agent stdout");
    let mut line = String::new();
    std::io::BufReader::new(stdout)
        .read_line(&mut line)
        .expect("reading the agent readiness line");
    assert!(
        line.contains("agent listening on"),
        "unexpected agent banner: {line:?}"
    );
    AgentProc { child }
}

#[test]
fn double_bind_is_refused_with_a_clear_error() {
    let bin = require_worker_bin!();
    let root = scratch("bind");
    std::fs::create_dir_all(&root).unwrap();
    let sock = root.join("agent.sock");
    let _agent = spawn_agent(&bin, &sock);

    // a second supervisor on the same endpoint must die at startup, and
    // its error must say which bind failed — not steal or queue behind
    // the first one
    let out = std::process::Command::new(&bin)
        .arg("agent")
        .arg("--bind")
        .arg(&sock)
        .output()
        .expect("running the second agent");
    assert!(!out.status.success(), "second bind must be refused");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("already bound") && stderr.contains(sock.to_str().unwrap()),
        "error must name the occupied bind: {stderr}"
    );
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn orphaned_worker_is_reaped_on_coordinator_disconnect() {
    let bin = require_worker_bin!();
    let root = scratch("orphan");
    std::fs::create_dir_all(root.join("work")).unwrap();
    let sock = root.join("agent.sock");
    let _agent = spawn_agent(&bin, &sock);

    // play coordinator by hand: dial, send the Spawn spec, and take the
    // worker's pid from its Hello
    let mut conn = std::os::unix::net::UnixStream::connect(&sock).unwrap();
    wire::write_frame(
        &mut conn,
        &Frame::Spawn {
            env_id: 0,
            rank: 0,
            seed: 7,
            heartbeat_ms: 50,
            scenario: "surrogate".into(),
            variant: "small".into(),
            artifact_dir: root.join("no-artifacts").display().to_string(),
            work_dir: root.join("work").display().to_string(),
            io_mode: "in-memory".into(),
            backend: "native".into(),
            cfd_backend: "xla".into(),
            fault_injection: String::new(),
            trace: 0,
        },
    )
    .unwrap();
    let pid = loop {
        match wire::read_frame(&mut conn).unwrap() {
            Some(Frame::Hello { pid, .. }) => break pid,
            Some(_) => continue, // heartbeats may land first
            None => panic!("agent closed the connection before the worker's Hello"),
        }
    };
    let proc_path = std::path::PathBuf::from(format!("/proc/{pid}"));
    assert!(proc_path.exists(), "worker pid {pid} should be alive");

    // the coordinator vanishes: the agent must kill and reap the worker
    // rather than leave it holding its cores
    drop(conn);
    let deadline = Instant::now() + Duration::from_secs(10);
    while proc_path.exists() {
        assert!(
            Instant::now() < deadline,
            "worker {pid} still alive 10 s after its coordinator disconnected"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn sigkilled_agent_surfaces_as_an_error_not_a_hang() {
    let bin = require_worker_bin!();
    let root = scratch("sigkill");
    std::fs::create_dir_all(root.join("work")).unwrap();
    let sock = root.join("agent.sock");
    let mut agent = spawn_agent(&bin, &sock);

    let cfg = PoolConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        n_envs: 2,
        io_mode: IoMode::InMemory,
        seed: 5,
        executor: ExecutorKind::MultiProcess,
        transport: TransportKind::Uds,
        worker_bin: worker_bin(),
        hosts: HostSpec::parse_list(&format!("{}:2", sock.display())).unwrap(),
        ..PoolConfig::default()
    };
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(9));
    let mut pool = EnvPool::standalone(&cfg).unwrap();
    // prove the topology works before breaking it
    let outs = pool.rollout(&params, 3, 0).unwrap();
    assert_eq!(outs.len(), 2);

    // SIGKILL the supervisor: its relays die with it, the coordinator's
    // readers see EOF, and the respawn's re-dial hits connection-refused
    // — a counted, contextual error, never a silent wait
    agent.kill();
    let t0 = Instant::now();
    let err = pool.rollout(&params, 3, 1);
    assert!(err.is_err(), "rollout through a dead agent must fail");
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "dead agent took {:?} to surface — the liveness timeout should never be the \
         mechanism here (reconnects fail fast)",
        t0.elapsed()
    );
    std::fs::remove_dir_all(&root).ok();
}
