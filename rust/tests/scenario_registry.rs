//! Scenario registry + batched-inference integration tests.
//!
//! Everything here runs WITHOUT AOT artifacts: the surrogate scenario and
//! the native policy backend exercise the full coordinator stack (worker
//! threads, channels, both rollout modes) in milliseconds, which is the
//! point of having them in the registry.

use std::sync::Arc;

use drlfoam::coordinator::{EnvPool, PolicyServer, PoolConfig};
use drlfoam::drl::{NativePolicy, PolicyBackendKind};
use drlfoam::env::scenario::{self, ScenarioContext, SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::io_interface::IoMode;

fn work_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("drlfoam-scen-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn standalone_cfg(tag: &str, n_envs: usize, io_mode: IoMode) -> PoolConfig {
    PoolConfig {
        artifact_dir: "artifacts".into(), // never read by the surrogate
        work_dir: work_dir(tag),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        n_envs,
        io_mode,
        seed: 9,
    }
}

#[test]
fn unknown_scenario_is_a_clean_error() {
    let err = scenario::spec("does-not-exist").unwrap_err().to_string();
    assert!(err.contains("does-not-exist"), "{err}");
    assert!(err.contains("cylinder") && err.contains("surrogate"), "{err}");

    // the pool rejects it up front, in the caller's thread
    let mut cfg = standalone_cfg("unknown", 1, IoMode::InMemory);
    cfg.scenario = "does-not-exist".into();
    assert!(EnvPool::standalone(&cfg).is_err());
}

#[test]
fn cylinder_without_artifacts_says_so() {
    let wd = work_dir("noartifacts");
    let ctx = ScenarioContext {
        artifact_dir: std::path::Path::new("artifacts"),
        work_dir: &wd,
        env_id: 0,
        io_mode: IoMode::InMemory,
        manifest: None,
        variant: "small",
        seed: 0,
    };
    let err = scenario::build("cylinder", &ctx).unwrap_err().to_string();
    assert!(err.contains("artifacts"), "{err}");
}

#[test]
fn surrogate_episode_deterministic_under_seed() {
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(4));
    let run = || {
        let mut pool = EnvPool::standalone(&standalone_cfg("det", 2, IoMode::InMemory)).unwrap();
        let outs = pool.rollout(&params, 6, 3).unwrap();
        outs.into_iter()
            .map(|o| {
                (
                    o.env_id,
                    o.traj
                        .transitions
                        .iter()
                        .map(|t| (t.action, t.reward))
                        .collect::<Vec<_>>(),
                )
            })
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must replay bitwise");
    // envs explore differently from each other
    assert_ne!(a[0].1, a[1].1);
}

#[test]
fn batched_and_per_env_inference_match_bitwise() {
    let net = NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let params = Arc::new(net.init_params(21));
    let horizon = 5;
    let iteration = 2;

    let mut per_env = EnvPool::standalone(&standalone_cfg("perenv", 3, IoMode::InMemory)).unwrap();
    let a = per_env.rollout(&params, horizon, iteration).unwrap();

    let mut batched = EnvPool::standalone(&standalone_cfg("batched", 3, IoMode::InMemory)).unwrap();
    let mut server = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let b = batched
        .rollout_batched(None, &mut server, &params, horizon, iteration)
        .unwrap();

    assert_eq!(a.len(), b.len());
    for (ea, eb) in a.iter().zip(&b) {
        assert_eq!(ea.env_id, eb.env_id);
        assert_eq!(ea.traj.transitions.len(), eb.traj.transitions.len());
        assert_eq!(ea.traj.last_value, eb.traj.last_value, "env {}", ea.env_id);
        for (t, (ta, tb)) in ea
            .traj
            .transitions
            .iter()
            .zip(&eb.traj.transitions)
            .enumerate()
        {
            assert_eq!(ta.action, tb.action, "env {} t {t}", ea.env_id);
            assert_eq!(ta.logp, tb.logp, "env {} t {t}", ea.env_id);
            assert_eq!(ta.reward, tb.reward, "env {} t {t}", ea.env_id);
            assert_eq!(ta.value, tb.value, "env {} t {t}", ea.env_id);
            assert_eq!(ta.obs, tb.obs, "env {} t {t}", ea.env_id);
        }
    }
}

#[test]
fn surrogate_runs_through_file_based_exchange() {
    // the surrogate pushes real bytes through the Optimized interface, so
    // I/O-strategy studies work without a single compiled artifact
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(1));
    let mut pool = EnvPool::standalone(&standalone_cfg("io", 1, IoMode::Optimized)).unwrap();
    let outs = pool.rollout(&params, 4, 0).unwrap();
    let io = &outs[0].stats.io;
    assert!(io.bytes_written > 0, "no bytes written");
    assert!(io.bytes_read > 0, "no bytes read");
    assert!(outs[0].stats.io_s >= 0.0);
}
