//! Scenario registry + batched-inference integration tests.
//!
//! Everything here runs WITHOUT AOT artifacts: the surrogate scenario and
//! the native policy backend exercise the full coordinator stack (worker
//! threads, channels, both rollout modes) in milliseconds, which is the
//! point of having them in the registry.

use std::sync::Arc;

use drlfoam::coordinator::{EnvPool, PolicyServer, PoolConfig};
use drlfoam::drl::{NativePolicy, PolicyBackendKind};
use drlfoam::env::scenario::{self, ScenarioContext, SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::io_interface::IoMode;

fn work_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("drlfoam-scen-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn standalone_cfg(tag: &str, n_envs: usize, io_mode: IoMode) -> PoolConfig {
    PoolConfig {
        artifact_dir: "artifacts".into(), // never read by the surrogate
        work_dir: work_dir(tag),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        n_envs,
        io_mode,
        seed: 9,
        ..PoolConfig::default()
    }
}

#[test]
fn unknown_scenario_is_a_clean_error() {
    let err = scenario::spec("does-not-exist").unwrap_err().to_string();
    assert!(err.contains("does-not-exist"), "{err}");
    assert!(err.contains("cylinder") && err.contains("surrogate"), "{err}");

    // the pool rejects it up front, in the caller's thread
    let mut cfg = standalone_cfg("unknown", 1, IoMode::InMemory);
    cfg.scenario = "does-not-exist".into();
    assert!(EnvPool::standalone(&cfg).is_err());
}

#[test]
fn cylinder_without_artifacts_says_so() {
    let wd = work_dir("noartifacts");
    let ctx = ScenarioContext {
        artifact_dir: std::path::Path::new("artifacts"),
        work_dir: &wd,
        env_id: 0,
        io_mode: IoMode::InMemory,
        manifest: None,
        variant: "small",
        cfd_backend: drlfoam::cfd::CfdBackend::Xla,
        seed: 0,
    };
    let err = scenario::build("cylinder", &ctx).unwrap_err().to_string();
    assert!(err.contains("artifacts"), "{err}");
}

#[test]
fn surrogate_episode_deterministic_under_seed() {
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(4));
    let run = || {
        let mut pool = EnvPool::standalone(&standalone_cfg("det", 2, IoMode::InMemory)).unwrap();
        let outs = pool.rollout(&params, 6, 3).unwrap();
        outs.into_iter()
            .map(|o| {
                (
                    o.env_id,
                    o.traj
                        .transitions
                        .iter()
                        .map(|t| (t.action, t.reward))
                        .collect::<Vec<_>>(),
                )
            })
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must replay bitwise");
    // envs explore differently from each other
    assert_ne!(a[0].1, a[1].1);
}

#[test]
fn batched_and_per_env_inference_match_bitwise() {
    let net = NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let params = Arc::new(net.init_params(21));
    let horizon = 5;
    let iteration = 2;

    let mut per_env = EnvPool::standalone(&standalone_cfg("perenv", 3, IoMode::InMemory)).unwrap();
    let a = per_env.rollout(&params, horizon, iteration).unwrap();

    let mut batched = EnvPool::standalone(&standalone_cfg("batched", 3, IoMode::InMemory)).unwrap();
    let mut server = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let b = batched
        .rollout_batched(None, &mut server, &params, horizon, iteration)
        .unwrap();

    assert_eq!(a.len(), b.len());
    for (ea, eb) in a.iter().zip(&b) {
        assert_eq!(ea.env_id, eb.env_id);
        assert_eq!(ea.traj.transitions.len(), eb.traj.transitions.len());
        assert_eq!(ea.traj.last_value, eb.traj.last_value, "env {}", ea.env_id);
        for (t, (ta, tb)) in ea
            .traj
            .transitions
            .iter()
            .zip(&eb.traj.transitions)
            .enumerate()
        {
            assert_eq!(ta.action, tb.action, "env {} t {t}", ea.env_id);
            assert_eq!(ta.logp, tb.logp, "env {} t {t}", ea.env_id);
            assert_eq!(ta.reward, tb.reward, "env {} t {t}", ea.env_id);
            assert_eq!(ta.value, tb.value, "env {} t {t}", ea.env_id);
            assert_eq!(ta.obs, tb.obs, "env {} t {t}", ea.env_id);
        }
    }
}

#[test]
fn pool_inflight_bookkeeping_and_try_recv() {
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(2));
    let mut pool = EnvPool::standalone(&standalone_cfg("inflight", 2, IoMode::InMemory)).unwrap();
    assert_eq!(pool.in_flight(), 0);
    pool.dispatch(0, &params, 3, 0).unwrap();
    assert!(pool.is_busy(0));
    assert!(!pool.is_busy(1));
    assert_eq!(pool.in_flight(), 1);
    // re-dispatching an env with an episode in flight is a clean error
    assert!(pool.dispatch(0, &params, 3, 1).is_err());
    // the non-blocking receive eventually yields the finished episode
    let out = loop {
        match pool.try_recv_one().unwrap() {
            Some(o) => break o,
            None => std::thread::yield_now(),
        }
    };
    assert_eq!(out.env_id, 0);
    assert_eq!(out.traj.transitions.len(), 3);
    assert_eq!(pool.in_flight(), 0);
    // and the env is re-dispatchable afterwards
    pool.dispatch(0, &params, 3, 1).unwrap();
    let o2 = pool.recv_one().unwrap();
    assert_eq!(o2.env_id, 0);
    assert_eq!(pool.in_flight(), 0);
}

#[test]
fn batched_subset_rollout_matches_full_set_rows() {
    // a subset lockstep rollout must reproduce the same episodes the
    // full-set call produces for those envs (same per-env seed streams)
    let net = NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let params = Arc::new(net.init_params(5));
    let horizon = 4;
    let iteration = 1u64;

    let mut full = EnvPool::standalone(&standalone_cfg("sub-full", 3, IoMode::InMemory)).unwrap();
    let mut server = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let a = full
        .rollout_batched(None, &mut server, &params, horizon, iteration)
        .unwrap();

    let mut part = EnvPool::standalone(&standalone_cfg("sub-part", 3, IoMode::InMemory)).unwrap();
    let mut server2 = PolicyServer::native(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let b = part
        .rollout_batched_subset(None, &mut server2, &params, horizon, &[(2, iteration), (0, iteration)])
        .unwrap();

    assert_eq!(b.len(), 2);
    for out in &b {
        let twin = a.iter().find(|o| o.env_id == out.env_id).unwrap();
        assert_eq!(out.traj.transitions.len(), twin.traj.transitions.len());
        for (x, y) in out.traj.transitions.iter().zip(&twin.traj.transitions) {
            assert_eq!(x.action, y.action);
            assert_eq!(x.reward, y.reward);
            assert_eq!(x.obs, y.obs);
        }
        assert_eq!(out.traj.last_value, twin.traj.last_value);
    }
    // per-env wall times are measured individually (reset-ack to last
    // step-ack), not the one shared coordinator clock the pre-fix code
    // stamped on every env: each env did real work, and two envs' own
    // ack sequences never measure bitwise-identical spans
    assert!(b.iter().all(|o| o.stats.wall_s > 0.0), "per-env wall time not recorded");
    assert_ne!(
        b[0].stats.wall_s, b[1].stats.wall_s,
        "wall_s must be per-env, not one shared clock"
    );
}

#[test]
fn surrogate_runs_through_file_based_exchange() {
    // the surrogate pushes real bytes through the Optimized interface, so
    // I/O-strategy studies work without a single compiled artifact
    let params = Arc::new(NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN).init_params(1));
    let mut pool = EnvPool::standalone(&standalone_cfg("io", 1, IoMode::Optimized)).unwrap();
    let outs = pool.rollout(&params, 4, 0).unwrap();
    let io = &outs[0].stats.io;
    assert!(io.bytes_written > 0, "no bytes written");
    assert!(io.bytes_read > 0, "no bytes read");
    assert!(outs[0].stats.io_s >= 0.0);
}
