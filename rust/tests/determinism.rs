//! Bitwise-determinism pins for the PR-7 audit cleanups.
//!
//! The audit pass (`drlfoam audit`, ARCHITECTURE.md §9) forced two kinds
//! of edits in determinism-critical modules:
//!
//! * wall-clock reads in `coordinator::scheduler` and `drl::trainer` were
//!   routed through `util::clock::telemetry_now()` and allowlisted, and
//! * every bare `.sum()` in `drl::{buffer,native_update}` and the
//!   scheduler gained an explicit, type-identical turbofish.
//!
//! Neither edit may change behaviour. These tests pin that: two training
//! runs with identical configs must agree bitwise on every learning
//! column of `train_log.csv` and on the final policy parameters, and two
//! planner sweeps must emit identical `plan.csv` bytes. If a "refactor"
//! ever slips a wall-clock value or a widened accumulator into a scored
//! path, the double-run comparison here goes red.

use drlfoam::cfd::CfdBackend;
use drlfoam::cluster::planner::{search, PlannerConfig};
use drlfoam::cluster::Calibration;
use drlfoam::coordinator::{train, TrainConfig};
use drlfoam::drl::{PolicyBackendKind, UpdateBackendKind};
use drlfoam::exec::ExecutorKind;
use drlfoam::io_interface::IoMode;

/// The obs plane is process-global (`obs::enable()`), so the
/// traced-vs-untraced twin tests serialize on this lock: a concurrently
/// tracing test would otherwise drain another run's spans into its own
/// trace file. Learning output is unaffected either way — that is the
/// invariant under test — only the trace *contents* need isolation.
static TRACE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn base_cfg(tag: &str) -> TrainConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-det-{tag}-{}", std::process::id()));
    TrainConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        n_envs: 3,
        io_mode: IoMode::InMemory,
        horizon: 5,
        iterations: 3,
        epochs: 2,
        seed: 7,
        log_every: 1,
        quiet: true,
        ..TrainConfig::default()
    }
}

/// The learning-curve columns of train_log.csv: everything before the
/// wall-clock fields (iteration..approx_kl, the first 9 of 14). The
/// telemetry columns are the only place `telemetry_now()` feeds, so they
/// are excluded by construction — exactly the contract the audit
/// allowlist entries for `det-wall-clock` claim.
fn learning_rows(out_dir: &std::path::Path) -> Vec<String> {
    let csv = std::fs::read_to_string(out_dir.join("train_log.csv")).unwrap();
    csv.lines()
        .skip(1)
        .map(|l| l.splitn(15, ',').take(9).collect::<Vec<_>>().join(","))
        .collect()
}

fn run_cfg(cfg: &TrainConfig) -> (Vec<String>, Vec<u8>) {
    train(cfg).unwrap();
    let rows = learning_rows(&cfg.out_dir);
    let params = std::fs::read(cfg.out_dir.join("policy_final.bin")).unwrap();
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
    (rows, params)
}

fn run_once(tag: &str) -> (Vec<String>, Vec<u8>) {
    run_cfg(&base_cfg(tag))
}

#[test]
fn training_is_bitwise_reproducible_across_runs() {
    let (rows_a, params_a) = run_once("a");
    let (rows_b, params_b) = run_once("b");
    assert!(!rows_a.is_empty(), "no learning rows written");
    assert_eq!(rows_a, rows_b, "learning columns diverged between runs");
    assert!(!params_a.is_empty(), "no final parameters written");
    assert_eq!(
        params_a, params_b,
        "policy_final.bin diverged between identical runs"
    );
}

/// The same double-run pin over the pure-Rust CFD engine: a real (tiny)
/// cylinder training run with `--cfd-backend native` — no artifacts
/// anywhere — must agree bitwise on the learning columns and the final
/// parameters. This is the end-to-end face of the engine's bitwise
/// contract (scalar == SIMD == threaded), which rust/tests/cfd_native.rs
/// pins at the kernel level.
#[test]
fn native_cfd_training_is_bitwise_reproducible_across_runs() {
    let cfg = |tag: &str| -> TrainConfig {
        let mut c = base_cfg(&format!("ncfd-{tag}"));
        c.scenario = "cylinder".into();
        c.variant = "tiny".into();
        c.cfd_backend = CfdBackend::Native;
        c.n_envs = 2;
        c.horizon = 3;
        c.iterations = 2;
        c
    };
    let (rows_a, params_a) = run_cfg(&cfg("a"));
    let (rows_b, params_b) = run_cfg(&cfg("b"));
    assert!(!rows_a.is_empty(), "no learning rows written");
    assert_eq!(rows_a, rows_b, "native-cfd learning columns diverged");
    assert_eq!(
        params_a, params_b,
        "native-cfd policy_final.bin diverged between identical runs"
    );
}

/// Like [`run_cfg`], but for a `--trace` run: additionally asserts the
/// three trace artifacts landed (Chrome-trace JSON with at least one
/// complete-event span, the percentile summary, the drift report) before
/// cleaning up.
fn run_traced(cfg: &TrainConfig) -> (Vec<String>, Vec<u8>) {
    train(cfg).unwrap();
    let rows = learning_rows(&cfg.out_dir);
    let params = std::fs::read(cfg.out_dir.join("policy_final.bin")).unwrap();
    let trace_path = cfg.trace.as_ref().unwrap();
    let trace = std::fs::read_to_string(trace_path).unwrap();
    assert!(
        trace.contains("\"traceEvents\"") && trace.contains("\"ph\":\"X\""),
        "trace.json should hold Chrome-trace complete events: {}",
        &trace[..trace.len().min(200)]
    );
    let summary = std::fs::read_to_string(cfg.out_dir.join("obs_summary.csv")).unwrap();
    assert!(summary.lines().count() > 1, "obs_summary.csv is empty");
    let drift = std::fs::read_to_string(cfg.out_dir.join("drift.csv")).unwrap();
    assert!(drift.lines().count() > 1, "drift.csv is empty");
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
    (rows, params)
}

/// The tentpole invariant, in-process lane: a `--trace` run must be
/// bitwise identical — every learning column and the final parameters —
/// to its untraced twin. Tracing reuses the Instants the timing columns
/// already read, so the only way this goes red is a new clock read or a
/// reordered side effect on a scored path.
#[test]
fn tracing_is_bitwise_invisible_in_process() {
    let _g = TRACE_LOCK.lock().unwrap();
    let (rows_plain, params_plain) = run_cfg(&base_cfg("plain-ip"));
    let mut cfg = base_cfg("traced-ip");
    cfg.trace = Some(cfg.out_dir.join("trace.json"));
    cfg.trace_calib = Some(Calibration::paper_scale());
    let (rows_traced, params_traced) = run_traced(&cfg);
    assert!(!rows_plain.is_empty(), "no learning rows written");
    assert_eq!(
        rows_plain, rows_traced,
        "--trace changed the learning columns (in-process)"
    );
    assert_eq!(
        params_plain, params_traced,
        "--trace changed policy_final.bin (in-process)"
    );
}

/// The same twin comparison across real `drlfoam worker` OS processes:
/// workers record spans locally, batch them over `Frame::Telemetry`, and
/// the coordinator clock-shifts them into the merged trace — none of
/// which may perturb the learning output.
#[test]
fn tracing_is_bitwise_invisible_multi_process() {
    let worker_bin: Option<std::path::PathBuf> =
        option_env!("CARGO_BIN_EXE_drlfoam").map(Into::into);
    if worker_bin.is_none() {
        eprintln!("skipping: CARGO_BIN_EXE_drlfoam not provided by cargo");
        return;
    }
    let _g = TRACE_LOCK.lock().unwrap();
    let mp = |tag: &str| -> TrainConfig {
        let mut c = base_cfg(tag);
        c.executor = ExecutorKind::MultiProcess;
        c.worker_bin = worker_bin.clone();
        c.n_envs = 2;
        c.iterations = 2;
        c
    };
    let (rows_plain, params_plain) = run_cfg(&mp("plain-mp"));
    let mut cfg = mp("traced-mp");
    cfg.trace = Some(cfg.out_dir.join("trace.json"));
    cfg.trace_calib = Some(Calibration::paper_scale());
    let (rows_traced, params_traced) = run_traced(&cfg);
    assert!(!rows_plain.is_empty(), "no learning rows written");
    assert_eq!(
        rows_plain, rows_traced,
        "--trace changed the learning columns (multi-process)"
    );
    assert_eq!(
        params_plain, params_traced,
        "--trace changed policy_final.bin (multi-process)"
    );
}

#[test]
fn planner_sweep_is_bitwise_reproducible_across_runs() {
    let calib = Calibration::paper_scale();
    let mut cfg = PlannerConfig::new(20);
    cfg.episodes_total = 120;
    let sweep = |tag: &str| -> String {
        let path = std::env::temp_dir().join(format!(
            "drlfoam-det-plan-{tag}-{}.csv",
            std::process::id()
        ));
        search(&calib, &cfg).unwrap().write_csv(&path).unwrap();
        let bytes = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        bytes
    };
    let a = sweep("a");
    let b = sweep("b");
    assert!(a.lines().count() > 1, "plan.csv has no data rows");
    assert_eq!(a, b, "plan.csv diverged between identical sweeps");
}
