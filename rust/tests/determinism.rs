//! Bitwise-determinism pins for the PR-7 audit cleanups.
//!
//! The audit pass (`drlfoam audit`, ARCHITECTURE.md §9) forced two kinds
//! of edits in determinism-critical modules:
//!
//! * wall-clock reads in `coordinator::scheduler` and `drl::trainer` were
//!   routed through `util::clock::telemetry_now()` and allowlisted, and
//! * every bare `.sum()` in `drl::{buffer,native_update}` and the
//!   scheduler gained an explicit, type-identical turbofish.
//!
//! Neither edit may change behaviour. These tests pin that: two training
//! runs with identical configs must agree bitwise on every learning
//! column of `train_log.csv` and on the final policy parameters, and two
//! planner sweeps must emit identical `plan.csv` bytes. If a "refactor"
//! ever slips a wall-clock value or a widened accumulator into a scored
//! path, the double-run comparison here goes red.

use drlfoam::cfd::CfdBackend;
use drlfoam::cluster::planner::{search, PlannerConfig};
use drlfoam::cluster::Calibration;
use drlfoam::coordinator::{train, TrainConfig};
use drlfoam::drl::{PolicyBackendKind, UpdateBackendKind};
use drlfoam::io_interface::IoMode;

fn base_cfg(tag: &str) -> TrainConfig {
    let root = std::env::temp_dir().join(format!("drlfoam-det-{tag}-{}", std::process::id()));
    TrainConfig {
        artifact_dir: root.join("no-artifacts"),
        work_dir: root.join("work"),
        out_dir: root.clone(),
        variant: "small".into(),
        scenario: "surrogate".into(),
        backend: PolicyBackendKind::Native,
        update_backend: UpdateBackendKind::Native,
        n_envs: 3,
        io_mode: IoMode::InMemory,
        horizon: 5,
        iterations: 3,
        epochs: 2,
        seed: 7,
        log_every: 1,
        quiet: true,
        ..TrainConfig::default()
    }
}

/// The learning-curve columns of train_log.csv: everything before the
/// wall-clock fields (iteration..approx_kl, the first 9 of 14). The
/// telemetry columns are the only place `telemetry_now()` feeds, so they
/// are excluded by construction — exactly the contract the audit
/// allowlist entries for `det-wall-clock` claim.
fn learning_rows(out_dir: &std::path::Path) -> Vec<String> {
    let csv = std::fs::read_to_string(out_dir.join("train_log.csv")).unwrap();
    csv.lines()
        .skip(1)
        .map(|l| l.splitn(15, ',').take(9).collect::<Vec<_>>().join(","))
        .collect()
}

fn run_cfg(cfg: &TrainConfig) -> (Vec<String>, Vec<u8>) {
    train(cfg).unwrap();
    let rows = learning_rows(&cfg.out_dir);
    let params = std::fs::read(cfg.out_dir.join("policy_final.bin")).unwrap();
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
    (rows, params)
}

fn run_once(tag: &str) -> (Vec<String>, Vec<u8>) {
    run_cfg(&base_cfg(tag))
}

#[test]
fn training_is_bitwise_reproducible_across_runs() {
    let (rows_a, params_a) = run_once("a");
    let (rows_b, params_b) = run_once("b");
    assert!(!rows_a.is_empty(), "no learning rows written");
    assert_eq!(rows_a, rows_b, "learning columns diverged between runs");
    assert!(!params_a.is_empty(), "no final parameters written");
    assert_eq!(
        params_a, params_b,
        "policy_final.bin diverged between identical runs"
    );
}

/// The same double-run pin over the pure-Rust CFD engine: a real (tiny)
/// cylinder training run with `--cfd-backend native` — no artifacts
/// anywhere — must agree bitwise on the learning columns and the final
/// parameters. This is the end-to-end face of the engine's bitwise
/// contract (scalar == SIMD == threaded), which rust/tests/cfd_native.rs
/// pins at the kernel level.
#[test]
fn native_cfd_training_is_bitwise_reproducible_across_runs() {
    let cfg = |tag: &str| -> TrainConfig {
        let mut c = base_cfg(&format!("ncfd-{tag}"));
        c.scenario = "cylinder".into();
        c.variant = "tiny".into();
        c.cfd_backend = CfdBackend::Native;
        c.n_envs = 2;
        c.horizon = 3;
        c.iterations = 2;
        c
    };
    let (rows_a, params_a) = run_cfg(&cfg("a"));
    let (rows_b, params_b) = run_cfg(&cfg("b"));
    assert!(!rows_a.is_empty(), "no learning rows written");
    assert_eq!(rows_a, rows_b, "native-cfd learning columns diverged");
    assert_eq!(
        params_a, params_b,
        "native-cfd policy_final.bin diverged between identical runs"
    );
}

#[test]
fn planner_sweep_is_bitwise_reproducible_across_runs() {
    let calib = Calibration::paper_scale();
    let mut cfg = PlannerConfig::new(20);
    cfg.episodes_total = 120;
    let sweep = |tag: &str| -> String {
        let path = std::env::temp_dir().join(format!(
            "drlfoam-det-plan-{tag}-{}.csv",
            std::process::id()
        ));
        search(&calib, &cfg).unwrap().write_csv(&path).unwrap();
        let bytes = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        bytes
    };
    let a = sweep("a");
    let b = sweep("b");
    assert!(a.lines().count() > 1, "plan.csv has no data rows");
    assert_eq!(a, b, "plan.csv diverged between identical sweeps");
}
