//! # drlfoam-rs
//!
//! Rust + JAX + Pallas reproduction of *"Optimal Parallelization Strategies
//! for Active Flow Control in Deep Reinforcement Learning-Based
//! Computational Fluid Dynamics"* (Jia & Xu, 2024).
//!
//! Three layers (see DESIGN.md):
//! * **L1** Pallas kernels (red-black SOR, advection-diffusion stencil, MXU
//!   dense) — `python/compile/kernels/`, build-time only.
//! * **L2** JAX CFD solver + PPO — `python/compile/{cfd,model}.py`, lowered
//!   once to HLO-text artifacts by `python/compile/aot.py`.
//! * **L3** this crate: PJRT runtime, the scenario registry of
//!   environments (cylinder CFD at two Reynolds numbers + an analytic
//!   surrogate), PPO trainer, multi-environment coordinator with per-env
//!   or central batched policy inference, the execution backends that
//!   realise a layout as OS threads or real `drlfoam worker` processes
//!   (rust/src/exec), the three CFD<->DRL exchange interfaces, the
//!   cluster discrete-event simulator that regenerates the paper's
//!   tables/figures, the allocation planner that searches the hybrid
//!   (envs x ranks x sync x io) layout space over it, and the CLI.
//!
//! README.md covers the quickstart; ARCHITECTURE.md maps every module to
//! the paper section it implements.

// Every unsafe operation must sit in an explicit `unsafe` block even
// inside `unsafe fn`, so each one is individually visible to the
// `drlfoam audit` SAFETY-comment rule (ARCHITECTURE.md §9).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod audit;
pub mod cfd;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod drl;
pub mod env;
pub mod exec;
pub mod io_interface;
pub mod metrics;
pub mod obs;
pub mod reproduce;
pub mod runtime;
pub mod util;
pub mod viz;
