//! Scenario registry: named, selectable workloads behind one [`Environment`]
//! trait.
//!
//! The paper's framework couples *one* CFD problem (the confined cylinder at
//! Re=100) to the DRL stack; the registry generalises that coupling so the
//! coordinator, benches and tests are scenario-agnostic:
//!
//! * `cylinder`        — the baseline AFC problem ([`CfdEnv`] on the manifest
//!                       variant selected by `--variant`, Re=100).
//! * `cylinder-re200`  — the same geometry AOT-compiled at Re=200 (manifest
//!                       variant `re200`, built by `make artifacts` after the
//!                       configs.py addition); stronger shedding, same MDP.
//! * `surrogate`       — a closed-form vortex-shedding surrogate with the
//!                       same observation/action/reward interface but no XLA
//!                       executable at all, so multi-environment scaling
//!                       studies and CI run in microseconds per period.
//!
//! Environments are built *inside* worker threads (PJRT clients are not
//! Send), so the trait does not require `Send`; what crosses threads is only
//! the scenario *name* plus the [`ScenarioContext`] ingredients.

use std::path::Path;

use anyhow::{Context, Result};

use crate::cfd::{self, CfdBackend, NativeEngine, N_PROBES};
use crate::env::{CfdEngineRef, CfdEnv, StepResult, StepTimings};
use crate::io_interface::{
    make_interface, CfdOutput, ExchangeInterface, FlowSnapshot, IoMode,
};
use crate::runtime::{Manifest, Runtime, VariantManifest};
use crate::util::clock::telemetry_now;
use crate::util::rng::Rng;

/// One selectable workload seen as an MDP: reset to a start state, then
/// advance one actuation period per [`Environment::step`] call.
///
/// Implementations own everything the episode loop needs (flow state,
/// compiled executables, exchange interface), so the coordinator drives
/// every scenario through this one interface.
pub trait Environment {
    /// Registry name of the scenario this environment instantiates.
    fn scenario(&self) -> &str;

    /// Observation vector length (must match the policy input width).
    fn n_obs(&self) -> usize;

    /// Reset to the developed start state; returns the initial observation.
    fn reset(&mut self) -> Result<Vec<f32>>;

    /// Apply one raw policy action for one actuation period.
    fn step(&mut self, action: f64) -> Result<StepResult>;

    /// The PJRT runtime backing this environment, when the scenario is
    /// XLA-based. Per-env policy serving compiles into the same client so
    /// a worker never needs a second runtime; `None` for analytic
    /// scenarios (pair those with the native policy backend).
    fn runtime_mut(&mut self) -> Option<&mut Runtime> {
        None
    }
}

/// How a scenario is realised.
#[derive(Clone, Copy, Debug)]
pub enum ScenarioKind {
    /// AOT-compiled cylinder-flow CFD ([`CfdEnv`]); `variant` pins a
    /// manifest variant, `None` defers to the caller's `--variant`.
    Cylinder {
        variant: Option<&'static str>,
        re: f64,
    },
    /// Closed-form vortex-shedding surrogate ([`SurrogateEnv`]); needs no
    /// artifacts and no XLA runtime.
    Surrogate,
}

/// Registry entry: a name the CLI/config can select plus how to build it.
#[derive(Clone, Copy, Debug)]
pub struct ScenarioSpec {
    pub name: &'static str,
    pub summary: &'static str,
    pub kind: ScenarioKind,
}

/// All selectable scenarios, in display order.
pub const SCENARIOS: &[ScenarioSpec] = &[
    ScenarioSpec {
        name: "cylinder",
        summary: "confined-cylinder AFC at Re=100 (paper baseline; uses --variant)",
        kind: ScenarioKind::Cylinder {
            variant: None,
            re: 100.0,
        },
    },
    ScenarioSpec {
        name: "cylinder-re200",
        summary: "cylinder AFC at Re=200 (manifest variant `re200`; stronger shedding)",
        kind: ScenarioKind::Cylinder {
            variant: Some("re200"),
            re: 200.0,
        },
    },
    ScenarioSpec {
        name: "surrogate",
        summary: "closed-form vortex-shedding surrogate (no XLA; CI/scaling studies)",
        kind: ScenarioKind::Surrogate,
    },
];

/// Look up a scenario by name; unknown names list what is available.
/// `analytic` is an accepted alias for `surrogate` (the registry entry
/// describes itself as the *analytic* surrogate, and docs/CLI examples
/// use both spellings).
pub fn spec(name: &str) -> Result<&'static ScenarioSpec> {
    let canonical = match name {
        "analytic" => "surrogate",
        n => n,
    };
    SCENARIOS
        .iter()
        .find(|s| s.name == canonical)
        .with_context(|| {
            let known: Vec<&str> = SCENARIOS.iter().map(|s| s.name).collect();
            format!("unknown scenario {name:?} (available: {})", known.join(", "))
        })
}

/// Policy dimensions `(n_obs, hidden)` for `scenario_name` under
/// `cfd_backend` — the one sizing dispatch point shared by the
/// coordinator, the pool and the workers, so the policy width cannot
/// drift between them. The native cylinder path is always
/// ([`N_PROBES`], [`cfd::NATIVE_HIDDEN`]) — [`build`] ignores the
/// manifest there, so sizing must too; otherwise the manifest sizes the
/// policy when present, and the artifact-free surrogate defaults apply.
pub fn policy_dims(
    scenario_name: &str,
    cfd_backend: CfdBackend,
    manifest: Option<&Manifest>,
) -> (usize, usize) {
    let cylinder = spec(scenario_name)
        .map(|sp| matches!(sp.kind, ScenarioKind::Cylinder { .. }))
        .unwrap_or(false);
    if cylinder && cfd_backend == CfdBackend::Native {
        return (N_PROBES, cfd::NATIVE_HIDDEN);
    }
    match manifest {
        Some(m) => (m.drl.n_obs, m.drl.hidden),
        None => (SURROGATE_N_OBS, SURROGATE_HIDDEN),
    }
}

/// Everything a worker thread needs to build its environment instance.
pub struct ScenarioContext<'a> {
    pub artifact_dir: &'a Path,
    pub work_dir: &'a Path,
    pub env_id: usize,
    pub io_mode: IoMode,
    /// Required for cylinder scenarios on the XLA backend; the surrogate
    /// uses it only to match `n_obs` to the compiled policy width when
    /// present, and the native CFD backend ignores it entirely.
    pub manifest: Option<&'a Manifest>,
    /// Manifest variant used when the scenario does not pin one.
    pub variant: &'a str,
    /// Which engine runs the cylinder CFD period (`--cfd-backend`).
    pub cfd_backend: CfdBackend,
    pub seed: u64,
}

/// Build a ready-to-reset environment for `name` under `ctx`.
pub fn build(name: &str, ctx: &ScenarioContext) -> Result<Box<dyn Environment>> {
    let sp = spec(name)?;
    match sp.kind {
        ScenarioKind::Cylinder { variant, .. } => {
            let vname = variant.unwrap_or(ctx.variant);
            let exchange = make_interface(ctx.io_mode, ctx.work_dir, ctx.env_id)?;
            match ctx.cfd_backend {
                CfdBackend::Xla => {
                    let manifest = ctx.manifest.with_context(|| {
                        format!(
                            "scenario {:?} needs AOT artifacts (run `make artifacts`, \
                             or use --cfd-backend native)",
                            sp.name
                        )
                    })?;
                    let vm = manifest
                        .variant(vname)
                        .with_context(|| format!("building scenario {:?}", sp.name))?
                        .clone();
                    let mut rt = Runtime::new(ctx.artifact_dir)?;
                    rt.load(&vm.cfd_period_file)?;
                    let cfd_file = vm.cfd_period_file.clone();
                    let inner = CfdEnv::new(
                        vm,
                        manifest.load_state0(vname)?,
                        manifest.drl.action_smoothing_beta,
                        manifest.drl.reward_lift_penalty,
                        exchange,
                    );
                    Ok(Box::new(CylinderEnv {
                        backend: CylinderBackend::Xla { rt, cfd_file },
                        inner,
                        name: sp.name,
                        n_obs: manifest.drl.n_obs,
                    }))
                }
                CfdBackend::Native => {
                    // Artifact-free: the manifest (if any) is ignored so
                    // behaviour is uniform with and without artifacts; the
                    // base flow is developed in-process (cached per
                    // variant) and stands in for the baked statistics.
                    let spec = cfd::variant(vname)
                        .with_context(|| format!("building scenario {:?}", sp.name))?;
                    let mut engine = NativeEngine::from_env(spec);
                    let bf = engine.cached_base_flow();
                    let vm = native_manifest(engine.spec(), &bf);
                    let inner = CfdEnv::new(
                        vm,
                        (bf.u.clone(), bf.v.clone(), bf.p.clone()),
                        cfd::NATIVE_ACTION_BETA as f64,
                        cfd::NATIVE_LIFT_PENALTY as f64,
                        exchange,
                    );
                    Ok(Box::new(CylinderEnv {
                        backend: CylinderBackend::Native(engine),
                        inner,
                        name: sp.name,
                        n_obs: N_PROBES,
                    }))
                }
            }
        }
        ScenarioKind::Surrogate => {
            // match the compiled policy width when artifacts are present,
            // so the same parameter vector serves real and surrogate
            // scenarios; standalone runs use the native defaults
            let cfg = SurrogateConfig {
                n_obs: ctx.manifest.map_or(SURROGATE_N_OBS, |m| m.drl.n_obs),
                ..SurrogateConfig::default()
            };
            let exchange = make_interface(ctx.io_mode, ctx.work_dir, ctx.env_id)?;
            Ok(Box::new(SurrogateEnv::new(cfg, ctx.seed, sp.name, exchange)))
        }
    }
}

// ---------------------------------------------------------------------------
// Cylinder scenarios: CfdEnv + its own PJRT runtime behind the trait
// ---------------------------------------------------------------------------

/// Synthesize the manifest entry the native engine would otherwise read
/// from `artifacts/manifest.json`: grid constants from the [`cfd::GridSpec`],
/// reward baseline + probe statistics from the developed base flow.
fn native_manifest(spec: &cfd::GridSpec, bf: &cfd::BaseFlow) -> VariantManifest {
    VariantManifest {
        name: spec.name.clone(),
        cfd_period_file: String::new(),
        state0_file: String::new(),
        ny: spec.ny,
        nx: spec.nx(),
        h: spec.h(),
        dt: spec.dt,
        substeps: spec.substeps,
        period: spec.period(),
        re: spec.re,
        n_sweeps: spec.n_sweeps,
        jet_max: spec.jet_max,
        cd0: bf.cd0,
        cl0_amplitude: bf.cl0_amplitude,
        probe_mean: bf.probe_mean.clone(),
        probe_std: bf.probe_std.clone(),
    }
}

/// The engine behind a [`CylinderEnv`]: a PJRT runtime owning the
/// compiled `cfd_period`, or the pure-Rust engine.
enum CylinderBackend {
    Xla { rt: Runtime, cfd_file: String },
    Native(NativeEngine),
}

/// [`CfdEnv`] plus the engine that advances it, packaged as one
/// [`Environment`].
pub struct CylinderEnv {
    backend: CylinderBackend,
    inner: CfdEnv,
    name: &'static str,
    n_obs: usize,
}

impl Environment for CylinderEnv {
    fn scenario(&self) -> &str {
        self.name
    }

    fn n_obs(&self) -> usize {
        self.n_obs
    }

    fn reset(&mut self) -> Result<Vec<f32>> {
        match &mut self.backend {
            CylinderBackend::Xla { rt, cfd_file } => {
                self.inner.reset(CfdEngineRef::Xla(rt.get(cfd_file)?))
            }
            CylinderBackend::Native(engine) => self.inner.reset(CfdEngineRef::Native(engine)),
        }
    }

    fn step(&mut self, action: f64) -> Result<StepResult> {
        match &mut self.backend {
            CylinderBackend::Xla { rt, cfd_file } => {
                self.inner.step(CfdEngineRef::Xla(rt.get(cfd_file)?), action)
            }
            CylinderBackend::Native(engine) => {
                self.inner.step(CfdEngineRef::Native(engine), action)
            }
        }
    }

    fn runtime_mut(&mut self) -> Option<&mut Runtime> {
        match &mut self.backend {
            CylinderBackend::Xla { rt, .. } => Some(rt),
            CylinderBackend::Native(_) => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Analytic surrogate: closed-form vortex shedding, no XLA
// ---------------------------------------------------------------------------

/// Observation width of the surrogate when no manifest pins one.
pub const SURROGATE_N_OBS: usize = 32;
/// Hidden width the standalone (artifact-free) policy pairs with it.
pub const SURROGATE_HIDDEN: usize = 32;

/// Tunables of the closed-form shedding model (defaults give a reward
/// surface qualitatively like the paper's Eq. 12: zero for the uncontrolled
/// flow, positive when the jet suppresses the wake).
#[derive(Clone, Debug)]
pub struct SurrogateConfig {
    pub n_obs: usize,
    /// force-history samples per actuation period (CfdEnv: CFD substeps)
    pub substeps: usize,
    /// shedding phase advance per actuation period (rad)
    pub dphase: f64,
    /// Eq. (11) action smoothing factor
    pub beta: f64,
    pub jet_max: f64,
    /// omega in Eq. (12)
    pub lift_penalty: f64,
    /// drag floor with the wake fully suppressed
    pub cd_base: f64,
    /// extra drag carried by the developed wake (amp = 1)
    pub cd_shed: f64,
    /// actuation cost: drag added per unit jet^2
    pub cd_jet: f64,
    /// lift oscillation amplitude of the developed wake
    pub cl0: f64,
    /// wake suppression per unit |jet|
    pub suppression: f64,
    /// wake-envelope relaxation per period towards its target
    pub relax: f64,
    /// synthetic flow-snapshot dims fed to file-based exchange interfaces
    pub ny: usize,
    pub nx: usize,
}

impl Default for SurrogateConfig {
    fn default() -> Self {
        SurrogateConfig {
            n_obs: SURROGATE_N_OBS,
            substeps: 8,
            dphase: 0.62,
            beta: 0.4,
            jet_max: 1.5,
            lift_penalty: 0.1,
            cd_base: 2.9,
            cd_shed: 0.3,
            cd_jet: 0.05,
            cl0: 1.0,
            suppression: 0.8,
            relax: 0.15,
            ny: 12,
            nx: 24,
        }
    }
}

/// Closed-form vortex-shedding environment.
///
/// State is (shedding phase, wake-envelope amplitude, smoothed jet); one
/// `step` advances the phase by `dphase`, relaxes the envelope towards
/// `1 - suppression*|jet|`, and emits probe/force signals that are pure
/// trigonometric functions of that state. The data still travels through
/// the configured [`ExchangeInterface`], so I/O-strategy studies run on the
/// surrogate at full fidelity — only the CFD solve is replaced.
///
/// Fully deterministic for a fixed construction seed (the seed only draws
/// the probe phases/gains, i.e. the virtual probe placement).
pub struct SurrogateEnv {
    cfg: SurrogateConfig,
    name: &'static str,
    phase: f64,
    amp: f64,
    jet: f64,
    step_idx: usize,
    probe_phase: Vec<f64>,
    probe_gain: Vec<f64>,
    needs_host_flow: bool,
    exchange: Box<dyn ExchangeInterface>,
}

impl SurrogateEnv {
    pub fn new(
        cfg: SurrogateConfig,
        seed: u64,
        name: &'static str,
        exchange: Box<dyn ExchangeInterface>,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let probe_phase: Vec<f64> = (0..cfg.n_obs)
            .map(|_| rng.range(0.0, 2.0 * std::f64::consts::PI))
            .collect();
        let probe_gain: Vec<f64> = (0..cfg.n_obs).map(|_| 0.5 + rng.uniform()).collect();
        let needs_host_flow = exchange.mode() != IoMode::InMemory;
        SurrogateEnv {
            cfg,
            name,
            phase: 0.0,
            amp: 1.0,
            jet: 0.0,
            step_idx: 0,
            probe_phase,
            probe_gain,
            needs_host_flow,
            exchange,
        }
    }

    /// Uncontrolled mean drag (amp = 1, jet = 0): the Eq. 12 reference.
    pub fn cd0(&self) -> f64 {
        self.cfg.cd_base + self.cfg.cd_shed
    }

    fn advance(&mut self, jet: f64) -> Result<StepResult> {
        let c = &self.cfg;

        // DRL -> CFD through the exchange interface, like CfdEnv
        let t_io0 = telemetry_now();
        let (jet_parsed, io_inject) = self.exchange.inject_action(self.step_idx, jet)?;
        let io_inject_s = t_io0.elapsed().as_secs_f64();

        // closed-form "solve" for one actuation period
        let t0 = telemetry_now();
        let target = (1.0 - c.suppression * jet_parsed.abs()).max(0.0);
        self.amp += c.relax * (target - self.amp);
        self.amp = self.amp.clamp(0.0, 1.2);
        let mut cd_hist = Vec::with_capacity(c.substeps);
        let mut cl_hist = Vec::with_capacity(c.substeps);
        for k in 0..c.substeps {
            let ph = self.phase + c.dphase * (k + 1) as f64 / c.substeps as f64;
            cd_hist.push((c.cd_base + c.cd_shed * self.amp * self.amp
                + c.cd_jet * jet_parsed * jet_parsed) as f32);
            cl_hist.push((c.cl0 * self.amp * ph.sin()) as f32);
        }
        self.phase += c.dphase;
        let probes: Vec<f32> = (0..c.n_obs)
            .map(|i| {
                (self.amp * (self.phase + self.probe_phase[i]).sin() * self.probe_gain[i]
                    + 0.1 * jet_parsed * self.probe_phase[i].cos()) as f32
            })
            .collect();
        let cfd_s = t0.elapsed().as_secs_f64();
        crate::obs::record_measured_here(crate::obs::Phase::Cfd, t0, cfd_s);

        // CFD -> DRL through the exchange interface
        let t1 = telemetry_now();
        let out = CfdOutput {
            probes,
            cd_hist,
            cl_hist,
        };
        let (u, v, p);
        let empty: &[f32] = &[];
        let flow = if self.needs_host_flow {
            // synthetic travelling vortex street so file-based exchanges
            // move a physically-shaped payload
            let n = c.ny * c.nx;
            let mut fu = Vec::with_capacity(n);
            let mut fv = Vec::with_capacity(n);
            let mut fp = Vec::with_capacity(n);
            for y in 0..c.ny {
                for x in 0..c.nx {
                    let kx = 2.0 * std::f64::consts::PI * x as f64 / c.nx as f64;
                    let ky = std::f64::consts::PI * y as f64 / c.ny as f64;
                    fu.push((1.0 + self.amp * (kx - self.phase).sin() * ky.cos()) as f32);
                    fv.push((self.amp * (kx - self.phase).cos() * ky.sin()) as f32);
                    fp.push((-self.amp * (kx - self.phase).cos() * ky.cos()) as f32);
                }
            }
            u = fu;
            v = fv;
            p = fp;
            FlowSnapshot {
                u: &u,
                v: &v,
                p: &p,
                ny: c.ny,
                nx: c.nx,
            }
        } else {
            FlowSnapshot {
                u: empty,
                v: empty,
                p: empty,
                ny: c.ny,
                nx: c.nx,
            }
        };
        let (parsed, mut io) = self.exchange.exchange(self.step_idx, &out, &flow)?;
        io.accumulate(&io_inject);
        let io_s = t1.elapsed().as_secs_f64() + io_inject_s;
        crate::obs::record_measured_here(crate::obs::Phase::Io, t_io0, io_s);

        let cd_mean = mean(&parsed.cd_hist);
        let cl_mean = mean(&parsed.cl_hist);
        // Eq. (12) with cd0 = cd_base + cd_shed
        let reward = self.cd0() - cd_mean - c.lift_penalty * cl_mean.abs();
        self.step_idx += 1;

        Ok(StepResult {
            obs: parsed.probes,
            reward,
            cd_mean,
            cl_mean,
            jet,
            timings: StepTimings { cfd_s, io_s },
            io,
        })
    }
}

impl Environment for SurrogateEnv {
    fn scenario(&self) -> &str {
        self.name
    }

    fn n_obs(&self) -> usize {
        self.cfg.n_obs
    }

    fn reset(&mut self) -> Result<Vec<f32>> {
        self.phase = 0.0;
        self.amp = 1.0;
        self.jet = 0.0;
        self.step_idx = 0;
        // one uncontrolled period for a consistent observation, like CfdEnv
        let r = self.advance(0.0)?;
        Ok(r.obs)
    }

    fn step(&mut self, action: f64) -> Result<StepResult> {
        // Eq. (11) smoothing, identical to CfdEnv::step
        let jet_target = self.jet + self.cfg.beta * (action - self.jet);
        let jet = jet_target.clamp(-self.cfg.jet_max, self.cfg.jet_max);
        self.jet = jet;
        self.advance(jet)
    }
}

fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io_interface::memory::InMemory;

    fn mk_surrogate(seed: u64) -> SurrogateEnv {
        SurrogateEnv::new(
            SurrogateConfig::default(),
            seed,
            "surrogate",
            Box::new(InMemory::new()),
        )
    }

    #[test]
    fn registry_has_three_scenarios() {
        assert!(SCENARIOS.len() >= 3);
        for s in SCENARIOS {
            assert!(spec(s.name).is_ok());
        }
    }

    #[test]
    fn analytic_is_an_alias_for_surrogate() {
        assert_eq!(spec("analytic").unwrap().name, "surrogate");
    }

    #[test]
    fn unknown_scenario_lists_available() {
        let err = spec("warp-drive").unwrap_err().to_string();
        assert!(err.contains("cylinder"), "{err}");
        assert!(err.contains("surrogate"), "{err}");
    }

    #[test]
    fn surrogate_uncontrolled_reward_near_zero() {
        let mut e = mk_surrogate(0);
        e.reset().unwrap();
        // amp stays at 1 with jet = 0, so cd == cd0 and r == -omega|cl|
        let sr = e.step(0.0).unwrap();
        assert!(sr.reward <= 1e-9, "r = {}", sr.reward);
        assert!(sr.reward > -0.2, "r = {}", sr.reward);
    }

    #[test]
    fn surrogate_jet_suppresses_wake() {
        let mut e = mk_surrogate(0);
        e.reset().unwrap();
        let mut last_cd = f64::MAX;
        for _ in 0..40 {
            let sr = e.step(1.0).unwrap();
            last_cd = sr.cd_mean;
        }
        assert!(last_cd < e.cd0(), "cd {last_cd} vs cd0 {}", e.cd0());
    }

    #[test]
    fn surrogate_deterministic_under_seed() {
        let mut a = mk_surrogate(42);
        let mut b = mk_surrogate(42);
        let oa = a.reset().unwrap();
        let ob = b.reset().unwrap();
        assert_eq!(oa, ob);
        for t in 0..20 {
            let action = (t as f64 * 0.37).sin();
            let ra = a.step(action).unwrap();
            let rb = b.step(action).unwrap();
            assert_eq!(ra.obs, rb.obs, "t={t}");
            assert_eq!(ra.reward, rb.reward, "t={t}");
        }
        // a different seed places the probes elsewhere
        let mut c = mk_surrogate(43);
        let oc = c.reset().unwrap();
        assert_ne!(oa, oc);
    }
}
