//! The AFC environments: CFD and surrogate workloads seen as MDPs.
//!
//! [`CfdEnv`] owns the flow state between actuation periods, invokes the
//! CFD engine behind [`CfdEngineRef`] — either the AOT-compiled
//! `cfd_period` executable (L2/L1) or the pure-Rust [`crate::cfd`] engine
//! (`--cfd-backend native`, artifact-free) — applies the paper's action
//! smoothing (Eq. 11) and reward (Eq. 12), normalises probe observations,
//! and pushes every period's outputs through the configured exchange
//! interface so the I/O cost of the coupled framework is physically
//! incurred and measured.
//!
//! [`scenario`] generalises this into a registry of named workloads behind
//! the [`Environment`] trait (cylinder at two Reynolds numbers plus an
//! analytic surrogate), which is what the coordinator drives.

pub mod scenario;

pub use scenario::{
    build as build_scenario, policy_dims, spec as scenario_spec, CylinderEnv, Environment,
    ScenarioContext, ScenarioKind, ScenarioSpec, SurrogateConfig, SurrogateEnv, SCENARIOS,
    SURROGATE_HIDDEN, SURROGATE_N_OBS,
};

use anyhow::Result;

use crate::cfd::NativeEngine;
use crate::io_interface::{CfdOutput, ExchangeInterface, FlowSnapshot};
use crate::runtime::{literal_f32, scalar_f32, to_vec_f32, Executable, VariantManifest};
use crate::util::clock::telemetry_now;

/// Which engine runs one actuation period for [`CfdEnv`]. Borrowed per
/// call (not owned) because PJRT executables live in the worker's
/// [`crate::runtime::Runtime`] while the native engine is plain state the
/// caller owns; either way the env itself stays engine-agnostic.
pub enum CfdEngineRef<'a> {
    /// AOT-compiled `cfd_period_<variant>` (requires `make artifacts`).
    Xla(&'a Executable),
    /// Pure-Rust engine (`--cfd-backend native`), artifact-free.
    Native(&'a mut NativeEngine),
}

/// Per-step wall-clock breakdown (feeds Fig 10 and the DES calibration).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepTimings {
    pub cfd_s: f64,
    pub io_s: f64,
}

/// What the agent sees after one actuation period.
#[derive(Clone, Debug, PartialEq)]
pub struct StepResult {
    pub obs: Vec<f32>,
    pub reward: f64,
    pub cd_mean: f64,
    pub cl_mean: f64,
    pub jet: f64,
    pub timings: StepTimings,
    pub io: crate::io_interface::IoStats,
}

/// Flow state between periods: kept as XLA literals on the hot path (the
/// cfd_period outputs are fed straight back as the next inputs, saving
/// ~3.8 MB of host memcpy per period — see EXPERIMENTS.md section Perf);
/// host vectors are materialised lazily only when an exchange interface
/// or caller needs to look at the raw fields.
struct FlowState {
    lits: Option<[xla::Literal; 3]>,
    host: Option<(Vec<f32>, Vec<f32>, Vec<f32>)>,
}

impl FlowState {
    fn from_host(u: Vec<f32>, v: Vec<f32>, p: Vec<f32>) -> Self {
        FlowState {
            lits: None,
            host: Some((u, v, p)),
        }
    }

    fn from_lits(u: xla::Literal, v: xla::Literal, p: xla::Literal) -> Self {
        FlowState {
            lits: Some([u, v, p]),
            host: None,
        }
    }

    /// Literal views for the next cfd_period invocation.
    fn as_literals(&mut self, dims: &[i64]) -> Result<&[xla::Literal; 3]> {
        if self.lits.is_none() {
            let (u, v, p) = self.host.as_ref().expect("empty FlowState");
            self.lits = Some([
                literal_f32(u, dims)?,
                literal_f32(v, dims)?,
                literal_f32(p, dims)?,
            ]);
        }
        Ok(self.lits.as_ref().unwrap())
    }

    /// Host views (materialised on demand).
    fn as_host(&mut self) -> Result<&(Vec<f32>, Vec<f32>, Vec<f32>)> {
        if self.host.is_none() {
            let l = self.lits.as_ref().expect("empty FlowState");
            self.host = Some((to_vec_f32(&l[0])?, to_vec_f32(&l[1])?, to_vec_f32(&l[2])?));
        }
        Ok(self.host.as_ref().unwrap())
    }

    /// Mutable host views for in-place native advancement. Any cached
    /// literals are dropped — they would go stale the moment the caller
    /// writes.
    fn as_host_mut(&mut self) -> Result<&mut (Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.as_host()?;
        self.lits = None;
        Ok(self.host.as_mut().unwrap())
    }
}

pub struct CfdEnv {
    pub variant: VariantManifest,
    flow: FlowState,
    state0: (Vec<f32>, Vec<f32>, Vec<f32>),
    jet: f64,
    step_idx: usize,
    beta: f64,
    lift_penalty: f64,
    /// file-based exchanges need host flow snapshots every period
    needs_host_flow: bool,
    exchange: Box<dyn ExchangeInterface>,
}

impl CfdEnv {
    pub fn new(
        variant: VariantManifest,
        state0: (Vec<f32>, Vec<f32>, Vec<f32>),
        beta: f64,
        lift_penalty: f64,
        exchange: Box<dyn ExchangeInterface>,
    ) -> Self {
        let needs_host_flow = exchange.mode() != crate::io_interface::IoMode::InMemory;
        CfdEnv {
            flow: FlowState::from_host(
                state0.0.clone(),
                state0.1.clone(),
                state0.2.clone(),
            ),
            state0,
            jet: 0.0,
            step_idx: 0,
            beta,
            lift_penalty,
            needs_host_flow,
            variant,
            exchange,
        }
    }

    /// Reset to the developed base flow; returns the initial observation.
    pub fn reset(&mut self, cfd: CfdEngineRef) -> Result<Vec<f32>> {
        self.flow = FlowState::from_host(
            self.state0.0.clone(),
            self.state0.1.clone(),
            self.state0.2.clone(),
        );
        self.jet = 0.0;
        self.step_idx = 0;
        // one uncontrolled period to produce a consistent observation
        let r = self.advance(cfd, 0.0)?;
        Ok(r.obs)
    }

    /// Apply the *raw policy action* for one actuation period.
    ///
    /// Eq. (11): V_{T_i} = V_{T_{i-1}} + beta (a - V_{T_{i-1}}), then the
    /// jet amplitude is capped at jet_max (paper: V_jet <= U_m).
    pub fn step(&mut self, cfd: CfdEngineRef, action: f64) -> Result<StepResult> {
        let jet_target = self.jet + self.beta * (action - self.jet);
        let jet = jet_target.clamp(-self.variant.jet_max, self.variant.jet_max);
        self.jet = jet;
        self.advance(cfd, jet)
    }

    fn advance(&mut self, cfd: CfdEngineRef, jet: f64) -> Result<StepResult> {
        let v = &self.variant;
        let dims = [v.ny as i64, v.nx as i64];

        // DRL -> CFD: the action travels through the exchange interface
        // (regex into a config dict for the baseline mode), and the solver
        // uses the value as parsed back.
        let t_io0 = telemetry_now();
        let (jet_parsed, io_inject) = self.exchange.inject_action(self.step_idx, jet)?;
        let io_inject_s = t_io0.elapsed().as_secs_f64();

        let t0 = telemetry_now();
        let (probes, cd_hist, cl_hist) = match cfd {
            CfdEngineRef::Xla(cfd_period) => {
                let state = self.flow.as_literals(&dims)?;
                let args = [
                    state[0].clone(),
                    state[1].clone(),
                    state[2].clone(),
                    scalar_f32(jet_parsed as f32),
                ];
                let mut outs = cfd_period.run(&args)?;
                anyhow::ensure!(outs.len() == 6, "cfd_period returned {} outputs", outs.len());
                let cl_hist = to_vec_f32(&outs[5])?;
                let cd_hist = to_vec_f32(&outs[4])?;
                let probes = to_vec_f32(&outs[3])?;
                // feed the output literals straight back as the next state
                let p_lit = outs.remove(2);
                let v_lit = outs.remove(1);
                let u_lit = outs.remove(0);
                self.flow = FlowState::from_lits(u_lit, v_lit, p_lit);
                (probes, cd_hist, cl_hist)
            }
            CfdEngineRef::Native(engine) => {
                // in place on the host-resident fields — the native engine
                // has no device/host boundary to pay for
                let (u, vv, p) = self.flow.as_host_mut()?;
                let out = engine.period(u, vv, p, jet_parsed as f32);
                (out.probes, out.cd_hist, out.cl_hist)
            }
        };
        let cfd_s = t0.elapsed().as_secs_f64();
        crate::obs::record_measured_here(crate::obs::Phase::Cfd, t0, cfd_s);

        // CFD -> DRL: outputs travel through the exchange interface; the
        // agent consumes the parsed-back copy.
        let t1 = telemetry_now();
        let out = CfdOutput {
            probes,
            cd_hist,
            cl_hist,
        };
        let empty: &[f32] = &[];
        let host = if self.needs_host_flow {
            Some(self.flow.as_host()?)
        } else {
            None
        };
        let flow = match host {
            Some((u, vv, p)) => FlowSnapshot {
                u,
                v: vv,
                p,
                ny: v.ny,
                nx: v.nx,
            },
            None => FlowSnapshot {
                u: empty,
                v: empty,
                p: empty,
                ny: v.ny,
                nx: v.nx,
            },
        };
        let (parsed, mut io) = self.exchange.exchange(self.step_idx, &out, &flow)?;
        io.accumulate(&io_inject);
        let io_s = t1.elapsed().as_secs_f64() + io_inject_s;
        crate::obs::record_measured_here(crate::obs::Phase::Io, t_io0, io_s);

        let cd_mean = mean(&parsed.cd_hist);
        let cl_mean = mean(&parsed.cl_hist);
        // Eq. (12): r = C_D0 - <C_D> - omega |<C_L>|
        let reward = v.cd0 - cd_mean - self.lift_penalty * cl_mean.abs();

        let obs = normalise(&parsed.probes, &v.probe_mean, &v.probe_std);
        self.step_idx += 1;

        Ok(StepResult {
            obs,
            reward,
            cd_mean,
            cl_mean,
            jet,
            timings: StepTimings { cfd_s, io_s },
            io,
        })
    }

    /// Host view of the current flow (materialises from device literals
    /// if the hot path kept them resident).
    pub fn flow_ref(&mut self) -> Result<(&[f32], &[f32], &[f32])> {
        let (u, v, p) = self.flow.as_host()?;
        Ok((u, v, p))
    }

    pub fn current_jet(&self) -> f64 {
        self.jet
    }
}

fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// z-score with the base-flow statistics from the manifest.
pub fn normalise(probes: &[f32], mean: &[f32], std: &[f32]) -> Vec<f32> {
    probes
        .iter()
        .zip(mean.iter().zip(std))
        .map(|(&x, (&m, &s))| (x - m) / s)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalise_zscores() {
        let p = [2.0f32, 4.0];
        let m = [1.0f32, 4.0];
        let s = [0.5f32, 2.0];
        assert_eq!(normalise(&p, &m, &s), vec![2.0, 0.0]);
    }

    #[test]
    fn smoothing_math() {
        // Eq. (11) applied twice from rest with beta = 0.4, a = 1.0
        let beta = 0.4f64;
        let mut jet = 0.0f64;
        jet += beta * (1.0 - jet);
        assert!((jet - 0.4).abs() < 1e-12);
        jet += beta * (1.0 - jet);
        assert!((jet - 0.64).abs() < 1e-12);
    }
}
