//! drlfoam CLI: leader entrypoint.
//!
//! Subcommands:
//!   train       — multi-environment PPO training on a selected scenario
//!                 (--layout auto plans envs/ranks/sync/io before training,
//!                 --executor picks threads or real worker processes)
//!   worker      — one environment rank behind the exec wire protocol
//!                 (spawned by `--executor multi-process` via self-exec)
//!   agent       — per-host worker supervisor for `train --hosts ...`:
//!                 accepts coordinator connections, spawns local rank
//!                 groups, relays their frames
//!   episode     — roll out a single episode and print per-period stats
//!   scenarios   — list the scenario registry
//!   calibrate   — measure per-component costs, write out/calib.json
//!   reproduce   — regenerate a paper table/figure (table1, table2, fig7,
//!                 fig8, fig9, fig10, summary, plan, all)
//!   simulate    — run one cluster-DES configuration
//!   plan        — sweep every feasible (envs x ranks x sync x io) layout
//!                 under a core budget and rank them (out/plan.csv)
//!   info        — print manifest/artifact info
//!
//! Hand-rolled argument parsing (see rust/src/config) because clap is not
//! vendored in this offline environment.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use drlfoam::cfd::CfdBackend;
use drlfoam::cluster::{planner, simulate_training, Calibration, SimConfig};
use drlfoam::config::{artifact_dir, Args};
use drlfoam::coordinator::{train, EnvPool, InferenceMode, LocalPolicy, PoolConfig, SyncPolicy, TrainConfig};
use drlfoam::drl::{NativePolicy, PolicyBackendKind, UpdateBackendKind};
use drlfoam::exec::{ExecutorKind, TransportKind};
use drlfoam::env::scenario::{self, ScenarioContext, SURROGATE_HIDDEN, SURROGATE_N_OBS};
use drlfoam::env::{CfdEngineRef, Environment};
use drlfoam::io_interface::{make_interface, CfdOutput, FlowSnapshot, IoMode};
use drlfoam::runtime::{Manifest, Runtime};
use drlfoam::{drl, env, reproduce};

const USAGE: &str = "usage: drlfoam <train|worker|agent|episode|scenarios|calibrate|reproduce|simulate|plan|audit|trace|info> [options]
  common options: --artifacts DIR  --out DIR  --variant small  --scenario cylinder  --seed N
  train:     --envs N --horizon N --iterations N --epochs N --io baseline|optimized|memory
             --inference per-env|batched --backend xla|native --update-backend xla|native
             --cfd-backend xla|native --sync full|partial:<k>|async
             --executor in-process|multi-process
             --transport pipe|shm|tcp|uds --ranks N --layout manual|auto
             [--hosts host:cores[,host:cores...]] [--quiet]
             [--trace out/trace.json]  (record spans from every worker —
              local threads and remote processes alike — and merge them into
              one Chrome-trace JSON for ui.perfetto.dev, plus
              out/obs_summary.csv percentiles and an out/drift.csv
              plan-vs-actual report against the DES prediction [--calib FILE
              supplies the calibration, otherwise a quick surrogate
              measurement]; learning output stays bitwise identical)
             (--scenario surrogate|analytic trains with no artifacts: native
              backends are auto-selected when artifacts/ is absent.
              --cfd-backend native runs the cylinder CFD on the pure-Rust
              SIMD+threaded engine — no artifacts needed, the base flow is
              developed in-process; DRLFOAM_CFD_THREADS and
              DRLFOAM_FORCE_SCALAR=1 tune it without changing results. --sync
              partial:<k> updates on any k of N trajectories. --executor
              multi-process runs each environment as a group of --ranks real
              `drlfoam worker` OS processes with heartbeat fault handling: a
              dead worker is respawned and its episode re-queued; --chaos
              <env>:<episode>[:midframe] injects one such crash. --transport
              shm moves the data frames over per-worker shared-memory seqlock
              rings (pipe stays the control channel + fallback); --transport
              tcp|uds moves the same frames over sockets, and with --hosts
              places rank groups first-fit across per-host `drlfoam agent`
              supervisors (host 0 = the coordinator's; a host entry is
              host[:port]:cores for tcp, /path.sock:cores for uds; the
              learning results stay bitwise identical to pipe). --layout auto
              measures a
              small calibration — through the worker processes when the
              executor is multi-process — plans the (envs, ranks, sync, io)
              layout under --cores [default: this machine's cores], applies
              the winner, and writes out/plan.csv; axes passed explicitly
              (--envs/--ranks/--sync/--io, and --executor itself) are pinned,
              not searched.)
  worker:    --env-id N --rank N --heartbeat-ms N [--shm-prefix PATH]
             [--connect tcp:host:port|uds:/path.sock]
             (internal: spawned by --executor multi-process; speaks
             length-prefixed binary frames on stdin/stdout — or over the
             --connect socket — plus shm rings under --transport shm; not
             for interactive use)
  agent:     --bind host:port|/path.sock
             (per-host worker supervisor for `train --hosts ...`: accepts
              coordinator connections, spawns one local rank group per
              connection — first frame = the spawn spec — and relays
              frames; killing a connection kills its workers)
  episode:   --horizon N --io MODE [--policy out/policy_final.bin]
             [--cfd-backend xla|native]
             (--scenario surrogate and --cfd-backend native run without
              artifacts)
  scenarios: list selectable scenarios
  evaluate:  --policy FILE --horizon N  (deterministic rollout + vorticity PPMs)
  calibrate: --periods N (measurement repetitions)
  reproduce: <table1|table2|fig6|fig7|fig8|fig9|fig10|summary|ablation|sync|plan|all>
             [--calib out/calib.json]   (plan = the 60-core optimal-config claim;
             not part of `all` — it sweeps hundreds of DES runs)
  simulate:  --envs N --ranks N --episodes N --io MODE --sync full|partial:<k>|async
  plan:      --cores N [--objective time|efficiency|pareto] [--ranks 1,2,5]
             [--envs N1,N2,...] [--syncs full,partial:8,async]
             [--ios baseline,optimized,memory] [--staleness-weight W]
             [--episodes N] [--calib out/calib.json]
             [--hosts host:cores[,host:cores...]]
             (exhaustive DES-scored sweep of feasible layouts; ranked table on
              stdout, every layout to out/plan.csv, Pareto front marked.
              --hosts makes packing part of feasibility — rank groups are
              never split across hosts — charges envs placed off host 0 the
              calibrated inter-node round trip, and defaults --cores to the
              topology's total)
  trace:     [FILE]  (default out/trace.json: per-phase time table + lane
             count of a `train --trace` recording; renders the sibling
             obs_summary.csv / drift.csv tables when present)
  audit:     [--root DIR] [--allowlist FILE] [--format text|json]
             (repo-invariant lint pass: SAFETY comments on every unsafe,
              no hash collections / wall-clock reads / f32 sums in
              determinism-critical modules, wire::Tag coverage; audited
              exceptions in rust/audit.allow; exits non-zero on findings)";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let value_opts = [
        "artifacts", "out", "variant", "scenario", "seed", "envs", "ranks",
        "horizon", "iterations", "epochs", "io", "inference", "backend",
        "update-backend", "cfd-backend", "sync", "episodes", "periods", "calib", "policy",
        "work-dir", "log-every", "layout", "cores", "objective", "syncs",
        "ios", "staleness-weight", "executor", "chaos", "env-id", "rank",
        "heartbeat-ms", "transport", "shm-prefix", "hosts", "bind",
        "connect", "root", "tests", "allowlist", "format", "trace",
    ];
    let args = Args::parse(argv, &value_opts)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "train" => cmd_train(&args),
        "worker" => cmd_worker(&args),
        "agent" => cmd_agent(&args),
        "episode" => cmd_episode(&args),
        "scenarios" => cmd_scenarios(),
        "evaluate" => cmd_evaluate(&args),
        "calibrate" => cmd_calibrate(&args),
        "reproduce" => cmd_reproduce(&args),
        "simulate" => cmd_simulate(&args),
        "plan" => cmd_plan(&args),
        "audit" => cmd_audit(&args),
        "trace" => cmd_trace(&args),
        "info" => cmd_info(&args),
        _ => bail!("{USAGE}"),
    }
}

fn out_dir(args: &Args) -> std::path::PathBuf {
    args.get_or("out", "out").into()
}

/// `--sync full|partial:<k>|async` (train and simulate share the axis).
/// The PR-3-era `--async` alias is gone; the parse-time error keeps
/// pointing migrating scripts at the replacement.
fn sync_policy(args: &Args) -> Result<SyncPolicy> {
    if args.has_flag("async") {
        bail!("--async was removed; use --sync async");
    }
    SyncPolicy::parse(&args.get_or("sync", "full"))
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = TrainConfig {
        artifact_dir: artifact_dir(args),
        work_dir: args.get_or("work-dir", "out/work").into(),
        out_dir: out_dir(args),
        variant: args.get_or("variant", "small"),
        scenario: args.get_or("scenario", "cylinder"),
        n_envs: args.usize_or("envs", 1)?,
        io_mode: IoMode::parse(&args.get_or("io", "memory"))?,
        inference: InferenceMode::parse(&args.get_or("inference", "per-env"))?,
        backend: PolicyBackendKind::parse(&args.get_or("backend", "xla"))?,
        update_backend: UpdateBackendKind::parse(&args.get_or("update-backend", "xla"))?,
        cfd_backend: CfdBackend::parse(&args.get_or("cfd-backend", "xla"))?,
        sync: sync_policy(args)?,
        executor: ExecutorKind::parse(&args.get_or("executor", "in-process"))?,
        ranks_per_env: args.usize_or("ranks", 1)?,
        worker_bin: None,
        fault_injection: args.get("chaos").map(|s| s.to_string()),
        transport: TransportKind::parse(&args.get_or("transport", "pipe"))?,
        hosts: match args.get("hosts") {
            Some(s) => drlfoam::exec::net::HostSpec::parse_list(s)?,
            None => Vec::new(),
        },
        horizon: args.usize_or("horizon", 100)?,
        iterations: args.usize_or("iterations", 100)?,
        epochs: args.usize_or("epochs", 4)?,
        seed: args.u64_or("seed", 0)?,
        log_every: args.usize_or("log-every", 1)?,
        quiet: args.has_flag("quiet"),
        trace: args.get("trace").map(std::path::PathBuf::from),
        trace_calib: None,
    };
    anyhow::ensure!(cfg.ranks_per_env >= 1, "--ranks must be >= 1");
    anyhow::ensure!(
        cfg.ranks_per_env == 1 || cfg.executor == ExecutorKind::MultiProcess,
        "--ranks {} needs --executor multi-process (in-process workers are single-rank)",
        cfg.ranks_per_env
    );
    anyhow::ensure!(
        cfg.fault_injection.is_none() || cfg.executor == ExecutorKind::MultiProcess,
        "--chaos injects worker-process crashes and needs --executor multi-process"
    );
    anyhow::ensure!(
        cfg.transport == TransportKind::Pipe || cfg.executor == ExecutorKind::MultiProcess,
        "--transport {} moves frames between worker processes and needs \
         --executor multi-process",
        cfg.transport.name()
    );
    anyhow::ensure!(
        cfg.hosts.is_empty() || cfg.transport.is_socket(),
        "--hosts spans machines over sockets; use --transport tcp or uds (got {})",
        cfg.transport.name()
    );
    match args.get_or("layout", "manual").trim().to_ascii_lowercase().as_str() {
        "manual" => {}
        "auto" => auto_layout(args, &mut cfg)?,
        other => bail!("unknown layout {other:?} (accepted: manual, auto)"),
    }
    if cfg.trace.is_some() {
        // the drift report compares measured spans against the DES
        // prediction, which needs a calibration: --calib when given,
        // otherwise the same quick surrogate measurement --layout auto uses
        cfg.trace_calib = Some(match args.get("calib") {
            Some(p) => Calibration::load(std::path::Path::new(p))
                .with_context(|| format!("loading calibration {p}"))?,
            None => quick_surrogate_calibration(
                &cfg.work_dir.join("trace-calib"),
                cfg.horizon,
                cfg.seed,
            )?,
        });
    }
    // io/inference are used as requested; the policy/update backends may
    // be downgraded by the artifact-free fallback, so the *resolved*
    // engines are reported from inside the training setup instead
    println!(
        "training: scenario={} variant={} envs={} ranks={} horizon={} iterations={} io={} inference={} cfd={} sync={} executor={} transport={}",
        cfg.scenario,
        cfg.variant,
        cfg.n_envs,
        cfg.ranks_per_env,
        cfg.horizon,
        cfg.iterations,
        cfg.io_mode.name(),
        cfg.inference.name(),
        cfg.cfd_backend.name(),
        cfg.sync.name(),
        cfg.executor.name(),
        cfg.transport.name()
    );
    if !cfg.hosts.is_empty() {
        let specs: Vec<String> = cfg
            .hosts
            .iter()
            .map(|h| format!("{}:{}", h.endpoint, h.cores))
            .collect();
        println!(
            "hosts: {} (rank groups packed first-fit; host 0 is the coordinator's)",
            specs.join(",")
        );
    }
    let summary = train(&cfg)?;
    if summary.worker_restarts > 0 {
        println!(
            "worker restarts: {} (episodes re-queued; see {}/workers.csv)",
            summary.worker_restarts,
            cfg.out_dir.display()
        );
    }
    let first = summary.log.first().context("no iterations")?;
    let last = summary.log.last().context("no iterations")?;
    println!(
        "done in {:.1}s: reward {:.3} -> {:.3}, Cd {:.3} -> {:.3}  (exchange {:.1} KB/episode)",
        summary.total_s,
        first.mean_reward,
        last.mean_reward,
        first.mean_cd,
        last.mean_cd,
        summary.io_bytes_per_episode / 1024.0
    );
    if cfg.sync != SyncPolicy::Full {
        println!(
            "staleness: mean {:.3} over {} episodes (histogram in {}/staleness.csv)",
            summary.mean_staleness,
            last.episodes_done,
            cfg.out_dir.display()
        );
    }
    println!("learning curve: {}/train_log.csv", cfg.out_dir.display());
    Ok(())
}

/// `drlfoam worker`: one environment rank driven over the exec wire
/// protocol on stdin/stdout. Spawned by `--executor multi-process` via
/// self-exec — stdout carries binary frames, so nothing here may print
/// to it (diagnostics go to stderr, inherited from the coordinator).
fn cmd_worker(args: &Args) -> Result<()> {
    let cfg = drlfoam::exec::worker::WorkerConfig {
        env_id: args.usize_or("env-id", 0)?,
        rank: args.usize_or("rank", 0)?,
        scenario: args.get_or("scenario", "surrogate"),
        variant: args.get_or("variant", "small"),
        artifact_dir: artifact_dir(args),
        work_dir: args.get_or("work-dir", "out/work").into(),
        io_mode: IoMode::parse(&args.get_or("io", "memory"))?,
        backend: PolicyBackendKind::parse(&args.get_or("backend", "native"))?,
        cfd_backend: CfdBackend::parse(&args.get_or("cfd-backend", "xla"))?,
        seed: args.u64_or("seed", 0)?,
        heartbeat_ms: args.u64_or("heartbeat-ms", 200)?,
        shm_prefix: args.get("shm-prefix").map(Into::into),
        connect: args.get("connect").map(|s| s.to_string()),
        trace: args.has_flag("trace-spans"),
    };
    drlfoam::exec::worker::run(&cfg)
}

/// `drlfoam agent`: the per-host worker supervisor behind
/// `train --hosts ...`. Binds the given TCP address or Unix-socket path
/// and serves coordinator connections until killed (see
/// [`drlfoam::exec::net::run_agent`]).
fn cmd_agent(args: &Args) -> Result<()> {
    let bind = args
        .get("bind")
        .context("agent needs --bind host:port (tcp) or --bind /path.sock (uds)")?;
    drlfoam::exec::net::run_agent(bind)
}

fn cmd_episode(args: &Args) -> Result<()> {
    let adir = artifact_dir(args);
    let variant = args.get_or("variant", "small");
    let scenario_name = args.get_or("scenario", "cylinder");
    let horizon = args.usize_or("horizon", 20)?;
    let seed = args.u64_or("seed", 0)?;
    let io_mode = IoMode::parse(&args.get_or("io", "memory"))?;
    let cfd_backend = CfdBackend::parse(&args.get_or("cfd-backend", "xla"))?;
    // the surrogate scenario runs without any artifacts, so a *missing*
    // manifest is fine — but a present-and-broken one is a real error,
    // not something to silently fall back from. The native CFD backend
    // ignores artifacts entirely (uniform with and without them), so the
    // policy is sized/initialised as if none existed.
    let manifest = Manifest::load_optional(&adir)?;
    let native_cfd = cfd_backend == CfdBackend::Native
        && matches!(scenario::spec(&scenario_name)?.kind, env::ScenarioKind::Cylinder { .. });
    let policy_manifest = if native_cfd { None } else { manifest.as_ref() };
    let work = out_dir(args).join("work");
    std::fs::create_dir_all(&work)?;

    let ctx = ScenarioContext {
        artifact_dir: &adir,
        work_dir: &work,
        env_id: 0,
        io_mode,
        manifest: manifest.as_ref(),
        variant: &variant,
        cfd_backend,
        seed,
    };
    let mut e = scenario::build(&scenario_name, &ctx)?;

    // XLA serving when the scenario brings a runtime and artifacts exist;
    // the native twin otherwise (surrogate, native-CFD and artifact-free
    // runs)
    let (mut lp, params) = match &policy_manifest {
        Some(m) if e.runtime_mut().is_some() => {
            let params = match args.get("policy") {
                Some(p) => drlfoam::runtime::read_f32_bin(p)?,
                None => m.load_params_init()?,
            };
            (LocalPolicy::xla(&m.drl), params)
        }
        Some(m) => {
            // e.g. surrogate with artifacts: same params, native forward
            let params = match args.get("policy") {
                Some(p) => drlfoam::runtime::read_f32_bin(p)?,
                None => m.load_params_init()?,
            };
            (LocalPolicy::native(m.drl.n_obs, m.drl.hidden), params)
        }
        None => {
            let (n_obs, hidden) = scenario::policy_dims(&scenario_name, cfd_backend, None);
            let net = NativePolicy::new(n_obs, hidden);
            let params = match args.get("policy") {
                Some(p) => drlfoam::runtime::read_f32_bin(p)?,
                None => net.init_params(seed),
            };
            if native_cfd {
                println!("cfd backend: native (artifact-free) — native policy backend");
            } else {
                println!("no artifacts at {} — native policy backend", adir.display());
            }
            (LocalPolicy::native(n_obs, hidden), params)
        }
    };
    lp.begin_episode(e.as_mut(), &params)?;
    let sampler = drl::Policy::new(e.n_obs());
    let mut rng = drlfoam::util::rng::Rng::new(seed);

    let mut obs = e.reset()?;
    println!("scenario: {scenario_name}");
    println!("period      jet   action     Cd       Cl     reward   cfd(ms)  io(ms)");
    let mut total_r = 0.0;
    for t in 0..horizon {
        let pout = lp.apply(e.as_mut(), &params, &obs)?;
        let (a, _logp) = sampler.sample(&pout, &mut rng);
        let sr = e.step(a)?;
        total_r += sr.reward;
        println!(
            "{t:>6} {:>8.4} {:>8.4} {:>8.4} {:>8.4} {:>9.5} {:>8.2} {:>7.2}",
            sr.jet,
            a,
            sr.cd_mean,
            sr.cl_mean,
            sr.reward,
            sr.timings.cfd_s * 1e3,
            sr.timings.io_s * 1e3
        );
        obs = sr.obs;
    }
    println!("episode reward: {total_r:.4}");
    Ok(())
}

fn cmd_scenarios() -> Result<()> {
    println!("{:<16} {:<10} summary", "name", "artifacts");
    for s in env::scenario::SCENARIOS {
        let needs = match s.kind {
            env::ScenarioKind::Cylinder { .. } => "required",
            env::ScenarioKind::Surrogate => "none",
        };
        println!("{:<16} {:<10} {}", s.name, needs, s.summary);
    }
    println!("\nselect with --scenario NAME (train, episode); see ARCHITECTURE.md");
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let adir = artifact_dir(args);
    let variant = args.get_or("variant", "small");
    let horizon = args.usize_or("horizon", 60)?;
    let odir = out_dir(args).join("eval");
    std::fs::create_dir_all(&odir)?;
    let manifest = Manifest::load(&adir)?;
    let mut rt = Runtime::new(&adir)?;
    let vm = manifest.variant(&variant)?.clone();
    rt.load(&vm.cfd_period_file)?;
    rt.load(&manifest.drl.policy_apply_file)?;
    let params = match args.get("policy") {
        Some(p) => drlfoam::runtime::read_f32_bin(p)?,
        None => manifest.load_params_init()?,
    };
    anyhow::ensure!(params.len() == manifest.drl.n_params, "policy size mismatch");
    let work = odir.join("work");
    std::fs::create_dir_all(&work)?;
    let mut e = env::CfdEnv::new(
        vm.clone(),
        manifest.load_state0(&variant)?,
        manifest.drl.action_smoothing_beta,
        manifest.drl.reward_lift_penalty,
        make_interface(IoMode::InMemory, &work, 0)?,
    );
    let policy = drl::Policy::new(manifest.drl.n_obs);
    let cfd = rt.get(&vm.cfd_period_file)?;
    let pol = rt.get(&manifest.drl.policy_apply_file)?;

    // vorticity snapshot of the uncontrolled base flow (Fig 5e analogue)
    let (u0, v0, _) = manifest.load_state0(&variant)?;
    drlfoam::viz::vorticity_snapshot(
        odir.join("vorticity_uncontrolled.ppm"),
        &u0, &v0, vm.ny, vm.nx, vm.h, 2.0, -2.0, 0.5,
    )?;

    let mut obs = e.reset(CfdEngineRef::Xla(cfd))?;
    let mut csv = String::from("step,jet,cd,cl,reward\n");
    let (mut cd_acc, mut r_acc) = (0.0, 0.0);
    for t in 0..horizon {
        // deterministic policy: action = mu (no exploration noise)
        let pout = policy.apply(pol, &params, &obs)?;
        let sr = e.step(CfdEngineRef::Xla(cfd), pout.mu)?;
        csv.push_str(&format!(
            "{t},{:.6},{:.6},{:.6},{:.6}\n",
            sr.jet, sr.cd_mean, sr.cl_mean, sr.reward
        ));
        cd_acc += sr.cd_mean;
        r_acc += sr.reward;
        obs = sr.obs;
    }
    std::fs::write(odir.join("eval_history.csv"), &csv)?;
    let (uf, vf, _) = e.flow_ref()?;
    drlfoam::viz::vorticity_snapshot(
        odir.join("vorticity_controlled.ppm"),
        uf, vf, vm.ny, vm.nx, vm.h, 2.0, -2.0, 0.5,
    )?;
    let cd_mean = cd_acc / horizon as f64;
    println!(
        "deterministic eval over {horizon} periods: mean Cd {cd_mean:.4} (Cd0 {:.4}, reduction {:+.2}%), total reward {r_acc:.3}",
        vm.cd0,
        100.0 * (vm.cd0 - cd_mean) / vm.cd0
    );
    println!("history: {}/eval_history.csv; vorticity PPMs alongside", odir.display());
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let adir = artifact_dir(args);
    let variant = args.get_or("variant", "small");
    let reps = args.usize_or("periods", 15)?;
    let odir = out_dir(args);
    std::fs::create_dir_all(&odir)?;
    let manifest = Manifest::load(&adir)?;
    let mut rt = Runtime::new(&adir)?;
    let vm = manifest.variant(&variant)?.clone();
    rt.load(&vm.cfd_period_file)?;
    rt.load(&manifest.drl.policy_apply_file)?;
    rt.load(&manifest.drl.ppo_update_file)?;
    let params = manifest.load_params_init()?;

    // --- CFD period cost
    let work = odir.join("calib-work");
    std::fs::create_dir_all(&work)?;
    let mut e = env::CfdEnv::new(
        vm.clone(),
        manifest.load_state0(&variant)?,
        manifest.drl.action_smoothing_beta,
        manifest.drl.reward_lift_penalty,
        make_interface(IoMode::InMemory, &work, 0)?,
    );
    let cfd = rt.get(&vm.cfd_period_file)?;
    e.reset(CfdEngineRef::Xla(cfd))?;
    let mut t_cfd = Vec::new();
    for _ in 0..reps {
        let sr = e.step(CfdEngineRef::Xla(cfd), 0.1)?;
        t_cfd.push(sr.timings.cfd_s);
    }
    let t_period = drlfoam::util::stats::mean(&t_cfd);

    // --- policy apply cost (the session fast path the workers use)
    let pol = rt.get(&manifest.drl.policy_apply_file)?;
    let session = drl::policy::PolicySession::new(&rt, &params, manifest.drl.n_obs)?;
    let obs = vec![0.1f32; manifest.drl.n_obs];
    let t0 = std::time::Instant::now();
    for _ in 0..50 {
        session.apply(&rt, pol, &obs)?;
    }
    let t_policy = t0.elapsed().as_secs_f64() / 50.0;

    // --- ppo update cost
    let mut trainer = drl::PpoTrainer::new(&manifest.drl, params.clone(), 1);
    let traj = synth_traj(manifest.drl.n_obs, manifest.drl.minibatch);
    let batch = drl::Batch::assemble(&[traj], manifest.drl.n_obs, 0.99, 0.95);
    let mut rng = drlfoam::util::rng::Rng::new(7);
    let upd_exe = rt.get(&manifest.drl.ppo_update_file)?;
    let t0 = std::time::Instant::now();
    let mut mbs = 0usize;
    for _ in 0..10 {
        let st = trainer.update(drl::TrainerBackend::Xla(upd_exe), &batch, &mut rng)?;
        mbs += st.minibatches;
    }
    let t_update_mb = t0.elapsed().as_secs_f64() / mbs as f64;

    // --- exchange costs per mode (real bytes + cpu time on this disk)
    let (u, v, p) = e.flow_ref()?;
    let flow = FlowSnapshot {
        u,
        v,
        p,
        ny: vm.ny,
        nx: vm.nx,
    };
    let probes = vec![0.5f32; manifest.drl.n_obs];
    let outp = CfdOutput {
        probes,
        cd_hist: vec![3.0; vm.substeps],
        cl_hist: vec![0.1; vm.substeps],
    };
    let measure = |mode: IoMode| -> Result<(f64, f64)> {
        let mut iface = make_interface(mode, &work, 9)?;
        let mut bytes = 0.0;
        let mut cpu = 0.0;
        for k in 0..10 {
            let (_, st) = iface.exchange(k, &outp, &flow)?;
            let (_, st2) = iface.inject_action(k, 0.5)?;
            bytes += (st.bytes_written + st.bytes_read + st2.bytes_written + st2.bytes_read) as f64;
            cpu += st.total_s() + st2.total_s();
        }
        Ok((bytes / 10.0, cpu / 10.0))
    };
    let (bytes_b, cpu_b) = measure(IoMode::Baseline)?;
    let (bytes_o, cpu_o) = measure(IoMode::Optimized)?;

    let calib = Calibration::from_measured(
        t_period,
        t_policy,
        t_update_mb,
        bytes_b,
        bytes_o,
        cpu_b,
        cpu_o,
        args.usize_or("horizon", 100)?,
    );
    let path = odir.join("calib.json");
    calib.save(&path)?;
    println!("measured on this machine ({variant} variant):");
    println!("  t_period        {:>10.2} ms", t_period * 1e3);
    println!("  t_policy        {:>10.3} ms", t_policy * 1e3);
    println!("  t_update_mb     {:>10.3} ms", t_update_mb * 1e3);
    println!("  exchange bytes  {:>10.0} (baseline) vs {:>8.0} (optimized)  ratio {:.1}x",
        bytes_b, bytes_o, bytes_b / bytes_o.max(1.0));
    println!("  exchange cpu    {:>10.3} ms vs {:>8.3} ms", cpu_b * 1e3, cpu_o * 1e3);
    println!("wrote {}", path.display());
    Ok(())
}

fn synth_traj(n_obs: usize, n: usize) -> drl::Trajectory {
    let mut rng = drlfoam::util::rng::Rng::new(3);
    drl::Trajectory {
        transitions: (0..n)
            .map(|_| drl::Transition {
                obs: (0..n_obs).map(|_| rng.normal() as f32).collect(),
                action: rng.normal() * 0.1,
                logp: -1.0,
                reward: rng.normal() * 0.1,
                value: 0.0,
            })
            .collect(),
        last_value: 0.0,
        env_id: 0,
    }
}

/// `train --layout auto`: search the (n_envs, ranks, sync, io) layout
/// before training and apply the winner to the scheduler loop. The
/// calibration is measured small — `--calib FILE` when given; otherwise a
/// quick measurement of the artifact-free surrogate pipeline, run
/// *through real `drlfoam worker` processes* when the executor is
/// multi-process ([`process_calibration`]) and in-process otherwise —
/// and the planner sweeps the `--cores` budget (default: this machine's
/// available parallelism). Axes pinned explicitly on the command line
/// (`--envs`, `--ranks`, `--sync`, `--io`) are respected, not searched,
/// and the requested `--executor` is never overridden. Without an
/// explicit `--ranks` the rank axis stays at 1: live rank groups are
/// placement-only (the in-repo CFD is single-core), so searching the
/// axis would claim MPI speedups this run cannot realise.
fn auto_layout(args: &Args, cfg: &mut TrainConfig) -> Result<()> {
    let cores = match args.get("cores") {
        Some(_) => args.usize_or("cores", 1)?,
        // a --hosts topology IS the core budget
        None if !cfg.hosts.is_empty() => cfg.hosts.iter().map(|h| h.cores).sum(),
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    let mut calib = match args.get("calib") {
        Some(p) => Calibration::load(std::path::Path::new(p))
            .with_context(|| format!("loading calibration {p}"))?,
        None if cfg.executor == ExecutorKind::MultiProcess => process_calibration(cfg)?,
        None => quick_surrogate_calibration(&cfg.work_dir.join("auto-calib"), cfg.horizon, cfg.seed)?,
    };
    if cfg.transport.is_socket() && calib.t_net_rtt == 0.0 {
        // measure the socket round trip the same way process_calibration
        // measures the exchange: on the live transport, loopback
        std::fs::create_dir_all(&cfg.work_dir)?;
        calib.t_net_rtt =
            drlfoam::exec::net::measure_rtt(cfg.transport, &cfg.work_dir, 16)?;
        println!(
            "layout auto: measured {} round trip {:.1} us (inter-node term for remote envs)",
            cfg.transport.name(),
            calib.t_net_rtt * 1e6
        );
    }
    let mut pc = planner::PlannerConfig::new(cores);
    if !cfg.hosts.is_empty() {
        pc.hosts = Some(cfg.hosts.iter().map(|h| h.cores).collect());
    }
    pc.ranks_options = if args.get("ranks").is_some() {
        vec![cfg.ranks_per_env]
    } else {
        vec![1]
    };
    // fixed total budget: what the run would consume with every core
    // hosting an environment (planning is comparative, not a promise)
    pc.episodes_total = (cfg.iterations * cores).max(1);
    pc.seed = cfg.seed;
    pc.objective = planner::Objective::parse(&args.get_or("objective", "time"))?;
    pc.staleness_weight = args.f64_or("staleness-weight", pc.staleness_weight)?;
    // unlike `drlfoam plan`, the live loop can genuinely skip the
    // filesystem, so the I/O-disabled mode is a real candidate here
    pc.io_options = vec![IoMode::Baseline, IoMode::Optimized, IoMode::InMemory];
    if args.get("envs").is_some() {
        pc.env_options = Some(vec![cfg.n_envs]);
    }
    if args.get("sync").is_some() {
        pc.sync_options = vec![cfg.sync];
    }
    if args.get("io").is_some() {
        pc.io_options = vec![cfg.io_mode];
    }
    let set = planner::search(&calib, &pc)?;
    std::fs::create_dir_all(&cfg.out_dir)?;
    set.write_csv(cfg.out_dir.join("plan.csv"))?;
    let best = set.best().context("planner found no feasible layout")?.clone();
    if !cfg.quiet {
        println!("{}", set.render(8));
    }
    println!(
        "layout auto: envs={} ranks={} sync={} io={} executor={} ({} of {} cores; ranking in {}/plan.csv)",
        best.n_envs,
        best.n_ranks,
        best.sync.name(),
        best.io_mode.name(),
        cfg.executor.name(),
        best.total_cpus,
        cores,
        cfg.out_dir.display()
    );
    cfg.apply_plan(&best);
    Ok(())
}

/// Measure the artifact-free surrogate pipeline THROUGH the multi-process
/// executor: a small pool of real `drlfoam worker` processes rolls a few
/// episodes per exchange mode, and the per-worker telemetry supplies the
/// period/exchange costs — so `--layout auto --executor multi-process`
/// calibrates from real process timings (transport hops, process
/// scheduling and all) instead of the in-process surrogate. The pool
/// inherits the run's `--transport`, so a `--transport shm` layout
/// search is calibrated against the shm data plane it will actually
/// train over, not the pipe. The policy-serving and
/// PPO-minibatch costs are measured natively in this process, where they
/// run under every executor.
fn process_calibration(cfg: &TrainConfig) -> Result<Calibration> {
    let reps = 8usize;
    let n_envs = 2usize;
    let measure = |mode: IoMode| -> Result<(f64, f64, f64)> {
        let work = cfg.work_dir.join(format!("auto-calib-{}", mode.name()));
        std::fs::create_dir_all(&work)?;
        let pool_cfg = PoolConfig {
            artifact_dir: work.join("no-artifacts"),
            work_dir: work,
            variant: cfg.variant.clone(),
            scenario: "surrogate".into(),
            backend: PolicyBackendKind::Native,
            cfd_backend: CfdBackend::Xla,
            n_envs,
            io_mode: mode,
            seed: cfg.seed,
            executor: ExecutorKind::MultiProcess,
            ranks_per_env: 1,
            worker_bin: cfg.worker_bin.clone(),
            fault_injection: None,
            transport: cfg.transport,
            trace: false,
        };
        let mut pool = EnvPool::standalone(&pool_cfg)?;
        let params =
            Arc::new(NativePolicy::new(pool.n_obs(), pool.hidden()).init_params(cfg.seed));
        let outs = pool.rollout(&params, reps, 0)?;
        let periods = (reps * outs.len()).max(1) as f64;
        let cfd = outs.iter().map(|o| o.stats.cfd_s).sum::<f64>() / periods;
        let cpu = outs.iter().map(|o| o.stats.io.total_s()).sum::<f64>() / periods;
        let bytes = outs
            .iter()
            .map(|o| (o.stats.io.bytes_written + o.stats.io.bytes_read) as f64)
            .sum::<f64>()
            / periods;
        Ok((cfd, cpu, bytes))
    };
    let (t_period, cpu_b, bytes_b) = measure(IoMode::Baseline)?;
    let (_, cpu_o, bytes_o) = measure(IoMode::Optimized)?;
    let (t_policy, t_update_mb) = native_policy_update_costs(cfg.seed)?;
    Ok(Calibration::from_measured(
        t_period.max(1e-7),
        t_policy,
        t_update_mb,
        bytes_b.max(1.0),
        bytes_o.max(1.0),
        cpu_b,
        cpu_o,
        cfg.horizon.max(1),
    ))
}

/// Measure the per-component costs of the artifact-free surrogate
/// pipeline on THIS machine and scale them into a calibration
/// (`Calibration::from_measured`), for `--layout auto` runs without an
/// out/calib.json: a few actuation periods per exchange mode give the
/// period time and the exchange bytes/CPU costs; the native policy and
/// native PPO backends give the serving and minibatch costs.
fn quick_surrogate_calibration(
    work: &std::path::Path,
    horizon: usize,
    seed: u64,
) -> Result<Calibration> {
    std::fs::create_dir_all(work)?;
    let reps = 12usize;
    let no_artifacts = work.join("no-artifacts");
    let measure = |mode: IoMode| -> Result<(f64, f64, f64)> {
        let ctx = ScenarioContext {
            artifact_dir: &no_artifacts,
            work_dir: work,
            env_id: 0,
            io_mode: mode,
            manifest: None,
            variant: "small",
            cfd_backend: CfdBackend::Xla,
            seed,
        };
        let mut e = scenario::build("surrogate", &ctx)?;
        e.reset()?;
        let (mut cfd, mut cpu, mut bytes) = (0.0f64, 0.0f64, 0.0f64);
        for k in 0..reps {
            let sr = e.step(0.2 * ((k % 3) as f64 - 1.0))?;
            cfd += sr.timings.cfd_s;
            cpu += sr.io.total_s();
            bytes += (sr.io.bytes_written + sr.io.bytes_read) as f64;
        }
        let n = reps as f64;
        Ok((cfd / n, cpu / n, bytes / n))
    };
    let (t_period, cpu_b, bytes_b) = measure(IoMode::Baseline)?;
    let (_, cpu_o, bytes_o) = measure(IoMode::Optimized)?;
    let (t_policy, t_update_mb) = native_policy_update_costs(seed)?;

    Ok(Calibration::from_measured(
        t_period.max(1e-7),
        t_policy,
        t_update_mb,
        bytes_b.max(1.0),
        bytes_o.max(1.0),
        cpu_b,
        cpu_o,
        horizon.max(1),
    ))
}

/// Native policy-serving and PPO-minibatch costs, measured in this
/// process (both components run on the coordinator/master under every
/// executor, so one measurement serves both calibration paths).
fn native_policy_update_costs(seed: u64) -> Result<(f64, f64)> {
    // policy serving (the backend auto-selected artifact-free)
    let net = NativePolicy::new(SURROGATE_N_OBS, SURROGATE_HIDDEN);
    let params = net.init_params(seed);
    let obs = vec![0.1f32; SURROGATE_N_OBS];
    let t0 = std::time::Instant::now();
    for _ in 0..200 {
        net.apply(&params, &obs)?;
    }
    let t_policy = t0.elapsed().as_secs_f64() / 200.0;

    // PPO minibatch
    let updater = drl::NativeUpdater::new(
        SURROGATE_N_OBS,
        SURROGATE_HIDDEN,
        drl::PpoHyperParams::default(),
    );
    let mut trainer = drl::PpoTrainer::with_minibatch(params, 64, 1);
    let traj = synth_traj(SURROGATE_N_OBS, 64);
    let batch = drl::Batch::assemble(&[traj], SURROGATE_N_OBS, 0.99, 0.95);
    let mut rng = drlfoam::util::rng::Rng::new(seed ^ 0xCA11B);
    let t0 = std::time::Instant::now();
    let mut mbs = 0usize;
    for _ in 0..5 {
        let st = trainer.update(drl::TrainerBackend::Native(&updater), &batch, &mut rng)?;
        mbs += st.minibatches;
    }
    Ok((t_policy, t0.elapsed().as_secs_f64() / mbs.max(1) as f64))
}

fn cmd_plan(args: &Args) -> Result<()> {
    let calib = load_calib(args)?;
    // `--hosts host:cores,...` — endpoints are carried for symmetry with
    // `train --hosts` but only the core counts matter to the sweep
    let hosts = match args.get("hosts") {
        Some(s) => Some(drlfoam::exec::net::HostSpec::parse_list(s)?),
        None => None,
    };
    let default_cores = hosts
        .as_ref()
        .map(|h| h.iter().map(|s| s.cores).sum())
        .unwrap_or(60);
    let mut pc = planner::PlannerConfig::new(args.usize_or("cores", default_cores)?);
    pc.hosts = hosts.map(|h| h.into_iter().map(|s| s.cores).collect());
    pc.episodes_total = args.usize_or("episodes", pc.episodes_total)?;
    pc.objective = planner::Objective::parse(&args.get_or("objective", "time"))?;
    pc.staleness_weight = args.f64_or("staleness-weight", pc.staleness_weight)?;
    pc.seed = args.u64_or("seed", pc.seed)?;
    pc.ranks_options = args.usize_list_or("ranks", &[1, 2, 5])?;
    if args.get("envs").is_some() {
        pc.env_options = Some(args.usize_list_or("envs", &[])?);
    }
    if let Some(s) = args.get("syncs") {
        pc.sync_options = s.split(',').map(SyncPolicy::parse).collect::<Result<Vec<_>>>()?;
    }
    if let Some(s) = args.get("ios") {
        pc.io_options = s.split(',').map(IoMode::parse).collect::<Result<Vec<_>>>()?;
    }
    let set = planner::search(&calib, &pc)?;
    let odir = out_dir(args);
    std::fs::create_dir_all(&odir)?;
    set.write_csv(odir.join("plan.csv"))?;
    println!("{}", set.render(15));
    let best = set.best().context("planner found no feasible layout")?;
    println!(
        "selected: {} envs x {} ranks ({} of {} cores), sync {}, io {} -> {:.1} h, {:.1}x, {:.1}% eff, staleness {:.2}",
        best.n_envs,
        best.n_ranks,
        best.total_cpus,
        pc.cores,
        best.sync.name(),
        best.io_mode.name(),
        best.duration_h,
        best.speedup,
        best.efficiency_pct,
        best.mean_staleness
    );
    println!(
        "full ranking ({} layouts): {}",
        set.plans.len(),
        odir.join("plan.csv").display()
    );
    Ok(())
}

fn load_calib(args: &Args) -> Result<Calibration> {
    match args.get("calib") {
        Some(p) => Calibration::load(std::path::Path::new(p))
            .with_context(|| format!("loading calibration {p}")),
        None => Ok(Calibration::paper_scale()),
    }
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let what = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let calib = load_calib(args)?;
    let odir = out_dir(args);
    std::fs::create_dir_all(&odir)?;
    let run = |name: &str| -> Result<String> {
        match name {
            "table1" => reproduce::table1(&calib, &odir),
            "table2" | "fig11" | "fig12" => reproduce::table2(&calib, &odir),
            "fig7" => reproduce::fig7(&calib, &odir),
            "fig8" => reproduce::fig8(&calib, &odir),
            "fig9" => reproduce::fig9(&calib, &odir),
            "fig10" => reproduce::fig10(&calib, &odir),
            "fig6" => reproduce::fig6(&artifact_dir(args), &odir, 24, 10),
            "ablation" => reproduce::ablation_async(&calib, &odir),
            "sync" => reproduce::sync_sweep(&calib, &odir),
            "plan" => reproduce::plan(&calib, &odir),
            "summary" => reproduce::summary(&calib, &odir),
            _ => bail!("unknown experiment {name:?}"),
        }
    };
    if what == "all" {
        for name in ["fig7", "table1", "fig8", "fig9", "fig10", "table2", "ablation", "sync", "summary"] {
            println!("{}", run(name)?);
        }
    } else {
        println!("{}", run(what)?);
    }
    println!("CSV series written under {}", odir.display());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let calib = load_calib(args)?;
    let cfg = SimConfig {
        n_envs: args.usize_or("envs", 1)?,
        n_ranks: args.usize_or("ranks", 1)?,
        episodes_total: args.usize_or("episodes", 3000)?,
        io_mode: IoMode::parse(&args.get_or("io", "baseline"))?,
        sync: sync_policy(args)?,
        remote_envs: 0,
        seed: args.u64_or("seed", 1)?,
    };
    let r = simulate_training(&calib, &cfg);
    println!(
        "envs={} ranks={} cpus={} io={} sync={} -> {:.2} h  (per-episode: cfd {:.1}s io {:.1}s policy {:.2}s; update+barrier {:.1}s/round, idle {:.1}s; disk {:.0}%)",
        r.cfg_envs,
        r.cfg_ranks,
        r.total_cpus,
        cfg.io_mode.name(),
        cfg.sync.name(),
        r.total_hours(),
        r.breakdown.cfd_s,
        r.breakdown.io_s,
        r.breakdown.policy_s,
        r.breakdown.update_barrier_s,
        r.breakdown.barrier_idle_s,
        100.0 * r.disk_utilisation
    );
    Ok(())
}

/// `drlfoam audit`: the repo-invariant lint pass (ARCHITECTURE.md §9).
/// Non-zero exit on any finding, so ci.sh can gate on it directly.
fn cmd_audit(args: &Args) -> Result<()> {
    use drlfoam::audit::{self, AuditConfig};
    let mut cfg = match args.get("root") {
        Some(root) => AuditConfig::for_root(root),
        None => AuditConfig::discover(&std::env::current_dir()?)?,
    };
    if let Some(tests) = args.get("tests") {
        cfg.tests_dir = tests.into();
    }
    if let Some(allow) = args.get("allowlist") {
        cfg.allowlist = Some(allow.into());
    }
    let report = audit::run(&cfg)?;
    match args.get_or("format", "text").as_str() {
        "text" => print!("{}", report.to_text()),
        "json" => println!("{}", report.to_json()),
        other => bail!("unknown audit format {other:?} (accepted: text, json)"),
    }
    if !report.ok() {
        bail!("audit failed: {} finding(s)", report.findings.len());
    }
    Ok(())
}

/// `drlfoam trace [FILE]`: summarize a Chrome-trace recording written by
/// `train --trace` — per-phase totals and lane count from the JSON, plus
/// the sibling `obs_summary.csv` / `drift.csv` tables when present.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = match args.positional.get(1) {
        Some(p) => std::path::PathBuf::from(p),
        None => out_dir(args).join("trace.json"),
    };
    print!("{}", drlfoam::obs::export::summarize_trace(&path)?);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let adir = artifact_dir(args);
    let m = Manifest::load(&adir)?;
    println!("artifacts: {} (kernels: {})", adir.display(), m.kernel_impl);
    println!(
        "policy: {} obs -> {}x{} -> {} act ({} params), minibatch {}",
        m.drl.n_obs, m.drl.hidden, m.drl.hidden, m.drl.n_act, m.drl.n_params, m.drl.minibatch
    );
    for (name, v) in &m.variants {
        println!(
            "variant {name}: {}x{} grid (h={:.4}), dt={}, {} substeps/period, {} SOR sweeps, cd0={:.3}",
            v.ny, v.nx, v.h, v.dt, v.substeps, v.n_sweeps, v.cd0
        );
    }
    // sanity: load everything once
    let mut rt = Runtime::new(&adir)?;
    for (_, v) in &m.variants {
        rt.load(&v.cfd_period_file)?;
    }
    rt.load(&m.drl.policy_apply_file)?;
    rt.load(&m.drl.ppo_update_file)?;
    let _ = Arc::new(m);
    println!("all artifacts compile OK");
    Ok(())
}
