//! Plain-text table rendering (paper-style) + CSV writing.

use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// Render an aligned text table. `rows` are pre-formatted cells.
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let line = |out: &mut String| {
        for w in &widths {
            out.push('+');
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    line(&mut out);
    out.push('|');
    for (h, w) in header.iter().zip(&widths) {
        out.push_str(&format!(" {h:>w$} |", w = w));
    }
    out.push('\n');
    line(&mut out);
    for row in rows {
        out.push('|');
        for (c, w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {c:>w$} |", w = w));
        }
        out.push('\n');
    }
    line(&mut out);
    out
}

/// Parse a CSV produced by [`write_csv`] back into `(header fields,
/// data rows)`. The format is the strict comma-separated subset this
/// crate emits (no quoting, no embedded commas); every row must match
/// the header's arity, so schema drift in any `out/*.csv` series fails
/// loudly in the tests that round-trip them (e.g. `plan.csv`).
pub fn parse_csv(text: &str) -> Result<(Vec<String>, Vec<Vec<String>>)> {
    let mut lines = text.lines();
    let split = |l: &str| -> Vec<String> { l.split(',').map(|s| s.to_string()).collect() };
    let header = split(lines.next().context("empty CSV")?);
    anyhow::ensure!(!header.is_empty(), "CSV header has no fields");
    let mut rows = Vec::new();
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row = split(line);
        anyhow::ensure!(
            row.len() == header.len(),
            "CSV row {} has {} fields, header has {}",
            i + 2,
            row.len(),
            header.len()
        );
        rows.push(row);
    }
    Ok((header, rows))
}

/// Write rows (first row = header) to a CSV file.
pub fn write_csv(path: impl AsRef<Path>, header: &str, rows: &[String]) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path.as_ref())?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = render_table(
            "T",
            &["a", "bb"],
            &[vec!["1".into(), "22".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("| 333 |"));
        assert!(t.lines().count() >= 6);
        // all data lines same width
        let widths: Vec<usize> = t.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn csv_parse_round_trip_and_arity_check() {
        let (h, rows) = parse_csv("a,b\n1,2\n\n3,4\n").unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(rows, vec![vec!["1", "2"], vec!["3", "4"]]);
        assert!(parse_csv("").is_err());
        let err = parse_csv("a,b\n1,2,3\n").unwrap_err().to_string();
        assert!(err.contains("3 fields"), "{err}");
    }

    #[test]
    fn csv_write() {
        let dir = std::env::temp_dir().join(format!("drlfoam-csv-{}", std::process::id()));
        let p = dir.join("t.csv");
        write_csv(&p, "a,b", &["1,2".to_string()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
