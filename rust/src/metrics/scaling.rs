//! Speedup / parallel-efficiency bookkeeping (paper's Eq.-less metrics).
//!
//! Conventions follow the paper exactly:
//! * Table I / Fig 8: reference = the single-env run *of the same rank
//!   set* (per-set reference).
//! * Fig 9: reference = the {ranks=1, envs=1} run for *all* points
//!   (global reference).
//! * Figs 11/12: per-strategy single-env reference.

/// speedup = T_ref / T
///
/// ```
/// assert_eq!(drlfoam::metrics::speedup(100.0, 50.0), 2.0);
/// ```
pub fn speedup(t_ref: f64, t: f64) -> f64 {
    t_ref / t
}

/// efficiency (%) = speedup / resource_ratio x 100, where resource ratio
/// is the factor of additional CPUs relative to the reference.
///
/// ```
/// use drlfoam::metrics::efficiency;
/// // double the CPUs, double the speed -> 100 %
/// assert!((efficiency(100.0, 50.0, 1, 2) - 100.0).abs() < 1e-12);
/// // double the CPUs, 1.6x the speed -> 80 %
/// assert!((efficiency(100.0, 62.5, 1, 2) - 80.0).abs() < 1e-12);
/// ```
pub fn efficiency(t_ref: f64, t: f64, cpus_ref: usize, cpus: usize) -> f64 {
    100.0 * speedup(t_ref, t) / (cpus as f64 / cpus_ref as f64)
}

/// One row of a scaling table (Table I / II superset).
#[derive(Clone, Debug)]
pub struct ScalingRow {
    pub episodes: usize,
    pub n_envs: usize,
    pub n_ranks: usize,
    pub total_cpus: usize,
    pub duration_h: f64,
    pub speedup: f64,
    pub efficiency_pct: f64,
}

impl ScalingRow {
    pub fn csv_header() -> &'static str {
        "episodes,n_envs,n_ranks,total_cpus,duration_h,speedup,efficiency_pct"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{:.4},{:.2},{:.1}",
            self.episodes,
            self.n_envs,
            self.n_ranks,
            self.total_cpus,
            self.duration_h,
            self.speedup,
            self.efficiency_pct
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_and_efficiency() {
        assert_eq!(speedup(100.0, 50.0), 2.0);
        // double the CPUs, double the speed -> 100%
        assert!((efficiency(100.0, 50.0, 1, 2) - 100.0).abs() < 1e-12);
        // double the CPUs, 1.6x speed -> 80%
        assert!((efficiency(100.0, 62.5, 1, 2) - 80.0).abs() < 1e-12);
        // per-set reference with 5 ranks: envs 1 -> 2 means cpus 5 -> 10
        assert!((efficiency(305.8, 170.8, 5, 10) - 89.52).abs() < 0.05);
    }
}
