//! Speedup/efficiency math, paper-style table rendering, CSV output.

pub mod scaling;
pub mod tables;

pub use scaling::{efficiency, speedup, ScalingRow};
pub use tables::{render_table, write_csv};
