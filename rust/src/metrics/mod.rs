//! Speedup/efficiency math, paper-style table rendering, CSV output —
//! the metric conventions of Table I / Figs 8–12, reused by
//! `reproduce` and by the allocation planner's ranking
//! (`crate::cluster::planner`).

pub mod scaling;
pub mod tables;

pub use scaling::{efficiency, speedup, ScalingRow};
pub use tables::{parse_csv, render_table, write_csv};
