//! Multi-environment worker pool.
//!
//! Mirrors the paper's resource model: each environment is an independent
//! instance of the configured *scenario* (an OS thread owning its own
//! [`Environment`] — for cylinder scenarios that means a private PJRT
//! client, compiled executables, flow state and exchange interface).
//! On this 1-core testbed threads interleave rather than truly parallelise
//! — the *structure* is the paper's, and the cluster DES (rust/src/cluster)
//! projects the measured per-component costs onto 60 cores.
//!
//! Two rollout modes (the paper's hybrid-parallelization axis):
//! * [`EnvPool::rollout`] — *per-env inference*: parameters are broadcast
//!   at episode boundaries and each worker serves its own policy
//!   ([`LocalPolicy`]); whole trajectories flow back over channels.
//! * [`EnvPool::rollout_batched`] — *central batched inference*: workers
//!   only advance the CFD; at every actuation period the coordinator
//!   gathers all observations at a sync barrier and a
//!   [`PolicyServer`](super::policy_server::PolicyServer) runs one batched
//!   forward pass for the whole environment set.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::coordinator::policy_server::PolicyServer;
use crate::drl::policy::{NativePolicy, PolicyBackendKind, PolicyOutput, PolicySession};
use crate::drl::{Policy, Trajectory, Transition};
use crate::env::scenario::{self, ScenarioContext, SURROGATE_HIDDEN, SURROGATE_N_OBS};
use crate::env::{Environment, StepResult};
use crate::io_interface::{IoMode, IoStats};
use crate::runtime::{Manifest, Runtime};
use crate::util::rng::Rng;

/// Static configuration shared by every worker of one pool.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub artifact_dir: std::path::PathBuf,
    pub work_dir: std::path::PathBuf,
    /// Manifest variant for scenarios that do not pin one (e.g. `cylinder`).
    pub variant: String,
    /// Scenario registry name (see [`crate::env::scenario::SCENARIOS`]).
    pub scenario: String,
    /// Per-env serving engine for [`EnvPool::rollout`] (ignored by the
    /// batched mode, where the coordinator's server does the inference).
    pub backend: PolicyBackendKind,
    pub n_envs: usize,
    pub io_mode: IoMode,
    pub seed: u64,
}

/// Per-episode summary returned alongside the trajectory.
#[derive(Clone, Debug, Default)]
pub struct EpisodeStats {
    pub reward_sum: f64,
    pub cd_mean: f64,
    pub cl_abs_mean: f64,
    pub jet_final: f64,
    pub cfd_s: f64,
    pub io_s: f64,
    pub policy_s: f64,
    pub wall_s: f64,
    pub io: IoStats,
}

/// One finished episode: who produced it, the trajectory, and its costs.
pub struct EpisodeOut {
    pub env_id: usize,
    pub traj: Trajectory,
    pub stats: EpisodeStats,
    /// When the episode actually finished (worker-side stamp). The
    /// scheduler measures barrier idle against this, NOT against when
    /// the coordinator got around to draining the channel — episodes
    /// completing while an update runs must charge that wait.
    pub completed_at: std::time::Instant,
}

enum Job {
    /// Per-env mode: roll a whole episode locally.
    Rollout {
        params: Arc<Vec<f32>>,
        horizon: usize,
        /// decorrelates exploration across envs and iterations
        episode_seed: u64,
    },
    /// Batched mode: reset the environment, reply with the initial obs.
    Reset,
    /// Batched mode: advance one actuation period with this action.
    Step { action: f64 },
    Shutdown,
}

/// Worker -> coordinator message for the lockstep (batched) protocol.
enum LockstepReply {
    Obs { env_id: usize, obs: Vec<f32> },
    Step { env_id: usize, result: StepResult },
}

/// Deterministic per-(iteration, env) exploration seed; shared by the
/// per-env dispatch path and the batched coordinator so the two inference
/// modes sample identical action sequences.
fn episode_seed(episode_index: u64, env_id: usize) -> u64 {
    episode_index
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(env_id as u64)
}

/// N scenario workers plus the channels to drive them (see module docs).
pub struct EnvPool {
    job_txs: Vec<Sender<Job>>,
    results: Receiver<Result<EpisodeOut>>,
    lockstep: Receiver<Result<LockstepReply>>,
    joins: Vec<Option<JoinHandle<()>>>,
    seed: u64,
    /// (n_obs, hidden) the workers' environments/policies are sized to
    dims: (usize, usize),
    /// per-env in-flight flag: true between [`EnvPool::dispatch`] and the
    /// receive of that env's episode (partial-barrier scheduling needs to
    /// know which envs can be re-dispatched)
    busy: Vec<bool>,
    /// finished episodes set aside while probing the results channel for
    /// a dead-worker root cause; drained before the channel on receive
    pending: VecDeque<EpisodeOut>,
}

impl EnvPool {
    /// Pool over AOT artifacts (cylinder scenarios, XLA policy serving).
    pub fn new(cfg: &PoolConfig, manifest: &Arc<Manifest>) -> Result<Self> {
        Self::spawn(cfg, Some(Arc::clone(manifest)))
    }

    /// Artifact-free pool: surrogate scenario + native policy only (CI and
    /// scaling studies with nothing compiled).
    pub fn standalone(cfg: &PoolConfig) -> Result<Self> {
        anyhow::ensure!(
            cfg.backend == PolicyBackendKind::Native,
            "standalone pools cannot serve an XLA policy (use PolicyBackendKind::Native)"
        );
        Self::spawn(cfg, None)
    }

    fn spawn(cfg: &PoolConfig, manifest: Option<Arc<Manifest>>) -> Result<Self> {
        // reject unknown scenario names here, in the caller's thread, so
        // the error is immediate instead of a dead worker
        scenario::spec(&cfg.scenario)?;
        let dims = match &manifest {
            Some(m) => (m.drl.n_obs, m.drl.hidden),
            None => (SURROGATE_N_OBS, SURROGATE_HIDDEN),
        };
        let mut job_txs = Vec::with_capacity(cfg.n_envs);
        let mut joins = Vec::with_capacity(cfg.n_envs);
        // one shared result channel: both the synchronous barrier and the
        // asynchronous trainer consume from it
        let (tx_out, rx_out) = channel::<Result<EpisodeOut>>();
        let (tx_step, rx_step) = channel::<Result<LockstepReply>>();
        for env_id in 0..cfg.n_envs {
            let (tx_job, rx_job) = channel::<Job>();
            let m = manifest.clone();
            let cfg = cfg.clone();
            let tx = tx_out.clone();
            let txs = tx_step.clone();
            let join = std::thread::Builder::new()
                .name(format!("env-{env_id}"))
                .spawn(move || worker_main(env_id, cfg, m, rx_job, tx, txs))
                .context("spawning env worker")?;
            job_txs.push(tx_job);
            joins.push(Some(join));
        }
        Ok(EnvPool {
            busy: vec![false; cfg.n_envs],
            pending: VecDeque::new(),
            job_txs,
            results: rx_out,
            lockstep: rx_step,
            joins,
            seed: cfg.seed,
            dims,
        })
    }

    pub fn n_envs(&self) -> usize {
        self.job_txs.len()
    }

    /// Observation width of the workers' environments.
    pub fn n_obs(&self) -> usize {
        self.dims.0
    }

    /// Hidden width the standalone native policy is sized to.
    pub fn hidden(&self) -> usize {
        self.dims.1
    }

    /// Dispatch one episode to a specific environment (partial-barrier
    /// and async scheduling). The env must not already have an episode in
    /// flight — the scheduler re-dispatches only after the previous
    /// episode was received.
    pub fn dispatch(
        &mut self,
        env_id: usize,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        episode_index: u64,
    ) -> Result<()> {
        anyhow::ensure!(
            !self.busy[env_id],
            "env {env_id} already has an episode in flight"
        );
        self.job_txs[env_id]
            .send(Job::Rollout {
                params: Arc::clone(params),
                horizon,
                episode_seed: episode_seed(episode_index, env_id),
            })
            .context("worker channel closed")?;
        self.busy[env_id] = true;
        Ok(())
    }

    /// Episodes currently in flight (dispatched, not yet received).
    pub fn in_flight(&self) -> usize {
        self.busy.iter().filter(|b| **b).count()
    }

    /// True while `env_id` has a dispatched episode not yet received.
    pub fn is_busy(&self, env_id: usize) -> bool {
        self.busy[env_id]
    }

    /// Receive the next finished episode from ANY environment, blocking
    /// until one arrives (partial-barrier and async scheduling).
    pub fn recv_one(&mut self) -> Result<EpisodeOut> {
        if let Some(out) = self.pending.pop_front() {
            return Ok(out);
        }
        let out = self.results.recv().context("all workers died")??;
        self.busy[out.env_id] = false;
        Ok(out)
    }

    /// Receive a finished episode if one is already queued, without
    /// blocking; `Ok(None)` means every in-flight episode is still
    /// running — lets a caller drain whatever has already arrived
    /// before deciding whether to block or do other work.
    pub fn try_recv_one(&mut self) -> Result<Option<EpisodeOut>> {
        if let Some(out) = self.pending.pop_front() {
            return Ok(Some(out));
        }
        match self.results.try_recv() {
            Ok(Ok(out)) => {
                self.busy[out.env_id] = false;
                Ok(Some(out))
            }
            Ok(Err(e)) => Err(e.context("env worker failed")),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow::anyhow!("all workers died")),
        }
    }

    /// Roll out one episode on every environment with per-env inference
    /// (the paper's synchronous iteration); blocks until all trajectories
    /// arrive (episode barrier).
    pub fn rollout(
        &mut self,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        iteration: u64,
    ) -> Result<Vec<EpisodeOut>> {
        for env_id in 0..self.job_txs.len() {
            self.dispatch(env_id, params, horizon, iteration)?;
        }
        let mut outs = Vec::with_capacity(self.job_txs.len());
        for _ in 0..self.job_txs.len() {
            outs.push(self.recv_one()?);
        }
        outs.sort_by_key(|o| o.env_id);
        Ok(outs)
    }

    /// Best-effort root cause when a worker goes away mid-lockstep: a
    /// worker that fails setup reports on the results channel and exits,
    /// which the lockstep path would otherwise only see as a dead channel.
    /// Finished episodes encountered while probing are re-queued (onto
    /// `pending`, drained by the next receive), never dropped.
    fn closed_reason(&mut self) -> anyhow::Error {
        loop {
            match self.results.try_recv() {
                Ok(Err(e)) => return e.context("env worker failed"),
                Ok(Ok(out)) => {
                    self.busy[out.env_id] = false;
                    self.pending.push_back(out);
                }
                Err(_) => return anyhow::anyhow!("worker channel closed"),
            }
        }
    }

    fn recv_lockstep(&mut self) -> Result<LockstepReply> {
        match self.lockstep.recv() {
            Ok(r) => r,
            Err(_) => Err(self.closed_reason()),
        }
    }

    /// Roll out one episode on every environment with CENTRAL batched
    /// inference: per actuation period the coordinator gathers all
    /// observations (sync barrier), `server` runs one batched forward
    /// pass, and the sampled actions are scattered back to the workers.
    ///
    /// Exploration uses the same per-(iteration, env) seed derivation as
    /// [`EnvPool::rollout`], so with a bitwise-matching server (native
    /// backend both sides) the two modes produce identical actions.
    ///
    /// `rt` is the coordinator runtime holding the server's compiled
    /// artifacts (`None` for native servers).
    pub fn rollout_batched(
        &mut self,
        rt: Option<&Runtime>,
        server: &mut PolicyServer,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        iteration: u64,
    ) -> Result<Vec<EpisodeOut>> {
        let jobs: Vec<(usize, u64)> = (0..self.job_txs.len()).map(|e| (e, iteration)).collect();
        self.rollout_batched_subset(rt, server, params, horizon, &jobs)
    }

    /// [`EnvPool::rollout_batched`] over an arbitrary SUBSET of the pool:
    /// `jobs` lists `(env_id, episode_index)` pairs, and the lockstep
    /// barrier (and the server's batch) spans only those environments —
    /// this is what lets central batched inference compose with the
    /// partial-barrier scheduler, which re-dispatches fewer than `n_envs`
    /// environments per round. Each env draws its exploration stream from
    /// its own `episode_index`, exactly like [`EnvPool::dispatch`].
    pub fn rollout_batched_subset(
        &mut self,
        rt: Option<&Runtime>,
        server: &mut PolicyServer,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        jobs: &[(usize, u64)],
    ) -> Result<Vec<EpisodeOut>> {
        let m = jobs.len();
        anyhow::ensure!(m > 0, "empty lockstep dispatch set");
        anyhow::ensure!(
            server.n_obs() == self.dims.0,
            "server n_obs {} != pool n_obs {}",
            server.n_obs(),
            self.dims.0
        );
        let mut slot_of: Vec<Option<usize>> = vec![None; self.job_txs.len()];
        for (slot, &(e, _)) in jobs.iter().enumerate() {
            anyhow::ensure!(e < self.job_txs.len(), "env id {e} out of range");
            anyhow::ensure!(
                slot_of[e].is_none(),
                "env {e} dispatched twice in one lockstep set"
            );
            slot_of[e] = Some(slot);
        }
        let t_start = std::time::Instant::now();
        server.set_params(rt, params)?;
        let policy = Policy::new(server.n_obs());
        let mut rngs: Vec<Rng> = jobs
            .iter()
            .map(|&(e, idx)| Rng::new(self.seed ^ episode_seed(idx, e)))
            .collect();

        for &(e, _) in jobs {
            if self.job_txs[e].send(Job::Reset).is_err() {
                return Err(self.closed_reason());
            }
        }
        let mut obs_all: Vec<Vec<f32>> = vec![Vec::new(); m];
        // per-env wall clock, reset-ack to last step-ack: the envs of one
        // lockstep set share every barrier, but their own service times
        // still differ — DES calibration must not see uniform episodes
        let mut t_reset_ack = vec![0.0f64; m];
        let mut t_last_ack = vec![0.0f64; m];
        for _ in 0..m {
            match self.recv_lockstep()? {
                LockstepReply::Obs { env_id, obs } => {
                    let slot = slot_of[env_id].context("reset reply from an undispatched env")?;
                    obs_all[slot] = obs;
                    t_reset_ack[slot] = t_start.elapsed().as_secs_f64();
                }
                LockstepReply::Step { .. } => bail!("unexpected step reply during reset"),
            }
        }

        let mut trajs: Vec<Trajectory> = jobs
            .iter()
            .map(|&(e, _)| Trajectory {
                env_id: e,
                ..Default::default()
            })
            .collect();
        let mut stats = vec![EpisodeStats::default(); m];
        let mut policy_total = 0.0f64;

        for _t in 0..horizon {
            let tp = std::time::Instant::now();
            let pouts = server.infer_batch(rt, params, &obs_all)?;
            policy_total += tp.elapsed().as_secs_f64();

            let mut actions: Vec<(f64, f64)> = Vec::with_capacity(m);
            for slot in 0..m {
                let (a, logp) = policy.sample(&pouts[slot], &mut rngs[slot]);
                actions.push((a, logp));
                if self.job_txs[jobs[slot].0].send(Job::Step { action: a }).is_err() {
                    return Err(self.closed_reason());
                }
            }
            for _ in 0..m {
                match self.recv_lockstep()? {
                    LockstepReply::Step { env_id, result: sr } => {
                        let slot = slot_of[env_id].context("step reply from an undispatched env")?;
                        let (a, logp) = actions[slot];
                        let st = &mut stats[slot];
                        st.cfd_s += sr.timings.cfd_s;
                        st.io_s += sr.timings.io_s;
                        st.io.accumulate(&sr.io);
                        st.reward_sum += sr.reward;
                        st.cd_mean += sr.cd_mean / horizon as f64;
                        st.cl_abs_mean += sr.cl_mean.abs() / horizon as f64;
                        st.jet_final = sr.jet;
                        trajs[slot].transitions.push(Transition {
                            obs: std::mem::take(&mut obs_all[slot]),
                            action: a,
                            logp,
                            reward: sr.reward,
                            value: pouts[slot].value,
                        });
                        obs_all[slot] = sr.obs;
                        t_last_ack[slot] = t_start.elapsed().as_secs_f64();
                    }
                    LockstepReply::Obs { .. } => bail!("unexpected reset reply during step"),
                }
            }
        }

        // bootstrap values for the truncated horizon, one last batch pass
        let tp = std::time::Instant::now();
        let pouts = server.infer_batch(rt, params, &obs_all)?;
        policy_total += tp.elapsed().as_secs_f64();
        // the lockstep set completes together at the final barrier
        let completed_at = std::time::Instant::now();

        Ok(trajs
            .into_iter()
            .zip(stats)
            .enumerate()
            .map(|(slot, (mut traj, mut st))| {
                traj.last_value = pouts[slot].value;
                // the batched pass serves the whole set at once; attribute
                // an equal share so per-episode stats stay comparable
                st.policy_s = policy_total / m as f64;
                st.wall_s = (t_last_ack[slot] - t_reset_ack[slot]).max(0.0);
                EpisodeOut {
                    env_id: jobs[slot].0,
                    traj,
                    stats: st,
                    completed_at,
                }
            })
            .collect())
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        for tx in &self.job_txs {
            let _ = tx.send(Job::Shutdown);
        }
        for j in &mut self.joins {
            if let Some(j) = j.take() {
                let _ = j.join();
            }
        }
    }
}

/// The per-env serving engine (one per worker; also reused by the CLI's
/// one-shot `episode` command). XLA serving compiles into and executes on
/// the *environment's* runtime, so a worker runs exactly one PJRT client.
pub enum LocalPolicy {
    /// The `policy_apply` artifact on the environment's runtime;
    /// parameters uploaded once per episode ([`PolicySession`]).
    Xla {
        file: String,
        n_obs: usize,
        session: Option<PolicySession>,
    },
    /// Pure-Rust forward pass; no runtime at all.
    Native(NativePolicy),
}

impl LocalPolicy {
    /// XLA serving over the manifest's policy artifact (lazily compiled
    /// into the environment's runtime at the first episode).
    pub fn xla(drl: &crate::runtime::DrlManifest) -> Self {
        LocalPolicy::Xla {
            file: drl.policy_apply_file.clone(),
            n_obs: drl.n_obs,
            session: None,
        }
    }

    /// Native serving sized to (n_obs, hidden).
    pub fn native(n_obs: usize, hidden: usize) -> Self {
        LocalPolicy::Native(NativePolicy::new(n_obs, hidden))
    }

    /// Params are constant for a whole episode: upload once (perf fast
    /// path, 3.1x on serving latency — EXPERIMENTS.md section Perf).
    pub fn begin_episode(&mut self, env: &mut dyn Environment, params: &[f32]) -> Result<()> {
        if let LocalPolicy::Xla {
            file,
            n_obs,
            session,
        } = self
        {
            let rt = env.runtime_mut().context(
                "the xla policy backend needs an XLA-backed scenario (try --backend native)",
            )?;
            rt.load(file)?;
            *session = Some(PolicySession::new(rt, params, *n_obs)?);
        }
        Ok(())
    }

    /// Evaluate the policy on one observation.
    pub fn apply(
        &self,
        env: &mut dyn Environment,
        params: &[f32],
        obs: &[f32],
    ) -> Result<PolicyOutput> {
        match self {
            LocalPolicy::Xla { file, session, .. } => {
                let rt = env
                    .runtime_mut()
                    .context("the xla policy backend needs an XLA-backed scenario")?;
                let exe = rt.get(file)?;
                session
                    .as_ref()
                    .context("begin_episode not called")?
                    .apply(rt, exe, obs)
            }
            LocalPolicy::Native(net) => net.apply(params, obs),
        }
    }
}

fn worker_main(
    env_id: usize,
    cfg: PoolConfig,
    manifest: Option<Arc<Manifest>>,
    rx: Receiver<Job>,
    tx: Sender<Result<EpisodeOut>>,
    tx_step: Sender<Result<LockstepReply>>,
) {
    // Environments and PJRT clients are built *inside* the thread: neither
    // is Send. Only the scenario name + config ingredients crossed over.
    let setup = (|| -> Result<(Box<dyn Environment>, LocalPolicy, Policy)> {
        let ctx = ScenarioContext {
            artifact_dir: &cfg.artifact_dir,
            work_dir: &cfg.work_dir,
            env_id,
            io_mode: cfg.io_mode,
            manifest: manifest.as_deref(),
            variant: &cfg.variant,
            seed: cfg.seed,
        };
        let env = scenario::build(&cfg.scenario, &ctx)?;
        let lp = match cfg.backend {
            PolicyBackendKind::Xla => {
                let m = manifest
                    .as_ref()
                    .context("XLA policy backend requires AOT artifacts")?;
                LocalPolicy::xla(&m.drl)
            }
            PolicyBackendKind::Native => {
                let (n_obs, hidden) = match &manifest {
                    Some(m) => (m.drl.n_obs, m.drl.hidden),
                    None => (SURROGATE_N_OBS, SURROGATE_HIDDEN),
                };
                LocalPolicy::native(n_obs, hidden)
            }
        };
        let policy = Policy::new(env.n_obs());
        Ok((env, lp, policy))
    })();

    let (mut env, mut lp, policy) = match setup {
        Ok(x) => x,
        Err(e) => {
            // the lockstep coordinator waits on the step channel, the
            // episode coordinator on the results channel: report the
            // setup failure on BOTH so neither rollout mode can hang
            // waiting for a worker that will never reply
            let _ = tx_step.send(Err(anyhow::anyhow!("env worker setup failed: {e:#}")));
            let _ = tx.send(Err(e));
            return;
        }
    };

    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::Rollout {
                params,
                horizon,
                episode_seed,
            } => {
                let out = run_episode(
                    env_id,
                    env.as_mut(),
                    &mut lp,
                    &policy,
                    &params,
                    horizon,
                    cfg.seed ^ episode_seed,
                );
                if tx.send(out).is_err() {
                    break;
                }
            }
            Job::Reset => {
                let r = env.reset().map(|obs| LockstepReply::Obs { env_id, obs });
                if tx_step.send(r).is_err() {
                    break;
                }
            }
            Job::Step { action } => {
                let r = env
                    .step(action)
                    .map(|result| LockstepReply::Step { env_id, result });
                if tx_step.send(r).is_err() {
                    break;
                }
            }
        }
    }
}

fn run_episode(
    env_id: usize,
    env: &mut dyn Environment,
    lp: &mut LocalPolicy,
    policy: &Policy,
    params: &Arc<Vec<f32>>,
    horizon: usize,
    seed: u64,
) -> Result<EpisodeOut> {
    let t_wall = std::time::Instant::now();
    lp.begin_episode(env, params)?;
    let mut rng = Rng::new(seed);

    let mut stats = EpisodeStats::default();
    let mut traj = Trajectory {
        env_id,
        ..Default::default()
    };

    let mut obs = env.reset()?;
    for _t in 0..horizon {
        let tp = std::time::Instant::now();
        let pout = lp.apply(env, params, &obs)?;
        let (action, logp) = policy.sample(&pout, &mut rng);
        stats.policy_s += tp.elapsed().as_secs_f64();

        let sr = env.step(action)?;
        stats.cfd_s += sr.timings.cfd_s;
        stats.io_s += sr.timings.io_s;
        stats.io.accumulate(&sr.io);
        stats.reward_sum += sr.reward;
        stats.cd_mean += sr.cd_mean / horizon as f64;
        stats.cl_abs_mean += sr.cl_mean.abs() / horizon as f64;
        stats.jet_final = sr.jet;

        traj.transitions.push(Transition {
            obs: std::mem::take(&mut obs),
            action,
            logp,
            reward: sr.reward,
            value: pout.value,
        });
        obs = sr.obs;
    }
    // bootstrap value for the truncated horizon
    let tp = std::time::Instant::now();
    traj.last_value = lp.apply(env, params, &obs)?.value;
    stats.policy_s += tp.elapsed().as_secs_f64();
    stats.wall_s = t_wall.elapsed().as_secs_f64();

    Ok(EpisodeOut {
        env_id,
        traj,
        stats,
        completed_at: std::time::Instant::now(),
    })
}
