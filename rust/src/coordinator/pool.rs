//! Multi-environment worker pool.
//!
//! Mirrors the paper's resource model: each environment is an independent
//! instance of the configured *scenario* owning its own [`Environment`] —
//! for cylinder scenarios that means a private PJRT client, compiled
//! executables, flow state and exchange interface. *Where* those workers
//! live is the [`crate::exec`] axis: OS threads inside this process
//! (`ExecutorKind::InProcess`, the default) or real `drlfoam worker` OS
//! processes in per-env rank groups (`ExecutorKind::MultiProcess`, the
//! paper's per-rank placement). The pool drives either backend through
//! one [`Executor`] handle, so every rollout mode and sync policy works
//! unchanged over both.
//!
//! Two rollout modes (the paper's hybrid-parallelization axis):
//! * [`EnvPool::rollout`] — *per-env inference*: parameters are broadcast
//!   at episode boundaries and each worker serves its own policy
//!   ([`LocalPolicy`]); whole trajectories flow back.
//! * [`EnvPool::rollout_batched`] — *central batched inference*: workers
//!   only advance the CFD; at every actuation period the coordinator
//!   gathers all observations at a sync barrier and a
//!   [`PolicyServer`](super::policy_server::PolicyServer) runs one batched
//!   forward pass for the whole environment set.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::policy_server::PolicyServer;
use crate::drl::policy::{NativePolicy, PolicyBackendKind, PolicyOutput, PolicySession};
use crate::drl::{Policy, Trajectory, Transition};
use crate::cfd::CfdBackend;
use crate::env::scenario::{self, policy_dims, ScenarioContext};
use crate::env::Environment;
use crate::exec::inprocess::InProcessExecutor;
use crate::exec::process::ProcessExecutor;
use crate::exec::{Executor, ExecutorKind, Job, LockstepReply, TransportKind};
use crate::io_interface::{IoMode, IoStats};
use crate::runtime::{Manifest, Runtime};
use crate::util::rng::Rng;

/// Static configuration shared by every worker of one pool.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub artifact_dir: std::path::PathBuf,
    pub work_dir: std::path::PathBuf,
    /// Manifest variant for scenarios that do not pin one (e.g. `cylinder`).
    pub variant: String,
    /// Scenario registry name (see [`crate::env::scenario::SCENARIOS`]).
    pub scenario: String,
    /// Per-env serving engine for [`EnvPool::rollout`] (ignored by the
    /// batched mode, where the coordinator's server does the inference).
    pub backend: PolicyBackendKind,
    /// Which engine advances cylinder CFD periods (`--cfd-backend`):
    /// the AOT XLA executable or the pure-Rust native engine.
    pub cfd_backend: CfdBackend,
    pub n_envs: usize,
    pub io_mode: IoMode,
    pub seed: u64,
    /// Threads in this process, or `drlfoam worker` OS processes.
    pub executor: ExecutorKind,
    /// Processes per environment under the multi-process executor (the
    /// paper's `N_ranks`): rank 0 runs the episodes, ranks 1.. hold
    /// their core as placement members. Must be 1 in-process.
    pub ranks_per_env: usize,
    /// Binary to self-exec for workers; `None` = `current_exe()` (tests
    /// point this at the real `drlfoam` binary, since *their* own
    /// executable has no `worker` subcommand).
    pub worker_bin: Option<std::path::PathBuf>,
    /// Chaos hook `"<env>:<episode>[:midframe]"`: that worker aborts
    /// once upon receiving that episode's dispatch — with `midframe`,
    /// after also leaving a partially written frame on each channel
    /// (multi-process only; drives the fault-recovery tests and
    /// `train --chaos`).
    pub fault_injection: Option<String>,
    /// Data plane of the multi-process executor: every frame over the
    /// worker pipes, data frames over shared-memory seqlock rings with
    /// the pipe as control channel + fallback, or every frame over a
    /// TCP / Unix-domain socket (`--transport`).
    pub transport: TransportKind,
    /// `--hosts` topology: `drlfoam agent` endpoints with their core
    /// counts. Empty = spawn workers directly on this machine; non-empty
    /// requires a socket transport, and rank groups are first-fit packed
    /// across the listed hosts.
    pub hosts: Vec<crate::exec::net::HostSpec>,
    /// Obs tracing (`train --trace`): in-process workers record spans
    /// directly, process workers are spawned with `--trace-spans` and
    /// batch them back over `Frame::Telemetry` (ARCHITECTURE.md §12).
    pub trace: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            artifact_dir: "artifacts".into(),
            work_dir: "out/work".into(),
            variant: "small".into(),
            scenario: "cylinder".into(),
            backend: PolicyBackendKind::Xla,
            cfd_backend: CfdBackend::Xla,
            n_envs: 1,
            io_mode: IoMode::InMemory,
            seed: 0,
            executor: ExecutorKind::InProcess,
            ranks_per_env: 1,
            worker_bin: None,
            fault_injection: None,
            transport: TransportKind::Pipe,
            hosts: Vec::new(),
            trace: false,
        }
    }
}

/// Per-episode summary returned alongside the trajectory.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EpisodeStats {
    pub reward_sum: f64,
    pub cd_mean: f64,
    pub cl_abs_mean: f64,
    pub jet_final: f64,
    pub cfd_s: f64,
    pub io_s: f64,
    pub policy_s: f64,
    pub wall_s: f64,
    pub io: IoStats,
}

/// One finished episode: who produced it, the trajectory, and its costs.
pub struct EpisodeOut {
    pub env_id: usize,
    pub traj: Trajectory,
    pub stats: EpisodeStats,
    /// When the episode actually finished (worker-side stamp; for
    /// process workers, the coordinator-side frame-arrival stamp). The
    /// scheduler measures barrier idle against this, NOT against when
    /// the coordinator got around to draining the queue — episodes
    /// completing while an update runs must charge that wait.
    pub completed_at: std::time::Instant,
}

/// Per-environment wall/CPU roll-up across every episode the pool
/// returned: feeds `out/workers.csv` and — under `--layout auto
/// --executor multi-process` —
/// [`Calibration::from_measured`](crate::cluster::Calibration::from_measured),
/// so auto-planning calibrates from *real process* timings instead of
/// the in-process surrogate.
#[derive(Clone, Debug, Default)]
pub struct EnvTelemetry {
    pub episodes: usize,
    pub wall_s: f64,
    pub cfd_s: f64,
    pub io_s: f64,
    pub policy_s: f64,
}

/// Deterministic per-(iteration, env) exploration seed; shared by the
/// per-env dispatch path and the batched coordinator so the two inference
/// modes sample identical action sequences.
fn episode_seed(episode_index: u64, env_id: usize) -> u64 {
    episode_index
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(env_id as u64)
}

/// N scenario workers plus the executor handle that drives them (see
/// module docs).
pub struct EnvPool {
    exec: Box<dyn Executor>,
    kind: ExecutorKind,
    seed: u64,
    /// (n_obs, hidden) the workers' environments/policies are sized to
    dims: (usize, usize),
    /// per-env in-flight flag: true between [`EnvPool::dispatch`] and the
    /// receive of that env's episode (partial-barrier scheduling needs to
    /// know which envs can be re-dispatched)
    busy: Vec<bool>,
    telemetry: Vec<EnvTelemetry>,
}

impl EnvPool {
    /// Pool over AOT artifacts (cylinder scenarios, XLA policy serving).
    pub fn new(cfg: &PoolConfig, manifest: &Arc<Manifest>) -> Result<Self> {
        Self::spawn(cfg, Some(Arc::clone(manifest)))
    }

    /// Artifact-free pool: surrogate scenario + native policy only (CI and
    /// scaling studies with nothing compiled).
    pub fn standalone(cfg: &PoolConfig) -> Result<Self> {
        anyhow::ensure!(
            cfg.backend == PolicyBackendKind::Native,
            "standalone pools cannot serve an XLA policy (use PolicyBackendKind::Native)"
        );
        Self::spawn(cfg, None)
    }

    fn spawn(cfg: &PoolConfig, manifest: Option<Arc<Manifest>>) -> Result<Self> {
        // reject unknown scenario names here, in the caller's thread, so
        // the error is immediate instead of a dead worker
        scenario::spec(&cfg.scenario)?;
        anyhow::ensure!(cfg.n_envs >= 1, "need at least one environment");
        let dims = policy_dims(&cfg.scenario, cfg.cfd_backend, manifest.as_deref());
        let exec: Box<dyn Executor> = match cfg.executor {
            ExecutorKind::InProcess => {
                anyhow::ensure!(
                    cfg.ranks_per_env <= 1,
                    "in-process workers are single-rank (got ranks_per_env = {}); \
                     use --executor multi-process to spawn rank groups",
                    cfg.ranks_per_env
                );
                anyhow::ensure!(
                    cfg.transport == TransportKind::Pipe,
                    "--transport {} needs worker processes; use --executor multi-process",
                    cfg.transport.name()
                );
                anyhow::ensure!(
                    cfg.hosts.is_empty(),
                    "--hosts spans machines and needs --executor multi-process with \
                     --transport tcp or uds"
                );
                Box::new(InProcessExecutor::spawn(cfg, manifest)?)
            }
            // process workers load their own manifest from artifact_dir;
            // the coordinator's copy only sized `dims` above
            ExecutorKind::MultiProcess => Box::new(ProcessExecutor::spawn(cfg)?),
        };
        Ok(EnvPool {
            exec,
            kind: cfg.executor,
            busy: vec![false; cfg.n_envs],
            telemetry: vec![EnvTelemetry::default(); cfg.n_envs],
            seed: cfg.seed,
            dims,
        })
    }

    pub fn n_envs(&self) -> usize {
        self.busy.len()
    }

    /// Observation width of the workers' environments.
    pub fn n_obs(&self) -> usize {
        self.dims.0
    }

    /// Hidden width the standalone native policy is sized to.
    pub fn hidden(&self) -> usize {
        self.dims.1
    }

    /// Which execution backend this pool runs on.
    pub fn executor(&self) -> ExecutorKind {
        self.kind
    }

    /// Workers respawned after faults (0 in-process).
    pub fn restarts(&self) -> usize {
        self.exec.restarts()
    }

    /// Per-env respawn counts (`workers.csv`).
    pub fn restarts_by_env(&self) -> Vec<usize> {
        self.exec.restarts_by_env()
    }

    /// OS pids of every live worker process (empty in-process).
    pub fn worker_pids(&self) -> Vec<u32> {
        self.exec.worker_pids()
    }

    /// Fault injection: SIGKILL `env_id`'s primary worker process. The
    /// pool recovers on the next receive — respawn + episode re-queue —
    /// which is exactly what `rust/tests/exec_backend.rs` asserts.
    pub fn kill_worker(&mut self, env_id: usize) -> Result<()> {
        self.exec.kill_worker(env_id)
    }

    /// Per-env cost roll-up over every episode returned so far.
    pub fn telemetry(&self) -> &[EnvTelemetry] {
        &self.telemetry
    }

    fn note(&mut self, out: &EpisodeOut) {
        let t = &mut self.telemetry[out.env_id];
        t.episodes += 1;
        t.wall_s += out.stats.wall_s;
        t.cfd_s += out.stats.cfd_s;
        t.io_s += out.stats.io_s;
        t.policy_s += out.stats.policy_s;
    }

    /// Dispatch one episode to a specific environment (partial-barrier
    /// and async scheduling). The env must not already have an episode in
    /// flight — the scheduler re-dispatches only after the previous
    /// episode was received.
    pub fn dispatch(
        &mut self,
        env_id: usize,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        episode_index: u64,
    ) -> Result<()> {
        anyhow::ensure!(
            !self.busy[env_id],
            "env {env_id} already has an episode in flight"
        );
        self.exec
            .send(
                env_id,
                Job::Rollout {
                    params: Arc::clone(params),
                    horizon,
                    episode: episode_index,
                    episode_seed: episode_seed(episode_index, env_id),
                },
            )
            .context("dispatching episode")?;
        self.busy[env_id] = true;
        Ok(())
    }

    /// Episodes currently in flight (dispatched, not yet received).
    pub fn in_flight(&self) -> usize {
        self.busy.iter().filter(|b| **b).count()
    }

    /// True while `env_id` has a dispatched episode not yet received.
    pub fn is_busy(&self, env_id: usize) -> bool {
        self.busy[env_id]
    }

    /// Receive the next finished episode from ANY environment, blocking
    /// until one arrives (partial-barrier and async scheduling).
    pub fn recv_one(&mut self) -> Result<EpisodeOut> {
        let out = self.exec.recv_episode()?;
        self.busy[out.env_id] = false;
        self.note(&out);
        Ok(out)
    }

    /// Receive a finished episode if one is already queued, without
    /// blocking; `Ok(None)` means every in-flight episode is still
    /// running — lets a caller drain whatever has already arrived
    /// before deciding whether to block or do other work.
    pub fn try_recv_one(&mut self) -> Result<Option<EpisodeOut>> {
        match self.exec.try_recv_episode()? {
            Some(out) => {
                self.busy[out.env_id] = false;
                self.note(&out);
                Ok(Some(out))
            }
            None => Ok(None),
        }
    }

    /// Roll out one episode on every environment with per-env inference
    /// (the paper's synchronous iteration); blocks until all trajectories
    /// arrive (episode barrier).
    pub fn rollout(
        &mut self,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        iteration: u64,
    ) -> Result<Vec<EpisodeOut>> {
        for env_id in 0..self.n_envs() {
            self.dispatch(env_id, params, horizon, iteration)?;
        }
        let mut outs = Vec::with_capacity(self.n_envs());
        for _ in 0..self.n_envs() {
            outs.push(self.recv_one()?);
        }
        outs.sort_by_key(|o| o.env_id);
        Ok(outs)
    }

    /// Roll out one episode on every environment with CENTRAL batched
    /// inference: per actuation period the coordinator gathers all
    /// observations (sync barrier), `server` runs one batched forward
    /// pass, and the sampled actions are scattered back to the workers.
    ///
    /// Exploration uses the same per-(iteration, env) seed derivation as
    /// [`EnvPool::rollout`], so with a bitwise-matching server (native
    /// backend both sides) the two modes produce identical actions.
    ///
    /// `rt` is the coordinator runtime holding the server's compiled
    /// artifacts (`None` for native servers).
    pub fn rollout_batched(
        &mut self,
        rt: Option<&Runtime>,
        server: &mut PolicyServer,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        iteration: u64,
    ) -> Result<Vec<EpisodeOut>> {
        let jobs: Vec<(usize, u64)> = (0..self.n_envs()).map(|e| (e, iteration)).collect();
        self.rollout_batched_subset(rt, server, params, horizon, &jobs)
    }

    /// [`EnvPool::rollout_batched`] over an arbitrary SUBSET of the pool:
    /// `jobs` lists `(env_id, episode_index)` pairs, and the lockstep
    /// barrier (and the server's batch) spans only those environments —
    /// this is what lets central batched inference compose with the
    /// partial-barrier scheduler, which re-dispatches fewer than `n_envs`
    /// environments per round. Each env draws its exploration stream from
    /// its own `episode_index`, exactly like [`EnvPool::dispatch`].
    pub fn rollout_batched_subset(
        &mut self,
        rt: Option<&Runtime>,
        server: &mut PolicyServer,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        jobs: &[(usize, u64)],
    ) -> Result<Vec<EpisodeOut>> {
        let m = jobs.len();
        anyhow::ensure!(m > 0, "empty lockstep dispatch set");
        anyhow::ensure!(
            server.n_obs() == self.dims.0,
            "server n_obs {} != pool n_obs {}",
            server.n_obs(),
            self.dims.0
        );
        let mut slot_of: Vec<Option<usize>> = vec![None; self.n_envs()];
        for (slot, &(e, _)) in jobs.iter().enumerate() {
            anyhow::ensure!(e < self.n_envs(), "env id {e} out of range");
            anyhow::ensure!(
                slot_of[e].is_none(),
                "env {e} dispatched twice in one lockstep set"
            );
            slot_of[e] = Some(slot);
        }
        let t_start = std::time::Instant::now();
        server.set_params(rt, params)?;
        let policy = Policy::new(server.n_obs());
        let mut rngs: Vec<Rng> = jobs
            .iter()
            .map(|&(e, idx)| Rng::new(self.seed ^ episode_seed(idx, e)))
            .collect();

        for &(e, _) in jobs {
            self.exec.send(e, Job::Reset)?;
        }
        let mut obs_all: Vec<Vec<f32>> = vec![Vec::new(); m];
        // per-env wall clock, reset-ack to last step-ack: the envs of one
        // lockstep set share every barrier, but their own service times
        // still differ — DES calibration must not see uniform episodes
        let mut t_reset_ack = vec![0.0f64; m];
        let mut t_last_ack = vec![0.0f64; m];
        for _ in 0..m {
            match self.exec.recv_lockstep()? {
                LockstepReply::Obs { env_id, obs } => {
                    let slot = slot_of[env_id].context("reset reply from an undispatched env")?;
                    obs_all[slot] = obs;
                    t_reset_ack[slot] = t_start.elapsed().as_secs_f64();
                }
                LockstepReply::Step { .. } => bail!("unexpected step reply during reset"),
            }
        }

        let mut trajs: Vec<Trajectory> = jobs
            .iter()
            .map(|&(e, _)| Trajectory {
                env_id: e,
                ..Default::default()
            })
            .collect();
        let mut stats = vec![EpisodeStats::default(); m];
        let mut policy_total = 0.0f64;

        for _t in 0..horizon {
            let tp = std::time::Instant::now();
            let pouts = server.infer_batch(rt, params, &obs_all)?;
            policy_total += tp.elapsed().as_secs_f64();

            let mut actions: Vec<(f64, f64)> = Vec::with_capacity(m);
            for slot in 0..m {
                let (a, logp) = policy.sample(&pouts[slot], &mut rngs[slot]);
                actions.push((a, logp));
                self.exec.send(jobs[slot].0, Job::Step { action: a })?;
            }
            for _ in 0..m {
                match self.exec.recv_lockstep()? {
                    LockstepReply::Step { env_id, result: sr } => {
                        let slot = slot_of[env_id].context("step reply from an undispatched env")?;
                        let (a, logp) = actions[slot];
                        let st = &mut stats[slot];
                        st.cfd_s += sr.timings.cfd_s;
                        st.io_s += sr.timings.io_s;
                        st.io.accumulate(&sr.io);
                        st.reward_sum += sr.reward;
                        st.cd_mean += sr.cd_mean / horizon as f64;
                        st.cl_abs_mean += sr.cl_mean.abs() / horizon as f64;
                        st.jet_final = sr.jet;
                        trajs[slot].transitions.push(Transition {
                            obs: std::mem::take(&mut obs_all[slot]),
                            action: a,
                            logp,
                            reward: sr.reward,
                            value: pouts[slot].value,
                        });
                        obs_all[slot] = sr.obs;
                        t_last_ack[slot] = t_start.elapsed().as_secs_f64();
                    }
                    LockstepReply::Obs { .. } => bail!("unexpected reset reply during step"),
                }
            }
        }

        // bootstrap values for the truncated horizon, one last batch pass
        let tp = std::time::Instant::now();
        let pouts = server.infer_batch(rt, params, &obs_all)?;
        policy_total += tp.elapsed().as_secs_f64();
        // the lockstep set completes together at the final barrier
        let completed_at = std::time::Instant::now();

        let outs: Vec<EpisodeOut> = trajs
            .into_iter()
            .zip(stats)
            .enumerate()
            .map(|(slot, (mut traj, mut st))| {
                traj.last_value = pouts[slot].value;
                // the batched pass serves the whole set at once; attribute
                // an equal share so per-episode stats stay comparable
                st.policy_s = policy_total / m as f64;
                st.wall_s = (t_last_ack[slot] - t_reset_ack[slot]).max(0.0);
                EpisodeOut {
                    env_id: jobs[slot].0,
                    traj,
                    stats: st,
                    completed_at,
                }
            })
            .collect();
        for out in &outs {
            self.note(out);
        }
        Ok(outs)
    }
}

/// The per-env serving engine (one per worker; also reused by the CLI's
/// one-shot `episode` command). XLA serving compiles into and executes on
/// the *environment's* runtime, so a worker runs exactly one PJRT client.
pub enum LocalPolicy {
    /// The `policy_apply` artifact on the environment's runtime;
    /// parameters uploaded once per episode ([`PolicySession`]).
    Xla {
        file: String,
        n_obs: usize,
        session: Option<PolicySession>,
    },
    /// Pure-Rust forward pass; no runtime at all.
    Native(NativePolicy),
}

impl LocalPolicy {
    /// XLA serving over the manifest's policy artifact (lazily compiled
    /// into the environment's runtime at the first episode).
    pub fn xla(drl: &crate::runtime::DrlManifest) -> Self {
        LocalPolicy::Xla {
            file: drl.policy_apply_file.clone(),
            n_obs: drl.n_obs,
            session: None,
        }
    }

    /// Native serving sized to (n_obs, hidden).
    pub fn native(n_obs: usize, hidden: usize) -> Self {
        LocalPolicy::Native(NativePolicy::new(n_obs, hidden))
    }

    /// Params are constant for a whole episode: upload once (perf fast
    /// path, 3.1x on serving latency — EXPERIMENTS.md section Perf).
    pub fn begin_episode(&mut self, env: &mut dyn Environment, params: &[f32]) -> Result<()> {
        if let LocalPolicy::Xla {
            file,
            n_obs,
            session,
        } = self
        {
            let rt = env.runtime_mut().context(
                "the xla policy backend needs an XLA-backed scenario (try --backend native)",
            )?;
            rt.load(file)?;
            *session = Some(PolicySession::new(rt, params, *n_obs)?);
        }
        Ok(())
    }

    /// Evaluate the policy on one observation.
    pub fn apply(
        &self,
        env: &mut dyn Environment,
        params: &[f32],
        obs: &[f32],
    ) -> Result<PolicyOutput> {
        match self {
            LocalPolicy::Xla { file, session, .. } => {
                let rt = env
                    .runtime_mut()
                    .context("the xla policy backend needs an XLA-backed scenario")?;
                let exe = rt.get(file)?;
                session
                    .as_ref()
                    .context("begin_episode not called")?
                    .apply(rt, exe, obs)
            }
            LocalPolicy::Native(net) => net.apply(params, obs),
        }
    }
}

/// Build one worker's environment + serving engine; shared by the
/// in-process thread workers and the `drlfoam worker` process (so the
/// two execution backends cannot drift).
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_worker(
    env_id: usize,
    artifact_dir: &Path,
    work_dir: &Path,
    variant: &str,
    scenario_name: &str,
    io_mode: IoMode,
    seed: u64,
    backend: PolicyBackendKind,
    cfd_backend: CfdBackend,
    manifest: Option<&Manifest>,
) -> Result<(Box<dyn Environment>, LocalPolicy, Policy)> {
    let ctx = ScenarioContext {
        artifact_dir,
        work_dir,
        env_id,
        io_mode,
        manifest,
        variant,
        cfd_backend,
        seed,
    };
    let env = scenario::build(scenario_name, &ctx)?;
    let lp = match backend {
        PolicyBackendKind::Xla => {
            let m = manifest.context("XLA policy backend requires AOT artifacts")?;
            LocalPolicy::xla(&m.drl)
        }
        PolicyBackendKind::Native => {
            let (n_obs, hidden) = policy_dims(scenario_name, cfd_backend, manifest);
            LocalPolicy::native(n_obs, hidden)
        }
    };
    let policy = Policy::new(env.n_obs());
    Ok((env, lp, policy))
}

/// One full per-env episode: reset, `horizon` actuation periods served by
/// `lp`, bootstrap value. Runs identically on a worker thread and inside
/// a `drlfoam worker` process.
pub(crate) fn run_episode(
    env_id: usize,
    env: &mut dyn Environment,
    lp: &mut LocalPolicy,
    policy: &Policy,
    params: &Arc<Vec<f32>>,
    horizon: usize,
    seed: u64,
) -> Result<EpisodeOut> {
    let t_wall = std::time::Instant::now();
    lp.begin_episode(env, params)?;
    let mut rng = Rng::new(seed);

    let mut stats = EpisodeStats::default();
    let mut traj = Trajectory {
        env_id,
        ..Default::default()
    };

    let mut obs = env.reset()?;
    for _t in 0..horizon {
        let tp = std::time::Instant::now();
        let pout = lp.apply(env, params, &obs)?;
        let (action, logp) = policy.sample(&pout, &mut rng);
        let policy_dt = tp.elapsed().as_secs_f64();
        stats.policy_s += policy_dt;
        crate::obs::record_measured_here(crate::obs::Phase::Policy, tp, policy_dt);

        let sr = env.step(action)?;
        stats.cfd_s += sr.timings.cfd_s;
        stats.io_s += sr.timings.io_s;
        stats.io.accumulate(&sr.io);
        stats.reward_sum += sr.reward;
        stats.cd_mean += sr.cd_mean / horizon as f64;
        stats.cl_abs_mean += sr.cl_mean.abs() / horizon as f64;
        stats.jet_final = sr.jet;

        traj.transitions.push(Transition {
            obs: std::mem::take(&mut obs),
            action,
            logp,
            reward: sr.reward,
            value: pout.value,
        });
        obs = sr.obs;
    }
    // bootstrap value for the truncated horizon
    let tp = std::time::Instant::now();
    traj.last_value = lp.apply(env, params, &obs)?.value;
    let policy_dt = tp.elapsed().as_secs_f64();
    stats.policy_s += policy_dt;
    crate::obs::record_measured_here(crate::obs::Phase::Policy, tp, policy_dt);
    stats.wall_s = t_wall.elapsed().as_secs_f64();
    crate::obs::record_measured_here(crate::obs::Phase::Episode, t_wall, stats.wall_s);

    Ok(EpisodeOut {
        env_id,
        traj,
        stats,
        completed_at: std::time::Instant::now(),
    })
}
