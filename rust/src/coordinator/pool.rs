//! Multi-environment worker pool.
//!
//! Mirrors the paper's resource model: each environment is an independent
//! CFD instance (here: an OS thread owning its own PJRT client, compiled
//! executables, flow state and exchange interface). Parameters are
//! broadcast at episode boundaries; trajectories flow back over channels.
//! On this 1-core testbed threads interleave rather than truly parallelise
//! — the *structure* is the paper's, and the cluster DES (rust/src/cluster)
//! projects the measured per-component costs onto 60 cores.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::drl::policy::PolicySession;
use crate::drl::{Policy, Trajectory, Transition};
use crate::env::CfdEnv;
use crate::io_interface::{make_interface, IoMode, IoStats};
use crate::runtime::{Manifest, Runtime};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub artifact_dir: std::path::PathBuf,
    pub work_dir: std::path::PathBuf,
    pub variant: String,
    pub n_envs: usize,
    pub io_mode: IoMode,
    pub seed: u64,
}

/// Per-episode summary returned alongside the trajectory.
#[derive(Clone, Debug, Default)]
pub struct EpisodeStats {
    pub reward_sum: f64,
    pub cd_mean: f64,
    pub cl_abs_mean: f64,
    pub jet_final: f64,
    pub cfd_s: f64,
    pub io_s: f64,
    pub policy_s: f64,
    pub wall_s: f64,
    pub io: IoStats,
}

pub struct EpisodeOut {
    pub env_id: usize,
    pub traj: Trajectory,
    pub stats: EpisodeStats,
}

enum Job {
    Rollout {
        params: Arc<Vec<f32>>,
        horizon: usize,
        /// decorrelates exploration across envs and iterations
        episode_seed: u64,
    },
    Shutdown,
}

pub struct EnvPool {
    job_txs: Vec<Sender<Job>>,
    results: Receiver<Result<EpisodeOut>>,
    joins: Vec<Option<JoinHandle<()>>>,
}

impl EnvPool {
    pub fn new(cfg: &PoolConfig, manifest: &Arc<Manifest>) -> Result<Self> {
        let mut job_txs = Vec::with_capacity(cfg.n_envs);
        let mut joins = Vec::with_capacity(cfg.n_envs);
        // one shared result channel: both the synchronous barrier and the
        // asynchronous trainer consume from it
        let (tx_out, rx_out) = channel::<Result<EpisodeOut>>();
        for env_id in 0..cfg.n_envs {
            let (tx_job, rx_job) = channel::<Job>();
            let m = Arc::clone(manifest);
            let cfg = cfg.clone();
            let tx = tx_out.clone();
            let join = std::thread::Builder::new()
                .name(format!("env-{env_id}"))
                .spawn(move || worker_main(env_id, cfg, m, rx_job, tx))
                .context("spawning env worker")?;
            job_txs.push(tx_job);
            joins.push(Some(join));
        }
        Ok(EnvPool {
            job_txs,
            results: rx_out,
            joins,
        })
    }

    pub fn n_envs(&self) -> usize {
        self.job_txs.len()
    }

    /// Dispatch one episode to a specific environment (async mode).
    pub fn dispatch(
        &self,
        env_id: usize,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        episode_index: u64,
    ) -> Result<()> {
        self.job_txs[env_id]
            .send(Job::Rollout {
                params: Arc::clone(params),
                horizon,
                episode_seed: episode_index
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(env_id as u64),
            })
            .context("worker channel closed")
    }

    /// Receive the next finished episode from ANY environment (async mode).
    pub fn recv_one(&self) -> Result<EpisodeOut> {
        self.results.recv().context("all workers died")?
    }

    /// Roll out one episode on every environment (the paper's synchronous
    /// iteration); blocks until all trajectories arrive (episode barrier).
    pub fn rollout(
        &mut self,
        params: &Arc<Vec<f32>>,
        horizon: usize,
        iteration: u64,
    ) -> Result<Vec<EpisodeOut>> {
        for env_id in 0..self.job_txs.len() {
            self.dispatch(env_id, params, horizon, iteration)?;
        }
        let mut outs = Vec::with_capacity(self.job_txs.len());
        for _ in 0..self.job_txs.len() {
            outs.push(self.recv_one()?);
        }
        outs.sort_by_key(|o| o.env_id);
        Ok(outs)
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        for tx in &self.job_txs {
            let _ = tx.send(Job::Shutdown);
        }
        for j in &mut self.joins {
            if let Some(j) = j.take() {
                let _ = j.join();
            }
        }
    }
}

fn worker_main(
    env_id: usize,
    cfg: PoolConfig,
    manifest: Arc<Manifest>,
    rx: Receiver<Job>,
    tx: Sender<Result<EpisodeOut>>,
) {
    // Each worker owns a full runtime: PJRT clients are not Send/Sync.
    let setup = (|| -> Result<(Runtime, CfdEnv, Policy)> {
        let mut rt = Runtime::new(&cfg.artifact_dir)?;
        let variant = manifest.variant(&cfg.variant)?.clone();
        rt.load(&variant.cfd_period_file)?;
        rt.load(&manifest.drl.policy_apply_file)?;
        let state0 = manifest.load_state0(&cfg.variant)?;
        let exchange = make_interface(cfg.io_mode, &cfg.work_dir, env_id)?;
        let env = CfdEnv::new(
            variant,
            state0,
            manifest.drl.action_smoothing_beta,
            manifest.drl.reward_lift_penalty,
            exchange,
        );
        let policy = Policy::new(manifest.drl.n_obs);
        Ok((rt, env, policy))
    })();

    let (rt, mut env, policy) = match setup {
        Ok(x) => x,
        Err(e) => {
            let _ = tx.send(Err(e));
            return;
        }
    };

    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::Rollout {
                params,
                horizon,
                episode_seed,
            } => {
                let out = run_episode(
                    env_id,
                    &rt,
                    &mut env,
                    &policy,
                    &manifest,
                    &params,
                    horizon,
                    cfg.seed ^ episode_seed,
                );
                if tx.send(out).is_err() {
                    break;
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_episode(
    env_id: usize,
    rt: &Runtime,
    env: &mut CfdEnv,
    policy: &Policy,
    manifest: &Manifest,
    params: &[f32],
    horizon: usize,
    seed: u64,
) -> Result<EpisodeOut> {
    let t_wall = std::time::Instant::now();
    let cfd_exe = rt.get(&env.variant.cfd_period_file)?;
    let pol_exe = rt.get(&manifest.drl.policy_apply_file)?;
    // params are constant for the whole episode: upload once (perf fast
    // path, 3.1x on serving latency — EXPERIMENTS.md section Perf)
    let session = PolicySession::new(rt, params, manifest.drl.n_obs)?;
    let mut rng = Rng::new(seed);

    let mut stats = EpisodeStats::default();
    let mut traj = Trajectory {
        env_id,
        ..Default::default()
    };

    let mut obs = env.reset(cfd_exe)?;
    for _t in 0..horizon {
        let tp = std::time::Instant::now();
        let pout = session.apply(rt, pol_exe, &obs)?;
        let (action, logp) = policy.sample(&pout, &mut rng);
        stats.policy_s += tp.elapsed().as_secs_f64();

        let sr = env.step(cfd_exe, action)?;
        stats.cfd_s += sr.timings.cfd_s;
        stats.io_s += sr.timings.io_s;
        stats.io.accumulate(&sr.io);
        stats.reward_sum += sr.reward;
        stats.cd_mean += sr.cd_mean / horizon as f64;
        stats.cl_abs_mean += sr.cl_mean.abs() / horizon as f64;
        stats.jet_final = sr.jet;

        traj.transitions.push(Transition {
            obs: std::mem::take(&mut obs),
            action,
            logp,
            reward: sr.reward,
            value: pout.value,
        });
        obs = sr.obs;
    }
    // bootstrap value for the truncated horizon
    let tp = std::time::Instant::now();
    traj.last_value = session.apply(rt, pol_exe, &obs)?.value;
    stats.policy_s += tp.elapsed().as_secs_f64();
    stats.wall_s = t_wall.elapsed().as_secs_f64();

    Ok(EpisodeOut {
        env_id,
        traj,
        stats,
    })
}
