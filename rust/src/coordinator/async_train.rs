//! Asynchronous PPO training — the paper's stated future-work direction
//! ("such as asynchronous reinforcement learning training in AFC
//! problems", section IV).
//!
//! Differences from the synchronous loop in [`super::train`]:
//! * no episode barrier: the master updates the policy the moment ANY
//!   environment delivers a trajectory and immediately re-dispatches that
//!   environment with the fresh parameters;
//! * environments therefore act on parameters that may be up to
//!   `N_envs - 1` updates stale (bounded staleness, A3C-style);
//! * the barrier idle time — the dominant multi-env efficiency loss in
//!   Table I once I/O is optimized — disappears entirely.
//!
//! Like the synchronous loop, the PPO update runs on either backend
//! (`--update-backend xla|native`), and with no manifest present the
//! whole loop falls back to the artifact-free path (surrogate scenario +
//! native everything). Batched central inference, however, has no sync
//! barrier to batch at in async mode: `cfg.inference` is ignored with a
//! visible warning and the workers always serve their own policy.
//!
//! The DES twin (`cluster::des` with `sync = false` via
//! [`crate::cluster::SimConfig`]... see `simulate_training_async`) projects
//! the same policy onto the 60-core cluster; `drlfoam reproduce ablation`
//! compares the two (EXPERIMENTS.md section Extensions).

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::train::{setup, update_engine, InferenceMode, TrainConfig, TrainSetup};
use crate::drl::Batch;
use crate::runtime::write_f32_bin;
use crate::util::rng::Rng;

/// One row of the async learning curve.
#[derive(Clone, Debug)]
pub struct AsyncEpisodeLog {
    pub episode: usize,
    pub env_id: usize,
    pub reward: f64,
    pub cd_mean: f64,
    pub staleness: u64,
    pub update_s: f64,
}

pub struct AsyncTrainSummary {
    pub log: Vec<AsyncEpisodeLog>,
    pub final_params: Vec<f32>,
    pub total_s: f64,
}

/// Asynchronous training: `cfg.iterations * cfg.n_envs` total episodes,
/// one PPO update per arriving episode.
pub fn train_async(cfg: &TrainConfig) -> Result<AsyncTrainSummary> {
    std::fs::create_dir_all(&cfg.out_dir)?;
    std::fs::create_dir_all(&cfg.work_dir)?;

    // async mode has no common sync point to batch inference at, so the
    // workers always serve their own policy; say so out loud instead of
    // silently ignoring the flag
    if cfg.inference == InferenceMode::Batched && !cfg.quiet {
        eprintln!(
            "warning: --inference batched has no effect with --async (no sync \
             barrier to batch at); environments serve their own policy"
        );
    }

    let TrainSetup {
        pool,
        mut trainer,
        rt,
        updater,
        update_file,
        n_obs,
        gamma,
        gae_lambda,
        ..
    } = setup(cfg, false)?;

    let mut rng = Rng::new(cfg.seed ^ 0xA5A5);
    let total_episodes = cfg.iterations * cfg.n_envs;
    let t0 = Instant::now();

    // track which policy version each env is running
    let mut version: u64 = 0;
    let mut env_version = vec![0u64; cfg.n_envs];

    // prime every env once
    let params = Arc::new(trainer.params.clone());
    for e in 0..cfg.n_envs {
        pool.dispatch(e, &params, cfg.horizon, e as u64)?;
    }

    let mut log = Vec::with_capacity(total_episodes);
    let mut csv = std::fs::File::create(cfg.out_dir.join("train_async_log.csv"))?;
    writeln!(csv, "episode,env_id,reward,cd_mean,staleness,update_s")?;

    for ep in 0..total_episodes {
        let out = pool.recv_one().context("async rollout")?;
        let staleness = version - env_version[out.env_id];

        // immediate update on this single trajectory
        let batch = Batch::assemble(std::slice::from_ref(&out.traj), n_obs, gamma, gae_lambda);
        let upd = trainer.update(update_engine(&updater, &rt, &update_file)?, &batch, &mut rng)?;
        version += 1;

        // re-dispatch the same env with fresh parameters (unless draining)
        if ep + cfg.n_envs < total_episodes {
            let params = Arc::new(trainer.params.clone());
            env_version[out.env_id] = version;
            pool.dispatch(out.env_id, &params, cfg.horizon, (ep + cfg.n_envs) as u64)?;
        }

        let row = AsyncEpisodeLog {
            episode: ep,
            env_id: out.env_id,
            reward: out.stats.reward_sum,
            cd_mean: out.stats.cd_mean,
            staleness,
            update_s: upd.wall_s,
        };
        writeln!(
            csv,
            "{},{},{:.6},{:.6},{},{:.4}",
            row.episode, row.env_id, row.reward, row.cd_mean, row.staleness, row.update_s
        )?;
        if !cfg.quiet && ep % cfg.log_every == 0 {
            println!(
                "async ep {:>5} env {:>2}  R {:>8.4}  Cd {:>6.3}  staleness {}",
                ep, out.env_id, row.reward, row.cd_mean, staleness
            );
        }
        log.push(row);
    }

    let final_params = trainer.params.clone();
    write_f32_bin(cfg.out_dir.join("policy_final_async.bin"), &final_params)?;
    Ok(AsyncTrainSummary {
        log,
        final_params,
        total_s: t0.elapsed().as_secs_f64(),
    })
}
