//! Training-run configuration and shared setup for the unified rollout
//! scheduler ([`super::scheduler`], the paper's Fig 4 loop generalized
//! over sync policies).
//!
//! Training runs on three orthogonal axes (the paper's §III
//! deconstruction of the framework into independently parallelizable
//! components):
//!
//! * policy serving — per-env or central batched, XLA artifact or native
//!   twin (`--inference`, `--backend`);
//! * PPO update — the AOT `ppo_update` artifact or the pure-Rust
//!   [`NativeUpdater`] (`--update-backend`);
//! * sync policy — full barrier, partial barrier, or async
//!   (`--sync`, see [`super::scheduler::SyncPolicy`]).
//!
//! When no AOT manifest is present at `artifact_dir`, the loop falls
//! back to the fully artifact-free path: `EnvPool::standalone` (surrogate
//! scenario), native policy serving and the native update backend — the
//! same fallback `main.rs::cmd_episode` applies to rollouts.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::pool::{EnvPool, PoolConfig};
use crate::coordinator::scheduler::SyncPolicy;
use crate::drl::native_update::{NativeUpdater, PpoHyperParams, DEFAULT_GAE_LAMBDA, DEFAULT_GAMMA};
use crate::drl::policy::{NativePolicy, PolicyBackendKind};
use crate::drl::{PpoTrainer, TrainerBackend, UpdateBackendKind};
use crate::cfd::CfdBackend;
use crate::env::scenario::{self, policy_dims, ScenarioKind};
use crate::exec::{ExecutorKind, TransportKind};
use crate::io_interface::IoMode;
use crate::runtime::{Manifest, Runtime};

/// Where policy inference runs during rollouts (the paper's
/// hybrid-parallelization axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InferenceMode {
    /// Each env worker serves its own policy (the validated baseline).
    PerEnv,
    /// The coordinator batches all envs' observations at a sync barrier
    /// and runs one forward pass per actuation period.
    Batched,
}

impl InferenceMode {
    /// Parse a CLI/config string (trimmed, case-insensitive); the error
    /// lists the accepted values.
    pub fn parse(s: &str) -> Result<InferenceMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "per-env" | "perenv" | "local" => Ok(InferenceMode::PerEnv),
            "batched" | "central" => Ok(InferenceMode::Batched),
            _ => anyhow::bail!("unknown inference mode {s:?} (accepted: per-env, batched)"),
        }
    }

    /// Canonical name, inverse of [`InferenceMode::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            InferenceMode::PerEnv => "per-env",
            InferenceMode::Batched => "batched",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifact_dir: std::path::PathBuf,
    pub work_dir: std::path::PathBuf,
    pub out_dir: std::path::PathBuf,
    pub variant: String,
    /// Scenario registry name (cylinder, cylinder-re200, surrogate, ...).
    pub scenario: String,
    pub n_envs: usize,
    pub io_mode: IoMode,
    /// Per-env vs central batched policy serving during rollouts.
    pub inference: InferenceMode,
    /// Serving engine for per-env mode (XLA artifact or native twin).
    pub backend: PolicyBackendKind,
    /// Engine for the PPO minibatch update (XLA artifact or native step).
    pub update_backend: UpdateBackendKind,
    /// Engine for cylinder CFD periods (`--cfd-backend`): the AOT XLA
    /// executable, or the pure-Rust native engine (artifact-free; forces
    /// native policy + update backends and ignores any manifest so the
    /// run is identical with and without artifacts present).
    pub cfd_backend: CfdBackend,
    /// Rollout scheduler barrier policy (full / `partial:<k>` / async).
    pub sync: SyncPolicy,
    /// Execution backend for the env workers: OS threads in this process
    /// (default) or `drlfoam worker` OS processes (`--executor`).
    pub executor: ExecutorKind,
    /// Worker processes per environment (the paper's `N_ranks`); only
    /// meaningful under [`ExecutorKind::MultiProcess`], must be 1
    /// in-process.
    pub ranks_per_env: usize,
    /// Binary to self-exec for multi-process workers; `None` uses
    /// `current_exe()` (integration tests override this).
    pub worker_bin: Option<std::path::PathBuf>,
    /// Chaos hook `"<env>:<episode>[:midframe]"` (`--chaos`): that
    /// worker aborts once on receiving that episode (with `midframe`,
    /// leaving partially written frames), exercising respawn + re-queue.
    pub fault_injection: Option<String>,
    /// Multi-process data plane (`--transport pipe|shm|tcp|uds`): worker
    /// pipes for everything, shared-memory seqlock rings for the data
    /// frames with the pipe as control channel + fallback, or a socket
    /// per worker.
    pub transport: TransportKind,
    /// `--hosts` topology for the socket transports: `drlfoam agent`
    /// endpoints + core counts the rank groups are packed across.
    pub hosts: Vec<crate::exec::net::HostSpec>,
    /// actuation periods per episode (paper: 100)
    pub horizon: usize,
    /// training iterations == episodes per environment (the episode
    /// budget is `iterations * n_envs` under every sync policy)
    pub iterations: usize,
    /// PPO epochs per iteration
    pub epochs: usize,
    pub seed: u64,
    pub log_every: usize,
    pub quiet: bool,
    /// `--trace <path>`: record obs spans across every worker and write
    /// a merged Chrome-trace JSON there, plus `obs_summary.csv` and
    /// `drift.csv` next to the other outputs (ARCHITECTURE.md §12).
    pub trace: Option<std::path::PathBuf>,
    /// Calibration behind the drift report; `None` skips `drift.csv`.
    pub trace_calib: Option<crate::cluster::Calibration>,
}

impl TrainConfig {
    /// Apply a planner-selected layout (`drlfoam train --layout auto`)
    /// to this run: the chosen environment count, scheduler barrier and
    /// exchange mode drive the real scheduler loop. The rank axis is
    /// executor-dependent: the multi-process executor spawns real
    /// `plan.n_ranks`-wide rank groups, while in-process workers are
    /// single-rank threads, so there `ranks_per_env` stays 1 (and the
    /// auto-layout search constrains itself accordingly). The executor
    /// itself is never part of the sweep — an explicitly requested
    /// `--executor` is pinned, not overridden.
    pub fn apply_plan(&mut self, plan: &crate::cluster::planner::Plan) {
        self.n_envs = plan.n_envs;
        self.sync = plan.sync;
        self.io_mode = plan.io_mode;
        self.ranks_per_env = match self.executor {
            ExecutorKind::MultiProcess => plan.n_ranks,
            ExecutorKind::InProcess => 1,
        };
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact_dir: "artifacts".into(),
            work_dir: "out/work".into(),
            out_dir: "out".into(),
            variant: "small".into(),
            scenario: "cylinder".into(),
            n_envs: 1,
            io_mode: IoMode::InMemory,
            inference: InferenceMode::PerEnv,
            backend: PolicyBackendKind::Xla,
            update_backend: UpdateBackendKind::Xla,
            cfd_backend: CfdBackend::Xla,
            sync: SyncPolicy::Full,
            executor: ExecutorKind::InProcess,
            ranks_per_env: 1,
            worker_bin: None,
            fault_injection: None,
            transport: TransportKind::Pipe,
            hosts: Vec::new(),
            horizon: 100,
            iterations: 100,
            epochs: 4,
            seed: 0,
            log_every: 1,
            quiet: false,
            trace: None,
            trace_calib: None,
        }
    }
}

/// Minibatch size of artifact-free runs (matches the static `minibatch`
/// the AOT pipeline bakes into `ppo_update`, configs.py::DrlConfig, so
/// learning dynamics stay comparable across the two paths).
pub(crate) const STANDALONE_MINIBATCH: usize = 64;

/// Everything the scheduler loop derives from the (optional) manifest:
/// worker pool, trainer, the resolved update engine, and the GAE
/// constants. Built by [`setup`].
pub(crate) struct TrainSetup {
    pub manifest: Option<Arc<Manifest>>,
    pub pool: EnvPool,
    pub trainer: PpoTrainer,
    /// Master-side runtime holding `ppo_update` (and, for batched XLA
    /// inference, the serving artifacts); `None` on the fully native path.
    pub rt: Option<Runtime>,
    /// The native update engine, when the resolved backend is native.
    pub updater: Option<NativeUpdater>,
    /// The `ppo_update` artifact file, when the resolved backend is XLA.
    pub update_file: Option<String>,
    /// Policy-serving backend after the artifact-free fallback resolved it.
    pub backend: PolicyBackendKind,
    pub n_obs: usize,
    pub hidden: usize,
    pub gamma: f64,
    pub gae_lambda: f64,
}

/// Resolve backends against the (optional) manifest and build the shared
/// training ingredients. `serve_batched` is true when the caller will run
/// central batched inference (it pre-warms the coordinator runtime).
pub(crate) fn setup(cfg: &TrainConfig, serve_batched: bool) -> Result<TrainSetup> {
    let mut manifest = Manifest::load_optional(&cfg.artifact_dir)?.map(Arc::new);

    let sp = scenario::spec(&cfg.scenario)?;
    // `--cfd-backend native` on a cylinder scenario is artifact-free by
    // construction: the scenario builder ignores the manifest, so the
    // whole run must too — policy sizing, params init and hyperparameters
    // all come from the native defaults, making the run bitwise identical
    // with and without artifacts on disk.
    let native_cfd =
        cfg.cfd_backend == CfdBackend::Native && matches!(sp.kind, ScenarioKind::Cylinder { .. });
    if native_cfd {
        manifest = None;
    }

    // with no artifacts anywhere, everything runs native (the same
    // fallback the CLI's `episode` command applies to rollouts)
    let (backend, update_backend) = match &manifest {
        Some(_) => (cfg.backend, cfg.update_backend),
        None => {
            anyhow::ensure!(
                native_cfd || matches!(sp.kind, ScenarioKind::Surrogate),
                "scenario {:?} needs AOT artifacts at {} (run `make artifacts`, \
                 or use --cfd-backend native, or --scenario surrogate)",
                cfg.scenario,
                cfg.artifact_dir.display()
            );
            if cfg.backend != PolicyBackendKind::Native
                || cfg.update_backend != UpdateBackendKind::Native
            {
                // a requested XLA engine is being downgraded: warn even
                // under --quiet, so benchmark labels can't silently lie
                // about which backend produced the numbers
                let why = if native_cfd {
                    "--cfd-backend native is artifact-free".to_string()
                } else {
                    format!("no artifacts at {}", cfg.artifact_dir.display())
                };
                eprintln!(
                    "warning: {why} — falling back to native policy + native update backends"
                );
            }
            (PolicyBackendKind::Native, UpdateBackendKind::Native)
        }
    };

    let (n_obs, hidden) = policy_dims(&cfg.scenario, cfg.cfd_backend, manifest.as_deref());

    let mut rt = None;
    let mut update_file = None;
    let mut updater = None;
    match update_backend {
        UpdateBackendKind::Xla => {
            // the fallback above already resolved Xla away when no
            // manifest exists (with a warning), so this cannot fail
            let m = manifest
                .as_ref()
                .expect("resolved xla update backend implies a manifest");
            let mut r = Runtime::new(&cfg.artifact_dir)?;
            r.load(&m.drl.ppo_update_file)?;
            update_file = Some(m.drl.ppo_update_file.clone());
            rt = Some(r);
        }
        UpdateBackendKind::Native => {
            updater = Some(match &manifest {
                Some(m) => NativeUpdater::from_manifest(&m.drl),
                None => NativeUpdater::new(n_obs, hidden, PpoHyperParams::default()),
            });
        }
    }
    // batched XLA serving shares the master runtime with the update path
    if serve_batched && backend == PolicyBackendKind::Xla && rt.is_none() {
        rt = Some(Runtime::new(&cfg.artifact_dir)?);
    }

    let pool_cfg = PoolConfig {
        artifact_dir: cfg.artifact_dir.clone(),
        work_dir: cfg.work_dir.clone(),
        variant: cfg.variant.clone(),
        scenario: cfg.scenario.clone(),
        // in batched mode the workers never serve the policy; the
        // LocalPolicy is lazy, so passing the backend through is free
        backend,
        cfd_backend: cfg.cfd_backend,
        n_envs: cfg.n_envs,
        io_mode: cfg.io_mode,
        seed: cfg.seed,
        executor: cfg.executor,
        ranks_per_env: cfg.ranks_per_env,
        worker_bin: cfg.worker_bin.clone(),
        fault_injection: cfg.fault_injection.clone(),
        transport: cfg.transport,
        hosts: cfg.hosts.clone(),
        trace: cfg.trace.is_some(),
    };
    let pool = match &manifest {
        Some(m) => EnvPool::new(&pool_cfg, m)?,
        None => EnvPool::standalone(&pool_cfg)?,
    };

    let (params0, minibatch, gamma, gae_lambda) = match &manifest {
        Some(m) => (
            m.load_params_init()?,
            m.drl.minibatch,
            m.drl.gamma,
            m.drl.gae_lambda,
        ),
        None => (
            NativePolicy::new(n_obs, hidden).init_params(cfg.seed),
            STANDALONE_MINIBATCH,
            DEFAULT_GAMMA,
            DEFAULT_GAE_LAMBDA,
        ),
    };
    let trainer = PpoTrainer::with_minibatch(params0, minibatch, cfg.epochs);

    // authoritative report of the *resolved* engine (the CLI banner only
    // knows what was requested)
    if !cfg.quiet {
        println!("ppo update backend: {}", update_backend.name());
    }

    Ok(TrainSetup {
        manifest,
        pool,
        trainer,
        rt,
        updater,
        update_file,
        backend,
        n_obs,
        hidden,
        gamma,
        gae_lambda,
    })
}

/// The update engine for one `PpoTrainer::update` call, from the state
/// [`setup`] resolved (one dispatch point for every sync policy, so the
/// logic cannot drift between them).
pub(crate) fn update_engine<'a>(
    updater: &'a Option<NativeUpdater>,
    rt: &'a Option<Runtime>,
    update_file: &Option<String>,
) -> Result<TrainerBackend<'a>> {
    match (updater, update_file) {
        (Some(nu), _) => Ok(TrainerBackend::Native(nu)),
        (None, Some(f)) => {
            let r = rt.as_ref().context("xla update runtime missing")?;
            Ok(TrainerBackend::Xla(r.get(f)?))
        }
        (None, None) => unreachable!("setup always picks an update engine"),
    }
}

/// One row of the learning curve (written to train_log.csv; Fig 5a/6a).
/// Under partial/async sync policies a "row" is one policy update over
/// `k` trajectories rather than one all-envs iteration.
#[derive(Clone, Debug)]
pub struct IterationLog {
    pub iteration: usize,
    pub episodes_done: usize,
    pub mean_reward: f64,
    pub mean_cd: f64,
    pub mean_cl_abs: f64,
    pub jet_final: f64,
    pub pi_loss: f64,
    pub v_loss: f64,
    pub approx_kl: f64,
    pub rollout_s: f64,
    pub update_s: f64,
    pub cfd_s: f64,
    pub io_s: f64,
    pub policy_s: f64,
}

pub struct TrainSummary {
    pub log: Vec<IterationLog>,
    pub final_params: Vec<f32>,
    pub total_s: f64,
    /// exchanged bytes per environment-episode under the configured mode
    pub io_bytes_per_episode: f64,
    /// mean parameter-version staleness over all consumed episodes
    /// (identically 0 under [`SyncPolicy::Full`])
    pub mean_staleness: f64,
    /// episode counts by staleness: `staleness_hist[s]` episodes acted on
    /// parameters `s` updates old (also written to out/staleness.csv)
    pub staleness_hist: Vec<usize>,
    /// total seconds finished episodes waited between completion
    /// (worker-side stamp) and the start of the update that consumed
    /// them, summed over the WHOLE run. Divide by `log.len()` (update
    /// rounds) to compare with the DES's per-round
    /// `SimBreakdown::barrier_idle_s` mean.
    pub barrier_idle_s: f64,
    /// Worker processes respawned after faults during the run (always 0
    /// under the in-process executor). Each restart re-queued the lost
    /// episode on the fresh worker; per-env counts are in
    /// `out/workers.csv`.
    pub worker_restarts: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_parse_is_lenient_and_lists_accepted() {
        assert_eq!(InferenceMode::parse(" Batched ").unwrap(), InferenceMode::Batched);
        assert_eq!(InferenceMode::parse("PER-ENV").unwrap(), InferenceMode::PerEnv);
        assert_eq!(InferenceMode::parse("central").unwrap(), InferenceMode::Batched);
        for m in [InferenceMode::PerEnv, InferenceMode::Batched] {
            assert_eq!(InferenceMode::parse(m.name()).unwrap(), m);
        }
        let err = InferenceMode::parse("remote").unwrap_err().to_string();
        assert!(err.contains("per-env") && err.contains("batched"), "{err}");
    }
}
