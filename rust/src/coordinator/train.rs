//! Synchronous multi-environment PPO training loop (the paper's Fig 4).

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::policy_server::PolicyServer;
use crate::coordinator::pool::{EnvPool, PoolConfig};
use crate::drl::policy::PolicyBackendKind;
use crate::drl::{Batch, PpoTrainer};
use crate::io_interface::IoMode;
use crate::runtime::{write_f32_bin, Manifest, Runtime};
use crate::util::rng::Rng;

/// Where policy inference runs during rollouts (the paper's
/// hybrid-parallelization axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InferenceMode {
    /// Each env worker serves its own policy (the validated baseline).
    PerEnv,
    /// The coordinator batches all envs' observations at a sync barrier
    /// and runs one forward pass per actuation period.
    Batched,
}

impl InferenceMode {
    /// Parse a CLI/config string; the error lists the accepted values.
    pub fn parse(s: &str) -> Result<InferenceMode> {
        match s {
            "per-env" | "perenv" | "local" => Ok(InferenceMode::PerEnv),
            "batched" | "central" => Ok(InferenceMode::Batched),
            _ => anyhow::bail!("unknown inference mode {s:?} (accepted: per-env, batched)"),
        }
    }

    /// Canonical name, inverse of [`InferenceMode::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            InferenceMode::PerEnv => "per-env",
            InferenceMode::Batched => "batched",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifact_dir: std::path::PathBuf,
    pub work_dir: std::path::PathBuf,
    pub out_dir: std::path::PathBuf,
    pub variant: String,
    /// Scenario registry name (cylinder, cylinder-re200, surrogate, ...).
    pub scenario: String,
    pub n_envs: usize,
    pub io_mode: IoMode,
    /// Per-env vs central batched policy serving during rollouts.
    pub inference: InferenceMode,
    /// Serving engine for per-env mode (XLA artifact or native twin).
    pub backend: PolicyBackendKind,
    /// actuation periods per episode (paper: 100)
    pub horizon: usize,
    /// training iterations == episodes per environment
    pub iterations: usize,
    /// PPO epochs per iteration
    pub epochs: usize,
    pub seed: u64,
    pub log_every: usize,
    pub quiet: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact_dir: "artifacts".into(),
            work_dir: "out/work".into(),
            out_dir: "out".into(),
            variant: "small".into(),
            scenario: "cylinder".into(),
            n_envs: 1,
            io_mode: IoMode::InMemory,
            inference: InferenceMode::PerEnv,
            backend: PolicyBackendKind::Xla,
            horizon: 100,
            iterations: 100,
            epochs: 4,
            seed: 0,
            log_every: 1,
            quiet: false,
        }
    }
}

/// One row of the learning curve (written to train_log.csv; Fig 5a/6a).
#[derive(Clone, Debug)]
pub struct IterationLog {
    pub iteration: usize,
    pub episodes_done: usize,
    pub mean_reward: f64,
    pub mean_cd: f64,
    pub mean_cl_abs: f64,
    pub jet_final: f64,
    pub pi_loss: f64,
    pub v_loss: f64,
    pub approx_kl: f64,
    pub rollout_s: f64,
    pub update_s: f64,
    pub cfd_s: f64,
    pub io_s: f64,
    pub policy_s: f64,
}

pub struct TrainSummary {
    pub log: Vec<IterationLog>,
    pub final_params: Vec<f32>,
    pub total_s: f64,
    /// exchanged bytes per environment-episode under the configured mode
    pub io_bytes_per_episode: f64,
}

/// Run the full training loop; returns the learning curve + final policy.
pub fn train(cfg: &TrainConfig) -> Result<TrainSummary> {
    std::fs::create_dir_all(&cfg.out_dir)?;
    std::fs::create_dir_all(&cfg.work_dir)?;
    let manifest = Arc::new(Manifest::load(&cfg.artifact_dir)?);

    // master-side runtime for ppo_update (and, in batched mode, for the
    // central policy server's artifacts)
    let mut rt = Runtime::new(&cfg.artifact_dir)?;
    rt.load(&manifest.drl.ppo_update_file)?;
    let mut server = match cfg.inference {
        InferenceMode::PerEnv => None,
        InferenceMode::Batched => {
            let s = match cfg.backend {
                PolicyBackendKind::Xla => {
                    let s = PolicyServer::xla(&manifest.drl);
                    s.load_into(&mut rt)?;
                    s
                }
                PolicyBackendKind::Native => {
                    PolicyServer::native(manifest.drl.n_obs, manifest.drl.hidden)
                }
            };
            if !cfg.quiet {
                println!("batched inference: {}", s.describe());
            }
            Some(s)
        }
    };

    let mut pool = EnvPool::new(
        &PoolConfig {
            artifact_dir: cfg.artifact_dir.clone(),
            work_dir: cfg.work_dir.clone(),
            variant: cfg.variant.clone(),
            scenario: cfg.scenario.clone(),
            // in batched mode the workers never serve the policy; the
            // LocalPolicy is lazy, so passing the backend through is free
            backend: cfg.backend,
            n_envs: cfg.n_envs,
            io_mode: cfg.io_mode,
            seed: cfg.seed,
        },
        &manifest,
    )?;

    let mut trainer = PpoTrainer::new(&manifest.drl, manifest.load_params_init()?, cfg.epochs);
    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    let mut log = Vec::with_capacity(cfg.iterations);
    let mut io_bytes_acc = 0u64;
    let mut episodes_done = 0usize;
    let t_total = Instant::now();

    let mut csv = std::fs::File::create(cfg.out_dir.join("train_log.csv"))?;
    writeln!(
        csv,
        "iteration,episodes,mean_reward,mean_cd,mean_cl_abs,jet_final,pi_loss,v_loss,approx_kl,rollout_s,update_s,cfd_s,io_s,policy_s"
    )?;

    for it in 0..cfg.iterations {
        let t0 = Instant::now();
        let params = Arc::new(trainer.params.clone());
        let outs = match &mut server {
            None => pool.rollout(&params, cfg.horizon, it as u64)?,
            Some(s) => pool.rollout_batched(Some(&rt), s, &params, cfg.horizon, it as u64)?,
        };
        let rollout_s = t0.elapsed().as_secs_f64();
        episodes_done += outs.len();

        let n = outs.len() as f64;
        let mean_reward = outs.iter().map(|o| o.stats.reward_sum).sum::<f64>() / n;
        let mean_cd = outs.iter().map(|o| o.stats.cd_mean).sum::<f64>() / n;
        let mean_cl = outs.iter().map(|o| o.stats.cl_abs_mean).sum::<f64>() / n;
        let jet_final = outs.last().map(|o| o.stats.jet_final).unwrap_or(0.0);
        let cfd_s = outs.iter().map(|o| o.stats.cfd_s).sum::<f64>() / n;
        let io_s = outs.iter().map(|o| o.stats.io_s).sum::<f64>() / n;
        let policy_s = outs.iter().map(|o| o.stats.policy_s).sum::<f64>() / n;
        io_bytes_acc += outs
            .iter()
            .map(|o| o.stats.io.bytes_written + o.stats.io.bytes_read)
            .sum::<u64>();

        let trajs: Vec<_> = outs.into_iter().map(|o| o.traj).collect();
        let batch = Batch::assemble(
            &trajs,
            manifest.drl.n_obs,
            manifest.drl.gamma,
            manifest.drl.gae_lambda,
        );
        let upd = trainer.update(rt.get(&manifest.drl.ppo_update_file)?, &batch, &mut rng)?;

        let row = IterationLog {
            iteration: it,
            episodes_done,
            mean_reward,
            mean_cd,
            mean_cl_abs: mean_cl,
            jet_final,
            pi_loss: upd.pi_loss,
            v_loss: upd.v_loss,
            approx_kl: upd.approx_kl,
            rollout_s,
            update_s: upd.wall_s,
            cfd_s,
            io_s,
            policy_s,
        };
        writeln!(
            csv,
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.4},{:.4},{:.4},{:.4},{:.4}",
            row.iteration,
            row.episodes_done,
            row.mean_reward,
            row.mean_cd,
            row.mean_cl_abs,
            row.jet_final,
            row.pi_loss,
            row.v_loss,
            row.approx_kl,
            row.rollout_s,
            row.update_s,
            row.cfd_s,
            row.io_s,
            row.policy_s
        )?;
        if !cfg.quiet && it % cfg.log_every == 0 {
            println!(
                "iter {:>4}  ep {:>5}  R {:>8.4}  Cd {:>6.3}  |Cl| {:>6.3}  kl {:>8.5}  rollout {:>6.2}s  update {:>5.2}s",
                it, episodes_done, mean_reward, mean_cd, mean_cl, upd.approx_kl, rollout_s, upd.wall_s
            );
        }
        log.push(row);
    }

    let final_params = trainer.params.clone();
    write_f32_bin(cfg.out_dir.join("policy_final.bin"), &final_params)
        .context("writing final policy")?;
    write_f32_bin(cfg.out_dir.join("trainer_ckpt.bin"), &trainer.checkpoint())?;

    Ok(TrainSummary {
        io_bytes_per_episode: io_bytes_acc as f64 / episodes_done.max(1) as f64,
        log,
        final_params,
        total_s: t_total.elapsed().as_secs_f64(),
    })
}
