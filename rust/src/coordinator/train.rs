//! Synchronous multi-environment PPO training loop (the paper's Fig 4).
//!
//! Runs on two orthogonal backend axes (the paper's §III deconstruction
//! of the framework into independently parallelizable components):
//!
//! * policy serving — per-env or central batched, XLA artifact or native
//!   twin (`--inference`, `--backend`);
//! * PPO update — the AOT `ppo_update` artifact or the pure-Rust
//!   [`NativeUpdater`] (`--update-backend`).
//!
//! When no AOT manifest is present at `artifact_dir`, both loops fall
//! back to the fully artifact-free path: `EnvPool::standalone` (surrogate
//! scenario), native policy serving and the native update backend — the
//! same fallback `main.rs::cmd_episode` applies to rollouts.

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::policy_server::PolicyServer;
use crate::coordinator::pool::{EnvPool, PoolConfig};
use crate::drl::native_update::{NativeUpdater, PpoHyperParams, DEFAULT_GAE_LAMBDA, DEFAULT_GAMMA};
use crate::drl::policy::{NativePolicy, PolicyBackendKind};
use crate::drl::{Batch, PpoTrainer, TrainerBackend, UpdateBackendKind};
use crate::env::scenario::{self, ScenarioKind, SURROGATE_HIDDEN, SURROGATE_N_OBS};
use crate::io_interface::IoMode;
use crate::runtime::{write_f32_bin, Manifest, Runtime};
use crate::util::rng::Rng;

/// Where policy inference runs during rollouts (the paper's
/// hybrid-parallelization axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InferenceMode {
    /// Each env worker serves its own policy (the validated baseline).
    PerEnv,
    /// The coordinator batches all envs' observations at a sync barrier
    /// and runs one forward pass per actuation period.
    Batched,
}

impl InferenceMode {
    /// Parse a CLI/config string (trimmed, case-insensitive); the error
    /// lists the accepted values.
    pub fn parse(s: &str) -> Result<InferenceMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "per-env" | "perenv" | "local" => Ok(InferenceMode::PerEnv),
            "batched" | "central" => Ok(InferenceMode::Batched),
            _ => anyhow::bail!("unknown inference mode {s:?} (accepted: per-env, batched)"),
        }
    }

    /// Canonical name, inverse of [`InferenceMode::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            InferenceMode::PerEnv => "per-env",
            InferenceMode::Batched => "batched",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifact_dir: std::path::PathBuf,
    pub work_dir: std::path::PathBuf,
    pub out_dir: std::path::PathBuf,
    pub variant: String,
    /// Scenario registry name (cylinder, cylinder-re200, surrogate, ...).
    pub scenario: String,
    pub n_envs: usize,
    pub io_mode: IoMode,
    /// Per-env vs central batched policy serving during rollouts.
    pub inference: InferenceMode,
    /// Serving engine for per-env mode (XLA artifact or native twin).
    pub backend: PolicyBackendKind,
    /// Engine for the PPO minibatch update (XLA artifact or native step).
    pub update_backend: UpdateBackendKind,
    /// actuation periods per episode (paper: 100)
    pub horizon: usize,
    /// training iterations == episodes per environment
    pub iterations: usize,
    /// PPO epochs per iteration
    pub epochs: usize,
    pub seed: u64,
    pub log_every: usize,
    pub quiet: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact_dir: "artifacts".into(),
            work_dir: "out/work".into(),
            out_dir: "out".into(),
            variant: "small".into(),
            scenario: "cylinder".into(),
            n_envs: 1,
            io_mode: IoMode::InMemory,
            inference: InferenceMode::PerEnv,
            backend: PolicyBackendKind::Xla,
            update_backend: UpdateBackendKind::Xla,
            horizon: 100,
            iterations: 100,
            epochs: 4,
            seed: 0,
            log_every: 1,
            quiet: false,
        }
    }
}

/// Minibatch size of artifact-free runs (matches the static `minibatch`
/// the AOT pipeline bakes into `ppo_update`, configs.py::DrlConfig, so
/// learning dynamics stay comparable across the two paths).
pub(crate) const STANDALONE_MINIBATCH: usize = 64;

/// Everything both training loops derive from the (optional) manifest:
/// worker pool, trainer, the resolved update engine, and the GAE
/// constants. Built by [`setup`].
pub(crate) struct TrainSetup {
    pub manifest: Option<Arc<Manifest>>,
    pub pool: EnvPool,
    pub trainer: PpoTrainer,
    /// Master-side runtime holding `ppo_update` (and, for batched XLA
    /// inference, the serving artifacts); `None` on the fully native path.
    pub rt: Option<Runtime>,
    /// The native update engine, when the resolved backend is native.
    pub updater: Option<NativeUpdater>,
    /// The `ppo_update` artifact file, when the resolved backend is XLA.
    pub update_file: Option<String>,
    /// Policy-serving backend after the artifact-free fallback resolved it.
    pub backend: PolicyBackendKind,
    pub n_obs: usize,
    pub hidden: usize,
    pub gamma: f64,
    pub gae_lambda: f64,
}

/// Resolve backends against the (optional) manifest and build the shared
/// training ingredients. `serve_batched` is true when the caller will run
/// central batched inference (the async loop has no barrier to batch at).
pub(crate) fn setup(cfg: &TrainConfig, serve_batched: bool) -> Result<TrainSetup> {
    let manifest = Manifest::load_optional(&cfg.artifact_dir)?.map(Arc::new);

    // with no artifacts anywhere, everything runs native (the same
    // fallback the CLI's `episode` command applies to rollouts)
    let (backend, update_backend) = match &manifest {
        Some(_) => (cfg.backend, cfg.update_backend),
        None => {
            let sp = scenario::spec(&cfg.scenario)?;
            anyhow::ensure!(
                matches!(sp.kind, ScenarioKind::Surrogate),
                "scenario {:?} needs AOT artifacts at {} (run `make artifacts`, or use --scenario surrogate)",
                cfg.scenario,
                cfg.artifact_dir.display()
            );
            if cfg.backend != PolicyBackendKind::Native
                || cfg.update_backend != UpdateBackendKind::Native
            {
                // a requested XLA engine is being downgraded: warn even
                // under --quiet, so benchmark labels can't silently lie
                // about which backend produced the numbers
                eprintln!(
                    "warning: no artifacts at {} — falling back to native policy + native update backends",
                    cfg.artifact_dir.display()
                );
            }
            (PolicyBackendKind::Native, UpdateBackendKind::Native)
        }
    };

    let (n_obs, hidden) = match &manifest {
        Some(m) => (m.drl.n_obs, m.drl.hidden),
        None => (SURROGATE_N_OBS, SURROGATE_HIDDEN),
    };

    let mut rt = None;
    let mut update_file = None;
    let mut updater = None;
    match update_backend {
        UpdateBackendKind::Xla => {
            // the fallback above already resolved Xla away when no
            // manifest exists (with a warning), so this cannot fail
            let m = manifest
                .as_ref()
                .expect("resolved xla update backend implies a manifest");
            let mut r = Runtime::new(&cfg.artifact_dir)?;
            r.load(&m.drl.ppo_update_file)?;
            update_file = Some(m.drl.ppo_update_file.clone());
            rt = Some(r);
        }
        UpdateBackendKind::Native => {
            updater = Some(match &manifest {
                Some(m) => NativeUpdater::from_manifest(&m.drl),
                None => NativeUpdater::new(n_obs, hidden, PpoHyperParams::default()),
            });
        }
    }
    // batched XLA serving shares the master runtime with the update path
    if serve_batched && backend == PolicyBackendKind::Xla && rt.is_none() {
        rt = Some(Runtime::new(&cfg.artifact_dir)?);
    }

    let pool_cfg = PoolConfig {
        artifact_dir: cfg.artifact_dir.clone(),
        work_dir: cfg.work_dir.clone(),
        variant: cfg.variant.clone(),
        scenario: cfg.scenario.clone(),
        // in batched mode the workers never serve the policy; the
        // LocalPolicy is lazy, so passing the backend through is free
        backend,
        n_envs: cfg.n_envs,
        io_mode: cfg.io_mode,
        seed: cfg.seed,
    };
    let pool = match &manifest {
        Some(m) => EnvPool::new(&pool_cfg, m)?,
        None => EnvPool::standalone(&pool_cfg)?,
    };

    let (params0, minibatch, gamma, gae_lambda) = match &manifest {
        Some(m) => (
            m.load_params_init()?,
            m.drl.minibatch,
            m.drl.gamma,
            m.drl.gae_lambda,
        ),
        None => (
            NativePolicy::new(n_obs, hidden).init_params(cfg.seed),
            STANDALONE_MINIBATCH,
            DEFAULT_GAMMA,
            DEFAULT_GAE_LAMBDA,
        ),
    };
    let trainer = PpoTrainer::with_minibatch(params0, minibatch, cfg.epochs);

    // authoritative report of the *resolved* engine (the CLI banner only
    // knows what was requested)
    if !cfg.quiet {
        println!("ppo update backend: {}", update_backend.name());
    }

    Ok(TrainSetup {
        manifest,
        pool,
        trainer,
        rt,
        updater,
        update_file,
        backend,
        n_obs,
        hidden,
        gamma,
        gae_lambda,
    })
}

/// The update engine for one `PpoTrainer::update` call, from the state
/// [`setup`] resolved (shared by the sync and async loops so the dispatch
/// logic cannot drift between them).
pub(crate) fn update_engine<'a>(
    updater: &'a Option<NativeUpdater>,
    rt: &'a Option<Runtime>,
    update_file: &Option<String>,
) -> Result<TrainerBackend<'a>> {
    match (updater, update_file) {
        (Some(nu), _) => Ok(TrainerBackend::Native(nu)),
        (None, Some(f)) => {
            let r = rt.as_ref().context("xla update runtime missing")?;
            Ok(TrainerBackend::Xla(r.get(f)?))
        }
        (None, None) => unreachable!("setup always picks an update engine"),
    }
}

/// One row of the learning curve (written to train_log.csv; Fig 5a/6a).
#[derive(Clone, Debug)]
pub struct IterationLog {
    pub iteration: usize,
    pub episodes_done: usize,
    pub mean_reward: f64,
    pub mean_cd: f64,
    pub mean_cl_abs: f64,
    pub jet_final: f64,
    pub pi_loss: f64,
    pub v_loss: f64,
    pub approx_kl: f64,
    pub rollout_s: f64,
    pub update_s: f64,
    pub cfd_s: f64,
    pub io_s: f64,
    pub policy_s: f64,
}

pub struct TrainSummary {
    pub log: Vec<IterationLog>,
    pub final_params: Vec<f32>,
    pub total_s: f64,
    /// exchanged bytes per environment-episode under the configured mode
    pub io_bytes_per_episode: f64,
}

/// Run the full training loop; returns the learning curve + final policy.
pub fn train(cfg: &TrainConfig) -> Result<TrainSummary> {
    std::fs::create_dir_all(&cfg.out_dir)?;
    std::fs::create_dir_all(&cfg.work_dir)?;
    let TrainSetup {
        manifest,
        mut pool,
        mut trainer,
        mut rt,
        updater,
        update_file,
        backend,
        n_obs,
        hidden,
        gamma,
        gae_lambda,
    } = setup(cfg, cfg.inference == InferenceMode::Batched)?;

    let mut server = match cfg.inference {
        InferenceMode::PerEnv => None,
        InferenceMode::Batched => {
            let s = match backend {
                PolicyBackendKind::Xla => {
                    // setup guarantees manifest + runtime on this path
                    let m = manifest.as_ref().context("xla serving needs a manifest")?;
                    let s = PolicyServer::xla(&m.drl);
                    s.load_into(rt.as_mut().context("serving runtime missing")?)?;
                    s
                }
                PolicyBackendKind::Native => PolicyServer::native(n_obs, hidden),
            };
            if !cfg.quiet {
                println!("batched inference: {}", s.describe());
            }
            Some(s)
        }
    };

    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    let mut log = Vec::with_capacity(cfg.iterations);
    let mut io_bytes_acc = 0u64;
    let mut episodes_done = 0usize;
    let t_total = Instant::now();

    let mut csv = std::fs::File::create(cfg.out_dir.join("train_log.csv"))?;
    writeln!(
        csv,
        "iteration,episodes,mean_reward,mean_cd,mean_cl_abs,jet_final,pi_loss,v_loss,approx_kl,rollout_s,update_s,cfd_s,io_s,policy_s"
    )?;

    for it in 0..cfg.iterations {
        let t0 = Instant::now();
        let params = Arc::new(trainer.params.clone());
        let outs = match &mut server {
            None => pool.rollout(&params, cfg.horizon, it as u64)?,
            Some(s) => pool.rollout_batched(rt.as_ref(), s, &params, cfg.horizon, it as u64)?,
        };
        let rollout_s = t0.elapsed().as_secs_f64();
        episodes_done += outs.len();

        let n = outs.len() as f64;
        let mean_reward = outs.iter().map(|o| o.stats.reward_sum).sum::<f64>() / n;
        let mean_cd = outs.iter().map(|o| o.stats.cd_mean).sum::<f64>() / n;
        let mean_cl = outs.iter().map(|o| o.stats.cl_abs_mean).sum::<f64>() / n;
        let jet_final = outs.last().map(|o| o.stats.jet_final).unwrap_or(0.0);
        let cfd_s = outs.iter().map(|o| o.stats.cfd_s).sum::<f64>() / n;
        let io_s = outs.iter().map(|o| o.stats.io_s).sum::<f64>() / n;
        let policy_s = outs.iter().map(|o| o.stats.policy_s).sum::<f64>() / n;
        io_bytes_acc += outs
            .iter()
            .map(|o| o.stats.io.bytes_written + o.stats.io.bytes_read)
            .sum::<u64>();

        let trajs: Vec<_> = outs.into_iter().map(|o| o.traj).collect();
        let batch = Batch::assemble(&trajs, n_obs, gamma, gae_lambda);
        let upd = trainer.update(update_engine(&updater, &rt, &update_file)?, &batch, &mut rng)?;

        let row = IterationLog {
            iteration: it,
            episodes_done,
            mean_reward,
            mean_cd,
            mean_cl_abs: mean_cl,
            jet_final,
            pi_loss: upd.pi_loss,
            v_loss: upd.v_loss,
            approx_kl: upd.approx_kl,
            rollout_s,
            update_s: upd.wall_s,
            cfd_s,
            io_s,
            policy_s,
        };
        writeln!(
            csv,
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.4},{:.4},{:.4},{:.4},{:.4}",
            row.iteration,
            row.episodes_done,
            row.mean_reward,
            row.mean_cd,
            row.mean_cl_abs,
            row.jet_final,
            row.pi_loss,
            row.v_loss,
            row.approx_kl,
            row.rollout_s,
            row.update_s,
            row.cfd_s,
            row.io_s,
            row.policy_s
        )?;
        if !cfg.quiet && it % cfg.log_every == 0 {
            println!(
                "iter {:>4}  ep {:>5}  R {:>8.4}  Cd {:>6.3}  |Cl| {:>6.3}  kl {:>8.5}  rollout {:>6.2}s  update {:>5.2}s",
                it, episodes_done, mean_reward, mean_cd, mean_cl, upd.approx_kl, rollout_s, upd.wall_s
            );
        }
        log.push(row);
    }

    let final_params = trainer.params.clone();
    write_f32_bin(cfg.out_dir.join("policy_final.bin"), &final_params)
        .context("writing final policy")?;
    write_f32_bin(cfg.out_dir.join("trainer_ckpt.bin"), &trainer.checkpoint())?;

    Ok(TrainSummary {
        io_bytes_per_episode: io_bytes_acc as f64 / episodes_done.max(1) as f64,
        log,
        final_params,
        total_s: t_total.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_parse_is_lenient_and_lists_accepted() {
        assert_eq!(InferenceMode::parse(" Batched ").unwrap(), InferenceMode::Batched);
        assert_eq!(InferenceMode::parse("PER-ENV").unwrap(), InferenceMode::PerEnv);
        assert_eq!(InferenceMode::parse("central").unwrap(), InferenceMode::Batched);
        for m in [InferenceMode::PerEnv, InferenceMode::Batched] {
            assert_eq!(InferenceMode::parse(m.name()).unwrap(), m);
        }
        let err = InferenceMode::parse("remote").unwrap_err().to_string();
        assert!(err.contains("per-env") && err.contains("batched"), "{err}");
    }
}
