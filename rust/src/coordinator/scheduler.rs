//! Unified rollout scheduler: ONE training loop, parameterized by a
//! [`SyncPolicy`] instead of two near-duplicate loops at the extremes.
//!
//! The paper's Table I / Figs 10–12 show that once I/O is optimized the
//! dominant multi-environment efficiency loss is **barrier idle time** —
//! every env waiting for the slowest of `n` episode draws — and its
//! stated future work is barrier-free training. The scheduler makes the
//! barrier a tunable axis:
//!
//! * [`SyncPolicy::Full`] — the classic synchronous iteration: update on
//!   all `n` trajectories (today's validated baseline; bitwise identical
//!   to the pre-refactor loop, see `rust/tests/scheduler_equivalence.rs`);
//! * [`SyncPolicy::Partial`]`{ k }` — update as soon as ANY `k` of `n`
//!   trajectories arrive; stragglers keep running and their episodes join
//!   the next batch, bounding both staleness and idle time;
//! * [`SyncPolicy::Async`] — `k = 1`, the A3C-style barrier-free extreme.
//!
//! Every policy runs on both PPO update backends and both inference
//! modes. Central batched inference composes with partial barriers via
//! [`EnvPool::rollout_batched_subset`](crate::coordinator::pool::EnvPool::rollout_batched_subset):
//! the policy server batches whatever observation set is currently at
//! the barrier (the envs being re-dispatched) instead of requiring all
//! `n`.
//!
//! Per-env parameter versions are tracked for every policy; the loop
//! reports a staleness histogram (`out/staleness.csv`, summarized in
//! [`TrainSummary`]) plus the measured barrier idle seconds (a run
//! total; per update round it mirrors the DES's `barrier_idle_s`
//! mean). The cluster DES
//! (`crate::cluster::des`) consumes the same [`SyncPolicy`] type, so the
//! measured-small/projected-big chain stays truthful for all three
//! policies (`drlfoam reproduce sync` sweeps the k/n ratio).

use std::collections::VecDeque;
use std::io::Write as _;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::policy_server::PolicyServer;
use crate::coordinator::pool::EpisodeOut;
use crate::coordinator::train::{
    setup, update_engine, InferenceMode, IterationLog, TrainConfig, TrainSetup, TrainSummary,
};
use crate::drl::policy::PolicyBackendKind;
use crate::drl::Batch;
use crate::runtime::write_f32_bin;
use crate::util::clock::telemetry_now;
use crate::util::rng::Rng;

/// When the coordinator stops collecting trajectories and updates the
/// policy — the barrier axis shared by the live training loop and the
/// cluster DES (`--sync full|partial:<k>|async`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncPolicy {
    /// Episode barrier over all `n_envs` trajectories (synchronous PPO,
    /// the paper's Fig 4 iteration).
    Full,
    /// Update as soon as any `k` trajectories arrive; stragglers'
    /// episodes join the next batch. `k` is clamped to `[1, n_envs]`, so
    /// `partial:1 == async` and `partial:n_envs == full`.
    Partial { k: usize },
    /// One update per arriving trajectory (`k = 1`, A3C-style barrier-free
    /// training — the paper's stated future-work direction).
    Async,
}

impl SyncPolicy {
    /// Parse a CLI/config string (trimmed, case-insensitive); the error
    /// lists the accepted values.
    pub fn parse(s: &str) -> Result<SyncPolicy> {
        let t = s.trim().to_ascii_lowercase();
        if let Some(ks) = t.strip_prefix("partial:") {
            let k: usize = ks
                .trim()
                .parse()
                .with_context(|| format!("--sync partial:<k> needs an integer, got {ks:?}"))?;
            anyhow::ensure!(k >= 1, "--sync partial:<k> needs k >= 1");
            return Ok(SyncPolicy::Partial { k });
        }
        match t.as_str() {
            "full" | "sync" | "barrier" => Ok(SyncPolicy::Full),
            "async" | "a3c" => Ok(SyncPolicy::Async),
            _ => anyhow::bail!("unknown sync policy {s:?} (accepted: full, partial:<k>, async)"),
        }
    }

    /// Canonical name, inverse of [`SyncPolicy::parse`].
    pub fn name(&self) -> String {
        match self {
            SyncPolicy::Full => "full".to_string(),
            SyncPolicy::Partial { k } => format!("partial:{k}"),
            SyncPolicy::Async => "async".to_string(),
        }
    }

    /// Trajectories per update for a pool of `n_envs` environments.
    pub fn effective_k(&self, n_envs: usize) -> usize {
        let n = n_envs.max(1);
        match self {
            SyncPolicy::Full => n,
            SyncPolicy::Partial { k } => (*k).clamp(1, n),
            SyncPolicy::Async => 1,
        }
    }
}

/// Scheduler's view of one environment.
#[derive(Clone, Copy, PartialEq)]
enum EnvState {
    /// No episode dispatched; eligible for re-dispatch with fresh params.
    Idle,
    /// An episode is running under the params it was dispatched with.
    InFlight,
    /// Episode finished, waiting in the arrival queue for an update.
    Arrived,
}

/// Run the full training loop under `cfg.sync`; returns the learning
/// curve, final policy, and the staleness/idle accounting.
///
/// Episode budget is `iterations * n_envs` for every policy, consumed in
/// `ceil(budget / k)` updates of `k` trajectories each (so `--sync full`
/// performs exactly `iterations` updates, like the pre-refactor loop,
/// and `--sync async` performs one update per episode).
pub fn train(cfg: &TrainConfig) -> Result<TrainSummary> {
    anyhow::ensure!(cfg.n_envs >= 1, "need at least one environment");
    std::fs::create_dir_all(&cfg.out_dir)?;
    std::fs::create_dir_all(&cfg.work_dir)?;
    let n = cfg.n_envs;
    let k = cfg.sync.effective_k(n);
    // The tracing plane must be live BEFORE the pool spawns workers:
    // spawn registers host lanes and sends the clock-offset probes, both
    // of which need an enabled plane with a pinned epoch.
    if cfg.trace.is_some() {
        crate::obs::enable();
    }
    let TrainSetup {
        manifest,
        mut pool,
        mut trainer,
        mut rt,
        updater,
        update_file,
        backend,
        n_obs,
        hidden,
        gamma,
        gae_lambda,
    } = setup(cfg, cfg.inference == InferenceMode::Batched)?;

    let mut server = match cfg.inference {
        InferenceMode::PerEnv => None,
        InferenceMode::Batched => {
            let s = match backend {
                PolicyBackendKind::Xla => {
                    // setup guarantees manifest + runtime on this path
                    let m = manifest.as_ref().context("xla serving needs a manifest")?;
                    let s = PolicyServer::xla(&m.drl);
                    s.load_into(rt.as_mut().context("serving runtime missing")?)?;
                    s
                }
                PolicyBackendKind::Native => PolicyServer::native(n_obs, hidden),
            };
            if !cfg.quiet {
                println!("batched inference: {}", s.describe());
            }
            Some(s)
        }
    };
    if !cfg.quiet && cfg.sync != SyncPolicy::Full {
        println!("sync policy: {} ({k} of {n} trajectories per update)", cfg.sync.name());
    }
    if cfg.sync == SyncPolicy::Async && cfg.inference == InferenceMode::Batched && !cfg.quiet {
        // the lockstep protocol completes its dispatch set together, so
        // async-with-batched-serving fully serializes generation and
        // updates — it runs correctly, but without the compute/update
        // overlap that is the point of async; say so out loud
        eprintln!(
            "warning: --sync async with --inference batched has no compute/update \
             overlap (the lockstep rollout is itself a barrier); \
             --inference per-env is the faithful async mode"
        );
    }

    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    let total_episodes = cfg.iterations * n;
    let total_updates = total_episodes.div_ceil(k);

    // per-env scheduling state: parameter version at dispatch, episode
    // counter (drives the exploration seed, one stream per (env, episode)
    // like the pre-refactor loops), and the idle/in-flight/arrived phase
    let mut state = vec![EnvState::Idle; n];
    let mut ep_count = vec![0u64; n];
    let mut env_version = vec![0u64; n];
    let mut version: u64 = 0;
    let mut dispatched = 0usize;
    let mut consumed = 0usize;
    // finished episodes not yet consumed by an update, in arrival order;
    // idle time is measured from each episode's worker-side
    // `completed_at` stamp, so episodes finishing while an update runs
    // are charged their true wait even though the single-threaded
    // coordinator drains them later
    let mut arrived: VecDeque<EpisodeOut> = VecDeque::new();

    let mut log = Vec::with_capacity(total_updates);
    let mut io_bytes_acc = 0u64;
    let mut stale_hist: Vec<usize> = Vec::new();
    let mut stale_sum = 0u64;
    let mut barrier_idle_s = 0.0f64;
    let t_total = telemetry_now();

    // Buffered writers so the per-row writeln!s don't issue one tiny
    // syscall each; flushed once per iteration so a crashed or killed run
    // still leaves every completed iteration on disk.
    let mut csv =
        std::io::BufWriter::new(std::fs::File::create(cfg.out_dir.join("train_log.csv"))?);
    writeln!(
        csv,
        "iteration,episodes,mean_reward,mean_cd,mean_cl_abs,jet_final,pi_loss,v_loss,approx_kl,rollout_s,update_s,cfd_s,io_s,policy_s"
    )?;
    let mut stale_csv =
        std::io::BufWriter::new(std::fs::File::create(cfg.out_dir.join("staleness.csv"))?);
    writeln!(stale_csv, "update,env_id,episode,staleness,wait_s")?;

    for it in 0..total_updates {
        let take = k.min(total_episodes - consumed);
        let t0 = telemetry_now();

        match &mut server {
            None => {
                // per-env inference: re-dispatch every idle env with the
                // fresh params, then block until `take` arrivals are in
                // (recv_one drains already-finished episodes first)
                if dispatched < total_episodes && state.contains(&EnvState::Idle) {
                    let params = Arc::new(trainer.params.clone());
                    for e in 0..n {
                        if state[e] == EnvState::Idle && dispatched < total_episodes {
                            env_version[e] = version;
                            pool.dispatch(e, &params, cfg.horizon, ep_count[e])?;
                            ep_count[e] += 1;
                            state[e] = EnvState::InFlight;
                            dispatched += 1;
                        }
                    }
                }
                while arrived.len() < take {
                    let out = pool.recv_one()?;
                    state[out.env_id] = EnvState::Arrived;
                    arrived.push_back(out);
                }
            }
            Some(s) => {
                // central batched inference: the lockstep rollout spans
                // exactly the idle envs — the observation set currently at
                // the barrier — and completes them together; partial
                // policies then consume the arrival queue across rounds
                while arrived.len() < take {
                    let mut jobs: Vec<(usize, u64)> = Vec::new();
                    for e in 0..n {
                        if state[e] == EnvState::Idle && dispatched + jobs.len() < total_episodes
                        {
                            jobs.push((e, ep_count[e]));
                        }
                    }
                    for &(e, _) in &jobs {
                        env_version[e] = version;
                        ep_count[e] += 1;
                        state[e] = EnvState::InFlight;
                    }
                    dispatched += jobs.len();
                    let params = Arc::new(trainer.params.clone());
                    let outs =
                        pool.rollout_batched_subset(rt.as_ref(), s, &params, cfg.horizon, &jobs)?;
                    for out in outs {
                        state[out.env_id] = EnvState::Arrived;
                        arrived.push_back(out);
                    }
                }
            }
        }

        // consume the oldest `take` arrivals; sorting by env id makes the
        // batch layout independent of wall-clock arrival order (and, under
        // Full, reproduces the pre-refactor loop bitwise)
        let mut batch_eps: Vec<EpisodeOut> = arrived.drain(..take).collect();
        batch_eps.sort_by_key(|o| o.env_id);
        let rollout_s = t0.elapsed().as_secs_f64();

        let t_update_start = telemetry_now();
        for o in &batch_eps {
            let e = o.env_id;
            let stale = version - env_version[e];
            stale_sum += stale;
            let si = stale as usize;
            if stale_hist.len() <= si {
                stale_hist.resize(si + 1, 0);
            }
            stale_hist[si] += 1;
            let wait = t_update_start
                .saturating_duration_since(o.completed_at)
                .as_secs_f64();
            barrier_idle_s += wait;
            crate::obs::record_measured(
                crate::obs::Phase::BarrierIdle,
                o.completed_at,
                wait,
                e as u32,
                ep_count[e] - 1,
            );
            writeln!(
                stale_csv,
                "{},{},{},{},{:.4}",
                it,
                e,
                ep_count[e] - 1,
                stale,
                wait
            )?;
            state[e] = EnvState::Idle;
        }
        consumed += take;

        let nf = batch_eps.len() as f64;
        let mean_reward = batch_eps.iter().map(|o| o.stats.reward_sum).sum::<f64>() / nf;
        let mean_cd = batch_eps.iter().map(|o| o.stats.cd_mean).sum::<f64>() / nf;
        let mean_cl = batch_eps.iter().map(|o| o.stats.cl_abs_mean).sum::<f64>() / nf;
        let jet_final = batch_eps.last().map(|o| o.stats.jet_final).unwrap_or(0.0);
        let cfd_s = batch_eps.iter().map(|o| o.stats.cfd_s).sum::<f64>() / nf;
        let io_s = batch_eps.iter().map(|o| o.stats.io_s).sum::<f64>() / nf;
        let policy_s = batch_eps.iter().map(|o| o.stats.policy_s).sum::<f64>() / nf;
        io_bytes_acc += batch_eps
            .iter()
            .map(|o| o.stats.io.bytes_written + o.stats.io.bytes_read)
            .sum::<u64>();

        let trajs: Vec<_> = batch_eps.into_iter().map(|o| o.traj).collect();
        let batch = Batch::assemble(&trajs, n_obs, gamma, gae_lambda);
        crate::obs::set_thread_episode(it as u64);
        let upd = trainer.update(update_engine(&updater, &rt, &update_file)?, &batch, &mut rng)?;
        version += 1;

        let row = IterationLog {
            iteration: it,
            episodes_done: consumed,
            mean_reward,
            mean_cd,
            mean_cl_abs: mean_cl,
            jet_final,
            pi_loss: upd.pi_loss,
            v_loss: upd.v_loss,
            approx_kl: upd.approx_kl,
            rollout_s,
            update_s: upd.wall_s,
            cfd_s,
            io_s,
            policy_s,
        };
        writeln!(
            csv,
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.4},{:.4},{:.4},{:.4},{:.4}",
            row.iteration,
            row.episodes_done,
            row.mean_reward,
            row.mean_cd,
            row.mean_cl_abs,
            row.jet_final,
            row.pi_loss,
            row.v_loss,
            row.approx_kl,
            row.rollout_s,
            row.update_s,
            row.cfd_s,
            row.io_s,
            row.policy_s
        )?;
        if !cfg.quiet && it % cfg.log_every == 0 {
            println!(
                "iter {:>4}  ep {:>5}  R {:>8.4}  Cd {:>6.3}  |Cl| {:>6.3}  kl {:>8.5}  rollout {:>6.2}s  update {:>5.2}s",
                it, consumed, mean_reward, mean_cd, mean_cl, upd.approx_kl, rollout_s, upd.wall_s
            );
        }
        log.push(row);
        csv.flush()?;
        stale_csv.flush()?;
    }

    let final_params = trainer.params.clone();
    write_f32_bin(cfg.out_dir.join("policy_final.bin"), &final_params)
        .context("writing final policy")?;
    write_f32_bin(cfg.out_dir.join("trainer_ckpt.bin"), &trainer.checkpoint())?;

    // per-worker telemetry + fault accounting (out/workers.csv): wall and
    // component seconds per environment, plus how often its worker had to
    // be respawned. Under the multi-process executor these are *real
    // process* timings — the measured source `--layout auto` calibrates
    // from.
    let restarts_by_env = pool.restarts_by_env();
    let worker_restarts: usize = restarts_by_env.iter().sum::<usize>();
    let mut wcsv =
        std::io::BufWriter::new(std::fs::File::create(cfg.out_dir.join("workers.csv"))?);
    writeln!(wcsv, "env_id,episodes,restarts,wall_s,cfd_s,io_s,policy_s")?;
    for (e, t) in pool.telemetry().iter().enumerate() {
        writeln!(
            wcsv,
            "{},{},{},{:.4},{:.4},{:.4},{:.4}",
            e, t.episodes, restarts_by_env[e], t.wall_s, t.cfd_s, t.io_s, t.policy_s
        )?;
    }
    wcsv.flush()?;
    if worker_restarts > 0 && !cfg.quiet {
        println!(
            "fault handling: {worker_restarts} worker restart(s); each lost episode was \
             re-queued and replayed (per-env counts in {}/workers.csv)",
            cfg.out_dir.display()
        );
    }

    let mean_staleness = stale_sum as f64 / consumed.max(1) as f64;
    if !cfg.quiet && cfg.sync != SyncPolicy::Full {
        println!(
            "sync={}: mean staleness {:.3} (histogram {:?}), barrier idle {:.2}s total",
            cfg.sync.name(),
            mean_staleness,
            stale_hist,
            barrier_idle_s
        );
    }

    // Tracing export: tear the pool down FIRST so process workers receive
    // Shutdown, flush their final telemetry batches, and the reader
    // threads ingest them on the way out; then give stragglers a short
    // settle window (ingest_seq ticks while batches are still landing).
    if let Some(trace_path) = &cfg.trace {
        drop(server);
        drop(pool);
        let mut last = crate::obs::ingest_seq();
        let mut stable = 0u32;
        for _ in 0..10 {
            std::thread::sleep(std::time::Duration::from_millis(25));
            let cur = crate::obs::ingest_seq();
            if cur == last {
                stable += 1;
                if stable >= 2 {
                    break;
                }
            } else {
                stable = 0;
                last = cur;
            }
        }
        let drift = cfg
            .trace_calib
            .clone()
            .map(|calib| crate::obs::export::DriftSpec {
                calib,
                sim: crate::cluster::SimConfig {
                    n_envs: n,
                    n_ranks: cfg.ranks_per_env,
                    episodes_total: consumed,
                    io_mode: cfg.io_mode,
                    sync: cfg.sync,
                    remote_envs: if cfg.hosts.is_empty() { 0 } else { n },
                    seed: cfg.seed,
                },
                episodes: consumed,
                rounds: log.len(),
            });
        let rep = crate::obs::export::export(trace_path, &cfg.out_dir, drift.as_ref())?;
        if !cfg.quiet {
            println!(
                "trace: {} span(s) -> {} (load in ui.perfetto.dev); per-phase summary {}",
                rep.spans,
                rep.trace_path.display(),
                rep.summary_path.display()
            );
            if let Some(d) = &rep.drift_path {
                println!("trace: plan-vs-actual drift -> {}", d.display());
            }
        }
        for w in &rep.drift_warnings {
            eprintln!("warning: {w}");
        }
    }

    Ok(TrainSummary {
        io_bytes_per_episode: io_bytes_acc as f64 / consumed.max(1) as f64,
        log,
        final_params,
        total_s: t_total.elapsed().as_secs_f64(),
        mean_staleness,
        staleness_hist: stale_hist,
        barrier_idle_s,
        worker_restarts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_policy_parse_is_lenient_and_lists_accepted() {
        assert_eq!(SyncPolicy::parse(" Full ").unwrap(), SyncPolicy::Full);
        assert_eq!(SyncPolicy::parse("ASYNC").unwrap(), SyncPolicy::Async);
        assert_eq!(
            SyncPolicy::parse("partial:3").unwrap(),
            SyncPolicy::Partial { k: 3 }
        );
        assert_eq!(
            SyncPolicy::parse(" Partial:12 ").unwrap(),
            SyncPolicy::Partial { k: 12 }
        );
        for p in [
            SyncPolicy::Full,
            SyncPolicy::Partial { k: 7 },
            SyncPolicy::Async,
        ] {
            assert_eq!(SyncPolicy::parse(&p.name()).unwrap(), p);
        }
        assert!(SyncPolicy::parse("partial:0").is_err());
        assert!(SyncPolicy::parse("partial:x").is_err());
        let err = SyncPolicy::parse("lockstep").unwrap_err().to_string();
        assert!(
            err.contains("full") && err.contains("partial") && err.contains("async"),
            "{err}"
        );
    }

    #[test]
    fn effective_k_clamps_to_the_pool() {
        assert_eq!(SyncPolicy::Full.effective_k(8), 8);
        assert_eq!(SyncPolicy::Async.effective_k(8), 1);
        assert_eq!(SyncPolicy::Partial { k: 3 }.effective_k(8), 3);
        assert_eq!(SyncPolicy::Partial { k: 99 }.effective_k(8), 8);
        assert_eq!(SyncPolicy::Partial { k: 3 }.effective_k(2), 2);
        assert_eq!(SyncPolicy::Full.effective_k(0), 1);
    }
}
