//! Central batched policy server: the paper's hybrid-parallelization axis.
//!
//! The per-env mode gives every worker thread its own serving session and
//! pays one XLA dispatch per environment per actuation period. This server
//! instead collects the observations of *all* environments at the
//! coordinator's sync barrier and runs ONE forward pass over the whole
//! `[N_envs, n_obs]` batch per period:
//!
//! * **XLA backend** — uses the `policy_apply_b<B>` artifact when the
//!   manifest ships one (observations padded up to the static batch B,
//!   parameters device-resident between calls); falls back to per-row
//!   B=1 calls against the same device-resident parameters otherwise.
//! * **Native backend** — [`NativePolicy`] batched forward, used by
//!   artifact-free scenarios (surrogate) and by the mode-equivalence test:
//!   its per-row arithmetic is bitwise identical to the per-env path.
//!
//! Action *sampling* stays outside the server (the coordinator owns one
//! RNG stream per environment, seeded exactly like the per-env workers, so
//! the two inference modes emit identical actions for the same seed).

use anyhow::{Context, Result};

use crate::drl::policy::{NativePolicy, PolicyOutput};
use crate::runtime::{to_vec_f32, DrlManifest, Runtime};

enum ServerKind {
    Xla {
        /// B=1 artifact (fallback path)
        b1_file: String,
        /// static-batch artifact, when the manifest ships one
        batch_file: Option<String>,
        /// static batch dimension of `batch_file`
        batch: usize,
        /// device-resident parameters (refreshed by [`PolicyServer::set_params`])
        params_buf: Option<xla::PjRtBuffer>,
    },
    Native {
        net: NativePolicy,
    },
}

/// Batched inference engine owned by the coordinator (see module docs).
pub struct PolicyServer {
    kind: ServerKind,
    n_obs: usize,
}

impl PolicyServer {
    /// XLA server over the manifest's policy artifacts. Call
    /// [`PolicyServer::load_into`] once on the coordinator runtime before
    /// serving.
    pub fn xla(drl: &DrlManifest) -> PolicyServer {
        PolicyServer {
            kind: ServerKind::Xla {
                b1_file: drl.policy_apply_file.clone(),
                batch_file: drl.policy_apply_batch_file.clone(),
                batch: drl.policy_batch.max(1),
                params_buf: None,
            },
            n_obs: drl.n_obs,
        }
    }

    /// Pure-Rust server (no artifacts, no runtime needed).
    pub fn native(n_obs: usize, hidden: usize) -> PolicyServer {
        PolicyServer {
            kind: ServerKind::Native {
                net: NativePolicy::new(n_obs, hidden),
            },
            n_obs,
        }
    }

    pub fn n_obs(&self) -> usize {
        self.n_obs
    }

    /// Human-readable serving-path description for logs and benches.
    pub fn describe(&self) -> String {
        match &self.kind {
            ServerKind::Xla {
                batch_file: Some(_),
                batch,
                ..
            } => format!("xla batched (B={batch})"),
            ServerKind::Xla { .. } => "xla per-row (no batch artifact)".to_string(),
            ServerKind::Native { .. } => "native batched".to_string(),
        }
    }

    /// Compile the artifacts this server will execute (XLA backend only).
    pub fn load_into(&self, rt: &mut Runtime) -> Result<()> {
        if let ServerKind::Xla {
            b1_file,
            batch_file,
            ..
        } = &self.kind
        {
            rt.load(b1_file)?;
            if let Some(bf) = batch_file {
                rt.load(bf)?;
            }
        }
        Ok(())
    }

    /// Refresh the served parameters (uploads once per training iteration
    /// on the XLA backend; the batch loop then reuses the device buffer).
    pub fn set_params(&mut self, rt: Option<&Runtime>, params: &[f32]) -> Result<()> {
        if let ServerKind::Xla { params_buf, .. } = &mut self.kind {
            let rt = rt.context("XLA policy server needs the coordinator runtime")?;
            *params_buf = Some(rt.upload_f32(params, &[params.len()])?);
        }
        Ok(())
    }

    /// One inference pass over the whole environment batch; `out[e]` is the
    /// policy output for `obs[e]`.
    pub fn infer_batch(
        &self,
        rt: Option<&Runtime>,
        params: &[f32],
        obs: &[Vec<f32>],
    ) -> Result<Vec<PolicyOutput>> {
        let _g = crate::obs::span(crate::obs::Phase::PolicyBatch);
        crate::obs::bump("policy.batch_rows", obs.len() as u64);
        match &self.kind {
            ServerKind::Native { net } => net.apply_batch(params, obs),
            ServerKind::Xla {
                b1_file,
                batch_file,
                batch,
                params_buf,
            } => {
                let rt = rt.context("XLA policy server needs the coordinator runtime")?;
                let pbuf = params_buf
                    .as_ref()
                    .context("PolicyServer::set_params not called")?;
                let mut out = Vec::with_capacity(obs.len());
                if let Some(bf) = batch_file {
                    let exe = rt.get(bf)?;
                    for chunk in obs.chunks(*batch) {
                        // pad up to the static batch dimension
                        let mut flat = vec![0.0f32; batch * self.n_obs];
                        for (r, row) in chunk.iter().enumerate() {
                            anyhow::ensure!(row.len() == self.n_obs, "obs len {}", row.len());
                            flat[r * self.n_obs..(r + 1) * self.n_obs].copy_from_slice(row);
                        }
                        let obuf = rt.upload_f32(&flat, &[*batch, self.n_obs])?;
                        let outs = exe.run_b(&[pbuf, &obuf])?;
                        anyhow::ensure!(outs.len() == 3, "policy_apply returned {}", outs.len());
                        let mu = to_vec_f32(&outs[0])?;
                        let logstd = to_vec_f32(&outs[1])?[0] as f64;
                        let value = to_vec_f32(&outs[2])?;
                        for r in 0..chunk.len() {
                            out.push(PolicyOutput {
                                mu: mu[r] as f64,
                                logstd,
                                value: value[r] as f64,
                            });
                        }
                    }
                } else {
                    // fallback: per-row B=1 calls, parameters still resident
                    let exe = rt.get(b1_file)?;
                    for row in obs {
                        anyhow::ensure!(row.len() == self.n_obs, "obs len {}", row.len());
                        let obuf = rt.upload_f32(row, &[1, self.n_obs])?;
                        let outs = exe.run_b(&[pbuf, &obuf])?;
                        anyhow::ensure!(outs.len() == 3, "policy_apply returned {}", outs.len());
                        out.push(PolicyOutput {
                            mu: to_vec_f32(&outs[0])?[0] as f64,
                            logstd: to_vec_f32(&outs[1])?[0] as f64,
                            value: to_vec_f32(&outs[2])?[0] as f64,
                        });
                    }
                }
                Ok(out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_server_matches_native_policy() {
        let net = NativePolicy::new(5, 8);
        let params = net.init_params(2);
        let server = PolicyServer::native(5, 8);
        let obs: Vec<Vec<f32>> = vec![vec![0.1; 5], vec![-0.3; 5]];
        let outs = server.infer_batch(None, &params, &obs).unwrap();
        for (row, o) in obs.iter().zip(&outs) {
            let single = net.apply(&params, row).unwrap();
            assert_eq!(single.mu, o.mu);
            assert_eq!(single.value, o.value);
        }
        assert!(server.describe().contains("native"));
    }
}
