//! L3 coordination: the paper's multi-environment parallel DRL training
//! framework (Fig 4), in Rust.
//!
//! * [`pool`]  — N environment workers on OS threads, each owning a full
//!   PJRT runtime + CFD environment + exchange interface; the agent
//!   broadcasts parameters at iteration start and the workers roll out
//!   episodes independently ("embarrassingly parallel" data collection).
//! * [`train`] — the synchronous PPO training loop: broadcast -> rollout
//!   barrier -> GAE -> minibatch updates -> log, exactly the structure
//!   whose scaling the paper studies.

pub mod async_train;
pub mod pool;
pub mod train;

pub use pool::{EnvPool, EpisodeOut, EpisodeStats, PoolConfig};
pub use async_train::{train_async, AsyncTrainSummary};
pub use train::{train, TrainConfig, TrainSummary};
