//! L3 coordination: the paper's multi-environment parallel DRL training
//! framework (Fig 4), in Rust.
//!
//! * [`pool`]  — N scenario workers on OS threads, each owning a full
//!   environment instance (for CFD scenarios: a private PJRT runtime +
//!   exchange interface); supports per-env serving and the lockstep
//!   protocol behind the batched mode.
//! * [`policy_server`] — central batched inference: one forward pass over
//!   the whole `[N_envs, n_obs]` observation batch per actuation period
//!   (the paper's hybrid-parallelization axis).
//! * [`train`] — the synchronous PPO training loop: broadcast -> rollout
//!   barrier -> GAE -> minibatch updates -> log, exactly the structure
//!   whose scaling the paper studies; rollouts run in either inference
//!   mode and the update on either backend (XLA artifact or the native
//!   pure-Rust step). With no manifest present, both loops fall back to
//!   the fully artifact-free path (surrogate scenario, native backends).
//! * [`async_train`] — the barrier-free A3C-style variant (per-env
//!   inference only: there is no common sync point to batch at; the
//!   ignored `--inference batched` flag warns instead of silently
//!   no-opping).

pub mod async_train;
pub mod policy_server;
pub mod pool;
pub mod train;

pub use async_train::{train_async, AsyncTrainSummary};
pub use policy_server::PolicyServer;
pub use pool::{EnvPool, EpisodeOut, EpisodeStats, LocalPolicy, PoolConfig};
pub use train::{train, InferenceMode, TrainConfig, TrainSummary};
