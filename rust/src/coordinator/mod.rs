//! L3 coordination: the paper's multi-environment parallel DRL training
//! framework (Fig 4), in Rust.
//!
//! * [`pool`]  — N scenario workers, each owning a full environment
//!   instance (for CFD scenarios: a private PJRT runtime + exchange
//!   interface); supports per-env serving and the lockstep protocol
//!   behind the batched mode. Workers run on either execution backend
//!   of [`crate::exec`] — OS threads (default) or `drlfoam worker`
//!   processes (`--executor multi-process`) — behind one `Executor`
//!   handle.
//! * [`policy_server`] — central batched inference: one forward pass over
//!   the whole `[N_envs, n_obs]` observation batch per actuation period
//!   (the paper's hybrid-parallelization axis).
//! * [`scheduler`] — the ONE training loop, parameterized by
//!   [`SyncPolicy`]: full episode barrier (the synchronous structure
//!   whose scaling the paper studies), partial barrier (update on any
//!   `k` of `n` trajectories; stragglers join the next batch), or async
//!   (A3C-style, one update per arriving trajectory — the paper's
//!   future-work direction). Rollouts run in either inference mode and
//!   the update on either backend (XLA artifact or the native pure-Rust
//!   step); with no manifest present the loop falls back to the fully
//!   artifact-free path (surrogate scenario, native backends).
//! * [`train`](mod@train) — run configuration ([`TrainConfig`]) and the
//!   shared setup both the scheduler and the CLI resolve backends
//!   through.
//!
//! The cluster DES (`crate::cluster::des`) mirrors the same
//! [`SyncPolicy`] type, so live measurements and 60-core projections
//! describe the same schedule.

pub mod policy_server;
pub mod pool;
pub mod scheduler;
pub mod train;

pub use policy_server::PolicyServer;
pub use pool::{EnvPool, EnvTelemetry, EpisodeOut, EpisodeStats, LocalPolicy, PoolConfig};
pub use scheduler::{train, SyncPolicy};
pub use train::{InferenceMode, IterationLog, TrainConfig, TrainSummary};
