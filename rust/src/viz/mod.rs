//! Flow-field visualisation: vorticity contours as PPM images
//! (reproduces the paper's Fig 5(e)-(j) panels without any plotting
//! dependency — PPM is plain bytes; `convert out/*.ppm out/*.png` if
//! ImageMagick is around).

use std::io::Write as _;
use std::path::Path;

use anyhow::Result;

/// z-vorticity omega = dv/dx - du/dy on the uniform grid (central
/// differences; boundary ring copied from the first interior ring).
pub fn vorticity(u: &[f32], v: &[f32], ny: usize, nx: usize, h: f64) -> Vec<f32> {
    assert_eq!(u.len(), ny * nx);
    assert_eq!(v.len(), ny * nx);
    let mut w = vec![0f32; ny * nx];
    let inv2h = (1.0 / (2.0 * h)) as f32;
    for j in 1..ny - 1 {
        for i in 1..nx - 1 {
            let dvdx = (v[j * nx + i + 1] - v[j * nx + i - 1]) * inv2h;
            let dudy = (u[(j + 1) * nx + i] - u[(j - 1) * nx + i]) * inv2h;
            w[j * nx + i] = dvdx - dudy;
        }
    }
    // copy edges for a clean image
    for i in 0..nx {
        w[i] = w[nx + i];
        w[(ny - 1) * nx + i] = w[(ny - 2) * nx + i];
    }
    for j in 0..ny {
        w[j * nx] = w[j * nx + 1];
        w[j * nx + nx - 1] = w[j * nx + nx - 2];
    }
    w
}

/// Blue-white-red diverging colormap over [-scale, +scale].
fn bwr(x: f32, scale: f32) -> [u8; 3] {
    let t = (x / scale).clamp(-1.0, 1.0);
    if t >= 0.0 {
        // white -> red
        let k = t;
        [255, (255.0 * (1.0 - k)) as u8, (255.0 * (1.0 - k)) as u8]
    } else {
        // blue <- white
        let k = -t;
        [(255.0 * (1.0 - k)) as u8, (255.0 * (1.0 - k)) as u8, 255]
    }
}

/// Render a scalar field to a binary PPM (P6). Row 0 of the field is the
/// channel bottom, so the image is flipped vertically for display.
pub fn write_ppm(
    path: impl AsRef<Path>,
    field: &[f32],
    ny: usize,
    nx: usize,
    scale: f32,
    solid: Option<&dyn Fn(usize, usize) -> bool>,
) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut buf = Vec::with_capacity(nx * ny * 3 + 32);
    write!(buf, "P6\n{nx} {ny}\n255\n")?;
    for j in (0..ny).rev() {
        for i in 0..nx {
            let px = if solid.map(|f| f(j, i)).unwrap_or(false) {
                [40u8, 40, 40]
            } else {
                bwr(field[j * nx + i], scale)
            };
            buf.extend_from_slice(&px);
        }
    }
    std::fs::write(path.as_ref(), buf)?;
    Ok(())
}

/// Convenience: vorticity snapshot of a flow state, cylinder blacked out.
pub fn vorticity_snapshot(
    path: impl AsRef<Path>,
    u: &[f32],
    v: &[f32],
    ny: usize,
    nx: usize,
    h: f64,
    x_up: f64,
    y_lo: f64,
    radius: f64,
) -> Result<()> {
    let w = vorticity(u, v, ny, nx, h);
    let solid = move |j: usize, i: usize| {
        let x = -x_up + (i as f64 + 0.5) * h;
        let y = y_lo + (j as f64 + 0.5) * h;
        (x * x + y * y).sqrt() < radius
    };
    write_ppm(path, &w, ny, nx, 5.0, Some(&solid))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vorticity_of_solid_rotation() {
        // u = -y, v = x -> omega = 2 everywhere
        let (ny, nx, h) = (16usize, 20usize, 0.5);
        let mut u = vec![0f32; ny * nx];
        let mut v = vec![0f32; ny * nx];
        for j in 0..ny {
            for i in 0..nx {
                let x = i as f64 * h;
                let y = j as f64 * h;
                u[j * nx + i] = -y as f32;
                v[j * nx + i] = x as f32;
            }
        }
        let w = vorticity(&u, &v, ny, nx, h);
        for j in 2..ny - 2 {
            for i in 2..nx - 2 {
                assert!((w[j * nx + i] - 2.0).abs() < 1e-4, "w = {}", w[j * nx + i]);
            }
        }
    }

    #[test]
    fn ppm_dimensions_and_header() {
        let dir = std::env::temp_dir().join(format!("drlfoam-viz-{}", std::process::id()));
        let p = dir.join("t.ppm");
        let field = vec![0f32; 6 * 4];
        write_ppm(&p, &field, 6, 4, 1.0, None).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n4 6\n255\n"));
        assert_eq!(bytes.len(), 11 + 4 * 6 * 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn colormap_endpoints() {
        assert_eq!(bwr(1.0, 1.0), [255, 0, 0]);
        assert_eq!(bwr(-1.0, 1.0), [0, 0, 255]);
        assert_eq!(bwr(0.0, 1.0), [255, 255, 255]);
    }
}
