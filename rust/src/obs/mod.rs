//! Unified tracing plane: span/counter telemetry across every executor.
//!
//! Off by default and bitwise-invisible to learning output: recording is
//! gated on one relaxed atomic load, spans carry only wall-time
//! measurements, and nothing here feeds a scored or learned value. The
//! module is part of the determinism-critical audit set, so its two
//! sanctioned clock reads are capped in `rust/audit.allow` like every
//! other telemetry read (ARCHITECTURE.md §9, §12).
//!
//! Recording model:
//! * every thread buffers spans locally ([`span`] guards, [`record`]);
//!   buffers drain into the global sink on thread exit, on explicit
//!   [`flush_thread`], and when a worker packs them into a
//!   [`Frame::Telemetry`](crate::exec::wire::Frame) batch;
//! * worker *processes* ship their batches on the control channel; the
//!   coordinator's reader threads ingest them via [`ingest_remote`],
//!   shifting each span by the worker's clock offset;
//! * clock offsets come from a probe/echo handshake over the same frame:
//!   the coordinator stamps a probe with its own µs clock, the worker
//!   echoes it alongside its clock, and [`record_probe_echo`] keeps the
//!   minimum-RTT NTP-style estimate (the same midpoint arithmetic
//!   `exec::net::measure_rtt` rests on).
//!
//! Exporters live in [`export`]: Chrome-trace-event JSON (Perfetto), the
//! per-phase percentile summary CSV, and the plan-vs-actual drift report
//! against the DES prediction.

pub mod export;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::clock::telemetry_now;

/// `env_id` for spans that belong to the coordinator itself (the PPO
/// update, batched inference) rather than to one environment lane.
pub const NO_ENV: u32 = u32::MAX;

/// The span taxonomy (ARCHITECTURE.md §12). Discriminants are the wire
/// encoding inside `Frame::Telemetry`; an unknown byte from a newer peer
/// is preserved raw, never dropped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Phase {
    /// One CFD actuation period (XLA or native engine).
    Cfd = 0,
    /// Exchange-interface packing/parsing (`io_interface`).
    Io = 1,
    /// Per-env policy forward pass (worker-side serving).
    Policy = 2,
    /// One batched forward pass over all envs (`PolicyServer`).
    PolicyBatch = 3,
    /// Encoding + writing one frame to a worker.
    WireSend = 4,
    /// Waiting for and reading the next frame (on worker lanes this is
    /// the worker's idle time between commands).
    WireRecv = 5,
    /// Coordinator barrier idle: episode finished, update not started.
    BarrierIdle = 6,
    /// One PPO update round (all epochs/minibatches).
    Update = 7,
    /// A worker died and was respawned (zero-duration event).
    Respawn = 8,
    /// One whole episode rollout on an environment.
    Episode = 9,
}

impl Phase {
    pub const ALL: [Phase; 10] = [
        Phase::Cfd,
        Phase::Io,
        Phase::Policy,
        Phase::PolicyBatch,
        Phase::WireSend,
        Phase::WireRecv,
        Phase::BarrierIdle,
        Phase::Update,
        Phase::Respawn,
        Phase::Episode,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Cfd => "cfd",
            Phase::Io => "io",
            Phase::Policy => "policy",
            Phase::PolicyBatch => "policy_batch",
            Phase::WireSend => "wire_send",
            Phase::WireRecv => "wire_recv",
            Phase::BarrierIdle => "barrier_idle",
            Phase::Update => "update",
            Phase::Respawn => "respawn",
            Phase::Episode => "episode",
        }
    }

    pub fn from_u8(v: u8) -> Option<Phase> {
        Phase::ALL.into_iter().find(|p| *p as u8 == v)
    }
}

/// One recorded span. `phase` stays a raw byte end to end (a decoded
/// telemetry frame must re-encode bit-exactly, see the wire fuzz tests).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRec {
    pub phase: u8,
    pub start_us: u64,
    pub dur_us: u64,
    pub env_id: u32,
    pub episode: u64,
}

// --- global state ----------------------------------------------------------

struct Global {
    sink: Vec<SpanRec>,
    /// env_id -> (host index, host label) for per-host Perfetto lanes
    hosts: BTreeMap<u32, (u32, String)>,
    /// (env_id, rank) -> (best rtt_us, offset_us): add offset to a
    /// peer-clock timestamp to land on the coordinator clock
    offsets: BTreeMap<(u32, u32), (u64, i64)>,
    counters: BTreeMap<String, u64>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static INGESTED: AtomicU64 = AtomicU64::new(0);

fn global() -> &'static Mutex<Global> {
    static G: OnceLock<Mutex<Global>> = OnceLock::new();
    G.get_or_init(|| {
        Mutex::new(Global {
            sink: Vec::new(),
            hosts: BTreeMap::new(),
            offsets: BTreeMap::new(),
            counters: BTreeMap::new(),
        })
    })
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(telemetry_now)
}

/// Turn recording on (idempotent). The first call pins the process-local
/// µs epoch every span is measured against.
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Microseconds since the tracing epoch on this process's clock.
pub fn now_us() -> u64 {
    telemetry_now()
        .checked_duration_since(epoch())
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

// --- thread-local recording ------------------------------------------------

struct ThreadBuf {
    env: u32,
    episode: u64,
    spans: Vec<SpanRec>,
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        if !self.spans.is_empty() {
            if let Ok(mut g) = global().lock() {
                g.sink.append(&mut self.spans);
            }
        }
    }
}

thread_local! {
    static TL: RefCell<ThreadBuf> = const {
        RefCell::new(ThreadBuf { env: NO_ENV, episode: 0, spans: Vec::new() })
    };
}

/// Attach this thread's future spans to environment `env`.
pub fn set_thread_env(env: u32) {
    TL.with(|b| b.borrow_mut().env = env);
}

/// Attach this thread's future spans to episode `ep`.
pub fn set_thread_episode(ep: u64) {
    TL.with(|b| b.borrow_mut().episode = ep);
}

/// Push one raw span into this thread's buffer (no clock read).
pub fn record(phase: Phase, start_us: u64, dur_us: u64, env: u32, episode: u64) {
    if !enabled() {
        return;
    }
    TL.with(|b| {
        b.borrow_mut().spans.push(SpanRec {
            phase: phase as u8,
            start_us,
            dur_us,
            env_id: env,
            episode,
        })
    });
}

/// Record a span from a measurement a caller already took — used by the
/// determinism-critical modules so tracing adds no clock reads there:
/// `start` is the Instant they measured from, `dur_s` the elapsed
/// seconds they report as telemetry anyway.
pub fn record_measured(phase: Phase, start: Instant, dur_s: f64, env: u32, episode: u64) {
    if !enabled() {
        return;
    }
    let start_us = start
        .checked_duration_since(epoch())
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0);
    record(phase, start_us, (dur_s.max(0.0) * 1e6) as u64, env, episode);
}

/// [`record_measured`] against this thread's ambient env/episode (set by
/// the worker loops via [`set_thread_env`] / [`set_thread_episode`]) —
/// for call sites like the CFD advance that don't know their env id.
pub fn record_measured_here(phase: Phase, start: Instant, dur_s: f64) {
    if !enabled() {
        return;
    }
    let (env, episode) = TL.with(|b| {
        let b = b.borrow();
        (b.env, b.episode)
    });
    record_measured(phase, start, dur_s, env, episode);
}

/// Zero-duration marker (respawn events and the like).
pub fn event(phase: Phase, env: u32) {
    if !enabled() {
        return;
    }
    record(phase, now_us(), 0, env, 0);
}

/// Bump a named counter by `n` (e.g. native CFD periods, batched rows).
pub fn bump(name: &str, n: u64) {
    if !enabled() {
        return;
    }
    if let Ok(mut g) = global().lock() {
        *g.counters.entry(name.to_string()).or_insert(0) += n;
    }
}

/// RAII span: records on drop. Cheap no-op when tracing is off.
pub struct SpanGuard {
    phase: Phase,
    start_us: u64,
    env: Option<u32>,
    on: bool,
}

impl SpanGuard {
    /// Use `env` instead of the thread's ambient environment.
    pub fn for_env(mut self, env: u32) -> SpanGuard {
        self.env = Some(env);
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.on {
            return;
        }
        let end = now_us();
        let dur = end.saturating_sub(self.start_us);
        TL.with(|b| {
            let mut b = b.borrow_mut();
            let env = self.env.unwrap_or(b.env);
            let episode = b.episode;
            b.spans.push(SpanRec {
                phase: self.phase as u8,
                start_us: self.start_us,
                dur_us: dur,
                env_id: env,
                episode,
            });
        });
    }
}

/// Open a span for `phase`; it records when the guard drops.
#[inline]
pub fn span(phase: Phase) -> SpanGuard {
    let on = enabled();
    SpanGuard {
        phase,
        start_us: if on { now_us() } else { 0 },
        env: None,
        on,
    }
}

/// Move this thread's buffered spans into the global sink.
pub fn flush_thread() {
    TL.with(|b| {
        let mut b = b.borrow_mut();
        if !b.spans.is_empty() {
            if let Ok(mut g) = global().lock() {
                g.sink.append(&mut b.spans);
            }
        }
    });
}

/// Take every span this process has buffered (thread-local + sink) —
/// what a worker packs into a `Frame::Telemetry` batch.
pub fn take_all_spans() -> Vec<SpanRec> {
    let mut out = TL.with(|b| std::mem::take(&mut b.borrow_mut().spans));
    if let Ok(mut g) = global().lock() {
        out.append(&mut g.sink);
    }
    out
}

// --- coordinator-side merge ------------------------------------------------

/// NTP-style midpoint estimate from one probe/echo exchange, all on the
/// coordinator clock except `peer_us`: `sent_us` = probe departure,
/// `recv_us` = echo arrival, `peer_us` = the worker clock inside the
/// echo. Returns `(rtt_us, offset_us)` where adding `offset_us` to a
/// peer timestamp lands it on the coordinator clock.
pub fn clock_offset(sent_us: u64, recv_us: u64, peer_us: u64) -> (u64, i64) {
    let rtt = recv_us.saturating_sub(sent_us);
    let mid = sent_us + rtt / 2;
    (rtt, mid as i64 - peer_us as i64)
}

/// Fold one probe echo into the per-worker offset table, keeping the
/// minimum-RTT sample (the least-delayed, hence least-biased, estimate).
pub fn record_probe_echo(env: u32, rank: u32, sent_us: u64, peer_us: u64, recv_us: u64) {
    let (rtt, offset) = clock_offset(sent_us, recv_us, peer_us);
    if let Ok(mut g) = global().lock() {
        let e = g.offsets.entry((env, rank)).or_insert((u64::MAX, 0));
        if rtt < e.0 {
            *e = (rtt, offset);
        }
    }
    INGESTED.fetch_add(1, Ordering::SeqCst);
}

/// Merge a worker's span batch onto the coordinator timeline, shifting
/// every span by the worker's current best clock offset.
pub fn ingest_remote(env: u32, rank: u32, spans: Vec<SpanRec>) {
    if let Ok(mut g) = global().lock() {
        let off = g.offsets.get(&(env, rank)).map(|e| e.1).unwrap_or(0);
        for mut s in spans {
            s.start_us = (s.start_us as i64).saturating_add(off).max(0) as u64;
            g.sink.push(s);
        }
    }
    INGESTED.fetch_add(1, Ordering::SeqCst);
}

/// Monotone ingest counter — the exporter polls it briefly after pool
/// shutdown so late-arriving worker batches still land in the trace.
pub fn ingest_seq() -> u64 {
    INGESTED.load(Ordering::SeqCst)
}

/// Label environment `env`'s Perfetto lane with its host.
pub fn set_env_host(env: u32, host_idx: u32, label: &str) {
    if let Ok(mut g) = global().lock() {
        g.hosts.insert(env, (host_idx, label.to_string()));
    }
}

/// Everything the exporters consume; draining resets the plane for the
/// next run in this process.
pub struct Drained {
    pub spans: Vec<SpanRec>,
    pub hosts: BTreeMap<u32, (u32, String)>,
    pub counters: BTreeMap<String, u64>,
}

pub fn drain_all() -> Drained {
    flush_thread();
    let mut g = global().lock().expect("obs global poisoned");
    Drained {
        spans: std::mem::take(&mut g.sink),
        hosts: std::mem::take(&mut g.hosts),
        counters: std::mem::take(&mut g.counters),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: obs state is process-global; these tests drain what they
    // record and never assert on absolute sink contents.

    #[test]
    fn disabled_span_records_nothing_enabled_span_records() {
        disable();
        {
            let _g = span(Phase::Cfd);
        }
        enable();
        set_thread_env(3);
        set_thread_episode(5);
        {
            let _g = span(Phase::Policy);
        }
        {
            let _g = span(Phase::Io).for_env(9);
        }
        let spans = take_all_spans();
        disable();
        let pol: Vec<_> = spans.iter().filter(|s| s.phase == Phase::Policy as u8).collect();
        assert_eq!(pol.len(), 1);
        assert_eq!(pol[0].env_id, 3);
        assert_eq!(pol[0].episode, 5);
        let io: Vec<_> = spans.iter().filter(|s| s.phase == Phase::Io as u8).collect();
        assert_eq!(io[0].env_id, 9, "for_env overrides the thread env");
        assert!(!spans.iter().any(|s| s.phase == Phase::Cfd as u8));
    }

    #[test]
    fn phase_round_trips_and_is_dense() {
        for (i, p) in Phase::ALL.into_iter().enumerate() {
            assert_eq!(p as u8, i as u8);
            assert_eq!(Phase::from_u8(p as u8), Some(p));
            assert!(!p.name().is_empty());
        }
        assert_eq!(Phase::from_u8(200), None);
    }

    #[test]
    fn clock_offset_midpoint_math() {
        // probe at 100, echo back at 300 -> rtt 200, midpoint 200.
        // peer stamped 1200 at the midpoint -> peer is 1000 ahead.
        let (rtt, off) = clock_offset(100, 300, 1200);
        assert_eq!(rtt, 200);
        assert_eq!(off, -1000);
        // symmetric case: peer behind by 50
        let (_, off) = clock_offset(1000, 1100, 1000);
        assert_eq!(off, 50);
    }

    #[test]
    fn ingest_applies_min_rtt_offset() {
        enable();
        // high-rtt sample first, better sample second: the second wins
        record_probe_echo(7, 0, 0, 5000, 1000); // rtt 1000, off -4500
        record_probe_echo(7, 0, 100, 5150, 200); // rtt 100, off -5000
        ingest_remote(
            7,
            0,
            vec![SpanRec {
                phase: Phase::Cfd as u8,
                start_us: 6000,
                dur_us: 10,
                env_id: 7,
                episode: 1,
            }],
        );
        let spans = take_all_spans();
        disable();
        let s = spans.iter().find(|s| s.env_id == 7).unwrap();
        assert_eq!(s.start_us, 1000, "6000 shifted by the -5000 min-rtt offset");
    }

    #[test]
    fn record_measured_uses_caller_measurement() {
        enable();
        // reuse the module's pinned epoch as the caller's Instant — this
        // file is audited to exactly two wall-clock reads, and a test
        // fixture must not be a third
        let t0 = epoch();
        record_measured(Phase::Update, t0, 0.25, NO_ENV, 2);
        let spans = take_all_spans();
        disable();
        let s = spans
            .iter()
            .find(|s| s.phase == Phase::Update as u8 && s.episode == 2)
            .unwrap();
        assert_eq!(s.dur_us, 250_000);
        assert_eq!(s.env_id, NO_ENV);
    }
}
