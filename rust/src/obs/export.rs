//! Trace exporters: Perfetto JSON, per-phase percentile summary, and the
//! plan-vs-actual drift report (ARCHITECTURE.md §12).
//!
//! All three run once, at the end of a traced training run, from the
//! coordinator thread — they drain the global span sink, so the tracing
//! plane is reset for the next run in this process.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::cluster::{simulate_training, Calibration, SimConfig};
use crate::metrics::tables::{parse_csv, render_table, write_csv};
use crate::util::json::{self, Json};
use crate::util::stats;

use super::{drain_all, Phase, SpanRec, NO_ENV};

/// Measured/predicted ratio beyond which (in either direction) a
/// component is flagged as calibration drift.
pub const DRIFT_WARN_RATIO: f64 = 3.0;

/// What the drift report compares against: the DES prediction for the
/// layout that actually trained, plus the live run's episode/round
/// counts used to normalise the measured totals into the DES units.
pub struct DriftSpec {
    pub calib: Calibration,
    pub sim: SimConfig,
    /// episodes the live run completed
    pub episodes: usize,
    /// PPO update rounds the live run performed
    pub rounds: usize,
}

/// Paths written + any drift warnings (the caller prints them).
pub struct TraceReport {
    pub trace_path: PathBuf,
    pub summary_path: PathBuf,
    pub drift_path: Option<PathBuf>,
    pub spans: usize,
    pub drift_warnings: Vec<String>,
}

/// Drain the tracing plane and write every exporter's output. `trace_path`
/// is the Chrome-trace-event JSON (`--trace <path>`); the summary and
/// drift CSVs land in `out_dir`.
pub fn export(trace_path: &Path, out_dir: &Path, drift: Option<&DriftSpec>) -> Result<TraceReport> {
    let d = drain_all();
    super::disable();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    if let Some(parent) = trace_path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }

    write_chrome_trace(trace_path, &d.spans, &d.hosts)?;
    let summary_path = out_dir.join("obs_summary.csv");
    write_summary(&summary_path, &d.spans, &d.counters)?;

    let mut drift_path = None;
    let mut drift_warnings = Vec::new();
    if let Some(spec) = drift {
        let p = out_dir.join("drift.csv");
        drift_warnings = write_drift(&p, &d.spans, spec)?;
        drift_path = Some(p);
    }

    Ok(TraceReport {
        trace_path: trace_path.to_path_buf(),
        summary_path,
        drift_path,
        spans: d.spans.len(),
        drift_warnings,
    })
}

fn phase_label(raw: u8) -> String {
    match Phase::from_u8(raw) {
        Some(p) => p.name().to_string(),
        None => format!("phase_{raw}"),
    }
}

/// Chrome trace events (Perfetto-loadable): one process lane per host,
/// one thread lane per environment, plus a coordinator lane on host 0.
fn write_chrome_trace(
    path: &Path,
    spans: &[SpanRec],
    hosts: &BTreeMap<u32, (u32, String)>,
) -> Result<()> {
    let lane = |env: u32| -> (u32, u64) {
        if env == NO_ENV {
            (0, 0) // coordinator lane
        } else {
            let pid = hosts.get(&env).map(|(h, _)| *h).unwrap_or(0);
            (pid, u64::from(env) + 1)
        }
    };
    let mut events = Vec::new();
    // metadata: process (host) and thread (env) lane names
    let mut host_names: BTreeMap<u32, String> = BTreeMap::new();
    host_names.insert(0, "host0".to_string());
    for (h, label) in hosts.values() {
        host_names.insert(*h, format!("host{h} {label}"));
    }
    for (pid, name) in &host_names {
        events.push(json::obj(vec![
            ("ph", json::s("M")),
            ("name", json::s("process_name")),
            ("pid", json::num(f64::from(*pid))),
            ("args", json::obj(vec![("name", json::s(name))])),
        ]));
    }
    let mut lanes_seen: BTreeMap<(u32, u64), String> = BTreeMap::new();
    lanes_seen.insert((0, 0), "coordinator".to_string());
    for s in spans {
        if s.env_id != NO_ENV {
            let (pid, tid) = lane(s.env_id);
            lanes_seen
                .entry((pid, tid))
                .or_insert_with(|| format!("env {}", s.env_id));
        }
    }
    for ((pid, tid), name) in &lanes_seen {
        events.push(json::obj(vec![
            ("ph", json::s("M")),
            ("name", json::s("thread_name")),
            ("pid", json::num(f64::from(*pid))),
            ("tid", json::num(*tid as f64)),
            ("args", json::obj(vec![("name", json::s(name))])),
        ]));
    }
    for s in spans {
        let (pid, tid) = lane(s.env_id);
        events.push(json::obj(vec![
            ("name", json::s(&phase_label(s.phase))),
            ("cat", json::s("obs")),
            ("ph", json::s("X")),
            ("ts", json::num(s.start_us as f64)),
            ("dur", json::num(s.dur_us as f64)),
            ("pid", json::num(f64::from(pid))),
            ("tid", json::num(tid as f64)),
            ("args", json::obj(vec![("episode", json::num(s.episode as f64))])),
        ]));
    }
    let root = json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", json::s("ms")),
    ]);
    std::fs::write(path, root.to_string())
        .with_context(|| format!("writing trace {}", path.display()))?;
    Ok(())
}

/// `obs_summary.csv`: per-phase count/total/percentiles (seconds), plus
/// one row per named counter (count column only).
fn write_summary(
    path: &Path,
    spans: &[SpanRec],
    counters: &BTreeMap<String, u64>,
) -> Result<()> {
    let mut by_phase: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for s in spans {
        by_phase
            .entry(phase_label(s.phase))
            .or_default()
            .push(s.dur_us as f64 / 1e6);
    }
    let mut rows = Vec::new();
    for (name, durs) in &by_phase {
        let total = durs.iter().sum::<f64>();
        rows.push(format!(
            "{name},{},{:.6},{:.6},{:.6},{:.6},{:.6}",
            durs.len(),
            total,
            total / durs.len() as f64,
            stats::percentile(durs, 50.0),
            stats::percentile(durs, 95.0),
            stats::percentile(durs, 99.0),
        ));
    }
    for (name, n) in counters {
        rows.push(format!("{name},{n},0.000000,0.000000,0.000000,0.000000,0.000000"));
    }
    write_csv(path, "phase,count,total_s,mean_s,p50_s,p95_s,p99_s", &rows)?;
    Ok(())
}

/// `drift.csv`: measured per-phase seconds vs the DES prediction for the
/// trained layout, in the DES's own units (cfd/io/policy/barrier_idle
/// per episode; update_barrier per update round). Returns warning lines
/// for components drifting beyond [`DRIFT_WARN_RATIO`].
fn write_drift(path: &Path, spans: &[SpanRec], spec: &DriftSpec) -> Result<Vec<String>> {
    let predicted = simulate_training(&spec.calib, &spec.sim).breakdown;
    let episodes = spec.episodes.max(1) as f64;
    let rounds = spec.rounds.max(1) as f64;
    let total = |p: Phase| -> f64 {
        spans
            .iter()
            .filter(|s| s.phase == p as u8)
            .map(|s| s.dur_us as f64 / 1e6)
            .sum::<f64>()
    };
    let idle_per_episode = total(Phase::BarrierIdle) / episodes;
    let components: [(&str, f64, f64); 5] = [
        ("cfd", predicted.cfd_s, total(Phase::Cfd) / episodes),
        ("io", predicted.io_s, total(Phase::Io) / episodes),
        (
            "policy",
            predicted.policy_s,
            (total(Phase::Policy) + total(Phase::PolicyBatch)) / episodes,
        ),
        (
            "update_barrier",
            predicted.update_barrier_s,
            total(Phase::Update) / rounds + idle_per_episode,
        ),
        ("barrier_idle", predicted.barrier_idle_s, idle_per_episode),
    ];
    let mut rows = Vec::new();
    let mut warnings = Vec::new();
    for (name, pred, meas) in components {
        let ratio = if pred > 1e-12 { meas / pred } else { 0.0 };
        rows.push(format!("{name},{pred:.6},{meas:.6},{ratio:.4}"));
        if pred > 1e-6 && meas > 1e-6 && (ratio > DRIFT_WARN_RATIO || ratio < 1.0 / DRIFT_WARN_RATIO)
        {
            warnings.push(format!(
                "calibration drift: {name} measured {meas:.4}s vs predicted {pred:.4}s \
                 (x{ratio:.2}, threshold x{DRIFT_WARN_RATIO:.1}) — re-run `drlfoam calibrate` \
                 or pass --calib for this machine"
            ));
        }
    }
    write_csv(path, "component,predicted_s,measured_s,ratio", &rows)?;
    Ok(warnings)
}

/// `drlfoam trace <file>`: summarise a Chrome-trace JSON into the
/// paper-style component-breakdown table; sibling `obs_summary.csv` /
/// `drift.csv` files (same directory) are validated and rendered too.
pub fn summarize_trace(path: &Path) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing trace {}", path.display()))?;
    let events = j.get("traceEvents")?.as_arr()?;
    let mut agg: BTreeMap<String, (usize, f64)> = BTreeMap::new();
    let mut lanes: std::collections::BTreeSet<(u64, u64)> = Default::default();
    for ev in events {
        if ev.get("ph")?.as_str()? != "X" {
            continue;
        }
        let name = ev.get("name")?.as_str()?.to_string();
        let dur_s = ev.get("dur")?.as_f64()? / 1e6;
        let e = agg.entry(name).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dur_s;
        lanes.insert((
            ev.get("pid")?.as_f64()? as u64,
            ev.get("tid")?.as_f64()? as u64,
        ));
    }
    let grand = agg.values().map(|(_, t)| t).sum::<f64>();
    let rows: Vec<Vec<String>> = agg
        .iter()
        .map(|(name, (n, t))| {
            vec![
                name.clone(),
                n.to_string(),
                format!("{t:.4}"),
                format!("{:.1}", 100.0 * t / grand.max(1e-12)),
            ]
        })
        .collect();
    let mut out = render_table(
        &format!(
            "trace {} — {} span(s), {} lane(s)",
            path.display(),
            agg.values().map(|(n, _)| n).sum::<usize>(),
            lanes.len()
        ),
        &["component", "count", "total_s", "share_%"],
        &rows,
    );
    let dir = path.parent().unwrap_or(Path::new("."));
    for (file, title) in [
        ("obs_summary.csv", "per-phase percentiles"),
        ("drift.csv", "plan-vs-actual drift"),
    ] {
        let p = dir.join(file);
        if !p.exists() {
            continue;
        }
        let (header, rows) = parse_csv(&std::fs::read_to_string(&p)?)
            .with_context(|| format!("parsing {}", p.display()))?;
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        out.push('\n');
        out.push_str(&render_table(&format!("{title} ({})", p.display()), &hdr, &rows));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SyncPolicy;
    use crate::io_interface::IoMode;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("drlfoam-obs-{}-{name}", std::process::id()))
    }

    #[test]
    fn export_writes_all_three_and_trace_summarises() {
        super::super::enable();
        super::super::set_thread_env(0);
        super::super::set_thread_episode(1);
        super::super::record(Phase::Cfd, 0, 2_000_000, 0, 1);
        super::super::record(Phase::Io, 2_000_000, 500_000, 0, 1);
        super::super::record(Phase::Update, 3_000_000, 100_000, NO_ENV, 1);
        super::super::record(Phase::BarrierIdle, 2_500_000, 400_000, 0, 1);
        super::super::bump("cfd.native_periods", 7);
        super::super::set_env_host(0, 1, "nodeB:7700");

        let dir = tmp("exp");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("trace.json");
        let spec = DriftSpec {
            calib: Calibration::paper_scale(),
            sim: SimConfig {
                n_envs: 1,
                n_ranks: 1,
                episodes_total: 1,
                io_mode: IoMode::InMemory,
                sync: SyncPolicy::Full,
                remote_envs: 0,
                seed: 1,
            },
            episodes: 1,
            rounds: 1,
        };
        let rep = export(&trace, &dir, Some(&spec)).unwrap();
        assert!(rep.spans >= 4);
        assert!(!super::super::enabled(), "export disables the plane");

        // Perfetto JSON parses and carries the host lane
        let j = Json::parse(&std::fs::read_to_string(&trace).unwrap()).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events.iter().any(|e| {
            e.get("ph").map(|p| p == &Json::Str("M".into())).unwrap_or(false)
                && e.get("args")
                    .and_then(|a| a.get("name"))
                    .map(|n| n.as_str().unwrap_or("").contains("nodeB"))
                    .unwrap_or(false)
        }));

        // summary + drift parse with the strict CSV reader
        let (h, rows) = parse_csv(&std::fs::read_to_string(&rep.summary_path).unwrap()).unwrap();
        assert_eq!(h[0], "phase");
        assert!(rows.iter().any(|r| r[0] == "cfd"));
        assert!(rows.iter().any(|r| r[0] == "cfd.native_periods" && r[1] == "7"));
        let (h, rows) =
            parse_csv(&std::fs::read_to_string(rep.drift_path.as_ref().unwrap()).unwrap()).unwrap();
        assert_eq!(h, vec!["component", "predicted_s", "measured_s", "ratio"]);
        assert_eq!(rows.len(), 5);
        // surrogate-speed spans vs paper-scale prediction must drift
        assert!(!rep.drift_warnings.is_empty());

        // the trace subcommand summarises file + sibling CSVs
        let summary = summarize_trace(&trace).unwrap();
        assert!(summary.contains("cfd"));
        assert!(summary.contains("plan-vs-actual drift"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summarize_rejects_non_trace_json() {
        let dir = tmp("bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("not-a-trace.json");
        std::fs::write(&p, "{\"x\": 1}").unwrap();
        assert!(summarize_trace(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
