//! *Optimized* exchange: one binary file per period (the paper's fix).
//!
//! Implements the two optimizations of section III D: (1) drop the
//! "unnecessary I/O of flow field data" — only the restart-essential
//! fields travel, raw f32 instead of ASCII; (2) collapse the four files
//! into one, probes + force histories + action in a single packed record.
//! The paper measured 5.0 MB -> 1.2 MB (76% less data) per exchange; our
//! ratio is recorded by rust/tests/io_roundtrip.rs.

use std::fs;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::{CfdOutput, ExchangeInterface, FlowSnapshot, IoMode, IoStats};

const MAGIC: u32 = 0x44524C46; // "DRLF"

/// Write the flow restart only every K periods: the paper's first
/// optimization is the *removal of unnecessary flow-field I/O* — the
/// agent only ever needs probes + force histories, and a restart
/// checkpoint every K periods bounds replay cost after a crash.
const FLOW_SNAPSHOT_EVERY: usize = 10;

/// The *Optimized* exchange strategy: one packed binary record per period
/// with periodic flow-restart snapshots (see module docs).
pub struct BinaryExchange {
    dir: PathBuf,
}

impl BinaryExchange {
    /// Exchange files live in `work_dir/env<NNN>/`, one dir per env.
    pub fn new(work_dir: &std::path::Path, env_id: usize) -> Result<Self> {
        let dir = work_dir.join(format!("env{env_id:03}"));
        fs::create_dir_all(&dir)?;
        Ok(BinaryExchange { dir })
    }
}

/// Pack f32s little-endian, bit-exact (shared with the exec wire
/// protocol, `crate::exec::wire`, which reuses this encoding).
pub(crate) fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Inverse of [`put_f32s`]: read `n` f32s at `*off`, advancing it.
pub(crate) fn get_f32s(bytes: &[u8], n: usize, off: &mut usize) -> Result<Vec<f32>> {
    ensure!(bytes.len() >= *off + 4 * n, "binary record truncated");
    let out = bytes[*off..*off + 4 * n]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    *off += 4 * n;
    Ok(out)
}

impl ExchangeInterface for BinaryExchange {
    fn mode(&self) -> IoMode {
        IoMode::Optimized
    }

    fn exchange(
        &mut self,
        step: usize,
        out: &CfdOutput,
        flow: &FlowSnapshot,
    ) -> Result<(CfdOutput, IoStats)> {
        let mut st = IoStats::default();
        let with_flow = step % FLOW_SNAPSHOT_EVERY == 0;
        let n_cells = if with_flow { flow.ny * flow.nx } else { 0 };

        let t0 = Instant::now();
        let mut buf =
            Vec::with_capacity(32 + 4 * (out.probes.len() + 2 * out.cd_hist.len() + 3 * n_cells));
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&(out.probes.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(out.cd_hist.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(n_cells as u32).to_le_bytes());
        put_f32s(&mut buf, &out.probes);
        put_f32s(&mut buf, &out.cd_hist);
        put_f32s(&mut buf, &out.cl_hist);
        if with_flow {
            // restart checkpoint (raw f32; the solver's restart file)
            put_f32s(&mut buf, flow.u);
            put_f32s(&mut buf, flow.v);
            put_f32s(&mut buf, flow.p);
        }
        let path = self.dir.join(format!("{step}.exchange.bin"));
        let mut f = fs::File::create(&path)?;
        f.write_all(&buf)?;
        drop(f);
        st.bytes_written += buf.len() as u64;
        st.files += 1;
        st.write_s = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let mut bytes = Vec::new();
        fs::File::open(&path)?.read_to_end(&mut bytes)?;
        st.bytes_read += bytes.len() as u64;
        ensure!(bytes.len() >= 16, "record too short");
        let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        ensure!(magic == MAGIC, "bad magic {magic:#x}");
        let n_probes = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let n_hist = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let mut off = 16;
        let probes = get_f32s(&bytes, n_probes, &mut off)?;
        let cd = get_f32s(&bytes, n_hist, &mut off)?;
        let cl = get_f32s(&bytes, n_hist, &mut off)?;
        st.read_s = t1.elapsed().as_secs_f64();

        if step > 0 {
            let _ = fs::remove_file(self.dir.join(format!("{}.exchange.bin", step - 1)));
        }

        Ok((
            CfdOutput {
                probes,
                cd_hist: cd,
                cl_hist: cl,
            },
            st,
        ))
    }

    fn inject_action(&mut self, step: usize, action: f64) -> Result<(f64, IoStats)> {
        let mut st = IoStats::default();
        let t0 = Instant::now();
        let path = self.dir.join("action.bin");
        fs::write(&path, action.to_le_bytes())?;
        st.bytes_written += 8;
        st.files += 1;
        st.write_s = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let bytes = fs::read(&path).context("reading action.bin")?;
        ensure!(bytes.len() == 8, "bad action record");
        let parsed = f64::from_le_bytes(bytes.try_into().unwrap());
        st.bytes_read += 8;
        st.read_s = t1.elapsed().as_secs_f64();
        let _ = step;
        Ok((parsed, st))
    }
}
