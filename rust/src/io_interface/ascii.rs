//! *Baseline* exchange: OpenFOAM-style multi-file ASCII + regex parsing.
//!
//! Faithful to DRLinFluids' data path: per actuation period the solver
//! writes a time directory with `U` and `p` field files (full flow field,
//! FoamFile headers, one value per line), a `probes.dat` postProcessing
//! file and a `forces.dat` history; the DRL side then *regex-parses* the
//! probe/force files, and actions travel back through a regex substitution
//! into a `jetVelocity` boundary-condition dict. This is where the paper's
//! 5.0 MB-per-exchange baseline cost comes from.

use std::fmt::Write as _;
use std::fs;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};
use once_cell::sync::Lazy;
use regex::Regex;

use super::{CfdOutput, ExchangeInterface, FlowSnapshot, IoMode, IoStats};

static PROBE_RE: Lazy<Regex> =
    Lazy::new(|| Regex::new(r"(?m)^\s*(\d+)\s+(-?[0-9.eE+-]+)\s*$").unwrap());
static FORCES_RE: Lazy<Regex> = Lazy::new(|| {
    Regex::new(r"(?m)^\s*[0-9.eE+-]+\s+\(?(-?[0-9.eE+-]+)\s+(-?[0-9.eE+-]+)\)?\s*$").unwrap()
});
static JET_RE: Lazy<Regex> =
    Lazy::new(|| Regex::new(r"jetValue\s+uniform\s+(-?[0-9.eE+-]+);").unwrap());

const JET_DICT_TEMPLATE: &str = r#"/*--------------------------------*- C++ -*----------------------------------*\
| =========                 |                                                 |
| \\      /  F ield         | drlfoam-rs synthetic-jet boundary dict          |
\*---------------------------------------------------------------------------*/
boundaryField
{
    jet1
    {
        type            jetParabolicVelocity;
        jetValue        uniform 0.0;
    }
    jet2
    {
        type            jetParabolicVelocity;
        jetValue        uniform 0.0;
    }
}
"#;

/// The *Baseline* exchange strategy: OpenFOAM-style ASCII field/probe/
/// force files plus regex parsing (see module docs).
pub struct AsciiFoam {
    dir: PathBuf,
}

impl AsciiFoam {
    /// Exchange files live in `work_dir/env<NNN>/`, one dir per env.
    pub fn new(work_dir: &std::path::Path, env_id: usize) -> Result<Self> {
        let dir = work_dir.join(format!("env{env_id:03}"));
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(AsciiFoam { dir })
    }

    fn write_field(&self, step: usize, name: &str, class: &str, data: &[&[f32]]) -> Result<u64> {
        // OpenFOAM-flavoured field file: FoamFile header + internalField list
        let n = data[0].len();
        let mut s = String::with_capacity(n * 14 * data.len() + 256);
        let _ = write!(
            s,
            "FoamFile\n{{\n    version 2.0;\n    format ascii;\n    class {class};\n    object {name};\n}}\n\ndimensions [0 1 -1 0 0 0 0];\n\ninternalField   nonuniform List<{}>\n{n}\n(\n",
            if data.len() > 1 { "vector" } else { "scalar" }
        );
        for i in 0..n {
            if data.len() > 1 {
                let _ = writeln!(s, "({} {} 0)", data[0][i], data[1][i]);
            } else {
                let _ = writeln!(s, "{}", data[0][i]);
            }
        }
        s.push_str(")\n;\n");
        let path = self.dir.join(format!("{step}.{name}"));
        fs::write(&path, &s)?;
        Ok(s.len() as u64)
    }
}

impl ExchangeInterface for AsciiFoam {
    fn mode(&self) -> IoMode {
        IoMode::Baseline
    }

    fn exchange(
        &mut self,
        step: usize,
        out: &CfdOutput,
        flow: &FlowSnapshot,
    ) -> Result<(CfdOutput, IoStats)> {
        let mut st = IoStats::default();

        // ---- write path (what OpenFOAM's write() + functionObjects do)
        let t0 = Instant::now();
        st.bytes_written += self.write_field(step, "U", "volVectorField", &[flow.u, flow.v])?;
        st.bytes_written += self.write_field(step, "p", "volScalarField", &[flow.p])?;

        let mut probes = String::with_capacity(out.probes.len() * 16 + 64);
        probes.push_str("# Probe pressure samples\n# id   p\n");
        for (i, p) in out.probes.iter().enumerate() {
            let _ = writeln!(probes, "{i}  {p}");
        }
        let probes_path = self.dir.join(format!("{step}.probes.dat"));
        fs::write(&probes_path, &probes)?;
        st.bytes_written += probes.len() as u64;

        let mut forces = String::with_capacity(out.cd_hist.len() * 32 + 64);
        forces.push_str("# time  (Cd Cl)\n");
        for (k, (cd, cl)) in out.cd_hist.iter().zip(&out.cl_hist).enumerate() {
            let _ = writeln!(forces, "{k} ({cd} {cl})");
        }
        let forces_path = self.dir.join(format!("{step}.forces.dat"));
        fs::write(&forces_path, &forces)?;
        st.bytes_written += forces.len() as u64;
        st.files += 4;
        st.write_s = t0.elapsed().as_secs_f64();

        // ---- read path (what DRLinFluids' regex parsers do)
        let t1 = Instant::now();
        let ptext = fs::read_to_string(&probes_path)?;
        st.bytes_read += ptext.len() as u64;
        let mut parsed_probes = vec![0f32; out.probes.len()];
        for cap in PROBE_RE.captures_iter(&ptext) {
            let idx: usize = cap[1].parse()?;
            parsed_probes[idx] = cap[2].parse()?;
        }
        let ftext = fs::read_to_string(&forces_path)?;
        st.bytes_read += ftext.len() as u64;
        let mut cd = Vec::with_capacity(out.cd_hist.len());
        let mut cl = Vec::with_capacity(out.cl_hist.len());
        for cap in FORCES_RE.captures_iter(&ftext) {
            cd.push(cap[1].parse()?);
            cl.push(cap[2].parse()?);
        }
        st.read_s = t1.elapsed().as_secs_f64();

        // previous period's files are no longer needed (OpenFOAM's
        // purgeWrite); keep the directory from growing unboundedly.
        if step > 0 {
            for name in ["U", "p", "probes.dat", "forces.dat"] {
                let _ = fs::remove_file(self.dir.join(format!("{}.{name}", step - 1)));
            }
        }

        Ok((
            CfdOutput {
                probes: parsed_probes,
                cd_hist: cd,
                cl_hist: cl,
            },
            st,
        ))
    }

    fn inject_action(&mut self, step: usize, action: f64) -> Result<(f64, IoStats)> {
        let mut st = IoStats::default();
        let t0 = Instant::now();
        // regex substitution into the jet BC dict (both jets; V_G2 = -V_G1)
        let mut first = true;
        let dict = JET_RE.replace_all(JET_DICT_TEMPLATE, |_: &regex::Captures| {
            let v = if first { action } else { -action };
            first = false;
            format!("jetValue        uniform {v:.9e};")
        });
        let path = self.dir.join(format!("{step}.jetDict"));
        fs::write(&path, dict.as_bytes())?;
        st.bytes_written += dict.len() as u64;
        st.files += 1;
        st.write_s = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let text = fs::read_to_string(&path)?;
        st.bytes_read += text.len() as u64;
        let caps = JET_RE
            .captures(&text)
            .context("jetValue not found in dict")?;
        let parsed: f64 = caps[1].parse()?;
        st.read_s = t1.elapsed().as_secs_f64();
        if step > 0 {
            let _ = fs::remove_file(self.dir.join(format!("{}.jetDict", step - 1)));
        }
        Ok((parsed, st))
    }
}
