//! CFD <-> DRL exchange interfaces (the paper's section III D subject).
//!
//! DRLinFluids couples OpenFOAM and TensorForce through the filesystem: at
//! the end of each actuation period the solver writes probe/force/flow
//! files, Python regex-parses them, and the next action is injected back
//! into OpenFOAM config files with regex substitution. The paper shows
//! this I/O becomes the scaling bottleneck past ~30 environments and
//! evaluates three strategies (Table II):
//!
//! * `Baseline`   — multi-file ASCII + regex parsing, full flow field
//!                  written every period ([`ascii::AsciiFoam`]).
//! * `Optimized`  — single binary file, flow field reduced to the restart
//!                  essentials ([`binary::BinaryExchange`]).
//! * `InMemory`   — no I/O at all; the paper's *I/O-Disabled* upper bound
//!                  ([`memory::InMemory`]).
//!
//! The interfaces are *load-bearing*: the environment consumes the values
//! that travelled through the interface (not the originals), so the
//! round-trip tests in rust/tests/io_roundtrip.rs guarantee the benchmark
//! is measuring a working data path.

pub mod ascii;
pub mod binary;
pub mod memory;

use anyhow::Result;

/// Which exchange strategy an environment uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// Multi-file ASCII + regex parsing (Table II "Baseline").
    Baseline,
    /// Single packed binary record per period (Table II "Optimized").
    Optimized,
    /// No files at all; the I/O-Disabled upper bound.
    InMemory,
}

impl IoMode {
    /// Parse a CLI/config string. Accepts the canonical names and their
    /// aliases, trimmed and case-insensitively; the error lists every
    /// accepted spelling.
    pub fn parse(s: &str) -> Result<IoMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "baseline" | "ascii" => Ok(IoMode::Baseline),
            "optimized" | "binary" => Ok(IoMode::Optimized),
            "memory" | "disabled" | "in-memory" => Ok(IoMode::InMemory),
            _ => anyhow::bail!(
                "unknown io mode {s:?} (accepted: baseline|ascii, \
                 optimized|binary, memory|in-memory|disabled)"
            ),
        }
    }

    /// Display name used in logs and result tables.
    pub fn name(&self) -> &'static str {
        match self {
            IoMode::Baseline => "baseline",
            IoMode::Optimized => "optimized",
            IoMode::InMemory => "in-memory",
        }
    }
}

/// What the CFD side produces at the end of an actuation period.
#[derive(Clone, Debug, PartialEq)]
pub struct CfdOutput {
    /// Pressure probe samples (one per probe, unnormalised).
    pub probes: Vec<f32>,
    /// Per-substep drag-coefficient history for the period.
    pub cd_hist: Vec<f32>,
    /// Per-substep lift-coefficient history for the period.
    pub cl_hist: Vec<f32>,
}

/// Borrowed view of the flow state for snapshot writing.
pub struct FlowSnapshot<'a> {
    pub u: &'a [f32],
    pub v: &'a [f32],
    pub p: &'a [f32],
    pub ny: usize,
    pub nx: usize,
}

/// Cost accounting for one exchange (consumed by metrics + DES calibration).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct IoStats {
    pub bytes_written: u64,
    pub bytes_read: u64,
    /// Files touched (created or rewritten) during the exchange.
    pub files: u32,
    pub write_s: f64,
    pub read_s: f64,
}

impl IoStats {
    /// Total CPU time spent in the exchange (write + read paths).
    pub fn total_s(&self) -> f64 {
        self.write_s + self.read_s
    }

    /// Element-wise accumulation (episode and iteration roll-ups).
    pub fn accumulate(&mut self, other: &IoStats) {
        self.bytes_written += other.bytes_written;
        self.bytes_read += other.bytes_read;
        self.files += other.files;
        self.write_s += other.write_s;
        self.read_s += other.read_s;
    }
}

/// The CFD<->DRL data path for one environment.
pub trait ExchangeInterface: Send {
    fn mode(&self) -> IoMode;

    /// CFD -> DRL: persist the period outputs the way the coupled
    /// framework would, read them back, and return the parsed copy.
    fn exchange(
        &mut self,
        step: usize,
        out: &CfdOutput,
        flow: &FlowSnapshot,
    ) -> Result<(CfdOutput, IoStats)>;

    /// DRL -> CFD: inject the next jet amplitude into the solver's
    /// configuration; returns the value as the solver would read it.
    fn inject_action(&mut self, step: usize, action: f64) -> Result<(f64, IoStats)>;
}

/// Construct the exchange implementation for `mode`; file-based modes get
/// a private `env<NNN>` directory under `work_dir`.
pub fn make_interface(
    mode: IoMode,
    work_dir: &std::path::Path,
    env_id: usize,
) -> Result<Box<dyn ExchangeInterface>> {
    Ok(match mode {
        IoMode::Baseline => Box::new(ascii::AsciiFoam::new(work_dir, env_id)?),
        IoMode::Optimized => Box::new(binary::BinaryExchange::new(work_dir, env_id)?),
        IoMode::InMemory => Box::new(memory::InMemory::new()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_aliases() {
        for (s, want) in [
            ("baseline", IoMode::Baseline),
            ("ascii", IoMode::Baseline),
            ("optimized", IoMode::Optimized),
            ("binary", IoMode::Optimized),
            ("memory", IoMode::InMemory),
            ("in-memory", IoMode::InMemory),
            ("disabled", IoMode::InMemory),
        ] {
            assert_eq!(IoMode::parse(s).unwrap(), want, "{s}");
        }
    }

    #[test]
    fn parse_roundtrips_canonical_names() {
        for m in [IoMode::Baseline, IoMode::Optimized, IoMode::InMemory] {
            assert_eq!(IoMode::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn parse_trims_and_ignores_case() {
        // sloppy-but-unambiguous CLI spellings must not hard-fail
        for (s, want) in [
            ("Baseline", IoMode::Baseline),
            ("memory ", IoMode::InMemory),
            ("  IN-MEMORY", IoMode::InMemory),
            ("OPTIMIZED", IoMode::Optimized),
            ("\tAscii\n", IoMode::Baseline),
        ] {
            assert_eq!(IoMode::parse(s).unwrap(), want, "{s:?}");
        }
    }

    #[test]
    fn parse_rejects_unknown_and_lists_accepted() {
        for bad in ["", "ramdisk", "base line", "mem"] {
            let err = IoMode::parse(bad).unwrap_err().to_string();
            // the message must teach the accepted spellings
            for accepted in [
                "baseline", "ascii", "optimized", "binary", "memory", "in-memory", "disabled",
            ] {
                assert!(err.contains(accepted), "{bad:?} -> {err}");
            }
        }
    }

    #[test]
    fn iostats_accumulate_sums_fields() {
        let mut a = IoStats {
            bytes_written: 10,
            bytes_read: 20,
            files: 1,
            write_s: 0.5,
            read_s: 0.25,
        };
        let b = a;
        a.accumulate(&b);
        assert_eq!(a.bytes_written, 20);
        assert_eq!(a.bytes_read, 40);
        assert_eq!(a.files, 2);
        assert!((a.total_s() - 1.5).abs() < 1e-12);
    }
}
