//! *I/O-Disabled* exchange: pure in-memory pass-through.
//!
//! The paper's theoretical-upper-bound configuration: all file I/O is
//! suspended and data moves by reference. Unlike the paper's variant
//! (which broke the data path and produced unusable control results, as
//! they note), ours is a real zero-copy interface, so training through it
//! is *both* the upper bound and correct — this is the mode the quickstart
//! and training examples default to.

use anyhow::Result;

use super::{CfdOutput, ExchangeInterface, FlowSnapshot, IoMode, IoStats};

/// The *I/O-Disabled* exchange strategy: zero-copy pass-through with zero
/// recorded cost (see module docs).
pub struct InMemory;

impl InMemory {
    pub fn new() -> Self {
        InMemory
    }
}

impl Default for InMemory {
    fn default() -> Self {
        Self::new()
    }
}

impl ExchangeInterface for InMemory {
    fn mode(&self) -> IoMode {
        IoMode::InMemory
    }

    fn exchange(
        &mut self,
        _step: usize,
        out: &CfdOutput,
        _flow: &FlowSnapshot,
    ) -> Result<(CfdOutput, IoStats)> {
        Ok((out.clone(), IoStats::default()))
    }

    fn inject_action(&mut self, _step: usize, action: f64) -> Result<(f64, IoStats)> {
        Ok((action, IoStats::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n_probes: usize, substeps: usize) -> CfdOutput {
        CfdOutput {
            probes: (0..n_probes).map(|i| 0.25 * i as f32 - 1.0).collect(),
            cd_hist: (0..substeps).map(|i| 3.0 + 0.01 * i as f32).collect(),
            cl_hist: (0..substeps).map(|i| -0.1 * i as f32).collect(),
        }
    }

    fn flow<'a>(u: &'a [f32], v: &'a [f32], p: &'a [f32]) -> FlowSnapshot<'a> {
        FlowSnapshot {
            u,
            v,
            p,
            ny: 2,
            nx: 3,
        }
    }

    #[test]
    fn exchange_round_trips_exactly_at_zero_cost() {
        let mut m = InMemory::new();
        assert_eq!(m.mode(), IoMode::InMemory);
        assert_eq!(m.mode().name(), "in-memory");
        let out = payload(16, 5);
        let cells = vec![0.5f32; 6];
        let (parsed, st) = m.exchange(0, &out, &flow(&cells, &cells, &cells)).unwrap();
        // the I/O-Disabled bound must be a *working* data path (unlike
        // the paper's variant, which broke it): the parsed copy equals
        // the original exactly...
        assert_eq!(parsed, out);
        // ...and costs nothing, on every IoStats axis
        assert_eq!(st, IoStats::default());
        assert_eq!(st.total_s(), 0.0);
    }

    #[test]
    fn action_passthrough_is_bit_exact_for_special_values() {
        let mut m = InMemory::new();
        for a in [0.0, -0.0, 1.5e-308, f64::MAX, f64::INFINITY, f64::NEG_INFINITY] {
            let (got, st) = m.inject_action(0, a).unwrap();
            assert_eq!(got.to_bits(), a.to_bits(), "{a}");
            assert_eq!(st, IoStats::default());
        }
        let (nan, _) = m.inject_action(1, f64::NAN).unwrap();
        assert!(nan.is_nan());
    }

    #[test]
    fn degenerate_payloads_never_error() {
        // the I/O-disabled contract is "cannot fail": empty histories,
        // empty flow snapshots and arbitrary (even repeated or
        // non-monotonic) step indices all pass through — the error paths
        // of the file-based modes (truncated records, bad magic) have no
        // analogue here, and that asymmetry is the point of the mode
        let mut m = InMemory::default();
        let empty = CfdOutput {
            probes: vec![],
            cd_hist: vec![],
            cl_hist: vec![],
        };
        for step in [0usize, 7, 7, 3] {
            let (parsed, st) = m.exchange(step, &empty, &flow(&[], &[], &[])).unwrap();
            assert_eq!(parsed, empty);
            assert_eq!(st, IoStats::default());
            assert!(m.inject_action(step, 0.9).is_ok());
        }
    }

    #[test]
    fn large_payload_round_trips_unchanged() {
        let mut m = InMemory::new();
        let out = payload(149, 10);
        let cells: Vec<f32> = (0..48 * 258).map(|i| (i % 97) as f32 * 0.01).collect();
        let (parsed, st) = m
            .exchange(5, &out, &flow(&cells, &cells, &cells))
            .unwrap();
        assert_eq!(parsed, out);
        // no hidden dependence on payload size
        assert_eq!(st.bytes_written + st.bytes_read, 0);
        assert_eq!(st.files, 0);
    }
}
