//! *I/O-Disabled* exchange: pure in-memory pass-through.
//!
//! The paper's theoretical-upper-bound configuration: all file I/O is
//! suspended and data moves by reference. Unlike the paper's variant
//! (which broke the data path and produced unusable control results, as
//! they note), ours is a real zero-copy interface, so training through it
//! is *both* the upper bound and correct — this is the mode the quickstart
//! and training examples default to.

use anyhow::Result;

use super::{CfdOutput, ExchangeInterface, FlowSnapshot, IoMode, IoStats};

/// The *I/O-Disabled* exchange strategy: zero-copy pass-through with zero
/// recorded cost (see module docs).
pub struct InMemory;

impl InMemory {
    pub fn new() -> Self {
        InMemory
    }
}

impl Default for InMemory {
    fn default() -> Self {
        Self::new()
    }
}

impl ExchangeInterface for InMemory {
    fn mode(&self) -> IoMode {
        IoMode::InMemory
    }

    fn exchange(
        &mut self,
        _step: usize,
        out: &CfdOutput,
        _flow: &FlowSnapshot,
    ) -> Result<(CfdOutput, IoStats)> {
        Ok((out.clone(), IoStats::default()))
    }

    fn inject_action(&mut self, _step: usize, action: f64) -> Result<(f64, IoStats)> {
        Ok((action, IoStats::default()))
    }
}
