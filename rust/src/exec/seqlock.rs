//! The SPSC seqlock protocol — ordering decisions in ONE place.
//!
//! [`super::shm`]'s memory-mapped ring and the loom model checks
//! (`rust/tests/loom_shm.rs`) must agree on the protocol *exactly*, or
//! the model proves the wrong thing. So the four sequence-word
//! transitions of the Vyukov bounded SPSC queue live here as free
//! functions over the [`crate::util::sync`] facade, and both the mmap
//! ring and the heap-backed [`ModelRing`] below drive their slots
//! through them:
//!
//! | transition        | who      | op                                  | why this ordering |
//! |-------------------|----------|-------------------------------------|-------------------|
//! | [`slot_init`]     | creator  | `seq.store(i, Release)`             | initial handoff to both sides' first `Acquire` load |
//! | [`producer_owns`] | producer | `seq.load(Acquire) == pos`          | acquires the consumer's *release* of this slot — the consumer's final payload read happens-before our overwrite |
//! | [`publish`]       | producer | `seq.store(pos + 1, Release)`       | releases the payload bytes — a consumer that acquires `pos + 1` sees the complete frame, never a torn one |
//! | [`consumer_owns`] | consumer | `seq.load(Acquire) == pos + 1`      | acquires the producer's publish — pairs with [`publish`] |
//! | [`release`]       | consumer | `seq.store(pos + n_slots, Release)` | releases the slot for the producer's next lap — pairs with [`producer_owns`] |
//!
//! The load half of each Release/Acquire pair is what makes torn writes
//! *invisible*: a producer that dies between writing payload bytes and
//! calling [`publish`] leaves `seq == pos`, so [`consumer_owns`] stays
//! false forever and the consumer never touches the half-written slot
//! (`torn_write_is_never_observable` in the loom suite, plus the chaos
//! tests over the real mmap ring). Weakening any `Release` here to
//! `Relaxed` is caught by loom as a causality violation on the payload
//! cell — `relaxed_publish_is_caught_by_loom` demonstrates it.
//!
//! [`ModelRing`] is the loom-checkable stand-in for the mmap ring:
//! payload slots are [`UnsafeCell`]s (tracked under loom), sequence
//! words are facade atomics, and push/pop mirror
//! `shm::Producer::push` / `shm::Consumer::try_pop` step for step.

use crate::util::sync::{Arc, AtomicU64, Ordering, UnsafeCell};

/// Stamp slot `idx`'s sequence word with its initial value (`seq = idx`
/// means "empty, awaiting lap-0 producer").
pub fn slot_init(seq: &AtomicU64, idx: u64) {
    seq.store(idx, Ordering::Release);
}

/// Does the producer at position `pos` own its slot (is it free)?
pub fn producer_owns(seq: &AtomicU64, pos: u64) -> bool {
    seq.load(Ordering::Acquire) == pos
}

/// Publish the frame the producer wrote into slot `pos`. Must be the
/// LAST thing the producer does to the slot: the Release store is what
/// transfers the payload bytes to the consumer.
pub fn publish(seq: &AtomicU64, pos: u64) {
    seq.store(pos + 1, Ordering::Release);
}

/// Does the consumer at position `pos` have a published frame waiting?
pub fn consumer_owns(seq: &AtomicU64, pos: u64) -> bool {
    seq.load(Ordering::Acquire) == pos + 1
}

/// Hand slot `pos` back to the producer for its next lap. Must be the
/// LAST thing the consumer does to the slot.
pub fn release(seq: &AtomicU64, pos: u64, n_slots: u64) {
    seq.store(pos + n_slots, Ordering::Release);
}

// --- heap-backed model ring -------------------------------------------------

/// Shared state of a heap-backed SPSC seqlock ring: the protocol of the
/// mmap ring, minus the mmap. Exists so the protocol can be (a) loom
/// model-checked and (b) unit-tested without touching the filesystem;
/// it is NOT a transport (the real data plane is [`super::shm`]).
pub struct ModelRing {
    seqs: Box<[AtomicU64]>,
    slots: Box<[UnsafeCell<Vec<u8>>]>,
}

// SAFETY: `UnsafeCell<Vec<u8>>` makes `ModelRing` `!Sync` by default,
// but every access to `slots[i]` is guarded by the seqlock discipline on
// `seqs[i]`: the producer only writes a slot it owns (`producer_owns`),
// the consumer only reads a slot that was published (`consumer_owns`),
// and the Release/Acquire pairs above order those accesses. Loom checks
// exactly this claim on every interleaving.
unsafe impl Sync for ModelRing {}
// SAFETY: sending the ring between threads moves no thread-affine state;
// see the `Sync` argument for why shared access is then sound.
unsafe impl Send for ModelRing {}

impl ModelRing {
    /// Create a ring of `n_slots` slots and split it into its two
    /// single-threaded halves.
    pub fn pair(n_slots: usize) -> (ModelProducer, ModelConsumer) {
        assert!(n_slots > 0, "model ring needs at least one slot");
        let seqs: Box<[AtomicU64]> = (0..n_slots as u64).map(AtomicU64::new).collect();
        let slots: Box<[UnsafeCell<Vec<u8>>]> =
            (0..n_slots).map(|_| UnsafeCell::new(Vec::new())).collect();
        let ring = Arc::new(ModelRing { seqs, slots });
        (
            ModelProducer {
                ring: Arc::clone(&ring),
                pos: 0,
            },
            ModelConsumer { ring, pos: 0 },
        )
    }

    fn n_slots(&self) -> u64 {
        self.seqs.len() as u64
    }

    fn idx(&self, pos: u64) -> usize {
        (pos % self.n_slots()) as usize
    }
}

/// Write half of a [`ModelRing`] (exactly one exists per ring).
pub struct ModelProducer {
    ring: Arc<ModelRing>,
    pos: u64,
}

/// Read half of a [`ModelRing`] (exactly one exists per ring).
pub struct ModelConsumer {
    ring: Arc<ModelRing>,
    pos: u64,
}

impl ModelProducer {
    /// Non-blocking push: write + publish one frame if the slot is free.
    /// Mirrors `shm::Producer::push` minus the backoff/timeout loop
    /// (model checks need bounded executions, so the caller spins).
    pub fn try_push(&mut self, bytes: &[u8]) -> bool {
        let idx = self.ring.idx(self.pos);
        let seq = &self.ring.seqs[idx];
        if !producer_owns(seq, self.pos) {
            return false;
        }
        // SAFETY: we own the slot (seq == pos): the consumer will not
        // touch the cell until `publish` below, and the previous
        // consumer's reads happened-before our `producer_owns` Acquire.
        self.ring.slots[idx].with_mut(|p| unsafe {
            (*p).clear();
            (*p).extend_from_slice(bytes);
        });
        publish(seq, self.pos);
        self.pos += 1;
        true
    }

    /// Chaos/model hook: write the payload but never publish — a
    /// producer crashed mid-write. The protocol must keep this slot
    /// invisible to the consumer forever (the seqlock's core guarantee).
    pub fn write_torn(&mut self, bytes: &[u8]) {
        let idx = self.ring.idx(self.pos);
        // SAFETY: as in `try_push` — we own the unpublished slot; since
        // `publish` is never called, no other side ever reads it.
        self.ring.slots[idx].with_mut(|p| unsafe {
            (*p).clear();
            (*p).extend_from_slice(bytes);
        });
        // no publish: the frame must stay unobservable
    }

    /// Deliberately WRONG publish (Relaxed instead of Release), kept for
    /// the negative loom test `relaxed_publish_is_caught_by_loom`: with
    /// no release fence the consumer can acquire the new sequence value
    /// without the payload bytes, which loom reports as a causality
    /// violation on the slot cell. Never call this outside that test.
    pub fn push_with_relaxed_publish(&mut self, bytes: &[u8]) -> bool {
        let idx = self.ring.idx(self.pos);
        let seq = &self.ring.seqs[idx];
        if !producer_owns(seq, self.pos) {
            return false;
        }
        // SAFETY: identical slot ownership to `try_push`; the *bug*
        // below is the ordering of the store, not the cell access.
        self.ring.slots[idx].with_mut(|p| unsafe {
            (*p).clear();
            (*p).extend_from_slice(bytes);
        });
        seq.store(self.pos + 1, Ordering::Relaxed); // BUG by design
        self.pos += 1;
        true
    }
}

impl ModelConsumer {
    /// Non-blocking pop: mirror of `shm::Consumer::try_pop`.
    pub fn try_pop(&mut self) -> Option<Vec<u8>> {
        let idx = self.ring.idx(self.pos);
        let seq = &self.ring.seqs[idx];
        if !consumer_owns(seq, self.pos) {
            return None;
        }
        // SAFETY: the slot is published (seq == pos + 1): the producer's
        // payload writes happened-before our `consumer_owns` Acquire,
        // and it will not write again until `release` below.
        let out = self.ring.slots[idx].with(|p| unsafe { (*p).clone() });
        release(seq, self.pos, self.ring.n_slots());
        self.pos += 1;
        Some(out)
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn model_ring_round_trips_in_order_across_wraps() {
        let (mut tx, mut rx) = ModelRing::pair(2);
        for lap in 0..5u32 {
            assert!(tx.try_push(&lap.to_le_bytes()));
            assert!(tx.try_push(&(lap + 100).to_le_bytes()));
            // ring of 2 is now full
            assert!(!tx.try_push(&[0xFF]));
            assert_eq!(rx.try_pop().unwrap(), lap.to_le_bytes());
            assert_eq!(rx.try_pop().unwrap(), (lap + 100).to_le_bytes());
            assert!(rx.try_pop().is_none());
        }
    }

    #[test]
    fn torn_write_is_invisible_on_the_model_ring() {
        let (mut tx, mut rx) = ModelRing::pair(4);
        tx.write_torn(&[0xDE, 0xAD]);
        assert!(rx.try_pop().is_none());
        assert!(rx.try_pop().is_none());
    }

    #[test]
    fn cross_thread_stream_is_ordered_and_complete() {
        let (mut tx, mut rx) = ModelRing::pair(4);
        let n = 1000u32;
        let h = std::thread::spawn(move || {
            let mut sent = 0u32;
            while sent < n {
                if tx.try_push(&sent.to_le_bytes()) {
                    sent += 1;
                } else {
                    std::thread::yield_now();
                }
            }
        });
        let mut next = 0u32;
        while next < n {
            match rx.try_pop() {
                Some(bytes) => {
                    assert_eq!(bytes, next.to_le_bytes());
                    next += 1;
                }
                None => std::thread::yield_now(),
            }
        }
        h.join().unwrap();
    }
}
