//! The `drlfoam worker` process: one environment rank behind the wire
//! protocol.
//!
//! Spawned by the multi-process executor ([`super::process`]) via
//! self-exec; speaks [`super::wire`] frames over stdin/stdout (stdout is
//! therefore *reserved* — all diagnostics go to stderr, which the
//! coordinator inherits). Rank 0 builds the environment + per-env policy
//! exactly like an in-process worker thread and serves
//! `SetParams`/`Rollout`/`Reset`/`Step`; ranks ≥ 1 are placement members
//! of their env's rank group and only heartbeat until shutdown. A
//! heartbeat thread beats every `--heartbeat-ms` so the coordinator can
//! tell a busy worker from a dead one.

use std::io::{self, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::pool::{build_worker, run_episode};
use crate::drl::policy::PolicyBackendKind;
use crate::exec::wire::{self, Frame, PROTOCOL_VERSION};
use crate::io_interface::IoMode;
use crate::runtime::Manifest;

/// Everything the `worker` subcommand parses off its command line.
pub struct WorkerConfig {
    pub env_id: usize,
    /// 0 = the env's primary (does the work); ≥ 1 = placement rank.
    pub rank: usize,
    pub scenario: String,
    pub variant: String,
    pub artifact_dir: PathBuf,
    pub work_dir: PathBuf,
    pub io_mode: IoMode,
    pub backend: PolicyBackendKind,
    pub seed: u64,
    /// Heartbeat period; 0 disables the heartbeat thread.
    pub heartbeat_ms: u64,
}

/// Serve this rank until Shutdown or stdin EOF. On error, a terminal
/// `Error` frame is emitted before returning so the coordinator gets the
/// root cause instead of a bare dead channel.
pub fn run(cfg: &WorkerConfig) -> Result<()> {
    let out: Arc<Mutex<io::Stdout>> = Arc::new(Mutex::new(io::stdout()));
    let stop = Arc::new(AtomicBool::new(false));
    let beat = if cfg.heartbeat_ms > 0 {
        let o = Arc::clone(&out);
        let s = Arc::clone(&stop);
        let period = std::time::Duration::from_millis(cfg.heartbeat_ms);
        Some(
            std::thread::Builder::new()
                .name("heartbeat".into())
                .spawn(move || {
                    while !s.load(Ordering::Relaxed) {
                        std::thread::sleep(period);
                        if send(&o, &Frame::Heartbeat).is_err() {
                            return; // coordinator gone
                        }
                    }
                })
                .context("spawning heartbeat thread")?,
        )
    } else {
        None
    };

    let res = serve(cfg, &out);
    stop.store(true, Ordering::Relaxed);
    if let Some(b) = beat {
        let _ = b.join();
    }
    if let Err(e) = &res {
        let _ = send(&out, &Frame::Error { msg: format!("{e:#}") });
    }
    res
}

fn send(out: &Mutex<io::Stdout>, frame: &Frame) -> Result<()> {
    let mut g = out.lock().expect("stdout mutex poisoned");
    wire::write_frame(&mut *g, frame)
}

fn hello(cfg: &WorkerConfig, n_obs: usize) -> Frame {
    Frame::Hello {
        env_id: cfg.env_id as u32,
        rank: cfg.rank as u32,
        pid: std::process::id(),
        n_obs: n_obs as u32,
        version: PROTOCOL_VERSION,
    }
}

fn serve(cfg: &WorkerConfig, out: &Arc<Mutex<io::Stdout>>) -> Result<()> {
    let stdin = io::stdin();
    let mut stdin = stdin.lock();

    if cfg.rank > 0 {
        // placement rank: hold the core, heartbeat, wait for shutdown
        send(out, &hello(cfg, 0))?;
        while let Some(frame) = wire::read_frame(&mut stdin)? {
            if matches!(frame, Frame::Shutdown) {
                break;
            }
        }
        return Ok(());
    }

    // a *missing* manifest selects the artifact-free path (surrogate +
    // native policy); a present-and-broken one is a real error
    let manifest = Manifest::load_optional(&cfg.artifact_dir)?;
    let (mut env, mut lp, policy) = build_worker(
        cfg.env_id,
        &cfg.artifact_dir,
        &cfg.work_dir,
        &cfg.variant,
        &cfg.scenario,
        cfg.io_mode,
        cfg.seed,
        cfg.backend,
        manifest.as_ref(),
    )
    .context("env worker setup failed")?;
    send(out, &hello(cfg, env.n_obs()))?;

    let mut params: Arc<Vec<f32>> = Arc::new(Vec::new());
    while let Some(frame) = wire::read_frame(&mut stdin)? {
        match frame {
            Frame::SetParams { params: p } => params = Arc::new(p),
            Frame::Rollout {
                horizon,
                episode,
                episode_seed,
            } => {
                maybe_crash(cfg, episode);
                let eo = run_episode(
                    cfg.env_id,
                    env.as_mut(),
                    &mut lp,
                    &policy,
                    &params,
                    horizon as usize,
                    cfg.seed ^ episode_seed,
                )?;
                send(
                    out,
                    &Frame::Episode {
                        env_id: cfg.env_id as u32,
                        stats: eo.stats,
                        traj: eo.traj,
                    },
                )?;
            }
            Frame::Reset => {
                let obs = env.reset()?;
                send(out, &Frame::Obs { obs })?;
            }
            Frame::Step { action } => {
                let result = env.step(action)?;
                send(out, &Frame::StepOut { result })?;
            }
            Frame::Shutdown => break,
            Frame::Heartbeat => {}
            other => anyhow::bail!("unexpected coordinator frame {other:?}"),
        }
    }
    Ok(())
}

/// Chaos hook behind `train --chaos <env>:<episode>` (the executor
/// exports it as `DRLFOAM_WORKER_CRASH`): the matching rank-0 worker
/// dies by fatal signal immediately after *receiving* that episode's
/// Rollout — exactly the SIGKILL-mid-dispatch shape the fault-recovery
/// tests and the CI smoke assert on. A tombstone file in the shared work
/// dir makes it a one-shot: the respawned twin runs the replay instead
/// of re-crashing.
fn maybe_crash(cfg: &WorkerConfig, episode: u64) {
    let Ok(spec) = std::env::var("DRLFOAM_WORKER_CRASH") else {
        return;
    };
    let Some((e, ep)) = spec.split_once(':') else {
        return;
    };
    match (e.trim().parse::<usize>(), ep.trim().parse::<u64>()) {
        (Ok(want_env), Ok(want_ep)) if want_env == cfg.env_id && want_ep == episode => {}
        _ => return,
    }
    let marker = cfg
        .work_dir
        .join(format!("chaos-env{}-ep{episode}.tombstone", cfg.env_id));
    if marker.exists() {
        return;
    }
    let _ = std::fs::write(&marker, b"chaos hook fired here once\n");
    let _ = io::stderr().flush();
    std::process::abort();
}
