//! The `drlfoam worker` process: one environment rank behind the wire
//! protocol.
//!
//! Spawned by the multi-process executor ([`super::process`]) via
//! self-exec; speaks [`super::wire`] frames over stdin/stdout (stdout is
//! therefore *reserved* — all diagnostics go to stderr, which the
//! coordinator inherits). Rank 0 builds the environment + per-env policy
//! exactly like an in-process worker thread and serves
//! `SetParams`/`Rollout`/`Reset`/`Step`; ranks ≥ 1 are placement members
//! of their env's rank group and only heartbeat until shutdown. A
//! heartbeat thread beats every `--heartbeat-ms` so the coordinator can
//! tell a busy worker from a dead one.
//!
//! When spawned with `--shm-prefix` (the coordinator's `--transport
//! shm`), rank 0 maps the pre-created seqlock rings of [`super::shm`]
//! and moves the *data* frames over them — `Step` in, `Obs`/`StepOut`/
//! `Episode` out (per-frame pipe fallback when one outgrows a slot) —
//! acking the rings via `Hello { shm: 1 }`. If mapping fails the worker
//! warns on stderr, sends `Hello { shm: 0 }` and serves everything over
//! the pipe; control frames stay on the pipe either way.
//!
//! When spawned with `--connect tcp:host:port|uds:path` (the
//! coordinator's `--transport tcp|uds`, directly or via a `drlfoam
//! agent`), the worker dials that address at startup and every frame —
//! heartbeats included — moves over the socket instead of stdin/stdout;
//! the serve loop is otherwise identical, which is what keeps the
//! socket transports inside the bitwise conformance bar.

use std::io::{self, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cfd::CfdBackend;
use crate::coordinator::pool::{build_worker, run_episode};
use crate::drl::policy::PolicyBackendKind;
use crate::exec::net::{self, NetStream};
use crate::exec::shm;
use crate::exec::wire::{self, Frame, PROTOCOL_VERSION};
use crate::io_interface::IoMode;
use crate::obs;
use crate::runtime::Manifest;

/// How long a ring push may block on a full ring before the worker gives
/// up (the coordinator stopped draining — effectively a dead peer).
const PUSH_TIMEOUT: Duration = Duration::from_secs(30);

/// Everything the `worker` subcommand parses off its command line.
pub struct WorkerConfig {
    pub env_id: usize,
    /// 0 = the env's primary (does the work); ≥ 1 = placement rank.
    pub rank: usize,
    pub scenario: String,
    pub variant: String,
    pub artifact_dir: PathBuf,
    pub work_dir: PathBuf,
    pub io_mode: IoMode,
    pub backend: PolicyBackendKind,
    /// Engine for cylinder CFD periods (`--cfd-backend`).
    pub cfd_backend: CfdBackend,
    pub seed: u64,
    /// Heartbeat period; 0 disables the heartbeat thread.
    pub heartbeat_ms: u64,
    /// Ring-file prefix (`<prefix>.c2w.ring` / `<prefix>.w2c.ring`) the
    /// coordinator pre-created; `None` = pipe-only transport.
    pub shm_prefix: Option<PathBuf>,
    /// Socket to dial back instead of serving stdin/stdout
    /// (`tcp:host:port` / `uds:path`, from the coordinator's
    /// `--transport tcp|uds`); frames then flow over that stream.
    pub connect: Option<String>,
    /// `--trace-spans`: record obs spans and batch them to the
    /// coordinator as `Frame::Telemetry` (ARCHITECTURE.md §12).
    pub trace: bool,
}

/// Where this worker's frames go: stdout (pipe transport) or the dialed
/// socket (`--connect`). Mirrors the coordinator's writer enum so both
/// ends treat the stream exactly like the pipe.
enum WireOut {
    Stdout(io::Stdout),
    Net(NetStream),
}

impl Write for WireOut {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            WireOut::Stdout(w) => w.write(buf),
            WireOut::Net(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            WireOut::Stdout(w) => w.flush(),
            WireOut::Net(s) => s.flush(),
        }
    }
}

/// Serve this rank until Shutdown or channel EOF. On error, a terminal
/// `Error` frame is emitted before returning so the coordinator gets the
/// root cause instead of a bare dead channel.
pub fn run(cfg: &WorkerConfig) -> Result<()> {
    if cfg.trace {
        obs::enable();
        obs::set_thread_env(cfg.env_id as u32);
    }
    let (input, output): (Box<dyn Read + Send>, WireOut) = match &cfg.connect {
        Some(spec) => {
            let stream = net::connect_arg(spec)
                .with_context(|| format!("env worker {} dialing the coordinator", cfg.env_id))?;
            (Box::new(stream.try_clone()?), WireOut::Net(stream))
        }
        None => (Box::new(io::stdin()), WireOut::Stdout(io::stdout())),
    };
    let out: Arc<Mutex<WireOut>> = Arc::new(Mutex::new(output));
    let stop = Arc::new(AtomicBool::new(false));
    let beat = if cfg.heartbeat_ms > 0 {
        let o = Arc::clone(&out);
        let s = Arc::clone(&stop);
        let period = std::time::Duration::from_millis(cfg.heartbeat_ms);
        Some(
            std::thread::Builder::new()
                .name("heartbeat".into())
                .spawn(move || {
                    while !s.load(Ordering::Relaxed) {
                        std::thread::sleep(period);
                        if send(&o, &Frame::Heartbeat).is_err() {
                            return; // coordinator gone
                        }
                    }
                })
                .context("spawning heartbeat thread")?,
        )
    } else {
        None
    };

    let res = serve(cfg, &out, input);
    stop.store(true, Ordering::Relaxed);
    if let Some(b) = beat {
        let _ = b.join();
    }
    if let Err(e) = &res {
        let _ = send(&out, &Frame::Error { msg: format!("{e:#}") });
    }
    res
}

fn send(out: &Mutex<WireOut>, frame: &Frame) -> Result<()> {
    let mut g = out.lock().expect("output mutex poisoned");
    wire::write_frame(&mut *g, frame)
}

/// Reply path for *data* frames: the ring when mapped (with per-frame
/// pipe fallback for frames that outgrow a slot), the pipe otherwise.
fn send_data(
    ring: Option<&mut shm::Producer>,
    out: &Mutex<WireOut>,
    frame: &Frame,
) -> Result<()> {
    if let Some(p) = ring {
        let body = wire::encode(frame);
        if p.push(&body, PUSH_TIMEOUT)
            .context("shm push to coordinator")?
        {
            return Ok(());
        } // frame outgrew the slot: fall through to the pipe
    }
    send(out, frame)
}

fn hello(cfg: &WorkerConfig, n_obs: usize, shm: bool) -> Frame {
    Frame::Hello {
        env_id: cfg.env_id as u32,
        rank: cfg.rank as u32,
        pid: std::process::id(),
        n_obs: n_obs as u32,
        version: PROTOCOL_VERSION,
        shm: shm as u32,
    }
}

/// Where the rank-0 serve loop gets its next coordinator frame from.
enum FrameSource {
    /// Single-channel transports (pipe or socket): block on the input
    /// stream directly.
    Stream(Box<dyn Read + Send>),
    /// Shm transport: a detached thread reads stdin into a channel while
    /// the serve loop polls both the channel and the ring.
    Dual {
        frames: Receiver<Result<Option<Frame>>>,
        ring: shm::Consumer,
        backoff: shm::Backoff,
    },
}

impl FrameSource {
    fn next(&mut self) -> Result<Option<Frame>> {
        match self {
            FrameSource::Stream(input) => wire::read_frame(input),
            FrameSource::Dual {
                frames,
                ring,
                backoff,
            } => loop {
                match frames.try_recv() {
                    Ok(item) => return item,
                    // the stdin thread exits right after its EOF/error
                    // item; a disconnect past that is a clean close
                    Err(TryRecvError::Disconnected) => return Ok(None),
                    Err(TryRecvError::Empty) => {}
                }
                if let Some(body) = ring.try_pop()? {
                    backoff.reset();
                    return wire::decode(&body).map(Some);
                }
                backoff.snooze();
            },
        }
    }
}

fn serve(
    cfg: &WorkerConfig,
    out: &Arc<Mutex<WireOut>>,
    mut input: Box<dyn Read + Send>,
) -> Result<()> {
    if cfg.rank > 0 {
        // placement rank: hold the core, heartbeat, wait for shutdown
        send(out, &hello(cfg, 0, false))?;
        while let Some(frame) = wire::read_frame(&mut input)? {
            if matches!(frame, Frame::Shutdown) {
                break;
            }
        }
        return Ok(());
    }

    // map the offered rings; failure downgrades to the pipe, never kills
    // the worker (the Hello ack tells the coordinator which happened)
    let mut rings: Option<(shm::Consumer, shm::Producer)> = None;
    if let Some(prefix) = &cfg.shm_prefix {
        let (c2w, w2c) = shm::ring_paths(prefix);
        match (|| -> Result<_> { Ok((shm::consumer(&c2w)?, shm::producer(&w2c)?)) })() {
            Ok(pair) => rings = Some(pair),
            Err(e) => eprintln!(
                "warning: env worker {} could not map shm rings ({e:#}); \
                 falling back to the pipe transport",
                cfg.env_id
            ),
        }
    }

    // a *missing* manifest selects the artifact-free path (surrogate +
    // native policy); a present-and-broken one is a real error
    let manifest = Manifest::load_optional(&cfg.artifact_dir)?;
    let (mut env, mut lp, policy) = build_worker(
        cfg.env_id,
        &cfg.artifact_dir,
        &cfg.work_dir,
        &cfg.variant,
        &cfg.scenario,
        cfg.io_mode,
        cfg.seed,
        cfg.backend,
        cfg.cfd_backend,
        manifest.as_ref(),
    )
    .context("env worker setup failed")?;
    send(out, &hello(cfg, env.n_obs(), rings.is_some()))?;

    let (mut source, mut tx_ring) = match rings {
        Some((rx_ring, tx_ring)) => {
            let (ftx, frx) = channel();
            std::thread::Builder::new()
                .name("stdin-read".into())
                .spawn(move || loop {
                    let item = wire::read_frame(&mut input);
                    let done = matches!(item, Ok(None) | Err(_));
                    if ftx.send(item).is_err() || done {
                        return;
                    }
                })
                .context("spawning stdin reader thread")?;
            (
                FrameSource::Dual {
                    frames: frx,
                    ring: rx_ring,
                    backoff: shm::Backoff::new(),
                },
                Some(tx_ring),
            )
        }
        None => (FrameSource::Stream(input), None),
    };

    let mut params: Arc<Vec<f32>> = Arc::new(Vec::new());
    loop {
        // WireRecv deliberately includes the wait for the coordinator's
        // next job — in the merged Perfetto timeline this is what makes
        // worker idle visible on the env lane (ARCHITECTURE.md §12)
        let t_recv = if cfg.trace { obs::now_us() } else { 0 };
        let Some(frame) = source.next()? else { break };
        if cfg.trace {
            obs::record(
                obs::Phase::WireRecv,
                t_recv,
                obs::now_us().saturating_sub(t_recv),
                cfg.env_id as u32,
                0,
            );
        }
        match frame {
            Frame::SetParams { params: p } => params = Arc::new(p),
            Frame::Rollout {
                horizon,
                episode,
                episode_seed,
            } => {
                maybe_crash(cfg, episode, tx_ring.as_mut(), out);
                obs::set_thread_episode(episode);
                let eo = run_episode(
                    cfg.env_id,
                    env.as_mut(),
                    &mut lp,
                    &policy,
                    &params,
                    horizon as usize,
                    cfg.seed ^ episode_seed,
                )?;
                let t_send = if cfg.trace { obs::now_us() } else { 0 };
                send_data(
                    tx_ring.as_mut(),
                    out,
                    &Frame::Episode {
                        env_id: cfg.env_id as u32,
                        stats: eo.stats,
                        traj: eo.traj,
                    },
                )?;
                if cfg.trace {
                    obs::record(
                        obs::Phase::WireSend,
                        t_send,
                        obs::now_us().saturating_sub(t_send),
                        cfg.env_id as u32,
                        episode,
                    );
                }
                flush_telemetry(cfg, out)?;
            }
            Frame::Reset => {
                // lockstep boundary: ship whatever the previous episode
                // accumulated before the step loop starts
                flush_telemetry(cfg, out)?;
                let obs = env.reset()?;
                send_data(tx_ring.as_mut(), out, &Frame::Obs { obs })?;
            }
            Frame::Step { action } => {
                let result = env.step(action)?;
                send_data(tx_ring.as_mut(), out, &Frame::StepOut { result })?;
            }
            Frame::Shutdown => {
                flush_telemetry(cfg, out)?;
                break;
            }
            Frame::Heartbeat => {}
            // clock probe: echo the coordinator's timestamp back with
            // ours so it can compute this worker's clock offset
            Frame::Telemetry {
                kind: 1, clock_us, ..
            } => {
                send(
                    out,
                    &Frame::Telemetry {
                        env_id: cfg.env_id as u32,
                        rank: cfg.rank as u32,
                        kind: 2,
                        clock_us: obs::now_us(),
                        echo_us: clock_us,
                        spans: Vec::new(),
                    },
                )?;
            }
            Frame::Telemetry { .. } => {}
            other => anyhow::bail!("unexpected coordinator frame {other:?}"),
        }
    }
    Ok(())
}

/// Batch this worker's recorded spans into one `Telemetry` frame on the
/// control channel (never the ring: span batches are rare and the rings
/// are reserved for the latency-critical data frames).
fn flush_telemetry(cfg: &WorkerConfig, out: &Mutex<WireOut>) -> Result<()> {
    if !cfg.trace {
        return Ok(());
    }
    let spans = obs::take_all_spans();
    if spans.is_empty() {
        return Ok(());
    }
    send(
        out,
        &Frame::Telemetry {
            env_id: cfg.env_id as u32,
            rank: cfg.rank as u32,
            kind: 0,
            clock_us: 0,
            echo_us: 0,
            spans,
        },
    )
}

/// Chaos hook behind `train --chaos <env>:<episode>[:midframe]` (the
/// executor exports it as `DRLFOAM_WORKER_CRASH`): the matching rank-0
/// worker dies by fatal signal immediately after *receiving* that
/// episode's Rollout — exactly the SIGKILL-mid-dispatch shape the
/// fault-recovery tests and the CI smoke assert on. The `midframe`
/// variant additionally dies with a *partially written* frame on every
/// channel — a torn (never-published) ring slot and a pipe frame whose
/// header promises more bytes than ever arrive — pinning down that
/// neither reader can surface a corrupt frame. A tombstone file in the
/// shared work dir makes it a one-shot: the respawned twin runs the
/// replay instead of re-crashing.
fn maybe_crash(
    cfg: &WorkerConfig,
    episode: u64,
    ring: Option<&mut shm::Producer>,
    out: &Mutex<WireOut>,
) {
    let Ok(spec) = std::env::var("DRLFOAM_WORKER_CRASH") else {
        return;
    };
    let mut parts = spec.splitn(3, ':');
    let (Some(e), Some(ep)) = (parts.next(), parts.next()) else {
        return;
    };
    let midframe = parts.next().map(str::trim) == Some("midframe");
    match (e.trim().parse::<usize>(), ep.trim().parse::<u64>()) {
        (Ok(want_env), Ok(want_ep)) if want_env == cfg.env_id && want_ep == episode => {}
        _ => return,
    }
    let marker = cfg
        .work_dir
        .join(format!("chaos-env{}-ep{episode}.tombstone", cfg.env_id));
    if marker.exists() {
        return;
    }
    let _ = std::fs::write(&marker, b"chaos hook fired here once\n");
    if midframe {
        if let Some(p) = ring {
            // payload bytes land in the slot, seq is never published
            p.write_torn(&[0xAA; 64]);
        }
        if let Ok(mut g) = out.lock() {
            // header promising 64 payload bytes, then only 3 of them
            let _ = g.write_all(&64u32.to_le_bytes());
            let _ = g.write_all(&[9u8, 0xAA, 0xAA]);
            let _ = g.flush();
        }
    }
    let _ = io::stderr().flush();
    std::process::abort();
}
