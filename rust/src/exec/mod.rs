//! Execution backends: how a planner-chosen layout becomes real workers.
//!
//! Until this subsystem existed, every environment ran on an OS *thread*
//! inside the coordinator process — the planner (`crate::cluster::planner`)
//! could only ever be validated against its own DES, and `ranks_per_env`
//! was pinned to 1 live. The paper's Sections IV–V (and the Rabault &
//! Kuhnle multi-environment framework it builds on) assume per-rank OS
//! *processes* with explicit placement; this module closes that
//! sim-to-real gap.
//!
//! One [`Executor`] trait, two backends:
//!
//! * [`inprocess`] — today's threaded path, kept as the default and as
//!   the golden reference (`rust/tests/exec_backend.rs` asserts the two
//!   backends produce bitwise-identical learning CSVs);
//! * [`process`] — worker processes spawned via `drlfoam worker`
//!   self-exec, speaking the length-prefixed binary protocol of
//!   [`wire`] over stdin/stdout. With `--transport shm` the *data*
//!   frames (actions out, observations/step results/episodes back) move
//!   through per-worker memory-mapped seqlock rings ([`shm`]) instead,
//!   while the pipe remains the control channel and the per-frame
//!   fallback — see [`TransportKind`]. With `--transport tcp|uds` every
//!   frame instead rides a socket ([`net`]): loopback sockets to
//!   directly-spawned children, or connections to per-host `drlfoam
//!   agent` supervisors when `--hosts` spans machines. Supports
//!   `ranks_per_env > 1` by
//!   spawning *rank groups* (rank 0 does the work; ranks 1.. are
//!   placement/heartbeat members, since the in-repo CFD is
//!   single-core), plus heartbeat/timeout fault handling: a dead
//!   worker's episode is re-queued on a respawned process and the
//!   restart is surfaced in
//!   [`TrainSummary`](crate::coordinator::TrainSummary).
//!
//! Process tree of a `MultiProcess` pool (`n_envs = 2`,
//! `ranks_per_env = 2`):
//!
//! ```text
//! drlfoam train --executor multi-process
//! ├── drlfoam worker --env-id 0 --rank 0     (episodes / lockstep)
//! ├── drlfoam worker --env-id 0 --rank 1     (placement + heartbeat)
//! ├── drlfoam worker --env-id 1 --rank 0
//! └── drlfoam worker --env-id 1 --rank 1
//! ```
//!
//! [`EnvPool`](crate::coordinator::pool::EnvPool) holds an executor
//! handle, so `rollout`/`rollout_batched`/`rollout_batched_subset` and
//! all three [`SyncPolicy`](crate::coordinator::SyncPolicy) loops work
//! unchanged over either backend. Determinism is preserved end to end:
//! the wire protocol round-trips every f32/f64 bit-exactly, episode
//! seeds travel in the `Rollout` frame, and a re-queued episode replays
//! the identical seed — so even a run that lost a worker mid-flight
//! reproduces the fault-free learning curve (see
//! `rust/tests/exec_backend.rs`).

pub mod inprocess;
pub mod net;
pub mod process;
pub mod seqlock;
pub mod shm;
pub mod wire;
pub mod worker;

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::pool::EpisodeOut;
use crate::env::StepResult;

/// Coordinator → worker command alphabet, shared by both backends (the
/// in-process backend moves it over a channel; the multi-process backend
/// encodes it as [`wire`] frames).
pub enum Job {
    /// Per-env mode: roll a whole episode locally under the carried
    /// parameters. `episode` is the per-env episode index (drives the
    /// chaos hook and observability); `episode_seed` is the derived
    /// exploration seed — both travel so a respawned worker can replay
    /// the identical episode.
    Rollout {
        params: Arc<Vec<f32>>,
        horizon: usize,
        episode: u64,
        episode_seed: u64,
    },
    /// Batched mode: reset the environment, reply with the initial obs.
    Reset,
    /// Batched mode: advance one actuation period with this action.
    Step { action: f64 },
    Shutdown,
}

/// Worker → coordinator reply for the lockstep (batched) protocol.
pub enum LockstepReply {
    Obs { env_id: usize, obs: Vec<f32> },
    Step { env_id: usize, result: StepResult },
}

/// Which execution backend realises the worker set
/// (`--executor in-process|multi-process`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// OS threads inside the coordinator process (default; the golden
    /// reference the multi-process backend is tested against).
    InProcess,
    /// One `drlfoam worker` OS process per rank, spawned by self-exec.
    MultiProcess,
}

impl ExecutorKind {
    /// Parse a CLI/config string (trimmed, case-insensitive); the error
    /// lists the accepted values.
    pub fn parse(s: &str) -> Result<ExecutorKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "in-process" | "inprocess" | "threads" => Ok(ExecutorKind::InProcess),
            "multi-process" | "multiprocess" | "processes" => Ok(ExecutorKind::MultiProcess),
            _ => anyhow::bail!(
                "unknown executor {s:?} (accepted: in-process|threads, \
                 multi-process|processes)"
            ),
        }
    }

    /// Canonical name, inverse of [`ExecutorKind::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            ExecutorKind::InProcess => "in-process",
            ExecutorKind::MultiProcess => "multi-process",
        }
    }
}

/// Which data plane the multi-process backend moves frames over
/// (`--transport pipe|shm|tcp|uds`). Irrelevant for the in-process
/// backend, which never serialises anything.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Every frame over the worker's stdin/stdout pipes (default).
    Pipe,
    /// Data frames over memory-mapped seqlock rings ([`shm`]); the pipe
    /// stays the control channel and the fallback when ring setup fails
    /// or a frame outgrows a slot.
    Shm,
    /// Every frame over a TCP socket ([`net`]); with `--hosts` the
    /// connection runs through a remote `drlfoam agent`, without it the
    /// coordinator listens on an ephemeral loopback port per worker.
    Tcp,
    /// Same as [`TransportKind::Tcp`] over a Unix-domain socket (one
    /// socket file per worker under the work dir, or a `drlfoam agent`
    /// bound to a socket path).
    Uds,
}

impl TransportKind {
    /// Parse a CLI/config string (trimmed, case-insensitive); the error
    /// lists the accepted values.
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "pipe" | "stdio" => Ok(TransportKind::Pipe),
            "shm" | "shared-memory" => Ok(TransportKind::Shm),
            "tcp" => Ok(TransportKind::Tcp),
            "uds" | "unix" => Ok(TransportKind::Uds),
            _ => anyhow::bail!(
                "unknown transport {s:?} (accepted: pipe|stdio, shm|shared-memory, \
                 tcp, uds|unix)"
            ),
        }
    }

    /// Canonical name, inverse of [`TransportKind::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Pipe => "pipe",
            TransportKind::Shm => "shm",
            TransportKind::Tcp => "tcp",
            TransportKind::Uds => "uds",
        }
    }

    /// True for the socket transports ([`net`] data plane).
    pub fn is_socket(&self) -> bool {
        matches!(self, TransportKind::Tcp | TransportKind::Uds)
    }
}

/// A set of `n_envs` workers the pool can drive: send [`Job`]s to a
/// specific worker, receive finished episodes from any, receive lockstep
/// replies. Implementations own fault handling — [`Executor::recv_episode`]
/// on the multi-process backend transparently respawns dead workers and
/// replays their in-flight episode.
pub trait Executor: Send {
    fn n_envs(&self) -> usize;

    /// Deliver one job to worker `env_id`.
    fn send(&mut self, env_id: usize, job: Job) -> Result<()>;

    /// Block until ANY worker finishes an episode.
    fn recv_episode(&mut self) -> Result<EpisodeOut>;

    /// Non-blocking variant; `Ok(None)` = nothing finished yet.
    fn try_recv_episode(&mut self) -> Result<Option<EpisodeOut>>;

    /// Block until the next lockstep (batched-mode) reply.
    fn recv_lockstep(&mut self) -> Result<LockstepReply>;

    /// Workers respawned after faults, total over the pool's lifetime.
    fn restarts(&self) -> usize;

    /// Per-env respawn counts (`workers.csv` telemetry).
    fn restarts_by_env(&self) -> Vec<usize>;

    /// OS pids of every live worker process (empty for in-process).
    fn worker_pids(&self) -> Vec<u32>;

    /// Fault injection: SIGKILL worker `env_id`'s primary rank (the
    /// multi-process backend's recovery path is tested through this).
    fn kill_worker(&mut self, env_id: usize) -> Result<()>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executor_kind_parse_round_trips_and_lists_accepted() {
        for k in [ExecutorKind::InProcess, ExecutorKind::MultiProcess] {
            assert_eq!(ExecutorKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(
            ExecutorKind::parse(" Threads ").unwrap(),
            ExecutorKind::InProcess
        );
        assert_eq!(
            ExecutorKind::parse("PROCESSES").unwrap(),
            ExecutorKind::MultiProcess
        );
        let err = ExecutorKind::parse("gpu").unwrap_err().to_string();
        assert!(
            err.contains("in-process") && err.contains("multi-process"),
            "{err}"
        );
    }

    #[test]
    fn transport_kind_parse_round_trips_and_lists_accepted() {
        for t in [
            TransportKind::Pipe,
            TransportKind::Shm,
            TransportKind::Tcp,
            TransportKind::Uds,
        ] {
            assert_eq!(TransportKind::parse(t.name()).unwrap(), t);
        }
        assert_eq!(TransportKind::parse(" Stdio ").unwrap(), TransportKind::Pipe);
        assert_eq!(
            TransportKind::parse("SHARED-MEMORY").unwrap(),
            TransportKind::Shm
        );
        assert_eq!(TransportKind::parse("UNIX").unwrap(), TransportKind::Uds);
        assert!(TransportKind::Tcp.is_socket() && TransportKind::Uds.is_socket());
        assert!(!TransportKind::Pipe.is_socket() && !TransportKind::Shm.is_socket());
        let err = TransportKind::parse("rdma").unwrap_err().to_string();
        assert!(
            err.contains("pipe") && err.contains("shm") && err.contains("tcp"),
            "{err}"
        );
    }
}
