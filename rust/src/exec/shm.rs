//! Shared-memory seqlock ring: the multi-process executor's data plane.
//!
//! ROADMAP item 5(b): observations, actions and step results are small
//! fixed-size f32 blocks, so instead of copying every frame through the
//! stdin/stdout pipes they move through a pair of memory-mapped
//! single-producer/single-consumer rings per worker (one per direction),
//! while the pipe of [`super::wire`] stays the *control* channel
//! (Hello/SetParams/Rollout/Reset/Heartbeat/Error/Shutdown) and the
//! fallback whenever shm setup fails or a frame outgrows a slot.
//!
//! ## File layout
//!
//! The ring lives in a plain file (under the pool's work dir) mapped
//! `MAP_SHARED` by both sides:
//!
//! ```text
//! [header: 64 B]  magic u64 | version u32 | n_slots u32 | slot_payload u32 | pad
//! [slot 0]        seq AtomicU64 | len u32 | pad u32 | payload [slot_payload B]
//! [slot 1]        ...
//! ```
//!
//! Slot stride is `16 + slot_payload` with `slot_payload` a multiple of
//! 8, keeping every `seq` word 8-byte aligned. Payload bytes are a wire
//! frame *body* (`[u8 tag][payload]`, exactly what [`super::wire`]
//! length-prefixes on the pipe); the length lives in the slot header, so
//! the bit-exact f32/f64 packing is byte-for-byte shared between both
//! transports.
//!
//! ## Seqlock protocol (Vyukov bounded SPSC)
//!
//! Slot `i` starts with `seq = i`. The producer at position `p` waits for
//! `seq == p` (Acquire), writes `len` + payload, then *publishes* with
//! `seq.store(p + 1, Release)`. The consumer at `p` waits for
//! `seq == p + 1` (Acquire), copies the frame out, then releases the slot
//! with `seq.store(p + n_slots, Release)`. A crash mid-write leaves the
//! slot unpublished — `seq` still reads `p` — so a torn frame is
//! *invisible* by construction: the consumer can never observe a
//! partially written payload (`torn_write_is_invisible` below, and the
//! chaos tests in `rust/tests/exec_transport_conformance.rs`).
//!
//! Mapping is raw `mmap(2)` via a local `extern "C"` declaration — no
//! crates are vendored for this — and the whole module degrades to a
//! clear error on non-unix targets, which the executor turns into a pipe
//! fallback.

use std::fs::{File, OpenOptions};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

/// `b"DRLFRING"` little-endian; rejects mapping some unrelated file.
const MAGIC: u64 = u64::from_le_bytes(*b"DRLFRING");

/// Bumped on any layout change; both sides must agree.
const RING_VERSION: u32 = 1;

const HEADER_BYTES: usize = 64;

/// Per-slot header: `seq: u64` + `len: u32` + 4 pad bytes.
const SLOT_HEADER: usize = 16;

/// Slots per ring for the executor's data plane. Lockstep traffic is
/// strict request/reply, so depth mostly buys slack for the episode
/// frames of the per-env path.
pub const DATA_SLOTS: u32 = 64;

/// Payload capacity per slot. Obs/Step/StepOut frames are a few hundred
/// bytes; whole small-horizon Episode frames also fit. Anything larger
/// falls back to the pipe per-frame (`push` returns `Ok(false)`).
pub const DATA_PAYLOAD: u32 = 16 << 10;

/// The two ring files behind a `--shm-prefix`: coordinator→worker
/// (actions) and worker→coordinator (observations / step results /
/// episodes). Shared by both sides so the naming can never drift.
pub fn ring_paths(prefix: &Path) -> (std::path::PathBuf, std::path::PathBuf) {
    let mut c2w = prefix.as_os_str().to_os_string();
    c2w.push(".c2w.ring");
    let mut w2c = prefix.as_os_str().to_os_string();
    w2c.push(".w2c.ring");
    (c2w.into(), w2c.into())
}

// --- raw mmap FFI (unix only) ----------------------------------------------

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const PROT_WRITE: i32 = 2;
    pub const MAP_SHARED: i32 = 0x01;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// An owned `MAP_SHARED` mapping (unmapped on drop). The raw pointer is
/// only ever dereferenced through the seqlock discipline above, and each
/// end of a ring is single-threaded, so shipping it across the spawn
/// boundary is sound.
struct Map {
    ptr: *mut u8,
    len: usize,
}

unsafe impl Send for Map {}

impl Map {
    #[cfg(unix)]
    fn new(file: &File, len: usize) -> Result<Map> {
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        ensure!(
            ptr as isize != -1 && !ptr.is_null(),
            "mmap of shm ring failed ({} bytes)",
            len
        );
        Ok(Map {
            ptr: ptr as *mut u8,
            len,
        })
    }

    #[cfg(not(unix))]
    fn new(_file: &File, _len: usize) -> Result<Map> {
        anyhow::bail!("shared-memory transport requires a unix target (mmap)");
    }
}

impl Drop for Map {
    fn drop(&mut self) {
        #[cfg(unix)]
        unsafe {
            sys::munmap(self.ptr as *mut _, self.len);
        }
    }
}

// --- ring geometry ----------------------------------------------------------

#[derive(Clone, Copy)]
struct Geometry {
    n_slots: u32,
    slot_payload: u32,
}

impl Geometry {
    fn stride(&self) -> usize {
        SLOT_HEADER + self.slot_payload as usize
    }

    fn file_len(&self) -> usize {
        HEADER_BYTES + self.n_slots as usize * self.stride()
    }
}

struct Ring {
    map: Map,
    geo: Geometry,
    /// Producer: next position to publish. Consumer: next to read.
    pos: u64,
}

impl Ring {
    fn slot_base(&self, pos: u64) -> *mut u8 {
        let idx = (pos % self.geo.n_slots as u64) as usize;
        unsafe { self.map.ptr.add(HEADER_BYTES + idx * self.geo.stride()) }
    }

    fn seq(&self, pos: u64) -> &AtomicU64 {
        // The seq word is 8-byte aligned by construction (64 B header,
        // stride = 16 + payload with payload % 8 == 0).
        unsafe { &*(self.slot_base(pos) as *const AtomicU64) }
    }
}

fn open_file(path: &Path) -> Result<File> {
    OpenOptions::new()
        .read(true)
        .write(true)
        .open(path)
        .with_context(|| format!("opening shm ring {}", path.display()))
}

/// Create a ring file at `path` (coordinator side): size it, map it,
/// stamp the header and initialise every slot's sequence word.
pub fn create(path: &Path, n_slots: u32, slot_payload: u32) -> Result<()> {
    ensure!(n_slots > 0, "shm ring needs at least one slot");
    ensure!(
        slot_payload > 0 && slot_payload % 8 == 0,
        "shm slot payload must be a positive multiple of 8"
    );
    let geo = Geometry {
        n_slots,
        slot_payload,
    };
    let file = OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .truncate(true)
        .open(path)
        .with_context(|| format!("creating shm ring {}", path.display()))?;
    file.set_len(geo.file_len() as u64)
        .context("sizing shm ring file")?;
    let map = Map::new(&file, geo.file_len())?;
    unsafe {
        let hdr = map.ptr;
        hdr.copy_from_nonoverlapping(MAGIC.to_le_bytes().as_ptr(), 8);
        hdr.add(8)
            .copy_from_nonoverlapping(RING_VERSION.to_le_bytes().as_ptr(), 4);
        hdr.add(12)
            .copy_from_nonoverlapping(n_slots.to_le_bytes().as_ptr(), 4);
        hdr.add(16)
            .copy_from_nonoverlapping(slot_payload.to_le_bytes().as_ptr(), 4);
    }
    let ring = Ring { map, geo, pos: 0 };
    for i in 0..n_slots as u64 {
        ring.seq(i).store(i, Ordering::Release);
    }
    Ok(())
}

fn open_ring(path: &Path) -> Result<Ring> {
    let file = open_file(path)?;
    let actual = file.metadata().context("statting shm ring")?.len() as usize;
    ensure!(
        actual >= HEADER_BYTES,
        "shm ring {} too short for a header",
        path.display()
    );
    // Map just the header first to read the geometry, then remap fully.
    let hdr_map = Map::new(&file, HEADER_BYTES)?;
    let (magic, version, n_slots, slot_payload) = unsafe {
        let p = hdr_map.ptr;
        let mut m = [0u8; 8];
        p.copy_to_nonoverlapping(m.as_mut_ptr(), 8);
        let mut v = [0u8; 4];
        p.add(8).copy_to_nonoverlapping(v.as_mut_ptr(), 4);
        let mut ns = [0u8; 4];
        p.add(12).copy_to_nonoverlapping(ns.as_mut_ptr(), 4);
        let mut sp = [0u8; 4];
        p.add(16).copy_to_nonoverlapping(sp.as_mut_ptr(), 4);
        (
            u64::from_le_bytes(m),
            u32::from_le_bytes(v),
            u32::from_le_bytes(ns),
            u32::from_le_bytes(sp),
        )
    };
    drop(hdr_map);
    ensure!(magic == MAGIC, "shm ring {}: bad magic", path.display());
    ensure!(
        version == RING_VERSION,
        "shm ring {}: version {version} != {RING_VERSION}",
        path.display()
    );
    ensure!(
        n_slots > 0 && slot_payload > 0 && slot_payload % 8 == 0,
        "shm ring {}: corrupt geometry ({n_slots} slots x {slot_payload} B)",
        path.display()
    );
    let geo = Geometry {
        n_slots,
        slot_payload,
    };
    ensure!(
        actual >= geo.file_len(),
        "shm ring {}: file shorter than its declared geometry",
        path.display()
    );
    let map = Map::new(&file, geo.file_len())?;
    Ok(Ring { map, geo, pos: 0 })
}

// --- producer / consumer ----------------------------------------------------

/// Write half of a ring (exactly one per ring file).
pub struct Producer {
    ring: Ring,
}

/// Read half of a ring (exactly one per ring file).
pub struct Consumer {
    ring: Ring,
}

/// Open the write half of an existing ring file.
pub fn producer(path: &Path) -> Result<Producer> {
    Ok(Producer {
        ring: open_ring(path)?,
    })
}

/// Open the read half of an existing ring file.
pub fn consumer(path: &Path) -> Result<Consumer> {
    Ok(Consumer {
        ring: open_ring(path)?,
    })
}

impl Producer {
    /// Bytes a single slot can carry.
    pub fn slot_payload(&self) -> usize {
        self.ring.geo.slot_payload as usize
    }

    /// Publish one frame body. `Ok(false)` means the frame does not fit
    /// a slot — the caller must send it over the pipe instead. Blocks
    /// (with backoff) while the ring is full; errors after `timeout`,
    /// which in practice means the peer died without draining.
    pub fn push(&mut self, bytes: &[u8], timeout: Duration) -> Result<bool> {
        if bytes.len() > self.slot_payload() {
            return Ok(false);
        }
        let pos = self.ring.pos;
        let seq = self.ring.seq(pos);
        let mut backoff = Backoff::new();
        let deadline = Instant::now() + timeout;
        while seq.load(Ordering::Acquire) != pos {
            ensure!(
                Instant::now() < deadline,
                "shm ring full for {timeout:?} (peer not draining)"
            );
            backoff.snooze();
        }
        unsafe {
            let base = self.ring.slot_base(pos);
            base.add(8)
                .copy_from_nonoverlapping((bytes.len() as u32).to_le_bytes().as_ptr(), 4);
            base.add(SLOT_HEADER)
                .copy_from_nonoverlapping(bytes.as_ptr(), bytes.len());
        }
        seq.store(pos + 1, Ordering::Release);
        self.ring.pos += 1;
        Ok(true)
    }

    /// Chaos hook: write a frame body into the current slot but *never
    /// publish it* — models a producer killed mid-write. The consumer
    /// must keep seeing the slot as empty (the seqlock guarantee the
    /// conformance chaos tests pin down).
    pub fn write_torn(&mut self, bytes: &[u8]) {
        let n = bytes.len().min(self.slot_payload());
        let pos = self.ring.pos;
        unsafe {
            let base = self.ring.slot_base(pos);
            base.add(8)
                .copy_from_nonoverlapping((n as u32).to_le_bytes().as_ptr(), 4);
            base.add(SLOT_HEADER)
                .copy_from_nonoverlapping(bytes.as_ptr(), n);
        }
        // no seq.store: the frame stays unpublished forever
    }
}

impl Consumer {
    /// Pop the next published frame body, if any. Never blocks; never
    /// yields a torn frame (unpublished slots are indistinguishable from
    /// empty ones).
    pub fn try_pop(&mut self) -> Result<Option<Vec<u8>>> {
        let pos = self.ring.pos;
        let seq = self.ring.seq(pos);
        if seq.load(Ordering::Acquire) != pos + 1 {
            return Ok(None);
        }
        let (len, base) = unsafe {
            let base = self.ring.slot_base(pos);
            let mut l = [0u8; 4];
            base.add(8).copy_to_nonoverlapping(l.as_mut_ptr(), 4);
            (u32::from_le_bytes(l) as usize, base)
        };
        ensure!(
            len <= self.ring.geo.slot_payload as usize,
            "shm slot declares {len} bytes > payload capacity"
        );
        let mut out = vec![0u8; len];
        unsafe {
            base.add(SLOT_HEADER)
                .copy_to_nonoverlapping(out.as_mut_ptr(), len);
        }
        seq.store(pos + self.ring.geo.n_slots as u64, Ordering::Release);
        self.ring.pos += 1;
        Ok(Some(out))
    }
}

// --- backoff ----------------------------------------------------------------

/// Spin → yield → sleep backoff for the polling loops on both ends; keeps
/// the hot path at spin-latency while idle waits cost ~no CPU.
pub struct Backoff {
    step: u32,
}

impl Backoff {
    pub fn new() -> Backoff {
        Backoff { step: 0 }
    }

    pub fn reset(&mut self) {
        self.step = 0;
    }

    pub fn snooze(&mut self) {
        if self.step < 64 {
            std::hint::spin_loop();
        } else if self.step < 256 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(100));
        }
        self.step = self.step.saturating_add(1);
    }
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn scratch(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("drlfoam-shm-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    const T: Duration = Duration::from_secs(5);

    #[test]
    fn frames_round_trip_bit_exact() {
        let path = scratch("roundtrip");
        create(&path, 4, 64).unwrap();
        let mut tx = producer(&path).unwrap();
        let mut rx = consumer(&path).unwrap();
        let frames: Vec<Vec<u8>> = vec![
            vec![],
            vec![0xAB],
            (0..64u8).collect(),
            1.25f64.to_le_bytes().to_vec(),
        ];
        for f in &frames {
            assert!(tx.push(f, T).unwrap());
        }
        for f in &frames {
            assert_eq!(rx.try_pop().unwrap().unwrap(), *f);
        }
        assert!(rx.try_pop().unwrap().is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ring_wraps_around_many_times() {
        let path = scratch("wrap");
        create(&path, 4, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        let mut rx = consumer(&path).unwrap();
        for i in 0..100u32 {
            assert!(tx.push(&i.to_le_bytes(), T).unwrap());
            let got = rx.try_pop().unwrap().unwrap();
            assert_eq!(got, i.to_le_bytes());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn oversized_frame_reports_pipe_fallback() {
        let path = scratch("oversize");
        create(&path, 2, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        assert!(!tx.push(&[0u8; 33], T).unwrap());
        // ring untouched: a normal frame still goes through slot 0
        let mut rx = consumer(&path).unwrap();
        assert!(tx.push(&[7u8; 32], T).unwrap());
        assert_eq!(rx.try_pop().unwrap().unwrap(), vec![7u8; 32]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn full_ring_times_out_instead_of_overwriting() {
        let path = scratch("full");
        create(&path, 2, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        assert!(tx.push(&[1], T).unwrap());
        assert!(tx.push(&[2], T).unwrap());
        let err = tx.push(&[3], Duration::from_millis(50)).unwrap_err();
        assert!(err.to_string().contains("full"), "{err}");
        // both published frames still intact
        let mut rx = consumer(&path).unwrap();
        assert_eq!(rx.try_pop().unwrap().unwrap(), vec![1]);
        assert_eq!(rx.try_pop().unwrap().unwrap(), vec![2]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_write_is_invisible() {
        let path = scratch("torn");
        create(&path, 4, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        let mut rx = consumer(&path).unwrap();
        // producer dies mid-write: payload bytes land, seq never flips
        tx.write_torn(&[0xDE, 0xAD, 0xBE, 0xEF]);
        assert!(rx.try_pop().unwrap().is_none());
        assert!(rx.try_pop().unwrap().is_none());
        // a fresh producer generation (new ring file) starts clean
        let path2 = scratch("torn2");
        create(&path2, 4, 32).unwrap();
        let mut tx2 = producer(&path2).unwrap();
        let mut rx2 = consumer(&path2).unwrap();
        assert!(tx2.push(&[1, 2, 3], T).unwrap());
        assert_eq!(rx2.try_pop().unwrap().unwrap(), vec![1, 2, 3]);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&path2);
    }

    #[test]
    fn header_validation_rejects_garbage() {
        let path = scratch("garbage");
        std::fs::write(&path, vec![0u8; 4096]).unwrap();
        assert!(producer(&path).is_err());
        assert!(consumer(&path).is_err());
        // too short for even a header
        let short = scratch("short");
        std::fs::write(&short, [0u8; 8]).unwrap();
        assert!(producer(&short).is_err());
        // bad geometry is rejected at create time
        assert!(create(&scratch("geo"), 0, 64).is_err());
        assert!(create(&scratch("geo2"), 4, 12).is_err());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&short);
    }

    #[test]
    fn cross_thread_spsc_stream_is_ordered_and_complete() {
        let path = scratch("spsc");
        create(&path, 8, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        let mut rx = consumer(&path).unwrap();
        let n = 10_000u32;
        let h = std::thread::spawn(move || {
            for i in 0..n {
                tx.push(&i.to_le_bytes(), Duration::from_secs(30)).unwrap();
            }
        });
        let mut backoff = Backoff::new();
        let mut next = 0u32;
        while next < n {
            match rx.try_pop().unwrap() {
                Some(bytes) => {
                    assert_eq!(bytes, next.to_le_bytes());
                    next += 1;
                    backoff.reset();
                }
                None => backoff.snooze(),
            }
        }
        h.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
