//! Shared-memory seqlock ring: the multi-process executor's data plane.
//!
//! ROADMAP item 5(b): observations, actions and step results are small
//! fixed-size f32 blocks, so instead of copying every frame through the
//! stdin/stdout pipes they move through a pair of memory-mapped
//! single-producer/single-consumer rings per worker (one per direction),
//! while the pipe of [`super::wire`] stays the *control* channel
//! (Hello/SetParams/Rollout/Reset/Heartbeat/Error/Shutdown) and the
//! fallback whenever shm setup fails or a frame outgrows a slot.
//!
//! ## File layout
//!
//! The ring lives in a plain file (under the pool's work dir) mapped
//! `MAP_SHARED` by both sides:
//!
//! ```text
//! [header: 64 B]  magic u64 | version u32 | n_slots u32 | slot_payload u32 | pad
//! [slot 0]        seq AtomicU64 | len u32 | pad u32 | payload [slot_payload B]
//! [slot 1]        ...
//! ```
//!
//! Slot stride is `16 + slot_payload` with `slot_payload` a multiple of
//! 8, keeping every `seq` word 8-byte aligned. Payload bytes are a wire
//! frame *body* (`[u8 tag][payload]`, exactly what [`super::wire`]
//! length-prefixes on the pipe); the length lives in the slot header, so
//! the bit-exact f32/f64 packing is byte-for-byte shared between both
//! transports.
//!
//! ## Seqlock protocol (Vyukov bounded SPSC)
//!
//! The sequence-word transitions — and every memory-ordering decision —
//! live in [`super::seqlock`], shared verbatim with the loom model
//! checks (`rust/tests/loom_shm.rs`): slot `i` starts at `seq = i`
//! ([`seqlock::slot_init`]); the producer at `p` waits for ownership
//! ([`seqlock::producer_owns`]), writes `len` + payload, publishes
//! ([`seqlock::publish`]); the consumer waits for the published frame
//! ([`seqlock::consumer_owns`]), copies it out, and releases the slot
//! for the next lap ([`seqlock::release`]). A crash mid-write leaves the
//! slot unpublished — `seq` still reads `p` — so a torn frame is
//! *invisible* by construction: the consumer can never observe a
//! partially written payload (`torn_write_is_invisible` below, the loom
//! suite, and the chaos tests in
//! `rust/tests/exec_transport_conformance.rs`).
//!
//! Mapping is raw `mmap(2)` via a local `extern "C"` declaration — no
//! crates are vendored for this — and the whole module degrades to a
//! clear error on non-unix targets, which the executor turns into a pipe
//! fallback. Under `--cfg loom` the mmap ring cannot exist (loom's
//! atomics are heap objects, not a transparent view over mapped bytes),
//! so every entry point degrades to the same clear error and the
//! protocol is checked on [`seqlock::ModelRing`] instead.

use std::path::Path;
use std::time::Duration;

#[cfg(not(loom))]
use std::fs::{File, OpenOptions};
#[cfg(not(loom))]
use std::time::Instant;

use anyhow::Result;
#[cfg(not(loom))]
use anyhow::{ensure, Context};

#[cfg(not(loom))]
use super::seqlock;
#[cfg(not(loom))]
use crate::util::sync::AtomicU64;

/// `b"DRLFRING"` little-endian; rejects mapping some unrelated file.
#[cfg(not(loom))]
const MAGIC: u64 = u64::from_le_bytes(*b"DRLFRING");

/// Bumped on any layout change; both sides must agree.
#[cfg(not(loom))]
const RING_VERSION: u32 = 1;

#[cfg(not(loom))]
const HEADER_BYTES: usize = 64;

/// Per-slot header: `seq: u64` + `len: u32` + 4 pad bytes.
#[cfg(not(loom))]
const SLOT_HEADER: usize = 16;

/// Slots per ring for the executor's data plane. Lockstep traffic is
/// strict request/reply, so depth mostly buys slack for the episode
/// frames of the per-env path.
pub const DATA_SLOTS: u32 = 64;

/// Payload capacity per slot. Obs/Step/StepOut frames are a few hundred
/// bytes; whole small-horizon Episode frames also fit. Anything larger
/// falls back to the pipe per-frame (`push` returns `Ok(false)`).
pub const DATA_PAYLOAD: u32 = 16 << 10;

/// The two ring files behind a `--shm-prefix`: coordinator→worker
/// (actions) and worker→coordinator (observations / step results /
/// episodes). Shared by both sides so the naming can never drift.
pub fn ring_paths(prefix: &Path) -> (std::path::PathBuf, std::path::PathBuf) {
    let mut c2w = prefix.as_os_str().to_os_string();
    c2w.push(".c2w.ring");
    let mut w2c = prefix.as_os_str().to_os_string();
    w2c.push(".w2c.ring");
    (c2w.into(), w2c.into())
}

// --- raw mmap FFI (unix only) ----------------------------------------------

#[cfg(all(unix, not(loom)))]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const PROT_WRITE: i32 = 2;
    pub const MAP_SHARED: i32 = 0x01;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// An owned `MAP_SHARED` mapping (unmapped on drop). The raw pointer is
/// only ever dereferenced through the seqlock discipline above, and each
/// end of a ring is single-threaded, so shipping it across the spawn
/// boundary is sound.
#[cfg(not(loom))]
struct Map {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is plain `MAP_SHARED` memory with no thread
// affinity; `Map` is `!Send` only because of the raw pointer. All
// dereferences go through the seqlock protocol (each slot is touched
// exclusively by whichever side owns its sequence word), and each half
// of a ring (Producer/Consumer) is used from a single thread at a time,
// so moving the handle to another thread cannot introduce a data race.
#[cfg(not(loom))]
unsafe impl Send for Map {}

#[cfg(not(loom))]
impl Map {
    #[cfg(unix)]
    fn new(file: &File, len: usize) -> Result<Map> {
        use std::os::unix::io::AsRawFd;
        // SAFETY: plain FFI call. `addr` is null (kernel picks the
        // address, never MAP_FIXED), `len > 0` is sized by the caller to
        // the ring geometry, `fd` is a live file descriptor owned by
        // `file` for the duration of the call, and the result is checked
        // for MAP_FAILED/null before use.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        ensure!(
            ptr as isize != -1 && !ptr.is_null(),
            "mmap of shm ring failed ({} bytes)",
            len
        );
        Ok(Map {
            ptr: ptr as *mut u8,
            len,
        })
    }

    #[cfg(not(unix))]
    fn new(_file: &File, _len: usize) -> Result<Map> {
        anyhow::bail!("shared-memory transport requires a unix target (mmap)");
    }
}

#[cfg(not(loom))]
impl Drop for Map {
    fn drop(&mut self) {
        // SAFETY: `ptr`/`len` are exactly the mapping `mmap` returned in
        // `Map::new` (never offset, never resized), this drop is the
        // unique owner, and no access can follow the unmap.
        #[cfg(unix)]
        unsafe {
            sys::munmap(self.ptr as *mut _, self.len);
        }
    }
}

// --- ring geometry ----------------------------------------------------------

#[cfg(not(loom))]
#[derive(Clone, Copy)]
struct Geometry {
    n_slots: u32,
    slot_payload: u32,
}

#[cfg(not(loom))]
impl Geometry {
    fn stride(&self) -> usize {
        SLOT_HEADER + self.slot_payload as usize
    }

    fn file_len(&self) -> usize {
        HEADER_BYTES + self.n_slots as usize * self.stride()
    }
}

#[cfg(not(loom))]
struct Ring {
    map: Map,
    geo: Geometry,
    /// Producer: next position to publish. Consumer: next to read.
    pos: u64,
}

#[cfg(not(loom))]
impl Ring {
    fn slot_base(&self, pos: u64) -> *mut u8 {
        let idx = (pos % self.geo.n_slots as u64) as usize;
        // SAFETY: `idx < n_slots`, so `HEADER_BYTES + idx * stride` is
        // at most `file_len - stride`, and the mapping is `file_len`
        // bytes (validated against the file's real size at open/create).
        // The offset stays within the single mapped allocation.
        unsafe { self.map.ptr.add(HEADER_BYTES + idx * self.geo.stride()) }
    }

    fn seq(&self, pos: u64) -> &AtomicU64 {
        // SAFETY: the slot base is 8-byte aligned by construction (64 B
        // header; stride = 16 + payload with payload % 8 == 0 — both
        // enforced at create/open), so casting the first 8 bytes to
        // `AtomicU64` is aligned and in-bounds. `AtomicU64` has the same
        // layout as `u64`, and cross-process concurrent access to the
        // word is exactly what the atomic type exists to make defined;
        // the returned borrow lives no longer than the mapping (`&self`).
        unsafe { &*(self.slot_base(pos) as *const AtomicU64) }
    }
}

#[cfg(not(loom))]
fn open_file(path: &Path) -> Result<File> {
    OpenOptions::new()
        .read(true)
        .write(true)
        .open(path)
        .with_context(|| format!("opening shm ring {}", path.display()))
}

/// Create a ring file at `path` (coordinator side): size it, map it,
/// stamp the header and initialise every slot's sequence word.
#[cfg(not(loom))]
pub fn create(path: &Path, n_slots: u32, slot_payload: u32) -> Result<()> {
    ensure!(n_slots > 0, "shm ring needs at least one slot");
    ensure!(
        slot_payload > 0 && slot_payload % 8 == 0,
        "shm slot payload must be a positive multiple of 8"
    );
    let geo = Geometry {
        n_slots,
        slot_payload,
    };
    let file = OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .truncate(true)
        .open(path)
        .with_context(|| format!("creating shm ring {}", path.display()))?;
    file.set_len(geo.file_len() as u64)
        .context("sizing shm ring file")?;
    let map = Map::new(&file, geo.file_len())?;
    // SAFETY: the mapping is `file_len >= HEADER_BYTES` bytes; all four
    // copies land inside the 64-byte header region, from local arrays of
    // exactly the lengths written. No other thread or process can hold
    // the file yet — the path is generation-unique and workers only map
    // it after spawn.
    unsafe {
        let hdr = map.ptr;
        hdr.copy_from_nonoverlapping(MAGIC.to_le_bytes().as_ptr(), 8);
        hdr.add(8)
            .copy_from_nonoverlapping(RING_VERSION.to_le_bytes().as_ptr(), 4);
        hdr.add(12)
            .copy_from_nonoverlapping(n_slots.to_le_bytes().as_ptr(), 4);
        hdr.add(16)
            .copy_from_nonoverlapping(slot_payload.to_le_bytes().as_ptr(), 4);
    }
    let ring = Ring { map, geo, pos: 0 };
    for i in 0..n_slots as u64 {
        seqlock::slot_init(ring.seq(i), i);
    }
    Ok(())
}

#[cfg(not(loom))]
fn open_ring(path: &Path) -> Result<Ring> {
    let file = open_file(path)?;
    let actual = file.metadata().context("statting shm ring")?.len() as usize;
    ensure!(
        actual >= HEADER_BYTES,
        "shm ring {} too short for a header",
        path.display()
    );
    // Map just the header first to read the geometry, then remap fully.
    let hdr_map = Map::new(&file, HEADER_BYTES)?;
    // SAFETY: `hdr_map` is `HEADER_BYTES` long (and the file is at least
    // that, checked above); all four reads stay inside the header region
    // and copy into local arrays of exactly the lengths read.
    let (magic, version, n_slots, slot_payload) = unsafe {
        let p = hdr_map.ptr;
        let mut m = [0u8; 8];
        p.copy_to_nonoverlapping(m.as_mut_ptr(), 8);
        let mut v = [0u8; 4];
        p.add(8).copy_to_nonoverlapping(v.as_mut_ptr(), 4);
        let mut ns = [0u8; 4];
        p.add(12).copy_to_nonoverlapping(ns.as_mut_ptr(), 4);
        let mut sp = [0u8; 4];
        p.add(16).copy_to_nonoverlapping(sp.as_mut_ptr(), 4);
        (
            u64::from_le_bytes(m),
            u32::from_le_bytes(v),
            u32::from_le_bytes(ns),
            u32::from_le_bytes(sp),
        )
    };
    drop(hdr_map);
    ensure!(magic == MAGIC, "shm ring {}: bad magic", path.display());
    ensure!(
        version == RING_VERSION,
        "shm ring {}: version {version} != {RING_VERSION}",
        path.display()
    );
    ensure!(
        n_slots > 0 && slot_payload > 0 && slot_payload % 8 == 0,
        "shm ring {}: corrupt geometry ({n_slots} slots x {slot_payload} B)",
        path.display()
    );
    let geo = Geometry {
        n_slots,
        slot_payload,
    };
    ensure!(
        actual >= geo.file_len(),
        "shm ring {}: file shorter than its declared geometry",
        path.display()
    );
    let map = Map::new(&file, geo.file_len())?;
    Ok(Ring { map, geo, pos: 0 })
}

// --- producer / consumer ----------------------------------------------------

/// Write half of a ring (exactly one per ring file).
#[cfg(not(loom))]
pub struct Producer {
    ring: Ring,
}

/// Read half of a ring (exactly one per ring file).
#[cfg(not(loom))]
pub struct Consumer {
    ring: Ring,
}

/// Open the write half of an existing ring file.
#[cfg(not(loom))]
pub fn producer(path: &Path) -> Result<Producer> {
    Ok(Producer {
        ring: open_ring(path)?,
    })
}

/// Open the read half of an existing ring file.
#[cfg(not(loom))]
pub fn consumer(path: &Path) -> Result<Consumer> {
    Ok(Consumer {
        ring: open_ring(path)?,
    })
}

#[cfg(not(loom))]
impl Producer {
    /// Bytes a single slot can carry.
    pub fn slot_payload(&self) -> usize {
        self.ring.geo.slot_payload as usize
    }

    /// Publish one frame body. `Ok(false)` means the frame does not fit
    /// a slot — the caller must send it over the pipe instead. Blocks
    /// (with backoff) while the ring is full; errors after `timeout`,
    /// which in practice means the peer died without draining.
    pub fn push(&mut self, bytes: &[u8], timeout: Duration) -> Result<bool> {
        if bytes.len() > self.slot_payload() {
            return Ok(false);
        }
        let pos = self.ring.pos;
        let seq = self.ring.seq(pos);
        let mut backoff = Backoff::new();
        let deadline = Instant::now() + timeout;
        while !seqlock::producer_owns(seq, pos) {
            ensure!(
                Instant::now() < deadline,
                "shm ring full for {timeout:?} (peer not draining)"
            );
            backoff.snooze();
        }
        // SAFETY: we own the slot (`producer_owns` acquired the
        // consumer's release of it, so its reads happened-before these
        // writes, and the consumer will not touch the slot again until
        // `publish` below). `bytes.len() <= slot_payload` was checked
        // above, so both copies stay inside this slot's `stride` bytes
        // of the mapping.
        unsafe {
            let base = self.ring.slot_base(pos);
            base.add(8)
                .copy_from_nonoverlapping((bytes.len() as u32).to_le_bytes().as_ptr(), 4);
            base.add(SLOT_HEADER)
                .copy_from_nonoverlapping(bytes.as_ptr(), bytes.len());
        }
        seqlock::publish(seq, pos);
        self.ring.pos += 1;
        Ok(true)
    }

    /// Chaos hook: write a frame body into the current slot but *never
    /// publish it* — models a producer killed mid-write. The consumer
    /// must keep seeing the slot as empty (the seqlock guarantee the
    /// conformance chaos tests pin down).
    pub fn write_torn(&mut self, bytes: &[u8]) {
        let n = bytes.len().min(self.slot_payload());
        let pos = self.ring.pos;
        // SAFETY: same slot ownership and bounds as `push` (`n` is
        // clamped to `slot_payload`); since `publish` is deliberately
        // never called, the consumer can never read these bytes.
        unsafe {
            let base = self.ring.slot_base(pos);
            base.add(8)
                .copy_from_nonoverlapping((n as u32).to_le_bytes().as_ptr(), 4);
            base.add(SLOT_HEADER)
                .copy_from_nonoverlapping(bytes.as_ptr(), n);
        }
        // no seq.store: the frame stays unpublished forever
    }
}

#[cfg(not(loom))]
impl Consumer {
    /// Pop the next published frame body, if any. Never blocks; never
    /// yields a torn frame (unpublished slots are indistinguishable from
    /// empty ones).
    pub fn try_pop(&mut self) -> Result<Option<Vec<u8>>> {
        let pos = self.ring.pos;
        let seq = self.ring.seq(pos);
        if !seqlock::consumer_owns(seq, pos) {
            return Ok(None);
        }
        // SAFETY: the slot is published (`consumer_owns` acquired the
        // producer's `publish`, so the complete header + payload writes
        // happened-before this read); the 4-byte length read is inside
        // the slot's header region of the mapping.
        let (len, base) = unsafe {
            let base = self.ring.slot_base(pos);
            let mut l = [0u8; 4];
            base.add(8).copy_to_nonoverlapping(l.as_mut_ptr(), 4);
            (u32::from_le_bytes(l) as usize, base)
        };
        ensure!(
            len <= self.ring.geo.slot_payload as usize,
            "shm slot declares {len} bytes > payload capacity"
        );
        let mut out = vec![0u8; len];
        // SAFETY: `len <= slot_payload` (validated just above against
        // the mapped geometry), so the copy stays inside this slot; the
        // destination is a freshly allocated Vec of exactly `len` bytes,
        // and the producer cannot overwrite the slot until `release`.
        unsafe {
            base.add(SLOT_HEADER)
                .copy_to_nonoverlapping(out.as_mut_ptr(), len);
        }
        seqlock::release(seq, pos, self.ring.geo.n_slots as u64);
        self.ring.pos += 1;
        Ok(Some(out))
    }
}

// --- loom stand-ins ---------------------------------------------------------

/// Under `--cfg loom` the mmap ring cannot exist: loom's `AtomicU64` is
/// a tracked heap object, not a transparent view over 8 mapped bytes,
/// so there is nothing sound to cast the file contents to. The protocol
/// itself is model-checked on [`seqlock::ModelRing`]
/// (`rust/tests/loom_shm.rs`); these stand-ins keep the executor
/// compiling and make every runtime entry point degrade to the error
/// path the executor already treats as "fall back to the pipe".
#[cfg(loom)]
mod loom_stub {
    use super::*;

    pub struct Producer {
        _priv: (),
    }

    pub struct Consumer {
        _priv: (),
    }

    impl Producer {
        pub fn slot_payload(&self) -> usize {
            0
        }

        pub fn push(&mut self, _bytes: &[u8], _timeout: Duration) -> Result<bool> {
            anyhow::bail!("shm ring unavailable under loom (model-checked via seqlock::ModelRing)")
        }

        pub fn write_torn(&mut self, _bytes: &[u8]) {}
    }

    impl Consumer {
        pub fn try_pop(&mut self) -> Result<Option<Vec<u8>>> {
            anyhow::bail!("shm ring unavailable under loom (model-checked via seqlock::ModelRing)")
        }
    }

    pub fn create(_path: &Path, _n_slots: u32, _slot_payload: u32) -> Result<()> {
        anyhow::bail!("shm ring unavailable under loom (model-checked via seqlock::ModelRing)")
    }

    pub fn producer(_path: &Path) -> Result<Producer> {
        anyhow::bail!("shm ring unavailable under loom (model-checked via seqlock::ModelRing)")
    }

    pub fn consumer(_path: &Path) -> Result<Consumer> {
        anyhow::bail!("shm ring unavailable under loom (model-checked via seqlock::ModelRing)")
    }
}

#[cfg(loom)]
pub use loom_stub::{consumer, create, producer, Consumer, Producer};

// --- backoff ----------------------------------------------------------------

/// Spin → yield → sleep backoff for the polling loops on both ends; keeps
/// the hot path at spin-latency while idle waits cost ~no CPU.
pub struct Backoff {
    step: u32,
}

impl Backoff {
    pub fn new() -> Backoff {
        Backoff { step: 0 }
    }

    pub fn reset(&mut self) {
        self.step = 0;
    }

    pub fn snooze(&mut self) {
        if self.step < 64 {
            std::hint::spin_loop();
        } else if self.step < 256 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(100));
        }
        self.step = self.step.saturating_add(1);
    }
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff::new()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn scratch(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("drlfoam-shm-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    const T: Duration = Duration::from_secs(5);

    #[test]
    fn frames_round_trip_bit_exact() {
        let path = scratch("roundtrip");
        create(&path, 4, 64).unwrap();
        let mut tx = producer(&path).unwrap();
        let mut rx = consumer(&path).unwrap();
        let frames: Vec<Vec<u8>> = vec![
            vec![],
            vec![0xAB],
            (0..64u8).collect(),
            1.25f64.to_le_bytes().to_vec(),
        ];
        for f in &frames {
            assert!(tx.push(f, T).unwrap());
        }
        for f in &frames {
            assert_eq!(rx.try_pop().unwrap().unwrap(), *f);
        }
        assert!(rx.try_pop().unwrap().is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ring_wraps_around_many_times() {
        let path = scratch("wrap");
        create(&path, 4, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        let mut rx = consumer(&path).unwrap();
        for i in 0..100u32 {
            assert!(tx.push(&i.to_le_bytes(), T).unwrap());
            let got = rx.try_pop().unwrap().unwrap();
            assert_eq!(got, i.to_le_bytes());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn oversized_frame_reports_pipe_fallback() {
        let path = scratch("oversize");
        create(&path, 2, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        assert!(!tx.push(&[0u8; 33], T).unwrap());
        // ring untouched: a normal frame still goes through slot 0
        let mut rx = consumer(&path).unwrap();
        assert!(tx.push(&[7u8; 32], T).unwrap());
        assert_eq!(rx.try_pop().unwrap().unwrap(), vec![7u8; 32]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn full_ring_times_out_instead_of_overwriting() {
        let path = scratch("full");
        create(&path, 2, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        assert!(tx.push(&[1], T).unwrap());
        assert!(tx.push(&[2], T).unwrap());
        let err = tx.push(&[3], Duration::from_millis(50)).unwrap_err();
        assert!(err.to_string().contains("full"), "{err}");
        // both published frames still intact
        let mut rx = consumer(&path).unwrap();
        assert_eq!(rx.try_pop().unwrap().unwrap(), vec![1]);
        assert_eq!(rx.try_pop().unwrap().unwrap(), vec![2]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_write_is_invisible() {
        let path = scratch("torn");
        create(&path, 4, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        let mut rx = consumer(&path).unwrap();
        // producer dies mid-write: payload bytes land, seq never flips
        tx.write_torn(&[0xDE, 0xAD, 0xBE, 0xEF]);
        assert!(rx.try_pop().unwrap().is_none());
        assert!(rx.try_pop().unwrap().is_none());
        // a fresh producer generation (new ring file) starts clean
        let path2 = scratch("torn2");
        create(&path2, 4, 32).unwrap();
        let mut tx2 = producer(&path2).unwrap();
        let mut rx2 = consumer(&path2).unwrap();
        assert!(tx2.push(&[1, 2, 3], T).unwrap());
        assert_eq!(rx2.try_pop().unwrap().unwrap(), vec![1, 2, 3]);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&path2);
    }

    #[test]
    fn header_validation_rejects_garbage() {
        let path = scratch("garbage");
        std::fs::write(&path, vec![0u8; 4096]).unwrap();
        assert!(producer(&path).is_err());
        assert!(consumer(&path).is_err());
        // too short for even a header
        let short = scratch("short");
        std::fs::write(&short, [0u8; 8]).unwrap();
        assert!(producer(&short).is_err());
        // bad geometry is rejected at create time
        assert!(create(&scratch("geo"), 0, 64).is_err());
        assert!(create(&scratch("geo2"), 4, 12).is_err());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&short);
    }

    #[test]
    fn cross_thread_spsc_stream_is_ordered_and_complete() {
        let path = scratch("spsc");
        create(&path, 8, 32).unwrap();
        let mut tx = producer(&path).unwrap();
        let mut rx = consumer(&path).unwrap();
        let n = 10_000u32;
        let h = std::thread::spawn(move || {
            for i in 0..n {
                tx.push(&i.to_le_bytes(), Duration::from_secs(30)).unwrap();
            }
        });
        let mut backoff = Backoff::new();
        let mut next = 0u32;
        while next < n {
            match rx.try_pop().unwrap() {
                Some(bytes) => {
                    assert_eq!(bytes, next.to_le_bytes());
                    next += 1;
                    backoff.reset();
                }
                None => backoff.snooze(),
            }
        }
        h.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
