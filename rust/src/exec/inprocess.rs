//! In-process execution backend: N scenario workers on OS threads.
//!
//! This is the seed implementation that used to live inside
//! [`EnvPool`](crate::coordinator::pool::EnvPool), now behind the
//! [`Executor`] trait so the pool can also run the multi-process backend
//! ([`super::process`]). It stays the default and the golden reference:
//! `rust/tests/exec_backend.rs` asserts the process backend reproduces
//! its learning curves bitwise.
//!
//! Environments and PJRT clients are built *inside* each thread (neither
//! is `Send`); only the scenario name + config ingredients cross over.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::coordinator::pool::{build_worker, run_episode, EpisodeOut, PoolConfig};
use crate::exec::{Executor, Job, LockstepReply};
use crate::runtime::Manifest;

/// Thread-backed worker set (see module docs).
pub(crate) struct InProcessExecutor {
    job_txs: Vec<Sender<Job>>,
    results: Receiver<Result<EpisodeOut>>,
    lockstep: Receiver<Result<LockstepReply>>,
    joins: Vec<Option<JoinHandle<()>>>,
    /// finished episodes set aside while probing the results channel for
    /// a dead-worker root cause; drained before the channel on receive
    pending: VecDeque<EpisodeOut>,
}

impl InProcessExecutor {
    pub(crate) fn spawn(
        cfg: &PoolConfig,
        manifest: Option<Arc<Manifest>>,
    ) -> Result<InProcessExecutor> {
        let mut job_txs = Vec::with_capacity(cfg.n_envs);
        let mut joins = Vec::with_capacity(cfg.n_envs);
        // one shared result channel: both the synchronous barrier and the
        // asynchronous trainer consume from it
        let (tx_out, rx_out) = channel::<Result<EpisodeOut>>();
        let (tx_step, rx_step) = channel::<Result<LockstepReply>>();
        for env_id in 0..cfg.n_envs {
            let (tx_job, rx_job) = channel::<Job>();
            let m = manifest.clone();
            let cfg = cfg.clone();
            let tx = tx_out.clone();
            let txs = tx_step.clone();
            let join = std::thread::Builder::new()
                .name(format!("env-{env_id}"))
                .spawn(move || worker_main(env_id, cfg, m, rx_job, tx, txs))
                .context("spawning env worker")?;
            job_txs.push(tx_job);
            joins.push(Some(join));
        }
        Ok(InProcessExecutor {
            job_txs,
            results: rx_out,
            lockstep: rx_step,
            joins,
            pending: VecDeque::new(),
        })
    }

    /// Best-effort root cause when a worker goes away: a worker that
    /// fails setup reports on the results channel and exits, which the
    /// lockstep path would otherwise only see as a dead channel.
    /// Finished episodes encountered while probing are re-queued (onto
    /// `pending`, drained by the next receive), never dropped.
    fn closed_reason(&mut self) -> anyhow::Error {
        loop {
            match self.results.try_recv() {
                Ok(Err(e)) => return e.context("env worker failed"),
                Ok(Ok(out)) => self.pending.push_back(out),
                Err(_) => return anyhow::anyhow!("worker channel closed"),
            }
        }
    }
}

impl Executor for InProcessExecutor {
    fn n_envs(&self) -> usize {
        self.job_txs.len()
    }

    fn send(&mut self, env_id: usize, job: Job) -> Result<()> {
        if self.job_txs[env_id].send(job).is_err() {
            return Err(self.closed_reason());
        }
        Ok(())
    }

    fn recv_episode(&mut self) -> Result<EpisodeOut> {
        if let Some(out) = self.pending.pop_front() {
            return Ok(out);
        }
        self.results.recv().context("all workers died")?
    }

    fn try_recv_episode(&mut self) -> Result<Option<EpisodeOut>> {
        if let Some(out) = self.pending.pop_front() {
            return Ok(Some(out));
        }
        match self.results.try_recv() {
            Ok(Ok(out)) => Ok(Some(out)),
            Ok(Err(e)) => Err(e.context("env worker failed")),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow::anyhow!("all workers died")),
        }
    }

    fn recv_lockstep(&mut self) -> Result<LockstepReply> {
        match self.lockstep.recv() {
            Ok(r) => r,
            Err(_) => Err(self.closed_reason()),
        }
    }

    fn restarts(&self) -> usize {
        0
    }

    fn restarts_by_env(&self) -> Vec<usize> {
        vec![0; self.job_txs.len()]
    }

    fn worker_pids(&self) -> Vec<u32> {
        Vec::new()
    }

    fn kill_worker(&mut self, _env_id: usize) -> Result<()> {
        anyhow::bail!(
            "in-process workers are threads and cannot be killed; \
             fault injection needs --executor multi-process"
        )
    }
}

impl Drop for InProcessExecutor {
    fn drop(&mut self) {
        for tx in &self.job_txs {
            let _ = tx.send(Job::Shutdown);
        }
        for j in &mut self.joins {
            if let Some(j) = j.take() {
                let _ = j.join();
            }
        }
    }
}

fn worker_main(
    env_id: usize,
    cfg: PoolConfig,
    manifest: Option<Arc<Manifest>>,
    rx: Receiver<Job>,
    tx: Sender<Result<EpisodeOut>>,
    tx_step: Sender<Result<LockstepReply>>,
) {
    let setup = build_worker(
        env_id,
        &cfg.artifact_dir,
        &cfg.work_dir,
        &cfg.variant,
        &cfg.scenario,
        cfg.io_mode,
        cfg.seed,
        cfg.backend,
        cfg.cfd_backend,
        manifest.as_deref(),
    );

    let (mut env, mut lp, policy) = match setup {
        Ok(x) => x,
        Err(e) => {
            // the lockstep coordinator waits on the step channel, the
            // episode coordinator on the results channel: report the
            // setup failure on BOTH so neither rollout mode can hang
            // waiting for a worker that will never reply
            let _ = tx_step.send(Err(anyhow::anyhow!("env worker setup failed: {e:#}")));
            let _ = tx.send(Err(e));
            return;
        }
    };

    crate::obs::set_thread_env(env_id as u32);
    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::Rollout {
                params,
                horizon,
                episode,
                episode_seed,
            } => {
                crate::obs::set_thread_episode(episode);
                let out = run_episode(
                    env_id,
                    env.as_mut(),
                    &mut lp,
                    &policy,
                    &params,
                    horizon,
                    cfg.seed ^ episode_seed,
                );
                if tx.send(out).is_err() {
                    break;
                }
            }
            Job::Reset => {
                let r = env.reset().map(|obs| LockstepReply::Obs { env_id, obs });
                if tx_step.send(r).is_err() {
                    break;
                }
            }
            Job::Step { action } => {
                let r = env
                    .step(action)
                    .map(|result| LockstepReply::Step { env_id, result });
                if tx_step.send(r).is_err() {
                    break;
                }
            }
        }
    }
}
