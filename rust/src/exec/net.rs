//! Socket data plane for the multi-process executor (`--transport
//! tcp|uds`) and the `drlfoam agent` per-host supervisor.
//!
//! The wire protocol is unchanged — the same length-prefixed frames of
//! [`super::wire`] move over a [`std::net::TcpStream`] or a
//! [`std::os::unix::net::UnixStream`] instead of stdin/stdout pipes, so
//! the transport conformance bar (bitwise learning curves,
//! `rust/tests/exec_transport_conformance.rs`) applies verbatim. Two
//! connection topologies share this module:
//!
//! * **Local (no `--hosts`)** — the coordinator binds one ephemeral
//!   listener *per worker* (loopback TCP port, or a per-generation
//!   socket file under the work dir), spawns the child with
//!   `--connect tcp:127.0.0.1:PORT` / `--connect uds:PATH`, and accepts
//!   exactly one connection. Listener↔worker is 1:1, so no
//!   identification handshake is needed and no relay hop taxes the
//!   throughput gate (`benches/exec_transport.rs --gate`: uds ≥ pipe).
//!
//! * **Agent (`--hosts host:cores[,host:cores…]`)** — the coordinator
//!   connects *out* to a `drlfoam agent` on each host and opens one
//!   connection per worker slot. The first frame on every connection is
//!   [`Frame::Spawn`]; the agent execs `drlfoam worker` with piped
//!   stdio and relays raw bytes both ways. Socket EOF therefore means
//!   exactly what pipe EOF means, and the executor's respawn + bitwise
//!   episode re-queue state machine ([`super::process`]) is reused
//!   unchanged:
//!
//! ```text
//! drlfoam train --hosts hostA:8,hostB:8   drlfoam agent --bind hostB:7700
//! │ coordinator                            │ per-host supervisor
//! ├── conn → agentA ── Spawn(env 0) ──►    ├── drlfoam worker --env-id 2
//! ├── conn → agentA ── Spawn(env 1) ──►    │     (stdio ↔ socket relay)
//! ├── conn → agentB ── Spawn(env 2) ──►    └── drlfoam worker --env-id 3
//! └── conn → agentB ── Spawn(env 3) ──►
//! ```
//!
//! Fault mapping: coordinator-side socket close → the agent kills that
//! connection's worker (orphan reaping); worker exit → the agent closes
//! the socket → the coordinator's reader sees EOF → `Died` → respawn
//! (reconnect + re-`Spawn`) with the identical `(episode, seed)` replay.
//! A dead agent makes the reconnect fail fast (connection refused), so a
//! SIGKILL'd agent surfaces as a counted worker-restart error instead of
//! a hang.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::exec::wire::{self, Frame};
use crate::exec::TransportKind;

/// Port a `drlfoam agent` binds when its `--bind`/`--hosts` entry names
/// a host without one.
pub const DEFAULT_AGENT_PORT: u16 = 7700;

/// How long the coordinator waits for a directly-spawned worker to
/// connect back to its per-worker listener. The worker connects before
/// any environment setup, so this only trips when the child failed to
/// start at all.
pub(crate) const ACCEPT_TIMEOUT: Duration = Duration::from_secs(10);

// --- host topology ---------------------------------------------------------

/// One `--hosts` entry: an agent endpoint plus the cores it contributes
/// to the layout. `endpoint` is `host`, `host:port`, or (for
/// `--transport uds`, agents on this machine) a socket path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostSpec {
    pub endpoint: String,
    pub cores: usize,
}

impl HostSpec {
    /// Parse one `endpoint:cores` entry. The cores count is the *last*
    /// `:`-separated field, so `host:port:cores` and `/path.sock:cores`
    /// both work.
    pub fn parse(s: &str) -> Result<HostSpec> {
        let s = s.trim();
        let (endpoint, cores) = s
            .rsplit_once(':')
            .with_context(|| format!("host spec {s:?} needs `endpoint:cores`"))?;
        let cores: usize = cores.trim().parse().with_context(|| {
            format!("host spec {s:?}: cores {cores:?} is not a positive integer")
        })?;
        ensure!(cores >= 1, "host spec {s:?} must offer at least 1 core");
        ensure!(!endpoint.trim().is_empty(), "host spec {s:?} has an empty endpoint");
        Ok(HostSpec {
            endpoint: endpoint.trim().to_string(),
            cores,
        })
    }

    /// Parse a comma-separated `--hosts` list.
    pub fn parse_list(s: &str) -> Result<Vec<HostSpec>> {
        let hosts: Vec<HostSpec> =
            s.split(',').map(HostSpec::parse).collect::<Result<_>>()?;
        ensure!(!hosts.is_empty(), "--hosts list is empty");
        Ok(hosts)
    }

    /// The address the coordinator dials for this host's agent under
    /// `transport` — TCP appends [`DEFAULT_AGENT_PORT`] when the entry
    /// carries no port; UDS uses the endpoint as a socket path.
    pub fn agent_addr(&self, transport: TransportKind) -> String {
        match transport {
            TransportKind::Tcp if !self.endpoint.contains(':') => {
                format!("{}:{DEFAULT_AGENT_PORT}", self.endpoint)
            }
            _ => self.endpoint.clone(),
        }
    }
}

/// First-fit packing of `n_envs` rank groups (each `ranks` cores, never
/// split across hosts) onto the offered core counts. Returns the host
/// index of each env; host 0 is the coordinator's host and fills first,
/// so the planner's "remote env" count is the tail of this vector.
pub fn place_rank_groups(
    host_cores: &[usize],
    n_envs: usize,
    ranks: usize,
) -> Result<Vec<usize>> {
    ensure!(!host_cores.is_empty(), "no hosts to place rank groups on");
    let mut free = host_cores.to_vec();
    let mut placement = Vec::with_capacity(n_envs);
    for env_id in 0..n_envs {
        let Some(h) = free.iter().position(|&f| f >= ranks) else {
            bail!(
                "host topology {host_cores:?} cannot hold env {env_id}: \
                 {n_envs} rank groups of {ranks} cores need more capacity \
                 (groups are never split across hosts)"
            );
        };
        free[h] -= ranks;
        placement.push(h);
    }
    Ok(placement)
}

// --- streams and listeners -------------------------------------------------

/// One established coordinator↔worker (or coordinator↔agent) socket.
/// TCP runs with `TCP_NODELAY`: frames are small and latency-bound, and
/// the writer flushes per frame exactly like the pipe transport.
pub(crate) enum NetStream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl NetStream {
    pub(crate) fn try_clone(&self) -> Result<NetStream> {
        Ok(match self {
            NetStream::Tcp(s) => NetStream::Tcp(s.try_clone().context("cloning tcp stream")?),
            NetStream::Uds(s) => NetStream::Uds(s.try_clone().context("cloning unix stream")?),
        })
    }

    /// Close both directions; a peer (or our own reader thread) blocked
    /// in `read` wakes with EOF.
    pub(crate) fn shutdown_both(&self) -> io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            NetStream::Uds(s) => s.shutdown(std::net::Shutdown::Both),
        }
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            NetStream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            NetStream::Uds(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            NetStream::Uds(s) => s.flush(),
        }
    }
}

/// A per-worker listener (local socket mode). The UDS variant unlinks
/// its socket file on drop so a work dir never accumulates stale
/// sockets.
pub(crate) enum NetListener {
    Tcp(TcpListener),
    Uds(UnixListener, PathBuf),
}

impl Drop for NetListener {
    fn drop(&mut self) {
        if let NetListener::Uds(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Bind the per-worker listener for a directly-spawned child and return
/// it with the `--connect` argument the child dials back on.
pub(crate) fn bind_worker_listener(
    transport: TransportKind,
    work_dir: &Path,
    env_id: usize,
    rank: usize,
    generation: u64,
) -> Result<(NetListener, String)> {
    match transport {
        TransportKind::Tcp => {
            let l = TcpListener::bind("127.0.0.1:0")
                .context("binding an ephemeral loopback port for a worker")?;
            let addr = l.local_addr().context("reading the bound worker port")?;
            Ok((NetListener::Tcp(l), format!("tcp:{addr}")))
        }
        TransportKind::Uds => {
            let path = work_dir.join(format!("net-env{env_id:03}-r{rank}-gen{generation}.sock"));
            let _ = std::fs::remove_file(&path);
            let l = UnixListener::bind(&path)
                .with_context(|| format!("binding worker socket {}", path.display()))?;
            let arg = format!("uds:{}", path.display());
            Ok((NetListener::Uds(l, path), arg))
        }
        other => bail!("transport {} has no socket listener", other.name()),
    }
}

/// Accept exactly one connection within `timeout` (the spawned worker
/// dials back immediately, before any environment setup).
pub(crate) fn accept_one(listener: &NetListener, timeout: Duration) -> Result<NetStream> {
    let deadline = Instant::now() + timeout;
    match listener {
        NetListener::Tcp(l) => l.set_nonblocking(true).context("listener nonblocking")?,
        NetListener::Uds(l, _) => l.set_nonblocking(true).context("listener nonblocking")?,
    }
    loop {
        let got = match listener {
            NetListener::Tcp(l) => l.accept().map(|(s, _)| NetStream::Tcp(s)),
            NetListener::Uds(l, _) => l.accept().map(|(s, _)| NetStream::Uds(s)),
        };
        match got {
            Ok(s) => {
                match &s {
                    NetStream::Tcp(t) => {
                        t.set_nonblocking(false).context("stream blocking")?;
                        let _ = t.set_nodelay(true);
                    }
                    NetStream::Uds(u) => u.set_nonblocking(false).context("stream blocking")?,
                }
                return Ok(s);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                ensure!(
                    Instant::now() < deadline,
                    "worker never connected back within {:.0?} (did the child start?)",
                    timeout
                );
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("accepting the worker connection"),
        }
    }
}

/// Dial `addr` under `transport` (`host:port` for TCP, a socket path for
/// UDS).
pub(crate) fn connect(transport: TransportKind, addr: &str) -> Result<NetStream> {
    match transport {
        TransportKind::Tcp => {
            let s = TcpStream::connect(addr)
                .with_context(|| format!("connecting tcp://{addr}"))?;
            let _ = s.set_nodelay(true);
            Ok(NetStream::Tcp(s))
        }
        TransportKind::Uds => {
            let s = UnixStream::connect(addr)
                .with_context(|| format!("connecting unix socket {addr}"))?;
            Ok(NetStream::Uds(s))
        }
        other => bail!("transport {} is not socket-based", other.name()),
    }
}

/// Parse a worker `--connect` argument (`tcp:host:port` / `uds:path`)
/// and dial it.
pub(crate) fn connect_arg(spec: &str) -> Result<NetStream> {
    if let Some(addr) = spec.strip_prefix("tcp:") {
        connect(TransportKind::Tcp, addr)
    } else if let Some(path) = spec.strip_prefix("uds:") {
        connect(TransportKind::Uds, path)
    } else {
        bail!("--connect {spec:?} must be tcp:host:port or uds:path")
    }
}

// --- inter-node latency calibration ----------------------------------------

/// Measure the socket round-trip time the way `process_calibration`
/// measures everything else: live, on this machine. A loopback
/// listener echoes Heartbeat frames; the mean of `reps` ping-pongs is
/// the [`Calibration::t_net_rtt`](crate::cluster::calib::Calibration)
/// term the DES charges each remote env per actuation period.
pub fn measure_rtt(transport: TransportKind, work_dir: &Path, reps: usize) -> Result<f64> {
    ensure!(transport.is_socket(), "rtt measurement needs tcp or uds");
    std::fs::create_dir_all(work_dir)
        .with_context(|| format!("creating {}", work_dir.display()))?;
    let (listener, arg) =
        bind_worker_listener(transport, work_dir, 999, 0, u64::from(std::process::id()))?;
    let echo = std::thread::Builder::new()
        .name("rtt-echo".into())
        .spawn(move || -> Result<()> {
            let mut s = accept_one(&listener, ACCEPT_TIMEOUT)?;
            while let Some(f) = wire::read_frame(&mut s)? {
                if matches!(f, Frame::Shutdown) {
                    break;
                }
                wire::write_frame(&mut s, &f)?;
            }
            Ok(())
        })
        .context("spawning rtt echo thread")?;
    let mut s = connect_arg(&arg)?;
    // warmup covers connection setup + first-touch costs
    for _ in 0..8 {
        wire::write_frame(&mut s, &Frame::Heartbeat)?;
        wire::read_frame(&mut s)?;
    }
    let reps = reps.max(1);
    let t0 = Instant::now();
    for _ in 0..reps {
        wire::write_frame(&mut s, &Frame::Heartbeat)?;
        wire::read_frame(&mut s)?;
    }
    let rtt = t0.elapsed().as_secs_f64() / reps as f64;
    let _ = wire::write_frame(&mut s, &Frame::Shutdown);
    let _ = echo.join();
    Ok(rtt)
}

// --- the drlfoam agent -----------------------------------------------------

/// Serve `drlfoam agent --bind <addr>` forever: accept coordinator
/// connections, expect a [`Frame::Spawn`] first on each, exec the
/// worker, relay bytes. `addr` containing a `/` is a UDS socket path,
/// anything else is a TCP `host:port` (bare `host` gets
/// [`DEFAULT_AGENT_PORT`]).
pub fn run_agent(bind: &str) -> Result<()> {
    let bin = std::env::current_exe().context("resolving the worker binary for self-exec")?;
    let uds = bind.contains('/');
    enum L {
        Tcp(TcpListener),
        Uds(UnixListener),
    }
    let listener = if uds {
        L::Uds(UnixListener::bind(bind).with_context(|| {
            format!(
                "drlfoam agent: binding {bind} failed — another agent already bound here? \
                 (a stale socket file from a crashed agent must be removed first)"
            )
        })?)
    } else {
        let addr = if bind.contains(':') {
            bind.to_string()
        } else {
            format!("{bind}:{DEFAULT_AGENT_PORT}")
        };
        L::Tcp(TcpListener::bind(&addr).with_context(|| {
            format!("drlfoam agent: binding {addr} failed — another agent already bound here?")
        })?)
    };
    // the readiness line scripts/tests wait for before connecting
    println!("agent listening on {bind}");
    io::stdout().flush().ok();
    loop {
        let conn = match &listener {
            L::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                NetStream::Tcp(s)
            }),
            L::Uds(l) => l.accept().map(|(s, _)| NetStream::Uds(s)),
        };
        match conn {
            Ok(stream) => {
                let bin = bin.clone();
                std::thread::Builder::new()
                    .name("agent-conn".into())
                    .spawn(move || {
                        if let Err(e) = serve_connection(stream, &bin) {
                            eprintln!("agent: connection failed: {e:#}");
                        }
                    })
                    .context("spawning agent connection thread")?;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("agent accept failed"),
        }
    }
}

/// One coordinator connection: read the `Spawn` spec, exec the worker,
/// relay until either side goes away. The worker is ALWAYS dead when
/// this returns — a vanished coordinator must not leave orphaned rank
/// groups holding cores.
fn serve_connection(mut stream: NetStream, bin: &Path) -> Result<()> {
    let frame = wire::read_frame(&mut stream)
        .context("reading the spawn frame")?
        .context("connection closed before a spawn frame")?;
    let Frame::Spawn {
        env_id,
        rank,
        seed,
        heartbeat_ms,
        scenario,
        variant,
        artifact_dir,
        work_dir,
        io_mode,
        backend,
        cfd_backend,
        fault_injection,
        trace,
    } = frame
    else {
        bail!("first frame on an agent connection must be Spawn, got {frame:?}");
    };
    std::fs::create_dir_all(&work_dir)
        .with_context(|| format!("creating worker work dir {work_dir}"))?;
    let mut cmd = std::process::Command::new(bin);
    cmd.arg("worker")
        .arg("--env-id")
        .arg(env_id.to_string())
        .arg("--rank")
        .arg(rank.to_string())
        .arg("--scenario")
        .arg(&scenario)
        .arg("--variant")
        .arg(&variant)
        .arg("--artifacts")
        .arg(&artifact_dir)
        .arg("--work-dir")
        .arg(&work_dir)
        .arg("--io")
        .arg(&io_mode)
        .arg("--backend")
        .arg(&backend)
        .arg("--cfd-backend")
        .arg(&cfd_backend)
        .arg("--seed")
        .arg(seed.to_string())
        .arg("--heartbeat-ms")
        .arg(heartbeat_ms.to_string());
    if trace != 0 {
        cmd.arg("--trace-spans");
    }
    cmd.stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::inherit());
    if !fault_injection.is_empty() {
        cmd.env("DRLFOAM_WORKER_CRASH", &fault_injection);
    }
    let mut child = cmd
        .spawn()
        .with_context(|| format!("agent: spawning worker env {env_id} rank {rank}"))?;
    let mut child_in = child.stdin.take().expect("piped stdin");
    let mut child_out = child.stdout.take().expect("piped stdout");
    let child = std::sync::Arc::new(std::sync::Mutex::new(child));
    let child_dn = std::sync::Arc::clone(&child);
    let mut sock_rd = stream.try_clone()?;
    // downstream: coordinator → worker stdin; EOF/error = coordinator
    // gone → reap the orphan
    let down = std::thread::Builder::new()
        .name(format!("agent-dn-{env_id}.{rank}"))
        .spawn(move || {
            let mut buf = [0u8; 16384];
            loop {
                match sock_rd.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => {
                        if child_in.write_all(&buf[..n]).and_then(|_| child_in.flush()).is_err() {
                            break;
                        }
                    }
                }
            }
            drop(child_in); // stdin EOF: the polite shutdown signal
            let mut c = child_dn.lock().expect("agent child mutex poisoned");
            let _ = c.kill();
        })
        .context("spawning agent downstream relay")?;
    // upstream: worker stdout → coordinator (this thread)
    let mut buf = [0u8; 16384];
    loop {
        match child_out.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                if stream.write_all(&buf[..n]).and_then(|_| stream.flush()).is_err() {
                    break;
                }
            }
        }
    }
    // worker stdout closed (or coordinator unreachable): tear everything
    // down — socket close tells the coordinator, kill+wait reaps the child
    let _ = stream.shutdown_both();
    {
        let mut c = child.lock().expect("agent child mutex poisoned");
        let _ = c.kill();
        let _ = c.wait();
    }
    let _ = down.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_spec_parses_host_port_and_path_forms() {
        assert_eq!(
            HostSpec::parse("localhost:4").unwrap(),
            HostSpec {
                endpoint: "localhost".into(),
                cores: 4
            }
        );
        assert_eq!(
            HostSpec::parse("node7:7801:12").unwrap(),
            HostSpec {
                endpoint: "node7:7801".into(),
                cores: 12
            }
        );
        assert_eq!(
            HostSpec::parse("/tmp/agent.sock:2").unwrap(),
            HostSpec {
                endpoint: "/tmp/agent.sock".into(),
                cores: 2
            }
        );
        let hosts = HostSpec::parse_list("localhost:2,localhost:7801:2").unwrap();
        assert_eq!(hosts.len(), 2);
        assert_eq!(hosts[1].endpoint, "localhost:7801");
        for bad in ["", "localhost", "host:0", "host:-1", "host:x", ":4"] {
            assert!(HostSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn agent_addr_defaults_the_tcp_port_only_when_missing() {
        let bare = HostSpec::parse("nodeA:4").unwrap();
        assert_eq!(
            bare.agent_addr(TransportKind::Tcp),
            format!("nodeA:{DEFAULT_AGENT_PORT}")
        );
        let with_port = HostSpec::parse("nodeA:7801:4").unwrap();
        assert_eq!(with_port.agent_addr(TransportKind::Tcp), "nodeA:7801");
        let sock = HostSpec::parse("/run/agent.sock:4").unwrap();
        assert_eq!(sock.agent_addr(TransportKind::Uds), "/run/agent.sock");
    }

    #[test]
    fn placement_is_first_fit_and_never_splits_groups() {
        // 2-core groups on 5+4 cores: host0 takes 2 groups, host1 takes 2
        assert_eq!(place_rank_groups(&[5, 4], 4, 2).unwrap(), vec![0, 0, 1, 1]);
        // exactly full
        assert_eq!(place_rank_groups(&[2, 2], 2, 2).unwrap(), vec![0, 1]);
        // a group never splits: 3+3 cores cannot hold two 4-rank groups
        let err = place_rank_groups(&[3, 3], 1, 4).unwrap_err().to_string();
        assert!(err.contains("never split"), "{err}");
        // capacity exhausted mid-way names the env that failed
        let err = place_rank_groups(&[2, 2], 3, 2).unwrap_err().to_string();
        assert!(err.contains("env 2"), "{err}");
    }

    #[test]
    fn connect_arg_rejects_unknown_schemes() {
        let err = connect_arg("ipc:/tmp/x").unwrap_err().to_string();
        assert!(err.contains("tcp:host:port"), "{err}");
    }

    #[test]
    fn loopback_rtt_measures_positive_and_finite() {
        let dir = std::env::temp_dir().join(format!("drlfoam-rtt-{}", std::process::id()));
        for t in [TransportKind::Tcp, TransportKind::Uds] {
            let rtt = measure_rtt(t, &dir, 16).unwrap();
            assert!(rtt.is_finite() && rtt > 0.0, "{t:?} rtt {rtt}");
            assert!(rtt < 1.0, "{t:?} loopback rtt implausibly slow: {rtt}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_listener_roundtrips_a_frame_and_cleans_up_uds_files() {
        let dir = std::env::temp_dir().join(format!("drlfoam-lst-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for t in [TransportKind::Tcp, TransportKind::Uds] {
            let (listener, arg) = bind_worker_listener(t, &dir, 0, 0, 1).unwrap();
            let dial = arg.clone();
            let peer = std::thread::spawn(move || {
                let mut s = connect_arg(&dial).unwrap();
                wire::write_frame(&mut s, &Frame::Heartbeat).unwrap();
                wire::read_frame(&mut s).unwrap()
            });
            let mut s = accept_one(&listener, Duration::from_secs(5)).unwrap();
            assert_eq!(
                wire::read_frame(&mut s).unwrap().unwrap(),
                Frame::Heartbeat
            );
            wire::write_frame(&mut s, &Frame::Shutdown).unwrap();
            assert_eq!(peer.join().unwrap().unwrap(), Frame::Shutdown);
            drop(listener);
        }
        // the UDS listener unlinked its socket file on drop
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "sock"))
            .collect();
        assert!(leftovers.is_empty(), "stale sockets: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
