//! Multi-process execution backend: one `drlfoam worker` OS process per
//! rank, spawned by self-exec, driven over the [`super::wire`] protocol.
//!
//! Per environment the executor owns a *rank group*: the rank-0 primary
//! (runs episodes / the lockstep protocol) plus `ranks_per_env - 1`
//! placement ranks that only hold their core and heartbeat — the
//! process-tree shape of the paper's `N_envs × N_ranks` allocation. A
//! reader thread per process decodes worker frames into one event
//! channel; heartbeats stamp a shared liveness clock.
//!
//! Fault handling (per-env rollout mode):
//!
//! ```text
//!            ┌──────────── Episode frame ────────────┐
//!            ▼                                       │
//!   idle ── dispatch ──► in-flight ──► done ──► (re-dispatch)
//!            │                │ EOF / EPIPE / heartbeat timeout
//!            │                ▼
//!            │           respawn worker (restart counted)
//!            │                │ replay SetParams + identical Rollout
//!            └────────────────┘
//! ```
//!
//! A re-queued episode carries the same `(episode, seed)` pair, so the
//! replay is bitwise identical to the lost attempt and recovery does not
//! perturb the learning curve. The lockstep (batched-inference) protocol
//! completes its dispatch set together and has no per-episode unit to
//! re-queue: a death mid-lockstep is a clean, contextual error instead.
//!
//! Transport (`--transport pipe|shm`): the pipe is always the control
//! channel. Under `shm` each rank-0 worker additionally gets a pair of
//! generation-keyed seqlock rings ([`super::shm`]) for the data frames —
//! `Step` out, `Obs`/`StepOut`/`Episode` back. The worker acks the rings
//! in its `Hello` (`shm: 1`); until then — or forever, if mapping failed
//! on either side — every frame stays on the pipe. Both receive paths
//! accept frames from both channels at all times, so mixed delivery is
//! always correct. On respawn the replacement worker gets *fresh* ring
//! files (new generation), so no stale ring state can leak into a
//! recovered run.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::pool::{EpisodeOut, PoolConfig};
use crate::exec::net::{self, HostSpec, NetStream};
use crate::exec::wire::{self, Frame, PROTOCOL_VERSION};
use crate::exec::{shm, Executor, Job, LockstepReply, TransportKind};
use crate::obs;

/// Clock probes sent to each freshly (re)spawned rank-0 worker when
/// tracing is on; the min-RTT echo wins (ARCHITECTURE.md §12).
const CLOCK_PROBES: usize = 5;

/// How often a blocked receive wakes to re-check worker liveness.
const LIVENESS_POLL: Duration = Duration::from_millis(250);

/// Heartbeat period workers are spawned with (`worker --heartbeat-ms`).
pub(crate) const HEARTBEAT_MS: u64 = 200;

/// A worker silent for longer than this is declared hung and killed for
/// respawn (override with `DRLFOAM_WORKER_TIMEOUT_S`; generous because a
/// cylinder-scenario worker's first episode includes artifact
/// compilation).
const DEFAULT_TIMEOUT_S: f64 = 30.0;

/// Crash-loop guard: a worker that dies this many times in a row without
/// completing an episode is not a transient fault (e.g. setup fails
/// identically on every respawn) — give up and surface the root cause.
const MAX_CONSECUTIVE_RESTARTS: usize = 3;

/// Reader-thread → executor event stream (one channel for all workers).
enum Event {
    Episode(EpisodeOut),
    Lockstep(LockstepReply),
    /// Terminal worker-side failure (setup or episode error).
    WorkerError { env_id: usize, msg: String },
    /// A worker's stdout reached EOF: the process is gone. `generation`
    /// guards against stale reports for an already-replaced worker;
    /// `rank` distinguishes the episode-running primary (recovered via
    /// re-queue) from placement ranks (respawned in place).
    Died {
        env_id: usize,
        rank: usize,
        generation: u64,
    },
}

/// Coordinator end of one worker's shm data plane (rank 0, `--transport
/// shm`, ring creation succeeded).
struct RingLink {
    /// coordinator → worker ring (actions).
    tx_ring: shm::Producer,
    /// Set by the reader thread when the worker's `Hello` acks the rings
    /// (`shm: 1`). Gates only our *send* side — the worker may fall back
    /// to the pipe unilaterally, and frames are accepted from both
    /// channels regardless.
    shm_active: Arc<AtomicBool>,
    /// Tells the detached ring-reader thread to exit (respawn/shutdown).
    stop: Arc<AtomicBool>,
    /// Ring files, for cleanup — each generation gets fresh ones.
    prefix: PathBuf,
}

impl RingLink {
    fn teardown(&self) {
        self.stop.store(true, Ordering::Release);
        let (c2w, w2c) = shm::ring_paths(&self.prefix);
        let _ = std::fs::remove_file(c2w);
        let _ = std::fs::remove_file(w2c);
    }
}

/// The coordinator→worker frame channel: the child's stdin pipe, or a
/// socket clone under `--transport tcp|uds`.
enum WorkerWriter {
    Pipe(ChildStdin),
    Net(NetStream),
}

impl Write for WorkerWriter {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            WorkerWriter::Pipe(w) => w.write(buf),
            WorkerWriter::Net(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            WorkerWriter::Pipe(w) => w.flush(),
            WorkerWriter::Net(s) => s.flush(),
        }
    }
}

/// How the coordinator ends a worker's life. Directly-spawned children
/// (pipe/shm transports, and the socket transports without `--hosts`)
/// are killed and reaped as OS children; agent-spawned workers have no
/// local `Child` — closing the connection makes the agent kill and reap
/// them on its host, and our reader's EOF feeds the same `Died` path.
enum WorkerHandle {
    Local(Child),
    Remote(NetStream),
}

impl WorkerHandle {
    fn kill(&mut self) -> io::Result<()> {
        match self {
            WorkerHandle::Local(c) => c.kill(),
            WorkerHandle::Remote(s) => s.shutdown_both(),
        }
    }

    fn kill_and_reap(&mut self) {
        match self {
            WorkerHandle::Local(c) => {
                let _ = c.kill();
                let _ = c.wait();
            }
            WorkerHandle::Remote(s) => {
                let _ = s.shutdown_both();
            }
        }
    }
}

struct ChildProc {
    handle: WorkerHandle,
    /// `None` once shutdown closed the channel.
    writer: Option<WorkerWriter>,
    /// 0 for agent-spawned workers (the pid lives on the remote host).
    pid: u32,
    generation: u64,
    last_seen: Arc<Mutex<Instant>>,
    /// Shm data plane (rank-0 + `--transport shm` only).
    ring: Option<RingLink>,
}

struct RankGroup {
    primary: ChildProc,
    secondaries: Vec<ChildProc>,
}

/// Everything needed to (re)spawn one worker process.
struct SpawnSpec {
    bin: PathBuf,
    artifact_dir: PathBuf,
    work_dir: PathBuf,
    variant: String,
    scenario: String,
    backend: &'static str,
    cfd_backend: &'static str,
    io_mode: &'static str,
    seed: u64,
    fault_injection: Option<String>,
    transport: TransportKind,
    /// Agent endpoints (`--hosts`); empty = spawn children directly.
    hosts: Vec<HostSpec>,
    /// First-fit rank-group placement: `host_of_env[env_id]` indexes
    /// `hosts`. Empty when `hosts` is.
    host_of_env: Vec<usize>,
    /// Spawn workers with `--trace-spans` (obs tracing on).
    trace: bool,
}

/// The rollout a worker currently owes us; replayed verbatim on respawn.
#[derive(Clone)]
struct InflightRollout {
    params: Arc<Vec<f32>>,
    horizon: usize,
    episode: u64,
    episode_seed: u64,
}

/// Process-backed worker set (see module docs).
pub(crate) struct ProcessExecutor {
    spec: SpawnSpec,
    groups: Vec<RankGroup>,
    tx: Sender<Event>,
    rx: Receiver<Event>,
    /// episodes that arrived while waiting for lockstep replies
    pending: VecDeque<EpisodeOut>,
    inflight: Vec<Option<InflightRollout>>,
    restarts: Vec<usize>,
    /// respawns since this env's last completed episode (crash-loop guard)
    consecutive_restarts: Vec<usize>,
    next_generation: u64,
    /// true while the pool drives the lockstep (batched) protocol —
    /// faults are then terminal instead of recoverable
    lockstep: bool,
    timeout: Duration,
}

impl ProcessExecutor {
    pub(crate) fn spawn(cfg: &PoolConfig) -> Result<ProcessExecutor> {
        anyhow::ensure!(cfg.ranks_per_env >= 1, "ranks_per_env must be >= 1");
        let bin = match &cfg.worker_bin {
            Some(p) => p.clone(),
            None => std::env::current_exe()
                .context("resolving the worker binary for self-exec")?,
        };
        // the work dir is shared state (exchange files, chaos tombstones)
        std::fs::create_dir_all(&cfg.work_dir)
            .with_context(|| format!("creating {}", cfg.work_dir.display()))?;
        // chaos tombstones are one-shot *per run*: clear leftovers from a
        // previous run in a reused work dir, or --chaos would silently
        // inject nothing the second time
        if cfg.fault_injection.is_some() {
            if let Ok(entries) = std::fs::read_dir(&cfg.work_dir) {
                for e in entries.flatten() {
                    let name = e.file_name();
                    let name = name.to_string_lossy();
                    if name.starts_with("chaos-") && name.ends_with(".tombstone") {
                        let _ = std::fs::remove_file(e.path());
                    }
                }
            }
        }
        let host_of_env = if cfg.hosts.is_empty() {
            Vec::new()
        } else {
            anyhow::ensure!(
                cfg.transport.is_socket(),
                "--hosts spans machines over sockets; use --transport tcp or uds \
                 (got {})",
                cfg.transport.name()
            );
            let cores: Vec<usize> = cfg.hosts.iter().map(|h| h.cores).collect();
            net::place_rank_groups(&cores, cfg.n_envs, cfg.ranks_per_env)?
        };
        let spec = SpawnSpec {
            bin,
            artifact_dir: cfg.artifact_dir.clone(),
            work_dir: cfg.work_dir.clone(),
            variant: cfg.variant.clone(),
            scenario: cfg.scenario.clone(),
            backend: cfg.backend.name(),
            cfd_backend: cfg.cfd_backend.name(),
            io_mode: cfg.io_mode.name(),
            seed: cfg.seed,
            fault_injection: cfg.fault_injection.clone(),
            transport: cfg.transport,
            hosts: cfg.hosts.clone(),
            host_of_env,
            trace: cfg.trace,
        };
        if cfg.trace {
            // Perfetto lane map: pid 0 = this (coordinator) host, agent
            // hosts count from 1 in --hosts order
            for env_id in 0..cfg.n_envs {
                let (host, label) = if spec.host_of_env.is_empty() {
                    (0, "local".to_string())
                } else {
                    let h = spec.host_of_env[env_id];
                    (h as u32 + 1, spec.hosts[h].endpoint.clone())
                };
                obs::set_env_host(env_id as u32, host, &label);
            }
        }
        let timeout =
            parse_worker_timeout(std::env::var("DRLFOAM_WORKER_TIMEOUT_S").ok().as_deref())?;
        let (tx, rx) = channel();
        let mut groups = Vec::with_capacity(cfg.n_envs);
        let mut next_generation = 0u64;
        for env_id in 0..cfg.n_envs {
            next_generation += 1;
            let mut primary = spawn_child(&spec, env_id, 0, next_generation, &tx)?;
            if spec.trace {
                send_clock_probes(&mut primary);
            }
            let mut secondaries = Vec::with_capacity(cfg.ranks_per_env - 1);
            for rank in 1..cfg.ranks_per_env {
                next_generation += 1;
                secondaries.push(spawn_child(&spec, env_id, rank, next_generation, &tx)?);
            }
            groups.push(RankGroup {
                primary,
                secondaries,
            });
        }
        Ok(ProcessExecutor {
            spec,
            groups,
            tx,
            rx,
            pending: VecDeque::new(),
            inflight: vec![None; cfg.n_envs],
            restarts: vec![0; cfg.n_envs],
            consecutive_restarts: vec![0; cfg.n_envs],
            next_generation,
            lockstep: false,
            timeout,
        })
    }

    /// Send a *data* frame: over the shm ring when the worker has acked
    /// it, over the pipe otherwise (including per-frame fallback when a
    /// frame outgrows a ring slot). Control frames use [`Self::write_plain`]
    /// directly.
    fn write_data(&mut self, env_id: usize, frame: &Frame) -> Result<()> {
        let timeout = self.timeout;
        let sent = {
            let g = &mut self.groups[env_id].primary;
            match g.ring.as_mut() {
                Some(link) if link.shm_active.load(Ordering::Acquire) => {
                    let body = wire::encode(frame);
                    link.tx_ring
                        .push(&body, timeout)
                        .with_context(|| format!("shm push to env worker {env_id}"))?
                }
                _ => false,
            }
        };
        if sent {
            Ok(())
        } else {
            self.write_plain(env_id, frame)
        }
    }

    fn write_plain(&mut self, env_id: usize, frame: &Frame) -> Result<()> {
        let _g = obs::span(obs::Phase::WireSend);
        let g = &mut self.groups[env_id].primary;
        let w = g
            .writer
            .as_mut()
            .with_context(|| format!("env worker {env_id} channel already closed"))?;
        wire::write_frame(w, frame)
            .with_context(|| format!("sending to env worker {env_id} (pid {})", g.pid))
    }

    /// SetParams followed by the Rollout frame. Params are re-sent on
    /// every dispatch: the scheduler builds a fresh vector per update
    /// round anyway, the bytes are negligible next to an episode, and an
    /// unconditional send means a respawned worker needs no
    /// cache-invalidation reasoning to replay correctly.
    fn write_rollout(&mut self, env_id: usize, fl: &InflightRollout) -> Result<()> {
        self.write_plain(
            env_id,
            &Frame::SetParams {
                params: (*fl.params).clone(),
            },
        )?;
        self.write_plain(
            env_id,
            &Frame::Rollout {
                horizon: fl.horizon as u32,
                episode: fl.episode,
                episode_seed: fl.episode_seed,
            },
        )
    }

    /// Respawn `env_id`'s primary rank and replay its in-flight episode,
    /// if any (identical `(episode, seed)` → bitwise-identical replay).
    fn revive(&mut self, env_id: usize, why: &str) -> Result<()> {
        anyhow::ensure!(
            !self.lockstep,
            "env worker {env_id} died mid-lockstep ({why}); the batched lockstep \
             protocol has no per-episode unit to re-queue — rerun with \
             --inference per-env for fault recovery"
        );
        if self.consecutive_restarts[env_id] >= MAX_CONSECUTIVE_RESTARTS {
            // not transient: dying workers report the root cause in a
            // terminal Error frame just before exiting — give their
            // readers a moment to deliver it, then fail with it
            let deadline = Instant::now() + Duration::from_secs(1);
            while Instant::now() < deadline {
                match self.rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(Event::WorkerError { env_id: e, msg }) => {
                        bail!("env worker {e} failed: {msg}")
                    }
                    Ok(Event::Episode(out)) => {
                        self.inflight[out.env_id] = None;
                        self.pending.push_back(out);
                    }
                    Ok(_) => {}
                    Err(_) => break,
                }
            }
            bail!(
                "env worker {env_id} died {MAX_CONSECUTIVE_RESTARTS} times without \
                 completing an episode ({why}); giving up"
            );
        }
        self.consecutive_restarts[env_id] += 1;
        let old_pid = {
            let g = &mut self.groups[env_id].primary;
            if let Some(link) = g.ring.take() {
                link.teardown(); // stop the ring reader, unlink the files
            }
            g.handle.kill_and_reap();
            g.pid
        };
        self.next_generation += 1;
        let mut fresh = spawn_child(&self.spec, env_id, 0, self.next_generation, &self.tx)?;
        if self.spec.trace {
            send_clock_probes(&mut fresh);
            obs::event(obs::Phase::Respawn, env_id as u32);
        }
        eprintln!(
            "warning: env worker {env_id} {why}; respawned (pid {old_pid} -> {})",
            fresh.pid
        );
        self.groups[env_id].primary = fresh;
        self.restarts[env_id] += 1;
        if let Some(fl) = self.inflight[env_id].clone() {
            self.write_rollout(env_id, &fl)
                .context("re-queueing the lost episode on the respawned worker")?;
        }
        Ok(())
    }

    fn on_death(&mut self, env_id: usize, rank: usize, generation: u64) -> Result<()> {
        if rank > 0 {
            return self.revive_secondary(env_id, generation);
        }
        if self.groups[env_id].primary.generation != generation {
            return Ok(()); // stale report about an already-replaced worker
        }
        self.revive(env_id, "exited unexpectedly")
    }

    /// Placement ranks carry no episode state: a dead one is respawned
    /// in place so the rank group keeps holding its claimed cores. This
    /// is never terminal (not even mid-lockstep) but IS counted — the
    /// group's placement was briefly broken, and workers.csv should say
    /// so.
    fn revive_secondary(&mut self, env_id: usize, generation: u64) -> Result<()> {
        let Some(idx) = self.groups[env_id]
            .secondaries
            .iter()
            .position(|s| s.generation == generation)
        else {
            return Ok(()); // stale report about an already-replaced rank
        };
        let rank = idx + 1;
        let old_pid = {
            let s = &mut self.groups[env_id].secondaries[idx];
            s.handle.kill_and_reap();
            s.pid
        };
        self.next_generation += 1;
        let fresh = spawn_child(&self.spec, env_id, rank, self.next_generation, &self.tx)?;
        if self.spec.trace {
            obs::event(obs::Phase::Respawn, env_id as u32);
        }
        eprintln!(
            "warning: placement rank {rank} of env {env_id} exited; \
             respawned (pid {old_pid} -> {})",
            fresh.pid
        );
        self.groups[env_id].secondaries[idx] = fresh;
        self.restarts[env_id] += 1;
        Ok(())
    }

    /// A failed send usually means the worker just died; its terminal
    /// `Error` frame — the root cause — may already be in the event
    /// channel. Prefer it over a bare broken-pipe error (the process
    /// analogue of the in-process backend's `closed_reason`). Episodes
    /// met while draining are kept, never dropped.
    fn send_failure(&mut self, err: anyhow::Error) -> anyhow::Error {
        let deadline = Instant::now() + Duration::from_millis(500);
        while Instant::now() < deadline {
            match self.rx.recv_timeout(Duration::from_millis(50)) {
                Ok(Event::WorkerError { env_id, msg }) => {
                    return anyhow::anyhow!("env worker {env_id} failed: {msg}");
                }
                Ok(Event::Episode(out)) => {
                    self.inflight[out.env_id] = None;
                    self.consecutive_restarts[out.env_id] = 0;
                    self.pending.push_back(out);
                }
                Ok(_) => {}
                Err(_) => break,
            }
        }
        err
    }

    /// Kill any primary whose heartbeat went silent past the timeout;
    /// the reader's EOF then raises [`Event::Died`], which re-queues and
    /// respawns through the normal path.
    fn check_liveness(&mut self) -> Result<()> {
        for (env_id, g) in self.groups.iter_mut().enumerate() {
            let mut seen = g.primary.last_seen.lock().expect("liveness clock poisoned");
            if seen.elapsed() > self.timeout {
                eprintln!(
                    "warning: env worker {env_id} (pid {}) silent for {:.1}s; killing for respawn",
                    g.primary.pid,
                    seen.elapsed().as_secs_f64()
                );
                *seen = Instant::now(); // don't re-kill every poll tick
                drop(seen);
                let _ = g.primary.handle.kill();
            }
        }
        Ok(())
    }
}

impl Executor for ProcessExecutor {
    fn n_envs(&self) -> usize {
        self.groups.len()
    }

    fn send(&mut self, env_id: usize, job: Job) -> Result<()> {
        match job {
            Job::Rollout {
                params,
                horizon,
                episode,
                episode_seed,
            } => {
                self.lockstep = false;
                let fl = InflightRollout {
                    params,
                    horizon,
                    episode,
                    episode_seed,
                };
                self.inflight[env_id] = Some(fl.clone());
                if let Err(e) = self.write_rollout(env_id, &fl) {
                    // broken pipe: the worker died while idle — respawn
                    // now; revive() replays the rollout just recorded
                    self.revive(env_id, &format!("dispatch failed ({e:#})"))?;
                }
                Ok(())
            }
            Job::Reset => {
                self.lockstep = true;
                self.write_plain(env_id, &Frame::Reset)
                    .map_err(|e| self.send_failure(e))
            }
            Job::Step { action } => {
                self.lockstep = true;
                self.write_data(env_id, &Frame::Step { action })
                    .map_err(|e| self.send_failure(e))
            }
            Job::Shutdown => {
                let _ = self.write_plain(env_id, &Frame::Shutdown);
                Ok(())
            }
        }
    }

    fn recv_episode(&mut self) -> Result<EpisodeOut> {
        loop {
            if let Some(out) = self.pending.pop_front() {
                return Ok(out);
            }
            match self.rx.recv_timeout(LIVENESS_POLL) {
                Ok(Event::Episode(out)) => {
                    self.inflight[out.env_id] = None;
                    self.consecutive_restarts[out.env_id] = 0;
                    return Ok(out);
                }
                Ok(Event::Lockstep(_)) => {
                    bail!("lockstep reply while waiting for an episode (protocol violation)")
                }
                Ok(Event::WorkerError { env_id, msg }) => {
                    bail!("env worker {env_id} failed: {msg}")
                }
                Ok(Event::Died {
                    env_id,
                    rank,
                    generation,
                }) => self.on_death(env_id, rank, generation)?,
                Err(RecvTimeoutError::Timeout) => self.check_liveness()?,
                Err(RecvTimeoutError::Disconnected) => bail!("all worker processes died"),
            }
        }
    }

    fn try_recv_episode(&mut self) -> Result<Option<EpisodeOut>> {
        loop {
            if let Some(out) = self.pending.pop_front() {
                return Ok(Some(out));
            }
            match self.rx.try_recv() {
                Ok(Event::Episode(out)) => {
                    self.inflight[out.env_id] = None;
                    self.consecutive_restarts[out.env_id] = 0;
                    return Ok(Some(out));
                }
                Ok(Event::Lockstep(_)) => {
                    bail!("lockstep reply while waiting for an episode (protocol violation)")
                }
                Ok(Event::WorkerError { env_id, msg }) => {
                    bail!("env worker {env_id} failed: {msg}")
                }
                Ok(Event::Died {
                    env_id,
                    rank,
                    generation,
                }) => self.on_death(env_id, rank, generation)?,
                Err(TryRecvError::Empty) => return Ok(None),
                Err(TryRecvError::Disconnected) => bail!("all worker processes died"),
            }
        }
    }

    fn recv_lockstep(&mut self) -> Result<LockstepReply> {
        loop {
            match self.rx.recv_timeout(LIVENESS_POLL) {
                Ok(Event::Lockstep(r)) => return Ok(r),
                // episodes can land while the scheduler switches between
                // per-env rounds and a lockstep set; keep them
                Ok(Event::Episode(out)) => {
                    self.inflight[out.env_id] = None;
                    self.consecutive_restarts[out.env_id] = 0;
                    self.pending.push_back(out);
                }
                Ok(Event::WorkerError { env_id, msg }) => {
                    bail!("env worker {env_id} failed: {msg}")
                }
                // lockstep is active, so this is terminal (revive() bails
                // with the mid-lockstep explanation)
                Ok(Event::Died {
                    env_id,
                    rank,
                    generation,
                }) => self.on_death(env_id, rank, generation)?,
                Err(RecvTimeoutError::Timeout) => self.check_liveness()?,
                Err(RecvTimeoutError::Disconnected) => bail!("all worker processes died"),
            }
        }
    }

    fn restarts(&self) -> usize {
        self.restarts.iter().sum()
    }

    fn restarts_by_env(&self) -> Vec<usize> {
        self.restarts.clone()
    }

    fn worker_pids(&self) -> Vec<u32> {
        self.groups
            .iter()
            .flat_map(|g| {
                std::iter::once(g.primary.pid).chain(g.secondaries.iter().map(|s| s.pid))
            })
            .collect()
    }

    fn kill_worker(&mut self, env_id: usize) -> Result<()> {
        anyhow::ensure!(env_id < self.groups.len(), "env id {env_id} out of range");
        // Local children die by SIGKILL; agent-spawned workers die by
        // connection-kill (the agent reaps them on its host) — both
        // surface as the same reader EOF → Died → respawn path.
        self.groups[env_id]
            .primary
            .handle
            .kill()
            .with_context(|| format!("killing env worker {env_id}"))
    }
}

impl Drop for ProcessExecutor {
    fn drop(&mut self) {
        // polite first: Shutdown frame + channel close...
        for g in &mut self.groups {
            for c in std::iter::once(&mut g.primary).chain(g.secondaries.iter_mut()) {
                if let Some(mut w) = c.writer.take() {
                    let _ = wire::write_frame(&mut w, &Frame::Shutdown);
                } // dropping w closes the pipe (the reader clone keeps a socket open)
                if let Some(link) = c.ring.take() {
                    link.teardown();
                }
            }
        }
        // ...then a bounded wait, then SIGKILL for stragglers. Remote
        // (agent-spawned) workers have no local child to wait on:
        // closing the connection makes the agent kill and reap them.
        let deadline = Instant::now() + Duration::from_secs(2);
        for g in &mut self.groups {
            for c in std::iter::once(&mut g.primary).chain(g.secondaries.iter_mut()) {
                match &mut c.handle {
                    WorkerHandle::Local(child) => loop {
                        match child.try_wait() {
                            Ok(Some(_)) => break,
                            Ok(None) if Instant::now() < deadline => {
                                std::thread::sleep(Duration::from_millis(10))
                            }
                            _ => {
                                let _ = child.kill();
                                let _ = child.wait();
                                break;
                            }
                        }
                    },
                    WorkerHandle::Remote(conn) => {
                        let _ = conn.shutdown_both();
                    }
                }
            }
        }
    }
}

/// Validate `DRLFOAM_WORKER_TIMEOUT_S`. Unset means the default; a set
/// value must be a finite number of seconds > 0 — anything else is a
/// startup error, because an override that silently fell back to the
/// default would defeat the point of setting it.
pub(crate) fn parse_worker_timeout(raw: Option<&str>) -> Result<Duration> {
    let Some(raw) = raw else {
        return Ok(Duration::from_secs_f64(DEFAULT_TIMEOUT_S));
    };
    let secs: f64 = raw.trim().parse().map_err(|_| {
        anyhow::anyhow!("DRLFOAM_WORKER_TIMEOUT_S={raw:?} is not a number (want seconds > 0)")
    })?;
    anyhow::ensure!(
        secs.is_finite() && secs > 0.0,
        "DRLFOAM_WORKER_TIMEOUT_S={raw:?} must be a finite number of seconds > 0"
    );
    Ok(Duration::from_secs_f64(secs))
}

/// Clock-offset handshake: fire a burst of probe frames at a freshly
/// (re)spawned rank-0 worker. Each probe carries the coordinator's clock;
/// the worker echoes it with its own, and the reader thread keeps the
/// offset from the minimum-RTT exchange ([`obs::record_probe_echo`]).
/// Best-effort — a worker that dies here is caught by the normal paths.
fn send_clock_probes(proc_: &mut ChildProc) {
    let Some(w) = proc_.writer.as_mut() else {
        return;
    };
    for _ in 0..CLOCK_PROBES {
        let probe = Frame::Telemetry {
            env_id: 0,
            rank: 0,
            kind: 1,
            clock_us: obs::now_us(),
            echo_us: 0,
            spans: Vec::new(),
        };
        if wire::write_frame(w, &probe).is_err() {
            return;
        }
    }
}

/// The shared `drlfoam worker` argv (everything but transport wiring).
fn worker_command(spec: &SpawnSpec, env_id: usize, rank: usize) -> Command {
    let mut cmd = Command::new(&spec.bin);
    cmd.arg("worker")
        .arg("--env-id")
        .arg(env_id.to_string())
        .arg("--rank")
        .arg(rank.to_string())
        .arg("--scenario")
        .arg(&spec.scenario)
        .arg("--variant")
        .arg(&spec.variant)
        .arg("--artifacts")
        .arg(&spec.artifact_dir)
        .arg("--work-dir")
        .arg(&spec.work_dir)
        .arg("--io")
        .arg(spec.io_mode)
        .arg("--backend")
        .arg(spec.backend)
        .arg("--cfd-backend")
        .arg(spec.cfd_backend)
        .arg("--seed")
        .arg(spec.seed.to_string())
        .arg("--heartbeat-ms")
        .arg(HEARTBEAT_MS.to_string());
    if spec.trace {
        cmd.arg("--trace-spans");
    }
    if let Some(f) = &spec.fault_injection {
        cmd.env("DRLFOAM_WORKER_CRASH", f);
    }
    cmd
}

/// Spawn one worker behind a socket (`--transport tcp|uds`): directly,
/// with a per-worker loopback listener the child dials back on, or via
/// the host's `drlfoam agent` when `--hosts` placed this env remotely.
/// Either way the frames flow over one stream and the pipe reader loop
/// is reused verbatim — socket EOF and pipe EOF are the same `Died`.
fn spawn_child_socket(
    spec: &SpawnSpec,
    env_id: usize,
    rank: usize,
    generation: u64,
    tx: &Sender<Event>,
) -> Result<ChildProc> {
    let (handle, stream, pid) = if spec.hosts.is_empty() {
        let (listener, connect) =
            net::bind_worker_listener(spec.transport, &spec.work_dir, env_id, rank, generation)?;
        let mut cmd = worker_command(spec, env_id, rank);
        cmd.arg("--connect")
            .arg(&connect)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit());
        let mut child = cmd.spawn().with_context(|| {
            format!(
                "spawning worker env {env_id} rank {rank} via {}",
                spec.bin.display()
            )
        })?;
        let pid = child.id();
        let stream = match net::accept_one(&listener, net::ACCEPT_TIMEOUT) {
            Ok(s) => s,
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(e)
                    .with_context(|| format!("env worker {env_id} rank {rank} ({connect})"));
            }
        };
        (WorkerHandle::Local(child), stream, pid)
    } else {
        let host = &spec.hosts[spec.host_of_env[env_id]];
        let addr = host.agent_addr(spec.transport);
        let mut stream = net::connect(spec.transport, &addr)
            .with_context(|| format!("dialing agent {addr} for env {env_id} rank {rank}"))?;
        wire::write_frame(
            &mut stream,
            &Frame::Spawn {
                env_id: env_id as u32,
                rank: rank as u32,
                seed: spec.seed,
                heartbeat_ms: HEARTBEAT_MS,
                scenario: spec.scenario.clone(),
                variant: spec.variant.clone(),
                artifact_dir: spec.artifact_dir.display().to_string(),
                work_dir: spec.work_dir.display().to_string(),
                io_mode: spec.io_mode.to_string(),
                backend: spec.backend.to_string(),
                cfd_backend: spec.cfd_backend.to_string(),
                fault_injection: spec.fault_injection.clone().unwrap_or_default(),
                trace: spec.trace as u8,
            },
        )
        .with_context(|| format!("sending the spawn spec to agent {addr}"))?;
        // the worker's pid lives on the agent's host — 0 locally
        (WorkerHandle::Remote(stream.try_clone()?), stream, 0)
    };
    let writer = WorkerWriter::Net(stream.try_clone()?);
    let last_seen = Arc::new(Mutex::new(Instant::now()));
    let shm_active = Arc::new(AtomicBool::new(false));
    let txc = tx.clone();
    let seen = Arc::clone(&last_seen);
    let active = Arc::clone(&shm_active);
    let gone = Arc::new(AtomicBool::new(false));
    std::thread::Builder::new()
        .name(format!("exec-read-{env_id}.{rank}"))
        .spawn(move || reader_loop(env_id, rank, generation, stream, txc, seen, active, gone, false))
        .context("spawning worker reader thread")?;
    Ok(ChildProc {
        handle,
        writer: Some(writer),
        pid,
        generation,
        last_seen,
        ring: None,
    })
}

fn spawn_child(
    spec: &SpawnSpec,
    env_id: usize,
    rank: usize,
    generation: u64,
    tx: &Sender<Event>,
) -> Result<ChildProc> {
    if spec.transport.is_socket() {
        return spawn_child_socket(spec, env_id, rank, generation, tx);
    }
    // Shm transport: create this generation's ring pair up front so the
    // worker can map it at startup. Failure is never fatal — warn and
    // run this worker on the pipe alone.
    let mut rings: Option<(shm::Producer, shm::Consumer, PathBuf)> = None;
    if rank == 0 && spec.transport == TransportKind::Shm {
        let prefix = spec
            .work_dir
            .join(format!("shm-env{env_id:03}-gen{generation}"));
        let (c2w, w2c) = shm::ring_paths(&prefix);
        let made = shm::create(&c2w, shm::DATA_SLOTS, shm::DATA_PAYLOAD)
            .and_then(|_| shm::create(&w2c, shm::DATA_SLOTS, shm::DATA_PAYLOAD))
            .and_then(|_| Ok((shm::producer(&c2w)?, shm::consumer(&w2c)?)));
        match made {
            Ok((p, c)) => rings = Some((p, c, prefix)),
            Err(e) => eprintln!(
                "warning: shm ring setup for env {env_id} failed ({e:#}); \
                 falling back to the pipe transport for this worker"
            ),
        }
    }
    let mut cmd = worker_command(spec, env_id, rank);
    cmd.stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    if let Some((_, _, prefix)) = &rings {
        cmd.arg("--shm-prefix").arg(prefix);
    }
    let mut child = cmd.spawn().with_context(|| {
        format!(
            "spawning worker env {env_id} rank {rank} via {}",
            spec.bin.display()
        )
    })?;
    let pid = child.id();
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = child.stdout.take().expect("piped stdout");
    let last_seen = Arc::new(Mutex::new(Instant::now()));
    let shm_active = Arc::new(AtomicBool::new(false));
    // Death-ordering handshake: with rings, the pipe reader does NOT
    // report `Died` itself — it raises `peer_gone` and the ring reader
    // reports death only once the ring is drained. Frames the worker
    // published before dying are therefore always delivered first, same
    // as the pipe's buffered-until-EOF semantics.
    let peer_gone = Arc::new(AtomicBool::new(false));
    let ring = match rings {
        Some((tx_ring, rx_ring, prefix)) => {
            let stop = Arc::new(AtomicBool::new(false));
            let txc = tx.clone();
            let seen = Arc::clone(&last_seen);
            let stop_c = Arc::clone(&stop);
            let gone = Arc::clone(&peer_gone);
            std::thread::Builder::new()
                .name(format!("exec-ring-{env_id}.{rank}"))
                .spawn(move || {
                    ring_reader_loop(env_id, rank, generation, rx_ring, txc, seen, stop_c, gone)
                })
                .context("spawning worker ring-reader thread")?;
            Some(RingLink {
                tx_ring,
                shm_active: Arc::clone(&shm_active),
                stop,
                prefix,
            })
        }
        None => None,
    };
    let has_ring = ring.is_some();
    let txc = tx.clone();
    let seen = Arc::clone(&last_seen);
    let active = Arc::clone(&shm_active);
    std::thread::Builder::new()
        .name(format!("exec-read-{env_id}.{rank}"))
        .spawn(move || {
            reader_loop(
                env_id, rank, generation, stdout, txc, seen, active, peer_gone, has_ring,
            )
        })
        .context("spawning worker reader thread")?;
    Ok(ChildProc {
        handle: WorkerHandle::Local(child),
        writer: Some(WorkerWriter::Pipe(stdin)),
        pid,
        generation,
        last_seen,
        ring,
    })
}

/// Turn one decoded worker frame into an event (`None` = nothing to
/// forward). Shared by the pipe and ring readers, so a frame means the
/// same thing whichever channel it arrived on.
fn event_for_frame(env_id: usize, frame: Frame, shm_active: &AtomicBool) -> Option<Event> {
    match frame {
        Frame::Heartbeat => None,
        Frame::Hello { version, shm, .. } => {
            if version != PROTOCOL_VERSION {
                Some(Event::WorkerError {
                    env_id,
                    msg: format!(
                        "wire protocol version {version} != coordinator {PROTOCOL_VERSION} \
                         (mixed binaries?)"
                    ),
                })
            } else {
                // the worker's shm ack arms our ring send side
                shm_active.store(shm == 1, Ordering::Release);
                None
            }
        }
        Frame::Obs { obs } => Some(Event::Lockstep(LockstepReply::Obs { env_id, obs })),
        Frame::StepOut { result } => {
            Some(Event::Lockstep(LockstepReply::Step { env_id, result }))
        }
        Frame::Episode { stats, traj, .. } => Some(Event::Episode(EpisodeOut {
            env_id,
            traj,
            stats,
            completed_at: Instant::now(),
        })),
        Frame::Error { msg } => Some(Event::WorkerError { env_id, msg }),
        // tracing plane: span batches merge into the coordinator's sink
        // (shifted by this worker's clock offset), probe echoes update
        // that offset. Never an event — telemetry must not be able to
        // perturb scheduling.
        Frame::Telemetry {
            env_id: tenv,
            rank,
            kind,
            clock_us,
            echo_us,
            spans,
        } => {
            if obs::enabled() {
                match kind {
                    0 => obs::ingest_remote(tenv, rank, spans),
                    2 => obs::record_probe_echo(tenv, rank, echo_us, clock_us, obs::now_us()),
                    _ => {}
                }
            }
            None
        }
        other => Some(Event::WorkerError {
            env_id,
            msg: format!("protocol violation: worker sent {other:?}"),
        }),
    }
}

/// Decode worker frames into events until EOF; every frame (heartbeats
/// included) stamps the liveness clock. The thread detaches — it exits
/// by itself when the process dies or the executor is dropped. Generic
/// over the byte source: a stdout pipe, or a socket under the net
/// transports (whose EOF means exactly the same thing).
#[allow(clippy::too_many_arguments)]
fn reader_loop<R: Read>(
    env_id: usize,
    rank: usize,
    generation: u64,
    mut input: R,
    tx: Sender<Event>,
    last_seen: Arc<Mutex<Instant>>,
    shm_active: Arc<AtomicBool>,
    peer_gone: Arc<AtomicBool>,
    has_ring: bool,
) {
    loop {
        let frame = match wire::read_frame(&mut input) {
            Ok(Some(f)) => f,
            // clean close and a torn frame both mean the worker is gone
            Ok(None) | Err(_) => break,
        };
        *last_seen.lock().expect("liveness clock poisoned") = Instant::now();
        if let Some(ev) = event_for_frame(env_id, frame, &shm_active) {
            if tx.send(ev).is_err() {
                return; // executor gone
            }
        }
    }
    if has_ring {
        // the ring reader reports the death once the ring is drained
        peer_gone.store(true, Ordering::Release);
    } else {
        let _ = tx.send(Event::Died {
            env_id,
            rank,
            generation,
        });
    }
}

/// Poll the worker→coordinator ring for published frames. The seqlock
/// guarantees a frame is either fully published or invisible, so a torn
/// write from a crashing worker can never surface here. Death (signalled
/// by the pipe reader via `peer_gone`) is only reported once the ring is
/// empty — every frame published before the crash is delivered first.
#[allow(clippy::too_many_arguments)]
fn ring_reader_loop(
    env_id: usize,
    rank: usize,
    generation: u64,
    mut rx_ring: shm::Consumer,
    tx: Sender<Event>,
    last_seen: Arc<Mutex<Instant>>,
    stop: Arc<AtomicBool>,
    peer_gone: Arc<AtomicBool>,
) {
    // the coordinator only sends on an acked ring, but the worker's ack
    // travels on the pipe; this thread just drains whatever is published
    let shm_active = AtomicBool::new(true);
    let mut backoff = shm::Backoff::new();
    loop {
        if stop.load(Ordering::Acquire) {
            return; // respawn/shutdown teardown
        }
        let gone = peer_gone.load(Ordering::Acquire);
        match rx_ring.try_pop() {
            Ok(Some(body)) => {
                backoff.reset();
                *last_seen.lock().expect("liveness clock poisoned") = Instant::now();
                let ev = match wire::decode(&body) {
                    Ok(frame) => event_for_frame(env_id, frame, &shm_active),
                    Err(e) => Some(Event::WorkerError {
                        env_id,
                        msg: format!("corrupt shm frame: {e:#}"),
                    }),
                };
                if let Some(ev) = ev {
                    if tx.send(ev).is_err() {
                        return; // executor gone
                    }
                }
            }
            Ok(None) if gone => {
                // pipe hit EOF before this empty poll: the producer is
                // dead and the ring is drained — now the death is safe
                // to report
                let _ = tx.send(Event::Died {
                    env_id,
                    rank,
                    generation,
                });
                return;
            }
            Ok(None) => backoff.snooze(),
            Err(e) => {
                let _ = tx.send(Event::WorkerError {
                    env_id,
                    msg: format!("shm ring read failed: {e:#}"),
                });
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_timeout_unset_uses_default() {
        assert_eq!(
            parse_worker_timeout(None).unwrap(),
            Duration::from_secs_f64(DEFAULT_TIMEOUT_S)
        );
    }

    #[test]
    fn worker_timeout_accepts_positive_seconds() {
        assert_eq!(
            parse_worker_timeout(Some(" 2.5 ")).unwrap(),
            Duration::from_secs_f64(2.5)
        );
        assert_eq!(
            parse_worker_timeout(Some("120")).unwrap(),
            Duration::from_secs_f64(120.0)
        );
    }

    #[test]
    fn worker_timeout_rejects_malformed_zero_and_negative() {
        for bad in ["", "abc", "1.5s", "0", "0.0", "-3", "inf", "nan"] {
            let err = parse_worker_timeout(Some(bad)).unwrap_err().to_string();
            assert!(
                err.contains("DRLFOAM_WORKER_TIMEOUT_S"),
                "{bad:?} error should name the variable: {err}"
            );
        }
    }
}
