//! Length-prefixed binary wire protocol between the coordinator and
//! `drlfoam worker` processes.
//!
//! Every frame is `[u32 payload_len][u8 tag][payload]`, little-endian
//! throughout, with the same raw-f32 packing the *Optimized* exchange
//! uses ([`crate::io_interface::binary`]) — floats travel bit-exact, so
//! the multi-process backend reproduces the in-process learning curves
//! bitwise (`rust/tests/exec_backend.rs`).
//!
//! With `--transport shm` the *data* frames (`Step`, `Obs`, `StepOut`,
//! and `Episode` when it fits a slot) ride the seqlock rings of
//! [`super::shm`] instead of the pipe; a ring slot carries the frame
//! *body* (`[u8 tag][payload]`, no length prefix — the slot header holds
//! the length), so [`encode`]/[`decode`] are shared byte-for-byte by both
//! transports. Control frames always stay on the pipe.
//!
//! | frame       | direction            | payload |
//! |-------------|----------------------|---------|
//! | `Hello`     | worker → coordinator | env_id, rank, pid, n_obs, protocol version, shm ack |
//! | `SetParams` | coordinator → worker | policy parameter vector (per-env serving) |
//! | `Rollout`   | coordinator → worker | horizon, episode index, exploration seed |
//! | `Reset`     | coordinator → worker | — (lockstep/batched mode) |
//! | `Step`      | coordinator → worker | action (lockstep/batched mode) |
//! | `Shutdown`  | coordinator → worker | — |
//! | `Heartbeat` | worker → coordinator | — (liveness, every `--heartbeat-ms`) |
//! | `Obs`       | worker → coordinator | initial observation (reply to `Reset`) |
//! | `StepOut`   | worker → coordinator | full [`StepResult`] (reply to `Step`) |
//! | `Episode`   | worker → coordinator | trajectory + [`EpisodeStats`] (reply to `Rollout`) |
//! | `Error`     | worker → coordinator | terminal failure message |
//! | `Spawn`     | coordinator → agent  | worker spawn spec (socket transport, `drlfoam agent`) |
//! | `Telemetry` | both directions      | obs span batch / clock probe / probe echo (ARCHITECTURE.md §12) |
//!
//! `Spawn` is the only frame addressed to a `drlfoam agent` rather than a
//! worker: it is the first frame on every coordinator→agent connection
//! and tells the agent which worker to exec and relay. Everything after
//! it on that connection is coordinator↔worker traffic, byte-identical
//! to the pipe transport.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::pool::EpisodeStats;
use crate::drl::{Trajectory, Transition};
use crate::env::{StepResult, StepTimings};
use crate::io_interface::binary::{get_f32s, put_f32s};
use crate::io_interface::IoStats;
use crate::obs::SpanRec;

/// Bumped on any incompatible frame-layout change; the coordinator
/// rejects a `Hello` carrying a different version.
pub const PROTOCOL_VERSION: u32 = 3;

/// Corrupt-stream guard: no legitimate frame (even a full cylinder-grid
/// trajectory) comes close to this.
const MAX_FRAME: usize = 256 << 20;

/// On-wire tag byte of each frame, one variant per [`Frame`] variant.
/// Discriminants are the protocol — never renumber, only append. The
/// `drlfoam audit` rule `wire-tag-coverage` parses this enum and
/// verifies every variant has an [`encode`] arm, a [`decode`] arm, and a
/// fuzz-corpus entry (`wire_fuzz` in `rust/tests/exec_backend.rs`), so
/// adding a frame without wiring it everywhere fails CI.
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tag {
    Hello = 1,
    SetParams = 2,
    Reset = 3,
    Step = 4,
    Rollout = 5,
    Shutdown = 6,
    Heartbeat = 7,
    Obs = 8,
    StepOut = 9,
    Episode = 10,
    Error = 11,
    Spawn = 12,
    Telemetry = 13,
}

impl Tag {
    /// Every tag, in discriminant order (corpus/coverage iteration).
    pub const ALL: [Tag; 13] = [
        Tag::Hello,
        Tag::SetParams,
        Tag::Reset,
        Tag::Step,
        Tag::Rollout,
        Tag::Shutdown,
        Tag::Heartbeat,
        Tag::Obs,
        Tag::StepOut,
        Tag::Episode,
        Tag::Error,
        Tag::Spawn,
        Tag::Telemetry,
    ];

    /// Inverse of `as u8`; `None` for bytes outside the protocol.
    pub fn from_u8(b: u8) -> Option<Tag> {
        Tag::ALL.into_iter().find(|t| *t as u8 == b)
    }
}

/// One protocol frame (see the module-level table).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Hello {
        env_id: u32,
        rank: u32,
        pid: u32,
        n_obs: u32,
        version: u32,
        /// 1 if the worker successfully mapped the shm rings it was
        /// offered (`--shm-prefix`); 0 means the coordinator must keep
        /// every frame on the pipe for this worker.
        shm: u32,
    },
    SetParams {
        params: Vec<f32>,
    },
    Reset,
    Step {
        action: f64,
    },
    Rollout {
        horizon: u32,
        episode: u64,
        episode_seed: u64,
    },
    Shutdown,
    Heartbeat,
    Obs {
        obs: Vec<f32>,
    },
    StepOut {
        result: StepResult,
    },
    Episode {
        env_id: u32,
        stats: EpisodeStats,
        traj: Trajectory,
    },
    Error {
        msg: String,
    },
    /// First frame on a coordinator → `drlfoam agent` connection: the
    /// spawn spec of the worker this connection will carry. Fields
    /// mirror the `drlfoam worker` argv contract; `fault_injection` is
    /// the `DRLFOAM_WORKER_CRASH` spec (empty = no chaos).
    Spawn {
        env_id: u32,
        rank: u32,
        seed: u64,
        heartbeat_ms: u64,
        scenario: String,
        variant: String,
        artifact_dir: String,
        work_dir: String,
        io_mode: String,
        backend: String,
        cfd_backend: String,
        fault_injection: String,
        /// nonzero = spawn the worker with `--trace-spans` (obs tracing
        /// on). Raw byte, not a bool: fuzz requires every decoded frame
        /// to re-encode bit-exactly.
        trace: u8,
    },
    /// Tracing-plane traffic (ARCHITECTURE.md §12). `kind` selects the
    /// payload interpretation — 0 = span batch (worker → coordinator,
    /// `spans` populated, clocks unused), 1 = clock probe (coordinator →
    /// worker, `clock_us` = coordinator send time), 2 = probe echo
    /// (worker → coordinator, `clock_us` = worker clock at echo,
    /// `echo_us` = the probe's `clock_us` reflected back). Kept as a raw
    /// byte so corrupt/fuzzed frames re-encode bit-exactly.
    Telemetry {
        env_id: u32,
        rank: u32,
        kind: u8,
        clock_us: u64,
        echo_us: u64,
        spans: Vec<SpanRec>,
    },
}

// --- little-endian scalar packing -----------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_bytes<'a>(bytes: &'a [u8], n: usize, off: &mut usize) -> Result<&'a [u8]> {
    ensure!(bytes.len() >= *off + n, "wire frame truncated");
    let s = &bytes[*off..*off + n];
    *off += n;
    Ok(s)
}

fn get_u32(bytes: &[u8], off: &mut usize) -> Result<u32> {
    Ok(u32::from_le_bytes(get_bytes(bytes, 4, off)?.try_into().unwrap()))
}

fn get_u64(bytes: &[u8], off: &mut usize) -> Result<u64> {
    Ok(u64::from_le_bytes(get_bytes(bytes, 8, off)?.try_into().unwrap()))
}

fn get_f64(bytes: &[u8], off: &mut usize) -> Result<f64> {
    Ok(f64::from_le_bytes(get_bytes(bytes, 8, off)?.try_into().unwrap()))
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    put_u32(buf, b.len() as u32);
    buf.extend_from_slice(b);
}

fn get_str(bytes: &[u8], off: &mut usize) -> Result<String> {
    let n = get_u32(bytes, off)? as usize;
    ensure!(n <= MAX_FRAME, "wire string implausibly long ({n})");
    let b = get_bytes(bytes, n, off)?;
    Ok(String::from_utf8_lossy(b).into_owned())
}

fn put_vec_f32(buf: &mut Vec<u8>, xs: &[f32]) {
    put_u32(buf, xs.len() as u32);
    put_f32s(buf, xs);
}

fn get_vec_f32(bytes: &[u8], off: &mut usize) -> Result<Vec<f32>> {
    let n = get_u32(bytes, off)? as usize;
    ensure!(n <= MAX_FRAME / 4, "wire f32 vector implausibly long ({n})");
    get_f32s(bytes, n, off)
}

// --- composite payloads ----------------------------------------------------

fn put_io_stats(buf: &mut Vec<u8>, io: &IoStats) {
    put_u64(buf, io.bytes_written);
    put_u64(buf, io.bytes_read);
    put_u32(buf, io.files);
    put_f64(buf, io.write_s);
    put_f64(buf, io.read_s);
}

fn get_io_stats(bytes: &[u8], off: &mut usize) -> Result<IoStats> {
    Ok(IoStats {
        bytes_written: get_u64(bytes, off)?,
        bytes_read: get_u64(bytes, off)?,
        files: get_u32(bytes, off)?,
        write_s: get_f64(bytes, off)?,
        read_s: get_f64(bytes, off)?,
    })
}

fn put_step_result(buf: &mut Vec<u8>, r: &StepResult) {
    put_vec_f32(buf, &r.obs);
    put_f64(buf, r.reward);
    put_f64(buf, r.cd_mean);
    put_f64(buf, r.cl_mean);
    put_f64(buf, r.jet);
    put_f64(buf, r.timings.cfd_s);
    put_f64(buf, r.timings.io_s);
    put_io_stats(buf, &r.io);
}

fn get_step_result(bytes: &[u8], off: &mut usize) -> Result<StepResult> {
    Ok(StepResult {
        obs: get_vec_f32(bytes, off)?,
        reward: get_f64(bytes, off)?,
        cd_mean: get_f64(bytes, off)?,
        cl_mean: get_f64(bytes, off)?,
        jet: get_f64(bytes, off)?,
        timings: StepTimings {
            cfd_s: get_f64(bytes, off)?,
            io_s: get_f64(bytes, off)?,
        },
        io: get_io_stats(bytes, off)?,
    })
}

fn put_stats(buf: &mut Vec<u8>, s: &EpisodeStats) {
    put_f64(buf, s.reward_sum);
    put_f64(buf, s.cd_mean);
    put_f64(buf, s.cl_abs_mean);
    put_f64(buf, s.jet_final);
    put_f64(buf, s.cfd_s);
    put_f64(buf, s.io_s);
    put_f64(buf, s.policy_s);
    put_f64(buf, s.wall_s);
    put_io_stats(buf, &s.io);
}

fn get_stats(bytes: &[u8], off: &mut usize) -> Result<EpisodeStats> {
    Ok(EpisodeStats {
        reward_sum: get_f64(bytes, off)?,
        cd_mean: get_f64(bytes, off)?,
        cl_abs_mean: get_f64(bytes, off)?,
        jet_final: get_f64(bytes, off)?,
        cfd_s: get_f64(bytes, off)?,
        io_s: get_f64(bytes, off)?,
        policy_s: get_f64(bytes, off)?,
        wall_s: get_f64(bytes, off)?,
        io: get_io_stats(bytes, off)?,
    })
}

fn put_traj(buf: &mut Vec<u8>, t: &Trajectory) {
    put_u64(buf, t.env_id as u64);
    put_f64(buf, t.last_value);
    put_u32(buf, t.transitions.len() as u32);
    for tr in &t.transitions {
        put_vec_f32(buf, &tr.obs);
        put_f64(buf, tr.action);
        put_f64(buf, tr.logp);
        put_f64(buf, tr.reward);
        put_f64(buf, tr.value);
    }
}

fn get_traj(bytes: &[u8], off: &mut usize) -> Result<Trajectory> {
    let env_id = get_u64(bytes, off)? as usize;
    let last_value = get_f64(bytes, off)?;
    let n = get_u32(bytes, off)? as usize;
    ensure!(n <= 1 << 24, "wire trajectory implausibly long ({n})");
    let mut transitions = Vec::with_capacity(n);
    for _ in 0..n {
        transitions.push(Transition {
            obs: get_vec_f32(bytes, off)?,
            action: get_f64(bytes, off)?,
            logp: get_f64(bytes, off)?,
            reward: get_f64(bytes, off)?,
            value: get_f64(bytes, off)?,
        });
    }
    Ok(Trajectory {
        transitions,
        last_value,
        env_id,
    })
}

fn put_spans(buf: &mut Vec<u8>, spans: &[SpanRec]) {
    put_u32(buf, spans.len() as u32);
    for s in spans {
        buf.push(s.phase);
        put_u64(buf, s.start_us);
        put_u64(buf, s.dur_us);
        put_u32(buf, s.env_id);
        put_u64(buf, s.episode);
    }
}

fn get_spans(bytes: &[u8], off: &mut usize) -> Result<Vec<SpanRec>> {
    let n = get_u32(bytes, off)? as usize;
    ensure!(n <= 1 << 24, "wire span batch implausibly long ({n})");
    let mut spans = Vec::with_capacity(n);
    for _ in 0..n {
        spans.push(SpanRec {
            phase: get_bytes(bytes, 1, off)?[0],
            start_us: get_u64(bytes, off)?,
            dur_us: get_u64(bytes, off)?,
            env_id: get_u32(bytes, off)?,
            episode: get_u64(bytes, off)?,
        });
    }
    Ok(spans)
}

// --- frame encode / decode -------------------------------------------------

/// Encode a frame *body* (`[u8 tag][payload]`, no length prefix). The
/// pipe transport prefixes it with a `u32` length ([`write_frame`]); the
/// shm transport drops it into a ring slot as-is.
pub(crate) fn encode(frame: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    match frame {
        Frame::Hello {
            env_id,
            rank,
            pid,
            n_obs,
            version,
            shm,
        } => {
            buf.push(Tag::Hello as u8);
            put_u32(&mut buf, *env_id);
            put_u32(&mut buf, *rank);
            put_u32(&mut buf, *pid);
            put_u32(&mut buf, *n_obs);
            put_u32(&mut buf, *version);
            put_u32(&mut buf, *shm);
        }
        Frame::SetParams { params } => {
            buf.push(Tag::SetParams as u8);
            put_vec_f32(&mut buf, params);
        }
        Frame::Reset => buf.push(Tag::Reset as u8),
        Frame::Step { action } => {
            buf.push(Tag::Step as u8);
            put_f64(&mut buf, *action);
        }
        Frame::Rollout {
            horizon,
            episode,
            episode_seed,
        } => {
            buf.push(Tag::Rollout as u8);
            put_u32(&mut buf, *horizon);
            put_u64(&mut buf, *episode);
            put_u64(&mut buf, *episode_seed);
        }
        Frame::Shutdown => buf.push(Tag::Shutdown as u8),
        Frame::Heartbeat => buf.push(Tag::Heartbeat as u8),
        Frame::Obs { obs } => {
            buf.push(Tag::Obs as u8);
            put_vec_f32(&mut buf, obs);
        }
        Frame::StepOut { result } => {
            buf.push(Tag::StepOut as u8);
            put_step_result(&mut buf, result);
        }
        Frame::Episode {
            env_id,
            stats,
            traj,
        } => {
            buf.push(Tag::Episode as u8);
            put_u32(&mut buf, *env_id);
            put_stats(&mut buf, stats);
            put_traj(&mut buf, traj);
        }
        Frame::Error { msg } => {
            buf.push(Tag::Error as u8);
            let b = msg.as_bytes();
            put_u32(&mut buf, b.len() as u32);
            buf.extend_from_slice(b);
        }
        Frame::Spawn {
            env_id,
            rank,
            seed,
            heartbeat_ms,
            scenario,
            variant,
            artifact_dir,
            work_dir,
            io_mode,
            backend,
            cfd_backend,
            fault_injection,
            trace,
        } => {
            buf.push(Tag::Spawn as u8);
            put_u32(&mut buf, *env_id);
            put_u32(&mut buf, *rank);
            put_u64(&mut buf, *seed);
            put_u64(&mut buf, *heartbeat_ms);
            put_str(&mut buf, scenario);
            put_str(&mut buf, variant);
            put_str(&mut buf, artifact_dir);
            put_str(&mut buf, work_dir);
            put_str(&mut buf, io_mode);
            put_str(&mut buf, backend);
            put_str(&mut buf, cfd_backend);
            put_str(&mut buf, fault_injection);
            buf.push(*trace);
        }
        Frame::Telemetry {
            env_id,
            rank,
            kind,
            clock_us,
            echo_us,
            spans,
        } => {
            buf.push(Tag::Telemetry as u8);
            put_u32(&mut buf, *env_id);
            put_u32(&mut buf, *rank);
            buf.push(*kind);
            put_u64(&mut buf, *clock_us);
            put_u64(&mut buf, *echo_us);
            put_spans(&mut buf, spans);
        }
    }
    buf
}

/// Decode a frame *body* (inverse of [`encode`]); rejects trailing bytes.
pub(crate) fn decode(bytes: &[u8]) -> Result<Frame> {
    ensure!(!bytes.is_empty(), "empty wire frame");
    let tag = bytes[0];
    let mut off = 1usize;
    let frame = match Tag::from_u8(tag) {
        Some(Tag::Hello) => Frame::Hello {
            env_id: get_u32(bytes, &mut off)?,
            rank: get_u32(bytes, &mut off)?,
            pid: get_u32(bytes, &mut off)?,
            n_obs: get_u32(bytes, &mut off)?,
            version: get_u32(bytes, &mut off)?,
            shm: get_u32(bytes, &mut off)?,
        },
        Some(Tag::SetParams) => Frame::SetParams {
            params: get_vec_f32(bytes, &mut off)?,
        },
        Some(Tag::Reset) => Frame::Reset,
        Some(Tag::Step) => Frame::Step {
            action: get_f64(bytes, &mut off)?,
        },
        Some(Tag::Rollout) => Frame::Rollout {
            horizon: get_u32(bytes, &mut off)?,
            episode: get_u64(bytes, &mut off)?,
            episode_seed: get_u64(bytes, &mut off)?,
        },
        Some(Tag::Shutdown) => Frame::Shutdown,
        Some(Tag::Heartbeat) => Frame::Heartbeat,
        Some(Tag::Obs) => Frame::Obs {
            obs: get_vec_f32(bytes, &mut off)?,
        },
        Some(Tag::StepOut) => Frame::StepOut {
            result: get_step_result(bytes, &mut off)?,
        },
        Some(Tag::Episode) => Frame::Episode {
            env_id: get_u32(bytes, &mut off)?,
            stats: get_stats(bytes, &mut off)?,
            traj: get_traj(bytes, &mut off)?,
        },
        Some(Tag::Error) => {
            let n = get_u32(bytes, &mut off)? as usize;
            let b = get_bytes(bytes, n, &mut off)?;
            Frame::Error {
                msg: String::from_utf8_lossy(b).into_owned(),
            }
        }
        Some(Tag::Spawn) => Frame::Spawn {
            env_id: get_u32(bytes, &mut off)?,
            rank: get_u32(bytes, &mut off)?,
            seed: get_u64(bytes, &mut off)?,
            heartbeat_ms: get_u64(bytes, &mut off)?,
            scenario: get_str(bytes, &mut off)?,
            variant: get_str(bytes, &mut off)?,
            artifact_dir: get_str(bytes, &mut off)?,
            work_dir: get_str(bytes, &mut off)?,
            io_mode: get_str(bytes, &mut off)?,
            backend: get_str(bytes, &mut off)?,
            cfd_backend: get_str(bytes, &mut off)?,
            fault_injection: get_str(bytes, &mut off)?,
            trace: get_bytes(bytes, 1, &mut off)?[0],
        },
        Some(Tag::Telemetry) => Frame::Telemetry {
            env_id: get_u32(bytes, &mut off)?,
            rank: get_u32(bytes, &mut off)?,
            kind: get_bytes(bytes, 1, &mut off)?[0],
            clock_us: get_u64(bytes, &mut off)?,
            echo_us: get_u64(bytes, &mut off)?,
            spans: get_spans(bytes, &mut off)?,
        },
        None => bail!("unknown wire frame tag {tag}"),
    };
    ensure!(
        off == bytes.len(),
        "wire frame has {} trailing bytes (tag {tag})",
        bytes.len() - off
    );
    Ok(frame)
}

/// Write one frame (length prefix + payload) and flush, so a frame is
/// never left sitting in a pipe buffer while the peer blocks on it.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let payload = encode(frame);
    w.write_all(&(payload.len() as u32).to_le_bytes())
        .context("writing wire frame length")?;
    w.write_all(&payload).context("writing wire frame payload")?;
    w.flush().context("flushing wire frame")?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary (the peer
/// closed the stream), an error on a truncated or corrupt frame.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut len4 = [0u8; 4];
    // EOF before the first length byte is a clean close; EOF inside a
    // frame is truncation
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("wire stream closed inside a frame header"),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading wire frame length"),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    ensure!(
        len >= 1 && len <= MAX_FRAME,
        "implausible wire frame length {len}"
    );
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .context("reading wire frame payload")?;
    decode(&payload).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let got = read_frame(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(got, f);
    }

    #[test]
    fn every_frame_round_trips_bit_exact() {
        roundtrip(Frame::Hello {
            env_id: 3,
            rank: 1,
            pid: 4242,
            n_obs: 32,
            version: PROTOCOL_VERSION,
            shm: 1,
        });
        roundtrip(Frame::SetParams {
            params: vec![0.25, -1.5e-7, f32::MIN_POSITIVE, 3.0e8],
        });
        roundtrip(Frame::Reset);
        roundtrip(Frame::Step { action: -0.123456789012345 });
        roundtrip(Frame::Rollout {
            horizon: 100,
            episode: 7,
            episode_seed: u64::MAX - 3,
        });
        roundtrip(Frame::Shutdown);
        roundtrip(Frame::Heartbeat);
        roundtrip(Frame::Obs {
            obs: vec![1.0, 2.0, -0.5],
        });
        roundtrip(Frame::StepOut {
            result: StepResult {
                obs: vec![0.1, 0.2],
                reward: 0.33,
                cd_mean: 3.01,
                cl_mean: -0.2,
                jet: 0.8,
                timings: StepTimings {
                    cfd_s: 1e-4,
                    io_s: 2e-5,
                },
                io: IoStats {
                    bytes_written: 1024,
                    bytes_read: 512,
                    files: 2,
                    write_s: 1e-5,
                    read_s: 2e-6,
                },
            },
        });
        roundtrip(Frame::Episode {
            env_id: 1,
            stats: EpisodeStats {
                reward_sum: 1.25,
                cd_mean: 3.0,
                cl_abs_mean: 0.4,
                jet_final: -0.7,
                cfd_s: 0.01,
                io_s: 0.002,
                policy_s: 0.0005,
                wall_s: 0.02,
                io: IoStats::default(),
            },
            traj: Trajectory {
                env_id: 1,
                last_value: -0.05,
                transitions: vec![
                    Transition {
                        obs: vec![0.5; 4],
                        action: 0.7,
                        logp: -0.9,
                        reward: 0.02,
                        value: 0.1,
                    },
                    Transition {
                        obs: vec![-0.25; 4],
                        action: -0.1,
                        logp: -1.3,
                        reward: -0.04,
                        value: 0.2,
                    },
                ],
            },
        });
        roundtrip(Frame::Error {
            msg: "env worker setup failed: boom".into(),
        });
        roundtrip(Frame::Spawn {
            env_id: 2,
            rank: 1,
            seed: 17,
            heartbeat_ms: 200,
            scenario: "surrogate".into(),
            variant: "tiny".into(),
            artifact_dir: "/tmp/artifacts".into(),
            work_dir: "/tmp/work".into(),
            io_mode: "optimized".into(),
            backend: "native".into(),
            cfd_backend: "reference".into(),
            fault_injection: String::new(),
            trace: 1,
        });
        roundtrip(Frame::Telemetry {
            env_id: 3,
            rank: 0,
            kind: 0,
            clock_us: 0,
            echo_us: 0,
            spans: vec![
                SpanRec {
                    phase: 0,
                    start_us: 12,
                    dur_us: 3400,
                    env_id: 3,
                    episode: 9,
                },
                SpanRec {
                    phase: 0xEE, // out-of-taxonomy phase must still round-trip
                    start_us: u64::MAX - 1,
                    dur_us: 0,
                    env_id: u32::MAX,
                    episode: u64::MAX,
                },
            ],
        });
        roundtrip(Frame::Telemetry {
            env_id: 0,
            rank: 2,
            kind: 2,
            clock_us: 123_456_789,
            echo_us: 123_400_000,
            spans: Vec::new(),
        });
    }

    #[test]
    fn tag_discriminants_round_trip_and_are_dense() {
        for (i, t) in Tag::ALL.into_iter().enumerate() {
            // dense, 1-based, in declaration order — the wire contract
            assert_eq!(t as u8, i as u8 + 1);
            assert_eq!(Tag::from_u8(t as u8), Some(t));
        }
        assert_eq!(Tag::from_u8(0), None);
        assert_eq!(Tag::from_u8(Tag::ALL.len() as u8 + 1), None);
        assert_eq!(Tag::from_u8(0xEE), None);
    }

    #[test]
    fn special_floats_survive_the_wire() {
        // NaN defeats PartialEq, so compare bit patterns directly
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Frame::SetParams {
                params: vec![f32::NAN, f32::INFINITY, -0.0],
            },
        )
        .unwrap();
        match read_frame(&mut Cursor::new(&buf)).unwrap().unwrap() {
            Frame::SetParams { params } => {
                assert_eq!(params[0].to_bits(), f32::NAN.to_bits());
                assert_eq!(params[1], f32::INFINITY);
                assert_eq!(params[2].to_bits(), (-0.0f32).to_bits());
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn clean_eof_is_none_truncation_is_error() {
        assert!(read_frame(&mut Cursor::new(&[])).unwrap().is_none());
        // header cut mid-way
        assert!(read_frame(&mut Cursor::new(&[5u8, 0])).is_err());
        // complete header promising more payload than exists
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Step { action: 1.0 }).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_frame(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn corrupt_frames_are_rejected_not_misread() {
        // implausible length prefix
        let big = (u32::MAX).to_le_bytes();
        assert!(read_frame(&mut Cursor::new(&big[..])).is_err());
        // unknown tag
        let mut buf = vec![1u8, 0, 0, 0, 0xEE];
        assert!(read_frame(&mut Cursor::new(&buf)).is_err());
        // trailing garbage after a valid payload
        buf = Vec::new();
        write_frame(&mut buf, &Frame::Reset).unwrap();
        buf[0] = 2; // lie: payload is 2 bytes
        buf.push(0u8);
        assert!(read_frame(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn multiple_frames_stream_in_order() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Reset).unwrap();
        write_frame(&mut buf, &Frame::Step { action: 2.0 }).unwrap();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        let mut c = Cursor::new(&buf);
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), Frame::Reset);
        assert_eq!(
            read_frame(&mut c).unwrap().unwrap(),
            Frame::Step { action: 2.0 }
        );
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), Frame::Shutdown);
        assert!(read_frame(&mut c).unwrap().is_none());
    }
}
