//! The DRL side of the framework: policy serving, trajectory buffers,
//! GAE, and the PPO update loop (all orchestration in Rust; the numeric
//! kernels are the AOT-compiled `policy_apply` / `ppo_update` artifacts).

pub mod buffer;
pub mod gae;
pub mod policy;
pub mod trainer;

pub use buffer::{Batch, Trajectory, Transition};
pub use policy::{NativePolicy, Policy, PolicyBackendKind, PolicyOutput, PolicySession};
pub use trainer::{PpoTrainer, UpdateStats};
