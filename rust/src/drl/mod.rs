//! The DRL side of the framework: policy serving, trajectory buffers,
//! GAE, and the PPO update loop. Orchestration lives in Rust; the numeric
//! kernels come in matched pairs — the AOT-compiled `policy_apply` /
//! `ppo_update` artifacts and their pure-Rust twins ([`NativePolicy`],
//! [`NativeUpdater`]) for artifact-free runs.

pub mod buffer;
pub mod gae;
pub mod native_update;
pub mod policy;
pub mod trainer;

pub use buffer::{Batch, Trajectory, Transition};
pub use native_update::{NativeUpdater, PpoHyperParams, DEFAULT_GAE_LAMBDA, DEFAULT_GAMMA};
pub use policy::{NativePolicy, Policy, PolicyBackendKind, PolicyOutput, PolicySession};
pub use trainer::{PpoTrainer, TrainerBackend, UpdateBackendKind, UpdateStats};
