//! Generalised Advantage Estimation (Schulman et al. 2016).
//!
//! Twin of `python/compile/model.py::gae`; cross-validated in
//! rust/tests against vectors generated from the python oracle and by the
//! in-tree property tests (telescoping identity).

/// Returns (advantages, returns) for one trajectory.
///
/// `last_value` bootstraps the value beyond the horizon (the episode is a
/// time-truncated, non-terminal MDP — the flow keeps evolving).
pub fn gae(
    rewards: &[f64],
    values: &[f64],
    last_value: f64,
    gamma: f64,
    lam: f64,
) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(rewards.len(), values.len());
    let n = rewards.len();
    let mut adv = vec![0.0; n];
    let mut last = 0.0;
    for t in (0..n).rev() {
        let next_v = if t + 1 == n { last_value } else { values[t + 1] };
        let delta = rewards[t] + gamma * next_v - values[t];
        last = delta + gamma * lam * last;
        adv[t] = last;
    }
    let ret: Vec<f64> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, ret)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn constant_reward_closed_form() {
        let n = 10;
        let (gamma, lam) = (0.9, 0.8);
        let rew = vec![1.0; n];
        let val = vec![0.0; n];
        let (adv, ret) = gae(&rew, &val, 0.0, gamma, lam);
        let gl: f64 = gamma * lam;
        for t in 0..n {
            let want = (1.0 - gl.powi((n - t) as i32)) / (1.0 - gl);
            assert!((adv[t] - want).abs() < 1e-12, "t={t}");
            assert!((ret[t] - adv[t]).abs() < 1e-12);
        }
    }

    #[test]
    fn lambda_one_gives_discounted_returns() {
        prop::check("gae lam=1 == discounted return", 50, |rng| {
            let n = 1 + rng.below(40);
            let rew: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let val: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let last = rng.normal();
            let gamma = 0.95;
            let (_, ret) = gae(&rew, &val, last, gamma, 1.0);
            let mut acc = last;
            for t in (0..n).rev() {
                acc = rew[t] + gamma * acc;
                if (ret[t] - acc).abs() > 1e-9 {
                    return Err(format!("t={t}: {} vs {}", ret[t], acc));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn telescoping_identity_lambda_zero() {
        // lam=0: adv_t == delta_t == r_t + gamma V_{t+1} - V_t exactly
        prop::check("gae lam=0 == TD residual", 50, |rng| {
            let n = 1 + rng.below(30);
            let rew: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let val: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let last = rng.normal();
            let gamma = 0.99;
            let (adv, _) = gae(&rew, &val, last, gamma, 0.0);
            for t in 0..n {
                let next_v = if t + 1 == n { last } else { val[t + 1] };
                let delta = rew[t] + gamma * next_v - val[t];
                if (adv[t] - delta).abs() > 1e-9 {
                    return Err(format!("t={t}"));
                }
            }
            Ok(())
        });
    }
}
