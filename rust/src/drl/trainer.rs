//! PPO trainer: owns the flat parameter/Adam-state buffers and drives
//! shuffled minibatch updates for K epochs through a selectable
//! [`TrainerBackend`] — the AOT `ppo_update` artifact (XLA) or the
//! pure-Rust [`NativeUpdater`] (no artifacts required).


use anyhow::Result;

use crate::drl::buffer::Batch;
use crate::drl::native_update::NativeUpdater;
use crate::runtime::{literal_f32, scalar_f32, to_vec_f32, DrlManifest, Executable};
use crate::util::clock::telemetry_now;
use crate::util::rng::Rng;

/// Which engine performs the PPO minibatch update (`--update-backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateBackendKind {
    /// The AOT-compiled `ppo_update` artifact on a PJRT runtime.
    Xla,
    /// The pure-Rust [`NativeUpdater`] (no artifacts required).
    Native,
}

impl UpdateBackendKind {
    /// Parse a CLI/config string (trimmed, case-insensitive); the error
    /// lists the accepted values.
    pub fn parse(s: &str) -> Result<UpdateBackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "xla" => Ok(UpdateBackendKind::Xla),
            "native" => Ok(UpdateBackendKind::Native),
            _ => anyhow::bail!("unknown update backend {s:?} (accepted: xla, native)"),
        }
    }

    /// Canonical name, inverse of [`UpdateBackendKind::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            UpdateBackendKind::Xla => "xla",
            UpdateBackendKind::Native => "native",
        }
    }
}

/// The engine one [`PpoTrainer::update`] call runs its minibatches on.
/// Both variants consume the same `(params | m | v)` state and the same
/// shuffled minibatch schedule, so switching backends changes *where* the
/// arithmetic runs, not what is computed (asserted, with f32-rounding
/// tolerances, by `rust/tests/train_smoke.rs`).
#[derive(Clone, Copy)]
pub enum TrainerBackend<'a> {
    /// The compiled `ppo_update` executable (on the caller's runtime).
    Xla(&'a Executable),
    /// The pure-Rust update step.
    Native(&'a NativeUpdater),
}

/// Aggregated statistics over one iteration's update epochs.
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateStats {
    pub pi_loss: f64,
    pub v_loss: f64,
    pub entropy: f64,
    pub approx_kl: f64,
    pub clip_frac: f64,
    pub grad_norm: f64,
    pub minibatches: usize,
    pub wall_s: f64,
}

/// Checkpoint header magic for the v1 blob format ("DRL1" as a bit
/// pattern); distinguishes versioned blobs from the legacy headerless
/// `(params | m | v)` layout, which [`PpoTrainer::restore`] still reads.
const CKPT_MAGIC: u32 = 0x4452_4C31;
const CKPT_VERSION: u32 = 1;
/// f32 slots the v1 header occupies before `(params | m | v)`:
/// magic, version, Adam-step low bits, Adam-step high bits.
const CKPT_HEADER: usize = 4;

/// Master-side PPO optimizer state: the flat parameter vector, the Adam
/// moments, and their device-resident mirrors between minibatches.
pub struct PpoTrainer {
    pub params: Vec<f32>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    /// device-resident copies fed back between minibatches on the XLA
    /// backend (perf: saves ~8 MB of host memcpy per minibatch,
    /// EXPERIMENTS.md section Perf); always `None` on the native backend
    lits: Option<[xla::Literal; 3]>,
    /// 1-based Adam step counter (bias correction).
    step: u64,
    minibatch: usize,
    epochs: usize,
}

impl PpoTrainer {
    /// Fresh optimizer over `params` (zero Adam moments, step 0), sized
    /// and minibatched per the AOT manifest.
    pub fn new(drl: &DrlManifest, params: Vec<f32>, epochs: usize) -> Self {
        assert_eq!(params.len(), drl.n_params);
        PpoTrainer::with_minibatch(params, drl.minibatch, epochs)
    }

    /// Fresh optimizer without a manifest (artifact-free runs): the caller
    /// picks the minibatch size instead of reading the artifact's static
    /// batch dimension.
    pub fn with_minibatch(params: Vec<f32>, minibatch: usize, epochs: usize) -> Self {
        let n = params.len();
        PpoTrainer {
            params,
            adam_m: vec![0.0; n],
            adam_v: vec![0.0; n],
            lits: None,
            step: 0,
            minibatch,
            epochs,
        }
    }

    /// 1-based Adam step counter (bias correction state).
    pub fn adam_step(&self) -> u64 {
        self.step
    }

    /// Run `epochs` passes of shuffled minibatch updates over the batch on
    /// the selected backend.
    pub fn update(
        &mut self,
        backend: TrainerBackend,
        batch: &Batch,
        rng: &mut Rng,
    ) -> Result<UpdateStats> {
        let t0 = telemetry_now();
        let mut agg = UpdateStats::default();
        match backend {
            TrainerBackend::Xla(exe) => self.update_xla(exe, batch, rng, &mut agg)?,
            TrainerBackend::Native(nu) => self.update_native(nu, batch, rng, &mut agg)?,
        }
        let k = agg.minibatches.max(1) as f64;
        agg.pi_loss /= k;
        agg.v_loss /= k;
        agg.entropy /= k;
        agg.approx_kl /= k;
        agg.clip_frac /= k;
        agg.grad_norm /= k;
        agg.wall_s = t0.elapsed().as_secs_f64();
        crate::obs::record_measured_here(crate::obs::Phase::Update, t0, agg.wall_s);
        Ok(agg)
    }

    /// Fold one minibatch's `[pg, v, ent, kl, clip, gnorm]` into the
    /// iteration aggregate (means are finalized by [`PpoTrainer::update`]).
    fn accumulate(agg: &mut UpdateStats, stats: &[f32]) {
        agg.pi_loss += stats[0] as f64;
        agg.v_loss += stats[1] as f64;
        agg.entropy += stats[2] as f64;
        agg.approx_kl += stats[3] as f64;
        agg.clip_frac += stats[4] as f64;
        agg.grad_norm += stats[5] as f64;
        agg.minibatches += 1;
    }

    fn update_xla(
        &mut self,
        exe: &Executable,
        batch: &Batch,
        rng: &mut Rng,
        agg: &mut UpdateStats,
    ) -> Result<()> {
        let np = self.params.len() as i64;
        let b = self.minibatch as i64;
        let n_obs = batch.n_obs as i64;

        // upload the optimizer state once; between minibatches the output
        // literals are fed straight back as inputs
        if self.lits.is_none() {
            self.lits = Some([
                literal_f32(&self.params, &[np])?,
                literal_f32(&self.adam_m, &[np])?,
                literal_f32(&self.adam_v, &[np])?,
            ]);
        }

        for _ in 0..self.epochs {
            for idx in batch.minibatch_indices(self.minibatch, rng) {
                let (obs, act, logp, adv, ret) = batch.gather(&idx);
                self.step += 1;
                let lits = self.lits.as_ref().unwrap();
                let args = [
                    lits[0].clone(),
                    lits[1].clone(),
                    lits[2].clone(),
                    scalar_f32(self.step as f32),
                    literal_f32(&obs, &[b, n_obs])?,
                    literal_f32(&act, &[b, 1])?,
                    literal_f32(&logp, &[b])?,
                    literal_f32(&adv, &[b])?,
                    literal_f32(&ret, &[b])?,
                ];
                let mut outs = exe.run(&args)?;
                anyhow::ensure!(outs.len() == 4, "ppo_update returned {}", outs.len());
                let stats = to_vec_f32(&outs[3])?;
                let v_lit = outs.remove(2);
                let m_lit = outs.remove(1);
                let p_lit = outs.remove(0);
                self.lits = Some([p_lit, m_lit, v_lit]);
                Self::accumulate(agg, &stats);
            }
        }
        // materialise the host mirrors once per update() call (the params
        // are broadcast to workers at iteration boundaries)
        if let Some(l) = &self.lits {
            self.params = to_vec_f32(&l[0])?;
            self.adam_m = to_vec_f32(&l[1])?;
            self.adam_v = to_vec_f32(&l[2])?;
        }
        Ok(())
    }

    fn update_native(
        &mut self,
        nu: &NativeUpdater,
        batch: &Batch,
        rng: &mut Rng,
        agg: &mut UpdateStats,
    ) -> Result<()> {
        anyhow::ensure!(
            nu.n_params() == self.params.len(),
            "native updater sized for {} params, trainer holds {}",
            nu.n_params(),
            self.params.len()
        );
        // the host vectors are authoritative on this path; stale device
        // mirrors from a previous XLA update must not be fed back
        self.lits = None;
        for _ in 0..self.epochs {
            for idx in batch.minibatch_indices(self.minibatch, rng) {
                let (obs, act, logp, adv, ret) = batch.gather(&idx);
                self.step += 1;
                let stats = nu.step(
                    self.step,
                    &mut self.params,
                    &mut self.adam_m,
                    &mut self.adam_v,
                    &obs,
                    &act,
                    &logp,
                    &adv,
                    &ret,
                )?;
                Self::accumulate(agg, &stats);
            }
        }
        Ok(())
    }

    /// Serialize the optimizer state for checkpointing: a 4-slot v1 header
    /// (magic, version, Adam step counter as two bit-cast f32s) followed by
    /// `(params | m | v)`.
    pub fn checkpoint(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(CKPT_HEADER + 3 * self.params.len());
        out.push(f32::from_bits(CKPT_MAGIC));
        out.push(f32::from_bits(CKPT_VERSION));
        out.push(f32::from_bits(self.step as u32));
        out.push(f32::from_bits((self.step >> 32) as u32));
        out.extend_from_slice(&self.params);
        out.extend_from_slice(&self.adam_m);
        out.extend_from_slice(&self.adam_v);
        out
    }

    /// Restore from a [`PpoTrainer::checkpoint`] blob. Reads the v1
    /// headered format and the legacy headerless `(params | m | v)` one;
    /// legacy blobs predate the step counter, so a resume from them starts
    /// at step 0 (maximal bias correction) like the seed always did.
    pub fn restore(&mut self, data: &[f32]) -> Result<()> {
        let n = self.params.len();
        let (step, body) = if data.len() == CKPT_HEADER + 3 * n && data[0].to_bits() == CKPT_MAGIC
        {
            let version = data[1].to_bits();
            anyhow::ensure!(version == CKPT_VERSION, "unsupported checkpoint version {version}");
            let step = data[2].to_bits() as u64 | ((data[3].to_bits() as u64) << 32);
            (step, &data[CKPT_HEADER..])
        } else if data.len() == 3 * n {
            (0, data)
        } else {
            anyhow::bail!(
                "checkpoint size {} (expected {} for v1 or {} legacy)",
                data.len(),
                CKPT_HEADER + 3 * n,
                3 * n
            );
        };
        self.step = step;
        self.params.copy_from_slice(&body[..n]);
        self.adam_m.copy_from_slice(&body[n..2 * n]);
        self.adam_v.copy_from_slice(&body[2 * n..]);
        self.lits = None; // invalidate device copies
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drl::native_update::PpoHyperParams;
    use crate::drl::{NativePolicy, Trajectory, Transition};

    fn dummy_drl(n_params: usize) -> DrlManifest {
        DrlManifest {
            n_obs: 4,
            n_act: 1,
            hidden: 8,
            n_params,
            minibatch: 16,
            lr: 3e-4,
            clip_eps: 0.2,
            gamma: 0.99,
            gae_lambda: 0.95,
            action_smoothing_beta: 0.4,
            reward_lift_penalty: 0.1,
            init_logstd: -0.5,
            param_layout: vec![],
            policy_apply_file: String::new(),
            policy_apply_batch_file: None,
            policy_batch: 1,
            ppo_update_file: String::new(),
        }
    }

    #[test]
    fn checkpoint_roundtrips_params_and_step() {
        let drl = dummy_drl(10);
        let mut t = PpoTrainer::new(&drl, vec![1.0; 10], 2);
        t.step = 11;
        let ck = t.checkpoint();
        assert_eq!(ck.len(), 4 + 30);
        let mut t2 = PpoTrainer::new(&drl, vec![0.0; 10], 2);
        t2.restore(&ck).unwrap();
        assert_eq!(t2.params, vec![1.0; 10]);
        assert_eq!(t2.adam_step(), 11, "Adam step must survive restore");
        assert!(t.restore(&[0.0; 7]).is_err());
    }

    #[test]
    fn restore_reads_legacy_headerless_blob() {
        let drl = dummy_drl(4);
        let mut t = PpoTrainer::new(&drl, vec![0.0; 4], 1);
        t.step = 5;
        let mut legacy = vec![2.0f32; 4];
        legacy.extend(vec![0.5f32; 4]);
        legacy.extend(vec![0.25f32; 4]);
        t.restore(&legacy).unwrap();
        assert_eq!(t.params, vec![2.0; 4]);
        assert_eq!(t.adam_step(), 0, "legacy blobs predate the step counter");
    }

    #[test]
    fn large_step_counter_survives_roundtrip() {
        let drl = dummy_drl(3);
        let mut t = PpoTrainer::new(&drl, vec![0.0; 3], 1);
        t.step = (1u64 << 40) + 12345; // far beyond f32's exact-integer range
        let ck = t.checkpoint();
        let mut t2 = PpoTrainer::new(&drl, vec![0.0; 3], 1);
        t2.restore(&ck).unwrap();
        assert_eq!(t2.adam_step(), (1u64 << 40) + 12345);
    }

    #[test]
    fn update_backend_parse_is_lenient_and_lists_accepted() {
        assert_eq!(UpdateBackendKind::parse(" XLA ").unwrap(), UpdateBackendKind::Xla);
        assert_eq!(UpdateBackendKind::parse("Native").unwrap(), UpdateBackendKind::Native);
        for k in [UpdateBackendKind::Xla, UpdateBackendKind::Native] {
            assert_eq!(UpdateBackendKind::parse(k.name()).unwrap(), k);
        }
        let err = UpdateBackendKind::parse("tpu").unwrap_err().to_string();
        assert!(err.contains("xla") && err.contains("native"), "{err}");
    }

    #[test]
    fn native_update_steps_and_counts_minibatches() {
        let (o, h) = (4, 8);
        let net = NativePolicy::new(o, h);
        let params = net.init_params(1);
        let mut t = PpoTrainer::with_minibatch(params.clone(), 8, 2);
        let nu = NativeUpdater::new(o, h, PpoHyperParams::default());
        let mut rng = Rng::new(2);
        let traj = Trajectory {
            transitions: (0..12)
                .map(|_| Transition {
                    obs: (0..o).map(|_| rng.normal() as f32).collect(),
                    action: rng.normal() * 0.1,
                    logp: -0.5,
                    reward: rng.normal() * 0.1,
                    value: 0.0,
                })
                .collect(),
            last_value: 0.0,
            env_id: 0,
        };
        let batch = Batch::assemble(&[traj], o, 0.99, 0.95);
        let s = t.update(TrainerBackend::Native(&nu), &batch, &mut rng).unwrap();
        // 12 samples at minibatch 8 -> 2 (padded) minibatches x 2 epochs
        assert_eq!(s.minibatches, 4);
        assert_eq!(t.adam_step(), 4);
        assert!(s.pi_loss.is_finite());
        assert!(s.grad_norm > 0.0, "gradient vanished");
        assert_ne!(t.params, params, "no parameter movement");
    }
}
