//! PPO trainer: owns the flat parameter/Adam-state buffers and drives the
//! `ppo_update` artifact over shuffled minibatches for K epochs.

use std::time::Instant;

use anyhow::Result;

use crate::drl::buffer::Batch;
use crate::runtime::{literal_f32, scalar_f32, to_vec_f32, DrlManifest, Executable};
use crate::util::rng::Rng;

/// Aggregated statistics over one iteration's update epochs.
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateStats {
    pub pi_loss: f64,
    pub v_loss: f64,
    pub entropy: f64,
    pub approx_kl: f64,
    pub clip_frac: f64,
    pub grad_norm: f64,
    pub minibatches: usize,
    pub wall_s: f64,
}

/// Master-side PPO optimizer state: the flat parameter vector, the Adam
/// moments, and their device-resident mirrors between minibatches.
pub struct PpoTrainer {
    pub params: Vec<f32>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    /// device-resident copies fed back between minibatches (perf: saves
    /// ~8 MB of host memcpy per minibatch, EXPERIMENTS.md section Perf)
    lits: Option<[xla::Literal; 3]>,
    /// 1-based Adam step counter (bias correction).
    step: u64,
    minibatch: usize,
    epochs: usize,
}

impl PpoTrainer {
    /// Fresh optimizer over `params` (zero Adam moments, step 0).
    pub fn new(drl: &DrlManifest, params: Vec<f32>, epochs: usize) -> Self {
        let n = params.len();
        assert_eq!(n, drl.n_params);
        PpoTrainer {
            params,
            adam_m: vec![0.0; n],
            adam_v: vec![0.0; n],
            lits: None,
            step: 0,
            minibatch: drl.minibatch,
            epochs,
        }
    }

    /// 1-based Adam step counter (bias correction state).
    pub fn adam_step(&self) -> u64 {
        self.step
    }

    /// Run `epochs` passes of shuffled minibatch updates over the batch.
    pub fn update(&mut self, exe: &Executable, batch: &Batch, rng: &mut Rng) -> Result<UpdateStats> {
        let t0 = Instant::now();
        let mut agg = UpdateStats::default();
        let np = self.params.len() as i64;
        let b = self.minibatch as i64;
        let n_obs = batch.n_obs as i64;

        // upload the optimizer state once; between minibatches the output
        // literals are fed straight back as inputs
        if self.lits.is_none() {
            self.lits = Some([
                literal_f32(&self.params, &[np])?,
                literal_f32(&self.adam_m, &[np])?,
                literal_f32(&self.adam_v, &[np])?,
            ]);
        }

        for _ in 0..self.epochs {
            for idx in batch.minibatch_indices(self.minibatch, rng) {
                let (obs, act, logp, adv, ret) = batch.gather(&idx);
                self.step += 1;
                let lits = self.lits.as_ref().unwrap();
                let args = [
                    lits[0].clone(),
                    lits[1].clone(),
                    lits[2].clone(),
                    scalar_f32(self.step as f32),
                    literal_f32(&obs, &[b, n_obs])?,
                    literal_f32(&act, &[b, 1])?,
                    literal_f32(&logp, &[b])?,
                    literal_f32(&adv, &[b])?,
                    literal_f32(&ret, &[b])?,
                ];
                let mut outs = exe.run(&args)?;
                anyhow::ensure!(outs.len() == 4, "ppo_update returned {}", outs.len());
                let stats = to_vec_f32(&outs[3])?;
                let v_lit = outs.remove(2);
                let m_lit = outs.remove(1);
                let p_lit = outs.remove(0);
                self.lits = Some([p_lit, m_lit, v_lit]);
                agg.pi_loss += stats[0] as f64;
                agg.v_loss += stats[1] as f64;
                agg.entropy += stats[2] as f64;
                agg.approx_kl += stats[3] as f64;
                agg.clip_frac += stats[4] as f64;
                agg.grad_norm += stats[5] as f64;
                agg.minibatches += 1;
            }
        }
        // materialise the host mirrors once per update() call (the params
        // are broadcast to workers at iteration boundaries)
        if let Some(l) = &self.lits {
            self.params = to_vec_f32(&l[0])?;
            self.adam_m = to_vec_f32(&l[1])?;
            self.adam_v = to_vec_f32(&l[2])?;
        }
        let k = agg.minibatches.max(1) as f64;
        agg.pi_loss /= k;
        agg.v_loss /= k;
        agg.entropy /= k;
        agg.approx_kl /= k;
        agg.clip_frac /= k;
        agg.grad_norm /= k;
        agg.wall_s = t0.elapsed().as_secs_f64();
        Ok(agg)
    }

    /// Serialize (params | m | v) for checkpointing.
    pub fn checkpoint(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(3 * self.params.len());
        out.extend_from_slice(&self.params);
        out.extend_from_slice(&self.adam_m);
        out.extend_from_slice(&self.adam_v);
        out
    }

    /// Restore (params | m | v) from a [`PpoTrainer::checkpoint`] blob.
    pub fn restore(&mut self, data: &[f32]) -> Result<()> {
        let n = self.params.len();
        anyhow::ensure!(data.len() == 3 * n, "checkpoint size {}", data.len());
        self.params.copy_from_slice(&data[..n]);
        self.adam_m.copy_from_slice(&data[n..2 * n]);
        self.adam_v.copy_from_slice(&data[2 * n..]);
        self.lits = None; // invalidate device copies
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_drl(n_params: usize) -> DrlManifest {
        DrlManifest {
            n_obs: 4,
            n_act: 1,
            hidden: 8,
            n_params,
            minibatch: 16,
            lr: 3e-4,
            clip_eps: 0.2,
            gamma: 0.99,
            gae_lambda: 0.95,
            action_smoothing_beta: 0.4,
            reward_lift_penalty: 0.1,
            init_logstd: -0.5,
            param_layout: vec![],
            policy_apply_file: String::new(),
            policy_apply_batch_file: None,
            policy_batch: 1,
            ppo_update_file: String::new(),
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let drl = dummy_drl(10);
        let mut t = PpoTrainer::new(&drl, vec![1.0; 10], 2);
        let ck = t.checkpoint();
        assert_eq!(ck.len(), 30);
        let mut t2 = PpoTrainer::new(&drl, vec![0.0; 10], 2);
        t2.restore(&ck).unwrap();
        assert_eq!(t2.params, vec![1.0; 10]);
        assert!(t.restore(&[0.0; 7]).is_err());
    }
}
