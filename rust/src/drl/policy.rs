//! Policy serving: wrap the `policy_apply` artifact + Gaussian sampling.

use anyhow::Result;

use crate::runtime::{literal_f32, to_vec_f32, Executable, Runtime};
use crate::util::rng::Rng;

const LOG_2PI: f64 = 1.8378770664093453;

#[derive(Clone, Debug)]
pub struct PolicyOutput {
    pub mu: f64,
    pub logstd: f64,
    pub value: f64,
}

pub struct Policy {
    n_obs: usize,
}

impl Policy {
    pub fn new(n_obs: usize) -> Self {
        Policy { n_obs }
    }

    /// Run the policy network on a single observation (serving path, B=1).
    pub fn apply(
        &self,
        exe: &Executable,
        params: &[f32],
        obs: &[f32],
    ) -> Result<PolicyOutput> {
        anyhow::ensure!(obs.len() == self.n_obs, "obs len {}", obs.len());
        let args = [
            literal_f32(params, &[params.len() as i64])?,
            literal_f32(obs, &[1, self.n_obs as i64])?,
        ];
        let outs = exe.run(&args)?;
        anyhow::ensure!(outs.len() == 3, "policy_apply returned {}", outs.len());
        let mu = to_vec_f32(&outs[0])?[0] as f64;
        let logstd = to_vec_f32(&outs[1])?[0] as f64;
        let value = to_vec_f32(&outs[2])?[0] as f64;
        Ok(PolicyOutput { mu, logstd, value })
    }

    /// Sample a ~ N(mu, std); returns (action, logp).
    pub fn sample(&self, out: &PolicyOutput, rng: &mut Rng) -> (f64, f64) {
        let std = out.logstd.exp();
        let z = rng.normal();
        let a = out.mu + std * z;
        let logp = -0.5 * z * z - out.logstd - 0.5 * LOG_2PI;
        (a, logp)
    }

    /// Log density of an arbitrary action under (mu, logstd).
    pub fn logp(&self, action: f64, out: &PolicyOutput) -> f64 {
        let std = out.logstd.exp();
        let z = (action - out.mu) / std;
        -0.5 * z * z - out.logstd - 0.5 * LOG_2PI
    }
}

/// Device-resident serving session: the policy parameters are uploaded
/// once per episode and reused for every actuation period (perf: the
/// parameters are 1.4 MB, the observation 600 B — see EXPERIMENTS.md
/// section Perf).
pub struct PolicySession {
    params_buf: xla::PjRtBuffer,
    n_obs: usize,
}

impl PolicySession {
    pub fn new(rt: &Runtime, params: &[f32], n_obs: usize) -> Result<Self> {
        Ok(PolicySession {
            params_buf: rt.upload_f32(params, &[params.len()])?,
            n_obs,
        })
    }

    pub fn apply(&self, rt: &Runtime, exe: &Executable, obs: &[f32]) -> Result<PolicyOutput> {
        anyhow::ensure!(obs.len() == self.n_obs, "obs len {}", obs.len());
        let obs_buf = rt.upload_f32(obs, &[1, self.n_obs])?;
        let outs = exe.run_b(&[&self.params_buf, &obs_buf])?;
        anyhow::ensure!(outs.len() == 3, "policy_apply returned {}", outs.len());
        Ok(PolicyOutput {
            mu: to_vec_f32(&outs[0])?[0] as f64,
            logstd: to_vec_f32(&outs[1])?[0] as f64,
            value: to_vec_f32(&outs[2])?[0] as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_logp_consistent() {
        let p = Policy::new(4);
        let out = PolicyOutput {
            mu: 0.3,
            logstd: -0.5,
            value: 0.0,
        };
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let (a, lp) = p.sample(&out, &mut rng);
            let lp2 = p.logp(a, &out);
            assert!((lp - lp2).abs() < 1e-12, "{lp} vs {lp2}");
        }
    }

    #[test]
    fn sample_distribution_moments() {
        let p = Policy::new(1);
        let out = PolicyOutput {
            mu: 1.0,
            logstd: 0.0,
            value: 0.0,
        };
        let mut rng = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.sample(&out, &mut rng).0).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "{mean}");
    }
}
