//! Policy serving: the `policy_apply` XLA artifact, a pure-Rust twin of
//! the same MLP for artifact-free scenarios, and Gaussian sampling.
//!
//! Three serving paths, slowest to fastest on the multi-env hot loop:
//! * [`Policy::apply`] — one XLA call per observation, parameters uploaded
//!   every call (simple; used by one-shot CLI commands).
//! * [`PolicySession::apply`] — one XLA call per observation with the
//!   parameters resident on device for the whole episode (the per-env
//!   worker fast path).
//! * [`NativePolicy`] / the coordinator's `PolicyServer` — centralised
//!   inference over the *whole environment batch* per actuation period
//!   (the paper's hybrid-parallelization axis; one forward pass instead of
//!   `N_envs` dispatches).

use anyhow::Result;

use crate::runtime::{literal_f32, to_vec_f32, DrlManifest, Executable, Runtime};
use crate::util::rng::Rng;

const LOG_2PI: f64 = 1.8378770664093453;

/// One policy evaluation: Gaussian head mean/log-std plus the value head.
#[derive(Clone, Debug)]
pub struct PolicyOutput {
    pub mu: f64,
    pub logstd: f64,
    pub value: f64,
}

/// Which engine evaluates the policy network inside an env worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyBackendKind {
    /// The AOT-compiled `policy_apply` artifact on a PJRT runtime.
    Xla,
    /// The pure-Rust [`NativePolicy`] twin (no artifacts required).
    Native,
}

impl PolicyBackendKind {
    /// Parse a CLI/config string (trimmed, case-insensitive); the error
    /// lists the accepted values.
    pub fn parse(s: &str) -> Result<PolicyBackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "xla" => Ok(PolicyBackendKind::Xla),
            "native" => Ok(PolicyBackendKind::Native),
            _ => anyhow::bail!("unknown policy backend {s:?} (accepted: xla, native)"),
        }
    }

    /// Canonical name, inverse of [`PolicyBackendKind::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            PolicyBackendKind::Xla => "xla",
            PolicyBackendKind::Native => "native",
        }
    }
}

/// Stateless helper around the XLA serving path: shape checks, sampling,
/// and log-density math shared by every serving engine.
pub struct Policy {
    n_obs: usize,
}

impl Policy {
    /// `n_obs` is the observation width the policy artifact was lowered at.
    pub fn new(n_obs: usize) -> Self {
        Policy { n_obs }
    }

    /// Run the policy network on a single observation (serving path, B=1).
    pub fn apply(
        &self,
        exe: &Executable,
        params: &[f32],
        obs: &[f32],
    ) -> Result<PolicyOutput> {
        anyhow::ensure!(obs.len() == self.n_obs, "obs len {}", obs.len());
        let args = [
            literal_f32(params, &[params.len() as i64])?,
            literal_f32(obs, &[1, self.n_obs as i64])?,
        ];
        let outs = exe.run(&args)?;
        anyhow::ensure!(outs.len() == 3, "policy_apply returned {}", outs.len());
        let mu = to_vec_f32(&outs[0])?[0] as f64;
        let logstd = to_vec_f32(&outs[1])?[0] as f64;
        let value = to_vec_f32(&outs[2])?[0] as f64;
        Ok(PolicyOutput { mu, logstd, value })
    }

    /// Sample a ~ N(mu, std); returns (action, logp).
    pub fn sample(&self, out: &PolicyOutput, rng: &mut Rng) -> (f64, f64) {
        let std = out.logstd.exp();
        let z = rng.normal();
        let a = out.mu + std * z;
        let logp = -0.5 * z * z - out.logstd - 0.5 * LOG_2PI;
        (a, logp)
    }

    /// Log density of an arbitrary action under (mu, logstd).
    pub fn logp(&self, action: f64, out: &PolicyOutput) -> f64 {
        let std = out.logstd.exp();
        let z = (action - out.mu) / std;
        -0.5 * z * z - out.logstd - 0.5 * LOG_2PI
    }
}

/// Device-resident serving session: the policy parameters are uploaded
/// once per episode and reused for every actuation period (perf: the
/// parameters are 1.4 MB, the observation 600 B — see EXPERIMENTS.md
/// section Perf).
pub struct PolicySession {
    params_buf: xla::PjRtBuffer,
    n_obs: usize,
}

impl PolicySession {
    /// Upload `params` once; `n_obs` must match the lowered artifact.
    pub fn new(rt: &Runtime, params: &[f32], n_obs: usize) -> Result<Self> {
        Ok(PolicySession {
            params_buf: rt.upload_f32(params, &[params.len()])?,
            n_obs,
        })
    }

    /// One B=1 forward pass against the device-resident parameters.
    pub fn apply(&self, rt: &Runtime, exe: &Executable, obs: &[f32]) -> Result<PolicyOutput> {
        anyhow::ensure!(obs.len() == self.n_obs, "obs len {}", obs.len());
        let obs_buf = rt.upload_f32(obs, &[1, self.n_obs])?;
        let outs = exe.run_b(&[&self.params_buf, &obs_buf])?;
        anyhow::ensure!(outs.len() == 3, "policy_apply returned {}", outs.len());
        Ok(PolicyOutput {
            mu: to_vec_f32(&outs[0])?[0] as f64,
            logstd: to_vec_f32(&outs[1])?[0] as f64,
            value: to_vec_f32(&outs[2])?[0] as f64,
        })
    }
}

/// Pure-Rust twin of the `policy_apply` MLP: tanh(W1) -> tanh(W2) ->
/// {mu, logstd, value} heads over the *same flat parameter vector* the XLA
/// artifact consumes (layout: `python/compile/model.py::param_layout`).
///
/// Two jobs:
/// * serve artifact-free scenarios (the surrogate env in CI and scaling
///   studies) — no PJRT client, no HLO compile;
/// * provide the batched central-inference path with a forward pass whose
///   per-row arithmetic is *bitwise identical* to its single-row path, so
///   per-env and batched modes produce identical actions for a fixed seed
///   (asserted in rust/tests/scenario_registry.rs).
///
/// Only `n_act == 1` is supported, matching every artifact this repo lowers.
#[derive(Clone, Debug)]
pub struct NativePolicy {
    n_obs: usize,
    hidden: usize,
}

impl NativePolicy {
    pub fn new(n_obs: usize, hidden: usize) -> Self {
        NativePolicy { n_obs, hidden }
    }

    /// Dimensions from the AOT manifest (single source of truth).
    pub fn from_manifest(drl: &DrlManifest) -> Self {
        NativePolicy::new(drl.n_obs, drl.hidden)
    }

    pub fn n_obs(&self) -> usize {
        self.n_obs
    }

    /// Flat-vector length: w1,b1,w2,b2,wmu,bmu,logstd,wv,bv (n_act = 1).
    pub fn n_params(&self) -> usize {
        let (o, h) = (self.n_obs, self.hidden);
        (o * h + h) + (h * h + h) + (h + 1) + 1 + (h + 1)
    }

    /// Glorot-scaled random parameters for artifact-free runs: zero biases,
    /// a tiny `wmu` head (actions start near zero, like the paper's agent)
    /// and `logstd = -0.5`. Deterministic in `seed`; NOT bit-identical to
    /// `python/compile/model.py::init_params` (different RNG), only
    /// statistically equivalent.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let (o, h) = (self.n_obs, self.hidden);
        let mut rng = Rng::new(seed);
        let mut flat = vec![0.0f32; self.n_params()];
        let mut off = 0usize;
        let mut fill = |flat: &mut [f32], off: &mut usize, n: usize, scale: f64| {
            for x in flat[*off..*off + n].iter_mut() {
                *x = (rng.normal() * scale) as f32;
            }
            *off += n;
        };
        fill(&mut flat, &mut off, o * h, (2.0 / (o + h) as f64).sqrt()); // w1
        off += h; // b1 = 0
        fill(&mut flat, &mut off, h * h, (2.0 / (2 * h) as f64).sqrt()); // w2
        off += h; // b2 = 0
        fill(&mut flat, &mut off, h, 0.01); // wmu
        off += 1; // bmu = 0
        flat[off] = -0.5; // logstd
        off += 1;
        fill(&mut flat, &mut off, h, (2.0 / (h + 1) as f64).sqrt()); // wv
        off += 1; // bv = 0
        debug_assert_eq!(off, self.n_params());
        flat
    }

    /// Forward one observation. f32 accumulation in the layout's natural
    /// order; the batched path reuses this row kernel unchanged.
    pub fn apply(&self, params: &[f32], obs: &[f32]) -> Result<PolicyOutput> {
        anyhow::ensure!(obs.len() == self.n_obs, "obs len {}", obs.len());
        anyhow::ensure!(
            params.len() == self.n_params(),
            "params len {} != {} for a {}x{} net",
            params.len(),
            self.n_params(),
            self.n_obs,
            self.hidden
        );
        Ok(self.forward_row(params, obs))
    }

    /// One batched forward pass: every observation of the environment batch
    /// evaluated in a single call (the coordinator's sync-barrier path).
    pub fn apply_batch(&self, params: &[f32], obs: &[Vec<f32>]) -> Result<Vec<PolicyOutput>> {
        anyhow::ensure!(
            params.len() == self.n_params(),
            "params len {} != {}",
            params.len(),
            self.n_params()
        );
        let mut out = Vec::with_capacity(obs.len());
        for row in obs {
            anyhow::ensure!(row.len() == self.n_obs, "obs len {}", row.len());
            out.push(self.forward_row(params, row));
        }
        Ok(out)
    }

    fn forward_row(&self, params: &[f32], obs: &[f32]) -> PolicyOutput {
        let (o, h) = (self.n_obs, self.hidden);
        let off_w1 = 0;
        let off_b1 = off_w1 + o * h;
        let off_w2 = off_b1 + h;
        let off_b2 = off_w2 + h * h;
        let off_wmu = off_b2 + h;
        let off_bmu = off_wmu + h;
        let off_logstd = off_bmu + 1;
        let off_wv = off_logstd + 1;
        let off_bv = off_wv + h;

        // h1 = tanh(obs @ W1 + b1); W1 is (o, h) row-major
        let mut h1 = vec![0.0f32; h];
        for (j, h1j) in h1.iter_mut().enumerate() {
            let mut acc = params[off_b1 + j];
            for (i, &x) in obs.iter().enumerate() {
                acc += x * params[off_w1 + i * h + j];
            }
            *h1j = acc.tanh();
        }
        // h2 = tanh(h1 @ W2 + b2)
        let mut h2 = vec![0.0f32; h];
        for (j, h2j) in h2.iter_mut().enumerate() {
            let mut acc = params[off_b2 + j];
            for (k, &x) in h1.iter().enumerate() {
                acc += x * params[off_w2 + k * h + j];
            }
            *h2j = acc.tanh();
        }
        // heads
        let mut mu = params[off_bmu];
        let mut value = params[off_bv];
        for (j, &x) in h2.iter().enumerate() {
            mu += x * params[off_wmu + j];
            value += x * params[off_wv + j];
        }
        PolicyOutput {
            mu: mu as f64,
            logstd: params[off_logstd] as f64,
            value: value as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_logp_consistent() {
        let p = Policy::new(4);
        let out = PolicyOutput {
            mu: 0.3,
            logstd: -0.5,
            value: 0.0,
        };
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let (a, lp) = p.sample(&out, &mut rng);
            let lp2 = p.logp(a, &out);
            assert!((lp - lp2).abs() < 1e-12, "{lp} vs {lp2}");
        }
    }

    #[test]
    fn sample_distribution_moments() {
        let p = Policy::new(1);
        let out = PolicyOutput {
            mu: 1.0,
            logstd: 0.0,
            value: 0.0,
        };
        let mut rng = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.sample(&out, &mut rng).0).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "{mean}");
    }

    #[test]
    fn native_init_deterministic_and_sized() {
        let net = NativePolicy::new(8, 16);
        let a = net.init_params(3);
        let b = net.init_params(3);
        assert_eq!(a.len(), net.n_params());
        assert_eq!(a, b);
        assert_ne!(a, net.init_params(4));
    }

    #[test]
    fn native_batch_matches_single_bitwise() {
        let net = NativePolicy::new(6, 12);
        let params = net.init_params(11);
        let mut rng = Rng::new(5);
        let obs: Vec<Vec<f32>> = (0..7)
            .map(|_| (0..6).map(|_| rng.normal() as f32).collect())
            .collect();
        let batch = net.apply_batch(&params, &obs).unwrap();
        for (row, out) in obs.iter().zip(&batch) {
            let single = net.apply(&params, row).unwrap();
            assert_eq!(single.mu, out.mu);
            assert_eq!(single.logstd, out.logstd);
            assert_eq!(single.value, out.value);
        }
    }

    #[test]
    fn native_rejects_bad_shapes() {
        let net = NativePolicy::new(4, 8);
        let params = net.init_params(0);
        assert!(net.apply(&params, &[0.0; 3]).is_err());
        assert!(net.apply(&params[..10], &[0.0; 4]).is_err());
    }

    #[test]
    fn backend_kind_parse_roundtrip() {
        for k in [PolicyBackendKind::Xla, PolicyBackendKind::Native] {
            assert_eq!(PolicyBackendKind::parse(k.name()).unwrap(), k);
        }
        // lenient to whitespace and case, like every parse in this crate
        assert_eq!(
            PolicyBackendKind::parse(" Native ").unwrap(),
            PolicyBackendKind::Native
        );
        let err = PolicyBackendKind::parse("tpu").unwrap_err().to_string();
        assert!(err.contains("xla") && err.contains("native"), "{err}");
    }
}
