//! Native PPO update backend: the pure-Rust half of the trainer's
//! `TrainerBackend` axis (the artifact-free training path's tentpole).
//!
//! One Adam minibatch step of the clipped-surrogate PPO loss — forward plus
//! a hand-derived backward pass through the 2-layer tanh Gaussian MLP — over
//! the *same flat parameter vector* and layout
//! (`python/compile/model.py::param_layout`) that the AOT `ppo_update`
//! artifact consumes. The XLA artifact stays the performance reference;
//! this backend makes `train()` (under every sync policy) runnable, testable and
//! benchmarkable with zero compiled artifacts, and
//! `rust/tests/train_smoke.rs::native_vs_xla_update_equivalence` asserts
//! gradient-level agreement between the two whenever artifacts exist.
//!
//! Loss (mirrors `python/compile/model.py::ppo_loss` term by term):
//!
//! ```text
//! total = pg_loss + vf_coef * v_loss - ent_coef * entropy
//! ```
//!
//! with the Eq. 10 clipped surrogate, a squared-error value loss and the
//! closed-form Gaussian entropy. The stats layout matches the artifact:
//! `[pg_loss, v_loss, entropy, approx_kl, clip_frac, grad_norm]`
//! (`grad_norm` is the pre-clipping global norm, exactly like the artifact,
//! which records the norm but never clips).
//!
//! Only `n_act == 1` is supported, like every artifact this repo lowers.

use anyhow::Result;

use crate::runtime::DrlManifest;

const LOG_2PI: f64 = 1.8378770664093453;

/// GAE discount used by artifact-free runs (the manifest records it when
/// artifacts are present; single source: python/compile/configs.py).
pub const DEFAULT_GAMMA: f64 = 0.99;
/// GAE lambda used by artifact-free runs (see [`DEFAULT_GAMMA`]).
pub const DEFAULT_GAE_LAMBDA: f64 = 0.95;

/// PPO/Adam hyper-parameters of the native update step.
///
/// `lr` and `clip_eps` travel in the manifest; the remaining constants are
/// baked into the lowered artifact, so their defaults here mirror
/// `python/compile/configs.py::DrlConfig` (the single source of truth).
#[derive(Clone, Copy, Debug)]
pub struct PpoHyperParams {
    pub lr: f64,
    pub clip_eps: f64,
    pub vf_coef: f64,
    pub ent_coef: f64,
    pub adam_b1: f64,
    pub adam_b2: f64,
    pub adam_eps: f64,
    /// Global-norm gradient clipping threshold; `None` disables clipping,
    /// matching the XLA artifact (which reports the norm but never clips).
    pub max_grad_norm: Option<f64>,
}

impl Default for PpoHyperParams {
    fn default() -> Self {
        PpoHyperParams {
            lr: 3e-4,
            clip_eps: 0.2,
            vf_coef: 0.5,
            ent_coef: 0.01,
            adam_b1: 0.9,
            adam_b2: 0.999,
            adam_eps: 1e-8,
            max_grad_norm: None,
        }
    }
}

impl PpoHyperParams {
    /// Adopt what the manifest records (lr, clip_eps); everything else is
    /// baked into the artifact and mirrored from configs.py by `default()`.
    pub fn from_manifest(drl: &DrlManifest) -> Self {
        PpoHyperParams {
            lr: drl.lr,
            clip_eps: drl.clip_eps,
            ..PpoHyperParams::default()
        }
    }
}

/// Flat-vector offsets of the 2x`hidden` tanh MLP (n_act = 1), shared by
/// the forward and backward passes. Must stay in lockstep with
/// `NativePolicy::forward_row` and `model.py::param_layout`.
struct Layout {
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
    wmu: usize,
    bmu: usize,
    logstd: usize,
    wv: usize,
    bv: usize,
    n_params: usize,
}

impl Layout {
    fn new(o: usize, h: usize) -> Layout {
        let w1 = 0;
        let b1 = w1 + o * h;
        let w2 = b1 + h;
        let b2 = w2 + h * h;
        let wmu = b2 + h;
        let bmu = wmu + h;
        let logstd = bmu + 1;
        let wv = logstd + 1;
        let bv = wv + h;
        Layout {
            w1,
            b1,
            w2,
            b2,
            wmu,
            bmu,
            logstd,
            wv,
            bv,
            n_params: bv + 1,
        }
    }
}

/// Scale `g` in place so its global L2 norm is at most `max_norm`; returns
/// the pre-clipping norm.
pub fn clip_global_norm(g: &mut [f32], max_norm: f64) -> f64 {
    let norm = g.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt();
    if norm > max_norm && norm > 0.0 {
        let scale = (max_norm / norm) as f32;
        for x in g.iter_mut() {
            *x *= scale;
        }
    }
    norm
}

/// One Adam minibatch step of PPO in pure Rust (see module docs).
pub struct NativeUpdater {
    n_obs: usize,
    hidden: usize,
    hp: PpoHyperParams,
}

impl NativeUpdater {
    pub fn new(n_obs: usize, hidden: usize, hp: PpoHyperParams) -> Self {
        NativeUpdater { n_obs, hidden, hp }
    }

    /// Dimensions + recorded hyper-parameters from the AOT manifest.
    pub fn from_manifest(drl: &DrlManifest) -> Self {
        NativeUpdater::new(drl.n_obs, drl.hidden, PpoHyperParams::from_manifest(drl))
    }

    pub fn n_obs(&self) -> usize {
        self.n_obs
    }

    pub fn hp(&self) -> &PpoHyperParams {
        &self.hp
    }

    /// Flat parameter-vector length (same formula as `NativePolicy`).
    pub fn n_params(&self) -> usize {
        Layout::new(self.n_obs, self.hidden).n_params
    }

    /// Gradient of the PPO loss over one minibatch of `act.len()` rows.
    /// Returns `(grad, stats)` with the artifact's stats layout
    /// `[pg_loss, v_loss, entropy, approx_kl, clip_frac, grad_norm]`.
    pub fn grad(
        &self,
        params: &[f32],
        obs: &[f32],
        act: &[f32],
        logp_old: &[f32],
        adv: &[f32],
        ret: &[f32],
    ) -> Result<(Vec<f32>, [f32; 6])> {
        let (o, h) = (self.n_obs, self.hidden);
        let lay = Layout::new(o, h);
        let b = act.len();
        anyhow::ensure!(b > 0, "empty minibatch");
        anyhow::ensure!(
            params.len() == lay.n_params,
            "params len {} != {} for a {o}x{h} net",
            params.len(),
            lay.n_params
        );
        anyhow::ensure!(obs.len() == b * o, "obs len {} != {b}x{o}", obs.len());
        anyhow::ensure!(
            logp_old.len() == b && adv.len() == b && ret.len() == b,
            "ragged minibatch"
        );

        let clip = self.hp.clip_eps as f32;
        let vf_coef = self.hp.vf_coef as f32;
        let bf = b as f32;
        let log2pi = LOG_2PI as f32;
        let logstd = params[lay.logstd];
        let std = logstd.exp();

        let mut g = vec![0.0f32; lay.n_params];
        let mut h1 = vec![0.0f32; h];
        let mut h2 = vec![0.0f32; h];
        let mut dh1 = vec![0.0f32; h];
        let mut dh2 = vec![0.0f32; h];

        let mut pg_acc = 0.0f32;
        let mut v_acc = 0.0f32;
        let mut kl_acc = 0.0f32;
        let mut clip_acc = 0.0f32;
        let mut g_logstd = 0.0f32;

        for r in 0..b {
            let row = &obs[r * o..(r + 1) * o];

            // ---- forward (identical arithmetic to NativePolicy::forward_row)
            for (j, h1j) in h1.iter_mut().enumerate() {
                let mut acc = params[lay.b1 + j];
                for (i, &x) in row.iter().enumerate() {
                    acc += x * params[lay.w1 + i * h + j];
                }
                *h1j = acc.tanh();
            }
            for (j, h2j) in h2.iter_mut().enumerate() {
                let mut acc = params[lay.b2 + j];
                for (k, &x) in h1.iter().enumerate() {
                    acc += x * params[lay.w2 + k * h + j];
                }
                *h2j = acc.tanh();
            }
            let mut mu = params[lay.bmu];
            let mut val = params[lay.bv];
            for (j, &x) in h2.iter().enumerate() {
                mu += x * params[lay.wmu + j];
                val += x * params[lay.wv + j];
            }

            // ---- loss terms (model.py::ppo_loss, n_act = 1)
            let z = (act[r] - mu) / std;
            let logp = -0.5 * z * z - logstd - 0.5 * log2pi;
            let ratio = (logp - logp_old[r]).exp();
            let unclipped = ratio * adv[r];
            let clipped = ratio.clamp(1.0 - clip, 1.0 + clip) * adv[r];
            pg_acc += unclipped.min(clipped);
            let v_err = val - ret[r];
            v_acc += v_err * v_err;
            kl_acc += logp_old[r] - logp;
            if (ratio - 1.0).abs() > clip {
                clip_acc += 1.0;
            }

            // ---- d(total)/d(mu, val, logstd) for this row. The surrogate
            // min() propagates through the active branch; inside the clip
            // interval both branches coincide (clamp is the identity), so
            // only the truly-clipped case gates the gradient to zero.
            let d_ratio = if unclipped <= clipped { -adv[r] / bf } else { 0.0 };
            let d_logp = d_ratio * ratio;
            let g_mu = d_logp * (z / std);
            g_logstd += d_logp * (z * z - 1.0);
            let g_val = vf_coef * 2.0 * v_err / bf;

            // ---- backprop through the heads and both tanh layers
            g[lay.bmu] += g_mu;
            g[lay.bv] += g_val;
            for (j, &h2j) in h2.iter().enumerate() {
                g[lay.wmu + j] += g_mu * h2j;
                g[lay.wv + j] += g_val * h2j;
                dh2[j] = (g_mu * params[lay.wmu + j] + g_val * params[lay.wv + j])
                    * (1.0 - h2j * h2j);
            }
            for (j, &d) in dh2.iter().enumerate() {
                g[lay.b2 + j] += d;
            }
            for (k, &h1k) in h1.iter().enumerate() {
                let wrow = lay.w2 + k * h;
                let mut acc = 0.0f32;
                for (j, &d) in dh2.iter().enumerate() {
                    g[wrow + j] += h1k * d;
                    acc += params[wrow + j] * d;
                }
                dh1[k] = acc * (1.0 - h1k * h1k);
            }
            for (k, &d) in dh1.iter().enumerate() {
                g[lay.b1 + k] += d;
            }
            for (i, &x) in row.iter().enumerate() {
                let wrow = lay.w1 + i * h;
                for (k, &d) in dh1.iter().enumerate() {
                    g[wrow + k] += x * d;
                }
            }
        }

        // entropy = logstd + 0.5*(ln(2*pi) + 1) for the 1-D Gaussian; its
        // gradient is the only term besides the surrogate touching logstd
        let entropy = logstd + 0.5 * (log2pi + 1.0);
        g[lay.logstd] = g_logstd - self.hp.ent_coef as f32;

        let norm2: f64 = g.iter().map(|&x| x as f64 * x as f64).sum::<f64>();
        let stats = [
            -pg_acc / bf,
            v_acc / bf,
            entropy,
            kl_acc / bf,
            clip_acc / bf,
            norm2.sqrt() as f32,
        ];
        Ok((g, stats))
    }

    /// One Adam step in place over `(params, m, v)`; `t` is the 1-based
    /// step counter (bias correction), exactly like the artifact's scalar
    /// input. Returns the minibatch stats (see [`NativeUpdater::grad`]).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        t: u64,
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        obs: &[f32],
        act: &[f32],
        logp_old: &[f32],
        adv: &[f32],
        ret: &[f32],
    ) -> Result<[f32; 6]> {
        anyhow::ensure!(
            m.len() == params.len() && v.len() == params.len(),
            "optimizer state size mismatch"
        );
        anyhow::ensure!(t >= 1, "Adam step counter is 1-based");
        let (mut g, stats) = self.grad(params, obs, act, logp_old, adv, ret)?;
        if let Some(maxn) = self.hp.max_grad_norm {
            clip_global_norm(&mut g, maxn);
        }
        let b1 = self.hp.adam_b1 as f32;
        let b2 = self.hp.adam_b2 as f32;
        let eps = self.hp.adam_eps as f32;
        let lr = self.hp.lr as f32;
        let bc1 = 1.0 - b1.powf(t as f32);
        let bc2 = 1.0 - b2.powf(t as f32);
        for i in 0..params.len() {
            let gi = g[i];
            m[i] = b1 * m[i] + (1.0 - b1) * gi;
            v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drl::{NativePolicy, Policy};
    use crate::util::rng::Rng;

    /// Synthetic minibatch whose `logp_old` sit close to the current
    /// policy's log-densities, keeping every ratio well inside the clip
    /// interval (where the surrogate is smooth, so finite differences and
    /// the analytic gradient must agree).
    fn synth(
        params: &[f32],
        o: usize,
        h: usize,
        b: usize,
        seed: u64,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let net = NativePolicy::new(o, h);
        let pol = Policy::new(o);
        let mut rng = Rng::new(seed);
        let obs: Vec<f32> = (0..b * o).map(|_| rng.normal() as f32).collect();
        let mut act = Vec::with_capacity(b);
        let mut logp_old = Vec::with_capacity(b);
        for r in 0..b {
            let out = net.apply(params, &obs[r * o..(r + 1) * o]).unwrap();
            let a = out.mu + 0.3 * rng.normal();
            act.push(a as f32);
            // small offset keeps every ratio = exp(logp - logp_old) well
            // inside [1-clip, 1+clip], away from the surrogate's kink
            logp_old.push((pol.logp(a, &out) + 0.02 * rng.normal()) as f32);
        }
        let adv: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
        let ret: Vec<f32> = (0..b).map(|_| (rng.normal() * 0.5) as f32).collect();
        (obs, act, logp_old, adv, ret)
    }

    fn jittered_params(o: usize, h: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut params = NativePolicy::new(o, h).init_params(seed);
        for x in params.iter_mut() {
            *x += (0.05 * rng.normal()) as f32;
        }
        params
    }

    fn loss_of(stats: &[f32; 6], hp: &PpoHyperParams) -> f64 {
        stats[0] as f64 + hp.vf_coef * stats[1] as f64 - hp.ent_coef * stats[2] as f64
    }

    #[test]
    fn n_params_matches_native_policy() {
        for (o, h) in [(3, 4), (32, 32), (149, 512)] {
            assert_eq!(
                NativeUpdater::new(o, h, PpoHyperParams::default()).n_params(),
                NativePolicy::new(o, h).n_params(),
                "{o}x{h}"
            );
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (o, h, b) = (3, 4, 8);
        let nu = NativeUpdater::new(o, h, PpoHyperParams::default());
        let params = jittered_params(o, h, 7);
        let (obs, act, logp_old, adv, ret) = synth(&params, o, h, b, 3);
        let (g, _) = nu.grad(&params, &obs, &act, &logp_old, &adv, &ret).unwrap();

        let eps = 1e-2f32;
        for i in 0..params.len() {
            let mut pp = params.clone();
            pp[i] += eps;
            let (_, sp) = nu.grad(&pp, &obs, &act, &logp_old, &adv, &ret).unwrap();
            pp[i] -= 2.0 * eps;
            let (_, sm) = nu.grad(&pp, &obs, &act, &logp_old, &adv, &ret).unwrap();
            let fd = (loss_of(&sp, nu.hp()) - loss_of(&sm, nu.hp())) / (2.0 * eps as f64);
            let gi = g[i] as f64;
            assert!(
                (fd - gi).abs() < 1e-3 + 0.05 * gi.abs().max(fd.abs()),
                "param {i}: analytic {gi} vs finite-difference {fd}"
            );
        }
    }

    #[test]
    fn forward_matches_native_policy_layout() {
        // guards against the Layout offsets drifting from the offsets
        // NativePolicy::forward_row hard-codes: recover (value, logp) from
        // a B=1 minibatch's stats and pin them to the policy-side forward
        let (o, h) = (5, 7);
        let nu = NativeUpdater::new(o, h, PpoHyperParams::default());
        let net = NativePolicy::new(o, h);
        let pol = Policy::new(o);
        let params = jittered_params(o, h, 13);
        let mut rng = Rng::new(4);
        let obs: Vec<f32> = (0..o).map(|_| rng.normal() as f32).collect();
        let out = net.apply(&params, &obs).unwrap();
        let act = [(out.mu + 0.2) as f32];
        // logp_old = 0 makes stats[3] = -logp; ret = 0 makes stats[1] = v^2
        let (_, stats) = nu
            .grad(&params, &obs, &act, &[0.0], &[0.3], &[0.0])
            .unwrap();
        let logp = pol.logp(act[0] as f64, &out);
        assert!(
            (stats[3] as f64 + logp).abs() < 1e-5,
            "updater logp {} vs policy logp {logp}",
            -stats[3]
        );
        assert!(
            (stats[1] as f64 - out.value * out.value).abs() < 1e-5 * out.value.abs().max(1.0),
            "updater v^2 {} vs policy value {}",
            stats[1],
            out.value
        );
        assert!(
            (stats[2] as f64 - (out.logstd + 0.5 * (LOG_2PI + 1.0))).abs() < 1e-6,
            "entropy reads a different logstd slot"
        );
    }

    #[test]
    fn gradient_is_deterministic() {
        let (o, h, b) = (4, 6, 5);
        let nu = NativeUpdater::new(o, h, PpoHyperParams::default());
        let params = jittered_params(o, h, 1);
        let (obs, act, logp_old, adv, ret) = synth(&params, o, h, b, 2);
        let (ga, sa) = nu.grad(&params, &obs, &act, &logp_old, &adv, &ret).unwrap();
        let (gb, sb) = nu.grad(&params, &obs, &act, &logp_old, &adv, &ret).unwrap();
        assert_eq!(ga, gb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn repeated_steps_reduce_loss() {
        let (o, h, b) = (4, 8, 16);
        let nu = NativeUpdater::new(
            o,
            h,
            PpoHyperParams {
                lr: 1e-2,
                ..PpoHyperParams::default()
            },
        );
        let mut params = jittered_params(o, h, 5);
        let (obs, act, logp_old, adv, ret) = synth(&params, o, h, b, 8);
        let (_, s0) = nu.grad(&params, &obs, &act, &logp_old, &adv, &ret).unwrap();
        let n = params.len();
        let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
        for t in 1..=50u64 {
            nu.step(t, &mut params, &mut m, &mut v, &obs, &act, &logp_old, &adv, &ret)
                .unwrap();
        }
        let (_, s1) = nu.grad(&params, &obs, &act, &logp_old, &adv, &ret).unwrap();
        assert!(
            loss_of(&s1, nu.hp()) < loss_of(&s0, nu.hp()),
            "loss did not decrease: {} -> {}",
            loss_of(&s0, nu.hp()),
            loss_of(&s1, nu.hp())
        );
    }

    #[test]
    fn global_norm_clipping_caps_the_norm() {
        let mut g = vec![3.0f32, 4.0];
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6, "pre-clip norm {pre}");
        let post = g.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt();
        assert!((post - 1.0).abs() < 1e-6, "post-clip norm {post}");
        // below the threshold the gradient is untouched
        let mut g2 = vec![0.3f32, 0.4];
        clip_global_norm(&mut g2, 1.0);
        assert_eq!(g2, vec![0.3, 0.4]);
    }

    #[test]
    fn rejects_bad_shapes_and_zero_step() {
        let nu = NativeUpdater::new(3, 4, PpoHyperParams::default());
        let n = nu.n_params();
        let params = vec![0.1f32; n];
        // ragged obs
        assert!(nu
            .grad(&params, &[0.0; 5], &[0.0; 2], &[0.0; 2], &[0.0; 2], &[0.0; 2])
            .is_err());
        // wrong param count
        assert!(nu
            .grad(&params[..n - 1], &[0.0; 3], &[0.0; 1], &[0.0; 1], &[0.0; 1], &[0.0; 1])
            .is_err());
        // optimizer state size mismatch
        let mut p = vec![0.1f32; n];
        let mut m = vec![0.0f32; n - 1];
        let mut v = vec![0.0f32; n];
        assert!(nu
            .step(1, &mut p, &mut m, &mut v, &[0.0; 3], &[0.0; 1], &[0.0; 1], &[0.0; 1], &[0.0; 1])
            .is_err());
        // 0-based step counter rejected (would divide by zero in the
        // bias correction)
        let mut m2 = vec![0.0f32; n];
        assert!(nu
            .step(0, &mut p, &mut m2, &mut v, &[0.0; 3], &[0.0; 1], &[0.0; 1], &[0.0; 1], &[0.0; 1])
            .is_err());
    }
}
