//! Trajectory storage and minibatch assembly for PPO.

use crate::drl::gae::gae;
use crate::util::rng::Rng;

/// One (s, a, r) tuple plus the serving-time policy byproducts.
#[derive(Clone, Debug, PartialEq)]
pub struct Transition {
    pub obs: Vec<f32>,
    pub action: f64,
    pub logp: f64,
    pub reward: f64,
    pub value: f64,
}

/// One environment episode.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trajectory {
    pub transitions: Vec<Transition>,
    /// V(s_T) bootstrap for the truncated horizon.
    pub last_value: f64,
    pub env_id: usize,
}

impl Trajectory {
    /// Number of transitions (actuation periods) collected.
    pub fn len(&self) -> usize {
        self.transitions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.transitions.is_empty()
    }

    /// Undiscounted episode return.
    pub fn total_reward(&self) -> f64 {
        self.transitions.iter().map(|t| t.reward).sum::<f64>()
    }
}

/// Flattened training batch (all envs' episodes for one iteration).
pub struct Batch {
    pub n_obs: usize,
    pub obs: Vec<f32>,     // (n, n_obs) row-major
    pub act: Vec<f32>,     // (n, 1)
    pub logp: Vec<f32>,    // (n,)
    pub adv: Vec<f32>,     // (n,) normalised
    pub ret: Vec<f32>,     // (n,)
}

impl Batch {
    /// GAE per trajectory, flatten, then normalise advantages batch-wide
    /// (standard PPO practice; keeps the update scale-invariant to the
    /// reward magnitude, which for Eq. 12 is O(0.1)).
    pub fn assemble(trajs: &[Trajectory], n_obs: usize, gamma: f64, lam: f64) -> Batch {
        let total: usize = trajs.iter().map(|t| t.len()).sum::<usize>();
        let mut b = Batch {
            n_obs,
            obs: Vec::with_capacity(total * n_obs),
            act: Vec::with_capacity(total),
            logp: Vec::with_capacity(total),
            adv: Vec::with_capacity(total),
            ret: Vec::with_capacity(total),
        };
        for tr in trajs {
            let rew: Vec<f64> = tr.transitions.iter().map(|t| t.reward).collect();
            let val: Vec<f64> = tr.transitions.iter().map(|t| t.value).collect();
            let (adv, ret) = gae(&rew, &val, tr.last_value, gamma, lam);
            for (k, t) in tr.transitions.iter().enumerate() {
                b.obs.extend_from_slice(&t.obs);
                b.act.push(t.action as f32);
                b.logp.push(t.logp as f32);
                b.adv.push(adv[k] as f32);
                b.ret.push(ret[k] as f32);
            }
        }
        // advantage normalisation
        let n = b.adv.len().max(1) as f64;
        let mean: f64 = b.adv.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var: f64 = b
            .adv
            .iter()
            .map(|&x| (x as f64 - mean) * (x as f64 - mean))
            .sum::<f64>()
            / n;
        let std = var.sqrt().max(1e-6);
        for a in &mut b.adv {
            *a = ((*a as f64 - mean) / std) as f32;
        }
        b
    }

    /// Number of samples (transitions across all trajectories).
    pub fn len(&self) -> usize {
        self.act.len()
    }

    pub fn is_empty(&self) -> bool {
        self.act.is_empty()
    }

    /// Shuffled minibatch index sets of exactly `mb` elements each; the
    /// ragged tail is padded by resampling (the update artifact has a
    /// static batch dimension).
    pub fn minibatch_indices(&self, mb: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        let n = self.len();
        if n == 0 {
            return vec![];
        }
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let mut out = Vec::new();
        for chunk in idx.chunks(mb) {
            let mut c = chunk.to_vec();
            while c.len() < mb {
                c.push(idx[rng.below(n)]);
            }
            out.push(c);
        }
        out
    }

    /// Gather one minibatch into dense arrays (obs, act, logp, adv, ret).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut obs = Vec::with_capacity(idx.len() * self.n_obs);
        let mut act = Vec::with_capacity(idx.len());
        let mut logp = Vec::with_capacity(idx.len());
        let mut adv = Vec::with_capacity(idx.len());
        let mut ret = Vec::with_capacity(idx.len());
        for &i in idx {
            obs.extend_from_slice(&self.obs[i * self.n_obs..(i + 1) * self.n_obs]);
            act.push(self.act[i]);
            logp.push(self.logp[i]);
            adv.push(self.adv[i]);
            ret.push(self.ret[i]);
        }
        (obs, act, logp, adv, ret)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn mk_traj(n: usize, env_id: usize) -> Trajectory {
        Trajectory {
            transitions: (0..n)
                .map(|k| Transition {
                    obs: vec![k as f32; 3],
                    action: k as f64 * 0.1,
                    logp: -1.0,
                    reward: 1.0,
                    value: 0.5,
                })
                .collect(),
            last_value: 0.25,
            env_id,
        }
    }

    #[test]
    fn assemble_counts_and_normalisation() {
        let trajs = vec![mk_traj(7, 0), mk_traj(5, 1)];
        let b = Batch::assemble(&trajs, 3, 0.99, 0.95);
        assert_eq!(b.len(), 12);
        assert_eq!(b.obs.len(), 12 * 3);
        let mean: f64 = b.adv.iter().map(|&x| x as f64).sum::<f64>() / 12.0;
        assert!(mean.abs() < 1e-5, "normalised mean {mean}");
    }

    #[test]
    fn minibatches_cover_all_indices() {
        prop::check("minibatch coverage", 30, |rng| {
            let n = 1 + rng.below(200);
            let mb = 1 + rng.below(64);
            let trajs = vec![mk_traj(n, 0)];
            let b = Batch::assemble(&trajs, 3, 0.99, 0.95);
            let batches = b.minibatch_indices(mb, rng);
            let mut seen = vec![false; n];
            for mbatch in &batches {
                if mbatch.len() != mb {
                    return Err(format!("minibatch size {}", mbatch.len()));
                }
                for &i in mbatch {
                    if i >= n {
                        return Err(format!("index {i} out of range"));
                    }
                    seen[i] = true;
                }
            }
            if seen.iter().all(|&s| s) {
                Ok(())
            } else {
                Err("some samples never visited".into())
            }
        });
    }

    #[test]
    fn gather_layout() {
        let b = Batch::assemble(&[mk_traj(4, 0)], 3, 0.99, 0.95);
        let (obs, act, _, _, _) = b.gather(&[2, 0]);
        assert_eq!(obs, vec![2.0, 2.0, 2.0, 0.0, 0.0, 0.0]);
        assert_eq!(act.len(), 2);
    }
}
