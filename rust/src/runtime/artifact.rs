//! Manifest loading: the contract between `python/compile/aot.py` and the
//! Rust coordinator. See DESIGN.md section 4 for the artifact table.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamSlot {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

/// DRL hyper-parameters recorded by the AOT pipeline (single source of
/// truth: python/compile/configs.py).
#[derive(Clone, Debug)]
pub struct DrlManifest {
    pub n_obs: usize,
    pub n_act: usize,
    pub hidden: usize,
    pub n_params: usize,
    pub minibatch: usize,
    pub lr: f64,
    pub clip_eps: f64,
    pub gamma: f64,
    pub gae_lambda: f64,
    pub action_smoothing_beta: f64,
    pub reward_lift_penalty: f64,
    pub init_logstd: f64,
    pub param_layout: Vec<ParamSlot>,
    pub policy_apply_file: String,
    /// Static-batch serving artifact (`policy_apply_b<B>` with B > 1) for
    /// the coordinator's batched inference mode; absent in older artifact
    /// sets, in which case the server falls back to per-row B=1 calls.
    pub policy_apply_batch_file: Option<String>,
    /// Static batch dimension of `policy_apply_batch_file` (1 when absent).
    pub policy_batch: usize,
    pub ppo_update_file: String,
}

/// Per-variant CFD metadata (grid, physics constants, file names).
#[derive(Clone, Debug)]
pub struct VariantManifest {
    pub name: String,
    pub cfd_period_file: String,
    pub state0_file: String,
    pub ny: usize,
    pub nx: usize,
    pub h: f64,
    pub dt: f64,
    pub substeps: usize,
    pub period: f64,
    pub re: f64,
    pub n_sweeps: usize,
    pub jet_max: f64,
    pub cd0: f64,
    pub cl0_amplitude: f64,
    pub probe_mean: Vec<f32>,
    pub probe_std: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub kernel_impl: String,
    pub drl: DrlManifest,
    pub variants: BTreeMap<String, VariantManifest>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let d = j.get("drl")?;
        let layout = d
            .get("param_layout")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(ParamSlot {
                    name: s.get("name")?.as_str()?.to_string(),
                    offset: s.get("offset")?.as_usize()?,
                    shape: s
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let arts = j.get("artifacts")?;
        // optional: artifact sets built before the batched-inference mode
        // simply lack this entry
        let (policy_apply_batch_file, policy_batch) = match arts.get("policy_apply_batch") {
            Ok(e) => (
                Some(e.get("file")?.as_str()?.to_string()),
                e.get("batch")?.as_usize()?,
            ),
            Err(_) => (None, 1),
        };
        let drl = DrlManifest {
            n_obs: d.get("n_obs")?.as_usize()?,
            n_act: d.get("n_act")?.as_usize()?,
            hidden: d.get("hidden")?.as_usize()?,
            n_params: d.get("n_params")?.as_usize()?,
            minibatch: d.get("minibatch")?.as_usize()?,
            lr: d.get("lr")?.as_f64()?,
            clip_eps: d.get("clip_eps")?.as_f64()?,
            gamma: d.get("gamma")?.as_f64()?,
            gae_lambda: d.get("gae_lambda")?.as_f64()?,
            action_smoothing_beta: d.get("action_smoothing_beta")?.as_f64()?,
            reward_lift_penalty: d.get("reward_lift_penalty")?.as_f64()?,
            init_logstd: d.get("init_logstd")?.as_f64()?,
            param_layout: layout,
            policy_apply_file: arts.get("policy_apply")?.get("file")?.as_str()?.to_string(),
            policy_apply_batch_file,
            policy_batch,
            ppo_update_file: arts.get("ppo_update")?.get("file")?.as_str()?.to_string(),
        };

        let mut variants = BTreeMap::new();
        for (name, v) in j.get("variants")?.as_obj()? {
            variants.insert(
                name.clone(),
                VariantManifest {
                    name: name.clone(),
                    cfd_period_file: v.get("cfd_period")?.as_str()?.to_string(),
                    state0_file: v.get("state0")?.as_str()?.to_string(),
                    ny: v.get("ny")?.as_usize()?,
                    nx: v.get("nx")?.as_usize()?,
                    h: v.get("h")?.as_f64()?,
                    dt: v.get("dt")?.as_f64()?,
                    substeps: v.get("substeps")?.as_usize()?,
                    period: v.get("period")?.as_f64()?,
                    re: v.get("re")?.as_f64()?,
                    n_sweeps: v.get("n_sweeps")?.as_usize()?,
                    jet_max: v.get("jet_max")?.as_f64()?,
                    cd0: v.get("cd0")?.as_f64()?,
                    cl0_amplitude: v.get("cl0_amplitude")?.as_f64()?,
                    probe_mean: v.get("probe_mean")?.f32_vec()?,
                    probe_std: v.get("probe_std")?.f32_vec()?,
                },
            );
        }

        Ok(Manifest {
            dir,
            kernel_impl: j.get("kernel_impl")?.as_str()?.to_string(),
            drl,
            variants,
        })
    }

    /// Load the manifest when `dir` ships one. A *missing* manifest
    /// returns `Ok(None)` — callers (the `episode` command, both training
    /// loops) fall back to their artifact-free paths — while a
    /// present-but-broken one is a real error, not something to silently
    /// fall back from.
    pub fn load_optional(dir: impl AsRef<Path>) -> Result<Option<Manifest>> {
        let dir = dir.as_ref();
        match Manifest::load(dir) {
            Ok(m) => Ok(Some(m)),
            Err(_) if !dir.join("manifest.json").exists() => Ok(None),
            Err(e) => Err(e.context("artifacts present but unreadable")),
        }
    }

    pub fn variant(&self, name: &str) -> Result<&VariantManifest> {
        self.variants
            .get(name)
            .with_context(|| format!("variant {name:?} not in manifest (built: {:?})",
                self.variants.keys().collect::<Vec<_>>()))
    }

    /// Initial flat policy parameters shipped by the AOT pipeline.
    pub fn load_params_init(&self) -> Result<Vec<f32>> {
        let v = super::read_f32_bin(self.dir.join("params_init.bin"))?;
        anyhow::ensure!(
            v.len() == self.drl.n_params,
            "params_init.bin has {} f32s, manifest says {}",
            v.len(),
            self.drl.n_params
        );
        Ok(v)
    }

    /// Developed base-flow state (u|v|p) for a variant.
    pub fn load_state0(&self, variant: &str) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let v = self.variant(variant)?;
        let all = super::read_f32_bin(self.dir.join(&v.state0_file))?;
        let n = v.ny * v.nx;
        anyhow::ensure!(all.len() == 3 * n, "state0 size mismatch");
        Ok((
            all[..n].to_vec(),
            all[n..2 * n].to_vec(),
            all[2 * n..].to_vec(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let m = Manifest::load(artifacts_dir()).expect("run `make artifacts` first");
        assert_eq!(m.drl.n_obs, 149);
        // layout covers the flat vector contiguously
        let mut off = 0;
        for s in &m.drl.param_layout {
            assert_eq!(s.offset, off, "slot {} not contiguous", s.name);
            off += s.shape.iter().product::<usize>();
        }
        assert_eq!(off, m.drl.n_params);
        let v = m.variant("small").unwrap();
        assert_eq!(v.probe_mean.len(), 149);
        assert!(v.cd0 > 1.0 && v.cd0 < 10.0);
    }

    #[test]
    fn state0_and_params_load() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        let p = m.load_params_init().unwrap();
        assert_eq!(p.len(), m.drl.n_params);
        let (u, v, pr) = m.load_state0("small").unwrap();
        let n = m.variant("small").unwrap().ny * m.variant("small").unwrap().nx;
        assert_eq!(u.len(), n);
        assert_eq!(v.len(), n);
        assert_eq!(pr.len(), n);
        // developed flow should be non-trivial
        let umax = u.iter().cloned().fold(0.0f32, f32::max);
        assert!(umax > 1.0, "u max {umax}");
    }

    #[test]
    fn load_optional_missing_is_none_but_broken_is_error() {
        let root = std::env::temp_dir().join(format!("drlfoam-oman-{}", std::process::id()));
        std::fs::create_dir_all(&root).unwrap();
        // missing directory -> artifact-free path
        assert!(Manifest::load_optional(root.join("nope")).unwrap().is_none());
        // present but unparseable -> hard error
        let broken = root.join("broken");
        std::fs::create_dir_all(&broken).unwrap();
        std::fs::write(broken.join("manifest.json"), "{not json").unwrap();
        assert!(Manifest::load_optional(&broken).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn unknown_variant_is_error() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.variant("nope").is_err());
    }
}
