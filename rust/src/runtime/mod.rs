//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the `xla` crate is touched. Python never runs on
//! the request path: `make artifacts` lowers the JAX/Pallas model once,
//! and everything here is plain `HLO text -> compile -> execute`.
//!
//! Threading model: `xla::PjRtClient` wraps a raw pointer without Send/Sync
//! impls, so each worker thread builds its own [`Runtime`] (one PJRT CPU
//! client + its compiled executables). Compilation is ~10-100 ms per
//! artifact and happens once per thread at pool startup, never in the
//! episode loop.

mod artifact;
mod client;

pub use artifact::{DrlManifest, Manifest, ParamSlot, VariantManifest};
pub use client::{literal_f32, read_f32_bin, scalar_f32, to_vec_f32, write_f32_bin, Executable, Runtime};
