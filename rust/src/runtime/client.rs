//! PJRT CPU client wrapper + executable cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One compiled HLO module plus its human name (for error reporting).
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; unwraps the single tuple output that
    /// `return_tuple=True` lowering produces into its elements.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))
    }

    /// Execute with device-resident buffer inputs (perf fast path: skips
    /// the per-call host->device literal copy for large constant-ish
    /// arguments like policy parameters).
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute_b(args)
            .with_context(|| format!("executing {} (buffers)", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))
    }
}

/// Per-thread PJRT CPU client with a named executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, Executable>,
    artifact_dir: PathBuf,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            exes: HashMap::new(),
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
        })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Compile (and cache) an HLO-text artifact by file name.
    pub fn load(&mut self, file: &str) -> Result<&Executable> {
        if !self.exes.contains_key(file) {
            let path = self.artifact_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.exes.insert(
                file.to_string(),
                Executable {
                    name: file.to_string(),
                    exe,
                },
            );
        }
        Ok(&self.exes[file])
    }

    pub fn get(&self, file: &str) -> Result<&Executable> {
        self.exes
            .get(file)
            .with_context(|| format!("executable {file} not loaded"))
    }

    /// Upload an f32 array to a device-resident buffer (perf fast path).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading buffer")
    }
}

// ---------------------------------------------------------------------------
// Literal <-> host helpers
// ---------------------------------------------------------------------------

/// Build a rank-N f32 literal from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {:?} != data len {}", dims, data.len());
    }
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        Ok(lit)
    } else {
        Ok(lit.reshape(dims)?)
    }
}

pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Copy a literal out to a host `Vec<f32>`.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

// ---------------------------------------------------------------------------
// Raw f32 binary files (params_init.bin, state0_*.bin, checkpoints)
// ---------------------------------------------------------------------------

pub fn read_f32_bin(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: size not a multiple of 4", path.as_ref().display());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn write_f32_bin(path: impl AsRef<Path>, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path.as_ref(), bytes)
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bin_roundtrip() {
        let dir = std::env::temp_dir().join(format!("drlfoam-bin-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.bin");
        let data = vec![1.0f32, -2.5, 3.25e-3, f32::MAX];
        write_f32_bin(&path, &data).unwrap();
        assert_eq!(read_f32_bin(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }
}
