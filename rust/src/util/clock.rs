//! Wall-clock access for *telemetry only* — the determinism choke point.
//!
//! This repo's acceptance bar is bitwise-identical learning output
//! (train_log.csv learning columns, policy parameters, plan.csv), so
//! determinism-critical modules (`cluster/des.rs`, `cluster/planner.rs`,
//! `coordinator/scheduler.rs`, `drl/*`) must never let wall-clock time
//! influence a scored or learned value. They still legitimately *report*
//! wall time — rollout seconds, update seconds, barrier idle — as
//! telemetry columns that the equivalence tests deliberately exclude.
//!
//! [`telemetry_now`] is the single sanctioned door to the wall clock for
//! those modules. The `drlfoam audit` rule `det-wall-clock` flags every
//! wall-clock read (including this function) inside the
//! determinism-critical set, so each call site needs an explicit,
//! justified entry in `rust/audit.allow` with a maximum count — new
//! clock reads can't creep in unreviewed, and the allowlist documents
//! exactly which telemetry each module is allowed to measure. See
//! ARCHITECTURE.md §9.

use std::time::Instant;

/// Read the wall clock for a telemetry measurement (never for anything
/// that feeds scoring, scheduling decisions, or learning output).
///
/// Returns a plain [`std::time::Instant`]; subtract two of them for a
/// duration column. The name exists so `drlfoam audit` can tell a
/// sanctioned telemetry read from a stray `Instant::now()`.
pub fn telemetry_now() -> Instant {
    Instant::now()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn telemetry_now_is_monotonic() {
        let a = telemetry_now();
        let b = telemetry_now();
        assert!(b >= a);
        // and the result subtracts like a std Instant
        let d = b.duration_since(a);
        assert!(d.as_secs_f64() >= 0.0);
    }
}
