//! Deterministic xoshiro256++ RNG with Box-Muller normals.
//!
//! Every stochastic component (action sampling, minibatch shuffles, DES
//! jitter) takes an explicit seed so training runs and simulations are
//! bit-reproducible — a requirement for the scaling benchmarks, where the
//! *same* episode workload must be replayed under different parallel
//! configurations.

/// xoshiro256++ by Blackman & Vigna (public domain reference constants).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (e.g. per environment id).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64)
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
