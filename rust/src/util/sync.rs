//! Loom-aware synchronization facade.
//!
//! Every atomic the concurrency-critical modules touch is imported from
//! HERE, never from `std::sync::atomic` directly. In a normal build the
//! re-exports below are exactly the `std` types (zero cost, zero
//! indirection); under `RUSTFLAGS="--cfg loom"` they become loom's
//! model-checked twins, and `rust/tests/loom_shm.rs` exhaustively
//! explores the interleavings of the shm seqlock protocol
//! ([`crate::exec::seqlock`]) instead of hoping a stress test hits the
//! bad one.
//!
//! Loom is a *dev-time* dependency gated behind the non-default `loom`
//! cfg — a regular `cargo build`/`cargo test` never compiles it (the
//! offline environment does not vendor it; the loom CI stage is env-gated
//! for toolchains that do). Manifest line, for when the crate graph is
//! materialized:
//!
//! ```text
//! [target.'cfg(loom)'.dependencies]
//! loom = "0.7"
//! ```
//!
//! The [`UnsafeCell`] here mirrors loom's `with`/`with_mut` access API
//! rather than `std::cell::UnsafeCell::get`, because that is the shape
//! loom needs to *track* reads and writes: any protocol bug that lets a
//! reader observe a cell while a writer holds it becomes a loom panic
//! instead of silent UB. Run `make loom` (or
//! `DRLFOAM_CI_LOOM=1 ./ci.sh`) to model-check; see ARCHITECTURE.md §9.

#[cfg(loom)]
pub use loom::cell::UnsafeCell;
#[cfg(loom)]
pub use loom::hint::spin_loop;
#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
#[cfg(loom)]
pub use loom::sync::Arc;
#[cfg(loom)]
pub use loom::thread::yield_now;

#[cfg(not(loom))]
pub use std::hint::spin_loop;
#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
#[cfg(not(loom))]
pub use std::sync::Arc;
#[cfg(not(loom))]
pub use std::thread::yield_now;

/// `std` stand-in for `loom::cell::UnsafeCell`: same `with`/`with_mut`
/// closure API, no tracking. Callers uphold the aliasing contract
/// themselves (for the seqlock ring: a slot's cell is only touched by
/// the side that currently owns the slot's sequence word) — under loom
/// that claim is *checked*, here it is merely documented.
#[cfg(not(loom))]
#[derive(Debug, Default)]
pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

#[cfg(not(loom))]
impl<T> UnsafeCell<T> {
    pub fn new(data: T) -> UnsafeCell<T> {
        UnsafeCell(std::cell::UnsafeCell::new(data))
    }

    /// Immutable access to the cell's contents (loom: tracked read).
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        f(self.0.get())
    }

    /// Mutable access to the cell's contents (loom: tracked write).
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn facade_atomics_are_std_atomics() {
        // the not(loom) build must stay zero-cost: these are the std
        // types, byte-compatible with what the mmap ring casts to
        let a = AtomicU64::new(7);
        a.store(9, Ordering::Release);
        assert_eq!(a.load(Ordering::Acquire), 9);
        assert_eq!(std::mem::size_of::<AtomicU64>(), 8);
        let b = AtomicBool::new(false);
        b.store(true, Ordering::Release);
        assert!(b.load(Ordering::Acquire));
    }

    #[test]
    fn unsafe_cell_shim_matches_looms_api_shape() {
        let c = UnsafeCell::new(vec![1u8, 2, 3]);
        // SAFETY: single-threaded test — no concurrent access to the cell.
        c.with_mut(|p| unsafe { (*p).push(4) });
        // SAFETY: as above.
        let got = c.with(|p| unsafe { (*p).clone() });
        assert_eq!(got, vec![1, 2, 3, 4]);
    }
}
