//! Tiny benchmark harness (criterion is not vendored offline).
//!
//! `cargo bench` binaries use this: warmup, N timed iterations, mean/std/
//! p50/p95 reporting, and machine-readable JSON lines appended to
//! `out/bench/<name>.json` so the reproduce pipeline can consume results.

use std::io::Write as _;
use std::time::Instant;

use crate::util::{json, stats};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10.4} ms ± {:>8.4}  (p50 {:.4}, p95 {:.4}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            self.iters
        );
    }

    pub fn to_json(&self) -> json::Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("iters", json::num(self.iters as f64)),
            ("mean_s", json::num(self.mean_s)),
            ("std_s", json::num(self.std_s)),
            ("p50_s", json::num(self.p50_s)),
            ("p95_s", json::num(self.p95_s)),
        ])
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&times),
        std_s: stats::std_dev(&times),
        p50_s: stats::percentile(&times, 50.0),
        p95_s: stats::percentile(&times, 95.0),
    };
    r.report();
    r
}

/// Append results as JSON lines under out/bench/.
pub fn save(group: &str, results: &[BenchResult]) {
    let dir = std::path::Path::new("out/bench");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let path = dir.join(format!("{group}.json"));
    if let Ok(mut f) = std::fs::File::create(&path) {
        for r in results {
            let _ = writeln!(f, "{}", r.to_json().to_string());
        }
        println!("saved {}", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_s >= 0.0);
        assert_eq!(r.iters, 5);
        assert!(r.p95_s >= r.p50_s);
    }
}
