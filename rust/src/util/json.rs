//! Minimal JSON parser/writer (serde is not vendored in this offline env).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the calibration/metrics files: objects, arrays, strings (with escapes),
//! f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|x| x.as_f64()).collect()
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    /// Compact serialization (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    write_escaped(key, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for metric writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number {txt:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        // accumulate raw UTF-8 bytes: the input is a &str, so multi-byte
        // sequences copied byte-for-byte stay valid (the quote/backslash
        // bytes never occur inside a multi-byte sequence), and escape
        // decoding appends complete encoded chars
        let mut out: Vec<u8> = Vec::new();
        let mut buf = [0u8; 4];
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(String::from_utf8(out)?),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0c),
                        b'u' => {
                            let ch = self.unicode_escape()?;
                            out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => out.push(c),
            }
        }
    }

    /// The code point of one `\uXXXX` escape (the `\u` already consumed),
    /// combining UTF-16 surrogate pairs (`\uD83D\uDE00` -> U+1F600).
    /// Truncated or non-hex input is an error, never a panic; an unpaired
    /// surrogate decodes to U+FFFD like any other unrepresentable code
    /// point.
    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // high surrogate: consume the paired \uXXXX if present
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                let mark = self.i;
                self.i += 2;
                let lo = self.hex4()?;
                if (0xDC00..0xE000).contains(&lo) {
                    let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                    return Ok(char::from_u32(cp).unwrap_or('\u{fffd}'));
                }
                // not a low surrogate: rewind so it parses on its own
                self.i = mark;
            }
            return Ok('\u{fffd}');
        }
        Ok(char::from_u32(hi).unwrap_or('\u{fffd}'))
    }

    /// Four hex digits at the cursor; bounds-checked (a truncated `\uXX`
    /// tail used to slice out of range and panic).
    fn hex4(&mut self) -> Result<u32> {
        let end = self.i + 4;
        if end > self.b.len() {
            bail!("truncated \\u escape at byte {}", self.i);
        }
        let hex = std::str::from_utf8(&self.b[self.i..end])?;
        let cp = u32::from_str_radix(hex, 16)
            .map_err(|e| anyhow!("bad \\u escape {hex:?} at byte {}: {e}", self.i))?;
        self.i = end;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.ws();
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                    self.ws();
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.ws();
        let mut map = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                    self.ws();
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": -0.5}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_f64().unwrap(), -0.5);
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"n":7}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // surrogate pair -> one supplementary-plane char
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("\u{1f600}".into())
        );
        // unpaired surrogates decode to U+FFFD, never panic
        assert_eq!(
            Json::parse(r#""x\ud83dy""#).unwrap(),
            Json::Str("x\u{fffd}y".into())
        );
        assert_eq!(
            Json::parse(r#""\ude00""#).unwrap(),
            Json::Str("\u{fffd}".into())
        );
        // a truncated \u tail is an error, not an out-of-bounds panic
        assert!(Json::parse(r#""\u00"#).is_err());
        assert!(Json::parse(r#""\uZZZZ""#).is_err());
    }

    /// The escaping contract the trace exporter leans on: every string —
    /// quotes, backslashes, control characters, multi-byte UTF-8 — must
    /// survive write -> parse bit-exactly. The old parser pushed raw
    /// bytes as chars, mangling anything outside ASCII.
    #[test]
    fn strings_roundtrip_bit_exactly() {
        let cases = [
            "plain",
            "quote \" backslash \\ slash /",
            "tabs\tnewlines\nreturns\r",
            "control \u{1} \u{1f} bell \u{7}",
            "caf\u{e9} \u{4e2d}\u{6587} \u{1f600}",
            "windows\\path\\\"quoted\"",
            "",
        ];
        for case in cases {
            let written = Json::Str(case.to_string()).to_string();
            let parsed = Json::parse(&written).unwrap();
            assert_eq!(
                parsed,
                Json::Str(case.to_string()),
                "string {case:?} did not round-trip (wire form {written})"
            );
        }
        // and through a nested document, where keys get escaped too
        let doc = obj(vec![("k\"ey\\", s("v\nal \u{1f600}"))]);
        assert_eq!(Json::parse(&doc.to_string()).unwrap(), doc);
    }
}
