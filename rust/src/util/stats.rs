//! Summary statistics used by the metrics layer and the bench harness.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
    }

    #[test]
    fn empty_is_nan() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
    }
}
