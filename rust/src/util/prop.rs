//! Minimal property-testing driver (proptest is not vendored offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded RNGs;
//! on panic or `Err`, it reports the failing seed so the case can be
//! replayed deterministically with `check_seed`.

use crate::util::rng::Rng;

/// Run `f` for `cases` deterministic seeds; panic with the failing seed.
pub fn check<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
            f(&mut rng)
        });
        match result {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!("property {name:?} failed at seed {seed}: {msg}"),
            Err(_) => panic!("property {name:?} panicked at seed {seed}"),
        }
    }
}

/// Replay a single failing seed (debugging helper).
pub fn check_seed<F>(f: F, seed: u64) -> Result<(), String>
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    f(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("uniform in range", 50, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn reports_failing_seed() {
        check("always fails", 3, |_| Err("nope".into()));
    }
}
