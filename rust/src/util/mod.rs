//! Small in-tree utilities.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure (no serde / rand / criterion / proptest), so deterministic RNG,
//! JSON, statistics, a bench harness and a property-test driver live here.

pub mod bench;
pub mod clock;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
