//! The audit allowlist: audited exceptions with justification and a cap.
//!
//! `rust/audit.allow` holds one entry per line:
//!
//! ```text
//! # comment
//! rule-name | rust/src/relative/path.rs | max-count | justification text
//! ```
//!
//! Semantics (enforced by [`Allowlist::apply`]):
//!
//! * an entry suppresses up to `max-count` findings of `rule-name` in
//!   `path` — the cap is the point: when a module is allowed 3 telemetry
//!   clock reads and a 4th appears, the audit fails with ALL of them
//!   listed, instead of the new one hiding behind the old justification;
//! * an entry that suppresses *zero* findings is itself reported
//!   (`allowlist-stale`): either the code was fixed (delete the entry)
//!   or the path/rule is misspelled (fix it) — the list cannot rot;
//! * the justification is mandatory, so the *why* lives next to the
//!   exception and shows up in diffs when someone widens it.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Finding;

pub(crate) const RULE_STALE: &str = "allowlist-stale";

/// One parsed allowlist line.
#[derive(Clone, Debug)]
pub struct AllowEntry {
    pub rule: String,
    /// Root-relative path, forward slashes (as findings report it).
    pub file: String,
    pub max_count: usize,
    pub justification: String,
    /// 1-based line in the allowlist file (for stale-entry findings).
    pub line: usize,
}

/// The parsed allowlist.
pub struct Allowlist {
    entries: Vec<AllowEntry>,
    /// Root-relative path of the allowlist file itself.
    rel: String,
}

impl Allowlist {
    pub fn load(path: &Path) -> Result<Allowlist> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading allowlist {}", path.display()))?;
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = t.splitn(4, '|').map(str::trim).collect();
            if parts.len() != 4 || parts.iter().any(|p| p.is_empty()) {
                bail!(
                    "allowlist {}:{}: expected `rule | file | max-count | justification`, \
                     got {t:?}",
                    path.display(),
                    i + 1
                );
            }
            let max_count: usize = parts[2].parse().with_context(|| {
                format!(
                    "allowlist {}:{}: max-count {:?} is not a number",
                    path.display(),
                    i + 1,
                    parts[2]
                )
            })?;
            if max_count == 0 {
                bail!(
                    "allowlist {}:{}: max-count 0 is meaningless — delete the entry instead",
                    path.display(),
                    i + 1
                );
            }
            entries.push(AllowEntry {
                rule: parts[0].to_string(),
                file: parts[1].to_string(),
                max_count,
                justification: parts[3].to_string(),
                line: i + 1,
            });
        }
        Ok(Allowlist {
            entries,
            rel: path
                .file_name()
                .map(|n| format!("rust/{}", n.to_string_lossy()))
                .unwrap_or_else(|| path.display().to_string()),
        })
    }

    /// Build directly from entries (tests).
    pub fn from_entries(entries: Vec<AllowEntry>, rel: &str) -> Allowlist {
        Allowlist {
            entries,
            rel: rel.to_string(),
        }
    }

    /// Suppress allowlisted findings; returns the kept findings (with
    /// stale-entry and over-cap findings added) and the suppressed count.
    pub fn apply(&self, findings: Vec<Finding>, _root: &Path) -> (Vec<Finding>, usize) {
        // count matches per (rule, file)
        let mut matched: BTreeMap<(String, String), usize> = BTreeMap::new();
        for f in &findings {
            *matched.entry((f.rule.to_string(), f.file.clone())).or_insert(0) += 1;
        }
        let mut kept = Vec::new();
        let mut suppressed = 0;
        for f in findings {
            let key = (f.rule.to_string(), f.file.clone());
            let n = matched.get(&key).copied().unwrap_or(0);
            match self.entry_for(&f.rule, &f.file) {
                Some(e) if n <= e.max_count => suppressed += 1,
                Some(e) => {
                    // over cap: keep every finding, annotated
                    kept.push(Finding {
                        message: format!(
                            "{} [allowlist caps {} at {} for this file; {n} found]",
                            f.message, e.rule, e.max_count
                        ),
                        ..f
                    });
                }
                None => kept.push(f),
            }
        }
        // stale entries: nothing matched at all
        for e in &self.entries {
            let n = matched
                .get(&(e.rule.clone(), e.file.clone()))
                .copied()
                .unwrap_or(0);
            if n == 0 {
                kept.push(Finding {
                    rule: RULE_STALE,
                    file: self.rel.clone(),
                    line: e.line,
                    message: format!(
                        "entry `{} | {}` suppresses nothing — fixed code or a typo; \
                         delete or correct it",
                        e.rule, e.file
                    ),
                });
            }
        }
        (kept, suppressed)
    }

    fn entry_for(&self, rule: &str, file: &str) -> Option<&AllowEntry> {
        self.entries.iter().find(|e| e.rule == rule && e.file == file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, file: &str, line: usize) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line,
            message: "m".to_string(),
        }
    }

    fn entry(rule: &str, file: &str, max: usize) -> AllowEntry {
        AllowEntry {
            rule: rule.to_string(),
            file: file.to_string(),
            max_count: max,
            justification: "j".to_string(),
            line: 1,
        }
    }

    #[test]
    fn within_cap_suppresses_over_cap_reports_all() {
        let allow = Allowlist::from_entries(vec![entry("r", "f.rs", 2)], "rust/audit.allow");
        let (kept, n) = allow.apply(
            vec![finding("r", "f.rs", 1), finding("r", "f.rs", 2)],
            Path::new("."),
        );
        assert_eq!(n, 2);
        assert!(kept.is_empty(), "{kept:?}");

        let (kept, n) = allow.apply(
            vec![
                finding("r", "f.rs", 1),
                finding("r", "f.rs", 2),
                finding("r", "f.rs", 3),
            ],
            Path::new("."),
        );
        assert_eq!(n, 0);
        assert_eq!(kept.len(), 3);
        assert!(kept[0].message.contains("caps"), "{:?}", kept[0].message);
    }

    #[test]
    fn stale_entry_is_a_finding_and_unmatched_findings_pass_through() {
        let allow = Allowlist::from_entries(vec![entry("r", "gone.rs", 1)], "rust/audit.allow");
        let (kept, n) = allow.apply(vec![finding("other", "f.rs", 9)], Path::new("."));
        assert_eq!(n, 0);
        assert_eq!(kept.len(), 2, "{kept:?}");
        assert!(kept.iter().any(|f| f.rule == RULE_STALE && f.line == 1));
        assert!(kept.iter().any(|f| f.rule == "other" && f.line == 9));
    }

    #[test]
    fn parser_rejects_malformed_lines_and_zero_caps() {
        let dir = std::env::temp_dir().join(format!("audit-allow-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("audit.allow");

        std::fs::write(&p, "# comment\n\nr | f.rs | 2 | why\n").unwrap();
        let a = Allowlist::load(&p).unwrap();
        assert_eq!(a.entries.len(), 1);
        assert_eq!(a.entries[0].max_count, 2);
        assert_eq!(a.entries[0].justification, "why");

        std::fs::write(&p, "r | f.rs | 2\n").unwrap();
        assert!(Allowlist::load(&p).is_err());
        std::fs::write(&p, "r | f.rs | nope | why\n").unwrap();
        assert!(Allowlist::load(&p).is_err());
        std::fs::write(&p, "r | f.rs | 0 | why\n").unwrap();
        assert!(Allowlist::load(&p).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
