//! The audit's rule implementations (see the table in [`super`]'s docs).
//!
//! Every rule is a pure function over pre-lexed [`SourceFile`]s pushing
//! [`Finding`]s; pattern checks run on the *stripped* line (comments and
//! string literals blanked) so prose can neither trigger nor mask a
//! finding, while SAFETY-comment detection reads the raw line (comments
//! are the evidence there). Rules never early-exit a file: the report
//! lists every violation, not the first.

use std::path::Path;

use anyhow::{Context, Result};

use super::{contains_token, strip_comments_and_strings, Finding, SourceFile};

pub(crate) const RULE_UNSAFE: &str = "unsafe-safety-comment";
pub(crate) const RULE_HASH: &str = "det-hash-collections";
pub(crate) const RULE_CLOCK: &str = "det-wall-clock";
pub(crate) const RULE_F32_SUM: &str = "f32-sum-in-scored-path";
pub(crate) const RULE_WIRE: &str = "wire-tag-coverage";

/// Every `unsafe` keyword (block, fn, impl) must be justified by a
/// `SAFETY:` comment — on the same line, or in the contiguous comment
/// block above it (attribute lines and blank lines in between are
/// skipped, so `// SAFETY: …` above `#[cfg(unix)]` + `unsafe {` counts).
pub(crate) fn unsafe_safety_comment(files: &[SourceFile], out: &mut Vec<Finding>) {
    for f in files {
        for (i, code) in f.code.iter().enumerate() {
            if !contains_token(code, "unsafe") {
                continue;
            }
            if f.raw[i].contains("SAFETY:") || comment_block_above_has_safety(f, i) {
                continue;
            }
            out.push(Finding {
                rule: RULE_UNSAFE,
                file: f.rel.clone(),
                line: i + 1,
                message: "unsafe without a preceding `// SAFETY:` comment".into(),
            });
        }
    }
}

fn comment_block_above_has_safety(f: &SourceFile, line: usize) -> bool {
    for j in (0..line).rev() {
        let t = f.raw[j].trim_start();
        if t.starts_with("//") {
            if t.contains("SAFETY:") {
                return true;
            }
        } else if t.starts_with("#[") || t.starts_with("#![") || t.is_empty() {
            // attributes/blank lines sit between the comment and the site
        } else {
            return false;
        }
    }
    false
}

/// Determinism-critical modules must not use `HashMap`/`HashSet` *at
/// all*: their iteration order is randomized per process, and these
/// modules' outputs (DES scores, plan rankings, learning columns) are
/// compared bitwise. Deliberately coarser than "no iteration" — whether
/// a given map is iterated is one refactor away from changing, so the
/// types are banned outright (`BTreeMap` / sorted `Vec` instead), with
/// the allowlist as the escape hatch for a justified, never-iterated use.
pub(crate) fn det_hash_collections(files: &[SourceFile], out: &mut Vec<Finding>) {
    for f in files.iter().filter(|f| f.is_det_critical()) {
        for (i, code) in f.code.iter().enumerate() {
            for ty in ["HashMap", "HashSet"] {
                if contains_token(code, ty) {
                    out.push(Finding {
                        rule: RULE_HASH,
                        file: f.rel.clone(),
                        line: i + 1,
                        message: format!(
                            "{ty} in a determinism-critical module (iteration order is \
                             nondeterministic; use BTreeMap or a sorted Vec)"
                        ),
                    });
                }
            }
        }
    }
}

/// Determinism-critical modules must not read the wall clock: `Instant`
/// and `SystemTime` values can never influence a pinned output. The
/// sanctioned telemetry choke point `telemetry_now`
/// ([`crate::util::clock`]) is flagged too — each telemetry read exists
/// by explicit allowlist entry, with a max count so new reads can't ride
/// in on an old justification.
pub(crate) fn det_wall_clock(files: &[SourceFile], out: &mut Vec<Finding>) {
    for f in files.iter().filter(|f| f.is_det_critical()) {
        for (i, code) in f.code.iter().enumerate() {
            // an import is not a read, and every actual read names the
            // pattern again at the call site — skip `use` lines so the
            // allowlist counts stay "number of reads", not reads + 1
            let t = code.trim_start();
            if t.starts_with("use ") || t.starts_with("pub use ") {
                continue;
            }
            for pat in ["Instant::now", "SystemTime", "telemetry_now"] {
                if contains_token(code, pat) {
                    out.push(Finding {
                        rule: RULE_CLOCK,
                        file: f.rel.clone(),
                        line: i + 1,
                        message: format!(
                            "{pat} in a determinism-critical module (wall-clock reads are \
                             allowlist-only telemetry)"
                        ),
                    });
                }
            }
        }
    }
}

/// f32 summation is order-sensitive, and scored paths must be
/// order-stable: `.sum::<f32>()` is banned outright, and an untyped
/// `.sum()` is flagged because nothing stops it inferring to f32 later —
/// spell the accumulator (`.sum::<f64>()`, `.sum::<usize>()`, …) so the
/// audit (and the reviewer) can see it.
pub(crate) fn f32_sum_in_scored_path(files: &[SourceFile], out: &mut Vec<Finding>) {
    for f in files.iter().filter(|f| f.is_det_critical()) {
        for (i, code) in f.code.iter().enumerate() {
            let (flagged, msg) = if code.contains(".sum::<f32") {
                (true, "f32 .sum() in a scored path (order-sensitive; accumulate in f64)")
            } else if code.contains(".sum()") {
                (
                    true,
                    "untyped .sum() in a scored path (could infer to f32; \
                     spell the accumulator type, e.g. .sum::<f64>())",
                )
            } else {
                (false, "")
            };
            if flagged {
                out.push(Finding {
                    rule: RULE_F32_SUM,
                    file: f.rel.clone(),
                    line: i + 1,
                    message: msg.into(),
                });
            }
        }
    }
}

/// Every `wire::Tag` variant must be wired end to end: an encode arm
/// (`Tag::V as u8`), a decode arm (`Some(Tag::V) =>`), and a fuzz-corpus
/// case (`Frame::V` inside `mod wire_fuzz` of the exec integration
/// tests). Parses the `pub enum Tag` block out of `exec/wire.rs`, so a
/// newly appended frame that misses any of the three fails the audit
/// instead of failing in production with "unknown wire frame tag".
pub(crate) fn wire_tag_coverage(
    files: &[SourceFile],
    tests_dir: &Path,
    out: &mut Vec<Finding>,
) -> Result<()> {
    let Some(wire) = files.iter().find(|f| f.rel == "rust/src/exec/wire.rs") else {
        return Ok(()); // no wire module under this root (fixture tree)
    };
    let variants = parse_tag_variants(wire);
    let fuzz = fuzz_corpus_text(tests_dir)?;
    for (line, v) in &variants {
        if !wire.code.iter().any(|c| c.contains(&format!("Tag::{v} as u8"))) {
            out.push(Finding {
                rule: RULE_WIRE,
                file: wire.rel.clone(),
                line: *line,
                message: format!("Tag::{v} has no encode arm (`Tag::{v} as u8`)"),
            });
        }
        if !wire.code.iter().any(|c| c.contains(&format!("Some(Tag::{v})"))) {
            out.push(Finding {
                rule: RULE_WIRE,
                file: wire.rel.clone(),
                line: *line,
                message: format!("Tag::{v} has no decode arm (`Some(Tag::{v}) =>`)"),
            });
        }
        match &fuzz {
            Some(corpus) if contains_token(corpus, &format!("Frame::{v}")) => {}
            Some(_) => out.push(Finding {
                rule: RULE_WIRE,
                file: wire.rel.clone(),
                line: *line,
                message: format!(
                    "Tag::{v} has no fuzz-corpus case (`Frame::{v}` in mod wire_fuzz \
                     of rust/tests/exec_backend.rs)"
                ),
            }),
            None => out.push(Finding {
                rule: RULE_WIRE,
                file: wire.rel.clone(),
                line: *line,
                message: "wire fuzz corpus not found (`mod wire_fuzz` in \
                          rust/tests/exec_backend.rs)"
                    .into(),
            }),
        }
    }
    Ok(())
}

/// `(line, name)` of each variant inside the `pub enum Tag { … }` block.
fn parse_tag_variants(wire: &SourceFile) -> Vec<(usize, String)> {
    let mut variants = Vec::new();
    let Some(start) = wire
        .code
        .iter()
        .position(|c| c.contains("pub enum Tag"))
    else {
        return variants;
    };
    for (i, code) in wire.code.iter().enumerate().skip(start + 1) {
        let t = code.trim();
        if t.starts_with('}') {
            break;
        }
        // `Hello = 1,`
        if let Some((name, _)) = t.split_once('=') {
            let name = name.trim();
            if !name.is_empty() && name.chars().all(|c| c.is_alphanumeric()) {
                variants.push((i + 1, name.to_string()));
            }
        }
    }
    variants
}

/// The stripped text of `mod wire_fuzz { … }` in the exec integration
/// tests (brace-counted extent), if present.
fn fuzz_corpus_text(tests_dir: &Path) -> Result<Option<String>> {
    let path = tests_dir.join("exec_backend.rs");
    if !path.is_file() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let raw: Vec<String> = text.lines().map(str::to_owned).collect();
    let code = strip_comments_and_strings(&raw);
    let Some(start) = code.iter().position(|c| c.contains("mod wire_fuzz")) else {
        return Ok(None);
    };
    let mut depth = 0i64;
    let mut opened = false;
    let mut block = String::new();
    for line in &code[start..] {
        block.push_str(line);
        block.push('\n');
        for ch in line.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            break;
        }
    }
    Ok(Some(block))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(rel: &str, src: &str) -> SourceFile {
        let raw: Vec<String> = src.lines().map(str::to_owned).collect();
        let code = strip_comments_and_strings(&raw);
        SourceFile {
            rel: rel.to_string(),
            raw,
            code,
        }
    }

    #[test]
    fn unsafe_rule_accepts_safety_above_attributes_and_same_line() {
        let good = file(
            "rust/src/exec/x.rs",
            "// SAFETY: fine\n#[cfg(unix)]\nunsafe { a(); }\n\
             let v = c.with(|p| unsafe { (*p).clone() }); // SAFETY: owned\n",
        );
        let mut out = Vec::new();
        unsafe_safety_comment(&[good], &mut out);
        assert!(out.is_empty(), "{out:?}");

        let bad = file("rust/src/exec/x.rs", "// setup\nunsafe { a(); }\n");
        let mut out = Vec::new();
        unsafe_safety_comment(&[bad], &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn unsafe_rule_ignores_lint_names_and_prose() {
        let f = file(
            "rust/src/lib.rs",
            "#![deny(unsafe_op_in_unsafe_fn)]\n// unsafe is discussed here\n",
        );
        let mut out = Vec::new();
        unsafe_safety_comment(&[f], &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn det_rules_fire_only_in_det_critical_files() {
        let src = "use std::collections::HashMap;\nlet t = Instant::now();\n\
                   let s: f32 = xs.iter().sum();\n";
        let critical = file("rust/src/drl/x.rs", src);
        let free = file("rust/src/exec/x.rs", src);
        let mut out = Vec::new();
        det_hash_collections(&[critical, free], &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].file, "rust/src/drl/x.rs");

        let critical = file("rust/src/coordinator/scheduler.rs", src);
        let mut out = Vec::new();
        det_wall_clock(&[critical], &mut out);
        assert_eq!(out.len(), 1);
        let critical = file("rust/src/cluster/des.rs", src);
        let mut out = Vec::new();
        f32_sum_in_scored_path(&[critical], &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn sum_rule_accepts_explicit_non_f32_accumulators() {
        let f = file(
            "rust/src/cluster/planner.rs",
            "let a = xs.iter().sum::<f64>();\nlet b: usize = ys.iter().sum::<usize>();\n",
        );
        let mut out = Vec::new();
        f32_sum_in_scored_path(&[f], &mut out);
        assert!(out.is_empty(), "{out:?}");
        let f = file("rust/src/cluster/planner.rs", "let c = zs.iter().sum::<f32>();\n");
        let mut out = Vec::new();
        f32_sum_in_scored_path(&[f], &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn clock_rule_flags_the_telemetry_choke_point_too() {
        let f = file(
            "rust/src/drl/trainer.rs",
            "let t0 = crate::util::clock::telemetry_now();\n",
        );
        let mut out = Vec::new();
        det_wall_clock(&[f], &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
    }

    #[test]
    fn wire_rule_reports_missing_arms_and_corpus() {
        let wire = file(
            "rust/src/exec/wire.rs",
            "pub enum Tag {\n    Hello = 1,\n    Probe = 2,\n}\n\
             fn enc() { buf.push(Tag::Hello as u8); }\n\
             fn dec() { match t { Some(Tag::Hello) => {} } }\n",
        );
        // fixture tests dir with a corpus that only covers Hello
        let dir = std::env::temp_dir().join(format!("audit-wire-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("exec_backend.rs"),
            "mod wire_fuzz {\n    fn corpus() { let _ = Frame::Hello; }\n}\n",
        )
        .unwrap();
        let mut out = Vec::new();
        wire_tag_coverage(&[wire], &dir, &mut out).unwrap();
        let msgs: Vec<&str> = out.iter().map(|f| f.message.as_str()).collect();
        assert_eq!(out.len(), 3, "{msgs:?}"); // Probe: encode + decode + corpus
        assert!(msgs.iter().all(|m| m.contains("Probe")), "{msgs:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
